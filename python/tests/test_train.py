"""Trainer substrate tests: data mixes, loss weighting, mask augmentation."""

import numpy as np
import pytest

from compile import corpus
from compile import train as T
from compile.model import CONFIGS


def test_batches_shapes_and_mix():
    gen = T.batches(7, 8, 64, mix=(0.25, 0.5, 0.25))
    arr = next(gen)
    assert arr.shape == (8, 65)
    assert arr.dtype == np.int32
    assert arr.min() >= 0 and arr.max() < corpus.VOCAB


def test_batches_deterministic():
    a = next(T.batches(3, 4, 32))
    b = next(T.batches(3, 4, 32))
    np.testing.assert_array_equal(a, b)


def test_repeat_doc_repeats():
    rng = corpus.Rng(5)
    doc = T.repeat_doc(rng, 100)
    assert len(doc) == 100
    assert doc[0] == corpus.BOS
    body = doc[1:]
    # find the segment period: body is seg tiled
    for period in range(8, 25):
        if body[:period] == body[period : 2 * period]:
            break
    else:
        pytest.fail("no repetition found")


def test_loss_weights_upweight_phrases():
    toks = np.array([[corpus.BOS, 20, corpus.SEP, 30, 31, 32, 33, 20, 20, 20]], np.int32)
    w = T.loss_weights(toks)
    assert w.shape == (1, 9)
    # targets following SEP (positions 2..5 predict 30,31,32,33) get weight 3
    assert w[0, 2] == 3.0 and w[0, 5] == 3.0
    assert w[0, 0] == 1.0 and w[0, 8] == 1.0


def test_streaming_mask_shape_and_semantics():
    m = T.streaming_mask(16, 4, sink=2, recent=4)
    assert m.shape == (4, 16, 16)
    # sinks always visible
    assert m[0, 15, 0] == 0.0 and m[0, 15, 1] == 0.0
    # recent window visible
    assert m[0, 15, 14] == 0.0
    # middle masked
    assert m[0, 15, 7] < -1e20


def test_ladder_mask_layers_differ():
    m = T.ladder_mask(64, 8, sink=2, recent=8, span=2, seg=8)
    assert m.shape == (8, 64, 64)
    assert not np.array_equal(m[0], m[4])
    # every layer keeps sinks + recency
    for l in range(8):
        assert m[l, 60, 0] == 0.0
        assert m[l, 60, 59] == 0.0


def test_sample_masks_distribution():
    rng = np.random.default_rng(0)
    kinds = {"full": 0, "other": 0}
    for _ in range(60):
        # t must exceed the max sampled recency window (128) or streaming
        # masks degenerate to fully-visible
        m = T.sample_masks(rng, 160, 4)
        if float(np.abs(m).sum()) == 0.0:
            kinds["full"] += 1
        else:
            kinds["other"] += 1
    assert kinds["full"] > 10 and kinds["other"] > 10


def test_adam_converges_quadratic():
    import jax
    import jax.numpy as jnp

    params = {"x": jnp.array([5.0, -3.0])}
    opt = T.adam_init(params)
    for _ in range(300):
        grads = {"x": 2 * params["x"]}
        params, opt = T.adam_update(params, grads, opt, lr=0.1)
    assert float(jnp.abs(params["x"]).max()) < 1e-2
