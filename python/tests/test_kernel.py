"""Layer-1 correctness: Pallas ladder_decode_attention vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel that serves LaCache's
decode hot path. hypothesis sweeps shapes and valid-lengths; explicit cases
pin the edge conditions (empty cache, single slot, full cache, block
boundaries).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ladder_attention import (
    DEFAULT_BLOCK_C,
    ladder_decode_attention,
    vmem_footprint_bytes,
)
from compile.kernels.ref import decode_attention_ref, window_attention_ref


def run_case(h, c, dh, length, seed, block_c=DEFAULT_BLOCK_C, scale=1.0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(h, dh)) * scale, jnp.float32)
    k = jnp.asarray(rng.normal(size=(h, c, dh)) * scale, jnp.float32)
    v = jnp.asarray(rng.normal(size=(h, c, dh)) * scale, jnp.float32)
    got = ladder_decode_attention(q, k, v, jnp.int32(length), block_c=block_c)
    want = decode_attention_ref(q, k, v, jnp.int32(length))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("length", [0, 1, 2, 63, 64, 65, 127, 128])
def test_edge_lengths(length):
    run_case(4, 128, 16, length, seed=length)


@pytest.mark.parametrize("h", [1, 2, 4, 8])
def test_heads(h):
    run_case(h, 64, 24, 40, seed=h)


@pytest.mark.parametrize("c", [64, 128, 256, 512])
def test_cache_sizes(c):
    run_case(4, c, 16, c // 2, seed=c)


@pytest.mark.parametrize("dh", [8, 16, 24, 32, 64])
def test_head_dims(dh):
    run_case(4, 128, dh, 77, seed=dh)


@pytest.mark.parametrize("block_c", [16, 32, 64, 128])
def test_block_sizes(block_c):
    run_case(4, 128, 16, 100, seed=block_c, block_c=block_c)


def test_large_scores_stable():
    """Online softmax must be stable under large score magnitudes."""
    run_case(2, 128, 16, 90, seed=0, scale=8.0)


def test_garbage_in_masked_slots_ignored():
    """Slots >= length may hold arbitrary garbage (stale KV) — masked out."""
    rng = np.random.default_rng(3)
    h, c, dh, length = 4, 128, 16, 50
    q = jnp.asarray(rng.normal(size=(h, dh)), jnp.float32)
    k = np.asarray(rng.normal(size=(h, c, dh)), np.float32)
    v = np.asarray(rng.normal(size=(h, c, dh)), np.float32)
    k2, v2 = k.copy(), v.copy()
    k2[:, length:] = 1e9
    v2[:, length:] = -1e9
    a = ladder_decode_attention(q, jnp.asarray(k), jnp.asarray(v), jnp.int32(length))
    b = ladder_decode_attention(q, jnp.asarray(k2), jnp.asarray(v2), jnp.int32(length))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    h=st.integers(1, 6),
    c_blocks=st.integers(1, 6),
    dh=st.sampled_from([8, 16, 24, 32]),
    data=st.data(),
)
def test_hypothesis_sweep(h, c_blocks, dh, data):
    c = 32 * c_blocks
    length = data.draw(st.integers(0, c))
    seed = data.draw(st.integers(0, 2**31 - 1))
    run_case(h, c, dh, length, seed=seed, block_c=32)


@settings(max_examples=15, deadline=None)
@given(c=st.sampled_from([64, 128]), data=st.data())
def test_window_ref_consistent_with_decode_ref(c, data):
    """The window oracle at W=1 with a valid-prefix cache must agree with the
    decode oracle (cross-validation of the two reference implementations)."""
    h, dh = 2, 16
    length = data.draw(st.integers(1, c))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    q = jnp.asarray(rng.normal(size=(1, h, dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(h, c, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(h, c, dh)), jnp.float32)
    kw = jnp.asarray(rng.normal(size=(1, h, dh)), jnp.float32)
    vw = jnp.asarray(rng.normal(size=(1, h, dh)), jnp.float32)
    out_w = window_attention_ref(q, kc, vc, kw, vw, jnp.int32(length))[0]
    # decode oracle over the concatenated [cache ; self] keys: move the window
    # key adjacent to the valid prefix so a single `length+1` mask covers it
    k_all = jnp.concatenate([kc, jnp.swapaxes(kw, 0, 1)], axis=1)
    v_all = jnp.concatenate([vc, jnp.swapaxes(vw, 0, 1)], axis=1)
    idx = jnp.concatenate([jnp.arange(length), jnp.array([c]),
                           jnp.arange(length, c)])
    out_d = decode_attention_ref(q[0], k_all[:, idx], v_all[:, idx], jnp.int32(length + 1))
    np.testing.assert_allclose(out_w, out_d, rtol=3e-5, atol=3e-5)


def test_rejects_misaligned_block():
    with pytest.raises(ValueError):
        run_case(2, 100, 16, 10, seed=0, block_c=64)


def test_vmem_footprint_reported():
    b = vmem_footprint_bytes(4, 256, 24)
    assert 0 < b < 16 * 2**20  # fits VMEM with huge margin
