"""Synthetic corpus generator invariants + determinism (the rust side mirrors
this generator; parity is asserted there against corpus_golden.json)."""

import pytest

from compile import corpus


def test_deterministic():
    a = corpus.take(123, 1000)
    b = corpus.take(123, 1000)
    assert a == b


def test_seeds_differ():
    assert corpus.take(1, 500) != corpus.take(2, 500)


def test_token_range():
    toks = corpus.take(9, 3000)
    assert all(0 <= t < corpus.VOCAB for t in toks)
    # no stray tokens between specials and words
    assert all(t < 6 or t >= corpus.WORD_BASE for t in toks)


def test_doc_structure():
    rng = corpus.Rng(5)
    doc = corpus.gen_doc(rng, 400)
    assert len(doc) == 400
    assert doc[0] == corpus.BOS
    # every MARK is followed by name + SEP + phrase (unless truncated)
    i = 0
    found = 0
    while i < len(doc) - (corpus.NAME_LEN + 1 + corpus.PHRASE_LEN):
        if doc[i] == corpus.MARK:
            assert doc[i + 1 + corpus.NAME_LEN] == corpus.SEP
            found += 1
            i += 1 + corpus.NAME_LEN + 1 + corpus.PHRASE_LEN
        else:
            i += 1
    assert found >= 1


def test_re_mention_repeats_phrase():
    """A re-mention of an entity repeats the exact intro surface form —
    the long-range predictability signal."""
    rng = corpus.Rng(1234)
    doc = corpus.gen_doc(rng, 1500, n_ent=2)
    seqs = {}
    i = 0
    span = 1 + corpus.NAME_LEN + 1 + corpus.PHRASE_LEN
    repeats = 0
    while i < len(doc) - span:
        if doc[i] == corpus.MARK:
            name = tuple(doc[i + 1 : i + 1 + corpus.NAME_LEN])
            phrase = tuple(doc[i + 2 + corpus.NAME_LEN : i + span])
            if name in seqs:
                assert seqs[name] == phrase
                repeats += 1
            seqs[name] = phrase
            i += span
        else:
            i += 1
    assert repeats >= 1


def test_rng_golden():
    """SplitMix64 reference values (mirrored in rust/src/util/rng.rs tests)."""
    r = corpus.Rng(0)
    vals = [r.next_u64() for _ in range(3)]
    assert vals == [16294208416658607535, 7960286522194355700, 487617019471545679]


def test_succ_pure():
    assert corpus.succ(20, 0) == corpus.succ(20, 0)
    assert 16 <= corpus.succ(20, 1) < 256


def test_stream_matches_concat_docs():
    toks = corpus.take(77, 700)
    assert toks[0] == corpus.BOS
    assert corpus.BOS in toks[1:]  # stream crosses at least one doc boundary
