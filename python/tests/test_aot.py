"""AOT pipeline: program inventory + HLO text sanity (full round-trip through
PJRT is exercised on the rust side)."""

import json
import os

import pytest

from compile.aot import C_FULL, C_SMALL, GEN_KS, SCORE_WINDOWS, program_specs
from compile.model import CONFIGS, n_params

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_program_inventory():
    names = [n for n, _, _, _ in program_specs(CONFIGS["mini"])]
    for w in SCORE_WINDOWS:
        assert f"score_w{w}_c{C_SMALL}" in names
        assert f"score_w{w}_c{C_FULL}" in names
        assert f"score_scored_w{w}_c{C_SMALL}" in names
    for k in GEN_KS:
        assert f"generate_k{k}_c{C_SMALL}" in names
    assert f"generate_scored_k16_c{C_SMALL}" in names


def test_spec_shapes_consistent():
    cfg = CONFIGS["mini"]
    for name, _, specs, meta in program_specs(cfg):
        assert specs[0].shape == (n_params(cfg),)
        if meta["kind"] == "score":
            assert specs[1].shape == (meta["w"],)
            assert specs[3].shape[2] == meta["c"]
        else:
            assert specs[1].shape[2] == meta["c"]
        assert len(specs) == len(meta["inputs"])


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_complete():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    assert {m["name"] for m in man["models"]} >= {"base", "mini"}
    for m in man["models"]:
        cfg = CONFIGS[m["name"]]
        assert m["n_params"] == n_params(cfg)
        for prog, meta in m["programs"].items():
            path = os.path.join(ART, meta["path"])
            assert os.path.exists(path), path
            text = open(path).read()
            assert "ENTRY" in text and text.startswith("HloModule")


@needs_artifacts
def test_corpus_golden_exported():
    with open(os.path.join(ART, "corpus_golden.json")) as f:
        g = json.load(f)
    assert set(g["streams"].keys()) == {"1", "42", "20250711"}
    from compile import corpus
    for seed, toks in g["streams"].items():
        assert toks[:64] == corpus.take(int(seed), 64)
