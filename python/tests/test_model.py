"""Layer-2 invariants: cache threading, window/generate/full-forward
consistency, weight packing, RoPE semantics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import (
    CONFIGS,
    generate,
    init_params,
    n_params,
    pack,
    rope,
    score_window,
    train_forward,
    unpack,
    weight_spec,
)

CFG = CONFIGS["mini"]


@pytest.fixture(scope="module")
def flat_w():
    return pack(init_params(CFG, seed=11), CFG)


def empty_cache(c=128):
    L, H, Dh = CFG.n_layers, CFG.n_heads, CFG.head_dim
    return (jnp.zeros((L, H, c, Dh), jnp.float32),
            jnp.zeros((L, H, c, Dh), jnp.float32),
            jnp.zeros((L,), jnp.int32))


def toks(seed, n):
    return jnp.asarray(np.random.default_rng(seed).integers(0, CFG.vocab, n), jnp.int32)


def test_pack_unpack_roundtrip(flat_w):
    params = unpack(flat_w, CFG)
    flat2 = pack(params, CFG)
    np.testing.assert_array_equal(flat_w, flat2)
    assert flat_w.shape == (n_params(CFG),)


def test_weight_spec_shapes():
    spec = weight_spec(CFG)
    names = [n for n, _ in spec]
    assert names[0] == "embed" and names[-1] == "ln_f"
    assert len([n for n in names if n.endswith(".wq")]) == CFG.n_layers


def test_score_empty_cache_matches_full_forward(flat_w):
    """Teacher-forced logprobs with an empty cache == plain causal forward."""
    t = toks(0, 17)
    tgt = toks(1, 17)
    kc, vc, lens = empty_cache()
    lp, _, _ = score_window(CFG, flat_w, t, tgt, kc, vc, lens)
    params = unpack(flat_w, CFG)
    masks = jnp.zeros((CFG.n_layers, 17, 17), jnp.float32)
    logits = train_forward(CFG, params, t[None], masks)[0]
    want = jnp.take_along_axis(jax.nn.log_softmax(logits, -1), tgt[:, None], -1)[:, 0]
    np.testing.assert_allclose(lp, want, rtol=2e-4, atol=2e-4)


def test_split_window_equals_single_window(flat_w):
    """Scoring [0:8] then [8:16] with full KV carry == scoring [0:16] at once."""
    t = toks(2, 16)
    tgt = toks(3, 16)
    kc, vc, lens = empty_cache()
    lp_full, _, _ = score_window(CFG, flat_w, t, tgt, kc, vc, lens)

    lp1, wk1, wv1 = score_window(CFG, flat_w, t[:8], tgt[:8], kc, vc, lens)
    # merge window KV into the cache unevicted (rust would do this)
    kc2 = kc.at[:, :, 0:8, :].set(wk1)
    vc2 = vc.at[:, :, 0:8, :].set(wv1)
    lens2 = lens + 8
    lp2, _, _ = score_window(CFG, flat_w, t[8:], tgt[8:], kc2, vc2, lens2)
    got = jnp.concatenate([lp1, lp2])
    np.testing.assert_allclose(got, lp_full, rtol=2e-4, atol=2e-4)


def test_generate_pallas_matches_jnp(flat_w):
    """The Pallas decode path and the materialized-softmax path agree."""
    kc, vc, lens = empty_cache()
    out_p = generate(CFG, flat_w, kc, vc, lens, jnp.int32(5), 8, use_pallas=True)
    out_j = generate(CFG, flat_w, kc, vc, lens, jnp.int32(5), 8, use_pallas=False)
    np.testing.assert_array_equal(out_p[0], out_j[0])  # identical greedy tokens
    np.testing.assert_allclose(out_p[1], out_j[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(out_p[4], out_j[4])


def test_generate_appends_cache(flat_w):
    kc, vc, lens = empty_cache()
    tokens, _, kc2, vc2, lens2 = generate(CFG, flat_w, kc, vc, lens, jnp.int32(1), 4)
    assert tokens.shape == (4,)
    np.testing.assert_array_equal(np.asarray(lens2), np.full(CFG.n_layers, 4))
    # appended slots are non-zero, untouched slots remain zero
    assert float(jnp.abs(kc2[:, :, :4]).sum()) > 0
    assert float(jnp.abs(kc2[:, :, 4:]).sum()) == 0


def test_generate_consistent_with_score(flat_w):
    """Greedy tokens from generate() must be argmaxes under score_window's
    teacher-forced view of the same prefix."""
    kc, vc, lens = empty_cache()
    start = jnp.int32(7)
    tokens, _, _, _, _ = generate(CFG, flat_w, kc, vc, lens, start, 4)
    seq = jnp.concatenate([jnp.array([start], jnp.int32), tokens])
    # score the sequence: logprob target positions = next tokens
    lp, _, _ = score_window(CFG, flat_w, seq[:-1], seq[1:], kc, vc, lens)
    # every generated token was the greedy choice => its logprob is the max
    # over the vocab; verify via a second scoring against a perturbed target
    rng = np.random.default_rng(0)
    for i in range(4):
        alt = jnp.int32((int(seq[i + 1]) + 1 + rng.integers(0, CFG.vocab - 2)) % CFG.vocab)
        tgt2 = seq[1:].at[i].set(alt)
        lp2, _, _ = score_window(CFG, flat_w, seq[:-1], tgt2, kc, vc, lens)
        assert float(lp[i]) >= float(lp2[i]) - 1e-5


def test_scored_mass_sums_to_queries(flat_w):
    """Attention mass per layer sums to (#queries x #heads)."""
    t = toks(4, 12)
    kc, vc, lens = empty_cache()
    lp, _, _, mass = score_window(CFG, flat_w, t, t, kc, vc, lens, with_mass=True)
    total = np.asarray(jnp.sum(mass, axis=1))
    np.testing.assert_allclose(total, np.full(CFG.n_layers, 12.0 * CFG.n_heads), rtol=1e-4)


def test_mass_zero_on_invalid_cache_slots(flat_w):
    t = toks(5, 8)
    kc, vc, lens = empty_cache(64)
    _, _, _, mass = score_window(CFG, flat_w, t, t, kc, vc, lens, with_mass=True)
    # empty cache -> all mass on window part
    np.testing.assert_allclose(np.asarray(mass[:, :64]).sum(), 0.0, atol=1e-6)


def test_rope_relative_shift_invariance():
    """RoPE inner products depend only on position differences."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    def ip(p, q):
        return float(jnp.dot(rope(x, jnp.float32(p), 10000.0),
                             rope(y, jnp.float32(q), 10000.0)))
    assert abs(ip(5, 3) - ip(105, 103)) < 1e-3
    assert abs(ip(0, 0) - ip(77, 77)) < 1e-3


def test_budget_masking_equivalence(flat_w):
    """A cache padded to larger C with the same valid prefix gives identical
    logprobs — the property that lets one compiled C serve every budget."""
    t = toks(6, 8)
    kc64, vc64, _ = empty_cache(64)
    kc128, vc128, _ = empty_cache(128)
    rng = np.random.default_rng(1)
    fill_k = jnp.asarray(rng.normal(size=(CFG.n_layers, CFG.n_heads, 20, CFG.head_dim)), jnp.float32)
    fill_v = jnp.asarray(rng.normal(size=(CFG.n_layers, CFG.n_heads, 20, CFG.head_dim)), jnp.float32)
    kc64 = kc64.at[:, :, :20].set(fill_k); vc64 = vc64.at[:, :, :20].set(fill_v)
    kc128 = kc128.at[:, :, :20].set(fill_k); vc128 = vc128.at[:, :, :20].set(fill_v)
    lens = jnp.full((CFG.n_layers,), 20, jnp.int32)
    lp64, _, _ = score_window(CFG, flat_w, t, t, kc64, vc64, lens)
    lp128, _, _ = score_window(CFG, flat_w, t, t, kc128, vc128, lens)
    np.testing.assert_allclose(lp64, lp128, rtol=1e-5, atol=1e-5)
