"""Build-time trainer for the tiny substitute models (DESIGN.md §6).

Trains the Layer-2 model on the synthetic long-range corpus so that the
mechanisms the paper's evaluation exercises actually exist in the weights:
  - entity re-mention -> long-range PPL signal (copy/induction heads),
  - QUERY/ANSWER pairs -> associative recall (NIAH / RULER substrate),
  - position-OOD explosion past t_train (full-cache PPL blowup in Tab. 1/Fig. 5),
  - *ladder-robustness augmentation*: a fraction of batches are trained under
    randomly sampled per-layer retention masks (full / streaming / ladder) so
    the model tolerates layer-heterogeneous context the way large pretrained
    LLMs empirically do. This replaces "use a pretrained Llama".

Runs once at build time (`make artifacts`); outputs artifacts/<model>/weights.bin.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import CONFIGS, ModelConfig, init_params, n_params, pack, train_forward

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Retention-mask augmentation
# ---------------------------------------------------------------------------

def streaming_mask(t: int, n_layers: int, sink: int, recent: int) -> np.ndarray:
    i = np.arange(t)[:, None]
    j = np.arange(t)[None, :]
    keep = (j < sink) | (i - j < recent)
    m = np.where(keep, 0.0, NEG_INF).astype(np.float32)
    return np.broadcast_to(m, (n_layers, t, t)).copy()

def ladder_mask(t: int, n_layers: int, sink: int, recent: int, span: int, seg: int) -> np.ndarray:
    """Per-layer banded retention: each layer-group keeps a different band of
    the older context, approximating what LaCache retention looks like from a
    query's point of view."""
    i = np.arange(t)[:, None]
    j = np.arange(t)[None, :]
    base = (j < sink) | (i - j < recent)
    n_groups = max(1, n_layers // span)
    dist = i - j - recent  # >= 0 for "older" keys
    rung = (dist // max(seg, 1)) % n_groups
    out = np.empty((n_layers, t, t), np.float32)
    for l in range(n_layers):
        keep = base | ((dist >= 0) & (rung == (l // span) % n_groups))
        out[l] = np.where(keep, 0.0, NEG_INF)
    return out

def sample_masks(rng: np.random.Generator, t: int, n_layers: int) -> np.ndarray:
    r = rng.random()
    if r < 0.5:
        return np.zeros((n_layers, t, t), np.float32)
    if r < 0.7:
        recent = int(rng.integers(24, 128))
        return streaming_mask(t, n_layers, 4, recent)
    recent = int(rng.integers(16, 64))
    span = int(rng.choice([1, 2, 4]))
    seg = int(rng.integers(16, 64))
    return ladder_mask(t, n_layers, 4, recent, span, seg)


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------

def recall_doc(rng: corpus.Rng, doclen: int, n_ent: int = 6):
    """Recall-dense training document: frequent intros / re-mentions / queries.

    Training-only distribution (the eval corpus stays corpus.gen_doc); it
    densifies the copy/recall signal so induction heads form at this scale.
    """
    toks = [corpus.BOS]
    ents = []
    prev = corpus.draw_word(rng)
    while len(toks) < doclen:
        a = rng.below(4)
        if (a == 0 and len(ents) < n_ent) or not ents:
            name = [corpus.draw_name(rng) for _ in range(corpus.NAME_LEN)]
            phrase = [corpus.draw_word(rng) for _ in range(corpus.PHRASE_LEN)]
            ents.append((name, phrase))
            toks += [corpus.MARK] + name + [corpus.SEP] + phrase
            prev = phrase[-1]
        elif a == 1:
            i = rng.below(len(ents))
            name, phrase = ents[i]
            toks += [corpus.MARK] + name + [corpus.SEP] + phrase
            prev = phrase[-1]
        elif a == 2:
            i = rng.below(len(ents))
            name, phrase = ents[i]
            toks += [corpus.QUERY] + name + [corpus.ANSWER] + phrase
            prev = phrase[-1]
        else:
            run = 2 + rng.below(8)
            for _ in range(run):
                if rng.next_u64() & 1:
                    prev = corpus.succ(prev, rng.below(4))
                else:
                    prev = corpus.draw_word(rng)
                toks.append(prev)
    return toks[:doclen]


def repeat_doc(rng: corpus.Rng, doclen: int):
    """Repeated random segment — the densest induction signal (drives the
    induction-head phase transition that entity recall then reuses)."""
    seg_len = 8 + int(rng.below(17))
    seg = [corpus.draw_word(rng) for _ in range(seg_len)]
    toks = [corpus.BOS]
    while len(toks) < doclen:
        toks += seg
    return toks[:doclen]


def needle_doc(rng: corpus.Rng, doclen: int):
    """Variable-gap retrieval document: entity introduced early, background
    gap of RANDOM length, then re-mention/query. Defeats fixed-offset copy
    shortcuts — only content-addressed retrieval fits all gaps."""
    toks = [corpus.BOS]
    while len(toks) < doclen:
        name = [corpus.draw_name(rng) for _ in range(corpus.NAME_LEN)]
        phrase = [corpus.draw_word(rng) for _ in range(corpus.PHRASE_LEN)]
        toks += [corpus.MARK] + name + [corpus.SEP] + phrase
        gap = 1 + int(rng.below(180))
        prev = corpus.draw_word(rng)
        for _ in range(gap):
            if rng.next_u64() & 1:
                prev = corpus.succ(prev, rng.below(4))
            else:
                prev = corpus.draw_word(rng)
            toks.append(prev)
        if rng.next_u64() & 1:
            toks += [corpus.MARK] + name + [corpus.SEP] + phrase
        else:
            toks += [corpus.QUERY] + name + [corpus.ANSWER] + phrase
        # short pad so consecutive needles don't align
        pad = int(rng.below(9))
        for _ in range(pad):
            prev = corpus.succ(prev, rng.below(4))
            toks.append(prev)
    return toks[:doclen]


def batches(seed: int, batch: int, t: int, mix=(0.25, 0.5, 0.25)):
    """Yield [B, T+1] i32 batches. mix = (corpus, recall-dense, repeat) row
    fractions."""
    n_corpus = max(1, int(batch * mix[0]))
    n_repeat = int(batch * mix[2])
    n_recall = batch - n_corpus - n_repeat
    streams = [corpus.stream(seed * 1000 + b, 160, 320) for b in range(n_corpus)]
    rngs = [corpus.Rng(seed * 131 + 7 * b + 1) for b in range(n_recall)]
    rep_rngs = [corpus.Rng(seed * 977 + 13 * b + 5) for b in range(n_repeat)]
    bufs = [[] for _ in range(n_recall)]
    rep_bufs = [[] for _ in range(n_repeat)]
    while True:
        arr = np.empty((batch, t + 1), np.int32)
        for b, s in enumerate(streams):
            for u in range(t + 1):
                arr[b, u] = next(s)
        for b in range(n_recall):
            while len(bufs[b]) < t + 1:
                bufs[b] += recall_doc(rngs[b], 160 + int(rngs[b].below(160)))
            arr[n_corpus + b] = bufs[b][: t + 1]
            bufs[b] = bufs[b][t + 1 :]
        for b in range(n_repeat):
            while len(rep_bufs[b]) < t + 1:
                rep_bufs[b] += needle_doc(rep_rngs[b], 200 + int(rep_rngs[b].below(120)))
            arr[n_corpus + n_recall + b] = rep_bufs[b][: t + 1]
            rep_bufs[b] = rep_bufs[b][t + 1 :]
        yield arr


def loss_weights(toks: np.ndarray) -> np.ndarray:
    """Per-target weights [B, T]: upweight phrase tokens following SEP/ANSWER
    (the long-range-recall positions the evaluation measures)."""
    b, t1 = toks.shape
    w = np.ones((b, t1 - 1), np.float32)
    is_trigger = (toks == corpus.SEP) | (toks == corpus.ANSWER)
    for d in range(corpus.PHRASE_LEN):
        # target at position i is toks[:, i+1]; trigger at toks[:, i-d]
        trig = is_trigger[:, : t1 - 1 - d]
        w[:, d:] += 2.0 * trig
    return w


# ---------------------------------------------------------------------------
# Optimizer (Adam, hand-rolled — no optax needed)
# ---------------------------------------------------------------------------

def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}

def adam_update(params, grads, state, lr, b1=0.9, b2=0.98, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# Training loop
# ---------------------------------------------------------------------------

def train(cfg: ModelConfig, steps: int, batch: int, t: int, seed: int, lr_max: float,
          log_every: int = 25):
    params = init_params(cfg, seed)
    opt = adam_init(params)

    def loss_fn(p, toks, masks, w):
        logits = train_forward(cfg, p, toks[:, :-1], masks)
        lp = jax.nn.log_softmax(logits, axis=-1)
        tgt = toks[:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * w) / jnp.sum(w)

    @jax.jit
    def step_fn(p, o, toks, masks, w, lr):
        loss, grads = jax.value_and_grad(loss_fn)(p, toks, masks, w)
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        p2, o2 = adam_update(p, grads, o, lr)
        return p2, o2, loss, gn

    rng = np.random.default_rng(seed)
    # Curriculum: phase 1 concentrates the copy/recall signal (no mask
    # augmentation) until induction heads form; phase 2 is the mixed
    # distribution with ladder-robustness augmentation.
    phase1_steps = int(steps * 0.5)
    gen1 = batches(seed, batch, t, mix=(0.125, 0.25, 0.625))
    gen2 = batches(seed + 1, batch, t, mix=(0.5, 0.375, 0.125))
    warmup = max(10, steps // 20)
    log = []
    t0 = time.time()
    for s in range(steps):
        phase1 = s < phase1_steps
        toks = next(gen1 if phase1 else gen2)
        masks = (np.zeros((cfg.n_layers, t, t), np.float32) if phase1
                 else sample_masks(rng, t, cfg.n_layers))
        w = loss_weights(toks)
        frac = max(0.0, (s - warmup) / max(1, steps - warmup))
        lr = lr_max * (s + 1) / warmup if s < warmup else lr_max * 0.5 * (1 + np.cos(np.pi * frac))
        params, opt, loss, gn = step_fn(params, opt, jnp.asarray(toks), jnp.asarray(masks),
                                        jnp.asarray(w), jnp.float32(lr))
        if s % log_every == 0 or s == steps - 1:
            loss = float(loss)
            log.append({"step": s, "loss": loss, "lr": float(lr),
                        "elapsed_s": round(time.time() - t0, 1)})
            print(f"[{cfg.name}] step {s:4d} loss {loss:.4f} gnorm {float(gn):.2f} "
                  f"lr {lr:.2e} ({time.time()-t0:.0f}s)", flush=True)
    return params, log


def recall_accuracy(cfg: ModelConfig, params, n_cases: int = 20, gap: int = 120):
    """Fraction of phrase tokens recovered greedily after a re-mention trigger
    placed `gap` background tokens after the introduction."""
    hits, total = 0, 0
    for case in range(n_cases):
        rng = corpus.Rng(50_000 + case)
        name = [corpus.draw_name(rng) for _ in range(corpus.NAME_LEN)]
        phrase = [corpus.draw_word(rng) for _ in range(corpus.PHRASE_LEN)]
        doc = [corpus.BOS, corpus.MARK] + name + [corpus.SEP] + phrase
        prev = corpus.draw_word(rng)
        for _ in range(gap):
            prev = corpus.succ(prev, rng.below(4))
            doc.append(prev)
        doc += [corpus.MARK] + name + [corpus.SEP]
        cur = list(doc)
        for i in range(corpus.PHRASE_LEN):
            tok = jnp.asarray(cur, jnp.int32)[None]
            m = jnp.zeros((cfg.n_layers, tok.shape[1], tok.shape[1]), jnp.float32)
            logits = train_forward(cfg, params, tok, m)[0]
            nxt = int(jnp.argmax(logits[-1]))
            hits += int(nxt == phrase[i])
            total += 1
            cur.append(phrase[i])  # teacher-forced continuation
    return hits / total


def holdout_ppl(cfg: ModelConfig, params, seed: int = 7777, n_seq: int = 4, t: int = 256):
    gen = batches(seed, n_seq, t)
    toks = jnp.asarray(next(gen))
    masks = jnp.zeros((cfg.n_layers, t, t), jnp.float32)
    logits = train_forward(cfg, params, toks[:, :-1], masks)
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, toks[:, 1:][..., None], axis=-1)[..., 0]
    return float(jnp.exp(jnp.mean(nll)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="base,mini")
    ap.add_argument("--steps-base", type=int, default=2200)
    ap.add_argument("--steps-mini", type=int, default=1200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    for name in args.models.split(","):
        cfg = CONFIGS[name]
        outdir = os.path.join(args.out, name)
        wpath = os.path.join(outdir, "weights.bin")
        if os.path.exists(wpath) and not args.force:
            print(f"[{name}] weights exist, skipping (use --force to retrain)")
            continue
        os.makedirs(outdir, exist_ok=True)
        steps = args.steps_base if name == "base" else args.steps_mini
        t = args.seq if name == "base" else min(args.seq, cfg.t_train)
        print(f"[{name}] training {n_params(cfg)} params, {steps} steps, seq {t}")
        params, log = train(cfg, steps, args.batch, t, args.seed, args.lr)
        ppl = holdout_ppl(cfg, params)
        rec = recall_accuracy(cfg, params)
        print(f"[{name}] holdout full-attention ppl = {ppl:.3f}, recall acc = {rec:.3f}")
        flat = np.asarray(pack(params, cfg), np.float32)
        flat.tofile(wpath)
        with open(os.path.join(outdir, "train_log.json"), "w") as f:
            json.dump({"model": name, "steps": steps, "holdout_ppl": ppl,
                       "recall_acc": rec, "log": log}, f, indent=1)
        print(f"[{name}] wrote {wpath} ({flat.nbytes} bytes)")


if __name__ == "__main__":
    main()
