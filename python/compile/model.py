"""Layer-2: tiny Llama-architecture decoder in JAX with an explicit,
per-layer compacted KV cache threaded through every program.

Architecture: RMSNorm, rotary positions (cache-relative, StreamingLLM-style:
keys are stored PRE-RoPE and rotated at attention time by their *slot index*,
so eviction/compaction automatically re-packs positions — exactly the position
handling LaCache inherits from StreamingLLM), multi-head attention, SwiGLU MLP,
tied embeddings.

Programs lowered by aot.py (python never runs at serve time):
  score    : W teacher-forced tokens over the resident cache -> per-token
             logprobs + the window's (pre-RoPE) K/V for the rust policy layer.
  scored   : same + per-slot attention mass (the *slow path* that
             H2O/TOVA/SnapKV/PyramidInfer require; LaCache never calls it).
  generate : K greedy decode steps with in-graph cache append, decode
             attention via the Layer-1 Pallas kernel.

The rust coordinator owns eviction: between program calls it gathers the
per-layer caches according to the active policy (LaCache ladder, StreamingLLM,
H2O, ...) and adjusts `lens`.
"""

from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.ladder_attention import ladder_decode_attention

NEG_INF = -1e30


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    n_layers: int
    n_heads: int
    d_model: int
    head_dim: int
    d_ff: int
    rope_theta: float
    t_train: int  # pretraining context length (positions seen in training)

    def to_dict(self):
        return asdict(self)


BASE = ModelConfig("base", 256, 8, 4, 96, 24, 192, 10000.0, 256)
MINI = ModelConfig("mini", 256, 4, 4, 64, 16, 128, 10000.0, 256)

CONFIGS = {c.name: c for c in (BASE, MINI)}


# ---------------------------------------------------------------------------
# Weights: flat f32 vector <-> named pytree. The flat form is the single
# runtime weights parameter the rust side uploads once per model.
# ---------------------------------------------------------------------------

def weight_spec(cfg: ModelConfig):
    """Ordered (name, shape) list defining the flat layout."""
    d, hd, f, v = cfg.d_model, cfg.n_heads * cfg.head_dim, cfg.d_ff, cfg.vocab
    spec = [("embed", (v, d))]
    for l in range(cfg.n_layers):
        spec += [
            (f"l{l}.ln1", (d,)),
            (f"l{l}.wq", (d, hd)),
            (f"l{l}.wk", (d, hd)),
            (f"l{l}.wv", (d, hd)),
            (f"l{l}.wo", (hd, d)),
            (f"l{l}.ln2", (d,)),
            (f"l{l}.wg", (d, f)),
            (f"l{l}.wu", (d, f)),
            (f"l{l}.wd", (f, d)),
        ]
    spec.append(("ln_f", (d,)))
    return spec


def n_params(cfg: ModelConfig) -> int:
    return sum(int(np.prod(s)) for _, s in weight_spec(cfg))


def unpack(flat, cfg: ModelConfig):
    params, off = {}, 0
    for name, shape in weight_spec(cfg):
        n = int(np.prod(shape))
        params[name] = flat[off : off + n].reshape(shape)
        off += n
    return params


def pack(params, cfg: ModelConfig):
    return jnp.concatenate([params[name].reshape(-1) for name, _ in weight_spec(cfg)])


def init_params(cfg: ModelConfig, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in weight_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            params[name] = jax.random.normal(sub, shape, jnp.float32) * (0.7 / np.sqrt(shape[0]))
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-5):
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def rope(x, pos, theta):
    """Rotate-half RoPE. x: [..., Dh]; pos broadcastable to x.shape[:-1]."""
    dh = x.shape[-1]
    half = dh // 2
    inv = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[..., None] * inv  # [..., half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# Fraction of heads that receive rotary position (the rest are NoPE —
# position-free content-matching heads). Mixed RoPE/NoPE attention makes
# content-addressed retrieval (induction) learnable at tiny scale and is an
# established design choice in production LLMs.
ROPE_HEAD_FRACTION = 0.5


def n_rope_heads(n_heads):
    return max(1, int(round(n_heads * ROPE_HEAD_FRACTION)))


def rope_heads(x, pos, theta, n_heads):
    """RoPE on the first n_rope heads only; x: [..., H, Dh]."""
    n_rope = n_rope_heads(n_heads)
    roped = rope(x[..., :n_rope, :], pos, theta)
    return jnp.concatenate([roped, x[..., n_rope:, :]], axis=-2)


def rope_lead_heads(x, pos, theta, n_heads):
    """RoPE on the first n_rope heads only; x: [H, ..., Dh] (heads leading)."""
    n_rope = n_rope_heads(n_heads)
    roped = rope(x[:n_rope], pos, theta)
    return jnp.concatenate([roped, x[n_rope:]], axis=0)


def _swiglu(h, params, l):
    g = h @ params[f"l{l}.wg"]
    u = h @ params[f"l{l}.wu"]
    return (jax.nn.silu(g) * u) @ params[f"l{l}.wd"]


def _qkv(h, params, l, cfg):
    q = (h @ params[f"l{l}.wq"]).reshape(h.shape[:-1] + (cfg.n_heads, cfg.head_dim))
    k = (h @ params[f"l{l}.wk"]).reshape(h.shape[:-1] + (cfg.n_heads, cfg.head_dim))
    v = (h @ params[f"l{l}.wv"]).reshape(h.shape[:-1] + (cfg.n_heads, cfg.head_dim))
    return q, k, v


# ---------------------------------------------------------------------------
# score: teacher-forced window over the resident cache
# ---------------------------------------------------------------------------

def score_window(cfg: ModelConfig, flat_w, tokens, targets, kcache, vcache, lens,
                 with_mass: bool = False):
    """W queries attend [cache(valid) ; window(causal)].

    tokens, targets: [W] i32; kcache/vcache: [L, H, C, Dh] (pre-RoPE keys);
    lens: [L] i32 valid-slot counts.
    Returns (logprobs[W], win_k[L,H,W,Dh], win_v[L,H,W,Dh][, mass[L,C+W]]).
    """
    params = unpack(flat_w, cfg)
    L, H, C, Dh = kcache.shape
    W = tokens.shape[0]
    x = params["embed"][tokens]  # [W, D]
    slot = jnp.arange(C)
    i_idx = jnp.arange(W)[:, None, None]
    u_idx = jnp.arange(W)[None, None, :]
    win_ks, win_vs, masses = [], [], []
    for l in range(cfg.n_layers):
        h = rmsnorm(x, params[f"l{l}.ln1"])
        q, k, v = _qkv(h, params, l, cfg)  # [W,H,Dh]
        win_ks.append(k)
        win_vs.append(v)
        pos_w = lens[l] + jnp.arange(W)
        q_r = rope_heads(q, pos_w[:, None], cfg.rope_theta, cfg.n_heads)  # [W,H,Dh]
        k_w = rope_heads(k, pos_w[:, None], cfg.rope_theta, cfg.n_heads)
        k_c = rope_lead_heads(kcache[l], slot[None, :], cfg.rope_theta, cfg.n_heads)  # [H,C,Dh]
        scale = 1.0 / jnp.sqrt(jnp.float32(cfg.head_dim))
        sc = jnp.einsum("whd,hcd->whc", q_r, k_c) * scale
        sc = jnp.where(slot[None, None, :] < lens[l], sc, NEG_INF)
        sw = jnp.einsum("whd,uhd->whu", q_r, k_w) * scale
        sw = jnp.where(u_idx <= i_idx, sw, NEG_INF)
        probs = jax.nn.softmax(jnp.concatenate([sc, sw], axis=-1), axis=-1)  # [W,H,C+W]
        if with_mass:
            masses.append(jnp.sum(probs, axis=(0, 1)))  # [C+W]
        att = jnp.einsum("whc,hcd->whd", probs[..., :C], vcache[l]) + \
              jnp.einsum("whu,uhd->whd", probs[..., C:], v)
        x = x + att.reshape(W, -1) @ params[f"l{l}.wo"]
        x = x + _swiglu(rmsnorm(x, params[f"l{l}.ln2"]), params, l)
    logits = rmsnorm(x, params["ln_f"]) @ params["embed"].T  # [W,V]
    lp = jax.nn.log_softmax(logits, axis=-1)
    logprobs = jnp.take_along_axis(lp, targets[:, None], axis=-1)[:, 0]
    win_k = jnp.stack(win_ks).transpose(0, 2, 1, 3)  # [L,H,W,Dh]
    win_v = jnp.stack(win_vs).transpose(0, 2, 1, 3)
    if with_mass:
        return logprobs, win_k, win_v, jnp.stack(masses)  # mass [L, C+W]
    return logprobs, win_k, win_v


# ---------------------------------------------------------------------------
# generate: K greedy steps, Pallas decode attention, in-graph cache append
# ---------------------------------------------------------------------------

def generate(cfg: ModelConfig, flat_w, kcache, vcache, lens, last_token, n_steps: int,
             use_pallas: bool = True, with_mass: bool = False):
    """Greedy-decode n_steps tokens starting after `last_token`.

    kcache/vcache: [L,H,C,Dh] pre-RoPE; lens: [L]; caller guarantees
    lens[l] + n_steps <= C for every layer.
    Returns (tokens[K], last_logits[V], kcache', vcache', lens'[, mass[L,C]]).
    """
    params = unpack(flat_w, cfg)
    L, H, C, Dh = kcache.shape
    slot = jnp.arange(C)

    def step(carry, _):
        kc, vc, ln, tok, mass = carry
        x = params["embed"][tok]  # [D]
        new_mass = mass
        for l in range(cfg.n_layers):
            h = rmsnorm(x, params[f"l{l}.ln1"])
            q, k_new, v_new = _qkv(h[None, :], params, l, cfg)  # [1,H,Dh]
            q, k_new, v_new = q[0], k_new[0], v_new[0]  # [H,Dh]
            # Append the new token's (pre-RoPE) K/V at slot ln[l].
            kc_l = jax.lax.dynamic_update_slice(kc[l], k_new[:, None, :], (0, ln[l], 0))
            vc_l = jax.lax.dynamic_update_slice(vc[l], v_new[:, None, :], (0, ln[l], 0))
            q_r = rope_lead_heads(q, ln[l], cfg.rope_theta, cfg.n_heads)  # [H,Dh]
            k_r = rope_lead_heads(kc_l, slot[None, :], cfg.rope_theta, cfg.n_heads)  # [H,C,Dh]
            length = ln[l] + 1
            if use_pallas and not with_mass:
                att = ladder_decode_attention(q_r, k_r, vc_l, length)  # [H,Dh]
            else:
                scale = 1.0 / jnp.sqrt(jnp.float32(cfg.head_dim))
                s = jnp.einsum("hd,hcd->hc", q_r, k_r) * scale
                s = jnp.where(slot[None, :] < length, s, NEG_INF)
                p = jax.nn.softmax(s, axis=-1)
                if with_mass:
                    new_mass = new_mass.at[l].add(jnp.sum(p, axis=0))
                att = jnp.einsum("hc,hcd->hd", p, vc_l)
            x = x + att.reshape(-1) @ params[f"l{l}.wo"]
            x = x + _swiglu(rmsnorm(x, params[f"l{l}.ln2"]), params, l)
            kc = kc.at[l].set(kc_l)
            vc = vc.at[l].set(vc_l)
        logits = rmsnorm(x, params["ln_f"]) @ params["embed"].T  # [V]
        nxt = jnp.argmax(logits).astype(jnp.int32)
        return (kc, vc, ln + 1, nxt, new_mass), (nxt, logits)

    mass0 = jnp.zeros((L, C), jnp.float32)
    carry0 = (kcache, vcache, lens, last_token.astype(jnp.int32), mass0)
    (kc, vc, ln, _, mass), (toks, logits_all) = jax.lax.scan(step, carry0, None, length=n_steps)
    out = (toks, logits_all[-1], kc, vc, ln)
    if with_mass:
        out = out + (mass,)
    return out


# ---------------------------------------------------------------------------
# training forward: full attention over T with per-layer additive masks
# (the ladder-robustness augmentation — see DESIGN.md §6)
# ---------------------------------------------------------------------------

def train_forward(cfg: ModelConfig, params, tokens, layer_masks):
    """tokens: [B,T] i32; layer_masks: [L,T,T] additive (0 or NEG_INF).

    Returns logits [B,T,V].
    """
    B, T = tokens.shape
    x = params["embed"][tokens]  # [B,T,D]
    pos = jnp.arange(T)
    causal = jnp.where(pos[None, :] <= pos[:, None], 0.0, NEG_INF)  # [T,T]
    for l in range(cfg.n_layers):
        h = rmsnorm(x, params[f"l{l}.ln1"])
        q, k, v = _qkv(h, params, l, cfg)  # [B,T,H,Dh]
        q_r = rope_heads(q, pos[None, :, None], cfg.rope_theta, cfg.n_heads)
        k_r = rope_heads(k, pos[None, :, None], cfg.rope_theta, cfg.n_heads)
        scale = 1.0 / jnp.sqrt(jnp.float32(cfg.head_dim))
        s = jnp.einsum("bihd,bjhd->bhij", q_r, k_r) * scale
        s = s + causal[None, None] + layer_masks[l][None, None]
        p = jax.nn.softmax(s, axis=-1)
        att = jnp.einsum("bhij,bjhd->bihd", p, v)
        x = x + att.reshape(B, T, -1) @ params[f"l{l}.wo"]
        x = x + _swiglu(rmsnorm(x, params[f"l{l}.ln2"]), params, l)
    return rmsnorm(x, params["ln_f"]) @ params["embed"].T
