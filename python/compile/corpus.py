"""Synthetic long-range corpus ("synthtext" / "synthbooks").

This is the data substrate substituting for Wikitext-2 / PG19 (see DESIGN.md §6).
The generator is *integer-only* and deterministic so the Rust side
(rust/src/data/corpus.rs) can mirror it bit-for-bit; parity is asserted against
golden vectors exported into artifacts/corpus_golden.json.

Structure per document:
  - background: order-1 Markov chain over 240 word tokens with a linearly
    decaying (Zipf-ish) marginal,
  - entities: MARK <name:2> SEP <phrase:P> introductions whose *re-mentions*
    repeat the same surface form -> a model that still holds the introduction
    in its KV cache predicts the phrase tokens (long-range PPL signal),
  - recall queries: QUERY <name> ANSWER <phrase> (associative recall; the
    mechanism behind the NIAH / RULER tasks).
"""

MASK64 = (1 << 64) - 1

VOCAB = 256
WORD_BASE = 16
N_WORDS = 184  # background words: [16, 200)
NAME_BASE = 200
N_NAMES = 56  # entity-name tokens: [200, 256) — disjoint from background

BOS, EOS, SEP, QUERY, ANSWER, MARK = 0, 1, 2, 3, 4, 5

PHRASE_LEN = 4
NAME_LEN = 2


class Rng:
    """SplitMix64 — mirrored in rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        self.s = seed & MASK64

    def next_u64(self) -> int:
        self.s = (self.s + 0x9E3779B97F4A7C15) & MASK64
        z = self.s
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        return self.next_u64() % n


def succ(prev: int, j: int) -> int:
    """j-th Markov successor of token `prev` (pure hash function)."""
    return WORD_BASE + ((prev * 2654435761 + j * 40503 + 12345) % N_WORDS)


def draw_word(rng: Rng) -> int:
    """Word with linearly decaying rank distribution (min of two uniforms)."""
    u = rng.below(N_WORDS)
    v = rng.below(N_WORDS)
    return WORD_BASE + min(u, v)


def draw_name(rng: Rng) -> int:
    """Entity-name token from the dedicated [NAME_BASE, VOCAB) range."""
    return NAME_BASE + rng.below(N_NAMES)


def gen_doc(rng: Rng, doclen: int, n_ent: int = 4):
    """One document of exactly `doclen` tokens."""
    toks = [BOS]
    prev = draw_word(rng)
    ents = []  # list of (name, phrase)
    while len(toks) < doclen:
        a = rng.below(10)
        if a == 0 and len(ents) < n_ent:
            name = [draw_name(rng) for _ in range(NAME_LEN)]
            phrase = [draw_word(rng) for _ in range(PHRASE_LEN)]
            ents.append((name, phrase))
            toks += [MARK] + name + [SEP] + phrase
            prev = phrase[-1]
        elif a == 1 and ents:
            i = rng.below(len(ents))
            name, phrase = ents[i]
            toks += [MARK] + name + [SEP] + phrase
            prev = phrase[-1]
        elif a == 2 and ents:
            i = rng.below(len(ents))
            name, phrase = ents[i]
            toks += [QUERY] + name + [ANSWER] + phrase
            prev = phrase[-1]
        else:
            run = 4 + rng.below(12)
            for _ in range(run):
                if rng.next_u64() & 1:
                    prev = succ(prev, rng.below(4))
                else:
                    prev = draw_word(rng)
                toks.append(prev)
    return toks[:doclen]


def stream(seed: int, doclen_min: int = 192, doclen_max: int = 512, n_ent: int = 4):
    """Infinite token stream of concatenated documents."""
    rng = Rng(seed)
    while True:
        span = doclen_max - doclen_min
        doclen = doclen_min + (rng.below(span) if span > 0 else 0)
        yield from gen_doc(rng, doclen, n_ent)


def take(seed: int, n: int, doclen_min: int = 192, doclen_max: int = 512, n_ent: int = 4):
    out = []
    it = stream(seed, doclen_min, doclen_max, n_ent)
    for _ in range(n):
        out.append(next(it))
    return out
