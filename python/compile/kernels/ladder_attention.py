"""Layer-1 Pallas kernel: flash-style decode attention over a compacted cache.

This is the hot-spot of LaCache's serving path. The defining property (the one
that gives LaCache its throughput edge over H2O/TOVA/SnapKV in the paper's
Fig. 7) is that the kernel is *attention-map-free*: a single pass over the
cache-slot axis with an online softmax; the [H, C] score tensor is never
materialized to memory. Eviction needs only `length` (valid-slot count), never
attention scores.

TPU mapping of the paper's CUDA/FlashAttention framing (DESIGN.md §2):
  - the query tile (one head, Dh lanes) is pinned in VMEM,
  - K/V stream HBM->VMEM in (BLOCK_C, Dh) tiles expressed via BlockSpec,
  - the online-softmax state (m, l, acc) lives in VMEM scratch and persists
    across the sequential grid steps of the slot axis,
  - masking of empty slots is additive -inf on in-register scores.

`interpret=True` is mandatory on CPU PJRT (real TPU lowering emits a Mosaic
custom-call the CPU plugin cannot execute); numerics are validated against
`ref.py` by the pytest suite.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_C = 64
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, block_c: int):
    """One (head, slot-block) grid step of online-softmax decode attention.

    Refs (VMEM blocks):
      len_ref : (1,)            i32  valid slot count (same for all heads)
      q_ref   : (1, Dh)         f32  roped query for this head
      k_ref   : (1, block_c, Dh) f32 roped key tile
      v_ref   : (1, block_c, Dh) f32 value tile
      o_ref   : (1, Dh)         f32  output (written on the last slot block)
      scratch : m (1,), l (1,), acc (Dh,) — online softmax state
    """
    c = pl.program_id(1)
    n_blocks = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        m_ref[0] = NEG_INF
        l_ref[0] = 0.0
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :]  # [Dh]
    k = k_ref[0, :, :]  # [block_c, Dh]
    v = v_ref[0, :, :]  # [block_c, Dh]
    dh = q.shape[-1]

    scores = jnp.dot(k, q) * (1.0 / (dh**0.5))  # [block_c]
    slot = c * block_c + jax.lax.iota(jnp.int32, block_c)
    valid = slot < len_ref[0]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[0]
    m_cur = jnp.maximum(m_prev, jnp.max(scores))
    alpha = jnp.exp(m_prev - m_cur)
    # Masked lanes must contribute exactly 0 even when every lane is masked
    # (m_cur == NEG_INF would make exp(score - m_cur) == 1 otherwise).
    p = jnp.where(valid, jnp.exp(scores - m_cur), 0.0)  # [block_c]
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
    m_ref[0] = m_cur

    @pl.when(c == n_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def ladder_decode_attention(q, k, v, length, *, block_c: int = DEFAULT_BLOCK_C, interpret: bool = True):
    """Single-token decode attention over a compacted per-layer cache.

    Args:
      q: [H, Dh] roped queries.
      k: [H, C, Dh] roped keys (slots >= length are garbage and masked).
      v: [H, C, Dh] values.
      length: scalar i32, number of valid slots (0 <= length <= C).
    Returns:
      [H, Dh] attention output. If length == 0, returns zeros.
    """
    h, dh = q.shape
    _, c, _ = k.shape
    block_c = min(block_c, c)
    if c % block_c != 0:
        raise ValueError(f"cache size {c} must be a multiple of block_c {block_c}")
    n_blocks = c // block_c
    len_arr = jnp.reshape(length.astype(jnp.int32), (1,))

    kernel = functools.partial(_decode_kernel, block_c=block_c)
    return pl.pallas_call(
        kernel,
        grid=(h, n_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1, dh), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_c, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_c, dh), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((dh,), jnp.float32),
        ],
        interpret=interpret,
    )(len_arr, q, k, v)


def vmem_footprint_bytes(h: int, c: int, dh: int, block_c: int = DEFAULT_BLOCK_C) -> int:
    """Estimated per-grid-step VMEM residency (DESIGN.md §7, EXPERIMENTS.md §Perf).

    q tile + k tile + v tile + scratch; all f32.
    """
    return 4 * (dh + 2 * block_c * dh + (2 + dh))
