"""Pure-jnp correctness oracles for the Pallas kernels and attention paths.

These are the ground truth the pytest suite compares against (assert_allclose)
under shape/dtype/length sweeps. Nothing here is ever lowered into the AOT
artifacts' hot path.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, length):
    """Masked softmax decode attention, materialized scores.

    q: [H, Dh], k/v: [H, C, Dh] (roped keys), length: scalar i32.
    Returns [H, Dh]; zeros when length == 0.
    """
    h, dh = q.shape
    c = k.shape[1]
    scores = jnp.einsum("hd,hcd->hc", q, k) / jnp.sqrt(jnp.float32(dh))
    slot = jnp.arange(c)[None, :]
    scores = jnp.where(slot < length, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    p = p / denom
    out = jnp.einsum("hc,hcd->hd", p, v)
    return jnp.where(length > 0, out, jnp.zeros_like(out))


def window_attention_ref(q, k_cache, v_cache, k_win, v_win, length):
    """Window (prefill/score) attention: W queries over [cache ; window] keys.

    q: [W, H, Dh] roped; k_cache/v_cache: [H, C, Dh] roped; k_win/v_win:
    [W, H, Dh] roped. Query i sees cache slots < length plus window keys <= i.
    Returns [W, H, Dh].
    """
    w, h, dh = q.shape
    c = k_cache.shape[1]
    sc = jnp.einsum("whd,hcd->whc", q, k_cache) / jnp.sqrt(jnp.float32(dh))  # [W,H,C]
    sw = jnp.einsum("whd,uhd->whu", q, k_win) / jnp.sqrt(jnp.float32(dh))  # [W,H,W]
    slot = jnp.arange(c)[None, None, :]
    sc = jnp.where(slot < length, sc, NEG_INF)
    i = jnp.arange(w)[:, None, None]
    u = jnp.arange(w)[None, None, :]
    sw = jnp.where(u <= i, sw, NEG_INF)
    scores = jnp.concatenate([sc, sw], axis=-1)  # [W,H,C+W]
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    vc = jnp.einsum("whc,hcd->whd", p[..., :c], v_cache)
    vw = jnp.einsum("whu,uhd->whd", p[..., c:], v_win)
    return vc + vw
