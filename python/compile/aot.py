"""AOT compile path: lower every serving program to HLO *text* + write the
artifact manifest the rust runtime consumes.

HLO text (NOT serialized HloModuleProto): jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the `xla` 0.1.6
crate) rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts layout:
  artifacts/manifest.json            program + weight index (read by rust)
  artifacts/corpus_golden.json       parity vectors for rust data generators
  artifacts/<model>/weights.bin      flat f32 weights (written by train.py)
  artifacts/<model>/<prog>.hlo.txt   HLO text programs

Python runs ONCE at build time and never on the request path.
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus
from .model import CONFIGS, ModelConfig, generate, n_params, score_window

# Static shape grid (DESIGN.md §2). C must be a multiple of the Pallas
# kernel block (64); budgets are enforced by masking so one C serves many.
SCORE_WINDOWS = (32, 128)
C_SMALL = 256     # all budget-bound policies (budget + W <= C_SMALL)
C_FULL = 2048     # full-cache runs (PPL explosion / simulated OOM axis)
GEN_KS = (1, 16)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def program_specs(cfg: ModelConfig):
    """Yield (name, fn, arg_specs, meta) for every program of one model."""
    L, H, Dh, P = cfg.n_layers, cfg.n_heads, cfg.head_dim, n_params(cfg)

    def cache_specs(c):
        return [f32(L, H, c, Dh), f32(L, H, c, Dh), i32(L)]

    for w in SCORE_WINDOWS:
        for c in (C_SMALL, C_FULL):
            for scored in (False, True):
                if scored and c == C_FULL:
                    continue  # baselines never run the full-cache config
                name = f"score{'_scored' if scored else ''}_w{w}_c{c}"
                fn = functools.partial(score_window, cfg, with_mass=scored)
                specs = [f32(P), i32(w), i32(w)] + cache_specs(c)
                outs = ["logprobs", "win_k", "win_v"] + (["mass"] if scored else [])
                yield name, fn, specs, {
                    "kind": "score", "w": w, "c": c, "scored": scored,
                    "inputs": ["weights", "tokens", "targets", "kcache", "vcache", "lens"],
                    "outputs": outs,
                }

    # Decode programs. The default fast path uses the fused jnp attention:
    # on this CPU-only PJRT the Pallas kernel can only run in interpret mode,
    # whose wallclock is an emulation artifact, not a TPU prediction
    # (DESIGN.md §Hardware-Adaptation). The interpret-mode kernel is still
    # emitted as the `generate_pallas_*` variant: numerics-identical (asserted
    # by rust integration tests through PJRT) and the artifact a TPU target
    # would compile natively.
    gen_variants = [(k, False, False) for k in GEN_KS]  # fast jnp
    gen_variants.append((16, True, False))  # scored (slow path)
    gen_variants.append((16, False, True))  # pallas kernel path
    for k, scored, pallas in gen_variants:
        tag = "_scored" if scored else ("_pallas" if pallas else "")
        name = f"generate{tag}_k{k}_c{C_SMALL}"
        fn = functools.partial(generate, cfg, n_steps=k,
                               use_pallas=pallas, with_mass=scored)
        specs = [f32(P)] + cache_specs(C_SMALL) + [i32()]
        outs = ["tokens", "last_logits", "kcache", "vcache", "lens"] + (
            ["mass"] if scored else [])
        yield name, fn, specs, {
            "kind": "generate", "k": k, "c": C_SMALL, "scored": scored,
            "uses_pallas": pallas,
            "inputs": ["weights", "kcache", "vcache", "lens", "last_token"],
            "outputs": outs,
        }


def lower_model(cfg: ModelConfig, outdir: str):
    progs = {}
    mdir = os.path.join(outdir, cfg.name)
    os.makedirs(mdir, exist_ok=True)
    for name, fn, specs, meta in program_specs(cfg):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        rel = f"{cfg.name}/{name}.hlo.txt"
        with open(os.path.join(outdir, rel), "w") as f:
            f.write(text)
        meta = dict(meta)
        meta["path"] = rel
        meta["hlo_bytes"] = len(text)
        progs[name] = meta
        print(f"  {cfg.name}/{name}: {len(text)} chars ({time.time()-t0:.1f}s)", flush=True)
    return progs


def export_corpus_golden(outdir: str):
    """Golden vectors for the rust corpus-generator parity test."""
    golden = {}
    for seed in (1, 42, 20250711):
        golden[str(seed)] = corpus.take(seed, 2048)
    doc = {"doclen_min": 192, "doclen_max": 512, "n_ent": 4,
           "phrase_len": corpus.PHRASE_LEN, "name_len": corpus.NAME_LEN,
           "streams": golden}
    with open(os.path.join(outdir, "corpus_golden.json"), "w") as f:
        json.dump(doc, f)
    print(f"  corpus_golden.json: {len(golden)} seeds x 2048 tokens")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="base,mini")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)

    manifest = {"version": 1, "c_small": C_SMALL, "c_full": C_FULL, "models": []}
    for name in args.models.split(","):
        cfg = CONFIGS[name]
        print(f"[{name}] lowering programs")
        progs = lower_model(cfg, out)
        manifest["models"].append({
            "name": name,
            "config": cfg.to_dict(),
            "weights": f"{name}/weights.bin",
            "n_params": n_params(cfg),
            "programs": progs,
        })
    export_corpus_golden(out)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out}/manifest.json")


if __name__ == "__main__":
    main()
