//! End-to-end decode/score benchmarks over the real PJRT runtime — the
//! measured halves of Tab. 1 (score path) and Fig. 7 (fast vs scored decode;
//! the attention-map-free property is *the* LaCache throughput claim).
//!
//! Run: `cargo bench` (requires `make artifacts`).

use lacache::cache::make_policy;
use lacache::data::corpus::Stream;
use lacache::engine::{Engine, EngineOpts};
use lacache::runtime::Runtime;
use lacache::util::bench::Bench;

fn main() -> anyhow::Result<()> {
    let dir = lacache::artifacts_dir();
    if !dir.join("manifest.json").exists() || !dir.join("base/weights.bin").exists() {
        eprintln!("bench_decode: run `make artifacts` first — skipping");
        return Ok(());
    }
    let rt = Runtime::load(&dir, &["base"])?;
    let cfg = rt.model("base")?.cfg.clone();
    let b = Bench::new(2, 8);

    // --- decode fast path (LaCache / StreamingLLM; Pallas kernel) ----------
    for (label, spec) in [
        ("decode16/lacache(128)", "lacache:budget=128,span=2"),
        ("decode16/streaming(128)", "streaming:budget=128"),
    ] {
        let policy = make_policy(spec, cfg.n_layers)?;
        let mut eng = Engine::new(
            &rt,
            EngineOpts {
                model: "base".into(),
                w: 128,
                c: 256,
                memory_budget_bytes: None,
                quantize_after_windows: None,
            },
            policy,
        )?;
        let ctx = Stream::default_eval(3).take_n(256);
        eng.prefill(&ctx)?;
        b.run_throughput(label, 16, "tok", || {
            eng.generate(16).unwrap();
        });
    }

    // --- Pallas-kernel decode variant (interpret mode emulation) -----------
    {
        let policy = make_policy("lacache:budget=128,span=2", cfg.n_layers)?;
        let mut eng = Engine::new(
            &rt,
            EngineOpts {
                model: "base".into(),
                w: 128,
                c: 256,
                memory_budget_bytes: None,
                quantize_after_windows: None,
            },
            policy,
        )?;
        let ctx = Stream::default_eval(3).take_n(256);
        eng.prefill(&ctx)?;
        let mut cache = eng.cache.clone();
        b.run_throughput("decode16/pallas-interpret(128)", 16, "tok", || {
            rt.generate_variant("base", 16, false, true, &mut cache, 7).unwrap();
        });
    }

    // --- decode slow (scored) path (H2O family) ----------------------------
    {
        let policy = make_policy("h2o:budget=128", cfg.n_layers)?;
        let mut eng = Engine::new(
            &rt,
            EngineOpts {
                model: "base".into(),
                w: 128,
                c: 256,
                memory_budget_bytes: None,
                quantize_after_windows: None,
            },
            policy,
        )?;
        let ctx = Stream::default_eval(3).take_n(256);
        eng.prefill(&ctx)?;
        b.run_throughput("decode16/h2o(128,scored)", 16, "tok", || {
            eng.generate(16).unwrap();
        });
    }

    // --- score (window PPL) path -------------------------------------------
    for (label, spec, w) in [
        ("score_w128/lacache(128)", "lacache:budget=128,span=2", 128usize),
        ("score_w32/lacache(128)", "lacache:budget=128,span=2", 32),
        ("score_w128/h2o(128,scored)", "h2o:budget=128", 128),
    ] {
        let policy = make_policy(spec, cfg.n_layers)?;
        let mut eng = Engine::new(
            &rt,
            EngineOpts {
                model: "base".into(),
                w,
                c: 256,
                memory_budget_bytes: None,
                quantize_after_windows: None,
            },
            policy,
        )?;
        let mut stream = Stream::default_eval(5);
        let toks = stream.take_n(w + 1);
        b.run_throughput(label, w as u64, "tok", || {
            eng.feed_score(&toks[..w], &toks[1..]).unwrap();
        });
    }

    // --- runtime breakdown --------------------------------------------------
    let st = rt.stats();
    println!(
        "\nruntime totals: {} calls, compile {:.2}s, upload {:.3}s, execute {:.3}s, download {:.3}s",
        st.calls, st.compile_s, st.upload_s, st.execute_s, st.download_s
    );
    println!(
        "transfer totals: {:.1} MiB h2d, {:.1} MiB d2h | gather {:.3}s, {:.2} MiB copied \
         ({} full / {} incremental / {} noop, {} scratch allocs)",
        st.bytes_h2d as f64 / (1 << 20) as f64,
        st.bytes_d2h as f64 / (1 << 20) as f64,
        st.gather_s,
        st.gathered_bytes as f64 / (1 << 20) as f64,
        st.gathers_full,
        st.gathers_incremental,
        st.gathers_noop,
        st.dense_scratch_allocs,
    );
    Ok(())
}
