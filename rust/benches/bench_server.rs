//! Server-substrate benchmarks: scheduler round overhead (with an instant
//! backend, isolating pure L3 cost), wire-protocol encode/decode, JSON parse
//! throughput for the manifest-sized payloads, the paged-KV arena
//! memory-pressure scenario (concurrency under a fixed byte budget vs. the
//! old dense-allocation baseline), the steady-state decode transfer
//! scenario (dirty-range incremental gather; asserts append-only decode
//! gathers only the appended rows with zero dense-buffer allocations, and
//! writes machine-readable `BENCH_decode.json`), and the burst-intake
//! serving scenario (one-round burst admission, post-shutdown rejection,
//! mid-decode cancellation page release, plus the split-phase overlap
//! record — decoder inter-token latency while a long multi-window prefill
//! is in flight, sync vs submit/reap; writes `BENCH_serving.json`), and the
//! chaos serving scenario (seeded transient-fault injection at a 10% rate
//! must leave every sequence byte-identical to the fault-free run with zero
//! quarantines at the default retry budget, and one injected worker panic
//! mid-decode must kill exactly the affected sequence; writes
//! `BENCH_chaos.json`), and the multi-device sharding scenario (two prompt
//! families homed on distinct stub devices via locality-aware placement:
//! aggregate resident bytes must exceed any single shard's cap, prefix hits
//! must equal the single-device run, and killing one stub device must
//! degrade only its own shard while later sequences spill over with a cold
//! prefill; writes `BENCH_shard.json`), and the tiered-compression capacity
//! scenario (same `kv_pool_bytes` budget, `--kv-quant cold-q8` vs `off`:
//! cold-page Q8 demotion must admit >= 3x the concurrent sequences with
//! prefix-hit parity and a bounded worst-case dequantization delta; writes
//! `BENCH_quant.json`), and the flight-recorder observability scenario (8
//! mixed sequences with tracing on must keep decoder ITL p95 within 5% of
//! the tracing-off twin, every admitted sequence's events must reconstruct
//! the complete queued→admitted→placed→first-token→finished chain —
//! including a retry under an injected transient fault — and the
//! `op:metrics` exposition must parse as Prometheus text; writes
//! `BENCH_obs.json`) — see PERF.md.
//!
//! Set `LACACHE_BENCH_SMOKE=1` (exactly) for the short CI mode; `BENCH_JSON`
//! / `BENCH_SERVING_JSON` / `BENCH_CHAOS_JSON` / `BENCH_SHARD_JSON` /
//! `BENCH_QUANT_JSON` / `BENCH_OBS_JSON` override the JSON output paths,
//! `LACACHE_FAULT_SEED` / `LACACHE_FAULT_RATE` the chaos plan.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::Duration;

use lacache::cache::{make_policy, CachePolicy};
use lacache::runtime::{
    admission_ok, place, seq_footprint_bytes, seq_footprint_bytes_mixed, Acquired, CallError,
    CallExecutor, Completion, DeviceTier, KvArena, KvCache, PlacementStats, PrefixCache,
    PrefixSnapshot, ScratchPool, ShardLoad, PAGE_SLOTS,
};
use lacache::server::batcher::{
    CallDone, CallOut, CancelToken, Decoded, FaultStats, Finished, Scheduler, SeqBackend,
    Submitted, Ticket,
};
use lacache::server::protocol::{ok_generate, parse_request, SHUTTING_DOWN};
use lacache::server::{Reactor, Work};
use lacache::util::bench::Bench;
use lacache::util::json::Json;
use lacache::util::stats::Samples;

struct InstantBackend;
struct NoSeq;

impl SeqBackend for InstantBackend {
    type Seq = NoSeq;
    fn new_seq(&mut self) -> anyhow::Result<NoSeq> {
        Ok(NoSeq)
    }
    fn prefill_chunk(&mut self, _s: &mut NoSeq, _c: &[i32]) -> anyhow::Result<()> {
        Ok(())
    }
    fn decode(&mut self, _s: &mut NoSeq, n: usize) -> anyhow::Result<Decoded> {
        Ok(Decoded { tokens: vec![17; n], t_first: None })
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = matches!(std::env::var("LACACHE_BENCH_SMOKE").as_deref(), Ok("1"));
    let b = if smoke { Bench::new(1, 3) } else { Bench::new(5, 20) };

    // scheduler: 64 requests through admission->prefill->decode->finish
    b.run_throughput("scheduler/64-requests (instant backend)", 64, "req", || {
        let mut s = Scheduler::new(InstantBackend, 128, 16, 4, 1024);
        for _ in 0..64 {
            s.submit(vec![1; 300], 32, CancelToken::new()).unwrap();
        }
        while s.has_work() {
            std::hint::black_box(s.step());
        }
    });

    // protocol encode/decode
    let line = r#"{"op":"generate","id":42,"prompt":"<bos> w1 w2 w3 w4 w5 w6 w7","max_new_tokens":16}"#;
    b.run_throughput("protocol/parse_request", 1, "req", || {
        std::hint::black_box(parse_request(line).unwrap());
    });
    let toks: Vec<i32> = (16..80).collect();
    b.run_throughput("protocol/ok_generate(64 tokens)", 1, "resp", || {
        std::hint::black_box(ok_generate(1, &toks, 300, 0, 1.0, 0.5, 2.0, None));
    });

    // json: manifest-scale parse
    let man_path = lacache::artifacts_dir().join("manifest.json");
    if man_path.exists() {
        let text = std::fs::read_to_string(&man_path)?;
        b.run_throughput("json/parse manifest", text.len() as u64, "byte", || {
            std::hint::black_box(Json::parse(&text).unwrap());
        });
    }

    memory_pressure_scenario()?;
    steady_state_decode_scenario(smoke)?;
    device_residency_scenario(smoke)?;
    burst_intake_scenario(smoke)?;
    shared_prefix_scenario(smoke)?;
    chaos_scenario(smoke)?;
    shard_scenario(smoke)?;
    quant_capacity_scenario(smoke)?;
    obs_scenario(smoke)?;
    Ok(())
}

/// One donated decode step of the residency scenario, via the runtime's own
/// contract emulation (`runtime::device::emulate_donated_step` — the same
/// helper the device property tests drive, so bench and tests cannot encode
/// divergent donation semantics). The real path is `Runtime::generate` +
/// `Runtime::absorb_generated`.
fn donated_decode_step(
    client: &xla::PjRtClient,
    kv: &mut KvCache,
    tier: &mut DeviceTier,
    pool: &mut ScratchPool,
    next_pos: &mut u64,
) -> anyhow::Result<()> {
    lacache::runtime::device::emulate_donated_step(client, tier, pool, kv, next_pos, || 0.25)
}

/// Device-residency decode scenario (device-free; the stub client retains
/// buffers): drives the three-tier path a decoding sequence takes — one
/// cold promotion, then donated decode steps that keep the KV state
/// resident — and asserts the residency tier's steady-state guarantees:
///
/// 1. per-step host→device traffic EXCLUDES KV bytes: after warmup the tier
///    uploads nothing per decode step, so a serving decode step moves only
///    the token + lens call inputs (`4·(1+L)` bytes at this shape);
/// 2. zero full host gathers after warmup (`gathers_full == 0` over the
///    measured loop — the scratch/spill tier is never touched);
/// 3. a ladder-style compaction reconciles ONLY the dirty rows, and an LRU
///    spill + re-promotion round-trips the image byte-identically with an
///    incremental (not full) re-gather.
///
/// Emits machine-readable `BENCH_residency.json` (path override:
/// `BENCH_RESIDENCY_JSON`) for the CI perf trajectory.
fn device_residency_scenario(smoke: bool) -> anyhow::Result<()> {
    let (l, h, c, dh) = (8usize, 4usize, 1024usize, 24usize);
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    let image_bytes = 2 * 4 * l * h * c * dh;
    let mut kv = KvCache::with_arena(KvArena::new(), l, h, c, dh);
    let mut pool = ScratchPool::new(4);
    let mut tier = DeviceTier::new(2 * image_bytes);

    // prefill, then the one cold promotion (full gather + full upload)
    let n_prefill = 128usize;
    let row = vec![0.5f32; h * n_prefill * dh];
    for layer in 0..l {
        kv.append_layer(layer, &row, &row, n_prefill, n_prefill, 0)?;
    }
    match tier.acquire(&client, &mut kv, &mut pool)? {
        Acquired::Resident => {}
        Acquired::Transient(..) => anyhow::bail!("prefill image must fit the tier"),
    }
    assert_eq!(tier.stats().uploaded_bytes, image_bytes as u64, "cold path pays one full upload");
    let mut next_pos = n_prefill as u64;

    // warmup donated decode steps
    for _ in 0..4 {
        donated_decode_step(&client, &mut kv, &mut tier, &mut pool, &mut next_pos)?;
    }
    let warm_t = tier.stats();
    let warm_p = pool.stats();

    let steps = if smoke { 64usize } else { 512 };
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        donated_decode_step(&client, &mut kv, &mut tier, &mut pool, &mut next_pos)?;
    }
    let dt = t0.elapsed().as_secs_f64();
    let st = tier.stats();
    let ps = pool.stats();

    // (1) per-step h2d excludes KV bytes entirely: the runtime's decode
    // step on this path uploads only tokens + lens
    let kv_h2d = st.uploaded_bytes - warm_t.uploaded_bytes;
    assert_eq!(kv_h2d, 0, "steady-state donated decode must upload zero KV bytes");
    assert_eq!(st.reconciled_bytes, warm_t.reconciled_bytes);
    assert_eq!(st.donations - warm_t.donations, steps as u64);
    // (2) the host gather path is never touched after warmup
    let gathers_full_after_warmup = ps.gathers_full - warm_p.gathers_full;
    assert_eq!(gathers_full_after_warmup, 0, "device-hit decode must not re-gather");
    assert_eq!(ps.gathered_bytes, warm_p.gathered_bytes, "zero host gather bytes");
    let token_lens_bytes = (4 * (1 + l)) as u64;

    // (3a) ladder-style compaction: reconcile uploads exactly the dirty rows
    let keep: Vec<usize> = (0..kv.lens[0]).filter(|s| s % 3 != 1).collect();
    for layer in 0..l {
        kv.retain_slots(layer, &keep)?;
    }
    let expected: u64 = (0..l)
        .map(|layer| {
            let (lo, hi) = kv.dirty_range(layer).expect("retain dirtied the layer");
            (2 * 4 * h * (hi - lo) * dh) as u64
        })
        .sum();
    let before = tier.stats();
    tier.acquire(&client, &mut kv, &mut pool)?;
    let reconciled_compaction = tier.stats().reconciled_bytes - before.reconciled_bytes;
    assert_eq!(reconciled_compaction, expected, "compaction must reconcile only dirty rows");
    assert!(reconciled_compaction < image_bytes as u64);

    // (3b) LRU spill + re-promotion: incremental re-gather, byte-identical
    tier.spill_one(&mut pool)?;
    let full_before = pool.stats().gathers_full;
    tier.acquire(&client, &mut kv, &mut pool)?;
    assert_eq!(
        pool.stats().gathers_full,
        full_before,
        "re-promotion after spill-to-scratch must gather incrementally"
    );
    let (dk, dv) = tier.read_back(kv.id())?.expect("re-promoted entry");
    let (fk, fv) = kv.gather_dense();
    assert!(dk == fk && dv == fv, "device image must survive spill/re-promotion byte-identically");
    let spills = tier.stats().spills;

    let tokens_per_s = steps as f64 / dt;
    println!(
        "\ndevice-residency decode: {steps} steps | {tokens_per_s:.0} tok/s (residency tier only) \
         | {kv_h2d} KV B h2d/step vs {image_bytes} B full image | {token_lens_bytes} B call \
         inputs/step | {gathers_full_after_warmup} full gathers after warmup | compaction \
         reconciled {reconciled_compaction} B | {spills} spills (byte-exact round-trip)"
    );

    let out = Json::from_pairs(vec![
        ("bench", "device_residency".into()),
        ("smoke", smoke.into()),
        ("shape_lhcd", vec![l, h, c, dh].into()),
        ("steps", steps.into()),
        ("tokens_per_s", tokens_per_s.into()),
        ("kv_bytes_h2d_per_step", (kv_h2d as i64).into()),
        ("token_lens_bytes_per_step", (token_lens_bytes as i64).into()),
        ("full_image_bytes", (image_bytes as i64).into()),
        ("gathers_full_after_warmup", (gathers_full_after_warmup as i64).into()),
        ("donations", ((st.donations - warm_t.donations) as i64).into()),
        ("compaction_reconciled_bytes", (reconciled_compaction as i64).into()),
        ("spills", (spills as i64).into()),
    ]);
    let path =
        std::env::var("BENCH_RESIDENCY_JSON").unwrap_or_else(|_| "BENCH_residency.json".into());
    std::fs::write(&path, out.to_string() + "\n")?;
    println!("wrote {path}");
    Ok(())
}

/// Steady-state decode transfer scenario (device-free): drives the exact
/// storage + transfer path of a decoding sequence — append one slot per
/// layer, re-materialize the dense image through the scratch pool — and
/// asserts the transfer layer's two steady-state guarantees:
///
/// 1. each step gathers ONLY the appended rows (counter-verified, and ≪ the
///    full `L·H·C·Dh` image the old path re-copied every call);
/// 2. zero dense-buffer allocations after warmup.
///
/// Also verifies the generate-path absorb: adopting the downloaded device
/// state as the scratch image makes the next gather a no-op. Emits
/// machine-readable `BENCH_decode.json` (path override: `BENCH_JSON`) for
/// the CI perf trajectory.
fn steady_state_decode_scenario(smoke: bool) -> anyhow::Result<()> {
    let (l, h, c, dh) = (8usize, 4usize, 1024usize, 24usize);
    let mut kv = KvCache::with_arena(KvArena::new(), l, h, c, dh);
    let mut pool = ScratchPool::new(4);

    // prefill, then the one cold full gather
    let n_prefill = 128usize;
    let row = vec![0.5f32; h * n_prefill * dh];
    for layer in 0..l {
        kv.append_layer(layer, &row, &row, n_prefill, n_prefill, 0)?;
    }
    pool.gather(&mut kv);
    let one = vec![0.25f32; h * dh];
    let mut next_pos = n_prefill as u64;

    // warmup decode steps (scratch pool + page tables reach steady state)
    for _ in 0..4 {
        for layer in 0..l {
            kv.append_layer(layer, &one, &one, 1, 1, next_pos)?;
        }
        next_pos += 1;
        pool.gather(&mut kv);
    }

    let warm = pool.stats();
    let steps = if smoke { 64usize } else { 512 };
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        for layer in 0..l {
            kv.append_layer(layer, &one, &one, 1, 1, next_pos)?;
        }
        next_pos += 1;
        std::hint::black_box(pool.gather(&mut kv));
    }
    let dt = t0.elapsed().as_secs_f64();
    let st = pool.stats();

    let full_image_bytes = (2 * l * h * c * dh * 4) as u64;
    let per_step_row_bytes = (2 * l * h * dh * 4) as u64; // K+V, one slot/layer
    let gathered = st.gathered_bytes - warm.gathered_bytes;
    let zeroed = st.zeroed_bytes - warm.zeroed_bytes;
    let allocs = st.dense_allocs - warm.dense_allocs;
    assert_eq!(
        gathered,
        steps as u64 * per_step_row_bytes,
        "steady-state decode must gather exactly the appended rows"
    );
    assert_eq!(zeroed, 0, "append-only decode must not zero-fill");
    assert_eq!(allocs, 0, "transfer scratch must not allocate after warmup");
    assert!(
        gathered * 16 <= steps as u64 * full_image_bytes,
        "gathered bytes per step must be \u{226a} the full dense image"
    );

    // generate-path absorb: the downloaded device image becomes the scratch,
    // so the next gather copies nothing at all
    let (mut dk, mut dv) = {
        let img = pool.gather(&mut kv);
        (img.k.clone(), img.v.clone())
    };
    let lens: Vec<i32> = kv.lens.iter().map(|&x| x as i32 + 1).collect();
    for layer in 0..l {
        let slot = kv.lens[layer];
        for hh in 0..h {
            let off = ((layer * h + hh) * c + slot) * dh;
            for x in &mut dk[off..off + dh] {
                *x = 0.75;
            }
            for x in &mut dv[off..off + dh] {
                *x = -0.75;
            }
        }
    }
    kv.replace_from_device(&dk, &dv, &lens, 1, next_pos)?;
    pool.absorb(&mut kv, dk, dv);
    let before = pool.stats();
    pool.gather(&mut kv);
    let after = pool.stats();
    assert_eq!(
        after.gathers_noop,
        before.gathers_noop + 1,
        "absorbed device image must make the next gather a no-op"
    );
    assert_eq!(after.gathered_bytes, before.gathered_bytes);

    let tokens_per_s = steps as f64 / dt;
    let gathered_per_step = gathered as f64 / steps as f64;
    println!(
        "\nsteady-state decode: {steps} steps | {tokens_per_s:.0} tok/s (storage+transfer only) \
         | {gathered_per_step:.0} B gathered/step vs {full_image_bytes} B full image \
         ({:.4}% of full) | {allocs} allocs after warmup",
        100.0 * gathered_per_step / full_image_bytes as f64,
    );

    // counters are deltas: gather fields over the measured loop,
    // absorb_noop_gathers over the absorb demonstration only
    let incremental = (st.gathers_incremental - warm.gathers_incremental) as i64;
    let absorb_noops = (after.gathers_noop - before.gathers_noop) as i64;
    let out = Json::from_pairs(vec![
        ("bench", "steady_state_decode".into()),
        ("smoke", smoke.into()),
        ("shape_lhcd", vec![l, h, c, dh].into()),
        ("steps", steps.into()),
        ("tokens_per_s", tokens_per_s.into()),
        ("gathered_bytes_per_step", gathered_per_step.into()),
        ("full_image_bytes", (full_image_bytes as i64).into()),
        ("dense_allocs_after_warmup", (allocs as i64).into()),
        ("gather_s", (st.gather_s - warm.gather_s).into()),
        ("gathers_incremental", incremental.into()),
        ("absorb_noop_gathers", absorb_noops.into()),
    ]);
    let path = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_decode.json".into());
    std::fs::write(&path, out.to_string() + "\n")?;
    println!("wrote {path}");
    Ok(())
}

/// Burst-intake serving scenario (device-free, full reactor control path):
/// the decoupled intake stage must absorb a whole burst in ONE reactor
/// round, shutdown must admit zero further sequences, and a mid-decode
/// client disconnect must return the sequence's arena pages before the next
/// round. Emits machine-readable `BENCH_serving.json` (path override:
/// `BENCH_SERVING_JSON`) with intake-latency and TTFT-at-first-token stats,
/// plus the split-phase overlap record nested under `"overlap"` (see
/// [`overlap_scenario`]).
fn burst_intake_scenario(smoke: bool) -> anyhow::Result<()> {
    let burst_n = 32usize;
    let iters = if smoke { 3usize } else { 20 };
    let no_hook = |_: &mut Json| {};
    let gen_line = |id: usize| {
        format!(r#"{{"op":"generate","id":{id},"prompt_tokens":[1,2,3,4],"max_new_tokens":8}}"#)
    };

    // (a) burst admission: capacity allows the whole burst -> all of it is
    // active after exactly one reactor round
    let mut intake_latency = Samples::new();
    let mut ttft_ms = Samples::new();
    for _ in 0..iters {
        let sched = Scheduler::new(InstantBackend, 128, 16, burst_n, 4 * burst_n);
        let mut reactor = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        let mut replies = Vec::with_capacity(burst_n);
        for i in 0..burst_n {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Work::Req { line: gen_line(i), reply: rtx, cancel: CancelToken::new() })
                .unwrap();
            replies.push(rrx);
        }
        let t0 = std::time::Instant::now();
        reactor.poll(&rx, &no_hook);
        intake_latency.record(t0.elapsed().as_secs_f64());
        let (q, a) = reactor.sched().depth();
        assert_eq!(
            (q, a),
            (0, burst_n),
            "burst of {burst_n} must be fully admitted within one scheduling round"
        );
        assert_eq!(reactor.metrics().intake_depth.max(), burst_n as f64);
        while reactor.sched().has_work() {
            reactor.poll(&rx, &no_hook);
        }
        for rrx in replies {
            let j = Json::parse(&rrx.recv()?).unwrap();
            assert_eq!(j.bool_of("ok"), Some(true));
            ttft_ms.record(j.f64_of("ttft_ms").unwrap());
        }
    }

    // (b) post-shutdown: zero admissions, explicit rejection
    let sched = Scheduler::new(InstantBackend, 128, 16, burst_n, 4 * burst_n);
    let mut reactor = Reactor::new(sched, 64);
    let (tx, rx) = mpsc::channel();
    let (stx, srx) = mpsc::channel();
    tx.send(Work::Req {
        line: r#"{"op":"shutdown","id":0}"#.into(),
        reply: stx,
        cancel: CancelToken::new(),
    })
    .unwrap();
    let mut late = Vec::new();
    for i in 0..burst_n {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Work::Req { line: gen_line(i), reply: rtx, cancel: CancelToken::new() }).unwrap();
        late.push(rrx);
    }
    reactor.poll(&rx, &no_hook);
    assert_eq!(reactor.sched().depth(), (0, 0), "zero sequences may be admitted after shutdown");
    let rejected_shutdown = reactor.metrics().rejected_shutdown;
    assert_eq!(rejected_shutdown, burst_n as u64);
    srx.recv()?;
    for rrx in late {
        let j = Json::parse(&rrx.recv()?).unwrap();
        assert_eq!(j.bool_of("ok"), Some(false));
        assert_eq!(j.str_of("error"), Some(SHUTTING_DOWN));
    }

    // (c) mid-decode cancellation returns arena pages before the next round
    let (l, h, c, dh) = (8usize, 4usize, 2048usize, 24usize);
    let arena = KvArena::new();
    let policy = make_policy("lacache:budget=128,span=2", l)?;
    let est_seq_bytes = seq_footprint_bytes(l, h * dh, 256);
    let backend = ArenaBackend {
        arena: arena.clone(),
        policy,
        l,
        h,
        c,
        dh,
        est_seq_bytes,
        budget_bytes: usize::MAX,
    };
    let mut s = Scheduler::new(backend, 128, 16, 4, 16);
    let cancel = CancelToken::new();
    s.submit(vec![1; 128], 1024, cancel.clone())?;
    s.step(); // admit + prefill the whole 128-token prompt
    s.step(); // first decode quantum -> mid-decode
    let mid_bytes = arena.stats().bytes_in_use;
    assert!(mid_bytes > 0, "mid-decode sequence must hold arena pages");
    cancel.cancel();
    let done = s.step(); // reap happens before any further quantum
    assert!(done.iter().any(|f| f.cancelled), "cancelled exit record expected");
    assert_eq!(
        arena.stats().bytes_in_use,
        0,
        "cancelled client's arena pages must be released before the next round"
    );

    println!(
        "\nburst-intake: {burst_n}-req burst x{iters} | intake+admit round p50 {:.1} us | \
         ttft p50 {:.3} ms p95 {:.3} ms | {rejected_shutdown} post-shutdown rejections | \
         {mid_bytes} B released on mid-decode cancel",
        intake_latency.p50() * 1e6,
        ttft_ms.p50(),
        ttft_ms.p95(),
    );

    // (d) split-phase overlap: decoder ITL while a long prefill is in flight
    let overlap = overlap_scenario(smoke)?;

    let out = Json::from_pairs(vec![
        ("bench", "burst_intake".into()),
        ("smoke", smoke.into()),
        ("burst_n", burst_n.into()),
        ("iters", iters.into()),
        ("rounds_to_admit_burst", 1usize.into()),
        ("intake_latency_s_p50", intake_latency.p50().into()),
        ("intake_latency_s_p95", intake_latency.p95().into()),
        ("ttft_ms_p50", ttft_ms.p50().into()),
        ("ttft_ms_p95", ttft_ms.p95().into()),
        ("ttft_ms_max", ttft_ms.max().into()),
        ("rejected_after_shutdown", (rejected_shutdown as i64).into()),
        ("cancel_released_bytes", (mid_bytes as i64).into()),
        ("overlap", overlap),
    ]);
    let path = std::env::var("BENCH_SERVING_JSON").unwrap_or_else(|_| "BENCH_serving.json".into());
    std::fs::write(&path, out.to_string() + "\n")?;
    println!("wrote {path}");
    Ok(())
}

/// In-flight call output for the simulated split-phase backend below.
type SimOut = (SimSeq, anyhow::Result<CallOut>);

struct SimSeq {
    emitted: usize,
}

/// Device-free split-phase backend whose calls cost pure wall-clock:
/// prefill burns a fixed latency per prompt token and decode a fixed
/// latency per quantum. With `ex` set, calls run on the scoped worker pool
/// (split-phase submit/reap); with `ex == None` the trait's inline default
/// path runs — the synchronous contrast the overlap scenario measures
/// against.
struct SimBackend<'env> {
    ex: Option<CallExecutor<'env, SimOut>>,
    prefill_us_per_token: u64,
    decode_sleep: Duration,
}

fn sim_decode(seq: &mut SimSeq, n: usize, sleep: Duration) -> anyhow::Result<Decoded> {
    std::thread::sleep(sleep);
    let tokens: Vec<i32> = (0..n).map(|i| 100 + (seq.emitted + i) as i32).collect();
    seq.emitted += n;
    Ok(Decoded { tokens, t_first: Some(std::time::Instant::now()) })
}

impl SeqBackend for SimBackend<'_> {
    type Seq = SimSeq;
    fn new_seq(&mut self) -> anyhow::Result<SimSeq> {
        Ok(SimSeq { emitted: 0 })
    }
    fn prefill_chunk(&mut self, _s: &mut SimSeq, c: &[i32]) -> anyhow::Result<()> {
        std::thread::sleep(Duration::from_micros(self.prefill_us_per_token * c.len() as u64));
        Ok(())
    }
    fn decode(&mut self, s: &mut SimSeq, n: usize) -> anyhow::Result<Decoded> {
        sim_decode(s, n, self.decode_sleep)
    }
    fn inflight_capacity(&self) -> usize {
        self.ex.as_ref().map_or(1, |ex| ex.workers())
    }
    fn submit_prefill(
        &mut self,
        ticket: Ticket,
        mut seq: SimSeq,
        chunk: &[i32],
    ) -> Submitted<SimSeq> {
        if let Some(ex) = self.ex.as_mut() {
            let us = self.prefill_us_per_token * chunk.len() as u64;
            ex.submit(ticket, move || {
                std::thread::sleep(Duration::from_micros(us));
                (seq, Ok(CallOut::Prefill))
            });
            return Submitted::InFlight;
        }
        let result = self.prefill_chunk(&mut seq, chunk).map(|()| CallOut::Prefill);
        Submitted::Done(CallDone { ticket, seq: Some(seq), result })
    }
    fn submit_decode(&mut self, ticket: Ticket, mut seq: SimSeq, n: usize) -> Submitted<SimSeq> {
        if let Some(ex) = self.ex.as_mut() {
            let sleep = self.decode_sleep;
            ex.submit(ticket, move || {
                let result = sim_decode(&mut seq, n, sleep).map(CallOut::Decode);
                (seq, result)
            });
            return Submitted::InFlight;
        }
        let result = self.decode(&mut seq, n).map(CallOut::Decode);
        Submitted::Done(CallDone { ticket, seq: Some(seq), result })
    }
    fn reap(&mut self, wait: Option<Duration>) -> Vec<CallDone<SimSeq>> {
        match self.ex.as_mut() {
            Some(ex) => ex.reap(wait).into_iter().map(pool_call_done).collect(),
            None => Vec::new(),
        }
    }
}

/// Map a worker-pool [`Completion`] to the scheduler's [`CallDone`]: a
/// panicked job dropped its sequence state during unwind, so it comes back
/// as `seq: None` with a structured fatal error.
fn pool_call_done<S>(c: Completion<(S, anyhow::Result<CallOut>)>) -> CallDone<S> {
    match c.out {
        Ok((seq, result)) => CallDone { ticket: c.ticket, seq: Some(seq), result },
        Err(panic) => CallDone {
            ticket: c.ticket,
            seq: None,
            result: Err(CallError::fatal(format!("worker panic: {panic}"))),
        },
    }
}

/// Drive one overlap case to completion: `decoders` short-prompt sequences
/// decoding `decode_quanta` quanta each, plus (when `prefill_tokens > 0`)
/// one long prefill admitted alongside them. Returns the decoders'
/// inter-token latency samples — the long prefill generates a single
/// token, which produces no ITL sample, so it never pollutes the fleet's
/// distribution.
fn drive_sim(
    mut s: Scheduler<SimBackend<'_>>,
    decoders: usize,
    decode_quanta: usize,
    quantum: usize,
    prefill_tokens: usize,
) -> anyhow::Result<Samples> {
    if prefill_tokens > 0 {
        s.submit(vec![9; prefill_tokens], 1, CancelToken::new())?;
    }
    for _ in 0..decoders {
        s.submit(vec![1], decode_quanta * quantum, CancelToken::new())?;
    }
    let mut itl = Samples::new();
    let mut finished = 0usize;
    let t0 = std::time::Instant::now();
    while s.has_work() && t0.elapsed() < Duration::from_secs(60) {
        finished += s.step().len();
        for x in s.take_itl() {
            itl.record(x);
        }
    }
    let want = decoders + usize::from(prefill_tokens > 0);
    anyhow::ensure!(finished == want, "overlap case finished {finished}/{want} sequences");
    Ok(itl)
}

/// Split-phase overlap scenario: one long multi-window prefill joins a
/// fleet of short decoders. Measures decoder inter-token latency three
/// ways — split-phase with no prefill (baseline), split-phase with the
/// prefill in flight (one worker slot busy ~40 ms per window chunk), and
/// synchronous dispatch with the prefill (every chunk stalls the whole
/// fleet) — and asserts the split-phase decoder ITL p95 stays within 2x of
/// the no-prefill baseline. Returns the record nested under `"overlap"` in
/// `BENCH_serving.json`.
fn overlap_scenario(smoke: bool) -> anyhow::Result<Json> {
    let (window, quantum) = (64usize, 4usize);
    let (decoders, workers) = (8usize, 4usize);
    let decode_quanta = if smoke { 4usize } else { 8 };
    let prefill_chunks = if smoke { 2usize } else { 4 };
    let prefill_tokens = prefill_chunks * window;
    let prefill_us_per_token = 625u64; // 40 ms per 64-token window chunk
    let decode_sleep = Duration::from_millis(5);
    let max_active = decoders + 1;

    // (a) split-phase baseline: the decode fleet alone on `workers` slots
    let baseline = std::thread::scope(|scope| {
        let backend = SimBackend {
            ex: Some(CallExecutor::new(scope, workers)),
            prefill_us_per_token,
            decode_sleep,
        };
        let s = Scheduler::new(backend, window, quantum, max_active, 16);
        drive_sim(s, decoders, decode_quanta, quantum, 0)
    })?;
    // (b) split-phase overlap: same fleet + one long prefill sharing slots
    let overlap = std::thread::scope(|scope| {
        let backend = SimBackend {
            ex: Some(CallExecutor::new(scope, workers)),
            prefill_us_per_token,
            decode_sleep,
        };
        let s = Scheduler::new(backend, window, quantum, max_active, 16);
        drive_sim(s, decoders, decode_quanta, quantum, prefill_tokens)
    })?;
    // (c) sync contrast: every 40 ms prefill chunk stalls the whole fleet
    let backend = SimBackend { ex: None, prefill_us_per_token, decode_sleep };
    let s = Scheduler::new(backend, window, quantum, max_active, 16);
    let sync = drive_sim(s, decoders, decode_quanta, quantum, prefill_tokens)?;

    let base_p95 = baseline.p95() * 1e3;
    let over_p95 = overlap.p95() * 1e3;
    let sync_p95 = sync.p95() * 1e3;
    let ratio = over_p95 / base_p95.max(1e-9);
    assert!(
        over_p95 <= 2.0 * base_p95,
        "split-phase decoder ITL p95 must stay within 2x of the no-prefill baseline \
         (overlap {over_p95:.3} ms vs baseline {base_p95:.3} ms)"
    );
    println!(
        "overlap: {decoders} decoders + {prefill_chunks}x{window}-token prefill on {workers} \
         in-flight slots | decoder ITL p95: baseline {base_p95:.3} ms | split-phase \
         {over_p95:.3} ms ({ratio:.2}x) | sync {sync_p95:.3} ms"
    );
    Ok(Json::from_pairs(vec![
        ("decoders", decoders.into()),
        ("workers", workers.into()),
        ("decode_quanta", decode_quanta.into()),
        ("prefill_chunks", prefill_chunks.into()),
        ("window", window.into()),
        ("baseline_itl_ms_p50", (baseline.p50() * 1e3).into()),
        ("baseline_itl_ms_p95", base_p95.into()),
        ("overlap_itl_ms_p50", (overlap.p50() * 1e3).into()),
        ("overlap_itl_ms_p95", over_p95.into()),
        ("overlap_over_baseline_p95", ratio.into()),
        ("sync_itl_ms_p95", sync_p95.into()),
    ]))
}

/// In-flight call output for the chaos backend.
type ChaosOut = (ChaosSeq, anyhow::Result<CallOut>);

struct ChaosSeq {
    id: u64,
    emitted: usize,
    /// Per-sequence fault-draw counter: keys [`xla::fault::check_keyed`] so
    /// fault placement is a pure function of (seed, site, sequence, op) —
    /// independent of thread interleaving across the worker pool.
    draws: u64,
    /// In the panic record, the one sequence whose decode worker panics.
    doomed: bool,
}

/// Split-phase worker-pool backend for the chaos scenario: deterministic
/// token stream per sequence, with seeded fault injection BEFORE any state
/// mutation — a faulted call leaves `emitted` untouched, so a retried
/// quantum reproduces exactly the tokens the fault-free run emits.
struct ChaosBackend<'env> {
    ex: CallExecutor<'env, ChaosOut>,
    next_id: u64,
    decode_sleep: Duration,
    /// `recover` hook invocations (one per retry the scheduler performs).
    recoveries: u64,
    /// Doom the first-admitted sequence (the panic record arms the
    /// `chaos-panic` site; without that rule the flag is inert).
    doom_leader: bool,
}

fn chaos_inject(site: &str, seq: &mut ChaosSeq) -> anyhow::Result<()> {
    seq.draws += 1;
    if let Some(kind) = xla::fault::check_keyed(site, (seq.id << 24) | seq.draws) {
        if let Some(msg) = xla::fault::apply(site, kind) {
            anyhow::bail!(msg);
        }
    }
    Ok(())
}

fn chaos_prefill(seq: &mut ChaosSeq, n: usize) -> anyhow::Result<()> {
    chaos_inject("chaos-prefill", seq)?;
    std::thread::sleep(Duration::from_micros(30 * n as u64));
    Ok(())
}

fn chaos_decode(seq: &mut ChaosSeq, n: usize, sleep: Duration) -> anyhow::Result<Decoded> {
    if seq.doomed && seq.emitted > 0 {
        // mid-decode (the first quantum already emitted): the panic record's
        // plan makes this site panic the worker thread
        if let Some(kind) = xla::fault::check("chaos-panic") {
            let _ = xla::fault::apply("chaos-panic", kind);
        }
    }
    chaos_inject("chaos-decode", seq)?;
    std::thread::sleep(sleep);
    let tokens: Vec<i32> =
        (0..n).map(|i| (seq.id as i32) * 1000 + (seq.emitted + i) as i32).collect();
    seq.emitted += n;
    Ok(Decoded { tokens, t_first: Some(std::time::Instant::now()) })
}

impl SeqBackend for ChaosBackend<'_> {
    type Seq = ChaosSeq;
    fn new_seq(&mut self) -> anyhow::Result<ChaosSeq> {
        let id = self.next_id;
        self.next_id += 1;
        Ok(ChaosSeq { id, emitted: 0, draws: 0, doomed: self.doom_leader && id == 0 })
    }
    fn prefill_chunk(&mut self, s: &mut ChaosSeq, c: &[i32]) -> anyhow::Result<()> {
        chaos_prefill(s, c.len())
    }
    fn decode(&mut self, s: &mut ChaosSeq, n: usize) -> anyhow::Result<Decoded> {
        chaos_decode(s, n, self.decode_sleep)
    }
    fn inflight_capacity(&self) -> usize {
        self.ex.workers()
    }
    fn recover(&mut self, _seq: &mut ChaosSeq, _pos: usize) {
        self.recoveries += 1;
    }
    fn submit_prefill(
        &mut self,
        ticket: Ticket,
        mut seq: ChaosSeq,
        chunk: &[i32],
    ) -> Submitted<ChaosSeq> {
        let n = chunk.len();
        self.ex.submit(ticket, move || {
            let result = chaos_prefill(&mut seq, n).map(|()| CallOut::Prefill);
            (seq, result)
        });
        Submitted::InFlight
    }
    fn submit_decode(&mut self, ticket: Ticket, mut seq: ChaosSeq, n: usize) -> Submitted<ChaosSeq> {
        let sleep = self.decode_sleep;
        self.ex.submit(ticket, move || {
            let result = chaos_decode(&mut seq, n, sleep).map(CallOut::Decode);
            (seq, result)
        });
        Submitted::InFlight
    }
    fn reap(&mut self, wait: Option<Duration>) -> Vec<CallDone<ChaosSeq>> {
        self.ex.reap(wait).into_iter().map(pool_call_done).collect()
    }
}

/// Drive one chaos workload to completion under whatever fault plan is
/// installed. Returns the finish records, decoder ITL samples, the
/// scheduler's fault counters, and the recovery-hook count.
fn chaos_run(
    n_seqs: usize,
    prompt_len: usize,
    max_new: usize,
    workers: usize,
    doom_leader: bool,
) -> anyhow::Result<(Vec<Finished>, Samples, FaultStats, u64)> {
    std::thread::scope(|scope| {
        let backend = ChaosBackend {
            ex: CallExecutor::new(scope, workers),
            next_id: 0,
            decode_sleep: Duration::from_millis(2),
            recoveries: 0,
            doom_leader,
        };
        let mut s = Scheduler::new(backend, 64, 4, n_seqs, 2 * n_seqs);
        for _ in 0..n_seqs {
            s.submit(vec![1; prompt_len], max_new, CancelToken::new())?;
        }
        let mut done = Vec::new();
        let mut itl = Samples::new();
        let t0 = std::time::Instant::now();
        while s.has_work() && t0.elapsed() < Duration::from_secs(60) {
            done.extend(s.step());
            for x in s.take_itl() {
                itl.record(x);
            }
        }
        anyhow::ensure!(done.len() == n_seqs, "chaos run finished {}/{n_seqs}", done.len());
        anyhow::ensure!(s.inflight() == 0, "chaos run left calls in flight");
        let stats = s.fault_stats();
        let recoveries = s.backend().recoveries;
        Ok((done, itl, stats, recoveries))
    })
}

fn tokens_by_id(done: &[Finished]) -> BTreeMap<u64, Vec<i32>> {
    done.iter().map(|f| (f.id, f.tokens.clone())).collect()
}

/// Chaos serving scenario (device-free, full split-phase scheduler +
/// worker-pool path): the fault-injected fleet must be indistinguishable
/// from the fault-free one except in latency.
///
/// 1. **Transient record**: a seeded plan injects transient faults at ~10%
///    of prefill/decode calls. Every sequence must finish with tokens
///    byte-identical to the fault-free run, `retries > 0` (faults actually
///    landed), `quarantined == 0` at the DEFAULT retry budget, one
///    `recover` (rebuild-from-arena) hook call per retry, and decoder ITL
///    p95 within a recorded bound of the fault-free p95.
/// 2. **Panic record**: one worker panic injected mid-decode (after the
///    doomed sequence's first quantum) must quarantine exactly that
///    sequence — structured `fatal` code, partial output kept — while every
///    survivor still matches the fault-free tokens and the pool survives.
///
/// Emits machine-readable `BENCH_chaos.json` (path override:
/// `BENCH_CHAOS_JSON`); `LACACHE_FAULT_SEED` / `LACACHE_FAULT_RATE`
/// override the plan. Faults are drawn per (seed, site, sequence, op), so a
/// given seed replays identically across runs and thread schedules.
fn chaos_scenario(smoke: bool) -> anyhow::Result<()> {
    use xla::fault::{self, FaultKind, FaultPlan};

    let n_seqs = if smoke { 8usize } else { 16 };
    let prompt_len = 96usize; // two prefill chunks at window 64
    let quanta = if smoke { 6usize } else { 12 };
    let max_new = quanta * 4;
    let workers = 4usize;
    let seed0: u64 = std::env::var("LACACHE_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x1acac4e);
    let rate: f64 = std::env::var("LACACHE_FAULT_RATE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.10);

    // fault-free baseline: the ground-truth token streams
    fault::install(None);
    let (base_done, base_itl, base_stats, _) =
        chaos_run(n_seqs, prompt_len, max_new, workers, false)?;
    assert_eq!(base_stats.retries, 0);
    assert!(base_done.iter().all(|f| f.error.is_none()), "fault-free run must be clean");
    let expect = tokens_by_id(&base_done);

    // transient record; a seed whose draws happen to land zero faults would
    // make the asserts vacuous, so bump until at least one retry happened
    // (each seed is still fully deterministic)
    let mut seed = seed0;
    let (f_done, f_itl, f_stats, recoveries) = loop {
        fault::install(Some(
            FaultPlan::new(seed)
                .rule("chaos-prefill", FaultKind::Transient, rate)
                .rule("chaos-decode", FaultKind::Transient, rate),
        ));
        let run = chaos_run(n_seqs, prompt_len, max_new, workers, false)?;
        if run.2.retries > 0 {
            break run;
        }
        println!("chaos: seed {seed} drew no faults at rate {rate}; bumping");
        seed += 1;
    };
    fault::install(None);
    for f in &f_done {
        assert!(f.error.is_none(), "faulted run must fully recover, got: {:?}", f.error);
    }
    assert_eq!(
        tokens_by_id(&f_done),
        expect,
        "recovered sequences must be byte-identical to the fault-free run"
    );
    assert_eq!(f_stats.quarantined, 0, "default retry budget must absorb a {rate} fault rate");
    assert_eq!(recoveries, f_stats.retries, "every retry must run rebuild-from-arena recovery");
    let base_p95_ms = base_itl.p95() * 1e3;
    let f_p95_ms = f_itl.p95() * 1e3;
    let itl_bound_ms = 10.0 * base_p95_ms.max(2.0) + 50.0;
    assert!(
        f_p95_ms <= itl_bound_ms,
        "faulted decoder ITL p95 {f_p95_ms:.3} ms exceeds bound {itl_bound_ms:.3} ms \
         (fault-free p95 {base_p95_ms:.3} ms)"
    );

    // panic record: one worker panic mid-decode kills only its sequence
    fault::install(Some(FaultPlan::new(seed).rule("chaos-panic", FaultKind::Panic, 1.0)));
    let (p_done, _, p_stats, _) = chaos_run(n_seqs, prompt_len, max_new, workers, true)?;
    fault::install(None);
    assert_eq!(p_stats.quarantined, 1, "exactly the doomed sequence must be quarantined");
    let doomed: Vec<&Finished> = p_done.iter().filter(|f| f.error.is_some()).collect();
    assert_eq!(doomed.len(), 1);
    let d = doomed[0];
    assert_eq!(d.code.as_deref(), Some("fatal"));
    assert!(d.error.as_deref().unwrap_or("").contains("panic"), "error must name the panic");
    let partial = d.tokens.len();
    assert!(
        partial > 0 && partial < max_new,
        "panic landed mid-decode: partial output expected, got {partial}/{max_new} tokens"
    );
    let survivors = p_done.iter().filter(|f| f.error.is_none()).count();
    assert_eq!(survivors, n_seqs - 1, "every other sequence must survive the worker panic");
    for f in p_done.iter().filter(|f| f.error.is_none()) {
        assert_eq!(Some(&f.tokens), expect.get(&f.id), "survivors must match fault-free output");
    }

    println!(
        "\nchaos: {n_seqs} seqs x {prompt_len}+{max_new} tokens | seed {seed} rate {rate} | \
         {} retries, {} recoveries, 0 quarantined, tokens identical | ITL p95 fault-free \
         {base_p95_ms:.3} ms vs faulted {f_p95_ms:.3} ms (bound {itl_bound_ms:.1} ms) | panic: \
         1 quarantined ({partial}-token partial), {survivors} survivors",
        f_stats.retries, recoveries,
    );

    let out = Json::from_pairs(vec![
        ("bench", "chaos_serving".into()),
        ("smoke", smoke.into()),
        ("sequences", n_seqs.into()),
        ("prompt_tokens", prompt_len.into()),
        ("max_new_tokens", max_new.into()),
        ("fault_seed", (seed as i64).into()),
        ("fault_rate", rate.into()),
        ("retries", (f_stats.retries as i64).into()),
        ("recoveries", (recoveries as i64).into()),
        ("quarantined", (f_stats.quarantined as i64).into()),
        ("tokens_identical_to_fault_free", true.into()),
        ("itl_ms_p50_fault_free", (base_itl.p50() * 1e3).into()),
        ("itl_ms_p95_fault_free", base_p95_ms.into()),
        ("itl_ms_p50_faulted", (f_itl.p50() * 1e3).into()),
        ("itl_ms_p95_faulted", f_p95_ms.into()),
        ("itl_ms_p95_bound", itl_bound_ms.into()),
        ("panic_quarantined", (p_stats.quarantined as i64).into()),
        ("panic_partial_tokens", partial.into()),
        ("panic_survivors", survivors.into()),
    ]);
    let path = std::env::var("BENCH_CHAOS_JSON").unwrap_or_else(|_| "BENCH_chaos.json".into());
    std::fs::write(&path, out.to_string() + "\n")?;
    println!("wrote {path}");
    Ok(())
}

/// Device-free sequence backend over a real paged-KV arena: prefill appends
/// window rows, decode appends one row per token, and the ladder policy
/// compacts between rounds — the full storage path minus PJRT.
struct ArenaBackend {
    arena: KvArena,
    policy: Box<dyn CachePolicy>,
    l: usize,
    h: usize,
    c: usize,
    dh: usize,
    est_seq_bytes: usize,
    budget_bytes: usize,
}

struct ArenaSeq {
    kv: KvCache,
    next_pos: u64,
}

impl ArenaBackend {
    fn append_all_layers(&self, s: &mut ArenaSeq, n: usize) -> anyhow::Result<()> {
        let row = vec![0.125f32; self.h * n * self.dh];
        for layer in 0..self.l {
            s.kv.append_layer(layer, &row, &row, n, n, s.next_pos)?;
        }
        s.next_pos += n as u64;
        self.policy.evict(&mut s.kv)?;
        Ok(())
    }
}

impl SeqBackend for ArenaBackend {
    type Seq = ArenaSeq;

    fn new_seq(&mut self) -> anyhow::Result<ArenaSeq> {
        let kv = KvCache::with_arena(self.arena.clone(), self.l, self.h, self.c, self.dh);
        Ok(ArenaSeq { kv, next_pos: 0 })
    }

    fn prefill_chunk(&mut self, s: &mut ArenaSeq, chunk: &[i32]) -> anyhow::Result<()> {
        self.append_all_layers(s, chunk.len())
    }

    fn decode(&mut self, s: &mut ArenaSeq, n: usize) -> anyhow::Result<Decoded> {
        for _ in 0..n {
            self.append_all_layers(s, 1)?;
        }
        Ok(Decoded { tokens: vec![7; n], t_first: None })
    }

    fn can_admit(&self, active: usize) -> bool {
        // the same gate the serving path uses (no staging tiers here: this
        // backend never promotes images, so staging_bytes is 0)
        admission_ok(&self.arena.stats(), active, self.est_seq_bytes, self.budget_bytes, 0, 0)
    }
}

/// Memory-pressure scenario: under one fixed simulated byte budget, how many
/// ladder-policy sequences run concurrently with arena paging vs. the old
/// eagerly-allocated dense `2·L·H·C·Dh` cache per sequence?
fn memory_pressure_scenario() -> anyhow::Result<()> {
    let (l, h, c, dh) = (8usize, 4usize, 2048usize, 24usize);
    let (window, quantum) = (128usize, 16usize);
    let dense_seq_bytes = 2 * l * h * c * dh * 4;
    let budget_bytes = 4 * dense_seq_bytes; // dense fits exactly 4 sequences
    let dense_concurrent = budget_bytes / dense_seq_bytes;

    let arena = KvArena::new();
    arena.set_budget(Some(budget_bytes));
    let policy = make_policy("lacache:budget=128,span=2", l)?;
    let slots = policy.budget().saturating_add(window).min(c);
    let est_seq_bytes = seq_footprint_bytes(l, h * dh, slots);
    let backend =
        ArenaBackend { arena: arena.clone(), policy, l, h, c, dh, est_seq_bytes, budget_bytes };

    let n_requests = 64;
    let mut s = Scheduler::new(backend, window, quantum, usize::MAX, n_requests);
    for _ in 0..n_requests {
        s.submit(vec![1; 384], 32, CancelToken::new()).unwrap();
    }
    let mut peak_active = 0usize;
    let mut finished = 0usize;
    let mut rounds = 0usize;
    while s.has_work() && rounds < 100_000 {
        finished += s.step().len();
        peak_active = peak_active.max(s.depth().1);
        rounds += 1;
    }
    let st = arena.stats();
    println!(
        "\nmemory-pressure: byte budget {:.1} MiB | dense baseline {} concurrent seqs \
         | paged arena peak {} concurrent ({}x) | arena high-water {:.1} MiB | {} finished",
        budget_bytes as f64 / (1 << 20) as f64,
        dense_concurrent,
        peak_active,
        peak_active / dense_concurrent.max(1),
        st.high_water as f64 / (1 << 20) as f64,
        finished,
    );
    assert_eq!(finished, n_requests, "scenario did not drain");
    assert!(st.high_water <= budget_bytes, "arena exceeded its budget");
    assert!(
        peak_active >= 4 * dense_concurrent,
        "paged arena should fit >=4x the dense baseline's concurrency \
         (got {peak_active} vs dense {dense_concurrent})"
    );
    Ok(())
}

/// Device-free cross-request prefix backend: prefill appends real rows into
/// the arena (so prefill cost and occupancy are real) and the ladder policy
/// compacts after every chunk; full-window boundaries publish frozen
/// snapshots into a [`PrefixCache`], and admission-time adoption installs
/// them into fresh sequences — the scheduler then never hands the matched
/// span to prefill. Decode appends one row per token and compacts once per
/// quantum (the engine's cadence).
struct PrefixBackend {
    arena: KvArena,
    prefix: PrefixCache,
    policy: Box<dyn CachePolicy>,
    l: usize,
    h: usize,
    c: usize,
    dh: usize,
    window: usize,
    /// Tokens actually prefilled — the on-device prefill-call proxy the
    /// scenario asserts on ("the shared span is prefilled exactly once").
    prefill_tokens: u64,
}

struct PrefixSeq {
    kv: KvCache,
    ingested: Vec<i32>,
    next_pos: u64,
}

impl PrefixBackend {
    fn fill_row(&self, row: &mut [f32], n: usize, i: usize, tok: i32, pos: u64) {
        let v = tok as f32 * 1e-3 + pos as f32 * 1e-6;
        for hh in 0..self.h {
            for d in 0..self.dh {
                row[(hh * n + i) * self.dh + d] = v;
            }
        }
    }
}

impl SeqBackend for PrefixBackend {
    type Seq = PrefixSeq;

    fn new_seq(&mut self) -> anyhow::Result<PrefixSeq> {
        let kv = KvCache::with_arena(self.arena.clone(), self.l, self.h, self.c, self.dh);
        Ok(PrefixSeq { kv, ingested: Vec::new(), next_pos: 0 })
    }

    fn adopt_prefix(&mut self, seq: &mut PrefixSeq, prompt: &[i32], allow: bool) -> usize {
        if !allow {
            return 0;
        }
        let Some((matched, snap)) = self.prefix.lookup(prompt) else {
            return 0;
        };
        if snap.apply(&mut seq.kv).is_err() {
            return 0;
        }
        seq.ingested.extend_from_slice(&prompt[..matched]);
        seq.next_pos = matched as u64;
        matched
    }

    fn prefill_chunk(&mut self, seq: &mut PrefixSeq, chunk: &[i32]) -> anyhow::Result<()> {
        let n = chunk.len();
        let mut row = vec![0.0f32; self.h * n * self.dh];
        for (i, &tok) in chunk.iter().enumerate() {
            self.fill_row(&mut row, n, i, tok, seq.next_pos + i as u64);
        }
        for layer in 0..self.l {
            seq.kv.append_layer(layer, &row, &row, n, n, seq.next_pos)?;
        }
        seq.next_pos += n as u64;
        self.policy.evict(&mut seq.kv)?;
        self.prefill_tokens += n as u64;
        seq.ingested.extend_from_slice(chunk);
        let w = self.window;
        if !seq.ingested.is_empty() && seq.ingested.len() % w == 0 {
            let kv = &mut seq.kv;
            self.prefix.insert_with(&seq.ingested, w, || PrefixSnapshot::freeze(kv));
        }
        Ok(())
    }

    fn decode(&mut self, seq: &mut PrefixSeq, n: usize) -> anyhow::Result<Decoded> {
        let mut row = vec![0.0f32; self.h * self.dh];
        for _ in 0..n {
            let tok = 1000 + seq.next_pos as i32;
            self.fill_row(&mut row, 1, 0, tok, seq.next_pos);
            for layer in 0..self.l {
                seq.kv.append_layer(layer, &row, &row, 1, 1, seq.next_pos)?;
            }
            seq.next_pos += 1;
        }
        self.policy.evict(&mut seq.kv)?;
        Ok(Decoded { tokens: vec![7; n], t_first: None })
    }
}

/// Cross-request shared-prefix scenario (device-free, full scheduler
/// path): one cold leader prefills an 8-window system prompt, publishing a
/// frozen snapshot at every window boundary; 7 followers submit the same
/// prompt, adopt the deepest snapshot at admission, and skip prefill
/// entirely. Asserts the subsystem's serving guarantees:
///
/// 1. the shared span is prefilled exactly once across all 8 sequences
///    (`prefix_hits == 7`, total prefilled tokens == one prompt);
/// 2. follower TTFT (p50) beats the cold leader's TTFT;
/// 3. the shared span's arena bytes are charged once however many forks
///    pin it, CoW charges only privately-written pages, and refcounts
///    return everything on drop (direct 8-fork segment).
///
/// Emits machine-readable `BENCH_prefix.json` (path override:
/// `BENCH_PREFIX_JSON`) for the CI perf trajectory.
fn shared_prefix_scenario(smoke: bool) -> anyhow::Result<()> {
    let (l, h, c, dh) = (8usize, 4usize, 2048usize, 24usize);
    let (window, quantum) = (128usize, 16usize);
    let shared_windows = 8usize; // acceptance floor is >= 4
    let prompt: Vec<i32> = (0..(shared_windows * window) as i32).map(|t| t % 251).collect();
    let arena = KvArena::new();
    let policy = make_policy("lacache:budget=128,span=2", l)?;
    let backend = PrefixBackend {
        arena: arena.clone(),
        prefix: PrefixCache::new("bench".into(), 256 << 20),
        policy,
        l,
        h,
        c,
        dh,
        window,
        prefill_tokens: 0,
    };
    let mut s = Scheduler::new(backend, window, quantum, 8, 16);

    // cold leader: pays the full prefill and publishes the snapshots
    s.submit(prompt.clone(), quantum, CancelToken::new())?;
    let mut cold = Vec::new();
    while s.has_work() {
        cold.extend(s.step());
    }
    assert_eq!(cold.len(), 1);
    assert!(cold[0].error.is_none());
    assert_eq!(cold[0].prefix_tokens, 0, "leader must start cold");
    let cold_ttft = cold[0].ttft_s;
    assert_eq!(s.backend().prefill_tokens, prompt.len() as u64);

    // 7 followers share the full prompt: admission adopts, prefill skipped
    for _ in 0..7 {
        s.submit(prompt.clone(), quantum, CancelToken::new())?;
    }
    let mut done = Vec::new();
    while s.has_work() {
        done.extend(s.step());
    }
    assert_eq!(done.len(), 7);
    let mut follower_ttft = Samples::new();
    for f in &done {
        assert!(f.error.is_none(), "follower failed: {:?}", f.error);
        assert_eq!(f.prefix_tokens, prompt.len(), "follower must adopt the full shared span");
        follower_ttft.record(f.ttft_s);
    }
    let st = s.backend().prefix.stats();
    assert_eq!(st.hits, 7, "prefix_hits must count one hit per follower");
    assert_eq!(st.tokens_reused, 7 * prompt.len() as u64);
    assert_eq!(
        s.backend().prefill_tokens,
        prompt.len() as u64,
        "the shared span must be prefilled on-device exactly once across all 8 sequences"
    );
    let follower_p50 = follower_ttft.p50();
    assert!(
        follower_p50 < cold_ttft,
        "adopting followers must beat the cold TTFT ({follower_p50:.6}s vs {cold_ttft:.6}s)"
    );

    // charged-once + leak check, direct (no scheduler): 8 forks off one
    // frozen prefix pin ZERO extra arena bytes until they mutate
    let arena2 = KvArena::new();
    let mut donor = KvCache::with_arena(arena2.clone(), l, h, c, dh);
    // NOT a multiple of PAGE_SLOTS: the forks' first append lands in the
    // shared partial tail page, so it must CoW (a full tail would just
    // allocate a fresh private page)
    let n_prefix = 250usize;
    let row = vec![0.5f32; h * n_prefix * dh];
    for layer in 0..l {
        donor.append_layer(layer, &row, &row, n_prefix, n_prefix, 0)?;
    }
    let snap = PrefixSnapshot::freeze(&mut donor);
    let shared_span_bytes = arena2.stats().bytes_in_use;
    let mut forks = Vec::new();
    for _ in 0..8 {
        let mut kv = KvCache::with_arena(arena2.clone(), l, h, c, dh);
        snap.apply(&mut kv)?;
        forks.push(kv);
    }
    assert_eq!(
        arena2.stats().bytes_in_use,
        shared_span_bytes,
        "8 forks of the shared span must charge its arena bytes exactly once"
    );
    let one = vec![0.25f32; h * dh];
    for layer in 0..l {
        forks[0].append_layer(layer, &one, &one, 1, 1, n_prefix as u64)?;
    }
    let after_write = arena2.stats();
    assert!(after_write.cow_copies > 0, "appending into the shared tail must CoW");
    assert!(after_write.bytes_in_use > shared_span_bytes);
    assert!(after_write.bytes_in_use < 2 * shared_span_bytes, "CoW must copy pages, not spans");
    drop(forks);
    drop(donor);
    drop(snap);
    assert_eq!(arena2.stats().bytes_in_use, 0, "refcounts must return every page on drop");

    let ast = arena.stats();
    let speedup = cold_ttft / follower_p50.max(1e-9);
    println!(
        "\nshared-prefix: {} seqs x {}-token shared prompt | prefill once ({} tokens total) | \
         {} prefix hits | cold ttft {:.3} ms vs follower p50 {:.3} ms ({speedup:.1}x) | \
         {} CoW copies | shared span charged once ({shared_span_bytes} B)",
        8,
        prompt.len(),
        s.backend().prefill_tokens,
        st.hits,
        cold_ttft * 1e3,
        follower_p50 * 1e3,
        ast.cow_copies,
    );

    let out = Json::from_pairs(vec![
        ("bench", "shared_prefix".into()),
        ("smoke", smoke.into()),
        ("shape_lhcd", vec![l, h, c, dh].into()),
        ("shared_windows", shared_windows.into()),
        ("prompt_tokens", prompt.len().into()),
        ("sequences", 8usize.into()),
        ("prefix_hits", (st.hits as i64).into()),
        ("prefix_tokens_reused", (st.tokens_reused as i64).into()),
        ("prefill_tokens_total", (s.backend().prefill_tokens as i64).into()),
        ("cold_ttft_ms", (cold_ttft * 1e3).into()),
        ("follower_ttft_ms_p50", (follower_p50 * 1e3).into()),
        ("ttft_speedup", speedup.into()),
        ("cow_copies", (ast.cow_copies as i64).into()),
        ("shared_span_bytes", (shared_span_bytes as i64).into()),
        ("shared_span_charged_once", true.into()),
    ]);
    let path = std::env::var("BENCH_PREFIX_JSON").unwrap_or_else(|_| "BENCH_prefix.json".into());
    std::fs::write(&path, out.to_string() + "\n")?;
    println!("wrote {path}");
    Ok(())
}

/// Multi-shard serving substrate for [`shard_scenario`]: N per-device
/// residency tiers + scratch pools over one multi-device stub client, one
/// logical prefix tree whose snapshots record a home shard, and the real
/// [`place`] policy deciding every admission — the same pieces the sharded
/// `EngineBackend` composes, minus the model.
struct ShardBenchBackend {
    client: xla::PjRtClient,
    arena: KvArena,
    prefix: PrefixCache,
    placement: PlacementStats,
    tiers: Vec<DeviceTier>,
    pools: Vec<ScratchPool>,
    policy: Box<dyn CachePolicy>,
    l: usize,
    h: usize,
    c: usize,
    dh: usize,
    window: usize,
    /// Tokens actually prefilled — grows only for cold (non-adopted) spans.
    prefill_tokens: u64,
}

struct ShardBenchSeq {
    kv: KvCache,
    ingested: Vec<i32>,
    next_pos: u64,
    shard: usize,
}

impl ShardBenchBackend {
    fn new(devices: usize, per_shard_cap: usize, shape: (usize, usize, usize, usize)) -> Self {
        let (l, h, c, dh) = shape;
        Self {
            client: xla::PjRtClient::cpu_with_devices(devices).unwrap(),
            arena: KvArena::new(),
            prefix: PrefixCache::new("bench-shard".into(), 256 << 20),
            placement: PlacementStats::default(),
            tiers: (0..devices).map(|d| DeviceTier::with_device(per_shard_cap, d)).collect(),
            pools: (0..devices).map(|_| ScratchPool::new(4)).collect(),
            policy: make_policy("lacache:budget=128,span=2", l).unwrap(),
            l,
            h,
            c,
            dh,
            window: 128,
            prefill_tokens: 0,
        }
    }

    fn fill_row(&self, row: &mut [f32], n: usize, i: usize, tok: i32, pos: u64) {
        let v = tok as f32 * 1e-3 + pos as f32 * 1e-6;
        for hh in 0..self.h {
            for d in 0..self.dh {
                row[(hh * n + i) * self.dh + d] = v;
            }
        }
    }

    fn shard_loads(&self) -> Vec<ShardLoad> {
        self.tiers
            .iter()
            .map(|t| ShardLoad {
                device: t.device(),
                resident_bytes: t.resident_bytes(),
                inflight: 0,
                degraded: t.degraded(),
                capacity_bytes: t.capacity_bytes(),
            })
            .collect()
    }

    /// Promote the sequence's image into ITS OWN shard's tier — the
    /// runtime's per-call residency path. A failed device call (e.g. the
    /// device was killed) is noted against that tier only; crossing the
    /// consecutive-failure threshold trips the shard's sticky degraded
    /// bypass while every other shard keeps its residency. The KV append
    /// already landed in the arena, so the call itself still succeeds
    /// host-side.
    fn promote(&mut self, seq: &mut ShardBenchSeq) {
        let tier = &mut self.tiers[seq.shard];
        if tier.degraded() {
            return;
        }
        match tier.acquire(&self.client, &mut seq.kv, &mut self.pools[seq.shard]) {
            Ok(_) => tier.note_call_success(),
            Err(_) => tier.note_call_failure(),
        }
    }

    fn aggregate_resident(&self) -> usize {
        self.tiers.iter().map(|t| t.resident_bytes()).sum()
    }
}

impl SeqBackend for ShardBenchBackend {
    type Seq = ShardBenchSeq;

    fn new_seq(&mut self) -> anyhow::Result<ShardBenchSeq> {
        let kv = KvCache::with_arena(self.arena.clone(), self.l, self.h, self.c, self.dh);
        Ok(ShardBenchSeq { kv, ingested: Vec::new(), next_pos: 0, shard: 0 })
    }

    fn adopt_prefix(&mut self, seq: &mut ShardBenchSeq, prompt: &[i32], allow: bool) -> usize {
        let hit = if allow { self.prefix.lookup(prompt) } else { None };
        let preferred = hit.as_ref().map(|(_, snap)| snap.home_shard());
        let placement = place(&self.shard_loads(), preferred);
        self.placement.note(placement.kind);
        seq.shard = placement.shard;
        let Some((matched, snap)) = hit else {
            return 0;
        };
        if placement.shard != snap.home_shard() {
            return 0; // spillover cold-prefills; snapshots never migrate
        }
        if snap.apply(&mut seq.kv).is_err() {
            return 0;
        }
        seq.ingested.extend_from_slice(&prompt[..matched]);
        seq.next_pos = matched as u64;
        matched
    }

    fn prefill_chunk(&mut self, seq: &mut ShardBenchSeq, chunk: &[i32]) -> anyhow::Result<()> {
        let n = chunk.len();
        let mut row = vec![0.0f32; self.h * n * self.dh];
        for (i, &tok) in chunk.iter().enumerate() {
            self.fill_row(&mut row, n, i, tok, seq.next_pos + i as u64);
        }
        for layer in 0..self.l {
            seq.kv.append_layer(layer, &row, &row, n, n, seq.next_pos)?;
        }
        seq.next_pos += n as u64;
        self.policy.evict(&mut seq.kv)?;
        self.prefill_tokens += n as u64;
        seq.ingested.extend_from_slice(chunk);
        let w = self.window;
        if !seq.ingested.is_empty() && seq.ingested.len() % w == 0 {
            let home = seq.shard;
            let kv = &mut seq.kv;
            self.prefix.insert_with(&seq.ingested, w, || PrefixSnapshot::freeze_on(kv, home));
        }
        self.promote(seq);
        Ok(())
    }

    fn decode(&mut self, seq: &mut ShardBenchSeq, n: usize) -> anyhow::Result<Decoded> {
        let mut row = vec![0.0f32; self.h * self.dh];
        for _ in 0..n {
            let tok = 1000 + seq.next_pos as i32;
            self.fill_row(&mut row, 1, 0, tok, seq.next_pos);
            for layer in 0..self.l {
                seq.kv.append_layer(layer, &row, &row, 1, 1, seq.next_pos)?;
            }
            seq.next_pos += 1;
        }
        self.policy.evict(&mut seq.kv)?;
        self.promote(seq);
        Ok(Decoded { tokens: vec![7; n], t_first: None })
    }

    fn can_admit(&self, _active: usize) -> bool {
        true
    }
}

/// Multi-device sharding scenario (full scheduler path over the stub
/// client's `--devices N` analog): two prompt families get distinct home
/// shards, followers place prefix-locally, and one killed device degrades
/// only its own shard. Asserts the subsystem's serving guarantees:
///
/// 1. **capacity scales with shards**: peak aggregate device-resident bytes
///    across the fleet exceed any single shard's residency cap;
/// 2. **locality preserves reuse**: `prefix_hits` equals the `--devices 1`
///    run of the same workload, and no pre-fault follower cold-prefills
///    (total prefilled tokens == the two leader prompts);
/// 3. **decode ITL stays bounded** under the cross-shard concurrent load;
/// 4. **fault isolation**: killing one stub device trips sticky degraded
///    mode on that shard alone — its sequences finish host-side, the other
///    shard keeps its residency, and later sequences homed on the dead
///    shard spill over (counted, cold-prefilled, never migrated).
///
/// Emits machine-readable `BENCH_shard.json` (path override:
/// `BENCH_SHARD_JSON`) for the CI perf trajectory.
fn shard_scenario(smoke: bool) -> anyhow::Result<()> {
    let shape = (8usize, 4usize, 2048usize, 24usize);
    let (l, h, c, dh) = shape;
    let (window, quantum) = (128usize, 16usize);
    let image_bytes = 2 * 4 * l * h * c * dh;
    // holds 2 dense images, not 3: follower load must spill within a shard
    let per_shard_cap = 2 * image_bytes + image_bytes / 2;
    let prompt_windows = 4usize;
    let prompt_a: Vec<i32> = (0..(prompt_windows * window) as i32).map(|t| t % 251).collect();
    let prompt_b: Vec<i32> =
        (0..(prompt_windows * window) as i32).map(|t| 1000 + (t % 241)).collect();

    // --- single-device reference: same workload, no fault ---------------
    let backend1 = ShardBenchBackend::new(1, per_shard_cap, shape);
    let mut s1 = Scheduler::new(backend1, window, quantum, 8, 32);
    let drive = |s: &mut Scheduler<ShardBenchBackend>| {
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.step());
        }
        done
    };
    s1.submit(prompt_a.clone(), quantum, CancelToken::new())?;
    drive(&mut s1);
    s1.submit(prompt_b.clone(), quantum, CancelToken::new())?;
    drive(&mut s1);
    for _ in 0..3 {
        s1.submit(prompt_a.clone(), 2 * quantum, CancelToken::new())?;
        s1.submit(prompt_b.clone(), 2 * quantum, CancelToken::new())?;
    }
    drive(&mut s1);
    s1.submit(prompt_b.clone(), 4 * quantum, CancelToken::new())?;
    s1.submit(prompt_a.clone(), 4 * quantum, CancelToken::new())?;
    s1.submit(prompt_b.clone(), 2 * quantum, CancelToken::new())?;
    let d1 = drive(&mut s1);
    assert!(d1.iter().all(|f| f.error.is_none()));
    let single_hits = s1.backend().prefix.stats().hits;
    let single_prefill = s1.backend().prefill_tokens;
    assert_eq!(single_prefill, (prompt_a.len() + prompt_b.len()) as u64);

    // --- two-shard run: same workload + one device killed mid-run -------
    let devices = 2usize;
    let backend = ShardBenchBackend::new(devices, per_shard_cap, shape);
    let mut s = Scheduler::new(backend, window, quantum, 8, 32);
    let mut itl = Samples::new();
    let mut agg_peak = 0usize;
    let mut finished = Vec::new();

    // leader A cold-prefills on the least-loaded shard (0) and publishes
    // its snapshots there; once its first window is resident, shard 0
    // carries bytes, so leader B's admission lands on shard 1 — distinct
    // home shards by load alone
    s.submit(prompt_a.clone(), quantum, CancelToken::new())?;
    for _ in 0..8 {
        finished.extend(s.step());
        if s.backend().aggregate_resident() > 0 {
            break;
        }
    }
    assert!(s.backend().aggregate_resident() > 0, "leader A's first window must promote");
    s.submit(prompt_b.clone(), quantum, CancelToken::new())?;
    while s.has_work() {
        finished.extend(s.step());
        for x in s.take_itl() {
            itl.record(x);
        }
        agg_peak = agg_peak.max(s.backend().aggregate_resident());
    }
    assert!(
        s.backend().tiers[0].resident_bytes() > 0 && s.backend().tiers[1].resident_bytes() > 0,
        "the two prompt families must land on distinct shards"
    );

    // 6 concurrent followers (3 per family): every one adopts on its home
    // shard, so both shards hold live images at once
    for _ in 0..3 {
        s.submit(prompt_a.clone(), 2 * quantum, CancelToken::new())?;
        s.submit(prompt_b.clone(), 2 * quantum, CancelToken::new())?;
    }
    while s.has_work() {
        finished.extend(s.step());
        for x in s.take_itl() {
            itl.record(x);
        }
        agg_peak = agg_peak.max(s.backend().aggregate_resident());
    }
    assert_eq!(
        s.backend().prefill_tokens,
        (prompt_a.len() + prompt_b.len()) as u64,
        "pre-fault followers must all adopt locally: zero cold prefill beyond the two leaders"
    );
    assert!(
        agg_peak > per_shard_cap,
        "aggregate resident bytes ({agg_peak} B) must exceed one shard's cap \
         ({per_shard_cap} B): the fleet holds more than any single device could"
    );

    // kill device 1: its follower finishes host-side after the shard trips
    // sticky degraded mode; a concurrent shard-0 follower is untouched
    s.backend().client.kill_device(1);
    s.submit(prompt_b.clone(), 4 * quantum, CancelToken::new())?;
    s.submit(prompt_a.clone(), 4 * quantum, CancelToken::new())?;
    while s.has_work() {
        finished.extend(s.step());
        for x in s.take_itl() {
            itl.record(x);
        }
    }
    assert!(
        s.backend().tiers[1].degraded(),
        "repeated failed calls on the killed device must trip its shard degraded"
    );
    assert!(
        !s.backend().tiers[0].degraded(),
        "one lost device must degrade ITS shard only — the fleet keeps serving"
    );

    // post-fault: a new family-B request spills over (home shard degraded),
    // cold-prefills on shard 0, and completes — no cross-device migration
    s.submit(prompt_b.clone(), 2 * quantum, CancelToken::new())?;
    while s.has_work() {
        finished.extend(s.step());
        for x in s.take_itl() {
            itl.record(x);
        }
    }
    for f in &finished {
        assert!(f.error.is_none(), "sequence failed: {:?}", f.error);
    }
    let spill = finished.last().unwrap();
    assert_eq!(spill.prefix_tokens, 0, "spillover must cold-prefill, never migrate pages");
    assert!(s.backend().placement.spillover >= 1);
    assert!(s.backend().placement.local_prefix >= 8, "followers must place prefix-locally");
    assert_eq!(
        s.backend().prefill_tokens,
        (prompt_a.len() + 2 * prompt_b.len()) as u64,
        "exactly one post-fault spillover prefill beyond the two leaders"
    );
    let hits = s.backend().prefix.stats().hits;
    assert_eq!(
        hits, single_hits,
        "prefix-local placement must preserve every hit of the --devices 1 run"
    );
    let itl_p95 = itl.p95();
    assert!(
        itl_p95 < 0.5,
        "decode ITL p95 must stay bounded under sharded load, got {itl_p95:.3}s"
    );

    let n_seqs = finished.len();
    println!(
        "\nshard: {devices} shards x {per_shard_cap} B cap | {n_seqs} seqs, 2 prompt families | \
         aggregate resident peak {agg_peak} B ({:.2}x one shard's cap) | \
         prefix hits {hits} (== 1-device run: {single_hits}) | \
         placement local={} spillover={} | itl p95 {:.3} ms | shard 1 degraded, shard 0 serving",
        agg_peak as f64 / per_shard_cap as f64,
        s.backend().placement.local_prefix,
        s.backend().placement.spillover,
        itl_p95 * 1e3,
    );

    let out = Json::from_pairs(vec![
        ("bench", "shard".into()),
        ("smoke", smoke.into()),
        ("devices", devices.into()),
        ("shape_lhcd", vec![l, h, c, dh].into()),
        ("per_shard_cap_bytes", per_shard_cap.into()),
        ("image_bytes", image_bytes.into()),
        ("aggregate_resident_peak_bytes", agg_peak.into()),
        ("exceeds_single_shard_cap", (agg_peak > per_shard_cap).into()),
        ("prefix_hits", (hits as i64).into()),
        ("prefix_hits_single_device", (single_hits as i64).into()),
        ("prefill_tokens_total", (s.backend().prefill_tokens as i64).into()),
        ("placement_local_prefix", (s.backend().placement.local_prefix as i64).into()),
        ("placement_least_loaded", (s.backend().placement.least_loaded as i64).into()),
        ("placement_spillover", (s.backend().placement.spillover as i64).into()),
        ("placement_host_only", (s.backend().placement.host_only as i64).into()),
        ("itl_ms_p95", (itl_p95 * 1e3).into()),
        ("shard0_degraded", s.backend().tiers[0].degraded().into()),
        ("shard1_degraded", s.backend().tiers[1].degraded().into()),
    ]);
    let path = std::env::var("BENCH_SHARD_JSON").unwrap_or_else(|_| "BENCH_shard.json".into());
    std::fs::write(&path, out.to_string() + "\n")?;
    println!("wrote {path}");
    Ok(())
}

/// Tiered-compression sequence backend: the [`ArenaBackend`] storage path
/// plus the engine's `--kv-quant cold-q8` cadence — after every append the
/// ladder policy compacts, a simulated transfer sync clears the dirty
/// ranges (this backend is device-free, so it stands in for the gather the
/// device path performs), and [`KvCache::demote_cold`] quantizes pages
/// older than the demotion horizon. Full-window prefill boundaries publish
/// frozen snapshots (Q8 under `cold-q8`) and admission adopts the deepest
/// match, so one backend drives both the capacity and the prefix-parity
/// measurements of [`quant_capacity_scenario`].
struct QuantBenchBackend {
    arena: KvArena,
    prefix: PrefixCache,
    policy: Box<dyn CachePolicy>,
    l: usize,
    h: usize,
    c: usize,
    dh: usize,
    window: usize,
    /// `Some(n)` = `--kv-quant cold-q8 --quantize-after-windows n`;
    /// `None` = `--kv-quant off`.
    after: Option<usize>,
    est_seq_bytes: usize,
    budget_bytes: usize,
    /// Tokens actually prefilled (adopted spans never count).
    prefill_tokens: u64,
}

struct QuantSeq {
    kv: KvCache,
    ingested: Vec<i32>,
    next_pos: u64,
}

impl QuantBenchBackend {
    fn fill_row(&self, row: &mut [f32], n: usize, i: usize, tok: i32, pos: u64) {
        let v = tok as f32 * 1e-3 + pos as f32 * 1e-6;
        for hh in 0..self.h {
            for d in 0..self.dh {
                row[(hh * n + i) * self.dh + d] = v;
            }
        }
    }

    /// The engine's per-round cadence after an append: compact, then let
    /// the transfer layer sync the dirty ranges, then demote everything
    /// older than `quantize_after_windows` full windows.
    fn settle(&self, seq: &mut QuantSeq) -> anyhow::Result<()> {
        self.policy.evict(&mut seq.kv)?;
        if let Some(after) = self.after {
            seq.kv.mark_synced();
            seq.kv.demote_cold(seq.next_pos.saturating_sub((after * self.window) as u64));
        }
        Ok(())
    }
}

impl SeqBackend for QuantBenchBackend {
    type Seq = QuantSeq;

    fn new_seq(&mut self) -> anyhow::Result<QuantSeq> {
        let mut kv = KvCache::with_arena(self.arena.clone(), self.l, self.h, self.c, self.dh);
        kv.set_quant(self.after.is_some());
        Ok(QuantSeq { kv, ingested: Vec::new(), next_pos: 0 })
    }

    fn adopt_prefix(&mut self, seq: &mut QuantSeq, prompt: &[i32], allow: bool) -> usize {
        if !allow {
            return 0;
        }
        let Some((matched, snap)) = self.prefix.lookup(prompt) else {
            return 0;
        };
        if snap.apply(&mut seq.kv).is_err() {
            return 0;
        }
        seq.ingested.extend_from_slice(&prompt[..matched]);
        seq.next_pos = matched as u64;
        matched
    }

    fn prefill_chunk(&mut self, seq: &mut QuantSeq, chunk: &[i32]) -> anyhow::Result<()> {
        let n = chunk.len();
        let mut row = vec![0.0f32; self.h * n * self.dh];
        for (i, &tok) in chunk.iter().enumerate() {
            self.fill_row(&mut row, n, i, tok, seq.next_pos + i as u64);
        }
        for layer in 0..self.l {
            seq.kv.append_layer(layer, &row, &row, n, n, seq.next_pos)?;
        }
        seq.next_pos += n as u64;
        self.prefill_tokens += n as u64;
        seq.ingested.extend_from_slice(chunk);
        self.settle(seq)?;
        let w = self.window;
        if !seq.ingested.is_empty() && seq.ingested.len() % w == 0 {
            let kv = &mut seq.kv;
            self.prefix.insert_with(&seq.ingested, w, || PrefixSnapshot::freeze(kv));
        }
        Ok(())
    }

    fn decode(&mut self, seq: &mut QuantSeq, n: usize) -> anyhow::Result<Decoded> {
        let mut row = vec![0.0f32; self.h * self.dh];
        for _ in 0..n {
            let tok = 1000 + seq.next_pos as i32;
            self.fill_row(&mut row, 1, 0, tok, seq.next_pos);
            for layer in 0..self.l {
                seq.kv.append_layer(layer, &row, &row, 1, 1, seq.next_pos)?;
            }
            seq.next_pos += 1;
        }
        self.settle(seq)?;
        Ok(Decoded { tokens: vec![7; n], t_first: None })
    }

    fn can_admit(&self, active: usize) -> bool {
        admission_ok(
            &self.arena.stats(),
            active,
            self.est_seq_bytes,
            self.budget_bytes,
            0,
            self.prefix.resident_bytes(),
        )
    }
}

/// One capacity run of [`quant_capacity_scenario`]'s fixed workload at a
/// fixed byte budget and precision mode.
struct QuantRunOut {
    peak_active: usize,
    finished: usize,
    high_water: usize,
    peak_quant_pages: usize,
    peak_quant_bytes: usize,
    compaction_ratio: f64,
}

fn quant_capacity_run(
    after: Option<usize>,
    est_seq_bytes: usize,
    budget_bytes: usize,
) -> anyhow::Result<QuantRunOut> {
    let (l, h, c, dh) = (2usize, 2usize, 1024usize, 16usize);
    let (window, quantum) = (16usize, 8usize);
    let arena = KvArena::new();
    arena.set_budget(Some(budget_bytes));
    let backend = QuantBenchBackend {
        arena: arena.clone(),
        // capacity 0 disables the tree: concurrency is measured without
        // cross-request sharing (the parity runs cover that axis)
        prefix: PrefixCache::new("bench-quant".into(), 0),
        policy: make_policy("lacache:budget=1008,span=2", l)?,
        l,
        h,
        c,
        dh,
        window,
        after,
        est_seq_bytes,
        budget_bytes,
        prefill_tokens: 0,
    };
    let n_requests = 48usize;
    let prompt: Vec<i32> = (0..992).map(|t| (t % 251) as i32).collect();
    let mut s = Scheduler::new(backend, window, quantum, usize::MAX, n_requests);
    for _ in 0..n_requests {
        s.submit(prompt.clone(), 16, CancelToken::new())?;
    }
    let mut out = QuantRunOut {
        peak_active: 0,
        finished: 0,
        high_water: 0,
        peak_quant_pages: 0,
        peak_quant_bytes: 0,
        compaction_ratio: 0.0,
    };
    let mut rounds = 0usize;
    while s.has_work() && rounds < 200_000 {
        out.finished += s.step().len();
        out.peak_active = out.peak_active.max(s.depth().1);
        let st = s.backend().arena.stats();
        out.peak_quant_pages = out.peak_quant_pages.max(st.quant_pages);
        out.peak_quant_bytes = out.peak_quant_bytes.max(st.quant_bytes);
        out.compaction_ratio = out.compaction_ratio.max(st.quant_compaction_ratio);
        rounds += 1;
    }
    out.high_water = s.backend().arena.stats().high_water;
    Ok(out)
}

/// One prefix-parity run: a cold leader prefills an 8-window shared prompt
/// (publishing a snapshot at every boundary), then 7 followers adopt it at
/// admission. Returns (prefix hits, tokens reused, prefix resident bytes,
/// tokens actually prefilled).
fn quant_prefix_run(after: Option<usize>) -> anyhow::Result<(u64, u64, usize, u64)> {
    let (l, h, c, dh) = (2usize, 2usize, 512usize, 16usize);
    let (window, quantum) = (16usize, 8usize);
    let arena = KvArena::new();
    let backend = QuantBenchBackend {
        arena: arena.clone(),
        prefix: PrefixCache::new("bench-quant".into(), 64 << 20),
        policy: make_policy("lacache:budget=256,span=2", l)?,
        l,
        h,
        c,
        dh,
        window,
        after,
        est_seq_bytes: seq_footprint_bytes(l, h * dh, c),
        budget_bytes: usize::MAX,
        prefill_tokens: 0,
    };
    let prompt: Vec<i32> = (0..128).map(|t| (t % 251) as i32).collect();
    let mut s = Scheduler::new(backend, window, quantum, 8, 16);
    s.submit(prompt.clone(), 8, CancelToken::new())?;
    while s.has_work() {
        let _ = s.step();
    }
    for _ in 0..7 {
        s.submit(prompt.clone(), 8, CancelToken::new())?;
    }
    while s.has_work() {
        let _ = s.step();
    }
    let st = s.backend().prefix.stats();
    let resident = s.backend().prefix.resident_bytes();
    Ok((st.hits, st.tokens_reused, resident, s.backend().prefill_tokens))
}

/// Drive one exact (f32) and one cold-q8 twin through an identical
/// append/compact/demote trace and measure the worst per-element divergence
/// of the gathered dense images — the bench's ppl/logit-delta proxy (same
/// occupancy, bounded value error). Returns (absmax of the exact image, max
/// abs delta, quantized pages in the cold-q8 twin).
fn quant_tolerance_probe() -> anyhow::Result<(f64, f64, usize)> {
    let (l, h, c, dh) = (2usize, 2usize, 128usize, 16usize);
    let w = 16usize;
    let policy = make_policy("lacache:budget=96,span=2", l)?;
    let mut exact = KvCache::with_arena(KvArena::new(), l, h, c, dh);
    let mut quant = KvCache::with_arena(KvArena::new(), l, h, c, dh);
    quant.set_quant(true);
    let mut pos = 0u64;
    for _ in 0..20 {
        let mut row = vec![0.0f32; h * w * dh];
        for i in 0..w {
            let val = ((pos + i as u64) * 7 % 251) as f32 * 1e-3;
            for hh in 0..h {
                for d in 0..dh {
                    row[(hh * w + i) * dh + d] = val;
                }
            }
        }
        for layer in 0..l {
            exact.append_layer(layer, &row, &row, w, w, pos)?;
            quant.append_layer(layer, &row, &row, w, w, pos)?;
        }
        pos += w as u64;
        policy.evict(&mut exact)?;
        policy.evict(&mut quant)?;
        quant.mark_synced();
        quant.demote_cold(pos.saturating_sub(w as u64));
    }
    assert_eq!(exact.lens_i32(), quant.lens_i32(), "demotion must not change occupancy");
    let n_q8: usize = (0..l).map(|layer| quant.n_quant_pages(layer)).sum();
    assert_eq!((0..l).map(|layer| exact.n_quant_pages(layer)).sum::<usize>(), 0);
    let (ek, ev) = exact.gather_dense();
    let (qk, qv) = quant.gather_dense();
    let mut absmax = 0f64;
    let mut delta = 0f64;
    for (e, q) in ek.iter().zip(&qk).chain(ev.iter().zip(&qv)) {
        absmax = absmax.max((*e as f64).abs());
        delta = delta.max((*e as f64 - *q as f64).abs());
    }
    Ok((absmax, delta, n_q8))
}

/// Tiered-compression capacity scenario (`--kv-quant cold-q8` vs `off` at
/// the SAME `kv_pool_bytes`): cold ladder pages demote to per-head
/// symmetric int8, so the same pool admits several times the concurrent
/// sequences. Asserts the subsystem's serving guarantees:
///
/// 1. cold-q8 admits >= 3x the concurrent sequences of the fp32 run under
///    one byte budget (both runs drain fully and stay inside the budget,
///    and `--kv-quant off` never quantizes a page);
/// 2. prefix-hit parity: the shared-prefix workload produces identical
///    hit/reuse counts in both modes, with the Q8 snapshots holding the
///    same prefixes in <= 1/3 the pool bytes;
/// 3. a bounded dequantization delta: an exact/cold-q8 twin pair driven
///    through one append/compact/demote trace keeps identical occupancy
///    and a worst-case per-element error under the symmetric-absmax bound.
///
/// Emits machine-readable `BENCH_quant.json` (path override:
/// `BENCH_QUANT_JSON`) for the CI perf trajectory.
fn quant_capacity_scenario(smoke: bool) -> anyhow::Result<()> {
    let (l, h, c, dh) = (2usize, 2usize, 1024usize, 16usize);
    let (window, after) = (16usize, 1usize);
    let policy = make_policy("lacache:budget=1008,span=2", l)?;
    let slots = policy.budget().saturating_add(window).min(c);
    let est_f32 = seq_footprint_bytes(l, h * dh, slots);
    // the serving projection: sinks + hot tail + demotion lag stay f32
    let fp32_slots = ((after + 2) * window + 2 * PAGE_SLOTS).min(slots);
    let est_q8 = seq_footprint_bytes_mixed(l, h * dh, h, slots, fp32_slots);
    let budget_bytes = 8 * est_f32;

    let off = quant_capacity_run(None, est_f32, budget_bytes)?;
    let q8 = quant_capacity_run(Some(after), est_q8, budget_bytes)?;
    assert_eq!(off.finished, 48, "off run did not drain");
    assert_eq!(q8.finished, 48, "cold-q8 run did not drain");
    assert!(off.high_water <= budget_bytes, "off run exceeded the pool budget");
    assert!(q8.high_water <= budget_bytes, "cold-q8 run exceeded the pool budget");
    assert_eq!(off.peak_quant_pages, 0, "--kv-quant off must never quantize a page");
    assert!(q8.peak_quant_pages > 0, "cold-q8 run never demoted a page");
    let capacity_ratio = q8.peak_active as f64 / off.peak_active.max(1) as f64;
    assert!(
        capacity_ratio >= 3.0,
        "cold-q8 must admit >=3x the concurrent sequences of fp32 at the same budget \
         (got {} vs {} = {capacity_ratio:.2}x)",
        q8.peak_active,
        off.peak_active
    );
    assert!(
        q8.compaction_ratio >= 3.0,
        "Q8 pages must replace >=3x their own bytes of f32 state, got {:.2}x",
        q8.compaction_ratio
    );

    let (hits_off, reused_off, prefix_bytes_off, prefilled_off) = quant_prefix_run(None)?;
    let (hits_q8, reused_q8, prefix_bytes_q8, prefilled_q8) = quant_prefix_run(Some(after))?;
    assert_eq!(hits_off, 7, "every follower must hit the shared prefix");
    assert_eq!(hits_q8, hits_off, "prefix-hit parity with --kv-quant off");
    assert_eq!(reused_q8, reused_off, "prefix tokens-reused parity with --kv-quant off");
    assert_eq!(prefilled_off, 128, "the shared span must prefill exactly once");
    assert_eq!(prefilled_q8, prefilled_off, "prefill-once parity with --kv-quant off");
    assert!(
        3 * prefix_bytes_q8 <= prefix_bytes_off,
        "Q8 snapshots must hold the same prefixes in <=1/3 the pool bytes \
         ({prefix_bytes_q8} B vs f32 {prefix_bytes_off} B)"
    );

    let (absmax, delta, n_q8_pages) = quant_tolerance_probe()?;
    let bound = 0.05 * absmax + 1e-6;
    assert!(n_q8_pages > 0, "tolerance probe must actually quantize");
    assert!(delta <= bound, "dequantization error {delta:.6} exceeds tolerance bound {bound:.6}");

    println!(
        "\nquant-capacity: pool {:.1} MiB | off peak {} concurrent | cold-q8 peak {} \
         ({capacity_ratio:.2}x, floor 3.0x) | peak {} quant pages replacing {:.2}x their bytes | \
         prefix hits {hits_q8} (off run {hits_off}), snapshots {prefix_bytes_q8} B vs f32 \
         {prefix_bytes_off} B | kv delta {delta:.2e} <= bound {bound:.2e}",
        budget_bytes as f64 / (1 << 20) as f64,
        off.peak_active,
        q8.peak_active,
        q8.peak_quant_pages,
        q8.compaction_ratio,
    );

    let out = Json::from_pairs(vec![
        ("bench", "quant_capacity".into()),
        ("smoke", smoke.into()),
        ("shape_lhcd", vec![l, h, c, dh].into()),
        ("window", window.into()),
        ("quantize_after_windows", after.into()),
        ("kv_pool_bytes", budget_bytes.into()),
        ("est_seq_bytes_f32", est_f32.into()),
        ("est_seq_bytes_q8", est_q8.into()),
        ("peak_active_off", off.peak_active.into()),
        ("peak_active_q8", q8.peak_active.into()),
        ("capacity_ratio", capacity_ratio.into()),
        ("high_water_off", off.high_water.into()),
        ("high_water_q8", q8.high_water.into()),
        ("peak_quant_pages", q8.peak_quant_pages.into()),
        ("peak_quant_bytes", q8.peak_quant_bytes.into()),
        ("quant_compaction_ratio", q8.compaction_ratio.into()),
        ("prefix_hits_off", (hits_off as i64).into()),
        ("prefix_hits_q8", (hits_q8 as i64).into()),
        ("prefix_tokens_reused_off", (reused_off as i64).into()),
        ("prefix_tokens_reused_q8", (reused_q8 as i64).into()),
        ("prefix_resident_bytes_off", prefix_bytes_off.into()),
        ("prefix_resident_bytes_q8", prefix_bytes_q8.into()),
        ("kv_absmax", absmax.into()),
        ("kv_delta_max_abs", delta.into()),
        ("kv_delta_bound", bound.into()),
        ("tolerance_ok", true.into()),
    ]);
    let path = std::env::var("BENCH_QUANT_JSON").unwrap_or_else(|_| "BENCH_quant.json".into());
    std::fs::write(&path, out.to_string() + "\n")?;
    println!("wrote {path}");
    Ok(())
}

/// Drive one flight-recorder workload to completion under whatever fault
/// plan is installed: `specs` is one `(prompt_tokens, max_new)` pair per
/// sequence, run through the split-phase [`ChaosBackend`] worker pool (its
/// token streams are a pure function of sequence id, so tracing-on and
/// tracing-off twins are byte-comparable). Returns the finish records, the
/// decoder ITL samples, and the scheduler's fault counters.
fn obs_run(
    specs: &[(usize, usize)],
    workers: usize,
    decode_sleep: Duration,
) -> anyhow::Result<(Vec<Finished>, Samples, FaultStats)> {
    std::thread::scope(|scope| {
        let backend = ChaosBackend {
            ex: CallExecutor::new(scope, workers),
            next_id: 0,
            decode_sleep,
            recoveries: 0,
            doom_leader: false,
        };
        let mut s = Scheduler::new(backend, 64, 4, specs.len(), 2 * specs.len());
        for &(p, m) in specs {
            s.submit(vec![1; p], m, CancelToken::new())?;
        }
        let mut done = Vec::new();
        let mut itl = Samples::new();
        let t0 = std::time::Instant::now();
        while s.has_work() && t0.elapsed() < Duration::from_secs(60) {
            done.extend(s.step());
            for x in s.take_itl() {
                itl.record(x);
            }
        }
        let (got, want) = (done.len(), specs.len());
        anyhow::ensure!(got == want, "obs run finished {got}/{want}");
        anyhow::ensure!(s.inflight() == 0, "obs run left calls in flight");
        let stats = s.fault_stats();
        Ok((done, itl, stats))
    })
}

/// Validate Prometheus text exposition (version 0.0.4): every non-comment
/// line must be `name[{labels}] value` with a legal metric name and a
/// finite value. Returns the number of metric sample lines.
fn prometheus_lines(text: &str) -> anyhow::Result<usize> {
    let mut n = 0usize;
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| anyhow::anyhow!("metric line has no value: {line}"))?;
        let name = series.split('{').next().unwrap_or("");
        anyhow::ensure!(
            !name.is_empty()
                && !name.starts_with(|c: char| c.is_ascii_digit())
                && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "bad metric name: {line}"
        );
        anyhow::ensure!(
            series.contains('{') == series.ends_with('}'),
            "unbalanced label braces: {line}"
        );
        let v: f64 = value.parse().map_err(|_| anyhow::anyhow!("bad value: {line}"))?;
        anyhow::ensure!(v.is_finite(), "non-finite value: {line}");
        n += 1;
    }
    anyhow::ensure!(n > 0, "exposition produced no metric lines");
    Ok(n)
}

/// Flight-recorder observability scenario (device-free, full split-phase
/// scheduler + worker-pool path): always-on tracing must be free enough to
/// leave on in production and complete enough to reconstruct every
/// sequence's life after the fact.
///
/// 1. **Overhead record**: 8 mixed sequences (three prompt lengths, four
///    generation budgets) run twice per rep — tracing on (`sample_every 1`)
///    vs off (`0`) — on identical seeds and workloads. Decoder ITL p95 with
///    tracing on must stay within 5% of the tracing-off twin (min-of-k per
///    mode: recording cost is systematic and survives the min, OS jitter is
///    not), and the token streams must be byte-identical.
/// 2. **Completeness record**: the same fleet re-runs with a seeded
///    transient-fault plan (seed bumped until a retry lands); every
///    admitted sequence's events must reconstruct the complete
///    queued→admitted→placed→first-token→finished chain in `at` order, and
///    the injected fault's `retry` event must land inside its own
///    sequence's admitted span.
/// 3. **Exposition record**: the `op:metrics` payload built from the run
///    (registry + fault counters + native histograms +
///    `lacache_trace_dropped_total`) must parse line-by-line as Prometheus
///    text.
///
/// Emits machine-readable `BENCH_obs.json` (path override:
/// `BENCH_OBS_JSON`) for the CI perf trajectory.
fn obs_scenario(smoke: bool) -> anyhow::Result<()> {
    use lacache::obs::{self, EventKind, TraceFilter};
    use lacache::server::metrics::{export_faults, prometheus_text, Metrics};
    use xla::fault::{self, FaultKind, FaultPlan};

    let quanta = if smoke { 4usize } else { 8 };
    let specs: Vec<(usize, usize)> =
        (0..8).map(|i| (64 + 16 * (i % 3), (quanta + i % 4) * 4)).collect();
    let workers = 4usize;
    let decode_sleep = Duration::from_millis(10);
    let reps = if smoke { 2usize } else { 3 };
    let seed0: u64 = std::env::var("LACACHE_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x0b5e7ace);
    let rate: f64 = std::env::var("LACACHE_FAULT_RATE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.10);

    // --- overhead record: tracing on vs off, interleaved min-of-k --------
    fault::install(None);
    let mut on_p95 = f64::INFINITY;
    let mut off_p95 = f64::INFINITY;
    let mut on_tokens = None;
    let mut off_tokens = None;
    for _ in 0..reps {
        obs::recorder().configure(1, obs::DEFAULT_CAPACITY);
        let (d_on, itl, st) = obs_run(&specs, workers, decode_sleep)?;
        assert_eq!(st.retries, 0, "overhead record must be fault-free");
        on_p95 = on_p95.min(itl.p95());
        let toks = tokens_by_id(&d_on);
        assert_eq!(*on_tokens.get_or_insert_with(|| toks.clone()), toks, "run not deterministic");
        obs::recorder().configure(0, obs::DEFAULT_CAPACITY);
        let (d_off, itl, _) = obs_run(&specs, workers, decode_sleep)?;
        off_p95 = off_p95.min(itl.p95());
        off_tokens.get_or_insert_with(|| tokens_by_id(&d_off));
    }
    assert_eq!(on_tokens, off_tokens, "tracing must be byte-invisible to generation");
    let overhead = on_p95 / off_p95.max(1e-9);
    assert!(
        on_p95 <= 1.05 * off_p95,
        "tracing-on decoder ITL p95 must stay within 5% of tracing-off \
         ({:.3} ms vs {:.3} ms = {overhead:.3}x)",
        on_p95 * 1e3,
        off_p95 * 1e3,
    );

    // --- completeness record: seeded transient faults, tracing on --------
    // a seed whose draws land zero faults would make the retry-chain assert
    // vacuous, so bump until at least one retry happened (each seed is
    // still fully deterministic)
    obs::recorder().configure(1, obs::DEFAULT_CAPACITY);
    let mut seed = seed0;
    let (done, events, fstats) = loop {
        fault::install(Some(
            FaultPlan::new(seed)
                .rule("chaos-prefill", FaultKind::Transient, rate)
                .rule("chaos-decode", FaultKind::Transient, rate),
        ));
        let mark = obs::recorder().watermark();
        let (done, _, st) = obs_run(&specs, workers, decode_sleep)?;
        if st.retries > 0 {
            let events =
                obs::recorder().snapshot(&TraceFilter { since: Some(mark), ..Default::default() });
            break (done, events, st);
        }
        println!("obs: seed {seed} drew no faults at rate {rate}; bumping");
        seed += 1;
    };
    fault::install(None);
    let at_of = |id: u64, kind: EventKind| -> Option<u64> {
        events.iter().find(|e| e.seq == id && e.kind == kind).map(|e| e.at)
    };
    for f in &done {
        assert!(f.error.is_none(), "faulted obs run must fully recover, got: {:?}", f.error);
        let chain = [
            EventKind::Queued,
            EventKind::Admitted,
            EventKind::Placed,
            EventKind::FirstToken,
            EventKind::Finished,
        ];
        let mut prev = 0u64;
        for kind in chain {
            let at = at_of(f.id, kind).unwrap_or_else(|| {
                panic!("sequence {} is missing its {} event", f.id, kind.as_str())
            });
            assert!(at > prev, "sequence {}: {} event out of chain order", f.id, kind.as_str());
            prev = at;
        }
    }
    let retry = events
        .iter()
        .find(|e| e.kind == EventKind::Retry)
        .expect("the injected transient fault must surface as a retry event");
    let r_placed = at_of(retry.seq, EventKind::Placed).expect("retried sequence was placed");
    let r_fin = at_of(retry.seq, EventKind::Finished).expect("retried sequence finished");
    assert!(
        r_placed < retry.at && retry.at < r_fin,
        "the retry event must land inside its own sequence's admitted span"
    );

    // --- exposition record: op:metrics parses as Prometheus text ---------
    let mut m = Metrics::default();
    m.submitted = done.len() as u64;
    for f in &done {
        m.record_finished(f);
    }
    m.itl_s.record(on_p95.max(1e-6));
    m.itl_s.record(off_p95.max(1e-6));
    let mut stats_json = m.to_json();
    export_faults(&mut stats_json, &fstats, false, 0);
    let text = prometheus_text(&stats_json, &m);
    let metric_lines = prometheus_lines(&text)?;
    assert!(text.contains("# TYPE lacache_itl_seconds histogram"));
    assert!(text.contains("lacache_trace_dropped_total"));
    assert!(text.contains("lacache_retries"));
    let dropped = obs::recorder().dropped_total();

    println!(
        "\nobs: {} seqs x mixed prompts | ITL p95 tracing on {:.3} ms vs off {:.3} ms \
         ({overhead:.3}x, budget 1.05x) | {} events, full lifecycle chain per sequence, \
         retry (seq {}) inside its span | {} retries | {metric_lines} Prometheus lines, \
         {dropped} dropped",
        specs.len(),
        on_p95 * 1e3,
        off_p95 * 1e3,
        events.len(),
        retry.seq,
        fstats.retries,
    );

    let out = Json::from_pairs(vec![
        ("bench", "obs_flight_recorder".into()),
        ("smoke", smoke.into()),
        ("sequences", specs.len().into()),
        ("reps", reps.into()),
        ("itl_ms_p95_tracing_on", (on_p95 * 1e3).into()),
        ("itl_ms_p95_tracing_off", (off_p95 * 1e3).into()),
        ("itl_p95_overhead_ratio", overhead.into()),
        ("itl_p95_overhead_budget", 1.05f64.into()),
        ("tokens_identical_tracing_on_off", true.into()),
        ("fault_seed", (seed as i64).into()),
        ("fault_rate", rate.into()),
        ("retries", (fstats.retries as i64).into()),
        ("events_captured", events.len().into()),
        ("lifecycle_chains_complete", true.into()),
        ("retry_inside_chain", true.into()),
        ("trace_dropped_total", (dropped as i64).into()),
        ("prometheus_metric_lines", metric_lines.into()),
    ]);
    let path = std::env::var("BENCH_OBS_JSON").unwrap_or_else(|_| "BENCH_obs.json".into());
    std::fs::write(&path, out.to_string() + "\n")?;
    println!("wrote {path}");
    Ok(())
}
