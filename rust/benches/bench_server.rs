//! Server-substrate benchmarks: scheduler round overhead (with an instant
//! backend, isolating pure L3 cost), wire-protocol encode/decode, and JSON
//! parse throughput for the manifest-sized payloads.

use lacache::server::batcher::{Scheduler, SeqBackend};
use lacache::server::protocol::{ok_generate, parse_request};
use lacache::util::bench::Bench;
use lacache::util::json::Json;

struct InstantBackend;
struct NoSeq {
    emitted: usize,
}

impl SeqBackend for InstantBackend {
    type Seq = NoSeq;
    fn new_seq(&mut self) -> anyhow::Result<NoSeq> {
        Ok(NoSeq { emitted: 0 })
    }
    fn prefill_chunk(&mut self, _s: &mut NoSeq, _c: &[i32]) -> anyhow::Result<()> {
        Ok(())
    }
    fn decode(&mut self, s: &mut NoSeq, n: usize) -> anyhow::Result<Vec<i32>> {
        s.emitted += n;
        Ok(vec![17; n])
    }
}

fn main() -> anyhow::Result<()> {
    let b = Bench::new(5, 20);

    // scheduler: 64 requests through admission->prefill->decode->finish
    b.run_throughput("scheduler/64-requests (instant backend)", 64, "req", || {
        let mut s = Scheduler::new(InstantBackend, 128, 16, 4, 1024);
        for _ in 0..64 {
            s.submit(vec![1; 300], 32).unwrap();
        }
        while s.has_work() {
            std::hint::black_box(s.step());
        }
    });

    // protocol encode/decode
    let line = r#"{"op":"generate","id":42,"prompt":"<bos> w1 w2 w3 w4 w5 w6 w7","max_new_tokens":16}"#;
    b.run_throughput("protocol/parse_request", 1, "req", || {
        std::hint::black_box(parse_request(line).unwrap());
    });
    let toks: Vec<i32> = (16..80).collect();
    b.run_throughput("protocol/ok_generate(64 tokens)", 1, "resp", || {
        std::hint::black_box(ok_generate(1, &toks, 300, 1.0, 2.0));
    });

    // json: manifest-scale parse
    let man_path = lacache::artifacts_dir().join("manifest.json");
    if man_path.exists() {
        let text = std::fs::read_to_string(&man_path)?;
        b.run_throughput("json/parse manifest", text.len() as u64, "byte", || {
            std::hint::black_box(Json::parse(&text).unwrap());
        });
    }
    Ok(())
}
