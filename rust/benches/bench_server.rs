//! Server-substrate benchmarks: scheduler round overhead (with an instant
//! backend, isolating pure L3 cost), wire-protocol encode/decode, JSON parse
//! throughput for the manifest-sized payloads, and the paged-KV arena
//! memory-pressure scenario (concurrency under a fixed byte budget vs. the
//! old dense-allocation baseline).

use lacache::cache::{make_policy, CachePolicy};
use lacache::runtime::{admission_ok, seq_footprint_bytes, KvArena, KvCache};
use lacache::server::batcher::{Scheduler, SeqBackend};
use lacache::server::protocol::{ok_generate, parse_request};
use lacache::util::bench::Bench;
use lacache::util::json::Json;

struct InstantBackend;
struct NoSeq {
    emitted: usize,
}

impl SeqBackend for InstantBackend {
    type Seq = NoSeq;
    fn new_seq(&mut self) -> anyhow::Result<NoSeq> {
        Ok(NoSeq { emitted: 0 })
    }
    fn prefill_chunk(&mut self, _s: &mut NoSeq, _c: &[i32]) -> anyhow::Result<()> {
        Ok(())
    }
    fn decode(&mut self, s: &mut NoSeq, n: usize) -> anyhow::Result<Vec<i32>> {
        s.emitted += n;
        Ok(vec![17; n])
    }
}

fn main() -> anyhow::Result<()> {
    let b = Bench::new(5, 20);

    // scheduler: 64 requests through admission->prefill->decode->finish
    b.run_throughput("scheduler/64-requests (instant backend)", 64, "req", || {
        let mut s = Scheduler::new(InstantBackend, 128, 16, 4, 1024);
        for _ in 0..64 {
            s.submit(vec![1; 300], 32).unwrap();
        }
        while s.has_work() {
            std::hint::black_box(s.step());
        }
    });

    // protocol encode/decode
    let line = r#"{"op":"generate","id":42,"prompt":"<bos> w1 w2 w3 w4 w5 w6 w7","max_new_tokens":16}"#;
    b.run_throughput("protocol/parse_request", 1, "req", || {
        std::hint::black_box(parse_request(line).unwrap());
    });
    let toks: Vec<i32> = (16..80).collect();
    b.run_throughput("protocol/ok_generate(64 tokens)", 1, "resp", || {
        std::hint::black_box(ok_generate(1, &toks, 300, 1.0, 2.0));
    });

    // json: manifest-scale parse
    let man_path = lacache::artifacts_dir().join("manifest.json");
    if man_path.exists() {
        let text = std::fs::read_to_string(&man_path)?;
        b.run_throughput("json/parse manifest", text.len() as u64, "byte", || {
            std::hint::black_box(Json::parse(&text).unwrap());
        });
    }

    memory_pressure_scenario()?;
    Ok(())
}

/// Device-free sequence backend over a real paged-KV arena: prefill appends
/// window rows, decode appends one row per token, and the ladder policy
/// compacts between rounds — the full storage path minus PJRT.
struct ArenaBackend {
    arena: KvArena,
    policy: Box<dyn CachePolicy>,
    l: usize,
    h: usize,
    c: usize,
    dh: usize,
    est_seq_bytes: usize,
    budget_bytes: usize,
}

struct ArenaSeq {
    kv: KvCache,
    next_pos: u64,
}

impl ArenaBackend {
    fn append_all_layers(&self, s: &mut ArenaSeq, n: usize) -> anyhow::Result<()> {
        let row = vec![0.125f32; self.h * n * self.dh];
        for layer in 0..self.l {
            s.kv.append_layer(layer, &row, &row, n, n, s.next_pos)?;
        }
        s.next_pos += n as u64;
        self.policy.evict(&mut s.kv)?;
        Ok(())
    }
}

impl SeqBackend for ArenaBackend {
    type Seq = ArenaSeq;

    fn new_seq(&mut self) -> anyhow::Result<ArenaSeq> {
        let kv = KvCache::with_arena(self.arena.clone(), self.l, self.h, self.c, self.dh);
        Ok(ArenaSeq { kv, next_pos: 0 })
    }

    fn prefill_chunk(&mut self, s: &mut ArenaSeq, chunk: &[i32]) -> anyhow::Result<()> {
        self.append_all_layers(s, chunk.len())
    }

    fn decode(&mut self, s: &mut ArenaSeq, n: usize) -> anyhow::Result<Vec<i32>> {
        for _ in 0..n {
            self.append_all_layers(s, 1)?;
        }
        Ok(vec![7; n])
    }

    fn can_admit(&self, active: usize) -> bool {
        // the same gate the serving path uses
        admission_ok(&self.arena.stats(), active, self.est_seq_bytes, self.budget_bytes)
    }
}

/// Memory-pressure scenario: under one fixed simulated byte budget, how many
/// ladder-policy sequences run concurrently with arena paging vs. the old
/// eagerly-allocated dense `2·L·H·C·Dh` cache per sequence?
fn memory_pressure_scenario() -> anyhow::Result<()> {
    let (l, h, c, dh) = (8usize, 4usize, 2048usize, 24usize);
    let (window, quantum) = (128usize, 16usize);
    let dense_seq_bytes = 2 * l * h * c * dh * 4;
    let budget_bytes = 4 * dense_seq_bytes; // dense fits exactly 4 sequences
    let dense_concurrent = budget_bytes / dense_seq_bytes;

    let arena = KvArena::new();
    arena.set_budget(Some(budget_bytes));
    let policy = make_policy("lacache:budget=128,span=2", l)?;
    let slots = policy.budget().saturating_add(window).min(c);
    let est_seq_bytes = seq_footprint_bytes(l, h * dh, slots);
    let backend =
        ArenaBackend { arena: arena.clone(), policy, l, h, c, dh, est_seq_bytes, budget_bytes };

    let n_requests = 64;
    let mut s = Scheduler::new(backend, window, quantum, usize::MAX, n_requests);
    for _ in 0..n_requests {
        s.submit(vec![1; 384], 32).unwrap();
    }
    let mut peak_active = 0usize;
    let mut finished = 0usize;
    let mut rounds = 0usize;
    while s.has_work() && rounds < 100_000 {
        finished += s.step().len();
        peak_active = peak_active.max(s.depth().1);
        rounds += 1;
    }
    let st = arena.stats();
    println!(
        "\nmemory-pressure: byte budget {:.1} MiB | dense baseline {} concurrent seqs \
         | paged arena peak {} concurrent ({}x) | arena high-water {:.1} MiB | {} finished",
        budget_bytes as f64 / (1 << 20) as f64,
        dense_concurrent,
        peak_active,
        peak_active / dense_concurrent.max(1),
        st.high_water as f64 / (1 << 20) as f64,
        finished,
    );
    assert_eq!(finished, n_requests, "scenario did not drain");
    assert!(st.high_water <= budget_bytes, "arena exceeded its budget");
    assert!(
        peak_active >= 4 * dense_concurrent,
        "paged arena should fit >=4x the dense baseline's concurrency \
         (got {peak_active} vs dense {dense_concurrent})"
    );
    Ok(())
}
