//! Pure-CPU policy micro-benchmarks: keep-set computation + gather cost per
//! compaction for every policy (the L3 contribution must never bottleneck
//! the device hot path; PERF.md §Bench methodology tracks these).

use lacache::cache::make_policy;
use lacache::runtime::KvCache;
use lacache::util::bench::Bench;

fn filled_cache(l: usize, h: usize, c: usize, dh: usize, n: usize) -> KvCache {
    let mut kv = KvCache::new(l, h, c, dh);
    for layer in 0..l {
        let wk = vec![0.1f32; h * n * dh];
        kv.append_layer(layer, &wk, &wk, n, n, 0).unwrap();
        let mass: Vec<f32> = (0..n).map(|i| ((i * 37) % 101) as f32).collect();
        kv.add_mass(layer, &mass);
    }
    kv
}

fn main() -> anyhow::Result<()> {
    let b = Bench::new(10, 50);
    // realistic serving shape: L=8, H=4, C=256, Dh=24, occupancy 250
    for spec in [
        "lacache:budget=128,span=2",
        "streaming:budget=128",
        "h2o:budget=128",
        "tova:budget=128",
        "snapkv:budget=128",
        "pyramid:budget=128",
        "random:budget=128,frac=0.3",
    ] {
        let policy = make_policy(spec, 8)?;
        let proto = filled_cache(8, 4, 256, 24, 250);
        b.run(&format!("evict/{spec}"), || {
            let mut kv = proto.clone();
            policy.evict(&mut kv).unwrap();
            std::hint::black_box(kv.max_len());
        });
    }

    // keep-set computation only (no gather)
    let policy = make_policy("lacache:budget=128,span=2", 8)?;
    let kv = filled_cache(8, 4, 256, 24, 250);
    b.run("keep_slots/lacache (8 layers)", || {
        for l in 0..8 {
            std::hint::black_box(policy.keep_slots(l, &kv));
        }
    });

    // gather (retain) cost at full occupancy
    let keep: Vec<usize> = (0..250).step_by(2).collect();
    b.run("retain_slots/gather 125-of-250", || {
        let mut kv2 = kv.clone();
        for l in 0..8 {
            kv2.retain_slots(l, &keep).unwrap();
        }
        std::hint::black_box(kv2.lens[0]);
    });
    Ok(())
}
