//! Integration tests over the real artifacts (AOT HLO + trained weights).
//! All tests no-op with a notice if `make artifacts` has not produced the
//! artifacts yet (CI ordering), but they are the real cross-layer signal:
//! python-lowered programs executed through the rust PJRT runtime.

use lacache::cache::make_policy;
use lacache::data::corpus::Stream;
use lacache::engine::{is_oom, Engine, EngineOpts};
use lacache::runtime::{KvCache, Runtime};

fn artifacts_ready() -> bool {
    let d = lacache::artifacts_dir();
    d.join("manifest.json").exists() && d.join("mini/weights.bin").exists()
}

macro_rules! need_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

fn mini_engine<'rt>(rt: &'rt Runtime, policy_spec: &str, w: usize, c: usize) -> Engine<'rt> {
    let cfg = rt.model("mini").unwrap().cfg.clone();
    let policy = make_policy(policy_spec, cfg.n_layers).unwrap();
    let opts = EngineOpts {
        model: "mini".into(),
        w,
        c,
        memory_budget_bytes: None,
        quantize_after_windows: None,
    };
    Engine::new(rt, opts, policy).unwrap()
}

#[test]
fn score_is_deterministic_and_finite() {
    need_artifacts!();
    let rt = Runtime::load(&lacache::artifacts_dir(), &["mini"]).unwrap();
    let toks = Stream::default_eval(2).take_n(65);
    let mut a = mini_engine(&rt, "lacache:budget=64,span=1", 32, 256);
    let lp1 = a.feed_score(&toks[..64], &toks[1..65]).unwrap();
    let mut b = mini_engine(&rt, "lacache:budget=64,span=1", 32, 256);
    let lp2 = b.feed_score(&toks[..64], &toks[1..65]).unwrap();
    assert_eq!(lp1, lp2);
    assert!(lp1.iter().all(|x| x.is_finite() && *x <= 0.0));
    assert_eq!(lp1.len(), 64);
}

#[test]
fn budgets_are_enforced_during_streaming() {
    need_artifacts!();
    let rt = Runtime::load(&lacache::artifacts_dir(), &["mini"]).unwrap();
    for spec in ["lacache:budget=48,span=1,recent=8", "streaming:budget=48"] {
        let mut eng = mini_engine(&rt, spec, 32, 256);
        let toks = Stream::default_eval(3).take_n(400);
        let mut tgts = toks[1..].to_vec();
        tgts.push(0);
        eng.feed_score(&toks, &tgts).unwrap();
        assert!(eng.cache.max_len() <= 48, "{spec}: {:?}", eng.cache.lens);
        eng.cache.check_invariants().unwrap();
        assert!(eng.n_compactions > 0);
    }
}

#[test]
fn generate_appends_and_respects_capacity() {
    need_artifacts!();
    let rt = Runtime::load(&lacache::artifacts_dir(), &["mini"]).unwrap();
    let mut eng = mini_engine(&rt, "lacache:budget=64,span=1", 32, 256);
    let prompt = Stream::default_eval(4).take_n(100);
    eng.prefill(&prompt).unwrap();
    let toks = eng.generate(33).unwrap(); // 2x k16 + 1x k1
    assert_eq!(toks.len(), 33);
    assert!(toks.iter().all(|&t| (0..256).contains(&t)));
    assert!(eng.cache.max_len() <= 64 + 16);
}

#[test]
fn decode_path_gathers_incrementally_after_warmup() {
    need_artifacts!();
    // once a sequence's dense image exists in the transfer scratch, further
    // calls must never re-gather the full image: decode steps absorb the
    // downloaded device state or re-copy only dirty ranges
    let rt = Runtime::load(&lacache::artifacts_dir(), &["mini"]).unwrap();
    let mut eng = mini_engine(&rt, "streaming:budget=64", 32, 256);
    let prompt = Stream::default_eval(10).take_n(64);
    eng.prefill(&prompt).unwrap();
    let warm = rt.stats();
    assert!(warm.gathers_full >= 1, "first call pays one full gather");
    eng.generate(16).unwrap();
    eng.generate(16).unwrap();
    let st = rt.stats();
    assert_eq!(st.calls, warm.calls + 2);
    assert_eq!(
        st.gathers_full, warm.gathers_full,
        "decode must not re-gather the full image"
    );
    assert!(st.bytes_h2d > 0 && st.bytes_d2h > 0, "transfer byte counters must move");
}

#[test]
fn device_resident_decode_uploads_tokens_not_kv() {
    need_artifacts!();
    // with the residency tier on (the default), steady-state decode keeps
    // the KV state on the device: calls donate the resident buffers, upload
    // only call inputs (+ dirty-range reconciles after evictions), and
    // never re-gather or re-upload the dense image
    let rt = Runtime::load(&lacache::artifacts_dir(), &["mini"]).unwrap();
    let mut eng = mini_engine(&rt, "streaming:budget=64", 32, 256);
    eng.prefill(&Stream::default_eval(13).take_n(64)).unwrap();
    eng.generate(16).unwrap(); // warm: state resident after this call
    let warm = rt.stats();
    assert!(warm.device_resident_bytes > 0, "decoding sequence must be device-resident");
    eng.generate(16).unwrap();
    let st = rt.stats();
    assert_eq!(st.calls, warm.calls + 1);
    assert!(st.donations > warm.donations, "device-hit decode must donate, not re-upload");
    assert_eq!(st.gathers_full, warm.gathers_full, "no full host gather on the hot path");
    assert_eq!(
        st.residency_misses, warm.residency_misses,
        "device-hit decode must not pay a full image upload"
    );
    // upload = tokens + lens + the eviction's dirty-range reconcile, which
    // is strictly less than re-uploading the dense image
    let image_bytes = (2 * 4 * eng.cache.dense_elems()) as u64;
    let h2d_delta = st.bytes_h2d - warm.bytes_h2d;
    assert!(
        h2d_delta < image_bytes / 2,
        "device-hit decode must reconcile, not re-upload ({h2d_delta} B h2d)"
    );
    // reset must release the sequence's device-tier buffers immediately
    eng.reset();
    let st = rt.stats();
    assert_eq!(st.device_resident_bytes, 0, "reset must free device-resident bytes");
}

#[test]
fn scored_path_accumulates_mass() {
    need_artifacts!();
    let rt = Runtime::load(&lacache::artifacts_dir(), &["mini"]).unwrap();
    let mut eng = mini_engine(&rt, "h2o:budget=64", 32, 256);
    let toks = Stream::default_eval(5).take_n(65);
    eng.feed_score(&toks[..64], &toks[1..]).unwrap();
    let total_mass: f64 = eng.cache.mass.iter().flatten().sum();
    assert!(total_mass > 0.0, "scored program returned no attention mass");
}

#[test]
fn full_cache_hits_simulated_oom() {
    need_artifacts!();
    let rt = Runtime::load(&lacache::artifacts_dir(), &["mini"]).unwrap();
    let cfg = rt.model("mini").unwrap().cfg.clone();
    let policy = make_policy("full", cfg.n_layers).unwrap();
    let mut eng = Engine::new(
        &rt,
        EngineOpts {
            model: "mini".into(),
            w: 128,
            c: 256,
            memory_budget_bytes: None,
            quantize_after_windows: None,
        },
        policy,
    )
    .unwrap();
    let toks = Stream::default_eval(6).take_n(1000);
    let mut tgts = toks[1..].to_vec();
    tgts.push(0);
    let err = eng.feed_score(&toks, &tgts).unwrap_err();
    assert!(is_oom(&err), "expected OOM, got: {err}");
}

#[test]
fn scored_generate_rolls_back_overgeneration() {
    need_artifacts!();
    // regression: the scored path over-generates K=16 and truncates the
    // returned tokens; engine state (cache slots, last_token, n_tokens) must
    // roll back to the truncated length so the next quantum continues from
    // the last token the caller actually received
    let rt = Runtime::load(&lacache::artifacts_dir(), &["mini"]).unwrap();
    let mut eng = mini_engine(&rt, "h2o:budget=64", 32, 256);
    let prompt = Stream::default_eval(11).take_n(40);
    eng.prefill(&prompt).unwrap();
    let n0 = eng.n_tokens;
    let toks = eng.generate(5).unwrap();
    assert_eq!(toks.len(), 5);
    assert_eq!(eng.n_tokens, n0 + 5, "stream counter advanced past the truncation");
    assert_eq!(eng.last_token, toks[4], "last_token is not the last returned token");
    eng.cache.check_invariants().unwrap();
    for l in 0..eng.cache.l {
        assert!(
            eng.cache.positions[l].iter().all(|&p| p < n0 + 5),
            "cache holds positions the caller never received: {:?}",
            eng.cache.positions[l]
        );
    }
    // decoding more must keep the invariants from the rolled-back state
    let more = eng.generate(3).unwrap();
    assert_eq!(more.len(), 3);
    assert_eq!(eng.n_tokens, n0 + 8);
    eng.cache.check_invariants().unwrap();
}

#[test]
fn reset_clears_counters_and_releases_pages() {
    need_artifacts!();
    let rt = Runtime::load(&lacache::artifacts_dir(), &["mini"]).unwrap();
    let mut eng = mini_engine(&rt, "lacache:budget=48,span=1,recent=8", 32, 256);
    let toks = Stream::default_eval(12).take_n(300);
    let mut tgts = toks[1..].to_vec();
    tgts.push(0);
    eng.feed_score(&toks, &tgts).unwrap();
    assert!(eng.n_compactions > 0);
    eng.reset();
    assert_eq!(eng.n_tokens, 0);
    assert_eq!(eng.n_evicted, 0, "reset must clear eviction diagnostics");
    assert_eq!(eng.n_compactions, 0, "reset must clear compaction diagnostics");
    assert_eq!(eng.cache.max_len(), 0);
    assert_eq!(eng.cache.resident_bytes(), 0, "reset must release arena pages");
}

#[test]
fn lacache_not_worse_than_streaming_on_long_stream() {
    need_artifacts!();
    let rt = Runtime::load(&lacache::artifacts_dir(), &["mini"]).unwrap();
    let toks = Stream::default_eval(7).take_n(1537);
    let mut ppls = Vec::new();
    for spec in ["streaming:budget=64", "lacache:budget=64,span=1"] {
        let mut eng = mini_engine(&rt, spec, 32, 256);
        let lps = eng.feed_score(&toks[..1536], &toks[1..1537]).unwrap();
        let ppl = (-lps.iter().map(|&x| x as f64).sum::<f64>() / lps.len() as f64).exp();
        ppls.push(ppl);
    }
    // shape check with slack: the ladder should not be meaningfully worse
    assert!(
        ppls[1] <= ppls[0] * 1.05,
        "lacache ppl {} vs streaming {}",
        ppls[1],
        ppls[0]
    );
}

#[test]
fn pallas_program_matches_fast_path_through_pjrt() {
    need_artifacts!();
    // The L1 kernel inside the full AOT program, executed via PJRT, must
    // produce the SAME greedy tokens as the fused-jnp fast path.
    let rt = Runtime::load(&lacache::artifacts_dir(), &["mini"]).unwrap();
    let cfg = rt.model("mini").unwrap().cfg.clone();
    let mut cache = KvCache::new(cfg.n_layers, cfg.n_heads, 256, cfg.head_dim);
    // seed the cache with some context via the score program
    let toks = Stream::default_eval(9).take_n(33);
    let so = rt.score("mini", 32, 256, false, &toks[..32], &toks[1..33], &mut cache).unwrap();
    for l in 0..cfg.n_layers {
        let base = l * cfg.n_heads * 32 * cfg.head_dim;
        let n = cfg.n_heads * 32 * cfg.head_dim;
        cache
            .append_layer(l, &so.win_k[base..base + n], &so.win_v[base..base + n], 32, 32, 0)
            .unwrap();
    }
    let fast = rt.generate_variant("mini", 16, false, false, &mut cache, 7).unwrap();
    let pallas = rt.generate_variant("mini", 16, false, true, &mut cache, 7).unwrap();
    assert_eq!(fast.tokens, pallas.tokens, "pallas kernel diverges from fast path");
    for (a, b) in fast.last_logits.iter().zip(&pallas.last_logits) {
        assert!((a - b).abs() < 3e-3, "logits diverge: {a} vs {b}");
    }
}

#[test]
fn kv_cache_padding_budget_equivalence_through_device() {
    need_artifacts!();
    // the same valid prefix in a larger-capacity cache must score identically
    let rt = Runtime::load(&lacache::artifacts_dir(), &["mini"]).unwrap();
    let cfg = rt.model("mini").unwrap().cfg.clone();
    let toks = Stream::default_eval(8).take_n(33);
    let mut empty = KvCache::new(cfg.n_layers, cfg.n_heads, 256, cfg.head_dim);
    let out1 = rt.score("mini", 32, 256, false, &toks[..32], &toks[1..33], &mut empty).unwrap();
    let out2 = rt.score("mini", 32, 256, false, &toks[..32], &toks[1..33], &mut empty).unwrap();
    assert_eq!(out1.logprobs, out2.logprobs);
    assert_eq!(out1.win_k.len(), cfg.n_layers * cfg.n_heads * 32 * cfg.head_dim);
}

#[test]
fn server_cancels_disconnected_client() {
    need_artifacts!();
    // a client that submits a long generation and drops the connection must
    // have its sequence cancelled (not decoded to completion), observable in
    // `op:stats` from another connection
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    let listen = "127.0.0.1:7912".to_string();
    let cfg = lacache::config::ServeConfig {
        listen: listen.clone(),
        model: "mini".into(),
        policy: "lacache:budget=64,span=1".into(),
        window: 32,
        capacity: 256,
        max_new_tokens: 512,
        ..Default::default()
    };
    let server = std::thread::spawn(move || lacache::server::run_server(cfg));
    let mut victim = None;
    for _ in 0..200 {
        if let Ok(s) = TcpStream::connect(&listen) {
            victim = Some(s);
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let mut victim = victim.expect("server did not start");
    victim
        .write_all(
            b"{\"op\":\"generate\",\"id\":1,\"prompt\":\"<bos> w1 w2 w3 w4 w5 w6 w7 w8\",\
              \"max_new_tokens\":512}\n",
        )
        .unwrap();
    victim.flush().unwrap();
    drop(victim); // disconnect with the request in flight

    let obs = TcpStream::connect(&listen).unwrap();
    let mut reader = BufReader::new(obs.try_clone().unwrap());
    let mut writer = obs;
    let mut cancelled = 0usize;
    for attempt in 0..200 {
        let req = format!("{{\"op\":\"stats\",\"id\":{}}}\n", 100 + attempt);
        writer.write_all(req.as_bytes()).unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = lacache::util::json::Json::parse(&line).unwrap();
        cancelled = j.req("stats").usize_of("cancelled").unwrap();
        if cancelled >= 1 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    assert_eq!(cancelled, 1, "disconnected client's sequence was not cancelled");
    writer.write_all(b"{\"op\":\"shutdown\",\"id\":999}\n").unwrap();
    writer.flush().unwrap();
    let fin = server.join().unwrap().unwrap();
    assert_eq!(fin.usize_of("cancelled"), Some(1));
    assert_eq!(fin.usize_of("completed"), Some(0));
}

#[test]
fn server_end_to_end_over_tcp() {
    need_artifacts!();
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    let listen = "127.0.0.1:7911".to_string();
    let cfg = lacache::config::ServeConfig {
        listen: listen.clone(),
        model: "mini".into(),
        policy: "lacache:budget=64,span=1".into(),
        window: 32,
        capacity: 256,
        ..Default::default()
    };
    let server = std::thread::spawn(move || lacache::server::run_server(cfg));
    let mut conn = None;
    for _ in 0..200 {
        if let Ok(s) = TcpStream::connect(&listen) {
            conn = Some(s);
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let conn = conn.expect("server did not start");
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut writer = conn;
    writer
        .write_all(b"{\"op\":\"generate\",\"id\":1,\"prompt\":\"<bos> w1 w2 w3 w4\",\"max_new_tokens\":3}\n")
        .unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = lacache::util::json::Json::parse(&line).unwrap();
    assert_eq!(j.bool_of("ok"), Some(true), "{line}");
    assert_eq!(j.usize_of("gen_tokens"), Some(3));
    assert!(j.f64_of("ttft_ms").unwrap() > 0.0);
    // stats then shutdown
    writer.write_all(b"{\"op\":\"stats\",\"id\":2}\n").unwrap();
    writer.flush().unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = lacache::util::json::Json::parse(&line).unwrap();
    assert_eq!(j.req("stats").usize_of("completed"), Some(1));
    writer.write_all(b"{\"op\":\"shutdown\",\"id\":3}\n").unwrap();
    writer.flush().unwrap();
    let _ = server.join().unwrap();
}
