//! The vendored stub backend (see the crate docs in `lib.rs`).
//!
//! Buffers RETAIN their host-sourced bytes so the runtime's device-residency
//! tier is fully exercisable without the native backend: `PjRtBuffer`s
//! survive across calls, support partial host↔device copies
//! ([`PjRtBuffer::overwrite_from_host_partial`] /
//! [`PjRtBuffer::copy_to_host_partial`]) and full readback
//! ([`PjRtBuffer::to_literal_sync`]). Only program parsing, compilation, and
//! execution report "backend unavailable" — integration tests gate on
//! artifacts and skip cleanly in stub builds.

use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str =
    "xla backend unavailable (stub build: native PJRT bindings are not linked)";

#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable() -> Self {
        Error { msg: UNAVAILABLE.to_string() }
    }

    fn msg(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

/// Injection hook shared by every stub call site: draw from the installed
/// [`crate::fault`] plan (free when no plan is active) and fail BEFORE the
/// operation touches anything, so a faulted call never half-applies.
fn faultpoint(site: &str) -> Result<()> {
    if let Some(kind) = crate::fault::check(site) {
        if let Some(msg) = crate::fault::apply(site, kind) {
            return Err(Error::msg(msg));
        }
    }
    Ok(())
}

/// Element types a buffer or [`Literal`] can be read back as. The stub stores
/// raw little-endian bytes, so each type carries its own (de)serialization.
pub trait NativeType: Copy {
    const SIZE: usize;
    fn from_le(b: &[u8]) -> Self;
    fn write_le(&self, out: &mut [u8]);
}

macro_rules! native_type {
    ($t:ty, $n:expr) => {
        impl NativeType for $t {
            const SIZE: usize = $n;
            fn from_le(b: &[u8]) -> Self {
                let mut a = [0u8; $n];
                a.copy_from_slice(b);
                <$t>::from_le_bytes(a)
            }
            fn write_le(&self, out: &mut [u8]) {
                out.copy_from_slice(&self.to_le_bytes());
            }
        }
    };
}

native_type!(f32, 4);
native_type!(f64, 8);
native_type!(i32, 4);
native_type!(i64, 8);
native_type!(u8, 1);

/// Stub client over N addressable "devices". The real bindings enumerate
/// PJRT devices from the platform; the stub fabricates `n` independent
/// device slots so the multi-shard runtime topology is exercisable offline.
/// [`PjRtClient::kill_device`] marks one slot lost: every subsequent
/// operation that targets it (uploads routed there, reads/writes of buffers
/// that live there) fails with a `DEVICE_LOST` error, which the runtime's
/// fault taxonomy classifies as retryable and — after the sticky threshold —
/// degrades only that device's shard.
pub struct PjRtClient {
    alive: Arc<Vec<AtomicBool>>,
}

/// A "device" buffer: host-sourced bytes retained for the buffer's lifetime,
/// so the residency tier can keep K/V state alive across program calls. The
/// partial-update surface models the real bindings' aliased update path.
/// Each buffer remembers the device it was placed on; once that device is
/// killed every access reports `DEVICE_LOST`.
pub struct PjRtBuffer {
    data: Mutex<Vec<u8>>,
    dims: Vec<usize>,
    elem_size: usize,
    device: usize,
    alive: Arc<Vec<AtomicBool>>,
}

pub struct PjRtLoadedExecutable;

pub struct HloModuleProto;

pub struct XlaComputation;

/// Host-side copy of a buffer's bytes (produced by
/// [`PjRtBuffer::to_literal_sync`]).
pub struct Literal {
    data: Vec<u8>,
    elem_size: usize,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Self::cpu_with_devices(1)
    }

    /// Stub multi-device enumeration: a client with `n` (≥ 1) addressable
    /// device slots. Real bindings enumerate platform devices instead and
    /// expose the same `device_count` / per-upload device routing surface.
    pub fn cpu_with_devices(n: usize) -> Result<PjRtClient> {
        let n = n.max(1);
        Ok(PjRtClient { alive: Arc::new((0..n).map(|_| AtomicBool::new(true)).collect()) })
    }

    /// Number of addressable devices on this client.
    pub fn device_count(&self) -> usize {
        self.alive.len()
    }

    /// Whether `device` is still serviceable (in range and not killed).
    pub fn device_alive(&self, device: usize) -> bool {
        self.alive.get(device).map(|a| a.load(Ordering::SeqCst)).unwrap_or(false)
    }

    /// Mark one device lost. Chaos/bench hook: every later operation that
    /// touches this device reports `DEVICE_LOST`, modeling a mid-run device
    /// failure without tearing down the whole client.
    pub fn kill_device(&self, device: usize) {
        if let Some(a) = self.alive.get(device) {
            a.store(false, Ordering::SeqCst);
        }
    }

    fn check_device(&self, device: usize) -> Result<()> {
        if device >= self.alive.len() {
            return Err(Error::msg(format!(
                "device {device} out of range ({} device(s))",
                self.alive.len()
            )));
        }
        if !self.alive[device].load(Ordering::SeqCst) {
            return Err(Error::msg(format!("DEVICE_LOST: stub device {device} was killed")));
        }
        Ok(())
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        faultpoint("upload")?;
        let device = device.unwrap_or(0);
        self.check_device(device)?;
        let mut bytes = vec![0u8; data.len() * T::SIZE];
        for (x, chunk) in data.iter().zip(bytes.chunks_exact_mut(T::SIZE)) {
            x.write_le(chunk);
        }
        Ok(PjRtBuffer {
            data: Mutex::new(bytes),
            dims: dims.to_vec(),
            elem_size: T::SIZE,
            device,
            alive: Arc::clone(&self.alive),
        })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        faultpoint("execute")?;
        Err(Error::unavailable())
    }

    /// Execute with the input buffers at `donated` indices aliased to the
    /// program's outputs: donated inputs are CONSUMED (invalid after the
    /// call) and the matching output leaves reuse their device memory, so a
    /// decode step updates the resident KV state in place instead of
    /// round-tripping it. Outputs are returned untupled, one buffer per
    /// leaf. The stub cannot execute programs, so this always reports
    /// unavailable — callers must treat donated buffers as lost either way.
    pub fn execute_with_donation(
        &self,
        _args: &[&PjRtBuffer],
        _donated: &[usize],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        faultpoint("execute")?;
        Err(Error::unavailable())
    }
}

impl PjRtBuffer {
    /// Poison-safe access to the retained bytes: an injected panic that
    /// unwound while a guard was held must not brick the buffer (the bytes
    /// themselves are always whole — writers copy element-wise into
    /// pre-validated ranges).
    fn bytes(&self) -> std::sync::MutexGuard<'_, Vec<u8>> {
        self.data.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// The device slot this buffer lives on.
    pub fn device(&self) -> usize {
        self.device
    }

    fn check_alive(&self) -> Result<()> {
        if !self.alive.get(self.device).map(|a| a.load(Ordering::SeqCst)).unwrap_or(false) {
            return Err(Error::msg(format!(
                "DEVICE_LOST: stub device {} was killed",
                self.device
            )));
        }
        Ok(())
    }

    /// Bytes this buffer occupies on the (stub) device.
    pub fn on_device_size_bytes(&self) -> usize {
        self.bytes().len()
    }

    /// Element count (device size / element size).
    pub fn element_count(&self) -> usize {
        self.bytes().len() / self.elem_size.max(1)
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Partial device→host read: fill `out` from `out.len()` elements
    /// starting at `elem_offset`. The residency tier uses this to download
    /// only appended decode rows and to spill a resident image back to host
    /// staging without a full-tuple literal transfer.
    pub fn copy_to_host_partial<T: NativeType>(
        &self,
        out: &mut [T],
        elem_offset: usize,
    ) -> Result<()> {
        faultpoint("download")?;
        self.check_alive()?;
        if T::SIZE != self.elem_size {
            return Err(Error::msg(format!(
                "copy_to_host_partial: element size {} != buffer element size {}",
                T::SIZE,
                self.elem_size
            )));
        }
        let data = self.bytes();
        let lo = elem_offset * T::SIZE;
        let hi = lo + out.len() * T::SIZE;
        if hi > data.len() {
            return Err(Error::msg(format!(
                "copy_to_host_partial: range [{lo}, {hi}) exceeds buffer ({} B)",
                data.len()
            )));
        }
        for (x, chunk) in out.iter_mut().zip(data[lo..hi].chunks_exact(T::SIZE)) {
            *x = T::from_le(chunk);
        }
        Ok(())
    }

    /// Partial host→device update: overwrite `src.len()` elements starting
    /// at `elem_offset`, leaving the rest of the buffer untouched. This is
    /// the dirty-range reconciliation primitive of the residency tier; real
    /// bindings lower it to a small input-aliased update program.
    pub fn overwrite_from_host_partial<T: NativeType>(
        &self,
        src: &[T],
        elem_offset: usize,
    ) -> Result<()> {
        faultpoint("overwrite")?;
        self.check_alive()?;
        if T::SIZE != self.elem_size {
            return Err(Error::msg(format!(
                "overwrite_from_host_partial: element size {} != buffer element size {}",
                T::SIZE,
                self.elem_size
            )));
        }
        let mut data = self.bytes();
        let lo = elem_offset * T::SIZE;
        let hi = lo + src.len() * T::SIZE;
        if hi > data.len() {
            return Err(Error::msg(format!(
                "overwrite_from_host_partial: range [{lo}, {hi}) exceeds buffer ({} B)",
                data.len()
            )));
        }
        for (x, chunk) in src.iter().zip(data[lo..hi].chunks_exact_mut(T::SIZE)) {
            x.write_le(chunk);
        }
        Ok(())
    }

    /// Full device→host readback of a retained buffer. (Program execution is
    /// unavailable in the stub, so execution *outputs* never exist here;
    /// host-sourced buffers read back fine.)
    pub fn to_literal_sync(&self) -> Result<Literal> {
        faultpoint("download")?;
        self.check_alive()?;
        Ok(Literal { data: self.bytes().clone(), elem_size: self.elem_size })
    }
}

impl Literal {
    /// Tuple decomposition needs the native runtime's shape metadata.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::SIZE != self.elem_size {
            return Err(Error::msg(format!(
                "to_vec: element size {} != literal element size {}",
                T::SIZE,
                self.elem_size
            )));
        }
        Ok(self.data.chunks_exact(T::SIZE).map(T::from_le).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_succeeds_execution_reports_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        let buf = client.buffer_from_host_buffer(&[1.0f32], &[1], None).unwrap();
        assert_eq!(buf.on_device_size_bytes(), 4);
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
        let err = PjRtLoadedExecutable.execute_b(&[]).unwrap_err();
        assert!(format!("{err}").contains("unavailable"));
        let err = PjRtLoadedExecutable.execute_with_donation(&[&buf], &[0]).unwrap_err();
        assert!(format!("{err}").contains("unavailable"));
    }

    #[test]
    fn buffers_retain_data_and_read_back() {
        let client = PjRtClient::cpu().unwrap();
        let data = vec![1.5f32, -2.0, 3.25, 0.0];
        let buf = client.buffer_from_host_buffer(&data, &[2, 2], None).unwrap();
        assert_eq!(buf.dims(), &[2, 2]);
        assert_eq!(buf.element_count(), 4);
        let lit = buf.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_tuple().is_err(), "tuple decomposition needs the native runtime");
    }

    #[test]
    fn partial_read_and_overwrite_round_trip() {
        let client = PjRtClient::cpu().unwrap();
        let buf = client.buffer_from_host_buffer(&[0.0f32; 8], &[8], None).unwrap();
        buf.overwrite_from_host_partial(&[7.0f32, 8.0], 3).unwrap();
        let mut out = [0.0f32; 4];
        buf.copy_to_host_partial(&mut out, 2).unwrap();
        assert_eq!(out, [0.0, 7.0, 8.0, 0.0]);
        // whole-buffer view agrees
        let all = buf.to_literal_sync().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(all, vec![0.0, 0.0, 0.0, 7.0, 8.0, 0.0, 0.0, 0.0]);
        // out-of-bounds and type mismatches are rejected
        assert!(buf.overwrite_from_host_partial(&[1.0f32; 4], 6).is_err());
        assert!(buf.copy_to_host_partial(&mut [0u8; 2], 0).is_err());
    }

    #[test]
    fn multi_device_enumeration_and_kill() {
        let client = PjRtClient::cpu_with_devices(3).unwrap();
        assert_eq!(client.device_count(), 3);
        let b0 = client.buffer_from_host_buffer(&[1.0f32], &[1], Some(0)).unwrap();
        let b2 = client.buffer_from_host_buffer(&[2.0f32], &[1], Some(2)).unwrap();
        assert_eq!(b0.device(), 0);
        assert_eq!(b2.device(), 2);
        assert!(client.buffer_from_host_buffer(&[0.0f32], &[1], Some(3)).is_err());

        client.kill_device(2);
        assert!(!client.device_alive(2));
        assert!(client.device_alive(0));
        let err = b2.to_literal_sync().unwrap_err();
        assert!(format!("{err}").contains("DEVICE_LOST"));
        let err = b2.overwrite_from_host_partial(&[9.0f32], 0).unwrap_err();
        assert!(format!("{err}").contains("DEVICE_LOST"));
        let err = client.buffer_from_host_buffer(&[0.0f32], &[1], Some(2)).unwrap_err();
        assert!(format!("{err}").contains("DEVICE_LOST"));
        // the surviving device is unaffected
        assert_eq!(b0.to_literal_sync().unwrap().to_vec::<f32>().unwrap(), vec![1.0]);
    }

    #[test]
    fn single_device_client_defaults_to_device_zero() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 1);
        let b = client.buffer_from_host_buffer(&[1i32], &[1], None).unwrap();
        assert_eq!(b.device(), 0);
        assert!(client.device_alive(0));
        assert!(!client.device_alive(1));
    }

    #[test]
    fn i32_buffers_round_trip() {
        let client = PjRtClient::cpu().unwrap();
        let buf = client.buffer_from_host_buffer(&[-5i32, 17, 1 << 20], &[3], None).unwrap();
        let v = buf.to_literal_sync().unwrap().to_vec::<i32>().unwrap();
        assert_eq!(v, vec![-5, 17, 1 << 20]);
    }
}
