//! Deterministic, seeded fault injection for chaos testing the serving
//! stack in stub builds.
//!
//! A [`FaultPlan`] maps call *sites* (short strings like `"upload"`,
//! `"execute"`, or any site a test invents) to an injection [`FaultKind`]
//! and a rate. Decisions are pure functions of `(seed, site, draw key)` —
//! no RNG state, no wall clock — so a plan replays identically across runs
//! and thread schedules:
//!
//! - [`check`] keys the draw on a per-site call counter (deterministic when
//!   the call order is; fine for single-threaded unit tests and the stub's
//!   own hooks);
//! - [`check_keyed`] takes a caller-supplied key (e.g. a per-sequence draw
//!   counter) so concurrent schedules cannot perturb fault placement —
//!   this is what the chaos bench and property tests use.
//!
//! Plans install programmatically ([`install`]) or from the
//! `LACACHE_FAULT_PLAN` env var (read once, on first check):
//!
//! ```text
//! LACACHE_FAULT_PLAN="seed=42;upload:transient:0.1;execute:panic:0.05;download:latency20:0.5"
//! ```
//!
//! Faults FIRE BEFORE the faulted operation touches anything — a faulted
//! call mutates nothing. That is the crash-consistency contract the
//! runtime's rebuild-from-arena recovery depends on.
//!
//! With no plan installed and the env var unset, [`check`] is a single
//! relaxed atomic load — the hooks cost nothing in normal runs.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once, OnceLock};

/// Marker substring carried by injected transient-fault errors; the
/// runtime's error taxonomy classifies on it.
pub const TRANSIENT_MARKER: &str = "injected-transient-fault";
/// Marker substring carried by injected fatal-fault errors and panics.
pub const FATAL_MARKER: &str = "injected-fatal-fault";

/// What an injected fault does at its call site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The operation fails with a [`TRANSIENT_MARKER`] error (retryable).
    Transient,
    /// The operation fails with a [`FATAL_MARKER`] error (never retried).
    Fatal,
    /// The operation succeeds after sleeping this many milliseconds.
    Latency(u64),
    /// The calling thread panics (exercises worker panic isolation).
    Panic,
}

/// One injection rule: at `site`, fire `kind` on a `rate` fraction of draws.
#[derive(Clone, Debug)]
pub struct SiteRule {
    pub site: String,
    pub kind: FaultKind,
    /// Fraction of draws at this site that fault, in `[0, 1]`.
    pub rate: f64,
}

/// A seeded set of injection rules.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub rules: Vec<SiteRule>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        FaultPlan { seed, rules: Vec::new() }
    }

    /// Builder-style: add one rule.
    pub fn rule(mut self, site: &str, kind: FaultKind, rate: f64) -> Self {
        self.rules.push(SiteRule { site: site.to_string(), kind, rate });
        self
    }

    /// Parse the `LACACHE_FAULT_PLAN` format: `;`-separated items, either
    /// `seed=N` or `site:kind:rate` with kind one of `transient`, `fatal`,
    /// `panic`, or `latencyNNN` (milliseconds). Unparseable items error so
    /// a typo'd plan fails loudly instead of silently injecting nothing.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut plan = FaultPlan::default();
        for item in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            if let Some(seed) = item.strip_prefix("seed=") {
                plan.seed =
                    seed.parse().map_err(|_| format!("fault plan: bad seed {seed:?}"))?;
                continue;
            }
            let parts: Vec<&str> = item.split(':').collect();
            if parts.len() != 3 {
                return Err(format!("fault plan: expected site:kind:rate, got {item:?}"));
            }
            let kind = match parts[1] {
                "transient" => FaultKind::Transient,
                "fatal" => FaultKind::Fatal,
                "panic" => FaultKind::Panic,
                k => {
                    let ms = k
                        .strip_prefix("latency")
                        .and_then(|ms| ms.parse().ok())
                        .ok_or_else(|| format!("fault plan: unknown kind {k:?}"))?;
                    FaultKind::Latency(ms)
                }
            };
            let rate: f64 =
                parts[2].parse().map_err(|_| format!("fault plan: bad rate {:?}", parts[2]))?;
            if !(0.0..=1.0).contains(&rate) {
                return Err(format!("fault plan: rate {rate} outside [0, 1]"));
            }
            plan.rules.push(SiteRule { site: parts[0].to_string(), kind, rate });
        }
        Ok(plan)
    }
}

struct FaultState {
    plan: Option<FaultPlan>,
    /// Per-site draw counters backing [`check`].
    counters: HashMap<String, u64>,
}

static STATE: OnceLock<Mutex<FaultState>> = OnceLock::new();
static ENABLED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

fn state() -> &'static Mutex<FaultState> {
    STATE.get_or_init(|| Mutex::new(FaultState { plan: None, counters: HashMap::new() }))
}

fn init_from_env() {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("LACACHE_FAULT_PLAN") {
            match FaultPlan::parse(&spec) {
                Ok(plan) => install_inner(Some(plan)),
                Err(e) => panic!("LACACHE_FAULT_PLAN: {e}"),
            }
        }
    });
}

fn install_inner(plan: Option<FaultPlan>) {
    let mut g = state().lock().unwrap_or_else(|p| p.into_inner());
    ENABLED.store(plan.as_ref().is_some_and(|p| !p.rules.is_empty()), Ordering::SeqCst);
    g.plan = plan;
    g.counters.clear();
}

/// Install (or clear, with `None`) the process-wide fault plan, resetting
/// per-site counters. Overrides any env-configured plan.
pub fn install(plan: Option<FaultPlan>) {
    init_from_env();
    install_inner(plan);
}

/// SplitMix64: a well-mixed hash of the 64-bit input.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Pure decision function: does draw `key` at `site` fault, and how?
fn decide(plan: &FaultPlan, site: &str, key: u64) -> Option<FaultKind> {
    for (i, r) in plan.rules.iter().enumerate() {
        if r.site != site {
            continue;
        }
        let h = splitmix64(
            plan.seed ^ fnv1a(site).rotate_left(i as u32) ^ key.wrapping_mul(0x2545F4914F6CDD1D),
        );
        // top 53 bits -> uniform in [0, 1)
        let u = (h >> 11) as f64 / (1u64 << 53) as f64;
        if u < r.rate {
            return Some(r.kind);
        }
    }
    None
}

/// Draw a fault decision for `site` keyed on its global call counter.
/// Returns the fault to apply, or `None` (the overwhelmingly common case).
pub fn check(site: &str) -> Option<FaultKind> {
    init_from_env();
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    let mut g = state().lock().unwrap_or_else(|p| p.into_inner());
    let key = {
        let c = g.counters.entry(site.to_string()).or_insert(0);
        let k = *c;
        *c += 1;
        k
    };
    g.plan.as_ref().and_then(|p| decide(p, site, key))
}

/// Draw a fault decision keyed by the caller — the decision depends only on
/// `(seed, site, key)`, so callers that key on e.g. a per-sequence op count
/// get fault placement independent of thread interleaving.
pub fn check_keyed(site: &str, key: u64) -> Option<FaultKind> {
    init_from_env();
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    let g = state().lock().unwrap_or_else(|p| p.into_inner());
    g.plan.as_ref().and_then(|p| decide(p, site, key))
}

/// Apply a drawn fault: sleep for latency faults (then proceed), panic for
/// panic faults, and return the marker error message for transient/fatal
/// faults — the caller turns `Some(msg)` into its own error type *before*
/// performing any part of the faulted operation.
pub fn apply(site: &str, kind: FaultKind) -> Option<String> {
    match kind {
        FaultKind::Latency(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            None
        }
        FaultKind::Panic => panic!("{FATAL_MARKER}: injected panic at {site}"),
        FaultKind::Transient => Some(format!("{TRANSIENT_MARKER} at {site}")),
        FaultKind::Fatal => Some(format!("{FATAL_MARKER} at {site}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_the_env_format() {
        let p = FaultPlan::parse("seed=42; upload:transient:0.1;execute:panic:0.05").unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!(p.rules.len(), 2);
        assert_eq!(p.rules[0].site, "upload");
        assert_eq!(p.rules[0].kind, FaultKind::Transient);
        assert!((p.rules[0].rate - 0.1).abs() < 1e-12);
        assert_eq!(p.rules[1].kind, FaultKind::Panic);
        let p = FaultPlan::parse("download:latency20:0.5").unwrap();
        assert_eq!(p.rules[0].kind, FaultKind::Latency(20));
        assert!(FaultPlan::parse("upload:transient").is_err());
        assert!(FaultPlan::parse("upload:flaky:0.1").is_err());
        assert!(FaultPlan::parse("upload:transient:1.5").is_err());
        assert!(FaultPlan::parse("seed=abc").is_err());
    }

    #[test]
    fn decisions_are_deterministic_and_rate_shaped() {
        let plan = FaultPlan::new(7).rule("op", FaultKind::Transient, 0.1);
        let hits: Vec<u64> = (0..10_000).filter(|&k| decide(&plan, "op", k).is_some()).collect();
        // deterministic: same plan, same answers
        let hits2: Vec<u64> = (0..10_000).filter(|&k| decide(&plan, "op", k).is_some()).collect();
        assert_eq!(hits, hits2);
        // rate-shaped: ~10% +/- generous slack
        assert!(hits.len() > 700 && hits.len() < 1300, "got {} faults", hits.len());
        // other sites unaffected
        assert!(decide(&plan, "other", 0).is_none());
        // different seeds place faults differently
        let plan2 = FaultPlan::new(8).rule("op", FaultKind::Transient, 0.1);
        let hits3: Vec<u64> = (0..10_000).filter(|&k| decide(&plan2, "op", k).is_some()).collect();
        assert_ne!(hits, hits3);
    }

    #[test]
    fn rate_bounds_are_absolute() {
        let never = FaultPlan::new(3).rule("op", FaultKind::Fatal, 0.0);
        assert!((0..1000).all(|k| decide(&never, "op", k).is_none()));
        let always = FaultPlan::new(3).rule("op", FaultKind::Fatal, 1.0);
        assert!((0..1000).all(|k| decide(&always, "op", k) == Some(FaultKind::Fatal)));
    }

    #[test]
    fn apply_formats_markers() {
        let msg = apply("upload", FaultKind::Transient).unwrap();
        assert!(msg.contains(TRANSIENT_MARKER) && msg.contains("upload"));
        let msg = apply("execute", FaultKind::Fatal).unwrap();
        assert!(msg.contains(FATAL_MARKER));
        assert!(apply("x", FaultKind::Latency(0)).is_none());
    }
}
