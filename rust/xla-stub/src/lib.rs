//! Offline stub of the `xla` PJRT bindings consumed by the runtime layer.
//!
//! The real backend (PJRT CPU client executing AOT-lowered HLO) only runs
//! where the native XLA bindings are installed and `make artifacts` has
//! produced compiled programs. This crate keeps the whole workspace
//! buildable and unit-testable in dependency-free environments: buffers are
//! real (host bytes retained, partial read/write, readback — see
//! [`stub`](crate) module docs), while parsing, compiling, or executing a
//! program reports a clear "backend unavailable" error. Integration tests
//! gate on artifacts and skip cleanly in stub builds.
//!
//! # Swapping in the real bindings (`real-pjrt` feature)
//!
//! Environments that have the native PJRT bindings enable the `real-pjrt`
//! cargo feature and point `LACACHE_XLA_BINDINGS` at a Rust source file that
//! provides the same surface (`PjRtClient`, `PjRtBuffer`,
//! `PjRtLoadedExecutable::{execute_b, execute_with_donation}`,
//! `HloModuleProto`, `XlaComputation`, `Literal`, `NativeType`, `Result`,
//! `Error`) backed by the native runtime:
//!
//! ```bash
//! LACACHE_XLA_BINDINGS=/opt/xla-rs/src/pjrt_surface.rs \
//!     cargo build --release --features real-pjrt
//! ```
//!
//! The env var is read at COMPILE time by `build.rs` (the file is
//! `include!`d). With the feature enabled but the env var unset, the build
//! falls back to the stub so artifact-less environments (CI's
//! both-feature-set build) still compile — the real-binding build is
//! artifact-gated, like the integration suite.

pub mod fault;
mod stub;

#[cfg(not(feature = "real-pjrt"))]
pub use stub::*;

#[cfg(feature = "real-pjrt")]
mod real {
    // build.rs writes `real_pjrt.rs`: either an `include!` of the file named
    // by LACACHE_XLA_BINDINGS, or a stub re-export fallback when unset.
    include!(concat!(env!("OUT_DIR"), "/real_pjrt.rs"));
}

#[cfg(feature = "real-pjrt")]
pub use real::*;
