//! Offline stub of the `xla` PJRT bindings consumed by the runtime layer.
//!
//! The real backend (PJRT CPU client executing AOT-lowered HLO) only runs
//! where the native XLA bindings are installed and `make artifacts` has
//! produced compiled programs. This stub keeps the whole crate buildable and
//! unit-testable in dependency-free environments: client/buffer construction
//! succeeds (so loaders get as far as their own file checks), while any
//! attempt to parse, compile, or execute a program reports a clear
//! "backend unavailable" error. Integration tests gate on artifacts and
//! skip cleanly in stub builds.

use std::fmt;
use std::path::Path;

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str =
    "xla backend unavailable (stub build: native PJRT bindings are not linked)";

#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable() -> Self {
        Error { msg: UNAVAILABLE.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

pub struct PjRtClient;

pub struct PjRtBuffer;

pub struct PjRtLoadedExecutable;

pub struct HloModuleProto;

pub struct XlaComputation;

pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_succeeds_execution_reports_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        let buf = client.buffer_from_host_buffer(&[1.0f32], &[1], None).unwrap();
        assert!(buf.to_literal_sync().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
        let err = PjRtLoadedExecutable.execute_b(&[]).unwrap_err();
        assert!(format!("{err}").contains("unavailable"));
    }
}
