//! With the `real-pjrt` feature, generate `$OUT_DIR/real_pjrt.rs`: an
//! `include!` of the bindings file named by `LACACHE_XLA_BINDINGS`, or a
//! fallback re-export of the vendored stub when the env var is unset (so the
//! feature set still builds in environments without the native runtime).

use std::env;
use std::path::PathBuf;

fn main() {
    println!("cargo:rerun-if-env-changed=LACACHE_XLA_BINDINGS");
    let out_dir = PathBuf::from(env::var("OUT_DIR").expect("OUT_DIR set by cargo"));
    let out = out_dir.join("real_pjrt.rs");
    let body = match env::var("LACACHE_XLA_BINDINGS") {
        Ok(path) if !path.is_empty() => {
            // canonicalize so include! (resolved relative to OUT_DIR) and
            // rerun-if-changed (resolved relative to the manifest dir) agree
            // even when the operator passes a relative path
            let path = std::fs::canonicalize(&path)
                .map(|p| p.display().to_string())
                .unwrap_or(path);
            println!("cargo:rerun-if-changed={path}");
            format!("include!({path:?});\n")
        }
        _ => {
            if env::var_os("CARGO_FEATURE_REAL_PJRT").is_some() {
                println!(
                    "cargo:warning=real-pjrt enabled but LACACHE_XLA_BINDINGS is unset; \
                     falling back to the vendored stub backend"
                );
            }
            "// LACACHE_XLA_BINDINGS unset: fall back to the vendored stub backend.\n\
             pub use crate::stub::*;\n"
                .to_string()
        }
    };
    std::fs::write(&out, body).expect("writing real_pjrt.rs");
}
