//! `lacache-exp` — one subcommand per paper table/figure (DESIGN.md §4).
//!
//! Every subcommand prints the table/series the paper reports (scaled per the
//! substitution ledger) and writes a JSON record under `results/`.
//!
//! Budget mapping: the paper quotes budgets as tokens (512/256 of a 4096
//! pretrain window) or as a context fraction; here budgets scale to
//! t_train=256 (so 50% ≈ 128, 25% ≈ 64). Perf-facing measurements (transfer
//! volume, gather counters, bench output) are documented in PERF.md.
//! Defaults reproduce everything end-to-end on CPU in minutes; pass
//! --fast for a quick smoke pass.

use std::path::Path;

use anyhow::{bail, Result};

use lacache::data::longbench::{longbench_task, LONGBENCH_DATASETS};
use lacache::data::ruler::{ruler_task, RULER_TASKS};
use lacache::data::tasks::GenTask;
use lacache::eval::niah::niah_heatmap;
use lacache::eval::ppl::{decode_ppl, stream_ppl_curve};
use lacache::eval::tasks::{run_suite, SuiteResult};
use lacache::runtime::Runtime;
use lacache::util::args::Args;
use lacache::util::json::Json;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    std::fs::create_dir_all(out_dir(&args))?;
    if cmd == "all" {
        for c in [
            "table1", "table2", "fig3", "fig5", "fig6", "table3", "table4", "fig7", "fig8",
            "fig9", "table5", "fig10", "table6",
        ] {
            println!("\n================ {c} ================");
            run_one(c, &args)?;
        }
        return Ok(());
    }
    run_one(cmd, &args)
}

fn run_one(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "table1" => table1(args),
        "table2" => table2(args),
        "fig3" => fig3(args),
        "fig5" => fig5(args),
        "fig6" => fig6(args),
        "table3" | "table4" => longbench(args, cmd),
        "fig7" => fig7(args),
        "fig8" | "fig9" => niah(args, cmd),
        "table5" => table5(args),
        "fig10" => fig10(args),
        "table6" => table6(args),
        _ => {
            eprintln!(
                "usage: lacache-exp <table1|table2|fig3|fig5|fig6|table3|table4|fig7|fig8|fig9|table5|fig10|table6|all> [--models ...] [--budgets ...] [--lengths ...] [--fast]"
            );
            if cmd != "help" {
                bail!("unknown subcommand `{cmd}`");
            }
            Ok(())
        }
    }
}

fn out_dir(args: &Args) -> String {
    args.str_or("out", "results")
}

fn save(args: &Args, name: &str, j: Json) -> Result<()> {
    let path = format!("{}/{name}.json", out_dir(args));
    std::fs::write(Path::new(&path), j.to_string())?;
    println!("[saved {path}]");
    Ok(())
}

fn load_rt(models: &[String]) -> Result<Runtime> {
    let refs: Vec<&str> = models.iter().map(|s| s.as_str()).collect();
    Runtime::load(&lacache::artifacts_dir(), &refs)
}

fn fast(args: &Args) -> bool {
    args.flag("fast")
}

// ---------------------------------------------------------------------------
// Table 1: decode-length PPL, LaCache vs StreamingLLM vs full, 2 budgets
// ---------------------------------------------------------------------------
fn table1(args: &Args) -> Result<()> {
    let models = args.list_or("models", &["base", "mini"]);
    let budgets = args.usize_list_or("budgets", &[128, 64]);
    let lengths = args.usize_list_or("lengths", &[64, 128, 256, 512, 1024]);
    let seed = args.u64_or("seed", 42);
    let w = args.usize_or("window", 32);
    let rt = load_rt(&models)?;
    let mut out = Json::obj();
    for model in &models {
        let n_layers = rt.model(model)?.cfg.n_layers;
        let span = (n_layers / 4).max(1);
        println!("\n== model {model} (L={n_layers}) ==");
        println!(
            "{:<34} {}",
            "policy",
            lengths.iter().map(|l| format!("{l:>8}")).collect::<String>()
        );
        let mut rows = Json::obj();
        let mut specs = vec![("full (100%)".to_string(), "full".to_string(), 2048usize)];
        for &b in &budgets {
            specs.push((format!("streaming ({b})"), format!("streaming:budget={b}"), 256));
            specs.push((format!("lacache ({b})"), format!("lacache:budget={b},span={span}"), 256));
        }
        for (label, spec, c) in specs {
            let pts = decode_ppl(&rt, model, &spec, seed, &lengths, w, c, None)?;
            let cells: String = pts
                .iter()
                .map(|p| if p.oom { format!("{:>8}", "nan") } else { format!("{:>8.2}", p.ppl) })
                .collect();
            println!("{label:<34} {cells}");
            rows.set(
                &label,
                Json::Arr(
                    pts.iter().map(|p| if p.oom { Json::Null } else { p.ppl.into() }).collect(),
                ),
            );
        }
        out.set(model, rows);
    }
    out.set("lengths", Json::Arr(lengths.iter().map(|&l| l.into()).collect()));
    save(args, "table1", out)
}

// ---------------------------------------------------------------------------
// Table 2: extreme small budget, long decode lengths
// ---------------------------------------------------------------------------
fn table2(args: &Args) -> Result<()> {
    let model = args.str_or("model", "base");
    let budget = args.usize_or("budget", 24);
    let max_len = if fast(args) { 1024 } else { 4096 };
    let lengths: Vec<usize> = args
        .usize_list_or("lengths", &[64, 128, 256, 512, 1024, 2048, max_len])
        .into_iter()
        .filter(|&l| l <= max_len)
        .collect();
    let seed = args.u64_or("seed", 42);
    let rt = load_rt(&[model.clone()])?;
    let n_layers = rt.model(&model)?.cfg.n_layers;
    let span = (n_layers / 4).max(1);
    println!("budget {budget} (~{:.0}% of t_train)", 100.0 * budget as f64 / 256.0);
    println!(
        "{:<22} {}",
        "policy",
        lengths.iter().map(|l| format!("{l:>8}")).collect::<String>()
    );
    let mut out = Json::obj();
    for (label, spec, c) in [
        ("full".to_string(), "full".to_string(), 2048),
        (format!("streaming ({budget})"), format!("streaming:budget={budget}"), 256),
        (
            format!("lacache ({budget})"),
            format!("lacache:budget={budget},span={span},recent=8"),
            256,
        ),
    ] {
        let pts = decode_ppl(&rt, &model, &spec, seed, &lengths, 32, c, None)?;
        let cells: String = pts
            .iter()
            .map(|p| if p.oom { format!("{:>8}", "nan") } else { format!("{:>8.2}", p.ppl) })
            .collect();
        println!("{label:<22} {cells}");
        out.set(
            &label,
            Json::Arr(pts.iter().map(|p| if p.oom { Json::Null } else { p.ppl.into() }).collect()),
        );
    }
    save(args, "table2", out)
}

// ---------------------------------------------------------------------------
// Fig 3: PPL-vs-cache-size Pareto — ladder vs random pattern cloud
// ---------------------------------------------------------------------------
fn fig3(args: &Args) -> Result<()> {
    let model = args.str_or("model", "base");
    let n_random = args.usize_or("n-patterns", if fast(args) { 24 } else { 120 });
    let length = args.usize_or("length", 512);
    let seed = args.u64_or("seed", 42);
    let rt = load_rt(&[model.clone()])?;
    let n_layers = rt.model(&model)?.cfg.n_layers;
    let span = (n_layers / 4).max(1);
    let budgets = args.usize_list_or("budgets", &[48, 64, 96, 128, 160]);
    let mut points = Vec::new(); // (kind, budget, ppl)
    for &b in &budgets {
        let spec = format!("lacache:budget={b},span={span}");
        let pts = decode_ppl(&rt, &model, &spec, seed, &[length], 32, 256, None)?;
        points.push(("ladder".to_string(), b, pts[0].ppl));
        println!("ladder  b={b:<4} ppl={:.3}", pts[0].ppl);
    }
    let mut rng = lacache::util::rng::Xoshiro256::new(seed);
    for i in 0..n_random {
        let b = *rng.choose(&budgets);
        let frac = 0.1 + rng.f64() * 0.6;
        let recent = 8 + rng.below(b as u64 / 2) as usize;
        let spec = format!("random:budget={b},frac={frac:.3},seed={i},recent={recent}");
        let pts = decode_ppl(&rt, &model, &spec, seed, &[length], 32, 256, None)?;
        points.push(("random".to_string(), b, pts[0].ppl));
        if i % 20 == 0 {
            println!("random pattern {i}/{n_random} b={b} ppl={:.3}", pts[0].ppl);
        }
    }
    println!("\nbudget  ladder_ppl  best_random  n_random_better");
    let mut out_rows = Vec::new();
    for &b in &budgets {
        let ladder = points
            .iter()
            .find(|(k, bb, _)| k == "ladder" && *bb == b)
            .map(|(_, _, p)| *p)
            .unwrap();
        let rand: Vec<f64> = points
            .iter()
            .filter(|(k, bb, _)| k == "random" && *bb == b)
            .map(|(_, _, p)| *p)
            .collect();
        let best = rand.iter().copied().fold(f64::INFINITY, f64::min);
        let n_better = rand.iter().filter(|&&p| p < ladder).count();
        println!("{b:>6}  {ladder:>10.3}  {best:>11.3}  {n_better:>3}/{}", rand.len());
        out_rows.push(Json::from_pairs(vec![
            ("budget", b.into()),
            ("ladder_ppl", ladder.into()),
            ("best_random_ppl", best.into()),
            ("n_random_better", n_better.into()),
            ("n_random", rand.len().into()),
        ]));
    }
    save(
        args,
        "fig3",
        Json::from_pairs(vec![
            ("summary", Json::Arr(out_rows)),
            (
                "points",
                Json::Arr(
                    points
                        .iter()
                        .map(|(k, b, p)| {
                            Json::from_pairs(vec![
                                ("kind", k.as_str().into()),
                                ("budget", (*b).into()),
                                ("ppl", (*p).into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    )
}

// ---------------------------------------------------------------------------
// Fig 5: long-stream PPL curve, full cache explodes/OOMs, LaCache flat
// Fig 6: very long stream, LaCache vs StreamingLLM
// ---------------------------------------------------------------------------
fn fig5(args: &Args) -> Result<()> {
    let model = args.str_or("model", "base");
    let total = args.usize_or("total", if fast(args) { 6_000 } else { 20_000 });
    let rt = load_rt(&[model.clone()])?;
    let n_layers = rt.model(&model)?.cfg.n_layers;
    let span = (n_layers / 4).max(1);
    let mut out = Json::obj();
    for (label, spec, c) in [
        ("full", "full".to_string(), 2048usize),
        ("lacache", format!("lacache:budget=128,span={span}"), 256),
    ] {
        let curve = stream_ppl_curve(&rt, &model, &spec, 7, total, 512, 128, c, None)?;
        println!("\n{label}:");
        for (pos, ppl) in &curve {
            if ppl.is_nan() {
                println!("  pos {pos:>7}: OOM");
            } else {
                println!("  pos {pos:>7}: ppl {ppl:.2}");
            }
        }
        out.set(
            label,
            Json::Arr(
                curve
                    .iter()
                    .map(|(p, v)| {
                        Json::Arr(vec![(*p).into(), if v.is_nan() { Json::Null } else { (*v).into() }])
                    })
                    .collect(),
            ),
        );
    }
    save(args, "fig5", out)
}

fn fig6(args: &Args) -> Result<()> {
    let model = args.str_or("model", "base");
    let total = args.usize_or("total", if fast(args) { 10_000 } else { 60_000 });
    let rt = load_rt(&[model.clone()])?;
    let n_layers = rt.model(&model)?.cfg.n_layers;
    let span = (n_layers / 4).max(1);
    let mut out = Json::obj();
    let mut finals = Vec::new();
    for (label, spec) in [
        ("streaming", "streaming:budget=128".to_string()),
        ("lacache", format!("lacache:budget=128,span={span}")),
    ] {
        let curve = stream_ppl_curve(&rt, &model, &spec, 11, total, 2048, 128, 256, None)?;
        let mean: f64 = curve.iter().map(|(_, p)| p).sum::<f64>() / curve.len() as f64;
        println!("{label}: mean segment ppl over {total} tokens = {mean:.3}");
        finals.push((label, mean));
        out.set(
            label,
            Json::Arr(
                curve.iter().map(|(p, v)| Json::Arr(vec![(*p).into(), (*v).into()])).collect(),
            ),
        );
    }
    println!(
        "\nLaCache {} StreamingLLM ({:.3} vs {:.3})",
        if finals[1].1 < finals[0].1 { "beats" } else { "does NOT beat" },
        finals[1].1,
        finals[0].1
    );
    save(args, "fig6", out)
}

// ---------------------------------------------------------------------------
// Tables 3/4: LongBench 21 datasets under 50%/25% budgets
// ---------------------------------------------------------------------------
fn longbench_suite(scale: f64, seeds: &[u64]) -> Vec<(String, Vec<GenTask>)> {
    LONGBENCH_DATASETS
        .iter()
        .map(|(name, _, _, _)| {
            let tasks: Vec<GenTask> = seeds.iter().map(|&s| longbench_task(name, s, scale)).collect();
            (name.to_string(), tasks)
        })
        .collect()
}

fn longbench(args: &Args, cmd: &str) -> Result<()> {
    let model = args.str_or("model", if cmd == "table4" { "mini" } else { "base" });
    let reps = args.usize_or("reps", if fast(args) { 1 } else { 3 });
    let scale = args.f64_or("scale", if fast(args) { 0.5 } else { 1.0 });
    let seeds: Vec<u64> = (0..reps as u64).map(|i| 1000 + i).collect();
    let rt = load_rt(&[model.clone()])?;
    // NOTE: no "100%" column — generation programs are compiled at C=256
    // (the serving capacity); an uncompressed cache cannot hold these
    // contexts, which is precisely the paper's motivation. The budgeted
    // policies below are the paper's comparison set.
    let cases = [
        ("stream-50%", "streaming:budget=128".to_string(), 256usize),
        ("stream-25%", "streaming:budget=64".to_string(), 256),
        ("lacache-50%", "lacache_und:budget=128,ratio=0.5".to_string(), 256),
        ("lacache-25%", "lacache_und:budget=64,ratio=0.25".to_string(), 256),
    ];
    let suite = longbench_suite(scale, &seeds);
    println!(
        "{:<22} {}",
        "dataset",
        cases.iter().map(|(l, _, _)| format!("{l:>13}")).collect::<String>()
    );
    let mut per_policy_means = vec![0.0; cases.len()];
    let mut out = Json::obj();
    for (ds, tasks) in &suite {
        let mut row = String::new();
        let mut row_json = Json::obj();
        for (ci, (label, spec, c)) in cases.iter().enumerate() {
            let r = run_suite(&rt, &model, spec, 128, *c, tasks)?;
            row.push_str(&format!("{:>13.1}", r.mean_score * 100.0));
            per_policy_means[ci] += r.mean_score * 100.0;
            row_json.set(label, (r.mean_score * 100.0).into());
        }
        println!("{ds:<22} {row}");
        out.set(ds, row_json);
    }
    let n = suite.len() as f64;
    println!(
        "{:<22} {}",
        "Average",
        per_policy_means.iter().map(|m| format!("{:>13.1}", m / n)).collect::<String>()
    );
    let mut avg = Json::obj();
    for (ci, (label, _, _)) in cases.iter().enumerate() {
        avg.set(label, (per_policy_means[ci] / n).into());
    }
    out.set("Average", avg);
    save(args, cmd, out)
}

// ---------------------------------------------------------------------------
// Fig 7: score vs throughput across all policies
// ---------------------------------------------------------------------------
fn fig7(args: &Args) -> Result<()> {
    let model = args.str_or("model", "base");
    let reps = args.usize_or("reps", if fast(args) { 1 } else { 2 });
    let scale = args.f64_or("scale", 0.5);
    let seeds: Vec<u64> = (0..reps as u64).map(|i| 2000 + i).collect();
    let rt = load_rt(&[model.clone()])?;
    // representative subset: one dataset per category
    let subset =
        ["HotpotQA", "MultiFieldQA-en", "GovReport", "TriviaQA", "PassageRetrieval-en", "LCC"];
    let mut tasks = Vec::new();
    for ds in subset {
        for &s in &seeds {
            tasks.push(longbench_task(ds, s, scale));
        }
    }
    let policies = [
        ("streaming", "streaming:budget=96".to_string()),
        ("lacache", "lacache_und:budget=96,ratio=0.4".to_string()),
        ("h2o", "h2o:budget=96".to_string()),
        ("tova", "tova:budget=96".to_string()),
        ("snapkv", "snapkv:budget=96".to_string()),
        // pyramid's mean budget: its widest layer gets ~1.5x, which must
        // still fit C with the ingestion window
        ("pyramid", "pyramid:budget=64".to_string()),
    ];
    println!("{:<12} {:>8} {:>12} {:>10}", "policy", "score", "tokens/s", "wall_s");
    let mut rows = Vec::new();
    for (label, spec) in &policies {
        let r: SuiteResult = run_suite(&rt, &model, spec, 128, 256, &tasks)?;
        println!(
            "{label:<12} {:>8.1} {:>12.1} {:>10.2}",
            r.mean_score * 100.0,
            r.tokens_per_s,
            r.wall_s
        );
        rows.push(Json::from_pairs(vec![
            ("policy", (*label).into()),
            ("score", (r.mean_score * 100.0).into()),
            ("tokens_per_s", r.tokens_per_s.into()),
            ("wall_s", r.wall_s.into()),
        ]));
    }
    save(args, "fig7", Json::Arr(rows))
}

// ---------------------------------------------------------------------------
// Fig 8/9: NIAH heatmaps at 50% / 25% budget
// ---------------------------------------------------------------------------
fn niah(args: &Args, cmd: &str) -> Result<()> {
    let model = args.str_or("model", "base");
    let budget = if cmd == "fig8" { 128 } else { 64 };
    let ratio = if cmd == "fig8" { 0.5 } else { 0.25 };
    let reps = args.usize_or("reps", if fast(args) { 1 } else { 3 });
    let ctx_lens = args.usize_list_or("ctx", &[384, 512, 768, 1024, 1536]);
    let depths = [0.1, 0.3, 0.5, 0.7, 0.9];
    let rt = load_rt(&[model.clone()])?;
    let mut out = Json::obj();
    for (label, spec) in [
        ("streaming", format!("streaming:budget={budget}")),
        ("lacache", format!("lacache_und:budget={budget},ratio={ratio}")),
    ] {
        let h = niah_heatmap(&rt, &model, &spec, 128, 256, &ctx_lens, &depths, reps, 77)?;
        println!("\n{label} (budget {budget}): mean acc {:.1}%", h.mean() * 100.0);
        println!("{}", h.render());
        out.set(
            label,
            Json::from_pairs(vec![
                ("mean", (h.mean() * 100.0).into()),
                (
                    "acc",
                    Json::Arr(
                        h.acc
                            .iter()
                            .map(|row| Json::Arr(row.iter().map(|&v| v.into()).collect()))
                            .collect(),
                    ),
                ),
            ]),
        );
    }
    save(args, cmd, out)
}

// ---------------------------------------------------------------------------
// Table 5: RULER 13 tasks at 50% budget
// ---------------------------------------------------------------------------
fn table5(args: &Args) -> Result<()> {
    let model = args.str_or("model", "base");
    let reps = args.usize_or("reps", if fast(args) { 1 } else { 3 });
    let ctx = args.usize_or("ctx", 768);
    let rt = load_rt(&[model.clone()])?;
    let policies = [
        ("streaming", "streaming:budget=128".to_string()),
        ("lacache", "lacache_und:budget=128,ratio=0.5".to_string()),
    ];
    println!("{:<14} {:>12} {:>12}", "task", "streaming", "lacache");
    let mut out = Json::obj();
    let mut means = [0.0f64; 2];
    for task_name in RULER_TASKS {
        let tasks: Vec<GenTask> =
            (0..reps as u64).map(|s| ruler_task(task_name, ctx, 3000 + s)).collect();
        let mut row = Json::obj();
        let mut cells = String::new();
        for (pi, (label, spec)) in policies.iter().enumerate() {
            let r = run_suite(&rt, &model, spec, 128, 256, &tasks)?;
            cells.push_str(&format!("{:>12.1}", r.mean_score * 100.0));
            means[pi] += r.mean_score * 100.0;
            row.set(label, (r.mean_score * 100.0).into());
        }
        println!("{task_name:<14} {cells}");
        out.set(task_name, row);
    }
    let n = RULER_TASKS.len() as f64;
    println!("{:<14} {:>12.1} {:>12.1}", "Avg.", means[0] / n, means[1] / n);
    out.set(
        "Avg",
        Json::from_pairs(vec![
            ("streaming", (means[0] / n).into()),
            ("lacache", (means[1] / n).into()),
        ]),
    );
    save(args, "table5", out)
}

// ---------------------------------------------------------------------------
// Fig 10: span-S ablation grid (PPL); Table 6: overlap-O ablation (tasks)
// ---------------------------------------------------------------------------
fn fig10(args: &Args) -> Result<()> {
    let model = args.str_or("model", "base");
    let budget = args.usize_or("budget", 64);
    let length = args.usize_or("length", 512);
    let rt = load_rt(&[model.clone()])?;
    let n_layers = rt.model(&model)?.cfg.n_layers;
    let spans: Vec<usize> = (1..=n_layers).filter(|s| n_layers % s == 0).collect();
    println!("budget {budget}, length {length} (paper: best near S = L/4 = {})", n_layers / 4);
    println!("{:<8} {:>10}", "span S", "ppl");
    let mut rows = Vec::new();
    for &s in &spans {
        let spec = format!("lacache:budget={budget},span={s},overlap={}", (s / 2).max(1));
        let pts = decode_ppl(&rt, &model, &spec, 42, &[length], 32, 256, None)?;
        println!("{s:<8} {:>10.3}", pts[0].ppl);
        rows.push(Json::from_pairs(vec![("span", s.into()), ("ppl", pts[0].ppl.into())]));
    }
    save(args, "fig10", Json::Arr(rows))
}

fn table6(args: &Args) -> Result<()> {
    let model = args.str_or("model", "base");
    let reps = args.usize_or("reps", if fast(args) { 2 } else { 4 });
    let rt = load_rt(&[model.clone()])?;
    let n_layers = rt.model(&model)?.cfg.n_layers;
    let span = (n_layers / 2).max(1);
    // QA tasks (local answers) vs synthetic tasks (global) vs overlap O
    let qa_sets = ["NarrativeQA", "Qasper", "MultiFieldQA-en", "MultiFieldQA-zh"];
    let syn_sets = ["PassageCount", "PassageRetrieval-en", "PassageRetrieval-zh"];
    let overlaps = [("O=1", 1usize), ("O=S/4", (span / 4).max(1)), ("O=S/2", (span / 2).max(1))];
    println!("{:<10} {:>10} {:>12}", "overlap", "QA", "synthetic");
    let mut rows = Vec::new();
    for (label, o) in overlaps {
        let spec = format!("lacache:budget=128,span={span},overlap={o}");
        let mut scores = [0.0f64; 2];
        for (gi, group) in [qa_sets.as_slice(), syn_sets.as_slice()].iter().enumerate() {
            let mut tasks = Vec::new();
            for ds in *group {
                for s in 0..reps as u64 {
                    tasks.push(longbench_task(ds, 4000 + s, 1.0));
                }
            }
            let r = run_suite(&rt, &model, &spec, 128, 256, &tasks)?;
            scores[gi] = r.mean_score * 100.0;
        }
        println!("{label:<10} {:>10.1} {:>12.1}", scores[0], scores[1]);
        rows.push(Json::from_pairs(vec![
            ("overlap", label.into()),
            ("qa", scores[0].into()),
            ("synthetic", scores[1].into()),
        ]));
    }
    save(args, "table6", Json::Arr(rows))
}
