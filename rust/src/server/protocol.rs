//! JSON-lines wire protocol (one JSON object per line, request/response).
//!
//! Requests:
//!
//! ```text
//! {"op":"generate","id":1,"prompt":"<mark> w4 w5 <sep> ...","max_new_tokens":8}
//! {"op":"generate","id":2,"prompt_tokens":[0,5,20,...],"max_new_tokens":4}
//! {"op":"generate","id":5,"prompt_tokens":[...],"prefix_hint":false}
//! {"op":"generate","id":6,"prompt_tokens":[...],"deadline_ms":500}
//! {"op":"generate","id":10,"prompt_tokens":[...],"trace":true}
//! {"op":"stats","id":3}
//! {"op":"ping","id":8}
//! {"op":"trace","id":11,"seq":5,"kind":"retry","since":100,"limit":64}
//! {"op":"metrics","id":12}
//! {"op":"shutdown","id":4}
//! ```
//!
//! `prefix_hint` (default true) lets the server reuse KV state computed for
//! an earlier request with the same prompt prefix (the cross-request prefix
//! cache); `false` opts this request out — it always prefills cold, which
//! benchmarking and privacy-sensitive clients want.
//!
//! `deadline_ms` (optional) bounds the request's wall-clock time from
//! submit: past the deadline the server finishes the request early with
//! whatever tokens it has generated, `ok:false`, and `code:
//! "deadline-exceeded"` (a stuck in-flight device call is abandoned by a
//! watchdog after a short grace period, so the reply never hangs on it).
//!
//! `trace: true` (default false) attaches the request's flight-recorder
//! phase breakdown to the reply as a `trace` array — every recorded event
//! for this request (queued / admitted / placed / prefill windows /
//! submit-reap / first-token / retries / finished), oldest-first, in the
//! same event shape `op:trace` dumps. Events already overwritten in the
//! ring (or sampled out by `--trace-sample-every`) are simply absent.
//!
//! Responses:
//!
//! ```text
//! {"id":1,"ok":true,"text":"w84 w85 ...","tokens":[...],"ttft_ms":..,
//!  "itl_ms":..,"total_ms":..,"prompt_tokens":N,"prefix_tokens":P,
//!  "gen_tokens":M}
//! {"id":3,"ok":true,"stats":{...}}
//! {"id":8,"ok":true,"version":"...","uptime_s":12.5,"degraded":false,
//!  "inflight":0,"queue_depth":0,"active_seqs":0,"trace_dropped_total":0,
//!  "shards":[{"device":0,"degraded":false,"inflight":0,
//!             "resident_bytes":0}, ...]}
//! {"id":11,"ok":true,"events":[{"at":1,"t_us":...,"seq":5,"shard":0,
//!  "kind":"queued","a":128,"b":16}, ...],"watermark":412,
//!  "trace_dropped_total":0}
//! {"id":12,"ok":true,"content_type":"text/plain; version=0.0.4",
//!  "metrics":"# TYPE lacache_submitted gauge\nlacache_submitted 3\n..."}
//! {"id":2,"ok":false,"error":"...","code":"..."}
//! {"id":7,"ok":false,"error":"overloaded: ...","code":"overloaded",
//!  "retry_after_ms":50}
//! ```
//!
//! `prefix_tokens` reports how many leading prompt tokens were served from
//! the prefix cache (0 = cold prefill). `itl_ms` is the request's mean
//! inter-token latency after the first token (0 when at most one token was
//! generated).
//!
//! Failed generates carry a machine-readable `code` alongside the free-text
//! `error`: `"overloaded"` (queue full — retry after `retry_after_ms`),
//! `"deadline-exceeded"` (partial `tokens`/`text` are included when any
//! were generated), or a device-call classification
//! (`"transient"` / `"device-lost"` / `"oom"` / `"fatal"`) once the retry
//! budget is exhausted.
//!
//! `op:stats` includes the tiered-compression gauges alongside the arena
//! and transfer counters: `quant_pages` / `quant_bytes` (live int8 cold
//! pages and their actual bytes), `fp32_bytes` (the full-precision
//! remainder of `kv_arena_bytes_in_use`), `quant_compaction_ratio` (f32
//! bytes the quantized pages replace over their actual bytes, ~4 at steady
//! state with `--kv-quant cold-q8`, 0 when nothing is quantized), and
//! `dequant_s` (cumulative seconds spent dequantizing Q8 pages during
//! gathers — a subset of `gather_s`, 0 with `--kv-quant off`).
//!
//! `op:ping` is the health probe: `degraded` reports
//! the FLEET-level sticky device-tier bypass — true only when every shard
//! has tripped (see PERF.md "Failure handling & recovery") — `inflight` /
//! `queue_depth` / `active_seqs` the load, `uptime_s` the process age,
//! `trace_dropped_total` the flight-recorder overflow counter (a rising
//! value means the trace ring is overwriting events faster than anyone
//! drains them — size it up or raise `--trace-sample-every`), and `shards`
//! the per-device breakdown (one entry per shard, device order; a
//! one-device server reports a one-element array), so orchestrators can see
//! a single lost device while the fleet keeps serving.
//!
//! `op:trace` dumps the flight recorder's recent event window (see
//! `crate::obs` for the taxonomy), oldest-first. Filters are optional and
//! conjunctive: `seq` (request id for scheduler events, KV cache id for
//! runtime events), `kind` (a kebab-case event name, e.g. `"retry"`;
//! unknown names are a parse error), `since` (only events with
//! `at > since` — pass a previous reply's `watermark` back to resume a
//! tail), and `limit` (keep the newest N matches; default 256, 0 =
//! unlimited). The reply's `watermark` is the global event sequence number
//! at dump time; `trace_dropped_total` counts ring overwrites plus
//! contention drops since startup.
//!
//! `op:metrics` renders every `op:stats` gauge (including the hook-attached
//! `export_*` counters and the per-shard breakdown) plus the native latency
//! histograms as Prometheus text exposition v0.0.4, returned as the
//! `metrics` string field — a sidecar scraper can poll this op and serve
//! the body over HTTP verbatim.
//!
//! Connection semantics: closing (or half-closing) the connection's write
//! side ABANDONS all of that connection's in-flight requests — the server
//! cancels the sequences and frees their KV pages immediately rather than
//! finishing work nobody acknowledged they still want. Clients must keep
//! the write side open while awaiting replies.

use anyhow::{bail, Result};

use crate::obs::{Event, EventKind, TraceFilter};
use crate::util::json::Json;

/// Error message for generate requests that arrive after `op:shutdown` has
/// been accepted: the reactor rejects them instead of admitting work no one
/// will wait for. String-matched by clients and tests.
pub const SHUTTING_DOWN: &str = "shutting-down";

/// Default `limit` for `op:trace` when the request omits it: the newest 256
/// matching events (a full ring dump over a line protocol is rarely what an
/// interactive client wants; pass `limit: 0` explicitly for unlimited).
pub const DEFAULT_TRACE_LIMIT: usize = 256;

#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    Generate {
        prompt: Vec<i32>,
        max_new_tokens: usize,
        prefix_hint: bool,
        /// Relative wall-clock bound from submit (`None` = unbounded).
        deadline_ms: Option<u64>,
        /// Attach this request's flight-recorder phase breakdown to the
        /// reply (`trace` array).
        trace: bool,
    },
    Stats,
    Ping,
    /// Dump the flight recorder's recent events through the filter.
    Trace(TraceFilter),
    /// Prometheus text exposition of stats gauges + latency histograms.
    Metrics,
    Shutdown,
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: i64,
    pub op: Op,
}

pub fn parse_request(line: &str) -> Result<Request> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let id = j.f64_of("id").unwrap_or(0.0) as i64;
    let op = match j.str_of("op") {
        Some("generate") => {
            let prompt = if let Some(txt) = j.str_of("prompt") {
                super::text::tokenize(txt).map_err(|e| anyhow::anyhow!(e))?
            } else if let Some(arr) = j.get("prompt_tokens").and_then(|a| a.as_arr()) {
                arr.iter().map(|x| x.as_i64().unwrap_or(0) as i32).collect()
            } else {
                bail!("generate needs `prompt` or `prompt_tokens`");
            };
            if prompt.is_empty() {
                bail!("empty prompt");
            }
            Op::Generate {
                prompt,
                max_new_tokens: j.usize_of("max_new_tokens").unwrap_or(16),
                prefix_hint: j.bool_of("prefix_hint").unwrap_or(true),
                deadline_ms: j.usize_of("deadline_ms").map(|d| d as u64),
                trace: j.bool_of("trace").unwrap_or(false),
            }
        }
        Some("stats") => Op::Stats,
        Some("ping") => Op::Ping,
        Some("trace") => {
            let kind = match j.str_of("kind") {
                Some(s) => match EventKind::parse(s) {
                    Some(k) => Some(k),
                    None => bail!("unknown trace kind {s:?}"),
                },
                None => None,
            };
            Op::Trace(TraceFilter {
                seq: j.usize_of("seq").map(|s| s as u64),
                kind,
                since: j.usize_of("since").map(|w| w as u64),
                limit: j.usize_of("limit").unwrap_or(DEFAULT_TRACE_LIMIT),
            })
        }
        Some("metrics") => Op::Metrics,
        Some("shutdown") => Op::Shutdown,
        other => bail!("unknown op {other:?}"),
    };
    Ok(Request { id, op })
}

/// Success reply for a generate. `trace` is the request's flight-recorder
/// phase breakdown (attached as a `trace` event array when the request set
/// `trace: true`; `None` omits the key entirely).
#[allow(clippy::too_many_arguments)]
pub fn ok_generate(
    id: i64,
    tokens: &[i32],
    prompt_tokens: usize,
    prefix_tokens: usize,
    ttft_ms: f64,
    itl_ms: f64,
    total_ms: f64,
    trace: Option<&[Event]>,
) -> String {
    let mut j = Json::from_pairs(vec![
        ("id", id.into()),
        ("ok", true.into()),
        ("text", super::text::detokenize(tokens).into()),
        ("tokens", tokens.iter().map(|&t| t as i64).collect::<Vec<i64>>().into()),
        ("prompt_tokens", prompt_tokens.into()),
        ("prefix_tokens", prefix_tokens.into()),
        ("gen_tokens", tokens.len().into()),
        ("ttft_ms", ttft_ms.into()),
        ("itl_ms", itl_ms.into()),
        ("total_ms", total_ms.into()),
    ]);
    if let Some(events) = trace {
        j.set("trace", events.iter().map(Event::to_json).collect::<Vec<Json>>().into());
    }
    j.to_string()
}

pub fn ok_stats(id: i64, stats: Json) -> String {
    Json::from_pairs(vec![("id", id.into()), ("ok", true.into()), ("stats", stats)]).to_string()
}

/// `op:trace` reply: the filtered event window oldest-first, the recorder's
/// current `watermark` (pass back as `since` to resume), and the overflow
/// counter.
pub fn ok_trace(id: i64, events: &[Event], watermark: u64, dropped_total: u64) -> String {
    Json::from_pairs(vec![
        ("id", id.into()),
        ("ok", true.into()),
        ("events", events.iter().map(Event::to_json).collect::<Vec<Json>>().into()),
        ("watermark", (watermark as i64).into()),
        ("trace_dropped_total", (dropped_total as i64).into()),
    ])
    .to_string()
}

/// `op:metrics` reply: the Prometheus text exposition body as a JSON string
/// field (see [`crate::server::metrics::prometheus_text`]).
pub fn ok_metrics(id: i64, body: &str) -> String {
    Json::from_pairs(vec![
        ("id", id.into()),
        ("ok", true.into()),
        ("content_type", "text/plain; version=0.0.4".into()),
        ("metrics", body.into()),
    ])
    .to_string()
}

/// Health-probe reply (`op:ping`): build version, process uptime, the
/// fleet-level sticky degraded flag (true only when EVERY shard has
/// tripped), the current load gauges, the flight-recorder overflow counter
/// (`trace_dropped_total` — probes watch it rise to detect ring overflow
/// without pulling a full trace), and the per-shard health breakdown —
/// always emitted, even for a one-device fleet, so probes never branch on
/// its presence.
#[allow(clippy::too_many_arguments)]
pub fn ok_ping(
    id: i64,
    version: &str,
    uptime_s: f64,
    degraded: bool,
    inflight: usize,
    queue_depth: usize,
    active_seqs: usize,
    trace_dropped_total: u64,
    shards: &[super::batcher::ShardHealth],
) -> String {
    let shard_arr: Vec<Json> = shards
        .iter()
        .map(|s| {
            Json::from_pairs(vec![
                ("device", (s.device as i64).into()),
                ("degraded", s.degraded.into()),
                ("inflight", (s.inflight as i64).into()),
                ("resident_bytes", (s.resident_bytes as i64).into()),
            ])
        })
        .collect();
    Json::from_pairs(vec![
        ("id", id.into()),
        ("ok", true.into()),
        ("version", version.into()),
        ("uptime_s", uptime_s.into()),
        ("degraded", degraded.into()),
        ("inflight", inflight.into()),
        ("queue_depth", queue_depth.into()),
        ("active_seqs", active_seqs.into()),
        ("trace_dropped_total", (trace_dropped_total as i64).into()),
        ("shards", shard_arr.into()),
    ])
    .to_string()
}

pub fn err_response(id: i64, msg: &str) -> String {
    err_full(id, msg, None, None, None)
}

/// Structured error reply: free-text `error` plus the optional
/// machine-readable `code`, a `retry_after_ms` backpressure hint
/// (`code: "overloaded"`), and the partial output generated before a
/// deadline or fault ended the request (omitted when empty).
pub fn err_full(
    id: i64,
    msg: &str,
    code: Option<&str>,
    retry_after_ms: Option<u64>,
    partial_tokens: Option<&[i32]>,
) -> String {
    let mut j = Json::from_pairs(vec![
        ("id", id.into()),
        ("ok", false.into()),
        ("error", msg.into()),
    ]);
    if let Some(c) = code {
        j.set("code", c.into());
    }
    if let Some(ms) = retry_after_ms {
        j.set("retry_after_ms", (ms as i64).into());
    }
    if let Some(t) = partial_tokens {
        if !t.is_empty() {
            j.set("text", super::text::detokenize(t).into());
            j.set("tokens", t.iter().map(|&x| x as i64).collect::<Vec<i64>>().into());
            j.set("gen_tokens", t.len().into());
        }
    }
    j.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_generate_text() {
        let r = parse_request(r#"{"op":"generate","id":7,"prompt":"<bos> w1 w2","max_new_tokens":4}"#)
            .unwrap();
        assert_eq!(r.id, 7);
        match r.op {
            Op::Generate { prompt, max_new_tokens, prefix_hint, deadline_ms, trace } => {
                assert_eq!(prompt, vec![0, 17, 18]);
                assert_eq!(max_new_tokens, 4);
                assert!(prefix_hint, "prefix reuse defaults to on");
                assert_eq!(deadline_ms, None, "deadline defaults to unbounded");
                assert!(!trace, "per-request tracing defaults to off");
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parse_generate_deadline() {
        let r = parse_request(
            r#"{"op":"generate","id":6,"prompt_tokens":[1,2],"deadline_ms":500}"#,
        )
        .unwrap();
        match r.op {
            Op::Generate { deadline_ms, .. } => assert_eq!(deadline_ms, Some(500)),
            _ => panic!(),
        }
    }

    #[test]
    fn parse_ping() {
        let r = parse_request(r#"{"op":"ping","id":8}"#).unwrap();
        assert_eq!(r.id, 8);
        assert_eq!(r.op, Op::Ping);
    }

    #[test]
    fn parse_generate_tokens() {
        let r =
            parse_request(r#"{"op":"generate","id":1,"prompt_tokens":[0,5,20,21,2]}"#).unwrap();
        match r.op {
            Op::Generate { prompt, max_new_tokens, .. } => {
                assert_eq!(prompt.len(), 5);
                assert_eq!(max_new_tokens, 16);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parse_generate_prefix_opt_out() {
        let r = parse_request(
            r#"{"op":"generate","id":9,"prompt_tokens":[1,2,3],"prefix_hint":false}"#,
        )
        .unwrap();
        match r.op {
            Op::Generate { prefix_hint, .. } => assert!(!prefix_hint),
            _ => panic!(),
        }
    }

    #[test]
    fn parse_generate_trace_flag() {
        let r = parse_request(r#"{"op":"generate","id":10,"prompt_tokens":[1,2],"trace":true}"#)
            .unwrap();
        match r.op {
            Op::Generate { trace, .. } => assert!(trace),
            _ => panic!(),
        }
    }

    #[test]
    fn parse_trace_op() {
        let r = parse_request(
            r#"{"op":"trace","id":11,"seq":5,"kind":"retry","since":100,"limit":64}"#,
        )
        .unwrap();
        assert_eq!(r.id, 11);
        match r.op {
            Op::Trace(f) => {
                assert_eq!(f.seq, Some(5));
                assert_eq!(f.kind, Some(crate::obs::EventKind::Retry));
                assert_eq!(f.since, Some(100));
                assert_eq!(f.limit, 64);
            }
            _ => panic!(),
        }
        // all filters optional; limit defaults to the bounded window
        match parse_request(r#"{"op":"trace","id":12}"#).unwrap().op {
            Op::Trace(f) => {
                assert_eq!(f, TraceFilter { limit: DEFAULT_TRACE_LIMIT, ..Default::default() });
            }
            _ => panic!(),
        }
        // an unknown kind is a parse error, not a silent empty dump
        assert!(parse_request(r#"{"op":"trace","id":13,"kind":"no-such"}"#).is_err());
    }

    #[test]
    fn parse_metrics_op() {
        let r = parse_request(r#"{"op":"metrics","id":12}"#).unwrap();
        assert_eq!(r.op, Op::Metrics);
    }

    #[test]
    fn trace_and_metrics_responses_round_trip() {
        let events = [
            Event { at: 1, t_us: 10, seq: 5, shard: 0, kind: EventKind::Queued, a: 128, b: 16 },
            Event { at: 2, t_us: 90, seq: 5, shard: 1, kind: EventKind::Placed, a: 0, b: 0 },
        ];
        let s = ok_trace(11, &events, 412, 3);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.bool_of("ok"), Some(true));
        assert_eq!(j.usize_of("watermark"), Some(412));
        assert_eq!(j.usize_of("trace_dropped_total"), Some(3));
        let arr = j.req("events").as_arr().expect("events array");
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].str_of("kind"), Some("queued"));
        assert_eq!(arr[1].usize_of("shard"), Some(1));

        let s = ok_metrics(12, "# TYPE lacache_submitted gauge\nlacache_submitted 3\n");
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.str_of("content_type"), Some("text/plain; version=0.0.4"));
        assert!(j.str_of("metrics").unwrap().contains("lacache_submitted 3"));
    }

    #[test]
    fn generate_reply_attaches_trace_when_requested() {
        let ev =
            [Event { at: 7, t_us: 5, seq: 3, shard: 0, kind: EventKind::Finished, a: 2, b: 0 }];
        let s = ok_generate(3, &[20, 21], 10, 0, 1.5, 2.25, 8.25, Some(&ev));
        let j = Json::parse(&s).unwrap();
        let arr = j.req("trace").as_arr().expect("trace array");
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].str_of("kind"), Some("finished"));
        // and is omitted entirely when not requested
        let s = ok_generate(3, &[20, 21], 10, 0, 1.5, 2.25, 8.25, None);
        assert!(Json::parse(&s).unwrap().get("trace").is_none());
    }

    #[test]
    fn parse_errors() {
        assert!(parse_request("{}").is_err());
        assert!(parse_request(r#"{"op":"generate","id":1}"#).is_err());
        assert!(parse_request(r#"{"op":"generate","id":1,"prompt":"zzz"}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn responses_are_valid_json() {
        let s = ok_generate(3, &[20, 21], 10, 4, 1.5, 2.25, 8.25, None);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.bool_of("ok"), Some(true));
        assert_eq!(j.usize_of("gen_tokens"), Some(2));
        assert_eq!(j.usize_of("prefix_tokens"), Some(4));
        assert_eq!(j.f64_of("itl_ms"), Some(2.25));
        let e = err_response(4, "boom \"quoted\"");
        assert_eq!(Json::parse(&e).unwrap().str_of("error"), Some("boom \"quoted\""));
    }

    #[test]
    fn coded_errors_carry_code_hint_and_partial_output() {
        let s = err_full(7, "overloaded: queue full", Some("overloaded"), Some(50), None);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.bool_of("ok"), Some(false));
        assert_eq!(j.str_of("code"), Some("overloaded"));
        assert_eq!(j.usize_of("retry_after_ms"), Some(50));
        assert!(j.get("tokens").is_none());

        let s = err_full(8, "deadline exceeded", Some("deadline-exceeded"), None, Some(&[20, 21]));
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.str_of("code"), Some("deadline-exceeded"));
        assert_eq!(j.usize_of("gen_tokens"), Some(2));
        assert_eq!(j.get("tokens").and_then(|a| a.as_arr()).map(|a| a.len()), Some(2));

        // empty partial output is omitted, and err_response stays code-free
        let s = err_full(9, "x", Some("fatal"), None, Some(&[]));
        let j = Json::parse(&s).unwrap();
        assert!(j.get("tokens").is_none());
        assert!(Json::parse(&err_response(1, "y")).unwrap().get("code").is_none());
    }

    #[test]
    fn ping_response_shape() {
        use crate::server::batcher::ShardHealth;
        let shards = [
            ShardHealth {
                device: 0,
                degraded: false,
                inflight: 1,
                resident_bytes: 4096,
                residency_hits: 7,
                spills: 2,
            },
            ShardHealth { device: 1, degraded: true, ..Default::default() },
        ];
        let s = ok_ping(8, "0.1.0", 12.5, true, 2, 3, 4, 9, &shards);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.bool_of("ok"), Some(true));
        assert_eq!(j.str_of("version"), Some("0.1.0"));
        assert_eq!(j.f64_of("uptime_s"), Some(12.5));
        assert_eq!(j.bool_of("degraded"), Some(true));
        assert_eq!(j.usize_of("inflight"), Some(2));
        assert_eq!(j.usize_of("queue_depth"), Some(3));
        assert_eq!(j.usize_of("active_seqs"), Some(4));
        assert_eq!(j.usize_of("trace_dropped_total"), Some(9));
        let arr = j.req("shards").as_arr().expect("shards array");
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].usize_of("device"), Some(0));
        assert_eq!(arr[0].bool_of("degraded"), Some(false));
        assert_eq!(arr[0].usize_of("inflight"), Some(1));
        assert_eq!(arr[0].usize_of("resident_bytes"), Some(4096));
        assert_eq!(arr[1].bool_of("degraded"), Some(true));
        // the shard array survives round-tripping even when empty
        let empty = ok_ping(9, "0.1.0", 0.0, false, 0, 0, 0, 0, &[]);
        let j = Json::parse(&empty).unwrap();
        assert_eq!(j.req("shards").as_arr().map(|a| a.len()), Some(0));
    }
}
