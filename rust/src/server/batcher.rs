//! Continuous batcher / prefill-decode scheduler (Orca/vLLM-style
//! iteration-level scheduling, single-executor variant).
//!
//! Sequences move `queued -> prefilling -> decoding -> finished`; each
//! scheduling round admits new work up to `max_active`, advances every
//! prefilling sequence by one window and every decoding sequence by one
//! quantum, interleaving fairly. The backend is abstracted so the scheduler
//! logic is unit-testable without a PJRT runtime.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

/// Execution backend for one sequence (real impl wraps [`crate::engine::Engine`]).
pub trait SeqBackend {
    type Seq;
    fn new_seq(&mut self) -> Result<Self::Seq>;
    /// Ingest a prompt chunk.
    fn prefill_chunk(&mut self, seq: &mut Self::Seq, chunk: &[i32]) -> Result<()>;
    /// Greedy-decode up to `n` tokens.
    fn decode(&mut self, seq: &mut Self::Seq, n: usize) -> Result<Vec<i32>>;
    /// Admission gate beyond the active-count cap: return false to defer
    /// admitting more sequences this round (real backends report paged-KV
    /// arena pressure; queued work stays queued until pages free up).
    /// `active` is the number of already-admitted sequences, so backends can
    /// reserve headroom for sequences that have not allocated pages yet.
    fn can_admit(&self, active: usize) -> bool {
        let _ = active;
        true
    }
}

#[derive(Clone, Debug)]
pub struct Finished {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    pub queue_s: f64,
    pub ttft_s: f64,
    pub total_s: f64,
    pub error: Option<String>,
}

struct Pending {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    t_submit: Instant,
}

struct Active<S> {
    id: u64,
    prompt: Vec<i32>,
    pos: usize,
    generated: Vec<i32>,
    max_new: usize,
    t_submit: Instant,
    t_admit: Instant,
    t_first: Option<Instant>,
    seq: S,
}

pub struct Scheduler<B: SeqBackend> {
    backend: B,
    pub window: usize,
    pub quantum: usize,
    pub max_active: usize,
    pub max_queue: usize,
    queue: VecDeque<Pending>,
    active: Vec<Active<B::Seq>>,
    next_id: u64,
}

impl<B: SeqBackend> Scheduler<B> {
    pub fn new(
        backend: B,
        window: usize,
        quantum: usize,
        max_active: usize,
        max_queue: usize,
    ) -> Self {
        Self {
            backend,
            window,
            quantum,
            max_active,
            max_queue,
            queue: VecDeque::new(),
            active: Vec::new(),
            next_id: 1,
        }
    }

    /// Admission control: Err when the queue is full (backpressure).
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> Result<u64> {
        if self.queue.len() >= self.max_queue {
            anyhow::bail!("queue full ({} pending)", self.queue.len());
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Pending { id, prompt, max_new, t_submit: Instant::now() });
        Ok(id)
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    pub fn depth(&self) -> (usize, usize) {
        (self.queue.len(), self.active.len())
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// One scheduling round. Returns sequences finished this round.
    pub fn step(&mut self) -> Vec<Finished> {
        // 1. admit (bounded by the active cap AND the backend's memory gate)
        while self.active.len() < self.max_active && self.backend.can_admit(self.active.len()) {
            let Some(p) = self.queue.pop_front() else { break };
            match self.backend.new_seq() {
                Ok(seq) => self.active.push(Active {
                    id: p.id,
                    prompt: p.prompt,
                    pos: 0,
                    generated: Vec::new(),
                    max_new: p.max_new,
                    t_submit: p.t_submit,
                    t_admit: Instant::now(),
                    t_first: None,
                    seq,
                }),
                Err(e) => {
                    return vec![finished_err(p.id, p.prompt.len(), p.t_submit, e)];
                }
            }
        }
        // 2. advance every active sequence by one unit of work
        let mut done = Vec::new();
        let window = self.window;
        let quantum = self.quantum;
        let mut i = 0;
        while i < self.active.len() {
            let a = &mut self.active[i];
            let result: Result<bool> = (|| {
                if a.pos < a.prompt.len() {
                    let end = (a.pos + window).min(a.prompt.len());
                    self.backend.prefill_chunk(&mut a.seq, &a.prompt[a.pos..end].to_vec())?;
                    a.pos = end;
                    Ok(false)
                } else {
                    let n = quantum.min(a.max_new - a.generated.len());
                    let toks = self.backend.decode(&mut a.seq, n)?;
                    if a.t_first.is_none() {
                        a.t_first = Some(Instant::now());
                    }
                    a.generated.extend(toks);
                    Ok(a.generated.len() >= a.max_new)
                }
            })();
            match result {
                Ok(true) => {
                    let a = self.active.swap_remove(i);
                    let now = Instant::now();
                    done.push(Finished {
                        id: a.id,
                        tokens: a.generated,
                        prompt_tokens: a.prompt.len(),
                        queue_s: (a.t_admit - a.t_submit).as_secs_f64(),
                        ttft_s: a
                            .t_first
                            .map(|t| (t - a.t_submit).as_secs_f64())
                            .unwrap_or_default(),
                        total_s: (now - a.t_submit).as_secs_f64(),
                        error: None,
                    });
                }
                Ok(false) => i += 1,
                Err(e) => {
                    let a = self.active.swap_remove(i);
                    done.push(finished_err(a.id, a.prompt.len(), a.t_submit, e));
                }
            }
        }
        done
    }
}

fn finished_err(id: u64, prompt_tokens: usize, t_submit: Instant, e: anyhow::Error) -> Finished {
    Finished {
        id,
        tokens: Vec::new(),
        prompt_tokens,
        queue_s: 0.0,
        ttft_s: 0.0,
        total_s: t_submit.elapsed().as_secs_f64(),
        error: Some(format!("{e:#}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mock backend: "generates" token 100+len; fails on prompts containing -1.
    struct Mock {
        prefilled: usize,
        admit: bool,
    }

    struct MockSeq {
        ingested: Vec<i32>,
        emitted: usize,
    }

    impl SeqBackend for Mock {
        type Seq = MockSeq;
        fn new_seq(&mut self) -> Result<MockSeq> {
            Ok(MockSeq { ingested: vec![], emitted: 0 })
        }
        fn can_admit(&self, _active: usize) -> bool {
            self.admit
        }
        fn prefill_chunk(&mut self, seq: &mut MockSeq, chunk: &[i32]) -> Result<()> {
            if chunk.contains(&-1) {
                anyhow::bail!("poison prompt");
            }
            self.prefilled += chunk.len();
            seq.ingested.extend_from_slice(chunk);
            Ok(())
        }
        fn decode(&mut self, seq: &mut MockSeq, n: usize) -> Result<Vec<i32>> {
            let out: Vec<i32> = (0..n).map(|i| 100 + (seq.emitted + i) as i32).collect();
            seq.emitted += n;
            Ok(out)
        }
    }

    fn sched() -> Scheduler<Mock> {
        Scheduler::new(Mock { prefilled: 0, admit: true }, 8, 4, 2, 4)
    }

    #[test]
    fn admission_deferred_while_backend_gates() {
        let mut s = Scheduler::new(Mock { prefilled: 0, admit: false }, 8, 4, 2, 4);
        s.submit(vec![1, 2], 1).unwrap();
        s.step();
        assert_eq!(s.depth(), (1, 0), "admitted despite backend pressure");
        s.backend_mut().admit = true;
        s.step();
        assert_eq!(s.depth().1, 1);
        let mut finished = Vec::new();
        while s.has_work() {
            finished.extend(s.step());
        }
        assert_eq!(finished.len(), 1);
        assert!(finished[0].error.is_none());
    }

    #[test]
    fn single_request_lifecycle() {
        let mut s = sched();
        let id = s.submit((0..20).collect(), 6).unwrap();
        let mut finished = Vec::new();
        let mut rounds = 0;
        while s.has_work() && rounds < 100 {
            finished.extend(s.step());
            rounds += 1;
        }
        assert_eq!(finished.len(), 1);
        let f = &finished[0];
        assert_eq!(f.id, id);
        assert_eq!(f.tokens, vec![100, 101, 102, 103, 104, 105]);
        assert_eq!(f.prompt_tokens, 20);
        assert!(f.error.is_none());
        // 20-token prompt at window 8 = 3 prefill rounds; 6 tokens at
        // quantum 4 = 2 decode rounds
        assert_eq!(rounds, 5);
    }

    #[test]
    fn interleaves_up_to_max_active() {
        let mut s = sched();
        for _ in 0..4 {
            s.submit((0..8).collect(), 4).unwrap();
        }
        let (q, a) = s.depth();
        assert_eq!((q, a), (4, 0));
        s.step();
        assert_eq!(s.depth().1, 2); // max_active respected
        let mut finished = 0;
        for _ in 0..50 {
            finished += s.step().len();
            if finished == 4 {
                break;
            }
        }
        assert_eq!(finished, 4);
    }

    #[test]
    fn admission_control_backpressure() {
        let mut s = sched();
        for _ in 0..4 {
            s.submit(vec![1], 1).unwrap();
        }
        assert!(s.submit(vec![1], 1).is_err(), "queue should be full");
    }

    #[test]
    fn backend_error_fails_only_that_sequence() {
        let mut s = sched();
        s.submit(vec![1, 2, 3], 2).unwrap();
        s.submit(vec![-1], 2).unwrap(); // poison
        let mut oks = 0;
        let mut errs = 0;
        for _ in 0..20 {
            for f in s.step() {
                if f.error.is_some() {
                    errs += 1;
                } else {
                    oks += 1;
                }
            }
            if !s.has_work() {
                break;
            }
        }
        assert_eq!((oks, errs), (1, 1));
    }

    #[test]
    fn timings_populated() {
        let mut s = sched();
        s.submit(vec![1, 2], 1).unwrap();
        let mut out = Vec::new();
        while s.has_work() {
            out.extend(s.step());
        }
        let f = &out[0];
        assert!(f.total_s >= f.ttft_s);
        assert!(f.ttft_s > 0.0);
    }
}
