//! Continuous batcher / prefill-decode scheduler (Orca/vLLM-style
//! iteration-level scheduling, split-phase submit/reap variant).
//!
//! Sequences move `queued -> prefilling -> decoding -> finished`, with a
//! `cancelled` exit from every state. Each scheduling round runs five
//! explicit phases:
//!
//! 1. **reap completions** — drain finished in-flight device calls from the
//!    backend ([`SeqBackend::reap`]); each completion hands the sequence
//!    state back to the scheduler, which applies the result (advance, emit
//!    tokens, finish, or fail) on the reactor thread;
//! 2. **reap queue** — queued requests whose [`CancelToken`] fired are
//!    dropped before they ever allocate anything;
//! 3. **reap cancelled** — active sequences whose token fired and whose
//!    state is on the host are dropped immediately (returning their paged-KV
//!    arena pages); a cancelled sequence with a call still in flight is
//!    dropped at that call's reap instead — nothing ever blocks on it;
//! 4. **admit** — queued requests are admitted FIFO up to `max_active` and
//!    the backend's memory gate; a `new_seq` failure fails only that request;
//!    a `max_new == 0` request finishes here without touching the backend;
//! 5. **submit** — ready sequences are handed one unit of work each (one
//!    prefill window or one decode quantum) up to the backend's
//!    [`SeqBackend::inflight_capacity`]. Synchronous backends (the default
//!    method shims) complete each submit inline, which reduces this phase to
//!    the classic blocking advance in admission order; async backends return
//!    [`Submitted::InFlight`] and the call completes in a later round's reap
//!    phase. Under a saturated capacity, candidates are picked
//!    least-recently-submitted first (ties in admission order), so one long
//!    prefill cannot starve the decode fleet.
//!
//! Ownership is the concurrency story: a submit MOVES the sequence (KV
//! pages, device-resident image and all) into the call, and the scheduler
//! only sees it again in a completion — there is no shared mutable sequence
//! state, so `DeviceTier` accounting stays race-free (see PERF.md "Async
//! overlap").
//!
//! The backend is abstracted so the scheduler logic is unit-testable without
//! a PJRT runtime. TTFT is stamped by the backend at the moment the first
//! token of a quantum materializes ([`Decoded::t_first`]), not when the
//! whole quantum returns. Inter-token latency samples are accumulated per
//! decode completion and drained with [`Scheduler::take_itl`].

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::obs::{self, EventKind};
use crate::runtime::classify;

/// How long a round blocks for a completion when calls are in flight but
/// nothing else can progress (prevents a busy-spin reactor loop).
const REAP_WAIT: Duration = Duration::from_millis(2);

/// Retry budget for failed device calls. A call that fails with a retryable
/// [`crate::runtime::CallErrorKind`] (transient / device-lost) is re-submitted
/// after `backoff * 2^(attempt-1)` — non-blocking: the sequence just sits out
/// submit rounds until its backoff elapses, so the rest of the fleet keeps
/// decoding. The budget is per-call: a successful settle resets the count.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    pub max_retries: u32,
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_retries: 4, backoff: Duration::from_millis(5) }
    }
}

/// Fault-handling counters (surfaced through `op:stats` and the chaos bench).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultStats {
    /// Failed calls re-submitted after rebuild-from-arena recovery.
    pub retries: u64,
    /// Sequences finished with a structured error (retry budget exhausted,
    /// non-retryable failure, or a worker panic that dropped their state).
    pub quarantined: u64,
    /// Sequences finished early (partial output) because their
    /// `deadline_ms` passed, plus queued requests that expired unadmitted.
    pub deadline_exceeded: u64,
    /// Requests rejected at submit because the queue was full.
    pub overloaded: u64,
}

/// Structured queue-full rejection: callers (the reactor) downcast this out
/// of the anyhow error to emit a protocol `overloaded` code with a
/// `retry_after_ms` hint instead of free-text.
#[derive(Clone, Copy, Debug)]
pub struct Overloaded {
    pub queued: usize,
    pub retry_after_ms: u64,
}

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "overloaded: queue full ({} pending); retry after {} ms",
            self.queued, self.retry_after_ms
        )
    }
}

impl std::error::Error for Overloaded {}

/// Shared cancellation flag connecting a connection handler to every
/// request it has in flight: the handler fires it when the client
/// disconnects, and the scheduler drops the sequence (releasing its arena
/// pages) before spending another quantum on it.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// One decode quantum's output. `t_first` is the instant the FIRST token of
/// the quantum became available (after the first program call inside the
/// quantum); `None` means the backend has no finer signal and the scheduler
/// stamps on receipt.
pub struct Decoded {
    pub tokens: Vec<i32>,
    pub t_first: Option<Instant>,
}

/// Identifies an in-flight call across submit and reap (the scheduler uses
/// the sequence id, which is unique per request).
pub type Ticket = u64;

/// Per-shard health snapshot surfaced through `op:ping` and `op:stats`
/// (see [`SeqBackend::shard_health`]): one entry per device shard, in shard
/// order. `inflight` counts the calls currently on that shard's executor
/// lane; the rest mirrors the runtime's per-shard gauges.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardHealth {
    /// PJRT device ordinal backing the shard.
    pub device: usize,
    /// Sticky per-shard degraded flag (this shard bypasses residency; the
    /// rest of the fleet keeps serving).
    pub degraded: bool,
    /// Device calls in flight on this shard's lane.
    pub inflight: usize,
    /// Bytes resident in this shard's device tier.
    pub resident_bytes: u64,
    /// Calls this shard served from a resident image.
    pub residency_hits: u64,
    /// Spills from this shard's device tier.
    pub spills: u64,
}

/// What a completed device call produced.
pub enum CallOut {
    /// A prefill chunk was ingested (the scheduler advanced `pos` at
    /// submit time; nothing else to carry back).
    Prefill,
    /// A decode quantum's tokens.
    Decode(Decoded),
}

/// A drained completion: the ticket it was submitted under, the sequence
/// state (ownership returns to the scheduler), and the call's outcome.
/// `seq: None` means the call's worker PANICKED — the sequence state was
/// dropped during unwind (its arena pages returned then), so there is
/// nothing to retry with; the scheduler quarantines the sequence with the
/// structured error in `result`.
pub struct CallDone<S> {
    pub ticket: Ticket,
    pub seq: Option<S>,
    pub result: Result<CallOut>,
}

/// Outcome of a non-blocking submit.
pub enum Submitted<S> {
    /// The backend ran the call inline (synchronous shim, or a failure
    /// before dispatch): the completion comes straight back.
    Done(CallDone<S>),
    /// The call is in flight; the sequence returns via [`SeqBackend::reap`].
    InFlight,
}

/// Execution backend for one sequence (real impl wraps [`crate::engine::Engine`]).
///
/// Backends implement the synchronous surface (`prefill_chunk` / `decode`);
/// the split-phase surface (`submit_*` / `reap`) has default shims that run
/// the synchronous call inline, so a plain backend IS the `capacity = 1`
/// scheduler. Async backends override the split-phase methods to dispatch
/// onto a worker pool ([`crate::runtime::CallExecutor`]) and raise
/// [`Self::inflight_capacity`].
pub trait SeqBackend {
    type Seq;
    fn new_seq(&mut self) -> Result<Self::Seq>;
    /// Placement plus cross-request prefix reuse, called once right after
    /// [`Self::new_seq`] for EVERY admission. Sharded backends assign the
    /// sequence's home shard here — a load/locality decision that must
    /// happen even when reuse is declined — and, when `allow` is true
    /// (protocol `prefix_hint`), may install an already-computed KV prefix
    /// into the fresh sequence and return how many leading prompt tokens it
    /// covers; the scheduler then starts the sequence `prefilling` at that
    /// position, skipping their device-side prefill entirely. `allow ==
    /// false` MUST return 0 (the request prefills cold) but still places
    /// the sequence. 0 (the default) means a cold start.
    fn adopt_prefix(&mut self, seq: &mut Self::Seq, prompt: &[i32], allow: bool) -> usize {
        let _ = (seq, prompt, allow);
        0
    }
    /// Ingest a prompt chunk.
    fn prefill_chunk(&mut self, seq: &mut Self::Seq, chunk: &[i32]) -> Result<()>;
    /// Greedy-decode up to `n` tokens.
    fn decode(&mut self, seq: &mut Self::Seq, n: usize) -> Result<Decoded>;
    /// Admission gate beyond the active-count cap: return false to defer
    /// admitting more sequences this round (real backends report paged-KV
    /// arena pressure plus the runtime's staging tiers — device-resident
    /// K/V images and host scratch images; queued work stays queued until
    /// bytes free up). Called in every round's admit phase while the active
    /// set has headroom — even with an empty queue — so backends use it to
    /// sweep staging state of sequences dropped last round (cancellation
    /// teardown; a saturated active set is covered by the sweeps inside the
    /// runtime calls the submit phase makes).
    /// `active` is the number of already-admitted sequences, so backends can
    /// reserve headroom for sequences that have not allocated pages yet.
    fn can_admit(&self, active: usize) -> bool {
        let _ = active;
        true
    }
    /// Device calls this backend can have in flight at once. The default 1
    /// is the synchronous path: every submit completes inline and
    /// [`Self::reap`] never has anything to drain.
    fn inflight_capacity(&self) -> usize {
        1
    }
    /// Crash-consistent recovery hook, called before a failed call is
    /// retried: drop any device/scratch residency the sequence holds so the
    /// retry rebuilds its dense image from the host arena pages — the
    /// durable source of truth (a failed call never mutated them; see
    /// PERF.md "Failure handling & recovery"). `pos` is the rolled-back
    /// prompt position the retry will resume from. Default: nothing to do
    /// (host-only backends are trivially consistent).
    fn recover(&mut self, seq: &mut Self::Seq, pos: usize) {
        let _ = (seq, pos);
    }
    /// Sticky degraded-mode flag (real backends surface the runtime's
    /// device-tier state; see `op:ping`). With device shards this is
    /// FLEET-level: true only when every shard is degraded — a single lost
    /// device degrades its shard ([`Self::shard_health`]) while the rest
    /// keep serving. Default: never degraded.
    fn degraded(&self) -> bool {
        false
    }
    /// Per-shard health (one entry per device shard, shard order), exported
    /// through `op:ping` / `op:stats`. Default: empty — single-tier mock
    /// backends have no shard topology to report.
    fn shard_health(&self) -> Vec<ShardHealth> {
        Vec::new()
    }
    /// The shard [`Self::adopt_prefix`] placed this sequence on — stamped
    /// into the sequence's flight-recorder events (`placed`, prefill/decode
    /// submits) so a trace shows which device served it. Default 0
    /// (single-shard backends).
    fn seq_shard(&self, seq: &Self::Seq) -> usize {
        let _ = seq;
        0
    }

    /// Dense code of the placement rule that chose the sequence's shard
    /// ([`crate::runtime::placement::PlacementKind::code`]) — the `b`
    /// payload of the flight recorder's `placed` event. Default 0
    /// (backends without a placement policy).
    fn placement_code(&self, seq: &Self::Seq) -> i64 {
        let _ = seq;
        0
    }
    /// Non-blocking prefill: ownership of `seq` moves into the call and
    /// comes back through [`Self::reap`] (or immediately, via
    /// [`Submitted::Done`]). The default shim runs [`Self::prefill_chunk`]
    /// inline.
    fn submit_prefill(
        &mut self,
        ticket: Ticket,
        mut seq: Self::Seq,
        chunk: &[i32],
    ) -> Submitted<Self::Seq> {
        let result = self.prefill_chunk(&mut seq, chunk).map(|()| CallOut::Prefill);
        Submitted::Done(CallDone { ticket, seq: Some(seq), result })
    }
    /// Non-blocking decode of up to `n` tokens; same ownership contract as
    /// [`Self::submit_prefill`].
    fn submit_decode(
        &mut self,
        ticket: Ticket,
        mut seq: Self::Seq,
        n: usize,
    ) -> Submitted<Self::Seq> {
        let result = self.decode(&mut seq, n).map(CallOut::Decode);
        Submitted::Done(CallDone { ticket, seq: Some(seq), result })
    }
    /// Drain completed in-flight calls, blocking up to `wait` for the first
    /// one when given. Synchronous backends never have any.
    fn reap(&mut self, wait: Option<Duration>) -> Vec<CallDone<Self::Seq>> {
        let _ = wait;
        Vec::new()
    }
}

#[derive(Clone, Debug)]
pub struct Finished {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    /// Leading prompt tokens served from an adopted cross-request prefix
    /// (their prefill never ran; 0 for cold starts).
    pub prefix_tokens: usize,
    pub queue_s: f64,
    pub ttft_s: f64,
    pub total_s: f64,
    pub error: Option<String>,
    /// Structured error code accompanying `error` (`"transient"`,
    /// `"device-lost"`, `"oom"`, `"fatal"`, `"deadline-exceeded"`) — the
    /// taxonomy clients branch on; `None` for clean completions.
    pub code: Option<String>,
    /// True when the sequence exited because its [`CancelToken`] fired (the
    /// client is gone; no response should be written).
    pub cancelled: bool,
}

struct Pending {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    t_submit: Instant,
    cancel: CancelToken,
    /// False when the request opted out of cross-request prefix reuse
    /// (protocol `prefix_hint: false`).
    allow_prefix: bool,
    /// Absolute wall-clock budget (protocol `deadline_ms`, stamped at
    /// submit): past this instant the request finishes with whatever it has.
    deadline: Option<Instant>,
}

/// Where an active sequence's state currently lives.
enum Slot<S> {
    /// On the host, owned by the scheduler: eligible for submit (and for
    /// immediate cancellation teardown).
    Ready(S),
    /// Moved into an in-flight device call; comes back at reap.
    InFlight,
}

struct Active<S> {
    id: u64,
    prompt: Vec<i32>,
    pos: usize,
    /// Prompt tokens covered by an adopted prefix at admission.
    prefix_tokens: usize,
    generated: Vec<i32>,
    max_new: usize,
    t_submit: Instant,
    t_admit: Instant,
    t_first: Option<Instant>,
    /// When the previous decode quantum's tokens were observed (drives the
    /// inter-token latency samples).
    t_last: Option<Instant>,
    /// Round this sequence last got a unit of work (least-recently-submitted
    /// fairness under a saturated in-flight capacity).
    last_step: u64,
    cancel: CancelToken,
    /// Failed attempts at the CURRENT unit of work (reset on success).
    attempts: u32,
    /// Retry backoff gate: the submit phase skips this sequence until the
    /// instant passes (non-blocking backoff).
    not_before: Option<Instant>,
    /// `pos` as of the last submit — the rollback point for retry (pos
    /// advances at submit time, but a failed call ingested nothing).
    submit_base: usize,
    /// Request deadline (see [`Pending::deadline`]); enforced at scheduler
    /// phase boundaries, with partial output.
    deadline: Option<Instant>,
    seq: Slot<S>,
}

impl<S> Active<S> {
    /// Consume into a `cancelled` record; dropping the slot here (when the
    /// state is `Ready`) is what returns the sequence's arena pages.
    fn into_cancelled(self) -> Finished {
        let now = Instant::now();
        obs::record(EventKind::Cancelled, self.id, 0, self.generated.len() as i64, 0);
        Finished {
            id: self.id,
            tokens: self.generated,
            prompt_tokens: self.prompt.len(),
            prefix_tokens: self.prefix_tokens,
            queue_s: (self.t_admit - self.t_submit).as_secs_f64(),
            ttft_s: self.t_first.map(|t| (t - self.t_submit).as_secs_f64()).unwrap_or_default(),
            total_s: (now - self.t_submit).as_secs_f64(),
            error: None,
            code: None,
            cancelled: true,
        }
    }

    /// Consume into an ok-completion record.
    fn into_finished(self) -> Finished {
        let now = Instant::now();
        Finished {
            id: self.id,
            tokens: self.generated,
            prompt_tokens: self.prompt.len(),
            prefix_tokens: self.prefix_tokens,
            queue_s: (self.t_admit - self.t_submit).as_secs_f64(),
            ttft_s: self.t_first.map(|t| (t - self.t_submit).as_secs_f64()).unwrap_or_default(),
            total_s: (now - self.t_submit).as_secs_f64(),
            error: None,
            code: None,
            cancelled: false,
        }
    }

    /// Consume into a structured-error record, KEEPING partial output: the
    /// tokens generated before the failure (or deadline) already cost device
    /// time and are often still useful to the client.
    fn into_failed(self, error: String, code: String) -> Finished {
        let now = Instant::now();
        Finished {
            id: self.id,
            tokens: self.generated,
            prompt_tokens: self.prompt.len(),
            prefix_tokens: self.prefix_tokens,
            queue_s: (self.t_admit - self.t_submit).as_secs_f64(),
            ttft_s: self.t_first.map(|t| (t - self.t_submit).as_secs_f64()).unwrap_or_default(),
            total_s: (now - self.t_submit).as_secs_f64(),
            error: Some(error),
            code: Some(code),
            cancelled: false,
        }
    }
}

pub struct Scheduler<B: SeqBackend> {
    backend: B,
    pub window: usize,
    pub quantum: usize,
    pub max_active: usize,
    pub max_queue: usize,
    /// Retry budget + backoff for failed device calls.
    pub retry: RetryPolicy,
    /// How far past its deadline an IN-FLIGHT call may run before the
    /// watchdog abandons the sequence (finishes it with partial output and
    /// lets the eventual completion drop at reap). Generous by default: the
    /// watchdog is for stuck calls, not ordinary overrun.
    pub watchdog_grace: Duration,
    queue: VecDeque<Pending>,
    active: Vec<Active<B::Seq>>,
    next_id: u64,
    /// Submit-phase round counter (fairness clock for `Active::last_step`).
    round: u64,
    /// Calls currently in flight at the backend.
    inflight: usize,
    /// Inter-token latency samples (seconds) accumulated by decode
    /// completions; drained by [`Self::take_itl`].
    itl_s: Vec<f64>,
    faults: FaultStats,
}

impl<B: SeqBackend> Scheduler<B> {
    pub fn new(
        backend: B,
        window: usize,
        quantum: usize,
        max_active: usize,
        max_queue: usize,
    ) -> Self {
        Self {
            backend,
            window,
            quantum,
            max_active,
            max_queue,
            retry: RetryPolicy::default(),
            watchdog_grace: Duration::from_secs(1),
            queue: VecDeque::new(),
            active: Vec::new(),
            next_id: 1,
            round: 0,
            inflight: 0,
            itl_s: Vec::new(),
            faults: FaultStats::default(),
        }
    }

    /// Admission control: Err when the queue is full (backpressure).
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize, cancel: CancelToken) -> Result<u64> {
        self.submit_req(prompt, max_new, cancel, true, None)
    }

    /// [`Self::submit`] with an explicit cross-request prefix-reuse flag
    /// (`false` = the protocol's `prefix_hint: false` opt-out: the sequence
    /// always prefills cold).
    pub fn submit_opt(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        cancel: CancelToken,
        allow_prefix: bool,
    ) -> Result<u64> {
        self.submit_req(prompt, max_new, cancel, allow_prefix, None)
    }

    /// Full-surface submit: prefix-reuse flag plus an optional relative
    /// deadline (protocol `deadline_ms`). A queue-full rejection is the
    /// structured [`Overloaded`] error with a `retry_after_ms` hint scaled
    /// to the backlog.
    pub fn submit_req(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        cancel: CancelToken,
        allow_prefix: bool,
        deadline: Option<Duration>,
    ) -> Result<u64> {
        if self.queue.len() >= self.max_queue {
            self.faults.overloaded += 1;
            let hint = (self.queue.len() as u64 * 10).clamp(50, 2000);
            return Err(anyhow::Error::new(Overloaded {
                queued: self.queue.len(),
                retry_after_ms: hint,
            }));
        }
        let id = self.next_id;
        self.next_id += 1;
        let now = Instant::now();
        obs::record(EventKind::Queued, id, 0, prompt.len() as i64, max_new as i64);
        self.queue.push_back(Pending {
            id,
            prompt,
            max_new,
            t_submit: now,
            cancel,
            allow_prefix,
            deadline: deadline.map(|d| now + d),
        });
        Ok(id)
    }

    /// Fault-handling counters (retries, quarantines, deadline exits,
    /// overload rejections).
    pub fn fault_stats(&self) -> FaultStats {
        self.faults
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    pub fn depth(&self) -> (usize, usize) {
        (self.queue.len(), self.active.len())
    }

    /// Device calls currently in flight (0 for synchronous backends).
    pub fn inflight(&self) -> usize {
        self.inflight
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Drain the inter-token latency samples (seconds per token) recorded
    /// since the last call.
    pub fn take_itl(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.itl_s)
    }

    /// One scheduling round (reap completions -> reap queue -> reap
    /// cancelled -> reap deadlines -> admit -> submit). Returns sequences
    /// that exited this round: completed, errored, expired, or cancelled.
    /// When calls are in flight and the round could make no other progress,
    /// blocks briefly for the next completion instead of spinning; with only
    /// retry backoffs pending, sleeps toward the earliest one.
    pub fn step(&mut self) -> Vec<Finished> {
        let mut done = Vec::new();
        let reaped = self.reap_completions(None, &mut done);
        self.reap_queue(&mut done);
        self.reap_cancelled(&mut done);
        self.reap_deadlines(&mut done);
        self.admit(&mut done);
        let submitted = self.submit_units(&mut done);
        if reaped == 0 && submitted == 0 && done.is_empty() {
            if self.inflight > 0 {
                self.reap_completions(Some(REAP_WAIT), &mut done);
            } else if let Some(t) = self.active.iter().filter_map(|a| a.not_before).min() {
                // nothing runnable until the earliest backoff elapses
                let now = Instant::now();
                if t > now {
                    std::thread::sleep((t - now).min(REAP_WAIT));
                }
            }
        }
        // one choke point records EVERY scheduler exit (clean, errored,
        // cancelled, deadline, never-admitted), so a trace always ends in a
        // `finished` event
        for f in &done {
            let outcome = if f.cancelled {
                2
            } else {
                i64::from(f.error.is_some())
            };
            obs::record(EventKind::Finished, f.id, 0, f.tokens.len() as i64, outcome);
        }
        done
    }

    /// Phase 1: drain in-flight completions and apply them. A completion
    /// whose sequence was cancelled while the call ran is dropped here —
    /// this is "cancellation at reap": the sequence state (arena pages,
    /// device residency) is released the moment the scheduler owns it again.
    /// A completion with `seq: None` is a worker panic: the state died in
    /// the unwind, so the sequence quarantines with its structured error
    /// while everyone else keeps going.
    fn reap_completions(&mut self, wait: Option<Duration>, done: &mut Vec<Finished>) -> usize {
        if self.inflight == 0 {
            return 0;
        }
        let mut reaped = 0;
        for c in self.backend.reap(wait) {
            reaped += 1;
            self.inflight = self.inflight.saturating_sub(1);
            obs::record(EventKind::ReapCall, c.ticket, 0, i64::from(c.result.is_err()), 0);
            let Some(i) = self.active.iter().position(|a| a.id == c.ticket) else {
                continue; // sequence already gone; drop the returned state
            };
            if self.active[i].cancel.is_cancelled() {
                drop(c.seq); // releases the sequence's pages/residency
                done.push(self.active.remove(i).into_cancelled());
                continue;
            }
            match c.seq {
                Some(seq) => self.settle(i, seq, c.result, done),
                None => {
                    self.faults.quarantined += 1;
                    obs::record(
                        EventKind::Quarantine,
                        c.ticket,
                        0,
                        self.active[i].attempts as i64,
                        0,
                    );
                    let e = c
                        .result
                        .err()
                        .unwrap_or_else(|| anyhow::anyhow!("worker panic (no detail)"));
                    let code = classify(&e).code().to_string();
                    done.push(self.active.remove(i).into_failed(format!("{e:#}"), code));
                }
            }
        }
        reaped
    }

    /// Phase 2: drop queued requests whose client disconnected — or whose
    /// deadline expired — before they were ever admitted.
    fn reap_queue(&mut self, done: &mut Vec<Finished>) {
        let now = Instant::now();
        let expired = |p: &Pending| p.deadline.is_some_and(|d| now >= d);
        // common case (no cancellations, no expiries) stays allocation- and
        // move-free
        if !self.queue.iter().any(|p| p.cancel.is_cancelled() || expired(p)) {
            return;
        }
        let mut kept = VecDeque::with_capacity(self.queue.len());
        for p in self.queue.drain(..) {
            if p.cancel.is_cancelled() {
                done.push(Finished {
                    id: p.id,
                    tokens: Vec::new(),
                    prompt_tokens: p.prompt.len(),
                    prefix_tokens: 0,
                    queue_s: (now - p.t_submit).as_secs_f64(),
                    ttft_s: 0.0,
                    total_s: (now - p.t_submit).as_secs_f64(),
                    error: None,
                    code: None,
                    cancelled: true,
                });
            } else if expired(&p) {
                self.faults.deadline_exceeded += 1;
                obs::record(EventKind::Deadline, p.id, 0, 0, 0);
                done.push(Finished {
                    id: p.id,
                    tokens: Vec::new(),
                    prompt_tokens: p.prompt.len(),
                    prefix_tokens: 0,
                    queue_s: (now - p.t_submit).as_secs_f64(),
                    ttft_s: 0.0,
                    total_s: (now - p.t_submit).as_secs_f64(),
                    error: Some("deadline exceeded before admission".to_string()),
                    code: Some("deadline-exceeded".to_string()),
                    cancelled: false,
                });
            } else {
                kept.push_back(p);
            }
        }
        self.queue = kept;
    }

    /// Phase 3: drop cancelled active sequences whose state is on the host
    /// (ready slots) — their pages return before this round's admission
    /// counts bytes. In-flight cancellations are handled at reap.
    fn reap_cancelled(&mut self, done: &mut Vec<Finished>) {
        let mut i = 0;
        while i < self.active.len() {
            if matches!(self.active[i].seq, Slot::Ready(_)) && self.active[i].cancel.is_cancelled()
            {
                done.push(self.active.remove(i).into_cancelled());
            } else {
                i += 1;
            }
        }
    }

    /// Phase 3b: enforce request deadlines at the phase boundary. A READY
    /// sequence past its deadline finishes now with partial output. An
    /// IN-FLIGHT sequence gets `watchdog_grace` beyond the deadline for its
    /// call to land; past that the watchdog abandons it — the sequence
    /// finishes (partial output, structured code) and the stuck call's
    /// eventual completion is dropped at reap, so one wedged device call
    /// can never pin a client connection open forever.
    fn reap_deadlines(&mut self, done: &mut Vec<Finished>) {
        let now = Instant::now();
        let mut i = 0;
        while i < self.active.len() {
            let a = &self.active[i];
            let Some(d) = a.deadline else {
                i += 1;
                continue;
            };
            let expired = match a.seq {
                Slot::Ready(_) => now >= d,
                Slot::InFlight => now >= d + self.watchdog_grace,
            };
            if expired {
                self.faults.deadline_exceeded += 1;
                obs::record(
                    EventKind::Deadline,
                    self.active[i].id,
                    0,
                    self.active[i].generated.len() as i64,
                    0,
                );
                let msg = match self.active[i].seq {
                    Slot::Ready(_) => "deadline exceeded".to_string(),
                    Slot::InFlight => {
                        "deadline exceeded (in-flight call abandoned by watchdog)".to_string()
                    }
                };
                done.push(
                    self.active.remove(i).into_failed(msg, "deadline-exceeded".to_string()),
                );
            } else {
                i += 1;
            }
        }
    }

    /// Phase 4: FIFO admission up to the active cap and the backend's memory
    /// gate. A `new_seq` failure fails only that request: the remaining
    /// queue still gets its admission chance and the submit phase still
    /// runs this round. A `max_new == 0` request is the degenerate
    /// zero-token generate: it finishes right here, without a sequence or
    /// any device call.
    fn admit(&mut self, done: &mut Vec<Finished>) {
        while self.active.len() < self.max_active && self.backend.can_admit(self.active.len()) {
            let Some(p) = self.queue.pop_front() else { break };
            if p.max_new == 0 {
                let now = Instant::now();
                done.push(Finished {
                    id: p.id,
                    tokens: Vec::new(),
                    prompt_tokens: p.prompt.len(),
                    prefix_tokens: 0,
                    queue_s: (now - p.t_submit).as_secs_f64(),
                    ttft_s: 0.0,
                    total_s: (now - p.t_submit).as_secs_f64(),
                    error: None,
                    code: None,
                    cancelled: false,
                });
                continue;
            }
            match self.backend.new_seq() {
                Ok(mut seq) => {
                    // placement + cross-request prefix reuse: every
                    // admission is placed on a shard; with reuse allowed,
                    // prefilling starts past the span the backend served
                    // from its prefix cache
                    let matched = self
                        .backend
                        .adopt_prefix(&mut seq, &p.prompt, p.allow_prefix)
                        .min(p.prompt.len());
                    let shard = self.backend.seq_shard(&seq);
                    obs::record(
                        EventKind::Admitted,
                        p.id,
                        shard,
                        (p.prompt.len() - matched) as i64,
                        matched as i64,
                    );
                    obs::record(
                        EventKind::Placed,
                        p.id,
                        shard,
                        matched as i64,
                        self.backend.placement_code(&seq),
                    );
                    self.active.push(Active {
                        id: p.id,
                        prompt: p.prompt,
                        pos: matched,
                        prefix_tokens: matched,
                        generated: Vec::new(),
                        max_new: p.max_new,
                        t_submit: p.t_submit,
                        t_admit: Instant::now(),
                        t_first: None,
                        t_last: None,
                        last_step: self.round,
                        cancel: p.cancel,
                        attempts: 0,
                        not_before: None,
                        submit_base: matched,
                        deadline: p.deadline,
                        seq: Slot::Ready(seq),
                    })
                }
                Err(e) => {
                    done.push(finished_err(p.id, p.prompt.len(), 0, p.t_submit, None, None, e));
                }
            }
        }
    }

    /// Phase 5: hand out units of work. Each ready sequence gets at most one
    /// submit per round; candidates are picked least-recently-submitted
    /// first with ties in admission order, so under a saturated capacity the
    /// fleet round-robins — and with the synchronous shims (capacity 1,
    /// inline completion) this is exactly the old blocking advance in
    /// admission order. Returns the number of units submitted.
    fn submit_units(&mut self, done: &mut Vec<Finished>) -> usize {
        self.round += 1;
        let capacity = self.backend.inflight_capacity().max(1);
        let window = self.window;
        let quantum = self.quantum;
        let now = Instant::now();
        let mut submitted = 0;
        loop {
            if self.inflight >= capacity {
                break;
            }
            let Some(i) = self
                .active
                .iter()
                .enumerate()
                .filter(|(_, a)| {
                    matches!(a.seq, Slot::Ready(_))
                        && a.last_step < self.round
                        // retry backoff: sit out rounds, never block them
                        && a.not_before.map_or(true, |t| t <= now)
                })
                .min_by_key(|&(i, a)| (a.last_step, i))
                .map(|(i, _)| i)
            else {
                break;
            };
            // drop between quanta: the seq (and its KvCache pages) is freed
            // before any more device time is spent on it
            if self.active[i].cancel.is_cancelled() {
                done.push(self.active.remove(i).into_cancelled());
                continue;
            }
            // nothing left to prefill or decode (max_new == generated):
            // finish without issuing a zero-step device call
            if self.active[i].pos >= self.active[i].prompt.len()
                && self.active[i].generated.len() >= self.active[i].max_new
            {
                done.push(self.active.remove(i).into_finished());
                continue;
            }
            self.active[i].last_step = self.round;
            submitted += 1;
            let sub = {
                let Self { backend, active, .. } = self;
                let a = &mut active[i];
                let ticket = a.id;
                a.not_before = None;
                // the retry rollback point: a failed call ingested nothing,
                // so resuming from here re-submits the same unit of work
                a.submit_base = a.pos;
                let Slot::Ready(seq) = std::mem::replace(&mut a.seq, Slot::InFlight) else {
                    unreachable!("submit candidates hold a ready slot");
                };
                let shard = backend.seq_shard(&seq);
                if a.pos < a.prompt.len() {
                    let start = a.pos;
                    let end = (a.pos + window).min(a.prompt.len());
                    // pos advances at submit: on failure settle rolls it
                    // back to submit_base, and nothing reads pos in flight
                    a.pos = end;
                    obs::record(
                        EventKind::PrefillWindow,
                        ticket,
                        shard,
                        start as i64,
                        (end - start) as i64,
                    );
                    obs::record(EventKind::SubmitCall, ticket, shard, 0, (end - start) as i64);
                    backend.submit_prefill(ticket, seq, &a.prompt[start..end])
                } else {
                    let n = quantum.min(a.max_new - a.generated.len());
                    obs::record(EventKind::SubmitCall, ticket, shard, 1, n as i64);
                    backend.submit_decode(ticket, seq, n)
                }
            };
            match sub {
                Submitted::Done(cd) => match cd.seq {
                    Some(seq) => self.settle(i, seq, cd.result, done),
                    None => {
                        // an inline shim panicked through catch_unwind-less
                        // code paths cannot happen (shims run in this
                        // thread); a backend may still hand back seq-less
                        // failures — quarantine them like reap does
                        self.faults.quarantined += 1;
                        obs::record(
                            EventKind::Quarantine,
                            self.active[i].id,
                            0,
                            self.active[i].attempts as i64,
                            0,
                        );
                        let e = cd
                            .result
                            .err()
                            .unwrap_or_else(|| anyhow::anyhow!("call lost its sequence"));
                        let code = classify(&e).code().to_string();
                        done.push(self.active.remove(i).into_failed(format!("{e:#}"), code));
                    }
                },
                Submitted::InFlight => self.inflight += 1,
            }
        }
        submitted
    }

    /// Apply a call's outcome to the active sequence at `i`: store the state
    /// back (ready for the next round), finish, retry, or quarantine. Decode
    /// completions stamp TTFT and record inter-token latency samples.
    ///
    /// The error arm is the crash-consistent recovery path: a RETRYABLE
    /// failure (transient / device-lost) with budget left rolls `pos` back
    /// to the submit point, invalidates the sequence's device/scratch
    /// residency ([`SeqBackend::recover`]) so the retry rebuilds its dense
    /// image from the host arena pages, and re-queues the sequence behind an
    /// exponential backoff gate. Budget exhaustion or a non-retryable error
    /// quarantines just this sequence — the round (and every other
    /// sequence) proceeds.
    fn settle(&mut self, i: usize, seq: B::Seq, result: Result<CallOut>, done: &mut Vec<Finished>) {
        match result {
            Ok(CallOut::Prefill) => {
                let a = &mut self.active[i];
                a.attempts = 0;
                a.not_before = None;
                a.seq = Slot::Ready(seq);
            }
            Ok(CallOut::Decode(d)) => {
                let now = Instant::now();
                let finished = {
                    let Self { active, itl_s, .. } = self;
                    let a = &mut active[i];
                    a.attempts = 0;
                    a.not_before = None;
                    if a.t_first.is_none() {
                        let tf = d.t_first.unwrap_or(now);
                        a.t_first = Some(tf);
                        obs::record(
                            EventKind::FirstToken,
                            a.id,
                            0,
                            tf.saturating_duration_since(a.t_submit).as_micros() as i64,
                            0,
                        );
                    }
                    if let Some(prev) = a.t_last {
                        if !d.tokens.is_empty() {
                            let per = (now - prev).as_secs_f64() / d.tokens.len() as f64;
                            itl_s.resize(itl_s.len() + d.tokens.len(), per);
                        }
                    }
                    a.t_last = Some(now);
                    a.generated.extend(d.tokens);
                    a.generated.len() >= a.max_new
                };
                if finished {
                    // `seq` drops at the end of this call: pages return now
                    done.push(self.active.remove(i).into_finished());
                } else {
                    self.active[i].seq = Slot::Ready(seq);
                }
            }
            Err(e) => {
                let kind = classify(&e);
                if kind.retryable() && self.active[i].attempts < self.retry.max_retries {
                    let mut seq = seq;
                    let a = &mut self.active[i];
                    a.attempts += 1;
                    self.faults.retries += 1;
                    // the failed call mutated nothing durable (append-after-
                    // success invariant): resume the same unit of work from
                    // the arena pages
                    a.pos = a.submit_base;
                    let pos = a.pos;
                    let shift = (a.attempts - 1).min(10);
                    let backoff = self.retry.backoff.saturating_mul(1u32 << shift);
                    a.not_before = Some(Instant::now() + backoff);
                    obs::record(
                        EventKind::Retry,
                        a.id,
                        0,
                        a.attempts as i64,
                        backoff.as_millis() as i64,
                    );
                    self.backend.recover(&mut seq, pos);
                    self.active[i].seq = Slot::Ready(seq);
                } else {
                    self.faults.quarantined += 1;
                    obs::record(
                        EventKind::Quarantine,
                        self.active[i].id,
                        0,
                        self.active[i].attempts as i64,
                        0,
                    );
                    let a = self.active.remove(i);
                    let attempts = a.attempts;
                    let mut msg = format!("{e:#}");
                    if attempts > 0 {
                        msg = format!("{msg} (after {attempts} retries)");
                    }
                    done.push(a.into_failed(msg, kind.code().to_string()));
                }
            }
        }
    }
}

/// Error exit with REAL timings: `queue_s` is the true submit->admit wait
/// (or the full submit->failure wait when the request never got admitted),
/// and `ttft_s` survives if a first token had already been emitted.
fn finished_err(
    id: u64,
    prompt_tokens: usize,
    prefix_tokens: usize,
    t_submit: Instant,
    t_admit: Option<Instant>,
    t_first: Option<Instant>,
    e: anyhow::Error,
) -> Finished {
    let now = Instant::now();
    Finished {
        id,
        tokens: Vec::new(),
        prompt_tokens,
        prefix_tokens,
        queue_s: (t_admit.unwrap_or(now) - t_submit).as_secs_f64(),
        ttft_s: t_first.map(|t| (t - t_submit).as_secs_f64()).unwrap_or_default(),
        total_s: (now - t_submit).as_secs_f64(),
        code: Some(classify(&e).code().to_string()),
        error: Some(format!("{e:#}")),
        cancelled: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::runtime::{CallExecutor, KvArena, KvCache};
    use crate::util::prop::PropRunner;
    use std::collections::BTreeMap;
    use std::sync::Mutex;

    /// Mock backend: "generates" token 100+len; fails on prompts containing -1.
    struct Mock {
        prefilled: usize,
        admit: bool,
        new_seq_calls: usize,
        decode_calls: usize,
        new_seq_fails_at: Option<usize>,
    }

    struct MockSeq {
        emitted: usize,
    }

    impl SeqBackend for Mock {
        type Seq = MockSeq;
        fn new_seq(&mut self) -> Result<MockSeq> {
            let call = self.new_seq_calls;
            self.new_seq_calls += 1;
            if self.new_seq_fails_at == Some(call) {
                anyhow::bail!("no pages");
            }
            Ok(MockSeq { emitted: 0 })
        }
        fn can_admit(&self, _active: usize) -> bool {
            self.admit
        }
        fn prefill_chunk(&mut self, _seq: &mut MockSeq, chunk: &[i32]) -> Result<()> {
            if chunk.contains(&-1) {
                anyhow::bail!("poison prompt");
            }
            self.prefilled += chunk.len();
            Ok(())
        }
        fn decode(&mut self, seq: &mut MockSeq, n: usize) -> Result<Decoded> {
            self.decode_calls += 1;
            let tokens: Vec<i32> = (0..n).map(|i| 100 + (seq.emitted + i) as i32).collect();
            seq.emitted += n;
            Ok(Decoded { tokens, t_first: Some(Instant::now()) })
        }
    }

    fn mock() -> Mock {
        Mock {
            prefilled: 0,
            admit: true,
            new_seq_calls: 0,
            decode_calls: 0,
            new_seq_fails_at: None,
        }
    }

    fn sched() -> Scheduler<Mock> {
        Scheduler::new(mock(), 8, 4, 2, 4)
    }

    fn submit(s: &mut Scheduler<Mock>, prompt: Vec<i32>, max_new: usize) -> u64 {
        s.submit(prompt, max_new, CancelToken::new()).unwrap()
    }

    #[test]
    fn admission_deferred_while_backend_gates() {
        let mut s = Scheduler::new(Mock { admit: false, ..mock() }, 8, 4, 2, 4);
        submit(&mut s, vec![1, 2], 1);
        s.step();
        assert_eq!(s.depth(), (1, 0), "admitted despite backend pressure");
        s.backend_mut().admit = true;
        s.step();
        assert_eq!(s.depth().1, 1);
        let mut finished = Vec::new();
        while s.has_work() {
            finished.extend(s.step());
        }
        assert_eq!(finished.len(), 1);
        assert!(finished[0].error.is_none());
    }

    #[test]
    fn single_request_lifecycle() {
        let mut s = sched();
        let id = submit(&mut s, (0..20).collect(), 6);
        let mut finished = Vec::new();
        let mut rounds = 0;
        while s.has_work() && rounds < 100 {
            finished.extend(s.step());
            rounds += 1;
        }
        assert_eq!(finished.len(), 1);
        let f = &finished[0];
        assert_eq!(f.id, id);
        assert_eq!(f.tokens, vec![100, 101, 102, 103, 104, 105]);
        assert_eq!(f.prompt_tokens, 20);
        assert!(f.error.is_none());
        assert!(!f.cancelled);
        // 20-token prompt at window 8 = 3 prefill rounds; 6 tokens at
        // quantum 4 = 2 decode rounds
        assert_eq!(rounds, 5);
    }

    #[test]
    fn interleaves_up_to_max_active() {
        let mut s = sched();
        for _ in 0..4 {
            submit(&mut s, (0..8).collect(), 4);
        }
        let (q, a) = s.depth();
        assert_eq!((q, a), (4, 0));
        s.step();
        assert_eq!(s.depth().1, 2); // max_active respected
        let mut finished = 0;
        for _ in 0..50 {
            finished += s.step().len();
            if finished == 4 {
                break;
            }
        }
        assert_eq!(finished, 4);
    }

    #[test]
    fn admission_control_backpressure() {
        let mut s = sched();
        for _ in 0..4 {
            submit(&mut s, vec![1], 1);
        }
        assert!(s.submit(vec![1], 1, CancelToken::new()).is_err(), "queue should be full");
    }

    #[test]
    fn backend_error_fails_only_that_sequence() {
        let mut s = sched();
        submit(&mut s, vec![1, 2, 3], 2);
        submit(&mut s, vec![-1], 2); // poison
        let mut oks = 0;
        let mut errs = 0;
        for _ in 0..20 {
            for f in s.step() {
                if f.error.is_some() {
                    errs += 1;
                } else {
                    oks += 1;
                }
            }
            if !s.has_work() {
                break;
            }
        }
        assert_eq!((oks, errs), (1, 1));
    }

    #[test]
    fn timings_populated() {
        let mut s = sched();
        submit(&mut s, vec![1, 2], 1);
        let mut out = Vec::new();
        while s.has_work() {
            out.extend(s.step());
        }
        let f = &out[0];
        assert!(f.total_s >= f.ttft_s);
        assert!(f.ttft_s > 0.0);
    }

    #[test]
    fn new_seq_failure_is_isolated_from_the_round() {
        // regression: a new_seq failure used to abort the whole round,
        // skipping the remaining admissions AND the advance phase
        let mut s = Scheduler::new(Mock { new_seq_fails_at: Some(1), ..mock() }, 8, 4, 3, 8);
        let a = submit(&mut s, vec![1; 4], 2);
        let b = submit(&mut s, vec![2; 4], 2); // this one's new_seq fails
        let c = submit(&mut s, vec![3; 4], 2);
        std::thread::sleep(std::time::Duration::from_millis(2));
        let first_round = s.step();
        // the failure surfaced immediately, the other two were admitted AND
        // advanced (prefill ran) in the same round
        assert_eq!(first_round.len(), 1);
        let f = &first_round[0];
        assert_eq!(f.id, b);
        assert!(f.error.is_some());
        assert!(f.queue_s >= 0.002, "errored request must keep its real queue time");
        assert!(f.total_s >= f.queue_s);
        assert_eq!(s.depth(), (0, 2), "remaining admissions must not be skipped");
        assert_eq!(s.backend().prefilled, 8, "advance phase must still run");
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.step());
        }
        let mut ok_ids: Vec<u64> =
            done.iter().filter(|f| f.error.is_none()).map(|f| f.id).collect();
        ok_ids.sort_unstable();
        assert_eq!(ok_ids, vec![a, c]);
    }

    #[test]
    fn zero_max_new_finishes_without_backend_calls() {
        // regression: max_new == 0 used to admit a sequence and issue a
        // zero-step decode device call before finishing
        let mut s = sched();
        let id = submit(&mut s, vec![1, 2, 3], 0);
        let done = s.step();
        assert_eq!(done.len(), 1);
        let f = &done[0];
        assert_eq!(f.id, id);
        assert!(f.tokens.is_empty());
        assert!(f.error.is_none());
        assert!(!f.cancelled);
        assert_eq!(f.prompt_tokens, 3);
        assert_eq!(s.backend().new_seq_calls, 0, "zero-token generate must not allocate a seq");
        assert_eq!(s.backend().prefilled, 0, "zero-token generate must not prefill");
        assert_eq!(s.backend().decode_calls, 0, "zero-token generate must not decode");
        assert!(!s.has_work());
    }

    #[test]
    fn zero_max_new_does_not_consume_the_rounds_admission_slots() {
        // a zero-token request ahead of real work must not block admission
        let mut s = sched();
        submit(&mut s, vec![1; 4], 0);
        let real = submit(&mut s, vec![1; 4], 4);
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.step());
        }
        assert_eq!(done.len(), 2);
        let f = done.iter().find(|f| f.id == real).unwrap();
        assert_eq!(f.tokens.len(), 4);
        assert_eq!(s.backend().new_seq_calls, 1);
    }

    /// Backend with a canned prefix-match length (cross-request reuse mock).
    struct PrefixMock {
        inner: Mock,
        matched: usize,
        adopt_calls: usize,
    }

    impl SeqBackend for PrefixMock {
        type Seq = MockSeq;
        fn new_seq(&mut self) -> Result<MockSeq> {
            self.inner.new_seq()
        }
        fn adopt_prefix(&mut self, _seq: &mut MockSeq, prompt: &[i32], allow: bool) -> usize {
            if !allow {
                return 0; // placed, but the cache is never consulted
            }
            self.adopt_calls += 1;
            self.matched.min(prompt.len())
        }
        fn prefill_chunk(&mut self, seq: &mut MockSeq, chunk: &[i32]) -> Result<()> {
            self.inner.prefill_chunk(seq, chunk)
        }
        fn decode(&mut self, seq: &mut MockSeq, n: usize) -> Result<Decoded> {
            self.inner.decode(seq, n)
        }
    }

    fn prefix_sched(matched: usize) -> Scheduler<PrefixMock> {
        Scheduler::new(PrefixMock { inner: mock(), matched, adopt_calls: 0 }, 8, 4, 2, 4)
    }

    #[test]
    fn adopted_prefix_skips_matched_prefill() {
        // 20-token prompt, 16 matched at admission: only the 4-token tail
        // is ever prefilled, and the finish record reports the reuse
        let mut s = prefix_sched(16);
        s.submit(vec![1; 20], 4, CancelToken::new()).unwrap();
        let mut done = Vec::new();
        let mut rounds = 0;
        while s.has_work() && rounds < 20 {
            done.extend(s.step());
            rounds += 1;
        }
        assert_eq!(done.len(), 1);
        assert!(done[0].error.is_none());
        assert_eq!(done[0].prefix_tokens, 16);
        assert_eq!(done[0].prompt_tokens, 20);
        assert_eq!(s.backend().inner.prefilled, 4, "matched span must never prefill");
        assert_eq!(s.backend().adopt_calls, 1);
        // one prefill round (the 4-token tail) + one decode round
        assert_eq!(rounds, 2);
    }

    #[test]
    fn fully_matched_prompt_goes_straight_to_decode() {
        let mut s = prefix_sched(64);
        s.submit(vec![1; 8], 4, CancelToken::new()).unwrap();
        let done = s.step(); // admit + first (and only) decode quantum
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].prefix_tokens, 8, "match is clamped to the prompt length");
        assert_eq!(done[0].tokens.len(), 4);
        assert_eq!(s.backend().inner.prefilled, 0);
    }

    #[test]
    fn prefix_opt_out_prefills_cold() {
        let mut s = prefix_sched(64);
        s.submit_opt(vec![1; 8], 2, CancelToken::new(), false).unwrap();
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.step());
        }
        assert_eq!(done[0].prefix_tokens, 0);
        assert_eq!(s.backend().adopt_calls, 0, "opt-out must not consult the prefix cache");
        assert_eq!(s.backend().inner.prefilled, 8);
    }

    #[test]
    fn fairness_no_starvation_across_eight_sequences() {
        // 8 sequences with mixed prefill/decode workloads, all admitted in
        // round 1: every sequence must advance one unit EVERY round, so each
        // finishes exactly at its workload's round count — order-preserving
        // removal must not starve or reorder anyone.
        let window = 8;
        let quantum = 4;
        let mut s = Scheduler::new(mock(), window, quantum, 8, 16);
        let loads: Vec<(usize, usize)> = vec![
            (4, 4),   // 1 prefill + 1 decode round
            (20, 4),  // 3 + 1
            (8, 12),  // 1 + 3
            (16, 8),  // 2 + 2
            (4, 16),  // 1 + 4
            (24, 4),  // 3 + 1
            (8, 4),   // 1 + 1
            (12, 20), // 2 + 5
        ];
        let mut expected = std::collections::BTreeMap::new();
        for &(p, m) in &loads {
            let id = submit(&mut s, vec![1; p], m);
            expected.insert(id, p.div_ceil(window) + m.div_ceil(quantum));
        }
        let mut finish_round = std::collections::BTreeMap::new();
        for round in 1usize..=20 {
            for f in s.step() {
                assert!(f.error.is_none());
                finish_round.insert(f.id, round);
            }
            if !s.has_work() {
                break;
            }
        }
        assert_eq!(finish_round.len(), loads.len());
        for (id, rounds) in &expected {
            assert_eq!(
                finish_round.get(id),
                Some(rounds),
                "sequence {id} was starved or served out of turn"
            );
        }
    }

    /// Backend whose sequences hold real paged-KV arena pages, so tests can
    /// observe cancellation returning bytes to the pool.
    struct ArenaMock {
        arena: KvArena,
    }

    struct ArenaMockSeq {
        kv: KvCache,
        pos: u64,
    }

    impl ArenaMock {
        fn append(&self, s: &mut ArenaMockSeq, n: usize) -> Result<()> {
            let row = vec![0.5f32; 2 * n * 4];
            for layer in 0..2 {
                s.kv.append_layer(layer, &row, &row, n, n, s.pos)?;
            }
            s.pos += n as u64;
            Ok(())
        }
    }

    impl SeqBackend for ArenaMock {
        type Seq = ArenaMockSeq;
        fn new_seq(&mut self) -> Result<ArenaMockSeq> {
            Ok(ArenaMockSeq { kv: KvCache::with_arena(self.arena.clone(), 2, 2, 256, 4), pos: 0 })
        }
        fn prefill_chunk(&mut self, seq: &mut ArenaMockSeq, chunk: &[i32]) -> Result<()> {
            self.append(seq, chunk.len())
        }
        fn decode(&mut self, seq: &mut ArenaMockSeq, n: usize) -> Result<Decoded> {
            self.append(seq, n)?;
            Ok(Decoded { tokens: vec![7; n], t_first: None })
        }
    }

    #[test]
    fn cancel_mid_prefill_releases_arena_bytes() {
        let arena = KvArena::new();
        let mut s = Scheduler::new(ArenaMock { arena: arena.clone() }, 8, 4, 2, 4);
        let cancel = CancelToken::new();
        s.submit(vec![1; 32], 8, cancel.clone()).unwrap();
        s.step(); // admit + first prefill window (8 of 32 tokens)
        assert_eq!(s.depth(), (0, 1));
        assert!(arena.stats().bytes_in_use > 0, "prefill must occupy pages");
        cancel.cancel();
        let done = s.step();
        assert_eq!(done.len(), 1);
        assert!(done[0].cancelled);
        assert!(done[0].error.is_none());
        assert_eq!(
            arena.stats().bytes_in_use,
            0,
            "cancelled mid-prefill sequence must return its pages immediately"
        );
        assert!(!s.has_work());
    }

    #[test]
    fn cancel_mid_decode_releases_arena_bytes() {
        let arena = KvArena::new();
        let mut s = Scheduler::new(ArenaMock { arena: arena.clone() }, 8, 4, 2, 4);
        let cancel = CancelToken::new();
        s.submit(vec![1; 8], 64, cancel.clone()).unwrap();
        s.step(); // admit + full prefill
        s.step(); // first decode quantum (4 of 64 tokens)
        let mid = arena.stats().bytes_in_use;
        assert!(mid > 0, "decoding sequence must occupy pages");
        cancel.cancel();
        let done = s.step();
        assert_eq!(done.len(), 1);
        assert!(done[0].cancelled);
        assert_eq!(done[0].tokens.len(), 4, "tokens decoded before the cancel are reported");
        assert!(done[0].ttft_s > 0.0, "cancelled-after-first-token keeps its TTFT");
        assert_eq!(
            arena.stats().bytes_in_use,
            0,
            "cancelled mid-decode sequence must return its pages before the next round"
        );
    }

    #[test]
    fn cancel_while_queued_never_admits() {
        let mut s = sched();
        let cancel = CancelToken::new();
        s.submit(vec![1; 4], 2, cancel.clone()).unwrap();
        cancel.cancel();
        let done = s.step();
        assert_eq!(done.len(), 1);
        assert!(done[0].cancelled);
        assert_eq!(done[0].tokens.len(), 0);
        assert!(done[0].queue_s >= 0.0);
        assert_eq!(s.backend().new_seq_calls, 0, "cancelled queued request must not admit");
        assert!(!s.has_work());
    }

    /// Backend whose sequences are ALSO resident in a device tier (the
    /// serving shape after the residency refactor): decode promotes the
    /// sequence's KV image onto the device, `can_admit` sweeps the tier.
    struct DeviceTierMock {
        arena: KvArena,
        client: xla::PjRtClient,
        tier: std::cell::RefCell<crate::runtime::DeviceTier>,
        pool: std::cell::RefCell<crate::runtime::ScratchPool>,
    }

    impl DeviceTierMock {
        fn new() -> Self {
            Self {
                arena: KvArena::new(),
                client: xla::PjRtClient::cpu().unwrap(),
                tier: std::cell::RefCell::new(crate::runtime::DeviceTier::new(1 << 24)),
                pool: std::cell::RefCell::new(crate::runtime::ScratchPool::new(4)),
            }
        }

        fn staging_bytes(&self) -> usize {
            self.tier.borrow().resident_bytes() + self.pool.borrow().resident_bytes()
        }

        fn append_and_acquire(&self, s: &mut ArenaMockSeq, n: usize) -> Result<()> {
            let row = vec![0.5f32; 2 * n * 4];
            for layer in 0..2 {
                s.kv.append_layer(layer, &row, &row, n, n, s.pos)?;
            }
            s.pos += n as u64;
            let mut tier = self.tier.borrow_mut();
            let mut pool = self.pool.borrow_mut();
            tier.acquire(&self.client, &mut s.kv, &mut pool)?;
            Ok(())
        }
    }

    impl SeqBackend for DeviceTierMock {
        type Seq = ArenaMockSeq;
        fn new_seq(&mut self) -> Result<ArenaMockSeq> {
            Ok(ArenaMockSeq { kv: KvCache::with_arena(self.arena.clone(), 2, 2, 256, 4), pos: 0 })
        }
        fn prefill_chunk(&mut self, seq: &mut ArenaMockSeq, chunk: &[i32]) -> Result<()> {
            self.append_and_acquire(seq, chunk.len())
        }
        fn decode(&mut self, seq: &mut ArenaMockSeq, n: usize) -> Result<Decoded> {
            self.append_and_acquire(seq, n)?;
            Ok(Decoded { tokens: vec![7; n], t_first: None })
        }
        fn can_admit(&self, _active: usize) -> bool {
            // the real backend's shape: sweep dead staging state before
            // counting it against the admission budget
            self.tier.borrow_mut().sweep();
            self.pool.borrow_mut().sweep();
            true
        }
    }

    #[test]
    fn cancelled_sequence_frees_device_tier_before_next_round_admits() {
        // regression: cancellation teardown must release the sequence's
        // device-tier buffers (and scratch image) like the KvCache Drop ->
        // arena page return path, BEFORE the next round's admission counts
        // staging bytes
        let mut s = Scheduler::new(DeviceTierMock::new(), 8, 4, 2, 4);
        let cancel = CancelToken::new();
        s.submit(vec![1; 8], 64, cancel.clone()).unwrap();
        s.step(); // admit + prefill (promotes the KV image into the tier)
        s.step(); // first decode quantum
        assert!(s.backend().staging_bytes() > 0, "decoding sequence must be device-resident");
        assert!(s.backend().arena.stats().bytes_in_use > 0);
        cancel.cancel();
        let done = s.step(); // reap: the seq (and its KvCache) is dropped
        assert!(done.iter().any(|f| f.cancelled));
        assert_eq!(s.backend().arena.stats().bytes_in_use, 0, "arena pages returned");
        s.step(); // next round: the admit phase's can_admit sweeps staging
        assert_eq!(
            s.backend().staging_bytes(),
            0,
            "cancelled sequence's device-resident bytes must be freed before \
             the next round admits"
        );
    }

    #[test]
    fn cancellation_does_not_stall_other_sequences() {
        let mut s = Scheduler::new(mock(), 8, 4, 4, 8);
        let cancel = CancelToken::new();
        submit(&mut s, vec![1; 8], 8);
        s.submit(vec![2; 8], 8, cancel.clone()).unwrap();
        submit(&mut s, vec![3; 8], 8);
        s.step(); // all admitted + prefilled
        cancel.cancel();
        let mut done = Vec::new();
        for _ in 0..10 {
            done.extend(s.step());
            if !s.has_work() {
                break;
            }
        }
        assert_eq!(done.len(), 3);
        assert_eq!(done.iter().filter(|f| f.cancelled).count(), 1);
        assert_eq!(
            done.iter().filter(|f| !f.cancelled && f.error.is_none()).count(),
            2,
            "survivors must complete normally"
        );
    }

    // ------------------------------------------------------------------
    // split-phase (submit/reap) coverage: a generic pool-backed async
    // backend over the real CallExecutor, used by the overlap test and the
    // sync-equivalence property test
    // ------------------------------------------------------------------

    type PrefillFn<S> = Arc<dyn Fn(&mut S, &[i32]) -> Result<()> + Send + Sync>;
    type DecodeFn<S> = Arc<dyn Fn(&mut S, usize) -> Result<Decoded> + Send + Sync>;

    type RecoverFn<S> = Option<Arc<dyn Fn(&mut S, usize) + Send + Sync>>;

    /// Async test backend: ships each call (with its owned sequence) onto a
    /// [`CallExecutor`] worker pool — the same ownership-transfer shape as
    /// the serving `EngineBackend`.
    struct PoolBackend<'env, S: Send + 'env> {
        ex: CallExecutor<'env, (S, Result<CallOut>)>,
        capacity: usize,
        new_fn: Box<dyn FnMut() -> Result<S> + 'env>,
        prefill_fn: PrefillFn<S>,
        decode_fn: DecodeFn<S>,
        recover_fn: RecoverFn<S>,
    }

    impl<'env, S: Send + 'env> SeqBackend for PoolBackend<'env, S> {
        type Seq = S;
        fn new_seq(&mut self) -> Result<S> {
            (self.new_fn)()
        }
        fn prefill_chunk(&mut self, seq: &mut S, chunk: &[i32]) -> Result<()> {
            (self.prefill_fn)(seq, chunk)
        }
        fn decode(&mut self, seq: &mut S, n: usize) -> Result<Decoded> {
            (self.decode_fn)(seq, n)
        }
        fn recover(&mut self, seq: &mut S, pos: usize) {
            if let Some(f) = &self.recover_fn {
                f(seq, pos);
            }
        }
        fn inflight_capacity(&self) -> usize {
            self.capacity
        }
        fn submit_prefill(&mut self, ticket: Ticket, mut seq: S, chunk: &[i32]) -> Submitted<S> {
            let f = Arc::clone(&self.prefill_fn);
            let chunk = chunk.to_vec();
            self.ex.submit(ticket, move || {
                let result = f(&mut seq, &chunk).map(|()| CallOut::Prefill);
                (seq, result)
            });
            Submitted::InFlight
        }
        fn submit_decode(&mut self, ticket: Ticket, mut seq: S, n: usize) -> Submitted<S> {
            let f = Arc::clone(&self.decode_fn);
            self.ex.submit(ticket, move || {
                let result = f(&mut seq, n).map(CallOut::Decode);
                (seq, result)
            });
            Submitted::InFlight
        }
        fn reap(&mut self, wait: Option<Duration>) -> Vec<CallDone<S>> {
            self.ex
                .reap(wait)
                .into_iter()
                .map(|c| match c.out {
                    Ok((seq, result)) => CallDone { ticket: c.ticket, seq: Some(seq), result },
                    Err(panic) => CallDone {
                        ticket: c.ticket,
                        seq: None,
                        result: Err(crate::runtime::CallError::fatal(format!(
                            "worker panic: {panic}"
                        ))),
                    },
                })
                .collect()
        }
    }

    #[test]
    fn long_prefill_does_not_stall_decoders_at_capacity() {
        // one slow 64-token prefill and one fast decoder in flight together
        // (capacity 2): the decoder must finish while the prefill still runs
        std::thread::scope(|scope| {
            let slow_mark = 9i32;
            let backend: PoolBackend<'_, MockSeq> = PoolBackend {
                ex: CallExecutor::new(scope, 2),
                capacity: 2,
                new_fn: Box::new(|| Ok(MockSeq { emitted: 0 })),
                prefill_fn: Arc::new(move |_seq, chunk: &[i32]| {
                    if chunk.contains(&slow_mark) {
                        std::thread::sleep(Duration::from_millis(40));
                    }
                    Ok(())
                }),
                decode_fn: Arc::new(|seq: &mut MockSeq, n| {
                    std::thread::sleep(Duration::from_millis(1));
                    let tokens: Vec<i32> =
                        (0..n).map(|i| 100 + (seq.emitted + i) as i32).collect();
                    seq.emitted += n;
                    Ok(Decoded { tokens, t_first: Some(Instant::now()) })
                }),
                recover_fn: None,
            };
            let mut s = Scheduler::new(backend, 64, 4, 4, 8);
            let slow = s.submit(vec![slow_mark; 64], 1, CancelToken::new()).unwrap();
            let fast = s.submit(vec![1; 1], 8, CancelToken::new()).unwrap();
            let t0 = Instant::now();
            let mut finished: BTreeMap<u64, (Instant, Vec<i32>)> = BTreeMap::new();
            while s.has_work() && t0.elapsed() < Duration::from_secs(10) {
                for f in s.step() {
                    assert!(f.error.is_none(), "unexpected error: {:?}", f.error);
                    finished.insert(f.id, (Instant::now(), f.tokens));
                }
            }
            assert_eq!(finished.len(), 2, "both sequences must drain");
            assert!(
                finished[&fast].0 < finished[&slow].0,
                "decoder must finish while the long prefill is in flight"
            );
            assert_eq!(finished[&fast].1, (100..108).collect::<Vec<i32>>());
            assert_eq!(finished[&slow].1.len(), 1);
        });
    }

    // --- sync vs split-phase equivalence over real paged-KV state ---

    /// Per-sequence KV checksums, recorded when the sequence drops (i.e.
    /// when the scheduler finishes or cancels it).
    type KvSums = Arc<Mutex<BTreeMap<u64, u64>>>;

    struct TraceSeq {
        kv: KvCache,
        pos: u64,
        emitted: usize,
        tag: u64,
        sums: KvSums,
    }

    impl Drop for TraceSeq {
        fn drop(&mut self) {
            // FNV-1a over the dense K/V image + per-layer lens: byte-level
            // witness of the exact prefill/decode schedule this seq saw
            let (k, v) = self.kv.gather_dense();
            let mut h = 0xcbf29ce484222325u64;
            for x in k.iter().chain(v.iter()) {
                for b in x.to_le_bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x100000001b3);
                }
            }
            for &l in &self.kv.lens {
                h ^= l as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
            self.sums.lock().unwrap().insert(self.tag, h);
        }
    }

    /// Append `n` rows of position-dependent values at `pos` (content is a
    /// pure function of (layer, head, position, dim), so identical schedules
    /// yield byte-identical state).
    fn trace_fill(kv: &mut KvCache, pos: u64, n: usize) -> Result<()> {
        let (l, h, dh) = (kv.l, kv.h, kv.dh);
        for layer in 0..l {
            let mut k = vec![0.0f32; h * n * dh];
            let mut v = vec![0.0f32; h * n * dh];
            for hh in 0..h {
                for r in 0..n {
                    for d in 0..dh {
                        let idx = (hh * n + r) * dh + d;
                        let base = (pos + r as u64) as f32
                            + layer as f32 * 0.5
                            + hh as f32 * 0.25
                            + d as f32 * 0.0625;
                        k[idx] = base;
                        v[idx] = -base;
                    }
                }
            }
            kv.append_layer(layer, &k, &v, n, n, pos)?;
        }
        Ok(())
    }

    fn trace_prefill(seq: &mut TraceSeq, chunk: &[i32]) -> Result<()> {
        trace_fill(&mut seq.kv, seq.pos, chunk.len())?;
        seq.pos += chunk.len() as u64;
        Ok(())
    }

    fn trace_decode(seq: &mut TraceSeq, n: usize) -> Result<Decoded> {
        trace_fill(&mut seq.kv, seq.pos, n)?;
        seq.pos += n as u64;
        let tokens: Vec<i32> = (0..n).map(|i| 100 + (seq.emitted + i) as i32).collect();
        seq.emitted += n;
        Ok(Decoded { tokens, t_first: Some(Instant::now()) })
    }

    fn trace_seq(arena: &KvArena, sums: &KvSums, tag: u64) -> TraceSeq {
        TraceSeq {
            kv: KvCache::with_arena(arena.clone(), 2, 2, 256, 4),
            pos: 0,
            emitted: 0,
            tag,
            sums: Arc::clone(sums),
        }
    }

    /// Synchronous reference backend over the same trace functions.
    struct TraceBackend {
        arena: KvArena,
        sums: KvSums,
        next_tag: u64,
    }

    impl SeqBackend for TraceBackend {
        type Seq = TraceSeq;
        fn new_seq(&mut self) -> Result<TraceSeq> {
            let tag = self.next_tag;
            self.next_tag += 1;
            Ok(trace_seq(&self.arena, &self.sums, tag))
        }
        fn prefill_chunk(&mut self, seq: &mut TraceSeq, chunk: &[i32]) -> Result<()> {
            trace_prefill(seq, chunk)
        }
        fn decode(&mut self, seq: &mut TraceSeq, n: usize) -> Result<Decoded> {
            trace_decode(seq, n)
        }
    }

    #[test]
    fn split_phase_matches_synchronous_path() {
        // property: for the same seeded request trace, the split-phase
        // scheduler over a real worker pool produces the same per-request
        // token streams and byte-identical final KV state as the
        // synchronous (capacity 1, inline shim) path
        PropRunner::new(12).run(
            |rng| {
                let n_req = 2 + rng.below(5) as usize;
                (0..n_req)
                    .map(|_| (1 + rng.below(40) as usize, rng.below(12) as usize))
                    .collect::<Vec<(usize, usize)>>()
            },
            |trace| {
                // synchronous reference run
                let sync_sums: KvSums = KvSums::default();
                let mut sync_tokens: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
                {
                    let backend = TraceBackend {
                        arena: KvArena::new(),
                        sums: Arc::clone(&sync_sums),
                        next_tag: 0,
                    };
                    let mut s = Scheduler::new(backend, 8, 4, 3, 64);
                    for &(p, m) in trace {
                        s.submit(vec![1; p], m, CancelToken::new()).unwrap();
                    }
                    let mut guard = 0;
                    while s.has_work() && guard < 10_000 {
                        for f in s.step() {
                            prop_assert!(f.error.is_none(), "sync error: {:?}", f.error);
                            sync_tokens.insert(f.id, f.tokens);
                        }
                        guard += 1;
                    }
                    prop_assert!(!s.has_work(), "sync run did not drain");
                }

                // split-phase run over a 3-worker pool, capacity 3
                let async_sums: KvSums = KvSums::default();
                let mut async_tokens: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
                let mut errors: Vec<String> = Vec::new();
                let mut drained = false;
                std::thread::scope(|scope| {
                    let arena = KvArena::new();
                    let sums = Arc::clone(&async_sums);
                    let mut tag = 0u64;
                    let backend: PoolBackend<'_, TraceSeq> = PoolBackend {
                        ex: CallExecutor::new(scope, 3),
                        capacity: 3,
                        new_fn: Box::new(move || {
                            let t = tag;
                            tag += 1;
                            Ok(trace_seq(&arena, &sums, t))
                        }),
                        prefill_fn: Arc::new(trace_prefill),
                        decode_fn: Arc::new(trace_decode),
                        recover_fn: None,
                    };
                    let mut s = Scheduler::new(backend, 8, 4, 3, 64);
                    for &(p, m) in trace {
                        s.submit(vec![1; p], m, CancelToken::new()).unwrap();
                    }
                    let mut guard = 0;
                    while s.has_work() && guard < 100_000 {
                        for f in s.step() {
                            if let Some(e) = &f.error {
                                errors.push(e.clone());
                            }
                            async_tokens.insert(f.id, f.tokens);
                        }
                        guard += 1;
                    }
                    drained = !s.has_work();
                });
                prop_assert!(errors.is_empty(), "split-phase errors: {errors:?}");
                prop_assert!(drained, "split-phase run did not drain");
                prop_assert!(
                    async_tokens == sync_tokens,
                    "token streams diverge: {async_tokens:?} vs {sync_tokens:?}"
                );
                let a = sync_sums.lock().unwrap().clone();
                let b = async_sums.lock().unwrap().clone();
                prop_assert!(a == b, "final KV state diverges: {a:?} vs {b:?}");
                prop_assert!(
                    a.len() == trace.iter().filter(|&&(_, m)| m > 0).count(),
                    "each admitted sequence must record exactly one checksum"
                );
                Ok(())
            },
        );
    }

    #[test]
    fn tracing_is_byte_invisible_to_generation() {
        // property: for the same seeded request trace, running with the
        // flight recorder fully on (every event sampled) and fully off
        // (sampling 0) yields identical per-request token streams and
        // byte-identical final KV state — recording observes generation,
        // never perturbs it
        let _guard = crate::obs::test_guard();
        struct Restore;
        impl Drop for Restore {
            fn drop(&mut self) {
                crate::obs::recorder().configure(1, crate::obs::DEFAULT_CAPACITY);
            }
        }
        let _restore = Restore;
        fn run_once(trace: &[(usize, usize)]) -> (BTreeMap<u64, Vec<i32>>, BTreeMap<u64, u64>) {
            let sums: KvSums = KvSums::default();
            let mut tokens: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
            let backend =
                TraceBackend { arena: KvArena::new(), sums: Arc::clone(&sums), next_tag: 0 };
            let mut s = Scheduler::new(backend, 8, 4, 3, 64);
            for &(p, m) in trace {
                s.submit(vec![1; p], m, CancelToken::new()).unwrap();
            }
            let mut guard = 0;
            while s.has_work() && guard < 10_000 {
                for f in s.step() {
                    assert!(f.error.is_none(), "unexpected error: {:?}", f.error);
                    tokens.insert(f.id, f.tokens);
                }
                guard += 1;
            }
            assert!(!s.has_work(), "run did not drain");
            drop(s);
            let sums = sums.lock().unwrap().clone();
            (tokens, sums)
        }
        PropRunner::new(10).run(
            |rng| {
                let n_req = 2 + rng.below(5) as usize;
                (0..n_req)
                    .map(|_| (1 + rng.below(40) as usize, rng.below(12) as usize))
                    .collect::<Vec<(usize, usize)>>()
            },
            |trace| {
                crate::obs::recorder().configure(1, crate::obs::DEFAULT_CAPACITY);
                let (on_tokens, on_sums) = run_once(trace);
                crate::obs::recorder().configure(0, crate::obs::DEFAULT_CAPACITY);
                let (off_tokens, off_sums) = run_once(trace);
                prop_assert!(
                    on_tokens == off_tokens,
                    "token streams diverge with tracing on: {on_tokens:?} vs {off_tokens:?}"
                );
                prop_assert!(
                    on_sums == off_sums,
                    "final KV state diverges with tracing on: {on_sums:?} vs {off_sums:?}"
                );
                Ok(())
            },
        );
    }

    /// Multi-lane test backend mirroring the sharded serving shape: each
    /// sequence is pinned to one lane (tag % lanes — the placement stand-in)
    /// and every call ships on that lane; reap drains ALL lanes, blocking at
    /// most once, exactly like the serving `EngineBackend`.
    struct LaneBackend<'env> {
        lanes: Vec<CallExecutor<'env, (TraceSeq, Result<CallOut>)>>,
        new_fn: Box<dyn FnMut() -> Result<TraceSeq> + 'env>,
    }

    impl<'env> SeqBackend for LaneBackend<'env> {
        type Seq = TraceSeq;
        fn new_seq(&mut self) -> Result<TraceSeq> {
            (self.new_fn)()
        }
        fn prefill_chunk(&mut self, seq: &mut TraceSeq, chunk: &[i32]) -> Result<()> {
            trace_prefill(seq, chunk)
        }
        fn decode(&mut self, seq: &mut TraceSeq, n: usize) -> Result<Decoded> {
            trace_decode(seq, n)
        }
        fn inflight_capacity(&self) -> usize {
            self.lanes.iter().map(|ex| ex.workers()).sum()
        }
        fn submit_prefill(
            &mut self,
            ticket: Ticket,
            mut seq: TraceSeq,
            chunk: &[i32],
        ) -> Submitted<TraceSeq> {
            let lane = (seq.tag as usize) % self.lanes.len();
            let chunk = chunk.to_vec();
            self.lanes[lane].submit(ticket, move || {
                let result = trace_prefill(&mut seq, &chunk).map(|()| CallOut::Prefill);
                (seq, result)
            });
            Submitted::InFlight
        }
        fn submit_decode(&mut self, ticket: Ticket, mut seq: TraceSeq, n: usize) -> Submitted<TraceSeq> {
            let lane = (seq.tag as usize) % self.lanes.len();
            self.lanes[lane].submit(ticket, move || {
                let result = trace_decode(&mut seq, n).map(CallOut::Decode);
                (seq, result)
            });
            Submitted::InFlight
        }
        fn reap(&mut self, mut wait: Option<Duration>) -> Vec<CallDone<TraceSeq>> {
            let mut done = Vec::new();
            for ex in &mut self.lanes {
                let w = if ex.inflight() > 0 { wait.take() } else { None };
                done.extend(ex.reap(w).into_iter().map(|c| match c.out {
                    Ok((seq, result)) => CallDone { ticket: c.ticket, seq: Some(seq), result },
                    Err(panic) => CallDone {
                        ticket: c.ticket,
                        seq: None,
                        result: Err(crate::runtime::CallError::fatal(format!(
                            "worker panic: {panic}"
                        ))),
                    },
                }));
            }
            done
        }
    }

    #[test]
    fn lane_fanout_matches_single_lane_byte_for_byte() {
        // property: for the same seeded request trace, fanning calls out
        // over N per-shard lanes produces the same per-request token streams
        // and byte-identical final KV state as a single lane — sharding the
        // call path must never change what any sequence computes. Traces of
        // length 1 pin the `--devices N` == `--devices 1` single-sequence
        // byte-identity claim.
        PropRunner::new(10).run(
            |rng| {
                let n_req = 1 + rng.below(5) as usize;
                (0..n_req)
                    .map(|_| (1 + rng.below(40) as usize, rng.below(12) as usize))
                    .collect::<Vec<(usize, usize)>>()
            },
            |trace| {
                let run = |n_lanes: usize| {
                    let sums: KvSums = KvSums::default();
                    let mut tokens: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
                    let mut errors: Vec<String> = Vec::new();
                    let mut drained = false;
                    std::thread::scope(|scope| {
                        let arena = KvArena::new();
                        let seq_sums = Arc::clone(&sums);
                        let mut tag = 0u64;
                        let backend = LaneBackend {
                            lanes: CallExecutor::lanes(scope, n_lanes, 2),
                            new_fn: Box::new(move || {
                                let t = tag;
                                tag += 1;
                                Ok(trace_seq(&arena, &seq_sums, t))
                            }),
                        };
                        let mut s = Scheduler::new(backend, 8, 4, 4, 64);
                        for &(p, m) in trace {
                            s.submit(vec![1; p], m, CancelToken::new()).unwrap();
                        }
                        let mut guard = 0;
                        while s.has_work() && guard < 100_000 {
                            for f in s.step() {
                                if let Some(e) = &f.error {
                                    errors.push(e.clone());
                                }
                                tokens.insert(f.id, f.tokens);
                            }
                            guard += 1;
                        }
                        drained = !s.has_work();
                    });
                    let sums = sums.lock().unwrap().clone();
                    (tokens, sums, errors, drained)
                };
                let (t1, k1, e1, d1) = run(1);
                let (t3, k3, e3, d3) = run(3);
                prop_assert!(e1.is_empty(), "single-lane errors: {e1:?}");
                prop_assert!(e3.is_empty(), "three-lane errors: {e3:?}");
                prop_assert!(d1 && d3, "a run did not drain (1 lane: {d1}, 3 lanes: {d3})");
                prop_assert!(t1 == t3, "token streams diverge across lane counts");
                prop_assert!(
                    k1 == k3,
                    "per-lane fan-out must be byte-identical to one lane: {k1:?} vs {k3:?}"
                );
                Ok(())
            },
        );
    }

    #[test]
    fn itl_samples_accumulate_across_decode_quanta() {
        let mut s = sched();
        submit(&mut s, vec![1; 8], 12); // 1 prefill + 3 decode quanta
        while s.has_work() {
            s.step();
        }
        // first quantum seeds the timestamp; quanta 2 and 3 emit 4 samples each
        let itl = s.take_itl();
        assert_eq!(itl.len(), 8);
        assert!(itl.iter().all(|&x| x >= 0.0));
        assert!(s.take_itl().is_empty(), "take_itl drains");
    }

    // ------------------------------------------------------------------
    // fault handling: retry/recover, quarantine, deadlines, watchdog,
    // structured overload, worker panic isolation
    // ------------------------------------------------------------------

    /// Sync backend that fails its next `fail_next` prefill/decode calls
    /// with a typed transient error, recording every recover() rollback.
    struct FlakyMock {
        inner: Mock,
        fail_next: usize,
        recover_calls: Vec<usize>,
    }

    impl SeqBackend for FlakyMock {
        type Seq = MockSeq;
        fn new_seq(&mut self) -> Result<MockSeq> {
            self.inner.new_seq()
        }
        fn prefill_chunk(&mut self, seq: &mut MockSeq, chunk: &[i32]) -> Result<()> {
            if self.fail_next > 0 {
                self.fail_next -= 1;
                return Err(crate::runtime::CallError::transient("injected flaky prefill"));
            }
            self.inner.prefill_chunk(seq, chunk)
        }
        fn decode(&mut self, seq: &mut MockSeq, n: usize) -> Result<Decoded> {
            if self.fail_next > 0 {
                self.fail_next -= 1;
                return Err(crate::runtime::CallError::transient("injected flaky decode"));
            }
            self.inner.decode(seq, n)
        }
        fn recover(&mut self, _seq: &mut MockSeq, pos: usize) {
            self.recover_calls.push(pos);
        }
    }

    #[test]
    fn transient_failure_retries_and_recovers() {
        let backend = FlakyMock { inner: mock(), fail_next: 2, recover_calls: Vec::new() };
        let mut s = Scheduler::new(backend, 8, 4, 2, 4);
        s.retry.backoff = Duration::from_millis(1);
        s.submit(vec![1; 12], 4, CancelToken::new()).unwrap();
        let mut done = Vec::new();
        let t0 = Instant::now();
        while s.has_work() && t0.elapsed() < Duration::from_secs(5) {
            done.extend(s.step());
        }
        assert_eq!(done.len(), 1);
        let f = &done[0];
        assert!(f.error.is_none(), "faults within the retry budget must be invisible: {f:?}");
        assert_eq!(f.tokens, vec![100, 101, 102, 103]);
        assert_eq!(s.fault_stats().retries, 2);
        assert_eq!(s.fault_stats().quarantined, 0);
        // both failures hit the first prefill unit: recover saw its rollback
        // point (pos 0) twice, and no prompt token was ingested twice
        assert_eq!(s.backend().recover_calls, vec![0, 0]);
        assert_eq!(s.backend().inner.prefilled, 12, "each prompt token ingested exactly once");
    }

    #[test]
    fn retry_budget_exhaustion_quarantines_with_code() {
        let backend = FlakyMock { inner: mock(), fail_next: usize::MAX, recover_calls: Vec::new() };
        let mut s = Scheduler::new(backend, 8, 4, 2, 4);
        s.retry = RetryPolicy { max_retries: 3, backoff: Duration::from_millis(1) };
        s.submit(vec![1; 4], 2, CancelToken::new()).unwrap();
        let mut done = Vec::new();
        let t0 = Instant::now();
        while s.has_work() && t0.elapsed() < Duration::from_secs(5) {
            done.extend(s.step());
        }
        assert_eq!(done.len(), 1);
        let f = &done[0];
        assert_eq!(f.code.as_deref(), Some("transient"));
        assert!(f.error.as_ref().unwrap().contains("after 3 retries"), "got {:?}", f.error);
        assert_eq!(s.fault_stats().retries, 3);
        assert_eq!(s.fault_stats().quarantined, 1);
        assert!(!s.has_work());
    }

    #[test]
    fn fatal_error_skips_retry_and_carries_code() {
        // unclassified backend errors (the poison prompt) are fatal: no
        // retries are burned, the sequence quarantines immediately
        let mut s = sched();
        submit(&mut s, vec![-1], 2);
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.step());
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].code.as_deref(), Some("fatal"));
        assert_eq!(s.fault_stats().retries, 0);
        assert_eq!(s.fault_stats().quarantined, 1);
    }

    #[test]
    fn overloaded_rejection_is_structured() {
        let mut s = sched(); // max_queue 4
        for _ in 0..4 {
            submit(&mut s, vec![1], 1);
        }
        let err = s.submit(vec![1], 1, CancelToken::new()).unwrap_err();
        let o = err.downcast_ref::<Overloaded>().expect("queue-full must be a typed Overloaded");
        assert_eq!(o.queued, 4);
        assert!(o.retry_after_ms >= 50);
        assert_eq!(s.fault_stats().overloaded, 1);
    }

    #[test]
    fn deadline_exceeded_finishes_with_partial_output() {
        let mut s = sched();
        let id = s
            .submit_req(
                vec![1; 4],
                1_000_000, // would decode forever; only the deadline ends it
                CancelToken::new(),
                true,
                Some(Duration::from_millis(30)),
            )
            .unwrap();
        let mut done = Vec::new();
        let t0 = Instant::now();
        while done.is_empty() && t0.elapsed() < Duration::from_secs(5) {
            done.extend(s.step());
        }
        let f = &done[0];
        assert_eq!(f.id, id);
        assert_eq!(f.code.as_deref(), Some("deadline-exceeded"));
        assert!(f.error.is_some());
        assert!(!f.cancelled);
        assert!(!f.tokens.is_empty(), "partial output generated before the deadline survives");
        assert_eq!(s.fault_stats().deadline_exceeded, 1);
        assert!(!s.has_work());
    }

    #[test]
    fn queued_request_expires_before_admission() {
        let mut s = Scheduler::new(Mock { admit: false, ..mock() }, 8, 4, 2, 4);
        s.submit_req(
            vec![1; 4],
            4,
            CancelToken::new(),
            true,
            Some(Duration::from_millis(5)),
        )
        .unwrap();
        std::thread::sleep(Duration::from_millis(10));
        let done = s.step();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].code.as_deref(), Some("deadline-exceeded"));
        assert!(done[0].tokens.is_empty());
        assert_eq!(s.backend().new_seq_calls, 0, "expired request must never admit");
        assert_eq!(s.fault_stats().deadline_exceeded, 1);
    }

    #[test]
    fn worker_panic_quarantines_only_that_sequence() {
        std::thread::scope(|scope| {
            let panic_mark = -7i32;
            let backend: PoolBackend<'_, MockSeq> = PoolBackend {
                ex: CallExecutor::new(scope, 2),
                capacity: 2,
                new_fn: Box::new(|| Ok(MockSeq { emitted: 0 })),
                prefill_fn: Arc::new(move |_seq, chunk: &[i32]| {
                    if chunk.contains(&panic_mark) {
                        panic!("injected panic mid-prefill");
                    }
                    Ok(())
                }),
                decode_fn: Arc::new(|seq: &mut MockSeq, n| {
                    let tokens: Vec<i32> =
                        (0..n).map(|i| 100 + (seq.emitted + i) as i32).collect();
                    seq.emitted += n;
                    Ok(Decoded { tokens, t_first: Some(Instant::now()) })
                }),
                recover_fn: None,
            };
            let mut s = Scheduler::new(backend, 8, 4, 4, 8);
            let doomed = s.submit(vec![panic_mark; 4], 4, CancelToken::new()).unwrap();
            let healthy = s.submit(vec![1; 4], 4, CancelToken::new()).unwrap();
            let mut done = Vec::new();
            let t0 = Instant::now();
            while s.has_work() && t0.elapsed() < Duration::from_secs(10) {
                done.extend(s.step());
            }
            assert_eq!(done.len(), 2, "both sequences must exit");
            let bad = done.iter().find(|f| f.id == doomed).unwrap();
            assert_eq!(bad.code.as_deref(), Some("fatal"));
            assert!(bad.error.as_ref().unwrap().contains("panic"), "got {:?}", bad.error);
            let good = done.iter().find(|f| f.id == healthy).unwrap();
            assert!(good.error.is_none(), "the panic must not leak into other sequences");
            assert_eq!(good.tokens.len(), 4);
            assert_eq!(s.fault_stats().quarantined, 1);
            assert_eq!(s.inflight(), 0);
        });
    }

    #[test]
    fn watchdog_abandons_stuck_inflight_call() {
        std::thread::scope(|scope| {
            let backend: PoolBackend<'_, MockSeq> = PoolBackend {
                ex: CallExecutor::new(scope, 1),
                capacity: 1,
                new_fn: Box::new(|| Ok(MockSeq { emitted: 0 })),
                prefill_fn: Arc::new(|_seq, _chunk: &[i32]| {
                    std::thread::sleep(Duration::from_millis(150)); // "wedged" call
                    Ok(())
                }),
                decode_fn: Arc::new(|seq: &mut MockSeq, n| {
                    let tokens: Vec<i32> =
                        (0..n).map(|i| 100 + (seq.emitted + i) as i32).collect();
                    seq.emitted += n;
                    Ok(Decoded { tokens, t_first: Some(Instant::now()) })
                }),
                recover_fn: None,
            };
            let mut s = Scheduler::new(backend, 8, 4, 2, 4);
            s.watchdog_grace = Duration::from_millis(25);
            let id = s
                .submit_req(
                    vec![1; 4],
                    4,
                    CancelToken::new(),
                    true,
                    Some(Duration::from_millis(25)),
                )
                .unwrap();
            let mut done = Vec::new();
            let t0 = Instant::now();
            while done.is_empty() && t0.elapsed() < Duration::from_secs(5) {
                done.extend(s.step());
            }
            let f = &done[0];
            assert_eq!(f.id, id);
            assert_eq!(f.code.as_deref(), Some("deadline-exceeded"));
            assert!(f.error.as_ref().unwrap().contains("watchdog"), "got {:?}", f.error);
            assert!(
                t0.elapsed() < Duration::from_millis(140),
                "the watchdog must not wait for the wedged call to land"
            );
            // the stuck call eventually completes and is dropped quietly
            let t1 = Instant::now();
            while s.inflight() > 0 && t1.elapsed() < Duration::from_secs(5) {
                s.step();
            }
            assert_eq!(s.inflight(), 0);
            assert!(!s.has_work());
        });
    }

    #[test]
    fn faulted_split_phase_recovers_to_fault_free_results() {
        // satellite property: seeded transient faults injected at every sim
        // call site (prefill / decode / upload / spill) of a pooled
        // split-phase run over real arena pages and a real device tier must
        // recover — via retry + rebuild-from-arena — to byte-identical final
        // KV images and identical token streams vs the fault-free
        // synchronous reference, with zero quarantines.
        use crate::runtime::{DeviceTier, ScratchPool};
        use std::sync::atomic::AtomicU64;
        use xla::fault::{self, FaultKind, FaultPlan};

        fn inject(site: &str) -> anyhow::Result<()> {
            if let Some(kind) = xla::fault::check(site) {
                if let Some(msg) = xla::fault::apply(site, kind) {
                    anyhow::bail!("{msg}");
                }
            }
            Ok(())
        }

        let total_retries = AtomicU64::new(0);
        PropRunner::new(6).run(
            |rng| {
                let n_req = 2 + rng.below(4) as usize;
                let seed = rng.below(u64::MAX);
                let trace: Vec<(usize, usize)> = (0..n_req)
                    .map(|_| (1 + rng.below(30) as usize, rng.below(10) as usize))
                    .collect();
                (seed, trace)
            },
            |(seed, trace)| {
                // fault-free synchronous reference
                fault::install(None);
                let sync_sums: KvSums = KvSums::default();
                let mut sync_tokens: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
                {
                    let backend = TraceBackend {
                        arena: KvArena::new(),
                        sums: Arc::clone(&sync_sums),
                        next_tag: 0,
                    };
                    let mut s = Scheduler::new(backend, 8, 4, 3, 64);
                    for &(p, m) in trace {
                        s.submit(vec![1; p], m, CancelToken::new()).unwrap();
                    }
                    let mut guard = 0;
                    while s.has_work() && guard < 10_000 {
                        for f in s.step() {
                            prop_assert!(f.error.is_none(), "sync error: {:?}", f.error);
                            sync_tokens.insert(f.id, f.tokens);
                        }
                        guard += 1;
                    }
                    prop_assert!(!s.has_work(), "sync run did not drain");
                }

                // faulted split-phase run: every fault fires BEFORE any
                // durable mutation, recovery drops device/scratch residency
                // so retries rebuild from the arena pages
                fault::install(Some(
                    FaultPlan::new(*seed)
                        .rule("sim-prefill", FaultKind::Transient, 0.12)
                        .rule("sim-decode", FaultKind::Transient, 0.12)
                        .rule("sim-upload", FaultKind::Transient, 0.08)
                        .rule("sim-spill", FaultKind::Transient, 0.08),
                ));
                let async_sums: KvSums = KvSums::default();
                let mut async_tokens: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
                let mut errors: Vec<String> = Vec::new();
                let mut drained = false;
                let mut faults = FaultStats::default();
                std::thread::scope(|scope| {
                    let arena = KvArena::new();
                    let sums = Arc::clone(&async_sums);
                    let mut tag = 0u64;
                    // capacity for ONE image: concurrent sequences thrash
                    // the spill path while faults land around them
                    let image_bytes = 2 * 4 * 2 * 2 * 256 * 4;
                    let tiers = Arc::new(Mutex::new((
                        DeviceTier::new(image_bytes),
                        ScratchPool::new(2),
                    )));
                    let client = Arc::new(xla::PjRtClient::cpu().unwrap());
                    let acq_tiers = Arc::clone(&tiers);
                    let acq_client = Arc::clone(&client);
                    let dec_tiers = Arc::clone(&tiers);
                    let dec_client = Arc::clone(&client);
                    let rec_tiers = Arc::clone(&tiers);
                    let backend: PoolBackend<'_, TraceSeq> = PoolBackend {
                        ex: CallExecutor::new(scope, 3),
                        capacity: 3,
                        new_fn: Box::new(move || {
                            let t = tag;
                            tag += 1;
                            Ok(trace_seq(&arena, &sums, t))
                        }),
                        prefill_fn: Arc::new(move |seq, chunk| {
                            inject("sim-prefill")?;
                            inject("sim-upload")?;
                            {
                                let mut g = acq_tiers.lock().unwrap();
                                let (tier, pool) = &mut *g;
                                tier.acquire(&acq_client, &mut seq.kv, pool)?;
                            }
                            trace_prefill(seq, chunk)
                        }),
                        decode_fn: Arc::new(move |seq, n| {
                            inject("sim-decode")?;
                            inject("sim-upload")?;
                            inject("sim-spill")?;
                            {
                                let mut g = dec_tiers.lock().unwrap();
                                let (tier, pool) = &mut *g;
                                tier.acquire(&dec_client, &mut seq.kv, pool)?;
                            }
                            trace_decode(seq, n)
                        }),
                        recover_fn: Some(Arc::new(move |seq: &mut TraceSeq, _pos| {
                            // rebuild-from-arena: drop all staged residency;
                            // the retry re-gathers from the host pages
                            let mut g = rec_tiers.lock().unwrap();
                            let (tier, pool) = &mut *g;
                            tier.release(seq.kv.id());
                            pool.release(seq.kv.id());
                        })),
                    };
                    let mut s = Scheduler::new(backend, 8, 4, 3, 64);
                    s.retry = RetryPolicy {
                        max_retries: 8,
                        backoff: Duration::from_micros(200),
                    };
                    for &(p, m) in trace {
                        s.submit(vec![1; p], m, CancelToken::new()).unwrap();
                    }
                    let mut guard = 0;
                    while s.has_work() && guard < 200_000 {
                        for f in s.step() {
                            if let Some(e) = &f.error {
                                errors.push(e.clone());
                            }
                            async_tokens.insert(f.id, f.tokens);
                        }
                        guard += 1;
                    }
                    drained = !s.has_work();
                    faults = s.fault_stats();
                });
                fault::install(None);
                total_retries.fetch_add(faults.retries, Ordering::Relaxed);
                prop_assert!(errors.is_empty(), "faulted run must fully recover: {errors:?}");
                prop_assert!(drained, "faulted run did not drain");
                prop_assert!(faults.quarantined == 0, "quarantines: {}", faults.quarantined);
                prop_assert!(
                    async_tokens == sync_tokens,
                    "token streams diverge under faults: {async_tokens:?} vs {sync_tokens:?}"
                );
                let a = sync_sums.lock().unwrap().clone();
                let b = async_sums.lock().unwrap().clone();
                prop_assert!(a == b, "final KV state diverges under faults: {a:?} vs {b:?}");
                Ok(())
            },
        );
        assert!(
            total_retries.load(Ordering::Relaxed) > 0,
            "the fault plan never fired; the property is vacuous"
        );
    }
}
