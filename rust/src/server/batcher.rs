//! Continuous batcher / prefill-decode scheduler (Orca/vLLM-style
//! iteration-level scheduling, single-executor variant).
//!
//! Sequences move `queued -> prefilling -> decoding -> finished`, with a
//! `cancelled` exit from every state. Each scheduling round runs three
//! explicit phases:
//!
//! 1. **reap** — queued requests whose [`CancelToken`] fired are dropped
//!    before they ever allocate anything;
//! 2. **admit** — queued requests are admitted FIFO up to `max_active` and
//!    the backend's memory gate; a `new_seq` failure fails only that request
//!    (the remaining admissions and the advance phase still run);
//! 3. **advance** — every active sequence gets exactly one unit of work (one
//!    prefill window or one decode quantum) in admission order. Finished and
//!    failed sequences are removed *order-preservingly* (no `swap_remove`
//!    reshuffling), and a sequence whose token fired is dropped before its
//!    quantum — dropping the backend sequence returns its paged-KV arena
//!    pages to the pool immediately.
//!
//! The backend is abstracted so the scheduler logic is unit-testable without
//! a PJRT runtime. TTFT is stamped by the backend at the moment the first
//! token of a quantum materializes ([`Decoded::t_first`]), not when the
//! whole quantum returns.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

/// Shared cancellation flag connecting a connection handler to every
/// request it has in flight: the handler fires it when the client
/// disconnects, and the scheduler drops the sequence (releasing its arena
/// pages) before spending another quantum on it.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// One decode quantum's output. `t_first` is the instant the FIRST token of
/// the quantum became available (after the first program call inside the
/// quantum); `None` means the backend has no finer signal and the scheduler
/// stamps on receipt.
pub struct Decoded {
    pub tokens: Vec<i32>,
    pub t_first: Option<Instant>,
}

/// Execution backend for one sequence (real impl wraps [`crate::engine::Engine`]).
pub trait SeqBackend {
    type Seq;
    fn new_seq(&mut self) -> Result<Self::Seq>;
    /// Cross-request prefix reuse, called once right after [`Self::new_seq`]
    /// during admission (unless the request opted out): the backend may
    /// install an already-computed KV prefix into the fresh sequence and
    /// return how many leading prompt tokens it covers — the scheduler then
    /// starts the sequence `prefilling` at that position, skipping their
    /// device-side prefill entirely. 0 (the default) means a cold start.
    fn adopt_prefix(&mut self, seq: &mut Self::Seq, prompt: &[i32]) -> usize {
        let _ = (seq, prompt);
        0
    }
    /// Ingest a prompt chunk.
    fn prefill_chunk(&mut self, seq: &mut Self::Seq, chunk: &[i32]) -> Result<()>;
    /// Greedy-decode up to `n` tokens.
    fn decode(&mut self, seq: &mut Self::Seq, n: usize) -> Result<Decoded>;
    /// Admission gate beyond the active-count cap: return false to defer
    /// admitting more sequences this round (real backends report paged-KV
    /// arena pressure plus the runtime's staging tiers — device-resident
    /// K/V images and host scratch images; queued work stays queued until
    /// bytes free up). Called in every round's admit phase while the active
    /// set has headroom — even with an empty queue — so backends use it to
    /// sweep staging state of sequences dropped last round (cancellation
    /// teardown; a saturated active set is covered by the sweeps inside the
    /// runtime calls the advance phase makes).
    /// `active` is the number of already-admitted sequences, so backends can
    /// reserve headroom for sequences that have not allocated pages yet.
    fn can_admit(&self, active: usize) -> bool {
        let _ = active;
        true
    }
}

#[derive(Clone, Debug)]
pub struct Finished {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    /// Leading prompt tokens served from an adopted cross-request prefix
    /// (their prefill never ran; 0 for cold starts).
    pub prefix_tokens: usize,
    pub queue_s: f64,
    pub ttft_s: f64,
    pub total_s: f64,
    pub error: Option<String>,
    /// True when the sequence exited because its [`CancelToken`] fired (the
    /// client is gone; no response should be written).
    pub cancelled: bool,
}

struct Pending {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    t_submit: Instant,
    cancel: CancelToken,
    /// False when the request opted out of cross-request prefix reuse
    /// (protocol `prefix_hint: false`).
    allow_prefix: bool,
}

struct Active<S> {
    id: u64,
    prompt: Vec<i32>,
    pos: usize,
    /// Prompt tokens covered by an adopted prefix at admission.
    prefix_tokens: usize,
    generated: Vec<i32>,
    max_new: usize,
    t_submit: Instant,
    t_admit: Instant,
    t_first: Option<Instant>,
    cancel: CancelToken,
    seq: S,
}

impl<S> Active<S> {
    /// Consume into a `cancelled` record; dropping `self.seq` here is what
    /// returns the sequence's arena pages.
    fn into_cancelled(self) -> Finished {
        let now = Instant::now();
        Finished {
            id: self.id,
            tokens: self.generated,
            prompt_tokens: self.prompt.len(),
            prefix_tokens: self.prefix_tokens,
            queue_s: (self.t_admit - self.t_submit).as_secs_f64(),
            ttft_s: self.t_first.map(|t| (t - self.t_submit).as_secs_f64()).unwrap_or_default(),
            total_s: (now - self.t_submit).as_secs_f64(),
            error: None,
            cancelled: true,
        }
    }
}

pub struct Scheduler<B: SeqBackend> {
    backend: B,
    pub window: usize,
    pub quantum: usize,
    pub max_active: usize,
    pub max_queue: usize,
    queue: VecDeque<Pending>,
    active: Vec<Active<B::Seq>>,
    next_id: u64,
}

impl<B: SeqBackend> Scheduler<B> {
    pub fn new(
        backend: B,
        window: usize,
        quantum: usize,
        max_active: usize,
        max_queue: usize,
    ) -> Self {
        Self {
            backend,
            window,
            quantum,
            max_active,
            max_queue,
            queue: VecDeque::new(),
            active: Vec::new(),
            next_id: 1,
        }
    }

    /// Admission control: Err when the queue is full (backpressure).
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize, cancel: CancelToken) -> Result<u64> {
        self.submit_opt(prompt, max_new, cancel, true)
    }

    /// [`Self::submit`] with an explicit cross-request prefix-reuse flag
    /// (`false` = the protocol's `prefix_hint: false` opt-out: the sequence
    /// always prefills cold).
    pub fn submit_opt(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        cancel: CancelToken,
        allow_prefix: bool,
    ) -> Result<u64> {
        if self.queue.len() >= self.max_queue {
            anyhow::bail!("queue full ({} pending)", self.queue.len());
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Pending {
            id,
            prompt,
            max_new,
            t_submit: Instant::now(),
            cancel,
            allow_prefix,
        });
        Ok(id)
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    pub fn depth(&self) -> (usize, usize) {
        (self.queue.len(), self.active.len())
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// One scheduling round (reap -> admit -> advance). Returns sequences
    /// that exited this round: completed, errored, or cancelled.
    pub fn step(&mut self) -> Vec<Finished> {
        let mut done = Vec::new();
        self.reap_queue(&mut done);
        self.admit(&mut done);
        self.advance(&mut done);
        done
    }

    /// Phase 1: drop queued requests whose client disconnected before they
    /// were ever admitted.
    fn reap_queue(&mut self, done: &mut Vec<Finished>) {
        // common case (no cancellations) stays allocation- and move-free
        if !self.queue.iter().any(|p| p.cancel.is_cancelled()) {
            return;
        }
        let mut kept = VecDeque::with_capacity(self.queue.len());
        for p in self.queue.drain(..) {
            if p.cancel.is_cancelled() {
                let now = Instant::now();
                done.push(Finished {
                    id: p.id,
                    tokens: Vec::new(),
                    prompt_tokens: p.prompt.len(),
                    prefix_tokens: 0,
                    queue_s: (now - p.t_submit).as_secs_f64(),
                    ttft_s: 0.0,
                    total_s: (now - p.t_submit).as_secs_f64(),
                    error: None,
                    cancelled: true,
                });
            } else {
                kept.push_back(p);
            }
        }
        self.queue = kept;
    }

    /// Phase 2: FIFO admission up to the active cap and the backend's memory
    /// gate. A `new_seq` failure fails only that request: the remaining
    /// queue still gets its admission chance and the advance phase still
    /// runs this round.
    fn admit(&mut self, done: &mut Vec<Finished>) {
        while self.active.len() < self.max_active && self.backend.can_admit(self.active.len()) {
            let Some(p) = self.queue.pop_front() else { break };
            match self.backend.new_seq() {
                Ok(mut seq) => {
                    // cross-request prefix reuse: start prefilling past the
                    // span the backend served from its prefix cache
                    let matched = if p.allow_prefix {
                        self.backend.adopt_prefix(&mut seq, &p.prompt).min(p.prompt.len())
                    } else {
                        0
                    };
                    self.active.push(Active {
                        id: p.id,
                        prompt: p.prompt,
                        pos: matched,
                        prefix_tokens: matched,
                        generated: Vec::new(),
                        max_new: p.max_new,
                        t_submit: p.t_submit,
                        t_admit: Instant::now(),
                        t_first: None,
                        cancel: p.cancel,
                        seq,
                    })
                }
                Err(e) => {
                    done.push(finished_err(p.id, p.prompt.len(), 0, p.t_submit, None, None, e));
                }
            }
        }
    }

    /// Phase 3: one unit of work per active sequence, in admission order.
    fn advance(&mut self, done: &mut Vec<Finished>) {
        let window = self.window;
        let quantum = self.quantum;
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].cancel.is_cancelled() {
                // drop between quanta: the seq (and its KvCache pages) is
                // freed before any more device time is spent on it
                done.push(self.active.remove(i).into_cancelled());
                continue;
            }
            let a = &mut self.active[i];
            let result: Result<bool> = (|| {
                if a.pos < a.prompt.len() {
                    let end = (a.pos + window).min(a.prompt.len());
                    self.backend.prefill_chunk(&mut a.seq, &a.prompt[a.pos..end])?;
                    a.pos = end;
                    Ok(false)
                } else {
                    let n = quantum.min(a.max_new - a.generated.len());
                    let d = self.backend.decode(&mut a.seq, n)?;
                    if a.t_first.is_none() {
                        a.t_first = Some(d.t_first.unwrap_or_else(Instant::now));
                    }
                    a.generated.extend(d.tokens);
                    Ok(a.generated.len() >= a.max_new)
                }
            })();
            match result {
                Ok(true) => {
                    let a = self.active.remove(i);
                    let now = Instant::now();
                    done.push(Finished {
                        id: a.id,
                        tokens: a.generated,
                        prompt_tokens: a.prompt.len(),
                        prefix_tokens: a.prefix_tokens,
                        queue_s: (a.t_admit - a.t_submit).as_secs_f64(),
                        ttft_s: a
                            .t_first
                            .map(|t| (t - a.t_submit).as_secs_f64())
                            .unwrap_or_default(),
                        total_s: (now - a.t_submit).as_secs_f64(),
                        error: None,
                        cancelled: false,
                    });
                }
                Ok(false) => i += 1,
                Err(e) => {
                    let a = self.active.remove(i);
                    done.push(finished_err(
                        a.id,
                        a.prompt.len(),
                        a.prefix_tokens,
                        a.t_submit,
                        Some(a.t_admit),
                        a.t_first,
                        e,
                    ));
                }
            }
        }
    }
}

/// Error exit with REAL timings: `queue_s` is the true submit->admit wait
/// (or the full submit->failure wait when the request never got admitted),
/// and `ttft_s` survives if a first token had already been emitted.
fn finished_err(
    id: u64,
    prompt_tokens: usize,
    prefix_tokens: usize,
    t_submit: Instant,
    t_admit: Option<Instant>,
    t_first: Option<Instant>,
    e: anyhow::Error,
) -> Finished {
    let now = Instant::now();
    Finished {
        id,
        tokens: Vec::new(),
        prompt_tokens,
        prefix_tokens,
        queue_s: (t_admit.unwrap_or(now) - t_submit).as_secs_f64(),
        ttft_s: t_first.map(|t| (t - t_submit).as_secs_f64()).unwrap_or_default(),
        total_s: (now - t_submit).as_secs_f64(),
        error: Some(format!("{e:#}")),
        cancelled: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{KvArena, KvCache};

    /// Mock backend: "generates" token 100+len; fails on prompts containing -1.
    struct Mock {
        prefilled: usize,
        admit: bool,
        new_seq_calls: usize,
        new_seq_fails_at: Option<usize>,
    }

    struct MockSeq {
        emitted: usize,
    }

    impl SeqBackend for Mock {
        type Seq = MockSeq;
        fn new_seq(&mut self) -> Result<MockSeq> {
            let call = self.new_seq_calls;
            self.new_seq_calls += 1;
            if self.new_seq_fails_at == Some(call) {
                anyhow::bail!("no pages");
            }
            Ok(MockSeq { emitted: 0 })
        }
        fn can_admit(&self, _active: usize) -> bool {
            self.admit
        }
        fn prefill_chunk(&mut self, _seq: &mut MockSeq, chunk: &[i32]) -> Result<()> {
            if chunk.contains(&-1) {
                anyhow::bail!("poison prompt");
            }
            self.prefilled += chunk.len();
            Ok(())
        }
        fn decode(&mut self, seq: &mut MockSeq, n: usize) -> Result<Decoded> {
            let tokens: Vec<i32> = (0..n).map(|i| 100 + (seq.emitted + i) as i32).collect();
            seq.emitted += n;
            Ok(Decoded { tokens, t_first: Some(Instant::now()) })
        }
    }

    fn mock() -> Mock {
        Mock { prefilled: 0, admit: true, new_seq_calls: 0, new_seq_fails_at: None }
    }

    fn sched() -> Scheduler<Mock> {
        Scheduler::new(mock(), 8, 4, 2, 4)
    }

    fn submit(s: &mut Scheduler<Mock>, prompt: Vec<i32>, max_new: usize) -> u64 {
        s.submit(prompt, max_new, CancelToken::new()).unwrap()
    }

    #[test]
    fn admission_deferred_while_backend_gates() {
        let mut s = Scheduler::new(Mock { admit: false, ..mock() }, 8, 4, 2, 4);
        submit(&mut s, vec![1, 2], 1);
        s.step();
        assert_eq!(s.depth(), (1, 0), "admitted despite backend pressure");
        s.backend_mut().admit = true;
        s.step();
        assert_eq!(s.depth().1, 1);
        let mut finished = Vec::new();
        while s.has_work() {
            finished.extend(s.step());
        }
        assert_eq!(finished.len(), 1);
        assert!(finished[0].error.is_none());
    }

    #[test]
    fn single_request_lifecycle() {
        let mut s = sched();
        let id = submit(&mut s, (0..20).collect(), 6);
        let mut finished = Vec::new();
        let mut rounds = 0;
        while s.has_work() && rounds < 100 {
            finished.extend(s.step());
            rounds += 1;
        }
        assert_eq!(finished.len(), 1);
        let f = &finished[0];
        assert_eq!(f.id, id);
        assert_eq!(f.tokens, vec![100, 101, 102, 103, 104, 105]);
        assert_eq!(f.prompt_tokens, 20);
        assert!(f.error.is_none());
        assert!(!f.cancelled);
        // 20-token prompt at window 8 = 3 prefill rounds; 6 tokens at
        // quantum 4 = 2 decode rounds
        assert_eq!(rounds, 5);
    }

    #[test]
    fn interleaves_up_to_max_active() {
        let mut s = sched();
        for _ in 0..4 {
            submit(&mut s, (0..8).collect(), 4);
        }
        let (q, a) = s.depth();
        assert_eq!((q, a), (4, 0));
        s.step();
        assert_eq!(s.depth().1, 2); // max_active respected
        let mut finished = 0;
        for _ in 0..50 {
            finished += s.step().len();
            if finished == 4 {
                break;
            }
        }
        assert_eq!(finished, 4);
    }

    #[test]
    fn admission_control_backpressure() {
        let mut s = sched();
        for _ in 0..4 {
            submit(&mut s, vec![1], 1);
        }
        assert!(s.submit(vec![1], 1, CancelToken::new()).is_err(), "queue should be full");
    }

    #[test]
    fn backend_error_fails_only_that_sequence() {
        let mut s = sched();
        submit(&mut s, vec![1, 2, 3], 2);
        submit(&mut s, vec![-1], 2); // poison
        let mut oks = 0;
        let mut errs = 0;
        for _ in 0..20 {
            for f in s.step() {
                if f.error.is_some() {
                    errs += 1;
                } else {
                    oks += 1;
                }
            }
            if !s.has_work() {
                break;
            }
        }
        assert_eq!((oks, errs), (1, 1));
    }

    #[test]
    fn timings_populated() {
        let mut s = sched();
        submit(&mut s, vec![1, 2], 1);
        let mut out = Vec::new();
        while s.has_work() {
            out.extend(s.step());
        }
        let f = &out[0];
        assert!(f.total_s >= f.ttft_s);
        assert!(f.ttft_s > 0.0);
    }

    #[test]
    fn new_seq_failure_is_isolated_from_the_round() {
        // regression: a new_seq failure used to abort the whole round,
        // skipping the remaining admissions AND the advance phase
        let mut s = Scheduler::new(Mock { new_seq_fails_at: Some(1), ..mock() }, 8, 4, 3, 8);
        let a = submit(&mut s, vec![1; 4], 2);
        let b = submit(&mut s, vec![2; 4], 2); // this one's new_seq fails
        let c = submit(&mut s, vec![3; 4], 2);
        std::thread::sleep(std::time::Duration::from_millis(2));
        let first_round = s.step();
        // the failure surfaced immediately, the other two were admitted AND
        // advanced (prefill ran) in the same round
        assert_eq!(first_round.len(), 1);
        let f = &first_round[0];
        assert_eq!(f.id, b);
        assert!(f.error.is_some());
        assert!(f.queue_s >= 0.002, "errored request must keep its real queue time");
        assert!(f.total_s >= f.queue_s);
        assert_eq!(s.depth(), (0, 2), "remaining admissions must not be skipped");
        assert_eq!(s.backend().prefilled, 8, "advance phase must still run");
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.step());
        }
        let mut ok_ids: Vec<u64> =
            done.iter().filter(|f| f.error.is_none()).map(|f| f.id).collect();
        ok_ids.sort_unstable();
        assert_eq!(ok_ids, vec![a, c]);
    }

    /// Backend with a canned prefix-match length (cross-request reuse mock).
    struct PrefixMock {
        inner: Mock,
        matched: usize,
        adopt_calls: usize,
    }

    impl SeqBackend for PrefixMock {
        type Seq = MockSeq;
        fn new_seq(&mut self) -> Result<MockSeq> {
            self.inner.new_seq()
        }
        fn adopt_prefix(&mut self, _seq: &mut MockSeq, prompt: &[i32]) -> usize {
            self.adopt_calls += 1;
            self.matched.min(prompt.len())
        }
        fn prefill_chunk(&mut self, seq: &mut MockSeq, chunk: &[i32]) -> Result<()> {
            self.inner.prefill_chunk(seq, chunk)
        }
        fn decode(&mut self, seq: &mut MockSeq, n: usize) -> Result<Decoded> {
            self.inner.decode(seq, n)
        }
    }

    fn prefix_sched(matched: usize) -> Scheduler<PrefixMock> {
        Scheduler::new(PrefixMock { inner: mock(), matched, adopt_calls: 0 }, 8, 4, 2, 4)
    }

    #[test]
    fn adopted_prefix_skips_matched_prefill() {
        // 20-token prompt, 16 matched at admission: only the 4-token tail
        // is ever prefilled, and the finish record reports the reuse
        let mut s = prefix_sched(16);
        s.submit(vec![1; 20], 4, CancelToken::new()).unwrap();
        let mut done = Vec::new();
        let mut rounds = 0;
        while s.has_work() && rounds < 20 {
            done.extend(s.step());
            rounds += 1;
        }
        assert_eq!(done.len(), 1);
        assert!(done[0].error.is_none());
        assert_eq!(done[0].prefix_tokens, 16);
        assert_eq!(done[0].prompt_tokens, 20);
        assert_eq!(s.backend().inner.prefilled, 4, "matched span must never prefill");
        assert_eq!(s.backend().adopt_calls, 1);
        // one prefill round (the 4-token tail) + one decode round
        assert_eq!(rounds, 2);
    }

    #[test]
    fn fully_matched_prompt_goes_straight_to_decode() {
        let mut s = prefix_sched(64);
        s.submit(vec![1; 8], 4, CancelToken::new()).unwrap();
        let done = s.step(); // admit + first (and only) decode quantum
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].prefix_tokens, 8, "match is clamped to the prompt length");
        assert_eq!(done[0].tokens.len(), 4);
        assert_eq!(s.backend().inner.prefilled, 0);
    }

    #[test]
    fn prefix_opt_out_prefills_cold() {
        let mut s = prefix_sched(64);
        s.submit_opt(vec![1; 8], 2, CancelToken::new(), false).unwrap();
        let mut done = Vec::new();
        while s.has_work() {
            done.extend(s.step());
        }
        assert_eq!(done[0].prefix_tokens, 0);
        assert_eq!(s.backend().adopt_calls, 0, "opt-out must not consult the prefix cache");
        assert_eq!(s.backend().inner.prefilled, 8);
    }

    #[test]
    fn fairness_no_starvation_across_eight_sequences() {
        // 8 sequences with mixed prefill/decode workloads, all admitted in
        // round 1: every sequence must advance one unit EVERY round, so each
        // finishes exactly at its workload's round count — order-preserving
        // removal must not starve or reorder anyone.
        let window = 8;
        let quantum = 4;
        let mut s = Scheduler::new(mock(), window, quantum, 8, 16);
        let loads: Vec<(usize, usize)> = vec![
            (4, 4),   // 1 prefill + 1 decode round
            (20, 4),  // 3 + 1
            (8, 12),  // 1 + 3
            (16, 8),  // 2 + 2
            (4, 16),  // 1 + 4
            (24, 4),  // 3 + 1
            (8, 4),   // 1 + 1
            (12, 20), // 2 + 5
        ];
        let mut expected = std::collections::BTreeMap::new();
        for &(p, m) in &loads {
            let id = submit(&mut s, vec![1; p], m);
            expected.insert(id, p.div_ceil(window) + m.div_ceil(quantum));
        }
        let mut finish_round = std::collections::BTreeMap::new();
        for round in 1usize..=20 {
            for f in s.step() {
                assert!(f.error.is_none());
                finish_round.insert(f.id, round);
            }
            if !s.has_work() {
                break;
            }
        }
        assert_eq!(finish_round.len(), loads.len());
        for (id, rounds) in &expected {
            assert_eq!(
                finish_round.get(id),
                Some(rounds),
                "sequence {id} was starved or served out of turn"
            );
        }
    }

    /// Backend whose sequences hold real paged-KV arena pages, so tests can
    /// observe cancellation returning bytes to the pool.
    struct ArenaMock {
        arena: KvArena,
    }

    struct ArenaMockSeq {
        kv: KvCache,
        pos: u64,
    }

    impl ArenaMock {
        fn append(&self, s: &mut ArenaMockSeq, n: usize) -> Result<()> {
            let row = vec![0.5f32; 2 * n * 4];
            for layer in 0..2 {
                s.kv.append_layer(layer, &row, &row, n, n, s.pos)?;
            }
            s.pos += n as u64;
            Ok(())
        }
    }

    impl SeqBackend for ArenaMock {
        type Seq = ArenaMockSeq;
        fn new_seq(&mut self) -> Result<ArenaMockSeq> {
            Ok(ArenaMockSeq { kv: KvCache::with_arena(self.arena.clone(), 2, 2, 256, 4), pos: 0 })
        }
        fn prefill_chunk(&mut self, seq: &mut ArenaMockSeq, chunk: &[i32]) -> Result<()> {
            self.append(seq, chunk.len())
        }
        fn decode(&mut self, seq: &mut ArenaMockSeq, n: usize) -> Result<Decoded> {
            self.append(seq, n)?;
            Ok(Decoded { tokens: vec![7; n], t_first: None })
        }
    }

    #[test]
    fn cancel_mid_prefill_releases_arena_bytes() {
        let arena = KvArena::new();
        let mut s = Scheduler::new(ArenaMock { arena: arena.clone() }, 8, 4, 2, 4);
        let cancel = CancelToken::new();
        s.submit(vec![1; 32], 8, cancel.clone()).unwrap();
        s.step(); // admit + first prefill window (8 of 32 tokens)
        assert_eq!(s.depth(), (0, 1));
        assert!(arena.stats().bytes_in_use > 0, "prefill must occupy pages");
        cancel.cancel();
        let done = s.step();
        assert_eq!(done.len(), 1);
        assert!(done[0].cancelled);
        assert!(done[0].error.is_none());
        assert_eq!(
            arena.stats().bytes_in_use,
            0,
            "cancelled mid-prefill sequence must return its pages immediately"
        );
        assert!(!s.has_work());
    }

    #[test]
    fn cancel_mid_decode_releases_arena_bytes() {
        let arena = KvArena::new();
        let mut s = Scheduler::new(ArenaMock { arena: arena.clone() }, 8, 4, 2, 4);
        let cancel = CancelToken::new();
        s.submit(vec![1; 8], 64, cancel.clone()).unwrap();
        s.step(); // admit + full prefill
        s.step(); // first decode quantum (4 of 64 tokens)
        let mid = arena.stats().bytes_in_use;
        assert!(mid > 0, "decoding sequence must occupy pages");
        cancel.cancel();
        let done = s.step();
        assert_eq!(done.len(), 1);
        assert!(done[0].cancelled);
        assert_eq!(done[0].tokens.len(), 4, "tokens decoded before the cancel are reported");
        assert!(done[0].ttft_s > 0.0, "cancelled-after-first-token keeps its TTFT");
        assert_eq!(
            arena.stats().bytes_in_use,
            0,
            "cancelled mid-decode sequence must return its pages before the next round"
        );
    }

    #[test]
    fn cancel_while_queued_never_admits() {
        let mut s = sched();
        let cancel = CancelToken::new();
        s.submit(vec![1; 4], 2, cancel.clone()).unwrap();
        cancel.cancel();
        let done = s.step();
        assert_eq!(done.len(), 1);
        assert!(done[0].cancelled);
        assert_eq!(done[0].tokens.len(), 0);
        assert!(done[0].queue_s >= 0.0);
        assert_eq!(s.backend().new_seq_calls, 0, "cancelled queued request must not admit");
        assert!(!s.has_work());
    }

    /// Backend whose sequences are ALSO resident in a device tier (the
    /// serving shape after the residency refactor): decode promotes the
    /// sequence's KV image onto the device, `can_admit` sweeps the tier.
    struct DeviceTierMock {
        arena: KvArena,
        client: xla::PjRtClient,
        tier: std::cell::RefCell<crate::runtime::DeviceTier>,
        pool: std::cell::RefCell<crate::runtime::ScratchPool>,
    }

    impl DeviceTierMock {
        fn new() -> Self {
            Self {
                arena: KvArena::new(),
                client: xla::PjRtClient::cpu().unwrap(),
                tier: std::cell::RefCell::new(crate::runtime::DeviceTier::new(1 << 24)),
                pool: std::cell::RefCell::new(crate::runtime::ScratchPool::new(4)),
            }
        }

        fn staging_bytes(&self) -> usize {
            self.tier.borrow().resident_bytes() + self.pool.borrow().resident_bytes()
        }

        fn append_and_acquire(&self, s: &mut ArenaMockSeq, n: usize) -> Result<()> {
            let row = vec![0.5f32; 2 * n * 4];
            for layer in 0..2 {
                s.kv.append_layer(layer, &row, &row, n, n, s.pos)?;
            }
            s.pos += n as u64;
            let mut tier = self.tier.borrow_mut();
            let mut pool = self.pool.borrow_mut();
            tier.acquire(&self.client, &mut s.kv, &mut pool)?;
            Ok(())
        }
    }

    impl SeqBackend for DeviceTierMock {
        type Seq = ArenaMockSeq;
        fn new_seq(&mut self) -> Result<ArenaMockSeq> {
            Ok(ArenaMockSeq { kv: KvCache::with_arena(self.arena.clone(), 2, 2, 256, 4), pos: 0 })
        }
        fn prefill_chunk(&mut self, seq: &mut ArenaMockSeq, chunk: &[i32]) -> Result<()> {
            self.append_and_acquire(seq, chunk.len())
        }
        fn decode(&mut self, seq: &mut ArenaMockSeq, n: usize) -> Result<Decoded> {
            self.append_and_acquire(seq, n)?;
            Ok(Decoded { tokens: vec![7; n], t_first: None })
        }
        fn can_admit(&self, _active: usize) -> bool {
            // the real backend's shape: sweep dead staging state before
            // counting it against the admission budget
            self.tier.borrow_mut().sweep();
            self.pool.borrow_mut().sweep();
            true
        }
    }

    #[test]
    fn cancelled_sequence_frees_device_tier_before_next_round_admits() {
        // regression: cancellation teardown must release the sequence's
        // device-tier buffers (and scratch image) like the KvCache Drop ->
        // arena page return path, BEFORE the next round's admission counts
        // staging bytes
        let mut s = Scheduler::new(DeviceTierMock::new(), 8, 4, 2, 4);
        let cancel = CancelToken::new();
        s.submit(vec![1; 8], 64, cancel.clone()).unwrap();
        s.step(); // admit + prefill (promotes the KV image into the tier)
        s.step(); // first decode quantum
        assert!(s.backend().staging_bytes() > 0, "decoding sequence must be device-resident");
        assert!(s.backend().arena.stats().bytes_in_use > 0);
        cancel.cancel();
        let done = s.step(); // reap: the seq (and its KvCache) is dropped
        assert!(done.iter().any(|f| f.cancelled));
        assert_eq!(s.backend().arena.stats().bytes_in_use, 0, "arena pages returned");
        s.step(); // next round: the admit phase's can_admit sweeps staging
        assert_eq!(
            s.backend().staging_bytes(),
            0,
            "cancelled sequence's device-resident bytes must be freed before \
             the next round admits"
        );
    }

    #[test]
    fn cancellation_does_not_stall_other_sequences() {
        let mut s = Scheduler::new(mock(), 8, 4, 4, 8);
        let cancel = CancelToken::new();
        submit(&mut s, vec![1; 8], 8);
        s.submit(vec![2; 8], 8, cancel.clone()).unwrap();
        submit(&mut s, vec![3; 8], 8);
        s.step(); // all admitted + prefilled
        cancel.cancel();
        let mut done = Vec::new();
        for _ in 0..10 {
            done.extend(s.step());
            if !s.has_work() {
                break;
            }
        }
        assert_eq!(done.len(), 3);
        assert_eq!(done.iter().filter(|f| f.cancelled).count(), 1);
        assert_eq!(
            done.iter().filter(|f| !f.cancelled && f.error.is_none()).count(),
            2,
            "survivors must complete normally"
        );
    }
}
