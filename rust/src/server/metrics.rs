//! Serving metrics registry: request counters, TTFT / end-to-end latency
//! distributions, token throughput, and the runtime transfer counters
//! (upload/download volume, incremental-gather traffic). Exported over the
//! wire via `op:stats`.

use std::time::Instant;

use crate::runtime::RuntimeStats;
use crate::util::json::Json;
use crate::util::stats::{Meter, Samples};

#[derive(Debug)]
pub struct Metrics {
    pub started: Instant,
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub errored: u64,
    pub queue_s: Samples,
    pub ttft_s: Samples,
    pub total_s: Samples,
    pub gen_tokens: Meter,
    pub prompt_tokens: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            started: Instant::now(),
            submitted: 0,
            completed: 0,
            rejected: 0,
            errored: 0,
            queue_s: Samples::new(),
            ttft_s: Samples::new(),
            total_s: Samples::new(),
            gen_tokens: Meter::default(),
            prompt_tokens: 0,
        }
    }
}

impl Metrics {
    pub fn record_finished(&mut self, f: &crate::server::batcher::Finished) {
        if f.error.is_some() {
            self.errored += 1;
            return;
        }
        self.completed += 1;
        self.queue_s.record(f.queue_s);
        self.ttft_s.record(f.ttft_s);
        self.total_s.record(f.total_s);
        self.gen_tokens.add(f.tokens.len() as u64, f.total_s);
        self.prompt_tokens += f.prompt_tokens as u64;
    }

    pub fn to_json(&self) -> Json {
        let uptime = self.started.elapsed().as_secs_f64();
        Json::from_pairs(vec![
            ("uptime_s", uptime.into()),
            ("submitted", (self.submitted as i64).into()),
            ("completed", (self.completed as i64).into()),
            ("rejected", (self.rejected as i64).into()),
            ("errored", (self.errored as i64).into()),
            ("prompt_tokens", (self.prompt_tokens as i64).into()),
            ("gen_tokens", (self.gen_tokens.count as i64).into()),
            ("gen_tokens_per_s", self.gen_tokens.rate().into()),
            ("throughput_req_per_s", (self.completed as f64 / uptime.max(1e-9)).into()),
            ("ttft_ms_p50", (self.ttft_s.p50() * 1e3).into()),
            ("ttft_ms_p95", (self.ttft_s.p95() * 1e3).into()),
            ("latency_ms_p50", (self.total_s.p50() * 1e3).into()),
            ("latency_ms_p95", (self.total_s.p95() * 1e3).into()),
            ("queue_ms_p95", (self.queue_s.p95() * 1e3).into()),
        ])
    }
}

/// Attach the runtime's call/transfer counters to an `op:stats` payload so
/// serving deployments can watch transfer volume per token: `bytes_h2d` /
/// `bytes_d2h` are total PJRT upload/download traffic, `gathered_bytes` is
/// the host-side page->scratch copy volume the dirty-range tracking drives
/// toward zero (see PERF.md), and the gather counters break calls down into
/// full / incremental / no-op materializations.
pub fn export_runtime(j: &mut Json, rs: &RuntimeStats) {
    j.set("runtime_calls", (rs.calls as i64).into());
    j.set("runtime_upload_s", rs.upload_s.into());
    j.set("runtime_execute_s", rs.execute_s.into());
    j.set("runtime_download_s", rs.download_s.into());
    j.set("bytes_h2d", (rs.bytes_h2d as i64).into());
    j.set("bytes_d2h", (rs.bytes_d2h as i64).into());
    j.set("gather_s", rs.gather_s.into());
    j.set("gathered_bytes", (rs.gathered_bytes as i64).into());
    j.set("gathers_full", (rs.gathers_full as i64).into());
    j.set("gathers_incremental", (rs.gathers_incremental as i64).into());
    j.set("gathers_noop", (rs.gathers_noop as i64).into());
    j.set("dense_scratch_allocs", (rs.dense_scratch_allocs as i64).into());
    j.set("scratch_resident_bytes", (rs.scratch_resident_bytes as i64).into());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::batcher::Finished;

    #[test]
    fn records_and_exports() {
        let mut m = Metrics::default();
        m.submitted = 2;
        m.record_finished(&Finished {
            id: 1,
            tokens: vec![1, 2, 3, 4],
            prompt_tokens: 10,
            queue_s: 0.001,
            ttft_s: 0.01,
            total_s: 0.05,
            error: None,
        });
        m.record_finished(&Finished {
            id: 2,
            tokens: vec![],
            prompt_tokens: 5,
            queue_s: 0.0,
            ttft_s: 0.0,
            total_s: 0.01,
            error: Some("boom".into()),
        });
        let j = m.to_json();
        assert_eq!(j.usize_of("completed"), Some(1));
        assert_eq!(j.usize_of("errored"), Some(1));
        assert_eq!(j.usize_of("gen_tokens"), Some(4));
        assert!(j.f64_of("ttft_ms_p50").unwrap() > 9.0);
    }

    #[test]
    fn exports_runtime_transfer_counters() {
        let m = Metrics::default();
        let mut j = m.to_json();
        let rs = RuntimeStats {
            calls: 3,
            bytes_h2d: 1024,
            bytes_d2h: 2048,
            gather_s: 0.25,
            gathered_bytes: 96,
            gathers_full: 1,
            gathers_incremental: 1,
            gathers_noop: 1,
            dense_scratch_allocs: 1,
            scratch_resident_bytes: 4096,
            ..Default::default()
        };
        export_runtime(&mut j, &rs);
        assert_eq!(j.usize_of("runtime_calls"), Some(3));
        assert_eq!(j.usize_of("bytes_h2d"), Some(1024));
        assert_eq!(j.usize_of("bytes_d2h"), Some(2048));
        assert_eq!(j.usize_of("gathered_bytes"), Some(96));
        assert_eq!(j.usize_of("gathers_noop"), Some(1));
        assert_eq!(j.usize_of("dense_scratch_allocs"), Some(1));
        assert_eq!(j.usize_of("scratch_resident_bytes"), Some(4096));
        assert!(j.f64_of("gather_s").unwrap() > 0.2);
    }
}
