//! Serving metrics registry: request counters, TTFT / inter-token /
//! end-to-end latency distributions, token throughput, reactor intake
//! depth, cancellation and
//! post-shutdown rejection counters, and the runtime transfer counters
//! (upload/download volume, incremental-gather traffic). Exported over the
//! wire via `op:stats` (JSON) and `op:metrics` (Prometheus text, see
//! [`prometheus_text`]).
//!
//! Latency distributions are fixed-memory log-bucket [`Histogram`]s (they
//! used to be unbounded per-request sample vectors — one entry per request
//! forever on a long-running server). Quantile keys keep their historical
//! `*_p50` / `*_p95` names; values are bucket-resolution (~25% per step)
//! clamped to the exact observed min/max.

use std::time::Instant;

use crate::runtime::{ArenaStats, PlacementStats, PrefixStats, RuntimeStats};
use crate::server::batcher::ShardHealth;
use crate::util::json::Json;
use crate::util::stats::{Histogram, Meter};

/// Log-bucket scheme for latency histograms: 64 geometric buckets over
/// [100 µs, 100 s] (≈24% ratio per bucket), values in seconds.
pub fn latency_histogram() -> Histogram {
    Histogram::new(1e-4, 100.0, 64)
}

/// Log-bucket scheme for the intake burst-depth histogram: 49 geometric
/// buckets over [1, 4096] requests per round (bounds land on powers of 2).
pub fn depth_histogram() -> Histogram {
    Histogram::new(1.0, 4096.0, 49)
}

#[derive(Debug)]
pub struct Metrics {
    pub started: Instant,
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Generate requests refused because `op:shutdown` had already been
    /// accepted (distinct from backpressure rejections).
    pub rejected_shutdown: u64,
    pub errored: u64,
    /// Sequences dropped because their client disconnected.
    pub cancelled: u64,
    /// Reactor rounds observed (each round fully drains the intake channel).
    pub intake_rounds: u64,
    /// Generate requests drained per non-empty intake round (the burst
    /// depth the decoupled intake absorbs in one round; control ops like
    /// stats polls are excluded so they don't dilute the statistic).
    pub intake_depth: Histogram,
    pub queue_s: Histogram,
    pub ttft_s: Histogram,
    pub total_s: Histogram,
    /// Per-step inter-token latency distribution (seconds per token),
    /// recorded at every decode-quantum completion across ALL sequences —
    /// unlike the per-request means, this distribution exposes the stalls
    /// one long prefill inflicts on concurrently decoding sequences.
    pub itl_s: Histogram,
    pub gen_tokens: Meter,
    pub prompt_tokens: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            started: Instant::now(),
            submitted: 0,
            completed: 0,
            rejected: 0,
            rejected_shutdown: 0,
            errored: 0,
            cancelled: 0,
            intake_rounds: 0,
            intake_depth: depth_histogram(),
            queue_s: latency_histogram(),
            ttft_s: latency_histogram(),
            total_s: latency_histogram(),
            itl_s: latency_histogram(),
            gen_tokens: Meter::default(),
            prompt_tokens: 0,
        }
    }
}

impl Metrics {
    /// One reactor round drained `drained` generate requests from the
    /// channel.
    pub fn record_intake(&mut self, drained: u64) {
        self.intake_rounds += 1;
        if drained > 0 {
            self.intake_depth.record(drained as f64);
        }
    }

    pub fn record_finished(&mut self, f: &crate::server::batcher::Finished) {
        if f.cancelled {
            self.cancelled += 1;
            return;
        }
        if f.error.is_some() {
            self.errored += 1;
            // queue time is a scheduler property, real even for errored
            // sequences — record it so admission latency is not skewed by
            // dropping failures (ttft/total stay success-only)
            self.queue_s.record(f.queue_s);
            return;
        }
        self.completed += 1;
        self.queue_s.record(f.queue_s);
        self.ttft_s.record(f.ttft_s);
        self.total_s.record(f.total_s);
        self.gen_tokens.add(f.tokens.len() as u64, f.total_s);
        self.prompt_tokens += f.prompt_tokens as u64;
    }

    pub fn to_json(&self) -> Json {
        let uptime = self.started.elapsed().as_secs_f64();
        Json::from_pairs(vec![
            ("uptime_s", uptime.into()),
            ("submitted", (self.submitted as i64).into()),
            ("completed", (self.completed as i64).into()),
            ("rejected", (self.rejected as i64).into()),
            ("rejected_shutdown", (self.rejected_shutdown as i64).into()),
            ("errored", (self.errored as i64).into()),
            ("cancelled", (self.cancelled as i64).into()),
            ("intake_rounds", (self.intake_rounds as i64).into()),
            ("intake_depth_p50", self.intake_depth.p50().into()),
            ("intake_depth_p95", self.intake_depth.p95().into()),
            ("intake_depth_max", self.intake_depth.max().into()),
            ("prompt_tokens", (self.prompt_tokens as i64).into()),
            ("gen_tokens", (self.gen_tokens.count as i64).into()),
            ("gen_tokens_per_s", self.gen_tokens.rate().into()),
            ("throughput_req_per_s", (self.completed as f64 / uptime.max(1e-9)).into()),
            ("ttft_ms_p50", (self.ttft_s.p50() * 1e3).into()),
            ("ttft_ms_p95", (self.ttft_s.p95() * 1e3).into()),
            ("ttft_ms_p99", (self.ttft_s.p99() * 1e3).into()),
            ("latency_ms_p50", (self.total_s.p50() * 1e3).into()),
            ("latency_ms_p95", (self.total_s.p95() * 1e3).into()),
            ("latency_ms_p99", (self.total_s.p99() * 1e3).into()),
            ("queue_ms_p95", (self.queue_s.p95() * 1e3).into()),
            ("itl_ms_p50", (self.itl_s.p50() * 1e3).into()),
            ("itl_ms_p95", (self.itl_s.p95() * 1e3).into()),
            ("itl_ms_p99", (self.itl_s.p99() * 1e3).into()),
            ("itl_ms_max", (self.itl_s.max() * 1e3).into()),
        ])
    }

    /// The latency histograms by Prometheus metric name (seconds), for
    /// native histogram exposition on `op:metrics`.
    pub fn histograms(&self) -> [(&'static str, &Histogram); 5] {
        [
            ("lacache_queue_seconds", &self.queue_s),
            ("lacache_ttft_seconds", &self.ttft_s),
            ("lacache_request_seconds", &self.total_s),
            ("lacache_itl_seconds", &self.itl_s),
            ("lacache_intake_depth", &self.intake_depth),
        ]
    }
}

/// Render an `op:stats`-shaped JSON payload plus the metrics registry's
/// native histograms as Prometheus text exposition (version 0.0.4).
///
/// Every scalar in `stats` becomes one `lacache_<key>` gauge (booleans as
/// 0/1); the `shards` array becomes per-shard gauges labeled
/// `{shard="<device>"}`; non-numeric strings and nested objects are
/// skipped. Histograms are emitted natively (`_bucket{le=...}` / `_sum` /
/// `_count`), so Prometheus can aggregate quantiles across servers instead
/// of scraping pre-computed percentiles.
pub fn prometheus_text(stats: &Json, m: &Metrics) -> String {
    let mut out = String::with_capacity(4096);
    let mut gauge = |name: &str, v: f64| {
        // Prometheus floats: integers render without a fraction already
        // (Json::Num formatting rules match), NaN/inf never reach here
        out.push_str(&format!("# TYPE lacache_{name} gauge\nlacache_{name} {v}\n"));
    };
    if let Some(pairs) = stats.as_obj() {
        for (k, v) in pairs {
            match v {
                Json::Num(x) => gauge(k, *x),
                Json::Bool(b) => gauge(k, f64::from(u8::from(*b))),
                _ => {}
            }
        }
    }
    if let Some(shards) = stats.get("shards").and_then(|s| s.as_arr()) {
        for s in shards {
            let Some(dev) = s.usize_of("device") else { continue };
            let Some(pairs) = s.as_obj() else { continue };
            for (k, v) in pairs {
                if k == "device" {
                    continue;
                }
                let x = match v {
                    Json::Num(x) => *x,
                    Json::Bool(b) => f64::from(u8::from(*b)),
                    _ => continue,
                };
                out.push_str(&format!("lacache_shard_{k}{{shard=\"{dev}\"}} {x}\n"));
            }
        }
    }
    for (name, h) in m.histograms() {
        out.push_str(&format!("# TYPE {name} histogram\n"));
        for (le, n) in h.cumulative_buckets() {
            if le.is_infinite() {
                out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {n}\n"));
            } else {
                out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {n}\n"));
            }
        }
        out.push_str(&format!("{name}_sum {}\n", h.sum()));
        out.push_str(&format!("{name}_count {}\n", h.count()));
    }
    out.push_str(&format!(
        "# TYPE lacache_trace_dropped_total counter\nlacache_trace_dropped_total {}\n",
        crate::obs::recorder().dropped_total()
    ));
    out
}

/// Attach the runtime's call/transfer/residency counters to an `op:stats`
/// payload so serving deployments can watch transfer volume per token:
/// `bytes_h2d` / `bytes_d2h` are total PJRT upload/download traffic,
/// `gathered_bytes` is the host-side page->scratch copy volume the
/// dirty-range tracking drives toward zero (see PERF.md), the gather
/// counters break calls down into full / incremental / no-op
/// materializations, and the residency gauges/counters
/// (`device_resident_bytes`, `residency_hits`/`misses`, `spills`,
/// `donations`, `reconciled_bytes`) describe the device tier that keeps
/// steady-state decode's per-call upload at tokens + lens.
pub fn export_runtime(j: &mut Json, rs: &RuntimeStats) {
    j.set("runtime_calls", (rs.calls as i64).into());
    j.set("runtime_upload_s", rs.upload_s.into());
    j.set("runtime_execute_s", rs.execute_s.into());
    j.set("runtime_download_s", rs.download_s.into());
    j.set("bytes_h2d", (rs.bytes_h2d as i64).into());
    j.set("bytes_d2h", (rs.bytes_d2h as i64).into());
    j.set("gather_s", rs.gather_s.into());
    j.set("dequant_s", rs.dequant_s.into());
    j.set("gathered_bytes", (rs.gathered_bytes as i64).into());
    j.set("gathers_full", (rs.gathers_full as i64).into());
    j.set("gathers_incremental", (rs.gathers_incremental as i64).into());
    j.set("gathers_noop", (rs.gathers_noop as i64).into());
    j.set("dense_scratch_allocs", (rs.dense_scratch_allocs as i64).into());
    j.set("scratch_resident_bytes", (rs.scratch_resident_bytes as i64).into());
    j.set("device_resident_bytes", (rs.device_resident_bytes as i64).into());
    j.set("residency_hits", (rs.residency_hits as i64).into());
    j.set("residency_misses", (rs.residency_misses as i64).into());
    j.set("spills", (rs.spills as i64).into());
    j.set("donations", (rs.donations as i64).into());
    j.set("reconciled_bytes", (rs.reconciled_bytes as i64).into());
}

/// Attach the shared paged-KV arena's occupancy gauges and pool-churn
/// counters, so bench records and dashboards can correlate prefix reuse
/// with real page traffic: `kv_arena_pool_hits` / `kv_arena_pages_allocated`
/// show recycling efficiency, and `cow_copies` counts shared pages that had
/// to be materialized privately before a mutation (the cost side of
/// cross-request sharing). The tiered-compression gauges (`quant_pages`,
/// `quant_bytes`, `fp32_bytes`, `quant_compaction_ratio`) split occupancy
/// by precision so deployments can watch how much of the pool the cold-page
/// Q8 demotions reclaim.
pub fn export_arena(j: &mut Json, ast: &ArenaStats) {
    j.set("kv_arena_bytes_in_use", ast.bytes_in_use.into());
    j.set("kv_arena_bytes_pooled", ast.bytes_pooled.into());
    j.set("kv_arena_high_water", ast.high_water.into());
    j.set("kv_arena_pages_pooled", ast.pages_pooled.into());
    j.set("kv_arena_pages_allocated", (ast.pages_allocated as i64).into());
    j.set("kv_arena_pages_freed", (ast.pages_freed as i64).into());
    j.set("kv_arena_pool_hits", (ast.pool_hits as i64).into());
    j.set("cow_copies", (ast.cow_copies as i64).into());
    j.set("quant_pages", ast.quant_pages.into());
    j.set("quant_bytes", ast.quant_bytes.into());
    j.set("fp32_bytes", ast.fp32_bytes.into());
    j.set("quant_compaction_ratio", ast.quant_compaction_ratio.into());
}

/// Attach the scheduler's fault-handling counters plus the process-wide
/// resilience gauges to an `op:stats` payload (PERF.md "Failure handling &
/// recovery"): `retries` counts failed device calls re-submitted after
/// rebuild-from-arena recovery, `quarantined` counts sequences that exited
/// with a structured error (budget exhausted, fatal, or worker panic),
/// `deadline_exceeded` / `overloaded` count the deadline and backpressure
/// exits, `device_degraded` is the sticky device-tier bypass flag, and
/// `lock_poisoned` counts runtime mutexes recovered after a panicking
/// holder.
pub fn export_faults(
    j: &mut Json,
    fs: &crate::server::batcher::FaultStats,
    degraded: bool,
    lock_poisoned: u64,
) {
    j.set("retries", (fs.retries as i64).into());
    j.set("quarantined", (fs.quarantined as i64).into());
    j.set("deadline_exceeded", (fs.deadline_exceeded as i64).into());
    j.set("overloaded", (fs.overloaded as i64).into());
    j.set("device_degraded", degraded.into());
    j.set("lock_poisoned", (lock_poisoned as i64).into());
}

/// Attach the cross-request prefix cache's counters: `prefix_hits` /
/// `prefix_tokens_reused` quantify skipped prefill work (the TTFT win),
/// `prefix_resident_bytes` is the page span pinned by the tree (bounded by
/// `ServeConfig.prefix_pool_bytes` and counted by the admission gate).
pub fn export_prefix(j: &mut Json, ps: &PrefixStats, resident_bytes: usize) {
    j.set("prefix_hits", (ps.hits as i64).into());
    j.set("prefix_misses", (ps.misses as i64).into());
    j.set("prefix_inserts", (ps.inserts as i64).into());
    j.set("prefix_evictions", (ps.evictions as i64).into());
    j.set("prefix_tokens_reused", (ps.tokens_reused as i64).into());
    j.set("prefix_resident_bytes", resident_bytes.into());
}

/// Attach per-shard residency/health gauges as a `shards` array — one
/// object per device shard, in device order. Aggregate counters
/// (`device_resident_bytes` etc., [`export_runtime`]) stay fleet-wide; this
/// breakdown is what shows one shard saturating or degrading while the
/// rest keep serving.
pub fn export_shards(j: &mut Json, shards: &[ShardHealth]) {
    let arr: Vec<Json> = shards
        .iter()
        .map(|s| {
            Json::from_pairs(vec![
                ("device", (s.device as i64).into()),
                ("degraded", s.degraded.into()),
                ("inflight", (s.inflight as i64).into()),
                ("resident_bytes", (s.resident_bytes as i64).into()),
                ("residency_hits", (s.residency_hits as i64).into()),
                ("spills", (s.spills as i64).into()),
            ])
        })
        .collect();
    j.set("shards", arr.into());
}

/// Attach the admission-time placement counters: `placement_local_prefix`
/// counts sequences landed on their prefix snapshot's home shard (the
/// locality win), `placement_least_loaded` cold placements by byte load,
/// `placement_spillover` sequences whose home shard was unserviceable (they
/// cold-prefill elsewhere instead of migrating pages cross-device), and
/// `placement_host_only` admissions with no serviceable shard at all.
pub fn export_placement(j: &mut Json, ps: &PlacementStats) {
    j.set("placement_local_prefix", (ps.local_prefix as i64).into());
    j.set("placement_least_loaded", (ps.least_loaded as i64).into());
    j.set("placement_spillover", (ps.spillover as i64).into());
    j.set("placement_host_only", (ps.host_only as i64).into());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::batcher::Finished;

    fn fin(id: u64) -> Finished {
        Finished {
            id,
            tokens: vec![1, 2, 3, 4],
            prompt_tokens: 10,
            prefix_tokens: 0,
            queue_s: 0.001,
            ttft_s: 0.01,
            total_s: 0.05,
            error: None,
            code: None,
            cancelled: false,
        }
    }

    #[test]
    fn records_and_exports() {
        let mut m = Metrics::default();
        m.submitted = 2;
        m.record_finished(&fin(1));
        m.record_finished(&Finished {
            id: 2,
            tokens: vec![],
            prompt_tokens: 5,
            prefix_tokens: 0,
            queue_s: 0.002,
            ttft_s: 0.0,
            total_s: 0.01,
            error: Some("boom".into()),
            code: Some("fatal".into()),
            cancelled: false,
        });
        let j = m.to_json();
        assert_eq!(j.usize_of("completed"), Some(1));
        assert_eq!(j.usize_of("errored"), Some(1));
        assert_eq!(j.usize_of("gen_tokens"), Some(4));
        assert!(j.f64_of("ttft_ms_p50").unwrap() > 9.0);
        // errored sequence's REAL queue time entered the queue distribution
        assert_eq!(m.queue_s.len(), 2);
        assert!(j.f64_of("queue_ms_p95").unwrap() >= 1.0);
    }

    #[test]
    fn cancelled_counts_separately_from_errors() {
        let mut m = Metrics::default();
        m.record_finished(&Finished { cancelled: true, error: None, ..fin(1) });
        m.record_finished(&fin(2));
        let j = m.to_json();
        assert_eq!(j.usize_of("cancelled"), Some(1));
        assert_eq!(j.usize_of("completed"), Some(1));
        assert_eq!(j.usize_of("errored"), Some(0));
        // cancellations do not pollute the success latency distributions
        assert_eq!(m.ttft_s.len(), 1);
    }

    #[test]
    fn itl_distribution_exports_in_ms() {
        let mut m = Metrics::default();
        for &s in &[0.002, 0.004, 0.010, 0.003] {
            m.itl_s.record(s);
        }
        let j = m.to_json();
        assert!(j.f64_of("itl_ms_p50").unwrap() >= 2.0);
        assert!(j.f64_of("itl_ms_p95").unwrap() <= 10.0 + 1e-9);
        assert_eq!(j.f64_of("itl_ms_max"), Some(10.0));
        // empty registry exports 0, not -inf
        let j0 = Metrics::default().to_json();
        assert_eq!(j0.f64_of("itl_ms_max"), Some(0.0));
    }

    #[test]
    fn intake_depth_tracks_nonempty_rounds() {
        let mut m = Metrics::default();
        m.record_intake(0);
        m.record_intake(8);
        m.record_intake(0);
        m.record_intake(2);
        let j = m.to_json();
        assert_eq!(j.usize_of("intake_rounds"), Some(4));
        assert_eq!(j.f64_of("intake_depth_max"), Some(8.0));
        assert!(j.f64_of("intake_depth_p50").unwrap() >= 2.0);
        // empty registry exports 0, not -inf
        let j0 = Metrics::default().to_json();
        assert_eq!(j0.f64_of("intake_depth_max"), Some(0.0));
    }

    #[test]
    fn exports_runtime_transfer_counters() {
        let m = Metrics::default();
        let mut j = m.to_json();
        let rs = RuntimeStats {
            calls: 3,
            bytes_h2d: 1024,
            bytes_d2h: 2048,
            gather_s: 0.25,
            dequant_s: 0.05,
            gathered_bytes: 96,
            gathers_full: 1,
            gathers_incremental: 1,
            gathers_noop: 1,
            dense_scratch_allocs: 1,
            scratch_resident_bytes: 4096,
            device_resident_bytes: 1 << 16,
            residency_hits: 9,
            residency_misses: 2,
            spills: 1,
            donations: 7,
            reconciled_bytes: 320,
            ..Default::default()
        };
        export_runtime(&mut j, &rs);
        assert_eq!(j.usize_of("runtime_calls"), Some(3));
        assert_eq!(j.usize_of("bytes_h2d"), Some(1024));
        assert_eq!(j.usize_of("bytes_d2h"), Some(2048));
        assert_eq!(j.usize_of("gathered_bytes"), Some(96));
        assert_eq!(j.usize_of("gathers_noop"), Some(1));
        assert_eq!(j.usize_of("dense_scratch_allocs"), Some(1));
        assert_eq!(j.usize_of("scratch_resident_bytes"), Some(4096));
        assert_eq!(j.usize_of("device_resident_bytes"), Some(1 << 16));
        assert_eq!(j.usize_of("residency_hits"), Some(9));
        assert_eq!(j.usize_of("residency_misses"), Some(2));
        assert_eq!(j.usize_of("spills"), Some(1));
        assert_eq!(j.usize_of("donations"), Some(7));
        assert_eq!(j.usize_of("reconciled_bytes"), Some(320));
        assert!(j.f64_of("gather_s").unwrap() > 0.2);
        assert_eq!(j.f64_of("dequant_s"), Some(0.05));
    }

    #[test]
    fn exports_arena_pool_counters() {
        let mut j = Json::obj();
        let ast = ArenaStats {
            bytes_in_use: 1024,
            bytes_pooled: 512,
            high_water: 2048,
            budget: None,
            pages_pooled: 2,
            pages_allocated: 9,
            pool_hits: 4,
            pages_freed: 6,
            cow_copies: 3,
            quant_pages: 5,
            quant_bytes: 320,
            fp32_bytes: 704,
            quant_compaction_ratio: 3.75,
        };
        export_arena(&mut j, &ast);
        assert_eq!(j.usize_of("kv_arena_bytes_in_use"), Some(1024));
        assert_eq!(j.usize_of("kv_arena_pages_pooled"), Some(2));
        assert_eq!(j.usize_of("kv_arena_pages_allocated"), Some(9));
        assert_eq!(j.usize_of("kv_arena_pool_hits"), Some(4));
        assert_eq!(j.usize_of("kv_arena_pages_freed"), Some(6));
        assert_eq!(j.usize_of("cow_copies"), Some(3));
        assert_eq!(j.usize_of("quant_pages"), Some(5));
        assert_eq!(j.usize_of("quant_bytes"), Some(320));
        assert_eq!(j.usize_of("fp32_bytes"), Some(704));
        assert_eq!(j.f64_of("quant_compaction_ratio"), Some(3.75));
    }

    #[test]
    fn exports_fault_counters() {
        let mut j = Json::obj();
        let fs = crate::server::batcher::FaultStats {
            retries: 6,
            quarantined: 1,
            deadline_exceeded: 2,
            overloaded: 3,
        };
        export_faults(&mut j, &fs, true, 4);
        assert_eq!(j.usize_of("retries"), Some(6));
        assert_eq!(j.usize_of("quarantined"), Some(1));
        assert_eq!(j.usize_of("deadline_exceeded"), Some(2));
        assert_eq!(j.usize_of("overloaded"), Some(3));
        assert_eq!(j.bool_of("device_degraded"), Some(true));
        assert_eq!(j.usize_of("lock_poisoned"), Some(4));
    }

    #[test]
    fn exports_per_shard_health_array() {
        let mut j = Json::obj();
        let shards = vec![
            ShardHealth {
                device: 0,
                degraded: false,
                inflight: 2,
                resident_bytes: 4096,
                residency_hits: 9,
                spills: 1,
            },
            ShardHealth { device: 1, degraded: true, ..Default::default() },
        ];
        export_shards(&mut j, &shards);
        let arr = j.req("shards").as_arr().expect("shards must be an array");
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].usize_of("device"), Some(0));
        assert_eq!(arr[0].bool_of("degraded"), Some(false));
        assert_eq!(arr[0].usize_of("inflight"), Some(2));
        assert_eq!(arr[0].usize_of("resident_bytes"), Some(4096));
        assert_eq!(arr[0].usize_of("residency_hits"), Some(9));
        assert_eq!(arr[0].usize_of("spills"), Some(1));
        assert_eq!(arr[1].usize_of("device"), Some(1));
        assert_eq!(arr[1].bool_of("degraded"), Some(true));
        // a single-device fleet still exports the (one-element) array so
        // dashboards never branch on its presence
        let mut j1 = Json::obj();
        export_shards(&mut j1, &[ShardHealth::default()]);
        assert_eq!(j1.req("shards").as_arr().map(|a| a.len()), Some(1));
    }

    #[test]
    fn exports_placement_counters() {
        let mut j = Json::obj();
        let ps = PlacementStats { local_prefix: 5, least_loaded: 3, spillover: 2, host_only: 1 };
        export_placement(&mut j, &ps);
        assert_eq!(j.usize_of("placement_local_prefix"), Some(5));
        assert_eq!(j.usize_of("placement_least_loaded"), Some(3));
        assert_eq!(j.usize_of("placement_spillover"), Some(2));
        assert_eq!(j.usize_of("placement_host_only"), Some(1));
    }

    #[test]
    fn empty_registry_exports_no_nan_percentiles() {
        // zero requests: every percentile/max/rate key must be a finite
        // number (0), never NaN or ±inf — health dashboards divide by these
        let j = Metrics::default().to_json();
        let pairs = j.as_obj().expect("stats is an object");
        for (k, v) in pairs {
            if let Json::Num(x) = v {
                assert!(x.is_finite(), "{k} must be finite on an empty registry, got {x}");
            }
        }
        for k in [
            "intake_depth_p50",
            "intake_depth_max",
            "ttft_ms_p50",
            "ttft_ms_p99",
            "latency_ms_p95",
            "queue_ms_p95",
            "itl_ms_p50",
            "itl_ms_max",
        ] {
            assert_eq!(j.f64_of(k), Some(0.0), "{k} must export 0 with no samples");
        }
    }

    #[test]
    fn export_hooks_tolerate_default_structs() {
        // the op:metrics path renders every export_* gauge from whatever
        // the hooks attach — all-default stats structs must round-trip
        // without NaN so the Prometheus exposition stays parseable
        let mut j = Metrics::default().to_json();
        export_runtime(&mut j, &RuntimeStats::default());
        export_arena(&mut j, &ArenaStats::default());
        export_faults(&mut j, &crate::server::batcher::FaultStats::default(), false, 0);
        export_prefix(&mut j, &PrefixStats::default(), 0);
        export_placement(&mut j, &PlacementStats::default());
        export_shards(&mut j, &[]);
        for (k, v) in j.as_obj().expect("stats object") {
            if let Json::Num(x) = v {
                assert!(x.is_finite(), "{k} must stay finite from default structs");
            }
        }
    }

    #[test]
    fn prometheus_text_is_well_formed() {
        let mut m = Metrics::default();
        m.submitted = 3;
        m.record_finished(&fin(1));
        let mut j = m.to_json();
        export_faults(&mut j, &crate::server::batcher::FaultStats::default(), true, 0);
        export_shards(
            &mut j,
            &[ShardHealth { device: 0, inflight: 2, resident_bytes: 4096, ..Default::default() }],
        );
        let text = prometheus_text(&j, &m);
        // every non-comment line is `name[{labels}] value` with a finite value
        for line in text.lines() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("metric line has a value");
            assert!(name.starts_with("lacache_"), "metric namespaced: {line}");
            assert!(value.parse::<f64>().map(f64::is_finite).unwrap_or(false), "bad: {line}");
        }
        assert!(text.contains("# TYPE lacache_submitted gauge"));
        assert!(text.contains("lacache_submitted 3"));
        // booleans export as 0/1 gauges
        assert!(text.contains("lacache_device_degraded 1"));
        // shard gauges are labeled by device ordinal
        assert!(text.contains("lacache_shard_resident_bytes{shard=\"0\"} 4096"));
        // native histograms: bucket series end at +Inf and count matches
        assert!(text.contains("# TYPE lacache_ttft_seconds histogram"));
        assert!(text.contains("lacache_ttft_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("lacache_ttft_seconds_count 1"));
        assert!(text.contains("lacache_trace_dropped_total"));
    }

    #[test]
    fn exports_prefix_counters() {
        let mut j = Json::obj();
        let ps = PrefixStats {
            hits: 7,
            misses: 2,
            inserts: 5,
            evictions: 1,
            tokens_reused: 3584,
        };
        export_prefix(&mut j, &ps, 1 << 16);
        assert_eq!(j.usize_of("prefix_hits"), Some(7));
        assert_eq!(j.usize_of("prefix_misses"), Some(2));
        assert_eq!(j.usize_of("prefix_inserts"), Some(5));
        assert_eq!(j.usize_of("prefix_evictions"), Some(1));
        assert_eq!(j.usize_of("prefix_tokens_reused"), Some(3584));
        assert_eq!(j.usize_of("prefix_resident_bytes"), Some(1 << 16));
    }
}
