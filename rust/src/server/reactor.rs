//! The decoupled intake/scheduling reactor driving the serving executor.
//!
//! One reactor round is: **intake** (drain the request channel to empty —
//! burst depth no longer scales with device-step time), then **one
//! scheduler step** (reap completions / reap cancelled / admit / submit,
//! see [`super::batcher`]), then **delivery** of everything that exited the
//! scheduler. With a split-phase backend the step's submit phase returns
//! while device calls are still running, so intake keeps draining (and
//! decoders keep being fed) underneath a long prefill. The reactor is
//! generic over [`SeqBackend`] so the whole serving control path —
//! including shutdown and cancellation semantics — is testable and
//! benchable without a PJRT runtime.
//!
//! Admission back-pressure is the backend's: the admit phase consults
//! [`SeqBackend::can_admit`] whenever the active set has headroom, where
//! the real backend counts paged-KV arena pressure PLUS the runtime's
//! staging tiers (device-resident K/V images, host scratch images) — and
//! sweeps entries of sequences reaped in earlier rounds, so a cancelled
//! client's `device_resident_bytes` never gate the next admission.
//!
//! Shutdown semantics: after an `op:shutdown` is accepted, already-admitted
//! and already-queued work drains to completion, but NEW generate requests
//! are rejected with [`SHUTTING_DOWN`] and counted in
//! `metrics.rejected_shutdown`. The reactor exits once the scheduler is
//! empty.

use std::collections::BTreeMap;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Duration;

use super::batcher::{CancelToken, Finished, Overloaded, Scheduler, SeqBackend};
use super::metrics::{export_faults, export_shards, prometheus_text, Metrics};
use super::protocol::{
    err_full, err_response, ok_generate, ok_metrics, ok_ping, ok_stats, ok_trace, parse_request,
    Op, SHUTTING_DOWN,
};
use crate::util::json::Json;

/// One unit of work handed from a connection handler to the reactor.
pub enum Work {
    Req {
        line: String,
        reply: Sender<String>,
        /// Fired by the connection handler when the client disconnects;
        /// shared by every request from that connection.
        cancel: CancelToken,
    },
}

/// How long an idle reactor blocks waiting for work before re-polling.
const IDLE_POLL: Duration = Duration::from_millis(50);

pub struct Reactor<B: SeqBackend> {
    sched: Scheduler<B>,
    metrics: Metrics,
    /// In-flight generates by scheduler sequence id: client request id,
    /// whether the request asked for its trace on the reply, reply channel.
    waiting: BTreeMap<u64, (i64, bool, Sender<String>)>,
    shutdown: bool,
    max_new_tokens: usize,
}

impl<B: SeqBackend> Reactor<B> {
    pub fn new(sched: Scheduler<B>, max_new_tokens: usize) -> Self {
        Self {
            sched,
            metrics: Metrics::default(),
            waiting: BTreeMap::new(),
            shutdown: false,
            max_new_tokens,
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn sched(&self) -> &Scheduler<B> {
        &self.sched
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown
    }

    /// Run rounds until shutdown is flagged and all admitted work has
    /// drained; returns the final metrics snapshot. `stats_hook` enriches
    /// `op:stats` payloads with backend state (runtime counters, arena
    /// occupancy) the generic reactor cannot see.
    pub fn run(mut self, rx: &Receiver<Work>, stats_hook: impl Fn(&mut Json)) -> Json {
        while self.poll(rx, &stats_hook) {}
        self.metrics.to_json()
    }

    /// One reactor round: drain intake, take one scheduler step, deliver
    /// exits. Returns false once the reactor should stop (shutdown flagged
    /// and nothing left in flight).
    pub fn poll(&mut self, rx: &Receiver<Work>, stats_hook: &impl Fn(&mut Json)) -> bool {
        self.intake(rx, stats_hook);
        for f in self.sched.step() {
            self.deliver(f);
        }
        for itl in self.sched.take_itl() {
            self.metrics.itl_s.record(itl);
        }
        !self.shutdown || self.sched.has_work()
    }

    /// Intake stage: drain the channel to EMPTY every round (the old loop
    /// pulled at most one request per device step, so burst intake latency
    /// scaled with model speed). Blocks briefly only when the scheduler is
    /// idle, so an idle reactor does not spin.
    fn intake(&mut self, rx: &Receiver<Work>, stats_hook: &impl Fn(&mut Json)) {
        // intake depth counts GENERATE work only (measured via the submitted
        // counter), so control ops (stats polls, shutdown) don't dilute the
        // burst-depth statistic
        let before = self.metrics.submitted;
        if !self.sched.has_work() && !self.shutdown {
            if let Ok(w) = rx.recv_timeout(IDLE_POLL) {
                self.dispatch(w, stats_hook);
            }
        }
        while let Ok(w) = rx.try_recv() {
            self.dispatch(w, stats_hook);
        }
        let drained = self.metrics.submitted - before;
        self.metrics.record_intake(drained);
    }

    fn dispatch(&mut self, work: Work, stats_hook: &impl Fn(&mut Json)) {
        let Work::Req { line, reply, cancel } = work;
        let req = match parse_request(&line) {
            Ok(req) => req,
            Err(e) => {
                let _ = reply.send(err_response(0, &format!("{e:#}")));
                return;
            }
        };
        match req.op {
            Op::Generate { prompt, max_new_tokens, prefix_hint, deadline_ms, trace } => {
                self.metrics.submitted += 1;
                if self.shutdown {
                    self.metrics.rejected_shutdown += 1;
                    let _ = reply.send(err_response(req.id, SHUTTING_DOWN));
                    return;
                }
                let max_new = max_new_tokens.min(self.max_new_tokens);
                let deadline = deadline_ms.map(Duration::from_millis);
                match self.sched.submit_req(prompt, max_new, cancel, prefix_hint, deadline) {
                    Ok(sid) => {
                        self.waiting.insert(sid, (req.id, trace, reply));
                    }
                    Err(e) => {
                        self.metrics.rejected += 1;
                        // queue-full backpressure is machine-readable: code
                        // + a retry_after_ms hint scaled to the backlog
                        let resp = match e.downcast_ref::<Overloaded>() {
                            Some(o) => err_full(
                                req.id,
                                &format!("{e:#}"),
                                Some("overloaded"),
                                Some(o.retry_after_ms),
                                None,
                            ),
                            None => err_response(req.id, &format!("{e:#}")),
                        };
                        let _ = reply.send(resp);
                    }
                }
            }
            Op::Stats => {
                let mut j = self.metrics.to_json();
                let (q, a) = self.sched.depth();
                j.set("queue_depth", q.into());
                j.set("active_seqs", a.into());
                export_faults(
                    &mut j,
                    &self.sched.fault_stats(),
                    self.sched.backend().degraded(),
                    crate::runtime::lock_poisoned_total(),
                );
                export_shards(&mut j, &self.sched.backend().shard_health());
                stats_hook(&mut j);
                let _ = reply.send(ok_stats(req.id, j));
            }
            Op::Ping => {
                let (q, a) = self.sched.depth();
                let _ = reply.send(ok_ping(
                    req.id,
                    env!("CARGO_PKG_VERSION"),
                    self.metrics.started.elapsed().as_secs_f64(),
                    self.sched.backend().degraded(),
                    self.sched.inflight(),
                    q,
                    a,
                    crate::obs::recorder().dropped_total(),
                    &self.sched.backend().shard_health(),
                ));
            }
            Op::Trace(filter) => {
                let rec = crate::obs::recorder();
                let events = rec.snapshot(&filter);
                let _ =
                    reply.send(ok_trace(req.id, &events, rec.watermark(), rec.dropped_total()));
            }
            Op::Metrics => {
                // same payload op:stats assembles (hook included), rendered
                // as Prometheus text plus the native latency histograms
                let mut j = self.metrics.to_json();
                let (q, a) = self.sched.depth();
                j.set("queue_depth", q.into());
                j.set("active_seqs", a.into());
                export_faults(
                    &mut j,
                    &self.sched.fault_stats(),
                    self.sched.backend().degraded(),
                    crate::runtime::lock_poisoned_total(),
                );
                export_shards(&mut j, &self.sched.backend().shard_health());
                stats_hook(&mut j);
                let _ = reply.send(ok_metrics(req.id, &prometheus_text(&j, &self.metrics)));
            }
            Op::Shutdown => {
                self.shutdown = true;
                let _ = reply.send(ok_stats(req.id, self.metrics.to_json()));
            }
        }
    }

    fn deliver(&mut self, f: Finished) {
        self.metrics.record_finished(&f);
        let Some((req_id, trace, reply)) = self.waiting.remove(&f.id) else { return };
        if f.cancelled {
            return; // the client is gone; there is no one to write to
        }
        let resp = match &f.error {
            // structured failure: free-text error + machine-readable code +
            // whatever partial output the request generated before it died
            Some(e) => err_full(req_id, e, f.code.as_deref(), None, Some(&f.tokens)),
            None => {
                // steady-state decode speed: time after the first token,
                // averaged over the remaining tokens (0 when ≤ 1 token)
                let n = f.tokens.len();
                let itl_ms = if n > 1 {
                    (f.total_s - f.ttft_s).max(0.0) * 1e3 / (n - 1) as f64
                } else {
                    0.0
                };
                // trace: true — attach the request's recorded phase chain
                // (whatever of it is still in the ring / survived sampling)
                let phases =
                    if trace { Some(crate::obs::recorder().phases_for(f.id)) } else { None };
                ok_generate(
                    req_id,
                    &f.tokens,
                    f.prompt_tokens,
                    f.prefix_tokens,
                    f.ttft_s * 1e3,
                    itl_ms,
                    f.total_s * 1e3,
                    phases.as_deref(),
                )
            }
        };
        let _ = reply.send(resp);
    }
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc;

    use super::*;
    use crate::server::batcher::Decoded;

    struct Instant0;
    struct NoSeq;

    impl SeqBackend for Instant0 {
        type Seq = NoSeq;
        fn new_seq(&mut self) -> anyhow::Result<NoSeq> {
            Ok(NoSeq)
        }
        fn prefill_chunk(&mut self, _s: &mut NoSeq, _c: &[i32]) -> anyhow::Result<()> {
            Ok(())
        }
        fn decode(&mut self, _s: &mut NoSeq, n: usize) -> anyhow::Result<Decoded> {
            Ok(Decoded { tokens: vec![17; n], t_first: None })
        }
    }

    fn gen_line(id: usize, max_new: usize) -> String {
        format!(
            r#"{{"op":"generate","id":{id},"prompt_tokens":[1,2,3],"max_new_tokens":{max_new}}}"#
        )
    }

    fn send(tx: &mpsc::Sender<Work>, line: String) -> mpsc::Receiver<String> {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Work::Req { line, reply: rtx, cancel: CancelToken::new() }).unwrap();
        rrx
    }

    fn no_hook(_: &mut Json) {}

    #[test]
    fn burst_is_fully_drained_and_admitted_in_one_round() {
        let sched = Scheduler::new(Instant0, 128, 16, 16, 64);
        let mut r = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        let replies: Vec<_> = (0..10).map(|i| send(&tx, gen_line(i, 4))).collect();
        r.poll(&rx, &no_hook);
        // the whole burst entered the scheduler in ONE round, and with
        // capacity available all of it was admitted
        assert_eq!(r.metrics().submitted, 10);
        assert_eq!(r.sched().depth(), (0, 10));
        assert_eq!(r.metrics().intake_depth.max(), 10.0);
        while r.sched().has_work() {
            r.poll(&rx, &no_hook);
        }
        for rrx in replies {
            let j = Json::parse(&rrx.recv().unwrap()).unwrap();
            assert_eq!(j.bool_of("ok"), Some(true));
            assert_eq!(j.usize_of("gen_tokens"), Some(4));
        }
    }

    #[test]
    fn post_shutdown_generates_are_rejected_not_admitted() {
        let sched = Scheduler::new(Instant0, 128, 16, 16, 64);
        let mut r = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        let shut = send(&tx, r#"{"op":"shutdown","id":99}"#.into());
        let replies: Vec<_> = (0..5).map(|i| send(&tx, gen_line(i, 4))).collect();
        let alive = r.poll(&rx, &no_hook);
        assert!(!alive, "nothing in flight: reactor must stop after shutdown");
        assert!(r.is_shutdown());
        assert_eq!(r.sched().depth(), (0, 0), "no sequence may be admitted after shutdown");
        assert_eq!(r.metrics().rejected_shutdown, 5);
        let j = Json::parse(&shut.recv().unwrap()).unwrap();
        assert_eq!(j.bool_of("ok"), Some(true));
        for rrx in replies {
            let j = Json::parse(&rrx.recv().unwrap()).unwrap();
            assert_eq!(j.bool_of("ok"), Some(false));
            assert_eq!(j.str_of("error"), Some(SHUTTING_DOWN));
        }
    }

    #[test]
    fn in_flight_work_drains_after_shutdown() {
        let sched = Scheduler::new(Instant0, 128, 4, 16, 64);
        let mut r = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        let gen = send(&tx, gen_line(1, 12)); // 3 decode rounds at quantum 4
        r.poll(&rx, &no_hook);
        let shut = send(&tx, r#"{"op":"shutdown","id":2}"#.into());
        let mut alive = true;
        let mut rounds = 0;
        while alive && rounds < 20 {
            alive = r.poll(&rx, &no_hook);
            rounds += 1;
        }
        assert!(!alive);
        let j = Json::parse(&gen.recv().unwrap()).unwrap();
        assert_eq!(j.bool_of("ok"), Some(true), "accepted work must complete during drain");
        assert_eq!(j.usize_of("gen_tokens"), Some(12));
        let _ = shut.recv().unwrap();
        assert_eq!(r.metrics().completed, 1);
    }

    #[test]
    fn stats_round_trips_through_dispatch_with_hook() {
        let sched = Scheduler::new(Instant0, 128, 16, 16, 64);
        let mut r = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        let stats = send(&tx, r#"{"op":"stats","id":5}"#.into());
        r.poll(&rx, &|j: &mut Json| j.set("hooked", true.into()));
        let j = Json::parse(&stats.recv().unwrap()).unwrap();
        assert_eq!(j.bool_of("ok"), Some(true));
        let s = j.req("stats");
        assert_eq!(s.bool_of("hooked"), Some(true));
        assert_eq!(s.usize_of("queue_depth"), Some(0));
        // stats are answered during intake, before the round is recorded
        assert_eq!(s.usize_of("intake_rounds"), Some(0));
    }

    #[test]
    fn bad_json_gets_an_error_reply() {
        let sched = Scheduler::new(Instant0, 128, 16, 16, 64);
        let mut r = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        let bad = send(&tx, "not json at all".into());
        r.poll(&rx, &no_hook);
        let j = Json::parse(&bad.recv().unwrap()).unwrap();
        assert_eq!(j.bool_of("ok"), Some(false));
    }

    #[test]
    fn disconnect_cancellation_suppresses_the_reply() {
        let sched = Scheduler::new(Instant0, 128, 4, 16, 64);
        let mut r = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        let cancel = CancelToken::new();
        let (rtx, rrx) = mpsc::channel();
        tx.send(Work::Req { line: gen_line(1, 64), reply: rtx, cancel: cancel.clone() }).unwrap();
        r.poll(&rx, &no_hook); // admitted + prefilled
        r.poll(&rx, &no_hook); // first decode quantum
        cancel.cancel();
        r.poll(&rx, &no_hook); // reaped
        assert_eq!(r.metrics().cancelled, 1);
        assert!(!r.sched().has_work());
        assert!(rrx.try_recv().is_err(), "cancelled request must not receive a response");
    }

    /// Backend that never admits (permanent memory pressure), to pin
    /// requests in the queue.
    struct Gated;

    impl SeqBackend for Gated {
        type Seq = NoSeq;
        fn new_seq(&mut self) -> anyhow::Result<NoSeq> {
            Ok(NoSeq)
        }
        fn prefill_chunk(&mut self, _s: &mut NoSeq, _c: &[i32]) -> anyhow::Result<()> {
            Ok(())
        }
        fn decode(&mut self, _s: &mut NoSeq, n: usize) -> anyhow::Result<Decoded> {
            Ok(Decoded { tokens: vec![17; n], t_first: None })
        }
        fn can_admit(&self, _active: usize) -> bool {
            false
        }
    }

    #[test]
    fn ping_reports_health() {
        let sched = Scheduler::new(Instant0, 128, 16, 16, 64);
        let mut r = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        let ping = send(&tx, r#"{"op":"ping","id":8}"#.into());
        r.poll(&rx, &no_hook);
        let j = Json::parse(&ping.recv().unwrap()).unwrap();
        assert_eq!(j.bool_of("ok"), Some(true));
        assert_eq!(j.str_of("version"), Some(env!("CARGO_PKG_VERSION")));
        assert_eq!(j.bool_of("degraded"), Some(false));
        assert_eq!(j.usize_of("inflight"), Some(0));
        assert_eq!(j.usize_of("queue_depth"), Some(0));
        assert_eq!(j.usize_of("active_seqs"), Some(0));
        // health-probe observability gauges: process age and recorder
        // overflow, both present and finite even on a fresh server
        assert!(j.f64_of("uptime_s").unwrap() >= 0.0);
        assert!(j.f64_of("trace_dropped_total").unwrap() >= 0.0);
        // shard array is always present; a backend without shard awareness
        // (the trait default) reports an empty fleet
        assert_eq!(j.req("shards").as_arr().map(|a| a.len()), Some(0));
    }

    #[test]
    fn trace_op_round_trips_filters_through_dispatch() {
        // the ring and sampling stride are process-global: serialize against
        // tests that reconfigure them (e.g. the tracing on/off property test)
        let _g = crate::obs::test_guard();
        let sched = Scheduler::new(Instant0, 128, 16, 16, 64);
        let mut r = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        // watermark BEFORE this test's request: the since filter must hide
        // everything already in the (process-global) ring
        let w = crate::obs::recorder().watermark();
        let gen = send(&tx, gen_line(1, 4));
        while r.sched().has_work() || r.metrics().completed == 0 {
            r.poll(&rx, &no_hook);
        }
        let ok = Json::parse(&gen.recv().unwrap()).unwrap();
        assert_eq!(ok.bool_of("ok"), Some(true));

        // by since: only events recorded after the watermark come back
        let t = send(&tx, format!(r#"{{"op":"trace","id":2,"since":{w}}}"#));
        r.poll(&rx, &no_hook);
        let j = Json::parse(&t.recv().unwrap()).unwrap();
        assert_eq!(j.bool_of("ok"), Some(true));
        let events = j.req("events").as_arr().expect("events array").to_vec();
        assert!(!events.is_empty(), "the request must have recorded events");
        assert!(events.iter().all(|e| e.usize_of("at").unwrap() as u64 > w));
        assert!(j.usize_of("watermark").unwrap() as u64 >= w);
        assert!(j.get("trace_dropped_total").is_some());
        // the completed request's scheduler lifecycle chain is
        // reconstructable from the dump: queued -> admitted -> placed ->
        // first-token -> finished in at-order for its seq (other tests'
        // schedulers may interleave events; at least OUR request's seq must
        // carry a complete chain)
        let full_chain = |sid: usize| {
            let chain: Vec<&str> = events
                .iter()
                .filter(|e| e.usize_of("seq") == Some(sid))
                .filter_map(|e| e.str_of("kind"))
                .collect();
            let mut want = ["queued", "admitted", "placed", "first-token", "finished"].iter();
            let mut need = want.next();
            for k in &chain {
                if Some(*k) == need.copied() {
                    need = want.next();
                }
            }
            need.is_none()
        };
        let seqs: std::collections::BTreeSet<usize> =
            events.iter().filter_map(|e| e.usize_of("seq")).collect();
        let sid = *seqs
            .iter()
            .find(|&&s| full_chain(s))
            .expect("one seq must carry a complete queued->finished chain");

        // by kind: every returned event is of the asked kind
        let t = send(&tx, format!(r#"{{"op":"trace","id":3,"kind":"finished","since":{w}}}"#));
        r.poll(&rx, &no_hook);
        let j = Json::parse(&t.recv().unwrap()).unwrap();
        let fins = j.req("events").as_arr().unwrap().to_vec();
        assert!(!fins.is_empty());
        assert!(fins.iter().all(|e| e.str_of("kind") == Some("finished")));

        // by seq: only the chosen request's events
        let t = send(&tx, format!(r#"{{"op":"trace","id":4,"seq":{sid},"since":{w}}}"#));
        r.poll(&rx, &no_hook);
        let j = Json::parse(&t.recv().unwrap()).unwrap();
        let evs = j.req("events").as_arr().unwrap().to_vec();
        assert!(!evs.is_empty());
        assert!(evs.iter().all(|e| e.usize_of("seq") == Some(sid)));

        // unknown kind is rejected at parse time with an error reply
        let t = send(&tx, r#"{"op":"trace","id":5,"kind":"bogus"}"#.into());
        r.poll(&rx, &no_hook);
        let j = Json::parse(&t.recv().unwrap()).unwrap();
        assert_eq!(j.bool_of("ok"), Some(false));
    }

    #[test]
    fn generate_with_trace_flag_attaches_phase_breakdown() {
        let _g = crate::obs::test_guard();
        let sched = Scheduler::new(Instant0, 128, 16, 16, 64);
        let mut r = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        let line = r#"{"op":"generate","id":7,"prompt_tokens":[1,2,3],"max_new_tokens":4,"trace":true}"#;
        let gen = send(&tx, line.to_string());
        while r.sched().has_work() || r.metrics().completed == 0 {
            r.poll(&rx, &no_hook);
        }
        let j = Json::parse(&gen.recv().unwrap()).unwrap();
        assert_eq!(j.bool_of("ok"), Some(true));
        let trace = j.req("trace").as_arr().expect("trace array on the reply").to_vec();
        assert!(!trace.is_empty());
        let kinds: Vec<&str> = trace.iter().filter_map(|e| e.str_of("kind")).collect();
        assert!(kinds.contains(&"queued"));
        assert!(kinds.contains(&"finished"));
        // all events in the breakdown belong to ONE request
        let seqs: std::collections::BTreeSet<usize> =
            trace.iter().filter_map(|e| e.usize_of("seq")).collect();
        assert_eq!(seqs.len(), 1);
        // an untraced request's reply stays trace-free
        let gen = send(&tx, gen_line(8, 2));
        while r.sched().has_work() || r.metrics().completed < 2 {
            r.poll(&rx, &no_hook);
        }
        let j = Json::parse(&gen.recv().unwrap()).unwrap();
        assert!(j.get("trace").is_none());
    }

    #[test]
    fn metrics_op_returns_prometheus_text() {
        let sched = Scheduler::new(TwoShards, 128, 16, 16, 64);
        let mut r = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        let m = send(&tx, r#"{"op":"metrics","id":9}"#.into());
        r.poll(&rx, &|j: &mut Json| j.set("hooked_gauge", 5i64.into()));
        let j = Json::parse(&m.recv().unwrap()).unwrap();
        assert_eq!(j.bool_of("ok"), Some(true));
        assert_eq!(j.str_of("content_type"), Some("text/plain; version=0.0.4"));
        let body = j.str_of("metrics").expect("metrics body");
        assert!(body.contains("# TYPE lacache_submitted gauge"));
        assert!(body.contains("lacache_queue_depth 0"));
        // the stats hook's additions are rendered too
        assert!(body.contains("lacache_hooked_gauge 5"));
        // per-shard gauges come through labeled
        assert!(body.contains("lacache_shard_resident_bytes{shard=\"0\"} 2048"));
        // native histogram series present
        assert!(body.contains("lacache_itl_seconds_bucket{le=\"+Inf\"}"));
        assert!(body.contains("lacache_trace_dropped_total"));
    }

    /// Backend reporting a two-shard fleet with one degraded shard, to pin
    /// the per-shard health wire format end to end.
    struct TwoShards;

    impl SeqBackend for TwoShards {
        type Seq = NoSeq;
        fn new_seq(&mut self) -> anyhow::Result<NoSeq> {
            Ok(NoSeq)
        }
        fn prefill_chunk(&mut self, _s: &mut NoSeq, _c: &[i32]) -> anyhow::Result<()> {
            Ok(())
        }
        fn decode(&mut self, _s: &mut NoSeq, n: usize) -> anyhow::Result<Decoded> {
            Ok(Decoded { tokens: vec![17; n], t_first: None })
        }
        fn shard_health(&self) -> Vec<crate::server::batcher::ShardHealth> {
            vec![
                crate::server::batcher::ShardHealth {
                    device: 0,
                    degraded: false,
                    inflight: 1,
                    resident_bytes: 2048,
                    residency_hits: 5,
                    spills: 0,
                },
                crate::server::batcher::ShardHealth {
                    device: 1,
                    degraded: true,
                    ..Default::default()
                },
            ]
        }
    }

    #[test]
    fn ping_and_stats_carry_per_shard_health() {
        let sched = Scheduler::new(TwoShards, 128, 16, 16, 64);
        let mut r = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        let ping = send(&tx, r#"{"op":"ping","id":11}"#.into());
        let stats = send(&tx, r#"{"op":"stats","id":12}"#.into());
        r.poll(&rx, &no_hook);
        let j = Json::parse(&ping.recv().unwrap()).unwrap();
        let shards = j.req("shards").as_arr().expect("ping shards array");
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].usize_of("device"), Some(0));
        assert_eq!(shards[0].bool_of("degraded"), Some(false));
        assert_eq!(shards[0].usize_of("inflight"), Some(1));
        assert_eq!(shards[0].usize_of("resident_bytes"), Some(2048));
        assert_eq!(shards[1].bool_of("degraded"), Some(true));
        // one degraded shard does NOT degrade the fleet flag
        assert_eq!(j.bool_of("degraded"), Some(false));
        let s = Json::parse(&stats.recv().unwrap()).unwrap();
        let s = s.req("stats");
        let sh = s.req("shards").as_arr().expect("stats shards array");
        assert_eq!(sh.len(), 2);
        assert_eq!(sh[1].usize_of("device"), Some(1));
        assert_eq!(sh[0].usize_of("residency_hits"), Some(5));
        assert_eq!(sh[0].usize_of("spills"), Some(0));
    }

    #[test]
    fn overload_rejection_is_coded_on_the_wire() {
        let sched = Scheduler::new(Gated, 128, 16, 16, 2); // queue cap 2
        let mut r = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        let replies: Vec<_> = (0..3).map(|i| send(&tx, gen_line(i, 4))).collect();
        r.poll(&rx, &no_hook);
        assert_eq!(r.metrics().rejected, 1);
        // first two queued (no reply yet), third rejected with the hint
        assert!(replies[0].try_recv().is_err());
        assert!(replies[1].try_recv().is_err());
        let j = Json::parse(&replies[2].recv().unwrap()).unwrap();
        assert_eq!(j.bool_of("ok"), Some(false));
        assert_eq!(j.str_of("code"), Some("overloaded"));
        assert!(j.usize_of("retry_after_ms").unwrap() >= 50);
        // and the counter is visible through op:stats
        let stats = send(&tx, r#"{"op":"stats","id":9}"#.into());
        r.poll(&rx, &no_hook);
        let s = Json::parse(&stats.recv().unwrap()).unwrap();
        let s = s.req("stats");
        assert_eq!(s.usize_of("overloaded"), Some(1));
        assert_eq!(s.usize_of("retries"), Some(0));
        assert_eq!(s.usize_of("quarantined"), Some(0));
        assert_eq!(s.bool_of("device_degraded"), Some(false));
    }

    /// Decode at ~5 ms/token so a deadline can land mid-generation.
    struct SlowDecode;

    impl SeqBackend for SlowDecode {
        type Seq = NoSeq;
        fn new_seq(&mut self) -> anyhow::Result<NoSeq> {
            Ok(NoSeq)
        }
        fn prefill_chunk(&mut self, _s: &mut NoSeq, _c: &[i32]) -> anyhow::Result<()> {
            Ok(())
        }
        fn decode(&mut self, _s: &mut NoSeq, n: usize) -> anyhow::Result<Decoded> {
            std::thread::sleep(Duration::from_millis(5));
            Ok(Decoded { tokens: vec![17; n], t_first: None })
        }
    }

    #[test]
    fn deadline_reply_is_coded_and_carries_partial_output() {
        let sched = Scheduler::new(SlowDecode, 128, 1, 16, 64); // 1 token per 5ms quantum
        let mut r = Reactor::new(sched, 64);
        let (tx, rx) = mpsc::channel();
        let line = r#"{"op":"generate","id":3,"prompt_tokens":[1,2,3],"max_new_tokens":64,"deadline_ms":30}"#;
        let reply = send(&tx, line.to_string());
        let t0 = std::time::Instant::now();
        let resp = loop {
            r.poll(&rx, &no_hook);
            if let Ok(resp) = reply.try_recv() {
                break resp;
            }
            assert!(t0.elapsed() < Duration::from_secs(5), "deadline reply never arrived");
        };
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.bool_of("ok"), Some(false));
        assert_eq!(j.str_of("code"), Some("deadline-exceeded"));
        let n = j.usize_of("gen_tokens").unwrap();
        assert!(n >= 1 && n < 64, "partial output expected, got {n} tokens");
        assert_eq!(j.get("tokens").and_then(|a| a.as_arr()).map(|a| a.len()), Some(n));
        assert!(!r.sched().has_work());
    }
}
