//! Token <-> text codec for the synthetic vocabulary. Words render as
//! `w<N>`; special tokens by name. The serving protocol speaks this text
//! form so clients stay human-readable.

use crate::data::corpus::{ANSWER, BOS, EOS, MARK, QUERY, SEP, VOCAB, WORD_BASE};

pub fn detokenize(tokens: &[i32]) -> String {
    tokens.iter().map(|&t| token_str(t)).collect::<Vec<_>>().join(" ")
}

pub fn token_str(t: i32) -> String {
    match t {
        x if x == BOS => "<bos>".into(),
        x if x == EOS => "<eos>".into(),
        x if x == SEP => "<sep>".into(),
        x if x == QUERY => "<query>".into(),
        x if x == ANSWER => "<answer>".into(),
        x if x == MARK => "<mark>".into(),
        x if (WORD_BASE..VOCAB).contains(&x) => format!("w{}", x - WORD_BASE),
        x => format!("<unk:{x}>"),
    }
}

pub fn tokenize(text: &str) -> Result<Vec<i32>, String> {
    text.split_whitespace()
        .map(|w| match w {
            "<bos>" => Ok(BOS),
            "<eos>" => Ok(EOS),
            "<sep>" => Ok(SEP),
            "<query>" => Ok(QUERY),
            "<answer>" => Ok(ANSWER),
            "<mark>" => Ok(MARK),
            _ => {
                let n: i32 = w
                    .strip_prefix('w')
                    .ok_or_else(|| format!("bad token `{w}`"))?
                    .parse()
                    .map_err(|_| format!("bad token `{w}`"))?;
                if (0..VOCAB - WORD_BASE).contains(&n) {
                    Ok(WORD_BASE + n)
                } else {
                    Err(format!("word id out of range `{w}`"))
                }
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let toks = vec![BOS, MARK, 20, 21, SEP, 100, 101, 102, 103, QUERY, 20, 21, ANSWER];
        let text = detokenize(&toks);
        assert_eq!(tokenize(&text).unwrap(), toks);
        assert!(text.starts_with("<bos> <mark> w4 w5 <sep>"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(tokenize("hello").is_err());
        assert!(tokenize("w999").is_err());
        assert!(tokenize("w-1").is_err());
    }

    #[test]
    fn all_tokens_render() {
        for t in 0..VOCAB {
            let s = token_str(t);
            if t < 6 || t >= WORD_BASE {
                assert!(!s.contains("unk"), "{t} -> {s}");
            }
        }
    }
}
