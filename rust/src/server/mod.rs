//! The serving front-end: JSON-lines TCP listener + single-executor
//! continuous-batching loop (the PJRT client is single-device; concurrency
//! is iteration-level interleaving, vLLM-style).
//!
//! Threads: N connection readers/writers + 1 executor that owns the
//! `Runtime` (PJRT handles are not `Send`; the executor constructs it on its
//! own thread and everything device-related stays there).

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod text;

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{self, Receiver, Sender};
use std::time::Duration;

use anyhow::Result;

use batcher::{Finished, Scheduler, SeqBackend};
use protocol::{err_response, ok_generate, ok_stats, parse_request, Op};

use crate::cache::make_policy;
use crate::config::ServeConfig;
use crate::engine::{Engine, EngineOpts};
use crate::runtime::{admission_ok, seq_footprint_bytes, KvArena, Runtime};

/// Real backend: each sequence is an [`Engine`] with its own page tables in
/// the shared paged-KV arena and a fresh policy instance; the `Runtime`
/// (weights + compiled programs) and the arena are shared.
pub struct EngineBackend<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: ServeConfig,
    arena: KvArena,
    /// Worst-case steady-state arena bytes for one sequence: policy budget
    /// plus one ingest window, clamped to capacity, in whole pages.
    est_seq_bytes: usize,
    pool_budget: Option<usize>,
}

impl<'rt> EngineBackend<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: ServeConfig) -> Result<Self> {
        let m = rt.model(&cfg.model)?;
        let (l, h, dh) = (m.cfg.n_layers, m.cfg.n_heads, m.cfg.head_dim);
        let policy = make_policy(&cfg.policy, l)?;
        let slots = policy.budget().saturating_add(cfg.window).min(cfg.capacity);
        let est_seq_bytes = seq_footprint_bytes(l, h * dh, slots);
        let pool_budget = (cfg.kv_pool_bytes > 0).then_some(cfg.kv_pool_bytes);
        if let Some(limit) = pool_budget {
            if limit < est_seq_bytes {
                anyhow::bail!(
                    "kv_pool_bytes {limit} is smaller than one sequence's footprint \
                     ({est_seq_bytes} B); no request could ever be admitted"
                );
            }
        }
        Ok(Self { rt, cfg, arena: KvArena::global().clone(), est_seq_bytes, pool_budget })
    }
}

impl<'rt> SeqBackend for EngineBackend<'rt> {
    type Seq = Engine<'rt>;

    fn new_seq(&mut self) -> Result<Engine<'rt>> {
        let n_layers = self.rt.model(&self.cfg.model)?.cfg.n_layers;
        let policy = make_policy(&self.cfg.policy, n_layers)?;
        Engine::new(
            self.rt,
            EngineOpts {
                model: self.cfg.model.clone(),
                w: self.cfg.window,
                c: self.cfg.capacity,
                memory_budget_bytes: None,
            },
            policy,
        )
    }

    fn prefill_chunk(&mut self, seq: &mut Engine<'rt>, chunk: &[i32]) -> Result<()> {
        seq.prefill(chunk)
    }

    fn decode(&mut self, seq: &mut Engine<'rt>, n: usize) -> Result<Vec<i32>> {
        seq.generate(n)
    }

    /// Admission control by real arena pressure: see
    /// [`crate::runtime::admission_ok`].
    fn can_admit(&self, active: usize) -> bool {
        match self.pool_budget {
            None => true,
            Some(limit) => admission_ok(&self.arena.stats(), active, self.est_seq_bytes, limit),
        }
    }
}

enum Work {
    Req { line: String, reply: Sender<String> },
}

/// Run the server until an `op:shutdown` request arrives. Returns the final
/// metrics snapshot.
pub fn run_server(cfg: ServeConfig) -> Result<crate::util::json::Json> {
    let listener = TcpListener::bind(&cfg.listen)?;
    let addr = listener.local_addr()?;
    eprintln!("lacache-serve listening on {addr} (model={}, policy={})", cfg.model, cfg.policy);
    let (tx, rx) = mpsc::channel::<Work>();
    let accept_tx = tx.clone();

    // Accept loop (its own thread; exits when the process ends).
    std::thread::spawn(move || {
        for conn in listener.incoming().flatten() {
            let tx = accept_tx.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(conn, tx);
            });
        }
    });

    executor_loop(cfg, rx)
}

fn handle_conn(conn: TcpStream, tx: Sender<Work>) -> Result<()> {
    let peer = conn.peer_addr()?;
    let reader = BufReader::new(conn.try_clone()?);
    let mut writer = conn;
    for line in reader.lines() {
        let line = match line {
            Ok(l) if !l.trim().is_empty() => l,
            Ok(_) => continue,
            Err(_) => break,
        };
        let (rtx, rrx) = mpsc::channel();
        if tx.send(Work::Req { line, reply: rtx }).is_err() {
            break; // executor gone
        }
        match rrx.recv() {
            Ok(resp) => {
                writer.write_all(resp.as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
            }
            Err(_) => break,
        }
    }
    let _ = peer;
    Ok(())
}

/// The executor: owns the Runtime, the scheduler and the metrics registry.
fn executor_loop(cfg: ServeConfig, rx: Receiver<Work>) -> Result<crate::util::json::Json> {
    let rt = Runtime::load(&crate::artifacts_dir(), &[cfg.model.as_str()])?;
    // pre-compile the serving programs so the first request isn't slow
    let _ = rt.warmup(
        &cfg.model,
        &[
            &format!("score_w{}_c{}", cfg.window, cfg.capacity),
            &format!("generate_k16_c{}", cfg.capacity),
            &format!("generate_k1_c{}", cfg.capacity),
        ],
    );
    // unconditional: clears any stale budget from a previous run_server in
    // the same process when the new config says unlimited (0)
    KvArena::global().set_budget((cfg.kv_pool_bytes > 0).then_some(cfg.kv_pool_bytes));
    let backend = EngineBackend::new(&rt, cfg.clone())?;
    let mut sched =
        Scheduler::new(backend, cfg.window, cfg.decode_quantum, cfg.max_active, cfg.max_queue);
    let mut metrics = metrics::Metrics::default();
    let mut waiting: BTreeMap<u64, (i64, Sender<String>)> = BTreeMap::new();
    let mut shutdown = false;

    while !shutdown || sched.has_work() {
        // drain incoming work (block briefly when idle)
        let work = if sched.has_work() {
            rx.try_recv().ok()
        } else {
            rx.recv_timeout(Duration::from_millis(50)).ok()
        };
        if let Some(Work::Req { line, reply }) = work {
            match parse_request(&line) {
                Ok(req) => match req.op {
                    Op::Generate { prompt, max_new_tokens } => {
                        let max_new = max_new_tokens.min(cfg.max_new_tokens);
                        metrics.submitted += 1;
                        match sched.submit(prompt, max_new) {
                            Ok(sid) => {
                                waiting.insert(sid, (req.id, reply));
                            }
                            Err(e) => {
                                metrics.rejected += 1;
                                let _ = reply.send(err_response(req.id, &format!("{e:#}")));
                            }
                        }
                    }
                    Op::Stats => {
                        let mut j = metrics.to_json();
                        let (q, a) = sched.depth();
                        j.set("queue_depth", q.into());
                        j.set("active_seqs", a.into());
                        metrics::export_runtime(&mut j, &rt.stats());
                        let ast = KvArena::global().stats();
                        j.set("kv_arena_bytes_in_use", ast.bytes_in_use.into());
                        j.set("kv_arena_bytes_pooled", ast.bytes_pooled.into());
                        j.set("kv_arena_high_water", ast.high_water.into());
                        let _ = reply.send(ok_stats(req.id, j));
                    }
                    Op::Shutdown => {
                        shutdown = true;
                        let _ = reply.send(ok_stats(req.id, metrics.to_json()));
                    }
                },
                Err(e) => {
                    let _ = reply.send(err_response(0, &format!("{e:#}")));
                }
            }
        }
        for f in sched.step() {
            deliver(&mut waiting, &mut metrics, f);
        }
    }
    Ok(metrics.to_json())
}

fn deliver(
    waiting: &mut BTreeMap<u64, (i64, Sender<String>)>,
    metrics: &mut metrics::Metrics,
    f: Finished,
) {
    metrics.record_finished(&f);
    if let Some((req_id, reply)) = waiting.remove(&f.id) {
        let resp = match &f.error {
            Some(e) => err_response(req_id, e),
            None => ok_generate(
                req_id,
                &f.tokens,
                f.prompt_tokens,
                f.ttft_s * 1e3,
                f.total_s * 1e3,
            ),
        };
        let _ = reply.send(resp);
    }
}
