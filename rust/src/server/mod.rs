//! The serving front-end: JSON-lines TCP listener + single-executor
//! reactor. Concurrency is iteration-level interleaving, vLLM-style, fanned
//! out across the runtime's device shards: each PJRT device backs one
//! [`crate::runtime::Runtime`] shard (its own residency tier, scratch pool,
//! and compiled executables), and the backend gives each shard its own
//! [`CallExecutor`] lane so per-device call queues drain in parallel.
//!
//! Control path: each connection runs a reader thread (parses lines,
//! forwards [`Work`] to the executor, observes EOF = client disconnect) and
//! a writer thread (serializes responses), so requests pipeline and a
//! disconnect is seen *while* the request is in flight — the reader fires
//! the connection's [`CancelToken`] and the scheduler drops the sequence,
//! returning its paged-KV arena pages between quanta. The executor itself
//! is a [`Reactor`]: every round it drains the intake channel to empty
//! (burst admission no longer waits on device steps), rejects generate
//! requests once `op:shutdown` was accepted, then takes one scheduler step
//! (reap completions / reap cancelled / admit / submit — see [`batcher`]).
//!
//! Sharding: sequences are assigned a shard at admission by the
//! [`crate::runtime::placement`] policy — the shard already holding the
//! sequence's deepest prefix-tree snapshot when it is serviceable,
//! least-loaded-bytes otherwise. The radix prefix tree stays ONE logical
//! index: snapshots record their home shard, adoption only happens on that
//! shard, and an unserviceable home shard means a counted cold-prefill
//! spillover, never an implicit cross-device page migration. One lost
//! device degrades its shard only; the fleet keeps serving
//! (`op:ping` reports per-shard health).
//!
//! Threads: N connection reader/writer pairs + 1 executor that owns the
//! `Runtime` and drives the scheduler, plus per-shard scoped
//! [`CallExecutor`] lanes the executor ships device calls to (a single lane
//! with `max_inflight_calls > 1` on one device). The `Runtime` is `Sync` —
//! workers borrow it directly — and each in-flight call OWNS the sequence
//! it advances, so device-tier accounting never races (split-phase
//! submit/reap, PERF.md "Async overlap"). The cross-request prefix cache
//! and the placement counters are the deliberately single-threaded pieces:
//! adoption, placement, and snapshot publishing all happen on the executor
//! thread (publishing at reap), so they need no locking.

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod reactor;
pub mod text;

use std::cell::RefCell;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::rc::Rc;
use std::sync::mpsc::{self, Receiver, Sender};
use std::time::Duration;

use anyhow::Result;

use batcher::{
    CallDone, CallOut, CancelToken, Decoded, Scheduler, SeqBackend, ShardHealth, Submitted, Ticket,
};
pub use reactor::{Reactor, Work};

use crate::cache::make_policy;
use crate::config::{KvQuantMode, ServeConfig};
use crate::engine::{Engine, EngineOpts};
use crate::runtime::manifest::serving_prog_names;
use crate::runtime::{
    admission_ok, place, seq_footprint_bytes, seq_footprint_bytes_mixed, sharded_staging_bytes,
    CallError, CallExecutor, KvArena, PlacementStats, PrefixCache, PrefixSnapshot, Runtime,
    RuntimeOpts, ShardLoad, PAGE_SLOTS,
};

/// The determinism domain of a frozen prefix: the ladder (or any registered)
/// policy produces byte-identical KV state at every ingestion-window
/// boundary only for the same model, policy spec, window, compiled
/// capacity, and KV precision mode (snapshots freeze straight to Q8 under
/// `cold-q8`, and the demotion horizon changes which pages carry rounding) —
/// reuse across any difference is unsound, so the prefix cache carries this
/// signature and the backend validates it before adopting.
pub fn prefix_signature(cfg: &ServeConfig) -> String {
    format!(
        "{}|{}|w{}|c{}|q{}-{}",
        cfg.model,
        cfg.policy,
        cfg.window,
        cfg.capacity,
        cfg.kv_quant.as_str(),
        cfg.quantize_after_windows
    )
}

/// One served sequence: the engine plus the prompt tokens it has ingested
/// so far — the prefix tree's path key, extended at adoption and after
/// every prefill chunk. The engine's `shard` field (set at admission by the
/// placement policy) routes every device call and picks the executor lane.
pub struct ServedSeq<'rt> {
    engine: Engine<'rt>,
    ingested: Vec<i32>,
    /// Why placement chose this sequence's shard
    /// ([`crate::runtime::placement::PlacementKind::code`]); carried into
    /// the flight recorder's `placed` event.
    placement_code: i64,
}

/// What an in-flight device call carries back through the worker pool: the
/// sequence it owned plus the call's outcome.
pub type SeqCall<'rt> = (ServedSeq<'rt>, Result<CallOut>);

/// Real backend: each sequence is an [`Engine`] (wrapped in [`ServedSeq`])
/// with its own page tables in the shared paged-KV arena and a fresh policy
/// instance; the `Runtime` (weights + compiled programs, one shard per
/// device), the arena, and the cross-request [`PrefixCache`] are shared.
/// The backend places every sequence on a shard at admission
/// (locality-aware: prefix home shard first, least-loaded-bytes otherwise),
/// publishes every sequence's KV state at full-window prefill boundaries
/// (stamped with its home shard), and adopts matching prefixes at
/// admission, so a fleet of prompts sharing one system prompt prefills the
/// shared span once — on one shard.
pub struct EngineBackend<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: ServeConfig,
    arena: KvArena,
    /// Cross-request prefix cache, shared with the executor's stats hook
    /// ([`Self::prefix_handle`]). One logical tree across all shards.
    prefix: Rc<RefCell<PrefixCache>>,
    /// This backend's determinism signature ([`prefix_signature`]).
    prefix_sig: String,
    /// The prefix pool's EFFECTIVE byte capacity (`cfg.prefix_pool_bytes`
    /// clamped to the budget headroom left after one sequence's worst
    /// case). Admission reserves this cap — not the current residency —
    /// because the tree fills AFTER sequences were admitted against it.
    prefix_cap: usize,
    /// Placement decision counters (`op:stats` `placement_*`), shared with
    /// the executor's stats hook ([`Self::placement_handle`]).
    placement: Rc<RefCell<PlacementStats>>,
    /// Worst-case steady-state arena bytes for one sequence: policy budget
    /// plus one ingest window, clamped to capacity, in whole pages.
    est_seq_bytes: usize,
    /// One dense `[L, H, C, Dh]` K/V staging image — what a hot sequence
    /// holds in its shard's device tier (or, spilled, in its scratch pool).
    image_bytes: usize,
    /// Per-shard staging ceilings: each shard's residency-slice bytes plus
    /// its scratch pool's worst case. Admission projects per-sequence
    /// staging but charges each shard at most its own ceiling (LRU evicts
    /// the rest) — one saturated shard cannot spend another shard's budget.
    shard_staging_caps: Vec<usize>,
    /// Global staging ceiling (the sum of [`Self::shard_staging_caps`]).
    staging_cap: usize,
    pool_budget: Option<usize>,
    /// Per-shard worker lanes for split-phase device calls
    /// ([`Self::with_executors`]): `seq.engine.shard` picks the lane, so a
    /// stalled device only backs up its own queue. Empty = the synchronous
    /// path: the scheduler's default submit shims run every call inline on
    /// the executor thread.
    executors: Vec<CallExecutor<'rt, SeqCall<'rt>>>,
}

impl<'rt> EngineBackend<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: ServeConfig) -> Result<Self> {
        let m = rt.model(&cfg.model)?;
        let (l, h, dh) = (m.cfg.n_layers, m.cfg.n_heads, m.cfg.head_dim);
        let policy = make_policy(&cfg.policy, l)?;
        let slots = policy.budget().saturating_add(cfg.window).min(cfg.capacity);
        let est_seq_bytes = match cfg.kv_quant {
            KvQuantMode::Off => seq_footprint_bytes(l, h * dh, slots),
            KvQuantMode::ColdQ8 => {
                // steady state under tiered compression: the hot tail (the
                // demotion horizon plus the ingest window in flight plus the
                // f32-pinned sink page and a partial tail page) stays f32,
                // every colder slot is Q8 — admission charges actual
                // mixed-precision bytes, which is what buys the ~4x
                // concurrent-sequence capacity under the same pool budget
                let fp32_slots =
                    ((cfg.quantize_after_windows + 2) * cfg.window + 2 * PAGE_SLOTS).min(slots);
                seq_footprint_bytes_mixed(l, h * dh, h, slots, fp32_slots)
            }
        };
        let image_bytes = 2 * 4 * l * h * cfg.capacity * dh;
        // mirror the runtime's partitioning: each shard gets a slice of the
        // device pool and `scratch_pool_entries / shards` (min 1) scratch
        // images, so each per-shard cap is what that shard's tiers can
        // physically hold (with one shard this is exactly the pre-sharding
        // `device_pool_bytes + entries.max(1) * image` ceiling)
        let scratch_per_shard = (cfg.scratch_pool_entries / rt.shard_count().max(1)).max(1);
        let shard_staging_caps: Vec<usize> = rt
            .shard_stats()
            .iter()
            .map(|s| {
                s.capacity_bytes.saturating_add(scratch_per_shard.saturating_mul(image_bytes))
            })
            .collect();
        let staging_cap = shard_staging_caps.iter().fold(0usize, |a, &c| a.saturating_add(c));
        let pool_budget = (cfg.kv_pool_bytes > 0).then_some(cfg.kv_pool_bytes);
        let mut prefix_cap = cfg.prefix_pool_bytes;
        if let Some(limit) = pool_budget {
            // kv_pool_bytes is the TOTAL serving budget: arena pages plus
            // staging. One sequence needs its pages and one image.
            let min_budget = est_seq_bytes + image_bytes.min(staging_cap);
            if limit < min_budget {
                anyhow::bail!(
                    "kv_pool_bytes {limit} is smaller than one sequence's footprint \
                     ({min_budget} B = {est_seq_bytes} B pages + one dense staging \
                     image); no request could ever be admitted"
                );
            }
            // prefix reuse is an optimization, never a startup blocker: a
            // budget that served pre-prefix configs must keep booting, so
            // the pool is clamped to the headroom left after one
            // sequence's worst case (possibly to 0 = disabled). Admission
            // reserves this cap, so a tree filling up AFTER sequences were
            // admitted can never push a live sequence into kv-arena-OOM.
            prefix_cap = prefix_cap.min(limit - min_budget);
        }
        let prefix_sig = prefix_signature(&cfg);
        let prefix = Rc::new(RefCell::new(PrefixCache::new(prefix_sig.clone(), prefix_cap)));
        Ok(Self {
            rt,
            cfg,
            arena: KvArena::global().clone(),
            prefix,
            prefix_sig,
            prefix_cap,
            placement: Rc::new(RefCell::new(PlacementStats::default())),
            est_seq_bytes,
            image_bytes,
            shard_staging_caps,
            staging_cap,
            pool_budget,
            executors: Vec::new(),
        })
    }

    /// Enable split-phase dispatch: prefill/decode calls are shipped whole —
    /// the [`ServedSeq`] moves into the job — onto the lane matching the
    /// sequence's shard and come back through [`SeqBackend::reap`]. With one
    /// lane per shard, per-device queues drain in parallel; the summed lane
    /// widths are the in-flight capacity the scheduler sees. The `Runtime`
    /// is `Sync`, so workers drive it concurrently; each shard's
    /// device/scratch tiers serialize internally (lock order: device before
    /// scratch, never across shards).
    pub fn with_executors(mut self, lanes: Vec<CallExecutor<'rt, SeqCall<'rt>>>) -> Self {
        self.executors = lanes;
        self
    }

    /// Handle to the backend's prefix cache (the executor's stats hook
    /// reads counters through it).
    pub fn prefix_handle(&self) -> Rc<RefCell<PrefixCache>> {
        self.prefix.clone()
    }

    /// Handle to the backend's placement counters (the executor's stats
    /// hook exports them as `placement_*`).
    pub fn placement_handle(&self) -> Rc<RefCell<PlacementStats>> {
        self.placement.clone()
    }

    /// Point-in-time placement inputs: the runtime's per-shard load gauges
    /// with each executor lane's in-flight count overlaid (the runtime
    /// cannot see the lanes).
    fn shard_loads(&self) -> Vec<ShardLoad> {
        let mut loads = self.rt.shard_loads();
        for (load, ex) in loads.iter_mut().zip(&self.executors) {
            load.inflight = ex.inflight();
        }
        loads
    }

    /// Publish a sequence's post-chunk KV state into the prefix tree at
    /// FULL-window boundaries only: an adopter re-chunks from the same
    /// offsets, so its eviction cadence (and therefore its ladder state) is
    /// identical to a cold prefill. `insert_with` freezes the engine's
    /// pages only if the tree actually wants this boundary; the snapshot is
    /// stamped with the donor's shard, which placement later prefers.
    ///
    /// Runs on the executor thread exclusively — after an inline prefill,
    /// or at reap for a pool-dispatched one (the prefix cache is the
    /// single-threaded piece of the backend, so in-flight jobs never touch
    /// it).
    fn publish_prefix(&self, seq: &mut ServedSeq<'rt>) {
        let w = self.cfg.window;
        if !seq.ingested.is_empty() && seq.ingested.len() % w == 0 {
            let engine = &mut seq.engine;
            let home = engine.shard;
            let mut prefix = self.prefix.borrow_mut();
            prefix.insert_with(&seq.ingested, w, || {
                PrefixSnapshot::freeze_on(&mut engine.cache, home)
            });
        }
    }
}

impl<'rt> SeqBackend for EngineBackend<'rt> {
    type Seq = ServedSeq<'rt>;

    fn new_seq(&mut self) -> Result<ServedSeq<'rt>> {
        let n_layers = self.rt.model(&self.cfg.model)?.cfg.n_layers;
        let policy = make_policy(&self.cfg.policy, n_layers)?;
        let engine = Engine::new(
            self.rt,
            EngineOpts {
                model: self.cfg.model.clone(),
                w: self.cfg.window,
                c: self.cfg.capacity,
                memory_budget_bytes: None,
                quantize_after_windows: (self.cfg.kv_quant == KvQuantMode::ColdQ8)
                    .then_some(self.cfg.quantize_after_windows),
            },
            policy,
        )?;
        Ok(ServedSeq { engine, ingested: Vec::new(), placement_code: 0 })
    }

    /// Placement plus cross-request prefix adoption (called at admission
    /// for every sequence). With reuse allowed, the prompt's deepest
    /// prefix-tree match supplies both the locality preference (its home
    /// shard) and — when placement lands there — the frozen KV state to
    /// install; the scheduler then skips prefill for the matched span. An
    /// unserviceable home shard spills the sequence elsewhere by load and
    /// cold-prefills (counted in `placement_spillover`): snapshots are
    /// never migrated across devices. Signature mismatch or a failed
    /// install likewise degrade to a cold start.
    fn adopt_prefix(&mut self, seq: &mut ServedSeq<'rt>, prompt: &[i32], allow: bool) -> usize {
        let hit = if allow {
            let mut prefix = self.prefix.borrow_mut();
            if prefix.enabled() && prefix.signature() == self.prefix_sig {
                prefix.lookup(prompt)
            } else {
                None
            }
        } else {
            None
        };
        let preferred = hit.as_ref().map(|(_, snap)| snap.home_shard());
        let placement = place(&self.shard_loads(), preferred);
        self.placement.borrow_mut().note(placement.kind);
        seq.engine.shard = placement.shard;
        seq.placement_code = placement.kind.code();
        let Some((matched, snap)) = hit else {
            return 0;
        };
        if placement.shard != snap.home_shard() {
            // spillover: the sequence lives elsewhere now, so the matched
            // span prefills cold there rather than copying pages cross-device
            return 0;
        }
        match seq.engine.adopt_prefix(&snap, matched as u64, prompt[matched - 1]) {
            Ok(()) => {
                seq.ingested.extend_from_slice(&prompt[..matched]);
                matched
            }
            Err(_) => 0,
        }
    }

    /// The placement policy's shard for this sequence — stamps the flight
    /// recorder's admitted/placed/submit events with the real device shard.
    fn seq_shard(&self, seq: &ServedSeq<'rt>) -> usize {
        seq.engine.shard
    }

    /// The placement rule that chose the shard
    /// ([`crate::runtime::placement::PlacementKind::code`]).
    fn placement_code(&self, seq: &ServedSeq<'rt>) -> i64 {
        seq.placement_code
    }

    fn prefill_chunk(&mut self, seq: &mut ServedSeq<'rt>, chunk: &[i32]) -> Result<()> {
        seq.engine.prefill(chunk)?;
        seq.ingested.extend_from_slice(chunk);
        self.publish_prefix(seq);
        Ok(())
    }

    fn decode(&mut self, seq: &mut ServedSeq<'rt>, n: usize) -> Result<Decoded> {
        let (tokens, t_first) = seq.engine.generate_timed(n)?;
        Ok(Decoded { tokens, t_first })
    }

    fn inflight_capacity(&self) -> usize {
        if self.executors.is_empty() {
            1
        } else {
            self.executors.iter().map(|ex| ex.workers()).sum()
        }
    }

    /// Split-phase prefill: the whole [`ServedSeq`] moves into the job on
    /// its shard's lane. The job runs engine ingestion only; prefix-tree
    /// publishing (non-`Send`) happens when the completion is reaped on the
    /// executor thread.
    fn submit_prefill(
        &mut self,
        ticket: Ticket,
        mut seq: ServedSeq<'rt>,
        chunk: &[i32],
    ) -> Submitted<ServedSeq<'rt>> {
        let lane = seq.engine.shard;
        if let Some(ex) = self.executors.get_mut(lane) {
            let chunk = chunk.to_vec();
            ex.submit(ticket, move || {
                let result = seq.engine.prefill(&chunk).map(|()| CallOut::Prefill);
                if result.is_ok() {
                    seq.ingested.extend_from_slice(&chunk);
                }
                (seq, result)
            });
            return Submitted::InFlight;
        }
        let result = self.prefill_chunk(&mut seq, chunk).map(|()| CallOut::Prefill);
        Submitted::Done(CallDone { ticket, seq: Some(seq), result })
    }

    fn submit_decode(
        &mut self,
        ticket: Ticket,
        mut seq: ServedSeq<'rt>,
        n: usize,
    ) -> Submitted<ServedSeq<'rt>> {
        let lane = seq.engine.shard;
        if let Some(ex) = self.executors.get_mut(lane) {
            ex.submit(ticket, move || {
                let result = seq
                    .engine
                    .generate_timed(n)
                    .map(|(tokens, t_first)| CallOut::Decode(Decoded { tokens, t_first }));
                (seq, result)
            });
            return Submitted::InFlight;
        }
        let result = self.decode(&mut seq, n).map(CallOut::Decode);
        Submitted::Done(CallDone { ticket, seq: Some(seq), result })
    }

    fn reap(&mut self, mut wait: Option<Duration>) -> Vec<CallDone<ServedSeq<'rt>>> {
        let mut done: Vec<CallDone<ServedSeq<'rt>>> = Vec::new();
        for ex in &mut self.executors {
            // block (at most once, on the first lane with work in flight)
            // only when the caller asked to wait; every other lane is
            // drained non-blocking so one idle shard never delays another's
            // completions
            let w = if ex.inflight() > 0 { wait.take() } else { None };
            done.extend(ex.reap(w).into_iter().map(|c| match c.out {
                Ok((seq, result)) => CallDone { ticket: c.ticket, seq: Some(seq), result },
                // the job panicked: its ServedSeq (arena pages, residency)
                // was dropped during unwind — surface a structured Fatal so
                // the scheduler quarantines just that sequence
                Err(panic) => CallDone {
                    ticket: c.ticket,
                    seq: None,
                    result: Err(CallError::fatal(format!("worker panic: {panic}"))),
                },
            }));
        }
        // deferred prefix publishing for pool-dispatched prefills (see
        // publish_prefix: the prefix cache lives on this thread only)
        for c in &mut done {
            if matches!(c.result, Ok(CallOut::Prefill)) {
                if let Some(seq) = c.seq.as_mut() {
                    self.publish_prefix(seq);
                }
            }
        }
        done
    }

    /// Crash-consistent recovery before a retry: drop the sequence's staged
    /// residency (device image + scratch spill) so the retried call rebuilds
    /// its dense image from the paged-KV arena — the durable source of truth
    /// a failed call never mutated (PERF.md "Failure handling & recovery").
    fn recover(&mut self, seq: &mut ServedSeq<'rt>, _pos: usize) {
        self.rt.release_cache_state(seq.engine.cache.id());
    }

    /// FLEET-level degraded flag (surfaced through `op:ping`): true only
    /// when every shard's device tier has tripped its sticky bypass. A
    /// single lost device degrades its shard alone —
    /// [`Self::shard_health`] carries the per-shard flags.
    fn degraded(&self) -> bool {
        self.rt.device_degraded()
    }

    /// Per-shard health: the runtime's residency gauges zipped with each
    /// executor lane's in-flight count (`op:ping` / `op:stats` `shards`).
    fn shard_health(&self) -> Vec<ShardHealth> {
        self.rt
            .shard_stats()
            .into_iter()
            .enumerate()
            .map(|(i, s)| ShardHealth {
                device: s.device,
                degraded: s.degraded,
                inflight: self.executors.get(i).map_or(0, |ex| ex.inflight()),
                resident_bytes: s.resident_bytes,
                residency_hits: s.residency_hits,
                spills: s.spills,
            })
            .collect()
    }

    /// Admission control by real memory pressure: arena pages PLUS the
    /// runtime's staging tiers (device-resident K/V images and host scratch
    /// images, which exist per hot sequence) — a full device tier
    /// back-pressures intake instead of OOMing. Staging is charged per
    /// shard: each shard's measured bytes (or its share of the projection,
    /// if larger) clamped to that shard's own ceiling, so one saturated
    /// shard cannot borrow headroom another shard will never grant. Sweeps
    /// dead staging entries first, so a sequence cancelled last round has
    /// already released its `device_resident_bytes` by the time this round
    /// admits.
    fn can_admit(&self, active: usize) -> bool {
        // sweep regardless of budget: a cancelled sequence's staging bytes
        // must not outlive it just because admission is unlimited (calls
        // themselves also sweep, covering the saturated-active case)
        self.rt.sweep_staging();
        match self.pool_budget {
            None => true,
            Some(limit) => {
                // projection: every hot sequence plus the incoming one holds
                // one image ((active+1) images; admitted sequences may not
                // have promoted yet)
                let projected = (active + 1).saturating_mul(self.image_bytes);
                let staged: Vec<usize> = (0..self.rt.shard_count())
                    .map(|i| self.rt.staging_bytes_on(i))
                    .collect();
                let staging = sharded_staging_bytes(&staged, &self.shard_staging_caps, projected);
                // reserve the prefix pool's CAPACITY, not its current
                // residency: snapshots are published while the admitted
                // sequences prefill, so the tree grows (pinning pages the
                // donors' compactions would otherwise free) after this
                // check ran — reserving the cap keeps that growth from
                // OOMing an in-flight sequence
                let prefix_bytes = self.prefix_cap.max(self.prefix.borrow().resident_bytes());
                admission_ok(
                    &self.arena.stats(),
                    active,
                    self.est_seq_bytes,
                    limit,
                    staging,
                    prefix_bytes,
                )
            }
        }
    }
}

/// Run the server until an `op:shutdown` request arrives. Returns the final
/// metrics snapshot.
pub fn run_server(cfg: ServeConfig) -> Result<crate::util::json::Json> {
    let listener = TcpListener::bind(&cfg.listen)?;
    let addr = listener.local_addr()?;
    eprintln!("lacache-serve listening on {addr} (model={}, policy={})", cfg.model, cfg.policy);
    let (tx, rx) = mpsc::channel::<Work>();
    let accept_tx = tx.clone();

    // Accept loop (its own thread; exits when the process ends).
    std::thread::spawn(move || {
        for conn in listener.incoming().flatten() {
            let tx = accept_tx.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(conn, tx);
            });
        }
    });

    executor_loop(cfg, rx)
}

/// Per-connection pump: the calling thread reads request lines and forwards
/// them to the executor; a writer thread serializes responses back. Reads
/// and writes are decoupled so (a) a client can pipeline requests and (b)
/// the reader observes EOF the moment the client disconnects — even with a
/// request still running — and fires the connection's [`CancelToken`] so
/// the scheduler can reclaim the sequence's arena pages immediately.
///
/// Read-side EOF is deliberately treated as "client gone": TCP cannot
/// distinguish a vanished client from one that half-closed and still
/// reads, and waiting for a write failure would burn device time on
/// every real disconnect — the exact leak this path exists to stop. The
/// protocol therefore requires clients to hold their write side open
/// while awaiting replies (documented in [`protocol`]).
fn handle_conn(conn: TcpStream, tx: Sender<Work>) -> Result<()> {
    let reader = BufReader::new(conn.try_clone()?);
    let mut writer = conn;
    let (wtx, wrx) = mpsc::channel::<String>();
    let writer_thread = std::thread::spawn(move || {
        for resp in wrx {
            if writer.write_all(resp.as_bytes()).is_err()
                || writer.write_all(b"\n").is_err()
                || writer.flush().is_err()
            {
                break;
            }
        }
    });
    let cancel = CancelToken::new();
    for line in reader.lines() {
        let line = match line {
            Ok(l) if !l.trim().is_empty() => l,
            Ok(_) => continue,
            Err(_) => break,
        };
        if tx.send(Work::Req { line, reply: wtx.clone(), cancel: cancel.clone() }).is_err() {
            break; // executor gone
        }
    }
    // EOF or read error: the client is gone. Flag every request this
    // connection still has in flight; the scheduler drops the sequences
    // between quanta and their arena pages return to the pool.
    cancel.cancel();
    drop(wtx);
    let _ = writer_thread.join();
    Ok(())
}

/// The executor: owns the Runtime and drives the reactor.
fn executor_loop(cfg: ServeConfig, rx: Receiver<Work>) -> Result<crate::util::json::Json> {
    // arm the flight recorder before any sequence can emit events:
    // per-kind sampling stride and ring capacity come from the config
    // (`--trace-sample-every` / `--trace-buffer-events`)
    crate::obs::recorder().configure(cfg.trace_sample_every, cfg.trace_buffer_events);
    let rt = Runtime::load_with(
        &crate::artifacts_dir(),
        &[cfg.model.as_str()],
        RuntimeOpts {
            scratch_pool_entries: cfg.scratch_pool_entries,
            device_pool_bytes: cfg.device_pool_bytes,
            devices: cfg.devices,
        },
    )?;
    // pre-compile the serving programs on every shard so no device pays
    // first-call compile latency
    let progs = serving_prog_names(cfg.window, cfg.capacity);
    let _ = rt.warmup(&cfg.model, &progs.iter().map(String::as_str).collect::<Vec<_>>());
    // unconditional: clears any stale budget from a previous run_server in
    // the same process when the new config says unlimited (0)
    KvArena::global().set_budget((cfg.kv_pool_bytes > 0).then_some(cfg.kv_pool_bytes));
    // the whole serving loop runs under a thread scope so the in-flight
    // call lanes (when enabled) can borrow the Runtime directly; dropping
    // the scheduler (and with it the backend's executors) at the end of the
    // closure is what lets the scope join its workers
    std::thread::scope(|scope| {
        let mut backend = EngineBackend::new(&rt, cfg.clone())?;
        let shards = rt.shard_count();
        if shards > 1 {
            // one lane per shard: a stalled device only backs up its own
            // queue, and healthy shards keep draining in parallel
            backend = backend
                .with_executors(CallExecutor::lanes(scope, shards, cfg.max_inflight_calls.max(1)));
        } else if cfg.max_inflight_calls > 1 {
            backend =
                backend.with_executors(vec![CallExecutor::new(scope, cfg.max_inflight_calls)]);
        }
        let prefix = backend.prefix_handle();
        let placement = backend.placement_handle();
        let mut sched =
            Scheduler::new(backend, cfg.window, cfg.decode_quantum, cfg.max_active, cfg.max_queue);
        sched.retry = batcher::RetryPolicy {
            max_retries: cfg.call_retries as u32,
            backoff: Duration::from_millis(cfg.retry_backoff_ms as u64),
        };
        let reactor = Reactor::new(sched, cfg.max_new_tokens);
        Ok(reactor.run(&rx, |j| {
            metrics::export_runtime(j, &rt.stats());
            metrics::export_arena(j, &KvArena::global().stats());
            let p = prefix.borrow();
            metrics::export_prefix(j, &p.stats(), p.resident_bytes());
            metrics::export_placement(j, &placement.borrow());
        }))
    })
}
