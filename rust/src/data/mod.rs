//! Workload substrates: corpus streams (Wikitext-2/PG19 substitutes) and
//! long-context task generators (NIAH / RULER / LongBench substitutes).
pub mod corpus;
pub mod longbench;
pub mod ruler;
pub mod tasks;
