//! Shared generation-task primitives for the NIAH / RULER / LongBench-style
//! suites (DESIGN.md §6): prompts are synthetic token sequences whose answers
//! require retrieving entity introductions planted in the context; scoring is
//! token-level recall of the expected phrase(s).

use crate::data::corpus::{self, ANSWER, MARK, NAME_LEN, PHRASE_LEN, QUERY, SEP};
use crate::util::rng::SplitMix64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scorer {
    /// Fraction of expected[0] matched positionally at the generation start.
    PrefixMatch,
    /// Fraction of expected groups appearing (contiguously) anywhere.
    ContainsAll,
}

#[derive(Clone, Debug)]
pub struct GenTask {
    pub name: String,
    pub prompt: Vec<i32>,
    pub expected: Vec<Vec<i32>>,
    pub gen_len: usize,
    pub scorer: Scorer,
}

/// Score a greedy generation against the task's expectation, in [0, 1].
pub fn score_generation(task: &GenTask, generated: &[i32]) -> f64 {
    match task.scorer {
        Scorer::PrefixMatch => {
            let exp = &task.expected[0];
            let hits = exp
                .iter()
                .zip(generated.iter())
                .filter(|(a, b)| a == b)
                .count();
            hits as f64 / exp.len() as f64
        }
        Scorer::ContainsAll => {
            let found = task
                .expected
                .iter()
                .filter(|grp| generated.windows(grp.len()).any(|w| w == grp.as_slice()))
                .count();
            found as f64 / task.expected.len().max(1) as f64
        }
    }
}

/// One named entity: 2-token name + 4-token phrase.
#[derive(Clone, Debug)]
pub struct Entity {
    pub name: Vec<i32>,
    pub phrase: Vec<i32>,
}

pub fn fresh_entity(rng: &mut SplitMix64) -> Entity {
    Entity {
        name: (0..NAME_LEN).map(|_| corpus::draw_name(rng)).collect(),
        phrase: (0..PHRASE_LEN).map(|_| corpus::draw_word(rng)).collect(),
    }
}

/// Markov-chain background filler (no entities, no special tokens).
pub fn filler(rng: &mut SplitMix64, n: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(n);
    let mut prev = corpus::draw_word(rng);
    for _ in 0..n {
        if rng.next_u64() & 1 == 1 {
            let j = rng.below(4);
            prev = corpus::succ(prev, j);
        } else {
            prev = corpus::draw_word(rng);
        }
        out.push(prev);
    }
    out
}

/// `MARK <name> SEP <phrase>` introduction tokens.
pub fn intro(e: &Entity) -> Vec<i32> {
    let mut t = vec![MARK];
    t.extend_from_slice(&e.name);
    t.push(SEP);
    t.extend_from_slice(&e.phrase);
    t
}

/// `QUERY <name> ANSWER` trigger tokens (the model must continue with the
/// phrase).
pub fn query(e: &Entity) -> Vec<i32> {
    let mut t = vec![QUERY];
    t.extend_from_slice(&e.name);
    t.push(ANSWER);
    t
}

/// Build a needle-in-haystack prompt: `ctx_len` total tokens of filler with
/// `needles` planted at the given depth fractions, ending with a query for
/// `target` (an index into `needles`).
pub fn needle_prompt(
    rng: &mut SplitMix64,
    ctx_len: usize,
    needles: &[(f64, Entity)],
    target: usize,
) -> GenTask {
    let mut inserts: Vec<(usize, Vec<i32>)> = needles
        .iter()
        .map(|(depth, e)| {
            let at = ((ctx_len as f64 - 32.0) * depth).max(1.0) as usize;
            (at, intro(e))
        })
        .collect();
    inserts.sort_by_key(|(at, _)| *at);
    let mut prompt = vec![corpus::BOS];
    let mut cursor = 1usize;
    for (at, toks) in inserts {
        if at > cursor {
            prompt.extend(filler(rng, at - cursor));
            cursor = at;
        }
        cursor += toks.len();
        prompt.extend(toks);
    }
    let tail_len = ctx_len.saturating_sub(prompt.len() + NAME_LEN + 2);
    prompt.extend(filler(rng, tail_len));
    prompt.extend(query(&needles[target].1));
    GenTask {
        name: "needle".into(),
        prompt,
        expected: vec![needles[target].1.phrase.clone()],
        gen_len: PHRASE_LEN,
        scorer: Scorer::PrefixMatch,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scorer_prefix() {
        let t = GenTask {
            name: "t".into(),
            prompt: vec![],
            expected: vec![vec![10, 11, 12, 13]],
            gen_len: 4,
            scorer: Scorer::PrefixMatch,
        };
        assert_eq!(score_generation(&t, &[10, 11, 12, 13]), 1.0);
        assert_eq!(score_generation(&t, &[10, 11, 0, 0]), 0.5);
        assert_eq!(score_generation(&t, &[0, 0, 0, 0]), 0.0);
    }

    #[test]
    fn scorer_contains() {
        let t = GenTask {
            name: "t".into(),
            prompt: vec![],
            expected: vec![vec![1, 2], vec![3, 4]],
            gen_len: 8,
            scorer: Scorer::ContainsAll,
        };
        assert_eq!(score_generation(&t, &[9, 1, 2, 9, 3, 4]), 1.0);
        assert_eq!(score_generation(&t, &[9, 1, 2, 9]), 0.5);
    }

    #[test]
    fn needle_prompt_structure() {
        let mut rng = SplitMix64::new(7);
        let e = fresh_entity(&mut rng);
        let task = needle_prompt(&mut rng, 512, &[(0.5, e.clone())], 0);
        // length close to requested
        assert!((500..=540).contains(&task.prompt.len()), "{}", task.prompt.len());
        // needle present around the middle
        let pos = task
            .prompt
            .windows(2 + NAME_LEN)
            .position(|w| w[0] == MARK && w[1] == e.name[0])
            .unwrap();
        assert!((180..330).contains(&pos), "needle at {pos}");
        // prompt ends with QUERY name ANSWER
        let n = task.prompt.len();
        assert_eq!(task.prompt[n - 2 - NAME_LEN], QUERY);
        assert_eq!(task.prompt[n - 1], ANSWER);
        assert_eq!(task.expected[0], e.phrase);
    }

    #[test]
    fn filler_has_no_specials() {
        let mut rng = SplitMix64::new(3);
        assert!(filler(&mut rng, 1000).iter().all(|&t| t >= corpus::WORD_BASE));
    }

    #[test]
    fn multi_needle_prompt_all_present() {
        let mut rng = SplitMix64::new(11);
        let needles: Vec<(f64, Entity)> =
            [0.2, 0.5, 0.8].iter().map(|&d| (d, fresh_entity(&mut rng))).collect();
        let task = needle_prompt(&mut rng, 1024, &needles, 1);
        for (_, e) in &needles {
            assert!(
                task.prompt.windows(NAME_LEN).any(|w| w == e.name.as_slice()),
                "needle missing"
            );
        }
    }
}
