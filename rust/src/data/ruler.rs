//! RULER benchmark substrate (Hsieh et al., 2024; paper Tab. 5): faithful
//! scaled re-implementations of the 13 task generators over the synthetic
//! token language. Relative task structure (retrieval / multi-key /
//! multi-value / tracking / aggregation / QA) is preserved; absolute scores
//! reflect the tiny substitute model.

use super::corpus::{self, PHRASE_LEN};
use super::tasks::{fresh_entity, intro, needle_prompt, query, Entity, GenTask, Scorer};
use crate::util::rng::SplitMix64;

pub const RULER_TASKS: [&str; 13] = [
    "single_1", "single_2", "single_3", "multikey_1", "multikey_2", "multikey_3", "multivalue",
    "multiquery", "vt", "cwe", "fwe", "qa_1", "qa_2",
];

/// Build one RULER task instance.
pub fn ruler_task(name: &str, ctx_len: usize, seed: u64) -> GenTask {
    let mut rng = SplitMix64::new(seed ^ 0x521e5);
    let mut t = match name {
        // --- retrieval ----------------------------------------------------
        "single_1" => {
            // constant-noise haystack (easiest)
            let e = fresh_entity(&mut rng);
            let mut task = needle_prompt(&mut rng, ctx_len, &[(0.5, e)], 0);
            for tok in task.prompt.iter_mut() {
                if *tok >= corpus::WORD_BASE && rng.below(2) == 0 {
                    *tok = corpus::WORD_BASE + 7; // flatten half the noise
                }
            }
            task
        }
        "single_2" => {
            let e = fresh_entity(&mut rng);
            let d = 0.1 + 0.8 * (rng.below(1000) as f64 / 1000.0);
            needle_prompt(&mut rng, ctx_len, &[(d, e)], 0)
        }
        "single_3" => {
            // long value (8-token phrase)
            let mut e = fresh_entity(&mut rng);
            e.phrase.extend((0..PHRASE_LEN).map(|_| corpus::draw_word(&mut rng)));
            let mut task = needle_prompt(&mut rng, ctx_len, &[(0.5, e.clone())], 0);
            task.expected = vec![e.phrase.clone()];
            task.gen_len = e.phrase.len();
            task
        }
        "multikey_1" | "multikey_2" | "multikey_3" => {
            let n_distract = match name {
                "multikey_1" => 3,
                "multikey_2" => 7,
                _ => 5,
            };
            let mut needles: Vec<(f64, Entity)> = Vec::new();
            let target_e = fresh_entity(&mut rng);
            for i in 0..=n_distract {
                let d = 0.1 + 0.8 * (i as f64) / (n_distract as f64 + 1.0);
                let mut e = if i == n_distract / 2 { target_e.clone() } else { fresh_entity(&mut rng) };
                if name == "multikey_3" && i != n_distract / 2 {
                    e.name[0] = target_e.name[0]; // confusable keys
                }
                needles.push((d, e));
            }
            let target = n_distract / 2;
            needle_prompt(&mut rng, ctx_len, &needles, target)
        }
        "multivalue" => {
            // one key introduced 3x with different values; all must surface
            let name_toks: Vec<i32> =
                (0..corpus::NAME_LEN).map(|_| corpus::draw_name(&mut rng)).collect();
            let values: Vec<Vec<i32>> = (0..3)
                .map(|_| (0..PHRASE_LEN).map(|_| corpus::draw_word(&mut rng)).collect())
                .collect();
            let needles: Vec<(f64, Entity)> = values
                .iter()
                .enumerate()
                .map(|(i, v)| {
                    (0.2 + 0.3 * i as f64, Entity { name: name_toks.clone(), phrase: v.clone() })
                })
                .collect();
            let mut task = needle_prompt(&mut rng, ctx_len, &needles, 2);
            task.expected = values;
            task.gen_len = 3 * (PHRASE_LEN + 2);
            task.scorer = Scorer::ContainsAll;
            task
        }
        "multiquery" => {
            // two needles; first query answered in-prompt, second generated
            let e1 = fresh_entity(&mut rng);
            let e2 = fresh_entity(&mut rng);
            let mut task =
                needle_prompt(&mut rng, ctx_len, &[(0.25, e1.clone()), (0.6, e2.clone())], 1);
            // insert an answered query for e1 before the final query
            let cut = task.prompt.len() - (corpus::NAME_LEN + 2);
            let mut extra = query(&e1);
            extra.extend_from_slice(&e1.phrase);
            task.prompt.splice(cut..cut, extra);
            task
        }
        // --- tracking / aggregation ---------------------------------------
        "vt" => {
            // variable tracking (alias form): two names bound to one phrase;
            // the queried alias's intro is far from the phrase's first intro
            let e1 = fresh_entity(&mut rng);
            let alias = Entity {
                name: (0..corpus::NAME_LEN).map(|_| corpus::draw_name(&mut rng)).collect(),
                phrase: e1.phrase.clone(),
            };
            needle_prompt(&mut rng, ctx_len, &[(0.15, e1), (0.5, alias)], 1)
        }
        "cwe" => {
            // common-entity recall: the queried entity is (re-)mentioned
            // repeatedly across the WHOLE context — global coverage pays
            let e = fresh_entity(&mut rng);
            let mentions: Vec<(f64, Entity)> =
                [0.1, 0.3, 0.5, 0.7].iter().map(|&d| (d, e.clone())).collect();
            needle_prompt(&mut rng, ctx_len, &mentions, 0)
        }
        "fwe" => {
            // front-loaded entity: mentions only in the first third; recency
            // windows have long since evicted them
            let e = fresh_entity(&mut rng);
            let mentions: Vec<(f64, Entity)> =
                [0.05, 0.15, 0.3].iter().map(|&d| (d, e.clone())).collect();
            needle_prompt(&mut rng, ctx_len, &mentions, 0)
        }
        // --- QA -------------------------------------------------------------
        "qa_1" => {
            // natural-ish context: corpus documents as haystack
            let e = fresh_entity(&mut rng);
            let mut prompt = vec![corpus::BOS];
            let mut doc_rng = SplitMix64::new(seed ^ 0x9a1);
            while prompt.len() < ctx_len / 2 {
                prompt.extend(corpus::gen_doc(&mut doc_rng, 256, 3));
            }
            prompt.extend(intro(&e));
            while prompt.len() + corpus::NAME_LEN + 2 < ctx_len {
                prompt.extend(corpus::gen_doc(&mut doc_rng, 256, 3));
            }
            prompt.truncate(ctx_len - corpus::NAME_LEN - 2);
            prompt.extend(query(&e));
            GenTask {
                name: String::new(),
                prompt,
                expected: vec![e.phrase],
                gen_len: PHRASE_LEN,
                scorer: Scorer::PrefixMatch,
            }
        }
        "qa_2" => {
            // two-document QA with a distractor entity sharing name[1]
            let e = fresh_entity(&mut rng);
            let mut distract = fresh_entity(&mut rng);
            distract.name[1] = e.name[1];
            needle_prompt(&mut rng, ctx_len, &[(0.3, e), (0.7, distract)], 0)
        }
        other => panic!("unknown RULER task `{other}`"),
    };
    t.name = format!("ruler/{name}");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate() {
        for name in RULER_TASKS {
            let t = ruler_task(name, 768, 42);
            assert!(t.prompt.len() >= 700, "{name}: {}", t.prompt.len());
            assert!(t.prompt.len() <= 900, "{name}: {}", t.prompt.len());
            assert!(!t.expected.is_empty());
            assert!(t.gen_len >= PHRASE_LEN);
            assert_eq!(t.name, format!("ruler/{name}"));
        }
    }

    #[test]
    fn tasks_deterministic_per_seed() {
        let a = ruler_task("multikey_2", 512, 5);
        let b = ruler_task("multikey_2", 512, 5);
        assert_eq!(a.prompt, b.prompt);
        assert_ne!(a.prompt, ruler_task("multikey_2", 512, 6).prompt);
    }

    #[test]
    fn multivalue_has_three_values() {
        let t = ruler_task("multivalue", 512, 1);
        assert_eq!(t.expected.len(), 3);
        assert_eq!(t.scorer, Scorer::ContainsAll);
    }

    #[test]
    fn cwe_mentions_repeat() {
        let t = ruler_task("cwe", 1024, 3);
        let e_name = &t.prompt[t.prompt.len() - 1 - corpus::NAME_LEN..t.prompt.len() - 1];
        let count = t.prompt.windows(corpus::NAME_LEN).filter(|w| *w == e_name).count();
        assert!(count >= 4, "only {count} mentions");
    }

    #[test]
    fn fwe_mentions_front_loaded() {
        let t = ruler_task("fwe", 1024, 3);
        let e_name = &t.prompt[t.prompt.len() - 1 - corpus::NAME_LEN..t.prompt.len() - 1];
        let last_mention = t
            .prompt
            .windows(corpus::NAME_LEN)
            .enumerate()
            .filter(|(i, w)| *w == e_name && *i < t.prompt.len() - 8)
            .map(|(i, _)| i)
            .max()
            .unwrap();
        assert!(last_mention < t.prompt.len() / 2, "mention at {last_mention}");
    }
}
