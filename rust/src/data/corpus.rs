//! Synthetic long-range corpus — bit-for-bit mirror of
//! `python/compile/corpus.py` (the substitute for Wikitext-2, DESIGN.md §6).
//! Parity with the python generator is asserted against
//! `artifacts/corpus_golden.json`.

use crate::util::rng::SplitMix64;

pub const VOCAB: i32 = 256;
pub const WORD_BASE: i32 = 16;
pub const N_WORDS: u64 = 184; // background words: [16, 200)
pub const NAME_BASE: i32 = 200;
pub const N_NAMES: u64 = 56; // entity-name tokens: [200, 256)

pub const BOS: i32 = 0;
pub const EOS: i32 = 1;
pub const SEP: i32 = 2;
pub const QUERY: i32 = 3;
pub const ANSWER: i32 = 4;
pub const MARK: i32 = 5;

pub const PHRASE_LEN: usize = 4;
pub const NAME_LEN: usize = 2;

/// j-th Markov successor of `prev` (pure hash — mirror of corpus.succ).
pub fn succ(prev: i32, j: u64) -> i32 {
    WORD_BASE + ((prev as u64 * 2654435761 + j * 40503 + 12345) % N_WORDS) as i32
}

/// Word with linearly decaying rank distribution (min of two uniforms).
pub fn draw_word(rng: &mut SplitMix64) -> i32 {
    let u = rng.below(N_WORDS);
    let v = rng.below(N_WORDS);
    WORD_BASE + u.min(v) as i32
}

/// Entity-name token from the dedicated [NAME_BASE, VOCAB) range.
pub fn draw_name(rng: &mut SplitMix64) -> i32 {
    NAME_BASE + rng.below(N_NAMES) as i32
}

/// One document of exactly `doclen` tokens (mirror of corpus.gen_doc).
pub fn gen_doc(rng: &mut SplitMix64, doclen: usize, n_ent: usize) -> Vec<i32> {
    let mut toks = vec![BOS];
    let mut prev = draw_word(rng);
    let mut ents: Vec<(Vec<i32>, Vec<i32>)> = Vec::new();
    while toks.len() < doclen {
        let a = rng.below(10);
        if a == 0 && ents.len() < n_ent {
            let name: Vec<i32> = (0..NAME_LEN).map(|_| draw_name(rng)).collect();
            let phrase: Vec<i32> = (0..PHRASE_LEN).map(|_| draw_word(rng)).collect();
            toks.push(MARK);
            toks.extend_from_slice(&name);
            toks.push(SEP);
            toks.extend_from_slice(&phrase);
            prev = *phrase.last().unwrap();
            ents.push((name, phrase));
        } else if a == 1 && !ents.is_empty() {
            let i = rng.below(ents.len() as u64) as usize;
            let (name, phrase) = &ents[i];
            toks.push(MARK);
            toks.extend_from_slice(name);
            toks.push(SEP);
            toks.extend_from_slice(phrase);
            prev = *phrase.last().unwrap();
        } else if a == 2 && !ents.is_empty() {
            let i = rng.below(ents.len() as u64) as usize;
            let (name, phrase) = &ents[i];
            toks.push(QUERY);
            toks.extend_from_slice(name);
            toks.push(ANSWER);
            toks.extend_from_slice(phrase);
            prev = *phrase.last().unwrap();
        } else {
            let run = 4 + rng.below(12);
            for _ in 0..run {
                if rng.next_u64() & 1 == 1 {
                    let j = rng.below(4);
                    prev = succ(prev, j);
                } else {
                    prev = draw_word(rng);
                }
                toks.push(prev);
            }
        }
    }
    toks.truncate(doclen);
    toks
}

/// Infinite token stream of concatenated documents (mirror of corpus.stream).
pub struct Stream {
    rng: SplitMix64,
    doclen_min: usize,
    doclen_max: usize,
    n_ent: usize,
    buf: Vec<i32>,
    pos: usize,
}

impl Stream {
    pub fn new(seed: u64, doclen_min: usize, doclen_max: usize, n_ent: usize) -> Self {
        Self { rng: SplitMix64::new(seed), doclen_min, doclen_max, n_ent, buf: Vec::new(), pos: 0 }
    }

    /// Default parameters matching the python eval/golden settings.
    pub fn default_eval(seed: u64) -> Self {
        Self::new(seed, 192, 512, 4)
    }

    pub fn take_n(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.next_token()).collect()
    }

    pub fn next_token(&mut self) -> i32 {
        if self.pos >= self.buf.len() {
            let span = self.doclen_max - self.doclen_min;
            let doclen =
                self.doclen_min + if span > 0 { self.rng.below(span as u64) as usize } else { 0 };
            self.buf = gen_doc(&mut self.rng, doclen, self.n_ent);
            self.pos = 0;
        }
        let t = self.buf[self.pos];
        self.pos += 1;
        t
    }
}

impl Iterator for Stream {
    type Item = i32;
    fn next(&mut self) -> Option<i32> {
        Some(self.next_token())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn deterministic() {
        let a = Stream::default_eval(123).take_n(1000);
        let b = Stream::default_eval(123).take_n(1000);
        assert_eq!(a, b);
        assert_ne!(a, Stream::default_eval(124).take_n(1000));
    }

    #[test]
    fn token_ranges() {
        let toks = Stream::default_eval(9).take_n(3000);
        assert!(toks.iter().all(|&t| (0..VOCAB).contains(&t)));
        assert!(toks.iter().all(|&t| t < 6 || t >= WORD_BASE));
    }

    #[test]
    fn doc_structure() {
        let mut rng = SplitMix64::new(5);
        let doc = gen_doc(&mut rng, 400, 4);
        assert_eq!(doc.len(), 400);
        assert_eq!(doc[0], BOS);
        let span = 1 + NAME_LEN + 1 + PHRASE_LEN;
        let mut i = 0;
        let mut found = 0;
        while i + span < doc.len() {
            if doc[i] == MARK {
                assert_eq!(doc[i + 1 + NAME_LEN], SEP);
                found += 1;
                i += span;
            } else {
                i += 1;
            }
        }
        assert!(found >= 1);
    }

    /// THE parity test: rust generator == python generator, bit for bit.
    #[test]
    fn golden_parity_with_python() {
        let path = crate::artifacts_dir().join("corpus_golden.json");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let g = Json::parse_file(&path).unwrap();
        let streams = g.req("streams").as_obj().unwrap();
        assert_eq!(streams.len(), 3);
        for (seed, toks) in streams {
            let want: Vec<i32> =
                toks.as_arr().unwrap().iter().map(|j| j.as_i64().unwrap() as i32).collect();
            let got = Stream::default_eval(seed.parse().unwrap()).take_n(want.len());
            assert_eq!(got, want, "corpus divergence for seed {seed}");
        }
    }
}
