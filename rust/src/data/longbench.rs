//! LongBench substrate (Bai et al., 2023; paper Tab. 3/4/6 and Fig. 7): 21
//! synthetic datasets in the benchmark's six categories, each mapped to a
//! generator whose *eviction-sensitivity profile* mirrors the original
//! (QA = local answers, summarization/synthetic = global coverage, few-shot
//! = pattern recall, code = recency-dominated) — see DESIGN.md §6.

use super::corpus;
use super::tasks::{filler, fresh_entity, intro, needle_prompt, query, Entity, GenTask, Scorer};
use crate::util::rng::SplitMix64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Category {
    QaSingle,
    QaMulti,
    Summarization,
    FewShot,
    Synthetic,
    Code,
}

/// (dataset name, category, context length, answer depth fraction)
pub const LONGBENCH_DATASETS: [(&str, Category, usize, f64); 21] = [
    ("HotpotQA", Category::QaMulti, 1024, 0.35),
    ("2WikiMultihopQA", Category::QaMulti, 1024, 0.5),
    ("MuSiQue", Category::QaMulti, 1536, 0.45),
    ("DuReader", Category::QaMulti, 1024, 0.65),
    ("MultiFieldQA-en", Category::QaSingle, 768, 0.4),
    ("MultiFieldQA-zh", Category::QaSingle, 768, 0.6),
    ("NarrativeQA", Category::QaSingle, 1536, 0.3),
    ("Qasper", Category::QaSingle, 1024, 0.7),
    ("GovReport", Category::Summarization, 1536, 0.0),
    ("QMSum", Category::Summarization, 1024, 0.0),
    ("MultiNews", Category::Summarization, 768, 0.0),
    ("VCSUM", Category::Summarization, 1024, 0.0),
    ("TriviaQA", Category::FewShot, 768, 0.5),
    ("SAMSum", Category::FewShot, 768, 0.35),
    ("TREC", Category::FewShot, 512, 0.5),
    ("LSHT", Category::FewShot, 512, 0.65),
    ("PassageCount", Category::Synthetic, 1024, 0.0),
    ("PassageRetrieval-en", Category::Synthetic, 1024, 0.2),
    ("PassageRetrieval-zh", Category::Synthetic, 1024, 0.8),
    ("LCC", Category::Code, 768, 0.0),
    ("RepoBench-P", Category::Code, 1024, 0.0),
];

pub fn category_of(dataset: &str) -> Category {
    LONGBENCH_DATASETS
        .iter()
        .find(|(n, _, _, _)| *n == dataset)
        .map(|(_, c, _, _)| *c)
        .unwrap_or_else(|| panic!("unknown LongBench dataset `{dataset}`"))
}

/// Build one LongBench task instance.
pub fn longbench_task(dataset: &str, seed: u64, scale: f64) -> GenTask {
    let (_, cat, base_len, depth) = *LONGBENCH_DATASETS
        .iter()
        .find(|(n, _, _, _)| *n == dataset)
        .unwrap_or_else(|| panic!("unknown LongBench dataset `{dataset}`"));
    let ctx_len = ((base_len as f64) * scale).round() as usize;
    let mut rng = SplitMix64::new(seed ^ hash_name(dataset));
    let mut t = match cat {
        Category::QaSingle => {
            let e = fresh_entity(&mut rng);
            needle_prompt(&mut rng, ctx_len, &[(depth, e)], 0)
        }
        Category::QaMulti => {
            // answered first-hop in-prompt; generate the second hop
            let e1 = fresh_entity(&mut rng);
            let e2 = fresh_entity(&mut rng);
            let d2 = (depth + 0.3).min(0.9);
            let mut task =
                needle_prompt(&mut rng, ctx_len, &[(depth, e1.clone()), (d2, e2.clone())], 1);
            let cut = task.prompt.len() - (corpus::NAME_LEN + 2);
            let mut hop = query(&e1);
            hop.extend_from_slice(&e1.phrase);
            task.prompt.splice(cut..cut, hop);
            task
        }
        Category::Summarization => {
            // global coverage: three entities spread over the document; the
            // earliest is queried (a summary must retain the whole doc)
            let es: Vec<Entity> = (0..3).map(|_| fresh_entity(&mut rng)).collect();
            let needles: Vec<(f64, Entity)> =
                es.iter().enumerate().map(|(i, e)| (0.08 + 0.3 * i as f64, e.clone())).collect();
            let mut task = needle_prompt(&mut rng, ctx_len, &needles, 0);
            task.expected = vec![es[0].phrase.clone()];
            task
        }
        Category::FewShot => {
            // several solved QUERY/ANSWER exemplars precede the final query
            let e = fresh_entity(&mut rng);
            let mut task = needle_prompt(&mut rng, ctx_len, &[(depth, e)], 0);
            let cut = task.prompt.len() - (corpus::NAME_LEN + 2);
            let mut shots = Vec::new();
            for _ in 0..3 {
                let ex = fresh_entity(&mut rng);
                shots.extend(intro(&ex));
                shots.extend(filler(&mut rng, 4));
                shots.extend(query(&ex));
                shots.extend_from_slice(&ex.phrase);
            }
            task.prompt.splice(cut..cut, shots);
            task
        }
        Category::Synthetic => {
            if dataset == "PassageCount" {
                // aggregation over the whole context: the queried entity is
                // re-mentioned in every "passage"
                let e = fresh_entity(&mut rng);
                let mentions: Vec<(f64, Entity)> =
                    [0.1, 0.35, 0.6, 0.85].iter().map(|&d| (d, e.clone())).collect();
                needle_prompt(&mut rng, ctx_len, &mentions, 0)
            } else {
                let e = fresh_entity(&mut rng);
                needle_prompt(&mut rng, ctx_len, &[(depth, e)], 0)
            }
        }
        Category::Code => {
            // induction on a structured "API template": a signature repeated
            // throughout; the final (recent) occurrence must be completed
            let sig: Vec<i32> = (0..6).map(|_| corpus::draw_word(&mut rng)).collect();
            let mut prompt = vec![corpus::BOS];
            while prompt.len() + 40 < ctx_len {
                let run = 16 + rng.below(16) as usize;
                prompt.extend(filler(&mut rng, run));
                prompt.extend_from_slice(&sig);
            }
            prompt.extend(filler(&mut rng, 8));
            prompt.extend_from_slice(&sig[..2]); // start the template ...
            GenTask {
                name: String::new(),
                prompt,
                expected: vec![sig[2..].to_vec()], // ... model completes it
                gen_len: 4,
                scorer: Scorer::PrefixMatch,
            }
        }
    };
    t.name = format!("longbench/{dataset}");
    t
}

fn hash_name(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_21_generate() {
        for (name, _, base_len, _) in LONGBENCH_DATASETS {
            let t = longbench_task(name, 9, 1.0);
            assert!(
                t.prompt.len() >= base_len - 64 && t.prompt.len() <= base_len + 128,
                "{name}: {} vs {base_len}",
                t.prompt.len()
            );
            assert!(!t.expected.is_empty(), "{name}");
        }
    }

    #[test]
    fn scale_shrinks_contexts() {
        let big = longbench_task("NarrativeQA", 1, 1.0);
        let small = longbench_task("NarrativeQA", 1, 0.5);
        assert!(small.prompt.len() < big.prompt.len());
    }

    #[test]
    fn categories_cover_six() {
        use std::collections::BTreeSet;
        let cats: BTreeSet<String> =
            LONGBENCH_DATASETS.iter().map(|(_, c, _, _)| format!("{c:?}")).collect();
        assert_eq!(cats.len(), 6);
    }

    #[test]
    fn code_task_is_recency_answerable() {
        let t = longbench_task("LCC", 4, 1.0);
        // the template prefix appears near the end of the prompt
        let tail = &t.prompt[t.prompt.len() - 16..];
        assert!(tail.len() >= 2);
        assert_eq!(t.scorer, Scorer::PrefixMatch);
    }

    #[test]
    fn deterministic() {
        assert_eq!(longbench_task("TREC", 3, 1.0).prompt, longbench_task("TREC", 3, 1.0).prompt);
    }
}
