//! The inference engine: drives AOT programs through the runtime with the
//! active cache policy applied between calls (windowed scoring for context
//! ingestion / PPL, greedy generate for decoding), plus the simulated
//! device-memory accountant that reproduces the paper's OOM axis.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::cache::{CachePolicy, MassUse};
use crate::runtime::{KvCache, Runtime};

/// Raised (as a string-matched anyhow error) when the memory budget is hit —
/// the full-cache failure mode of Fig. 5.
pub const OOM_MARKER: &str = "simulated-OOM";

pub struct EngineOpts {
    pub model: String,
    /// Score-window length (eviction cadence for teacher-forced evaluation).
    pub w: usize,
    /// Cache capacity (must match a compiled program C).
    pub c: usize,
    /// Simulated device-memory budget for resident KV bytes.
    pub memory_budget_bytes: Option<usize>,
    /// Cold-page Q8 demotion distance (`--kv-quant cold-q8`): pages whose
    /// every token is at least this many full ladder windows behind the
    /// stream head quantize to int8 after each eviction pass. `None` is
    /// `--kv-quant off` — the store stays byte-identical to pre-quantization
    /// behavior.
    pub quantize_after_windows: Option<usize>,
}

pub struct Engine<'rt> {
    rt: &'rt Runtime,
    pub opts: EngineOpts,
    pub policy: Box<dyn CachePolicy>,
    pub cache: KvCache,
    /// Device shard every runtime call routes through. Defaults to 0 (the
    /// single-device CLI/eval paths never change it); serving assigns it at
    /// admission from the placement policy, before the first device call.
    pub shard: usize,
    /// Original-stream token index of the next token to ingest.
    pub n_tokens: u64,
    pub last_token: i32,
    /// Total evictions performed (diagnostics).
    pub n_evicted: u64,
    /// Compaction events (iterative-compaction counter).
    pub n_compactions: u64,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, opts: EngineOpts, policy: Box<dyn CachePolicy>) -> Result<Self> {
        let lm = rt.model(&opts.model)?;
        let cfg = &lm.cfg;
        if policy.budget() != usize::MAX && policy.budget() + opts.w > opts.c {
            bail!(
                "budget {} + window {} exceeds program capacity {}",
                policy.budget(),
                opts.w,
                opts.c
            );
        }
        let mut cache = KvCache::new(cfg.n_layers, cfg.n_heads, opts.c, cfg.head_dim);
        cache.set_quant(opts.quantize_after_windows.is_some());
        Ok(Self {
            rt,
            opts,
            policy,
            cache,
            shard: 0,
            n_tokens: 0,
            last_token: crate::data::corpus::BOS,
            n_evicted: 0,
            n_compactions: 0,
        })
    }

    pub fn reset(&mut self) {
        let (l, h, c, dh) = (self.cache.l, self.cache.h, self.cache.c, self.cache.dh);
        // release the old cache's device-tier buffers and scratch image
        // deterministically (mirrors the KvCache Drop -> arena page return
        // path; dropped caches are also swept lazily, but reset should not
        // leave stale staging bytes until the next sweep point)
        self.rt.release_cache_state(self.cache.id());
        self.cache = KvCache::new(l, h, c, dh);
        self.cache.set_quant(self.opts.quantize_after_windows.is_some());
        self.n_tokens = 0;
        self.last_token = crate::data::corpus::BOS;
        self.n_evicted = 0;
        self.n_compactions = 0;
    }

    /// Resume from a frozen cross-request prefix: install the snapshot's
    /// shared pages into this engine's empty cache (no copying — mutation
    /// goes through the arena's CoW) and fast-forward the stream counter
    /// past the matched tokens. Only valid on a fresh engine; the caller
    /// guarantees the snapshot came from the same `(model, policy, window,
    /// capacity)` signature, which is what makes the adopted state equal a
    /// from-scratch prefill of those tokens.
    pub fn adopt_prefix(
        &mut self,
        snap: &crate::runtime::PrefixSnapshot,
        n_tokens: u64,
        last_token: i32,
    ) -> Result<()> {
        if self.n_tokens != 0 {
            bail!("adopt_prefix: engine already ingested {} tokens", self.n_tokens);
        }
        snap.apply(&mut self.cache)?;
        self.n_tokens = n_tokens;
        self.last_token = last_token;
        Ok(())
    }

    fn scored(&self) -> bool {
        self.policy.needs_scores()
    }

    fn check_memory(&self, extra_tokens: usize) -> Result<()> {
        if let Some(limit) = self.opts.memory_budget_bytes {
            let per_tok = 2 * self.cache.h * self.cache.dh * 4 * self.cache.l;
            let projected = self.cache.kv_bytes() + extra_tokens * per_tok;
            if projected > limit {
                bail!(
                    "{OOM_MARKER}: resident KV {} + window {} bytes > budget {} \
                     (at stream position {})",
                    self.cache.kv_bytes(),
                    extra_tokens * per_tok,
                    limit,
                    self.n_tokens
                );
            }
        }
        // hard capacity check (full-cache runs exhaust the compiled C)
        if self.cache.max_len() + extra_tokens > self.opts.c {
            bail!(
                "{OOM_MARKER}: cache capacity C={} exhausted at stream position {} \
                 (resident {}, incoming {extra_tokens})",
                self.opts.c,
                self.n_tokens,
                self.cache.max_len()
            );
        }
        Ok(())
    }

    fn evict(&mut self) -> Result<()> {
        let before = self.cache.max_len();
        let n = self.policy.evict(&mut self.cache)?;
        if n > 0 {
            self.n_evicted += n as u64;
            self.n_compactions += 1;
        }
        debug_assert!(self.cache.check_invariants().is_ok());
        let _ = before;
        Ok(())
    }

    /// Cold-page demotion hook (`--kv-quant cold-q8`): after each eviction
    /// pass, quantize every page all of whose tokens are at least
    /// `quantize_after_windows` full ladder windows behind the stream head.
    /// Pages touched this window are inside the open dirty ranges and are
    /// skipped until the next sync point, so demotion trails the hot tail.
    fn demote_cold(&mut self) {
        if let Some(after) = self.opts.quantize_after_windows {
            let cutoff = self.n_tokens.saturating_sub((after * self.opts.w) as u64);
            self.cache.demote_cold(cutoff);
        }
    }

    /// Teacher-forced scoring of a token stream continuation: returns the
    /// per-token logprobs of `targets[i] = stream[i+1]` for the provided
    /// `tokens`. Applies the eviction policy every window (the iterative
    /// compaction cadence).
    pub fn feed_score(&mut self, tokens: &[i32], targets: &[i32]) -> Result<Vec<f32>> {
        if tokens.len() != targets.len() {
            bail!("tokens/targets length mismatch");
        }
        let w = self.opts.w;
        let scored = self.scored();
        let mut out = Vec::with_capacity(tokens.len());
        for (chunk_t, chunk_g) in tokens.chunks(w).zip(targets.chunks(w)) {
            let n_valid = chunk_t.len();
            self.check_memory(n_valid)?;
            if self.policy.mass_use() == MassUse::LastWindow {
                for l in 0..self.cache.l {
                    for m in self.cache.mass[l].iter_mut() {
                        *m = 0.0;
                    }
                }
            }
            let so = self.rt.score_on(
                self.shard,
                &self.opts.model,
                w,
                self.opts.c,
                scored,
                chunk_t,
                chunk_g,
                &mut self.cache,
            )?;
            out.extend_from_slice(&so.logprobs[..n_valid]);
            // merge window KV into every layer, then compact
            let (l, h, dh, c) = (self.cache.l, self.cache.h, self.cache.dh, self.cache.c);
            for layer in 0..l {
                let base = layer * h * w * dh;
                let wk = &so.win_k[base..base + h * w * dh];
                let wv = &so.win_v[base..base + h * w * dh];
                self.cache.append_layer(layer, wk, wv, w, n_valid, self.n_tokens)?;
            }
            if let Some(mass) = &so.mass {
                // device row layout [L, C+W]: resident slots then window slots
                for layer in 0..l {
                    let row = &mass[layer * (c + w)..(layer + 1) * (c + w)];
                    // window tokens were appended after `old_len` resident
                    // slots; stitch their mass onto the appended entries
                    let old_len = self.cache.lens[layer] - n_valid;
                    let mut stitched = row[..old_len].to_vec();
                    stitched.extend_from_slice(&row[c..c + n_valid]);
                    for (i, &mv) in stitched.iter().enumerate() {
                        self.cache.mass[layer][i] += mv as f64;
                    }
                }
            }
            self.n_tokens += n_valid as u64;
            self.last_token = *chunk_t.last().unwrap();
            self.evict()?;
            self.demote_cold();
        }
        Ok(out)
    }

    /// Ingest context without keeping logprobs (prompt prefill path).
    pub fn prefill(&mut self, tokens: &[i32]) -> Result<()> {
        if tokens.is_empty() {
            return Ok(());
        }
        // targets = next tokens (last target is a dummy BOS)
        let mut targets: Vec<i32> = tokens[1..].to_vec();
        targets.push(crate::data::corpus::BOS);
        self.feed_score(tokens, &targets)?;
        Ok(())
    }

    /// Greedy-decode `n` tokens (chunked through the compiled K-step
    /// programs), applying the policy between chunks.
    pub fn generate(&mut self, n: usize) -> Result<Vec<i32>> {
        Ok(self.generate_timed(n)?.0)
    }

    /// [`Self::generate`], also returning the instant the FIRST token of
    /// this call materialized — stamped right after the first program call
    /// returns, not after the whole chunk loop, so the serving layer's TTFT
    /// measures time-to-first-token rather than time-to-first-quantum.
    pub fn generate_timed(&mut self, n: usize) -> Result<(Vec<i32>, Option<Instant>)> {
        let scored = self.scored();
        let mut out = Vec::with_capacity(n);
        let mut t_first: Option<Instant> = None;
        let mut remaining = n;
        while remaining > 0 {
            // scored programs are only compiled at K=16; over-generate and
            // roll the surplus back after the call
            let k = if remaining >= 16 || scored { 16 } else { 1 };
            self.check_memory(k)?;
            if self.policy.mass_use() == MassUse::LastWindow {
                for l in 0..self.cache.l {
                    for m in self.cache.mass[l].iter_mut() {
                        *m = 0.0;
                    }
                }
            }
            let mut go = self.rt.generate_on(
                self.shard,
                &self.opts.model,
                k,
                scored,
                &mut self.cache,
                self.last_token,
            )?;
            if t_first.is_none() {
                // the first token of the call exists as soon as the first
                // program call returns
                t_first = Some(Instant::now());
            }
            // merge the appended rows and adopt the downloaded state as the
            // next upload's scratch image (the steady-state decode path
            // re-gathers nothing)
            self.rt.absorb_generated_on(self.shard, &mut self.cache, &mut go, k, self.n_tokens)?;
            if let Some(mass) = &go.mass {
                let c = self.cache.c;
                for layer in 0..self.cache.l {
                    self.cache.add_mass(layer, &mass[layer * c..(layer + 1) * c]);
                }
            }
            let take = k.min(remaining);
            if take < k {
                // the device appended k slots but the caller only receives
                // `take` tokens: drop the surplus so the next quantum
                // continues from the last *returned* token, not k-take
                // tokens past it
                for layer in 0..self.cache.l {
                    let keep = self.cache.lens[layer] - (k - take);
                    self.cache.truncate_layer(layer, keep)?;
                }
            }
            out.extend_from_slice(&go.tokens[..take]);
            self.last_token = go.tokens[take - 1];
            self.n_tokens += take as u64;
            remaining -= take;
            self.evict()?;
            self.demote_cold();
        }
        Ok((out, t_first))
    }

    /// One decode step returning the *logits* (serving path with host-side
    /// sampling).
    pub fn step_logits(&mut self) -> Result<Vec<f32>> {
        self.check_memory(1)?;
        let mut go = self.rt.generate_on(
            self.shard,
            &self.opts.model,
            1,
            false,
            &mut self.cache,
            self.last_token,
        )?;
        self.rt.absorb_generated_on(self.shard, &mut self.cache, &mut go, 1, self.n_tokens)?;
        self.last_token = go.tokens[0];
        self.n_tokens += 1;
        self.evict()?;
        self.demote_cold();
        Ok(go.last_logits)
    }

    /// Force the sampled token to `tok` (after host-side sampling the device
    /// already appended KV for its own greedy choice — the KV of a token
    /// depends only on the *input* token at that step, which was
    /// `last_token`, so the cache is correct; only the continuation token
    /// changes).
    pub fn set_last_token(&mut self, tok: i32) {
        self.last_token = tok;
    }
}

pub fn is_oom(err: &anyhow::Error) -> bool {
    let msg = format!("{err:#}");
    msg.contains(OOM_MARKER) || msg.contains(crate::runtime::ARENA_OOM_MARKER)
}
