//! Flight recorder: a bounded, always-on ring buffer of structured serving
//! events, recorded at every request-lifecycle edge across the scheduler,
//! runtime, and wire layers.
//!
//! Aggregate counters (`op:stats`) say *how much*; the flight recorder says
//! *in what order, for which request*. Every significant edge — queued /
//! admitted / placed, each prefill window, submit/reap of device calls,
//! retry / quarantine, residency hit / spill / donation, prefix adopt /
//! freeze / evict, quant demote / promote, cancellation / deadline — emits
//! one fixed-size [`Event`] into a global fixed-capacity ring. `op:trace`
//! dumps the recent window (filterable by `seq`, `kind`, `since`), and a
//! `trace: true` generate request gets its own phase-timing breakdown
//! attached to the reply.
//!
//! Design constraints (and how they are met):
//!
//! - **Bounded.** The ring is preallocated once ([`FlightRecorder::configure`],
//!   default [`DEFAULT_CAPACITY`] events); on overflow the oldest event is
//!   overwritten and `trace_dropped_total` incremented. Memory is
//!   `capacity * size_of::<Event>()`, independent of uptime.
//! - **Non-blocking on the hot path.** Recording never allocates (events are
//!   plain `Copy` structs with two integer payload slots instead of strings)
//!   and never waits: the ring is guarded by a `try_lock` — a contended
//!   record is *dropped and counted*, not queued. Sequencing is one relaxed
//!   atomic `fetch_add`.
//! - **`Send`/`Sync`.** The recorder is a process-global singleton
//!   ([`recorder`]); worker-pool call sites record through the same handle.
//! - **Byte-invisible to generation.** Recording touches no KV state; the
//!   scheduler property test pins token streams and FNV-1a KV checksums
//!   identical with tracing on vs off (see `server::batcher` tests).
//!
//! Sampling: `--trace-sample-every N` keeps every Nth event *per kind* (so a
//! chatty kind cannot starve rare kinds out of the sample), `1` records
//! everything (default), `0` disables recording entirely.
//!
//! Event keying: scheduler lifecycle events (`queued` … `finished`) carry
//! the request id in `seq`, so a request's whole phase chain is one `seq`
//! filter away. Runtime-layer events (residency, prefix, quant) happen below
//! the request boundary and carry the KV cache id (residency/quant) or the
//! prefix tree's LRU clock tick (prefix) instead — see the taxonomy table
//! in PERF.md "Observability".

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock, TryLockError};
use std::time::Instant;

use crate::util::json::Json;

/// Default ring capacity in events (~3 MiB at 48 B/event).
pub const DEFAULT_CAPACITY: usize = 65_536;

/// What happened at a lifecycle edge. Payload slots `a`/`b` are
/// kind-specific (documented per variant); unused slots are 0.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// Request entered the scheduler queue. `a` = prompt tokens,
    /// `b` = max_new_tokens.
    Queued = 0,
    /// Request left the queue for the active set. `a` = prompt tokens
    /// remaining to prefill (after prefix adoption), `b` = adopted prefix
    /// tokens.
    Admitted,
    /// Placement decided the request's shard (recorded in `shard`).
    /// `a` = adopted prefix tokens, `b` = placement kind code (see
    /// `PlacementKind::code`: 0 local-prefix, 1 least-loaded, 2 spillover,
    /// 3 host-only).
    Placed,
    /// One prefill window submitted. `a` = window start position,
    /// `b` = window length in tokens.
    PrefillWindow,
    /// First generated token observed. `a` = microseconds since queued
    /// when known, else 0.
    FirstToken,
    /// Request exited the scheduler. `a` = generated tokens, `b` = 0 clean /
    /// 1 errored / 2 cancelled.
    Finished,
    /// A device call left for the backend. `a` = 0 prefill / 1 decode,
    /// `b` = tokens in the call.
    SubmitCall,
    /// A device call came back. `a` = 0 ok / 1 error.
    ReapCall,
    /// A failed call was rolled back and re-submitted. `a` = attempt number,
    /// `b` = backoff milliseconds.
    Retry,
    /// Retry budget exhausted or fatal error: the request exits with a
    /// structured error. `a` = attempts used. A second, shard-level form
    /// marks a device tier tripping its sticky degraded bypass:
    /// `seq` = 0 (no single sequence at fault), `shard` = device ordinal,
    /// `a` = consecutive failures, `b` = 1.
    Quarantine,
    /// Client cancelled (disconnect). `a` = tokens generated so far.
    Cancelled,
    /// Deadline exceeded. `a` = tokens generated so far.
    Deadline,
    /// Device residency tier served a decode from a resident image.
    /// `seq` = KV cache id, `a` = reconciled bytes.
    ResidencyHit,
    /// Residency miss: full image upload. `seq` = KV cache id,
    /// `a` = image bytes, `b` = 1 on the degraded bypass path, else 0.
    ResidencyMiss,
    /// LRU spill of a resident image to host scratch. `seq` = KV cache id,
    /// `a` = bytes.
    Spill,
    /// Donated decode step kept the image resident. `seq` = KV cache id,
    /// `a` = resident bytes kept on-device.
    Donation,
    /// A prefix snapshot was adopted by a new sequence. `seq` = the tree's
    /// LRU clock tick, `shard` = the snapshot's home shard, `a` = matched
    /// tokens, `b` = snapshot bytes.
    PrefixAdopt,
    /// A full-window boundary froze pages into the prefix cache.
    /// `seq` = the tree's LRU clock tick, `shard` = home shard,
    /// `a` = snapshot tokens, `b` = snapshot bytes.
    PrefixFreeze,
    /// Capacity eviction from the prefix cache. `seq` = the tree's LRU
    /// clock tick, `a` = evicted bytes.
    PrefixEvict,
    /// A cold page was demoted to int8. `seq` = KV cache id, `a` = layer,
    /// `b` = page index.
    QuantDemote,
    /// A Q8 page was promoted back to f32 (write / un-share).
    /// `seq` = KV cache id, `a` = page index, `b` = 1 when the promotion
    /// CoW-copied a shared page, 0 for an in-place owned promote.
    QuantPromote,
}

/// Every kind, in discriminant order (indexes the per-kind sampling
/// counters; keep in sync with the enum).
pub const KINDS: [EventKind; 21] = [
    EventKind::Queued,
    EventKind::Admitted,
    EventKind::Placed,
    EventKind::PrefillWindow,
    EventKind::FirstToken,
    EventKind::Finished,
    EventKind::SubmitCall,
    EventKind::ReapCall,
    EventKind::Retry,
    EventKind::Quarantine,
    EventKind::Cancelled,
    EventKind::Deadline,
    EventKind::ResidencyHit,
    EventKind::ResidencyMiss,
    EventKind::Spill,
    EventKind::Donation,
    EventKind::PrefixAdopt,
    EventKind::PrefixFreeze,
    EventKind::PrefixEvict,
    EventKind::QuantDemote,
    EventKind::QuantPromote,
];

impl EventKind {
    /// Wire name (kebab-case), used by `op:trace` filters and dumps.
    pub fn as_str(self) -> &'static str {
        match self {
            EventKind::Queued => "queued",
            EventKind::Admitted => "admitted",
            EventKind::Placed => "placed",
            EventKind::PrefillWindow => "prefill-window",
            EventKind::FirstToken => "first-token",
            EventKind::Finished => "finished",
            EventKind::SubmitCall => "submit-call",
            EventKind::ReapCall => "reap-call",
            EventKind::Retry => "retry",
            EventKind::Quarantine => "quarantine",
            EventKind::Cancelled => "cancelled",
            EventKind::Deadline => "deadline",
            EventKind::ResidencyHit => "residency-hit",
            EventKind::ResidencyMiss => "residency-miss",
            EventKind::Spill => "spill",
            EventKind::Donation => "donation",
            EventKind::PrefixAdopt => "prefix-adopt",
            EventKind::PrefixFreeze => "prefix-freeze",
            EventKind::PrefixEvict => "prefix-evict",
            EventKind::QuantDemote => "quant-demote",
            EventKind::QuantPromote => "quant-promote",
        }
    }

    /// Inverse of [`Self::as_str`] (`None` for unknown names).
    pub fn parse(s: &str) -> Option<Self> {
        KINDS.iter().copied().find(|k| k.as_str() == s)
    }
}

/// One recorded lifecycle edge. Fixed-size and `Copy`: recording never
/// allocates.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Global monotonic event sequence number (1-based); the `since`
    /// watermark of `op:trace` filters on this.
    pub at: u64,
    /// Microseconds since the recorder was created (monotonic clock).
    pub t_us: u64,
    /// Request id for scheduler lifecycle kinds; KV cache id (or other
    /// kind-specific key) for runtime kinds.
    pub seq: u64,
    /// Shard the event happened on (0 when not shard-specific).
    pub shard: u16,
    pub kind: EventKind,
    /// Kind-specific payload (see [`EventKind`]).
    pub a: i64,
    /// Kind-specific payload (see [`EventKind`]).
    pub b: i64,
}

impl Event {
    /// Wire form for `op:trace` dumps.
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("at", (self.at as i64).into()),
            ("t_us", (self.t_us as i64).into()),
            ("seq", (self.seq as i64).into()),
            ("shard", (self.shard as i64).into()),
            ("kind", self.kind.as_str().into()),
            ("a", self.a.into()),
            ("b", self.b.into()),
        ])
    }
}

/// `op:trace` query: every field is optional and conjunctive.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TraceFilter {
    /// Only events with this `seq` key.
    pub seq: Option<u64>,
    /// Only events of this kind.
    pub kind: Option<EventKind>,
    /// Only events with `at > since` (resume from a watermark).
    pub since: Option<u64>,
    /// Keep at most the LAST `limit` matching events (0 = unlimited).
    pub limit: usize,
}

impl TraceFilter {
    fn matches(&self, e: &Event) -> bool {
        self.seq.map_or(true, |s| e.seq == s)
            && self.kind.map_or(true, |k| e.kind == k)
            && self.since.map_or(true, |w| e.at > w)
    }
}

/// Fixed-capacity drop-oldest ring. `buf` is preallocated at configure time;
/// once full, `head` walks the buffer circularly overwriting the oldest
/// slot.
struct Ring {
    buf: Vec<Event>,
    cap: usize,
    /// Next write index once `buf.len() == cap`.
    head: usize,
}

impl Ring {
    fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(16);
        Self { buf: Vec::with_capacity(cap), cap, head: 0 }
    }

    /// Append, overwriting the oldest event when full. Returns true when an
    /// event was overwritten (counted as dropped).
    fn push(&mut self, e: Event) -> bool {
        if self.buf.len() < self.cap {
            self.buf.push(e);
            return false;
        }
        self.buf[self.head] = e;
        self.head = (self.head + 1) % self.cap;
        true
    }

    /// Visit events oldest-first.
    fn iter_ordered(&self) -> impl Iterator<Item = &Event> {
        let (older, newer) = self.buf.split_at(self.head);
        newer.iter().chain(older.iter())
    }
}

/// The process-global flight recorder. See the module docs for the
/// guarantees; obtain the singleton via [`recorder`].
pub struct FlightRecorder {
    epoch: Instant,
    ring: Mutex<Ring>,
    next_at: AtomicU64,
    dropped: AtomicU64,
    sample_every: AtomicU64,
    /// Per-kind sampling counters (indexed by discriminant).
    seen: [AtomicU64; KINDS.len()],
}

impl FlightRecorder {
    fn new(capacity: usize, sample_every: u64) -> Self {
        Self {
            epoch: Instant::now(),
            ring: Mutex::new(Ring::with_capacity(capacity)),
            next_at: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            sample_every: AtomicU64::new(sample_every),
            seen: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Re-arm the recorder: set the sampling stride (`0` disables recording,
    /// `1` records everything, `N` keeps every Nth event per kind) and
    /// reallocate the ring to `capacity` events. The one allocation happens
    /// here; recording afterwards is allocation-free. Existing events are
    /// discarded; the `at` sequence and `trace_dropped_total` keep counting.
    pub fn configure(&self, sample_every: usize, capacity: usize) {
        self.sample_every.store(sample_every as u64, Ordering::Relaxed);
        let mut g = lock_ring(&self.ring);
        *g = Ring::with_capacity(capacity);
    }

    /// Record one event. Never blocks and never allocates: a contended ring
    /// lock drops the event (counted in `trace_dropped_total`), a full ring
    /// overwrites the oldest event (also counted).
    #[inline]
    pub fn record(&self, kind: EventKind, seq: u64, shard: usize, a: i64, b: i64) {
        let every = self.sample_every.load(Ordering::Relaxed);
        if every == 0 {
            return;
        }
        if every > 1 {
            let n = self.seen[kind as usize].fetch_add(1, Ordering::Relaxed);
            if n % every != 0 {
                return;
            }
        }
        let mut g = match self.ring.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
        };
        let at = self.next_at.fetch_add(1, Ordering::Relaxed) + 1;
        let t_us = self.epoch.elapsed().as_micros() as u64;
        let overwrote = g.push(Event { at, t_us, seq, shard: shard as u16, kind, a, b });
        drop(g);
        if overwrote {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Events dropped so far: ring overwrites + lock-contention drops.
    /// Exposed on `op:ping` as `trace_dropped_total`.
    pub fn dropped_total(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// The `at` of the most recently issued event (0 before any). A client
    /// resuming a trace passes this back as `since`.
    pub fn watermark(&self) -> u64 {
        self.next_at.load(Ordering::Relaxed)
    }

    /// Dump matching events oldest-first (at most `filter.limit` newest when
    /// the limit is nonzero).
    pub fn snapshot(&self, filter: &TraceFilter) -> Vec<Event> {
        let g = lock_ring(&self.ring);
        let mut out: Vec<Event> = g.iter_ordered().filter(|e| filter.matches(e)).copied().collect();
        if filter.limit > 0 && out.len() > filter.limit {
            out.drain(..out.len() - filter.limit);
        }
        out
    }

    /// All events for one request id, oldest-first — the per-request phase
    /// breakdown a `trace: true` generate attaches to its reply.
    pub fn phases_for(&self, seq: u64) -> Vec<Event> {
        self.snapshot(&TraceFilter { seq: Some(seq), ..TraceFilter::default() })
    }
}

fn lock_ring(m: &Mutex<Ring>) -> std::sync::MutexGuard<'_, Ring> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

static GLOBAL: OnceLock<FlightRecorder> = OnceLock::new();

/// The process-global recorder (created on first use with the default
/// capacity and sample-every 1; `run_server` re-arms it from `ServeConfig`).
pub fn recorder() -> &'static FlightRecorder {
    GLOBAL.get_or_init(|| FlightRecorder::new(DEFAULT_CAPACITY, 1))
}

/// Record one event on the global recorder — the one-liner the
/// instrumentation hooks call.
#[inline]
pub fn record(kind: EventKind, seq: u64, shard: usize, a: i64, b: i64) {
    recorder().record(kind, seq, shard, a, b);
}

/// Serializes tests (and benches) that reconfigure the global recorder —
/// sampling stride and ring capacity are process-global, so concurrent
/// `cargo test` threads that toggle them must take this guard first.
#[doc(hidden)]
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_round_trip() {
        for k in KINDS {
            assert_eq!(EventKind::parse(k.as_str()), Some(k), "{}", k.as_str());
        }
        assert_eq!(EventKind::parse("no-such-kind"), None);
        // discriminants index the sampling counters: they must be dense
        for (i, k) in KINDS.iter().enumerate() {
            assert_eq!(*k as usize, i);
        }
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let r = FlightRecorder::new(16, 1);
        for i in 0..40u64 {
            r.record(EventKind::Queued, i, 0, 0, 0);
        }
        assert_eq!(r.dropped_total(), 24, "40 events into 16 slots drop 24");
        let ev = r.snapshot(&TraceFilter::default());
        assert_eq!(ev.len(), 16);
        // the survivors are the NEWEST 16, oldest-first
        assert_eq!(ev.first().unwrap().seq, 24);
        assert_eq!(ev.last().unwrap().seq, 39);
        let ats: Vec<u64> = ev.iter().map(|e| e.at).collect();
        assert!(ats.windows(2).all(|w| w[0] < w[1]), "dump must be at-ordered");
        assert_eq!(r.watermark(), 40);
    }

    #[test]
    fn filters_by_seq_kind_since_and_limit() {
        let r = FlightRecorder::new(64, 1);
        r.record(EventKind::Queued, 7, 0, 0, 0);
        r.record(EventKind::Admitted, 7, 0, 0, 0);
        r.record(EventKind::Queued, 8, 0, 0, 0);
        let w = r.watermark();
        r.record(EventKind::Finished, 7, 0, 5, 0);
        r.record(EventKind::Finished, 8, 0, 3, 0);

        let f7 = r.snapshot(&TraceFilter { seq: Some(7), ..Default::default() });
        assert_eq!(f7.len(), 3);
        assert!(f7.iter().all(|e| e.seq == 7));

        let fins =
            r.snapshot(&TraceFilter { kind: Some(EventKind::Finished), ..Default::default() });
        assert_eq!(fins.len(), 2);

        let after = r.snapshot(&TraceFilter { since: Some(w), ..Default::default() });
        assert_eq!(after.len(), 2, "watermark resume returns only newer events");
        assert!(after.iter().all(|e| e.at > w));

        let last2 = r.snapshot(&TraceFilter { limit: 2, ..Default::default() });
        assert_eq!(last2.len(), 2);
        assert_eq!(last2[1].seq, 8, "limit keeps the newest events");

        // conjunctive: seq AND kind
        let q7 = r.snapshot(&TraceFilter {
            seq: Some(7),
            kind: Some(EventKind::Queued),
            ..Default::default()
        });
        assert_eq!(q7.len(), 1);
    }

    #[test]
    fn sampling_keeps_every_nth_per_kind() {
        let _g = test_guard();
        let r = FlightRecorder::new(256, 3);
        for i in 0..9u64 {
            r.record(EventKind::Donation, i, 0, 0, 0);
        }
        // a rare kind is NOT starved by the chatty one: its own counter
        // starts fresh, so its first occurrence records
        r.record(EventKind::Quarantine, 99, 0, 0, 0);
        let d = r.snapshot(&TraceFilter { kind: Some(EventKind::Donation), ..Default::default() });
        assert_eq!(d.len(), 3, "every 3rd of 9 donations");
        let q =
            r.snapshot(&TraceFilter { kind: Some(EventKind::Quarantine), ..Default::default() });
        assert_eq!(q.len(), 1, "per-kind counters: first quarantine always records");
    }

    #[test]
    fn sample_every_zero_disables() {
        let r = FlightRecorder::new(64, 0);
        r.record(EventKind::Queued, 1, 0, 0, 0);
        assert!(r.snapshot(&TraceFilter::default()).is_empty());
        assert_eq!(r.dropped_total(), 0, "disabled recording is not 'dropping'");
        r.configure(1, 64);
        r.record(EventKind::Queued, 2, 0, 0, 0);
        assert_eq!(r.snapshot(&TraceFilter::default()).len(), 1);
    }

    #[test]
    fn event_json_shape() {
        let e = Event {
            at: 3,
            t_us: 250,
            seq: 42,
            shard: 1,
            kind: EventKind::Placed,
            a: 64,
            b: 0,
        };
        let j = e.to_json();
        assert_eq!(j.usize_of("at"), Some(3));
        assert_eq!(j.usize_of("seq"), Some(42));
        assert_eq!(j.usize_of("shard"), Some(1));
        assert_eq!(j.str_of("kind"), Some("placed"));
        assert_eq!(j.f64_of("a"), Some(64.0));
    }

    #[test]
    fn recorder_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<FlightRecorder>();
        assert_send_sync::<Event>();
    }
}
