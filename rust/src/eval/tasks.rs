//! Task-suite runner: drives a [`GenTask`] through the engine (windowed
//! context ingestion under the active policy, then greedy generation) and
//! scores the output. Also measures wall-clock throughput — the Fig. 7 axis.

use std::time::Instant;

use anyhow::Result;

use crate::cache::make_policy;
use crate::data::tasks::{score_generation, GenTask};
use crate::engine::{Engine, EngineOpts};
use crate::runtime::Runtime;

#[derive(Clone, Debug)]
pub struct TaskResult {
    pub name: String,
    pub score: f64,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    pub wall_s: f64,
}

/// Run one task under one policy.
pub fn run_task(
    rt: &Runtime,
    model: &str,
    policy_spec: &str,
    w: usize,
    c: usize,
    task: &GenTask,
) -> Result<TaskResult> {
    let cfg = rt.model(model)?.cfg.clone();
    let policy = make_policy(policy_spec, cfg.n_layers)?;
    let opts = EngineOpts {
        model: model.into(),
        w,
        c,
        memory_budget_bytes: None,
        quantize_after_windows: None,
    };
    let mut eng = Engine::new(rt, opts, policy)?;
    let t0 = Instant::now();
    eng.prefill(&task.prompt)?;
    let gen = eng.generate(task.gen_len)?;
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(TaskResult {
        name: task.name.clone(),
        score: score_generation(task, &gen),
        prompt_tokens: task.prompt.len(),
        gen_tokens: gen.len(),
        wall_s,
    })
}

/// Run a batch of task instances, aggregating score + throughput.
pub fn run_suite(
    rt: &Runtime,
    model: &str,
    policy_spec: &str,
    w: usize,
    c: usize,
    tasks: &[GenTask],
) -> Result<SuiteResult> {
    let mut scores = Vec::new();
    let mut total_tokens = 0usize;
    let mut total_wall = 0.0;
    // warmup: run the first task untimed so lazy program compilation is not
    // billed to whichever policy happens to run first
    let _ = run_task(rt, model, policy_spec, w, c, &tasks[0])?;
    for task in tasks {
        let r = run_task(rt, model, policy_spec, w, c, task)?;
        total_tokens += r.prompt_tokens + r.gen_tokens;
        total_wall += r.wall_s;
        scores.push(r.score);
    }
    let mean = scores.iter().sum::<f64>() / scores.len().max(1) as f64;
    Ok(SuiteResult {
        policy: policy_spec.to_string(),
        mean_score: mean,
        scores,
        tokens_per_s: total_tokens as f64 / total_wall.max(1e-9),
        wall_s: total_wall,
    })
}

#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub policy: String,
    pub mean_score: f64,
    pub scores: Vec<f64>,
    pub tokens_per_s: f64,
    pub wall_s: f64,
}
