//! Needle-In-A-Haystack heatmap harness (paper Fig. 8/9): accuracy over a
//! (context length × needle depth) grid, repeated over seeds.

use anyhow::Result;

use crate::data::tasks::{fresh_entity, needle_prompt};
use crate::eval::tasks::run_task;
use crate::runtime::Runtime;
use crate::util::rng::SplitMix64;

#[derive(Clone, Debug)]
pub struct Heatmap {
    pub ctx_lens: Vec<usize>,
    pub depths: Vec<f64>,
    /// acc[i][j] = accuracy at ctx_lens[i], depths[j].
    pub acc: Vec<Vec<f64>>,
}

impl Heatmap {
    pub fn mean(&self) -> f64 {
        let all: Vec<f64> = self.acc.iter().flatten().copied().collect();
        all.iter().sum::<f64>() / all.len().max(1) as f64
    }

    /// ASCII rendering (the paper's green heatmap, terminal edition).
    pub fn render(&self) -> String {
        let mut s = String::from("ctx\\depth ");
        for d in &self.depths {
            s.push_str(&format!("{d:>6.2}"));
        }
        s.push('\n');
        for (i, c) in self.ctx_lens.iter().enumerate() {
            s.push_str(&format!("{c:>9} "));
            for v in &self.acc[i] {
                s.push_str(&format!("{:>6.2}", v));
            }
            s.push('\n');
        }
        s
    }
}

#[allow(clippy::too_many_arguments)]
pub fn niah_heatmap(
    rt: &Runtime,
    model: &str,
    policy_spec: &str,
    w: usize,
    c: usize,
    ctx_lens: &[usize],
    depths: &[f64],
    reps: usize,
    seed0: u64,
) -> Result<Heatmap> {
    let mut acc = vec![vec![0.0; depths.len()]; ctx_lens.len()];
    for (i, &ctx) in ctx_lens.iter().enumerate() {
        for (j, &depth) in depths.iter().enumerate() {
            let mut total = 0.0;
            for rep in 0..reps {
                let seed = seed0 ^ ((ctx as u64) << 24) ^ ((j as u64) << 8) ^ rep as u64;
                let mut rng = SplitMix64::new(seed);
                let e = fresh_entity(&mut rng);
                let task = needle_prompt(&mut rng, ctx, &[(depth, e)], 0);
                let r = run_task(rt, model, policy_spec, w, c, &task)?;
                total += r.score;
            }
            acc[i][j] = total / reps as f64;
        }
    }
    Ok(Heatmap { ctx_lens: ctx_lens.to_vec(), depths: depths.to_vec(), acc })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heatmap_render_and_mean() {
        let h = Heatmap {
            ctx_lens: vec![256, 512],
            depths: vec![0.2, 0.8],
            acc: vec![vec![1.0, 0.5], vec![0.0, 0.5]],
        };
        assert!((h.mean() - 0.5).abs() < 1e-9);
        let r = h.render();
        assert!(r.contains("256") && r.contains("0.80"));
    }
}
