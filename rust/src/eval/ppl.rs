//! Perplexity harnesses: cumulative decode-length PPL (Tab. 1/2, Fig. 3,
//! Fig. 10) and streaming segment PPL over long corpora (PG19-style,
//! Fig. 5/6).

use anyhow::Result;

use crate::cache::make_policy;
use crate::data::corpus::Stream;
use crate::engine::{is_oom, Engine, EngineOpts};
use crate::runtime::Runtime;

#[derive(Clone, Debug)]
pub struct PplPoint {
    pub len: usize,
    pub ppl: f64,
    pub oom: bool,
}

/// Cumulative PPL at a set of decode lengths (teacher-forced over the
/// synthetic corpus — the Wikitext-2 substitute).
pub fn decode_ppl(
    rt: &Runtime,
    model: &str,
    policy_spec: &str,
    seed: u64,
    lengths: &[usize],
    w: usize,
    c: usize,
    memory_budget_bytes: Option<usize>,
) -> Result<Vec<PplPoint>> {
    let cfg = rt.model(model)?.cfg.clone();
    let policy = make_policy(policy_spec, cfg.n_layers)?;
    let opts =
        EngineOpts { model: model.into(), w, c, memory_budget_bytes, quantize_after_windows: None };
    let mut eng = Engine::new(rt, opts, policy)?;

    let max_len = *lengths.iter().max().unwrap();
    let mut stream = Stream::default_eval(seed);
    let toks = stream.take_n(max_len + 1);

    let mut out = Vec::new();
    let mut nll_sum = 0.0f64;
    let mut n = 0usize;
    let mut checkpoints = lengths.to_vec();
    checkpoints.sort_unstable();
    let mut ci = 0;
    let mut pos = 0usize;
    let mut oom = false;
    while ci < checkpoints.len() {
        let target_len = checkpoints[ci];
        if !oom {
            let step = (target_len - pos).min(w);
            if step == 0 {
                // checkpoint reached
            } else {
                let chunk = &toks[pos..pos + step];
                let tgts = &toks[pos + 1..pos + step + 1];
                match eng.feed_score(chunk, tgts) {
                    Ok(lps) => {
                        for lp in lps {
                            nll_sum -= lp as f64;
                            n += 1;
                        }
                        pos += step;
                    }
                    Err(e) if is_oom(&e) => {
                        oom = true;
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        if oom {
            out.push(PplPoint { len: target_len, ppl: f64::NAN, oom: true });
            ci += 1;
            continue;
        }
        if pos >= target_len {
            out.push(PplPoint { len: target_len, ppl: (nll_sum / n as f64).exp(), oom: false });
            ci += 1;
        }
    }
    Ok(out)
}

/// Streaming segment PPL: local perplexity of each `report_every`-token
/// segment over a very long stream (the Fig. 5/6 curves; the full-cache
/// explosion + OOM point is visible directly).
pub fn stream_ppl_curve(
    rt: &Runtime,
    model: &str,
    policy_spec: &str,
    seed: u64,
    total_len: usize,
    report_every: usize,
    w: usize,
    c: usize,
    memory_budget_bytes: Option<usize>,
) -> Result<Vec<(usize, f64)>> {
    let cfg = rt.model(model)?.cfg.clone();
    let policy = make_policy(policy_spec, cfg.n_layers)?;
    let opts =
        EngineOpts { model: model.into(), w, c, memory_budget_bytes, quantize_after_windows: None };
    let mut eng = Engine::new(rt, opts, policy)?;

    let mut stream = Stream::new(seed, 1024, 4096, 8); // book-like long docs
    let mut prev = stream.next_token();
    let mut curve = Vec::new();
    let mut seg_nll = 0.0f64;
    let mut seg_n = 0usize;
    let mut pos = 0usize;
    'outer: while pos < total_len {
        let step = w.min(total_len - pos);
        let mut chunk = Vec::with_capacity(step);
        let mut tgts = Vec::with_capacity(step);
        let mut cur = prev;
        for _ in 0..step {
            let nxt = stream.next_token();
            chunk.push(cur);
            tgts.push(nxt);
            cur = nxt;
        }
        prev = cur;
        match eng.feed_score(&chunk, &tgts) {
            Ok(lps) => {
                for lp in lps {
                    seg_nll -= lp as f64;
                    seg_n += 1;
                }
            }
            Err(e) if is_oom(&e) => {
                curve.push((pos, f64::NAN)); // OOM sentinel
                break 'outer;
            }
            Err(e) => return Err(e),
        }
        pos += step;
        if seg_n >= report_every {
            curve.push((pos, (seg_nll / seg_n as f64).exp()));
            seg_nll = 0.0;
            seg_n = 0;
        }
    }
    if seg_n > 0 {
        curve.push((pos, (seg_nll / seg_n as f64).exp()));
    }
    Ok(curve)
}
