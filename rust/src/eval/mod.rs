//! Evaluation harnesses reproducing the paper's benchmark suites.
pub mod niah;
pub mod ppl;
pub mod tasks;
