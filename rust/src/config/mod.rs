//! Typed configuration: serving + experiment configs, JSON-file loadable
//! with CLI overrides (the framework's "config system" — vLLM-style).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::args::Args;
use crate::util::json::Json;

/// KV storage precision mode (`--kv-quant`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvQuantMode {
    /// Every page stays f32 — byte-identical to the pre-quantization store
    /// (the exact-mode escape hatch for the tolerance tests).
    Off,
    /// Cold ladder pages demote to per-head symmetric int8 (~4x KV capacity
    /// per byte at a bounded dequantization error). The default.
    #[default]
    ColdQ8,
}

impl KvQuantMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(Self::Off),
            "cold-q8" => Ok(Self::ColdQ8),
            other => anyhow::bail!("unknown --kv-quant mode {other:?} (expected off|cold-q8)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::ColdQ8 => "cold-q8",
        }
    }
}

/// Serving configuration (`lacache-serve --config serve.json`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub model: String,
    pub policy: String,
    /// TCP listen address for the JSON-lines protocol.
    pub listen: String,
    /// Max tokens a single request may generate.
    pub max_new_tokens: usize,
    /// Max in-flight requests admitted to the scheduler queue.
    pub max_queue: usize,
    /// Score-window (prompt ingestion chunk).
    pub window: usize,
    /// Cache capacity (compiled program C).
    pub capacity: usize,
    /// Scheduler quantum: decode steps per scheduling round per sequence.
    pub decode_quantum: usize,
    /// Max concurrently active sequences in the scheduler.
    pub max_active: usize,
    /// Shared paged-KV arena byte budget (0 = unlimited). Drives admission
    /// control: new sequences wait while projected arena occupancy — plus
    /// the staging tiers below — would exceed this, and page allocations
    /// beyond it fail.
    pub kv_pool_bytes: usize,
    /// Dense host scratch images the transfer layer keeps warm (LRU
    /// entries, one per hot sequence; clamped to >= 1 — the gather path
    /// always needs one staging image). Their bytes are exported as
    /// `scratch_resident_bytes` and counted by admission control.
    pub scratch_pool_entries: usize,
    /// Device-residency tier byte capacity (resident K/V images;
    /// cost-aware spill-to-scratch beyond it). 0 disables residency —
    /// every call re-uploads its dense image.
    pub device_pool_bytes: usize,
    /// Cross-request prefix cache byte capacity: arena pages pinned by the
    /// radix tree of frozen prompt-prefix KV states (LRU leaf eviction
    /// beyond it; counted by admission control since pinned pages belong
    /// to no sequence). 0 disables cross-request prefix reuse.
    pub prefix_pool_bytes: usize,
    /// Device calls the scheduler may have in flight at once. 1 (the
    /// default) is the synchronous path: every call runs inline on the
    /// executor thread. > 1 enables split-phase submit/reap over a worker
    /// pool of that size, so one long prefill no longer stalls concurrently
    /// decoding sequences.
    pub max_inflight_calls: usize,
    /// Retry budget per device call: a call failing with a retryable error
    /// (transient / device-lost) is re-submitted up to this many times after
    /// rebuild-from-arena recovery; exhaustion quarantines just that
    /// sequence with a structured error. 0 disables retries.
    pub call_retries: usize,
    /// Base backoff (ms) before the first retry; doubles per attempt
    /// (non-blocking — the sequence sits out submit rounds while the rest
    /// of the fleet keeps decoding).
    pub retry_backoff_ms: usize,
    /// Device shards the runtime partitions itself across (clamped to
    /// >= 1). Each shard gets its own PJRT device, compiled executables,
    /// residency tier with a `device_pool_bytes / devices` byte slice,
    /// scratch pool, and submit/reap executor lane; sequences are placed at
    /// admission by `runtime::placement` (prefix-local first, then
    /// least-loaded-bytes). On the stub backend `--devices N` fabricates N
    /// device slots; under `real-pjrt` the client enumerates platform
    /// devices and this is clamped to what exists.
    pub devices: usize,
    /// KV storage precision: `off` keeps every page f32; `cold-q8` (the
    /// default) demotes cold ladder pages to per-head symmetric int8, so the
    /// same `kv_pool_bytes` admits several times more concurrent sequences
    /// and `prefix_pool_bytes` holds several times more frozen prefixes.
    pub kv_quant: KvQuantMode,
    /// Demotion distance for `cold-q8`: a page quantizes once every one of
    /// its tokens is at least this many full ladder windows behind the
    /// stream head (clamped to >= 1 — the hot window never demotes).
    pub quantize_after_windows: usize,
    /// Flight-recorder sampling stride (`--trace-sample-every`): record
    /// every Nth event per kind. 1 (the default) records everything, 0
    /// disables tracing entirely; `op:trace` serves whatever was kept.
    pub trace_sample_every: usize,
    /// Flight-recorder ring capacity in events (`--trace-buffer-events`):
    /// the bounded in-memory trace buffer. When full the oldest events are
    /// overwritten (counted in `trace_dropped_total`); clamped to a small
    /// minimum so the ring is never useless.
    pub trace_buffer_events: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            model: "base".into(),
            policy: "lacache:budget=128".into(),
            listen: "127.0.0.1:7333".into(),
            max_new_tokens: 256,
            max_queue: 64,
            window: 128,
            capacity: 256,
            decode_quantum: 16,
            max_active: 4,
            kv_pool_bytes: 0,
            scratch_pool_entries: 16,
            device_pool_bytes: 256 << 20,
            prefix_pool_bytes: 64 << 20,
            max_inflight_calls: 1,
            call_retries: 4,
            retry_backoff_ms: 5,
            devices: 1,
            kv_quant: KvQuantMode::ColdQ8,
            quantize_after_windows: 2,
            trace_sample_every: 1,
            trace_buffer_events: crate::obs::DEFAULT_CAPACITY,
        }
    }
}

impl ServeConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let d = Self::default();
        Ok(Self {
            model: j.str_of("model").unwrap_or(&d.model).to_string(),
            policy: j.str_of("policy").unwrap_or(&d.policy).to_string(),
            listen: j.str_of("listen").unwrap_or(&d.listen).to_string(),
            max_new_tokens: j.usize_of("max_new_tokens").unwrap_or(d.max_new_tokens),
            max_queue: j.usize_of("max_queue").unwrap_or(d.max_queue),
            window: j.usize_of("window").unwrap_or(d.window),
            capacity: j.usize_of("capacity").unwrap_or(d.capacity),
            decode_quantum: j.usize_of("decode_quantum").unwrap_or(d.decode_quantum),
            max_active: j.usize_of("max_active").unwrap_or(d.max_active),
            kv_pool_bytes: j.usize_of("kv_pool_bytes").unwrap_or(d.kv_pool_bytes),
            scratch_pool_entries: j
                .usize_of("scratch_pool_entries")
                .unwrap_or(d.scratch_pool_entries),
            device_pool_bytes: j.usize_of("device_pool_bytes").unwrap_or(d.device_pool_bytes),
            prefix_pool_bytes: j.usize_of("prefix_pool_bytes").unwrap_or(d.prefix_pool_bytes),
            max_inflight_calls: j.usize_of("max_inflight_calls").unwrap_or(d.max_inflight_calls),
            call_retries: j.usize_of("call_retries").unwrap_or(d.call_retries),
            retry_backoff_ms: j.usize_of("retry_backoff_ms").unwrap_or(d.retry_backoff_ms),
            devices: j.usize_of("devices").unwrap_or(d.devices).max(1),
            kv_quant: match j.str_of("kv_quant") {
                Some(s) => KvQuantMode::parse(s)?,
                None => d.kv_quant,
            },
            quantize_after_windows: j
                .usize_of("quantize_after_windows")
                .unwrap_or(d.quantize_after_windows)
                .max(1),
            trace_sample_every: j.usize_of("trace_sample_every").unwrap_or(d.trace_sample_every),
            trace_buffer_events: j
                .usize_of("trace_buffer_events")
                .unwrap_or(d.trace_buffer_events),
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_json(&Json::parse_file(path)?)
    }

    /// CLI overrides on top of (optional) file config.
    pub fn from_args(args: &Args) -> Result<Self> {
        let mut cfg = match args.get("config") {
            Some(p) => Self::load(Path::new(p)).context("loading --config")?,
            None => Self::default(),
        };
        if let Some(m) = args.get("model") {
            cfg.model = m.to_string();
        }
        if let Some(p) = args.get("policy") {
            cfg.policy = p.to_string();
        }
        if let Some(l) = args.get("listen") {
            cfg.listen = l.to_string();
        }
        cfg.max_new_tokens = args.usize_or("max-new-tokens", cfg.max_new_tokens);
        cfg.max_queue = args.usize_or("max-queue", cfg.max_queue);
        cfg.window = args.usize_or("window", cfg.window);
        cfg.capacity = args.usize_or("capacity", cfg.capacity);
        cfg.decode_quantum = args.usize_or("decode-quantum", cfg.decode_quantum);
        cfg.max_active = args.usize_or("max-active", cfg.max_active);
        cfg.kv_pool_bytes = args.usize_or("kv-pool-bytes", cfg.kv_pool_bytes);
        cfg.scratch_pool_entries = args.usize_or("scratch-pool-entries", cfg.scratch_pool_entries);
        cfg.device_pool_bytes = args.usize_or("device-pool-bytes", cfg.device_pool_bytes);
        cfg.prefix_pool_bytes = args.usize_or("prefix-pool-bytes", cfg.prefix_pool_bytes);
        cfg.max_inflight_calls = args.usize_or("max-inflight-calls", cfg.max_inflight_calls);
        cfg.call_retries = args.usize_or("call-retries", cfg.call_retries);
        cfg.retry_backoff_ms = args.usize_or("retry-backoff-ms", cfg.retry_backoff_ms);
        cfg.devices = args.usize_or("devices", cfg.devices).max(1);
        if let Some(q) = args.get("kv-quant") {
            cfg.kv_quant = KvQuantMode::parse(q)?;
        }
        cfg.quantize_after_windows =
            args.usize_or("quantize-after-windows", cfg.quantize_after_windows).max(1);
        cfg.trace_sample_every = args.usize_or("trace-sample-every", cfg.trace_sample_every);
        cfg.trace_buffer_events = args.usize_or("trace-buffer-events", cfg.trace_buffer_events);
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("model", self.model.as_str().into()),
            ("policy", self.policy.as_str().into()),
            ("listen", self.listen.as_str().into()),
            ("max_new_tokens", self.max_new_tokens.into()),
            ("max_queue", self.max_queue.into()),
            ("window", self.window.into()),
            ("capacity", self.capacity.into()),
            ("decode_quantum", self.decode_quantum.into()),
            ("max_active", self.max_active.into()),
            ("kv_pool_bytes", self.kv_pool_bytes.into()),
            ("scratch_pool_entries", self.scratch_pool_entries.into()),
            ("device_pool_bytes", self.device_pool_bytes.into()),
            ("prefix_pool_bytes", self.prefix_pool_bytes.into()),
            ("max_inflight_calls", self.max_inflight_calls.into()),
            ("call_retries", self.call_retries.into()),
            ("retry_backoff_ms", self.retry_backoff_ms.into()),
            ("devices", self.devices.into()),
            ("kv_quant", self.kv_quant.as_str().into()),
            ("quantize_after_windows", self.quantize_after_windows.into()),
            ("trace_sample_every", self.trace_sample_every.into()),
            ("trace_buffer_events", self.trace_buffer_events.into()),
        ])
    }
}

/// Shared experiment knobs (scaled-down decode lengths etc. — DESIGN.md §4).
#[derive(Clone, Debug)]
pub struct ExpConfig {
    pub models: Vec<String>,
    pub budgets: Vec<usize>,
    pub lengths: Vec<usize>,
    pub seeds: Vec<u64>,
    pub window: usize,
    pub out_dir: String,
}

impl Default for ExpConfig {
    fn default() -> Self {
        Self {
            models: vec!["base".into(), "mini".into()],
            budgets: vec![128, 64],
            lengths: vec![64, 128, 256, 512, 1024],
            seeds: vec![42],
            window: 32,
            out_dir: "results".into(),
        }
    }
}

impl ExpConfig {
    pub fn from_args(args: &Args) -> Self {
        let d = Self::default();
        Self {
            models: args.list_or("models", &["base", "mini"]),
            budgets: args.usize_list_or("budgets", &d.budgets),
            lengths: args.usize_list_or("lengths", &d.lengths),
            seeds: args
                .get("seeds")
                .map(|_| args.usize_list_or("seeds", &[]).into_iter().map(|s| s as u64).collect())
                .unwrap_or(d.seeds),
            window: args.usize_or("window", d.window),
            out_dir: args.str_or("out", &d.out_dir),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_defaults_and_json() {
        let d = ServeConfig::default();
        let j = d.to_json();
        let back = ServeConfig::from_json(&j).unwrap();
        assert_eq!(back.model, d.model);
        assert_eq!(back.capacity, d.capacity);
        assert_eq!(back.max_active, 4);
        assert_eq!(back.kv_pool_bytes, 0);
        assert_eq!(back.scratch_pool_entries, 16);
        assert_eq!(back.device_pool_bytes, 256 << 20);
        assert_eq!(back.prefix_pool_bytes, 64 << 20);
        assert_eq!(back.max_inflight_calls, 1, "split-phase dispatch defaults to off");
        assert_eq!(back.call_retries, 4);
        assert_eq!(back.retry_backoff_ms, 5);
        assert_eq!(back.devices, 1, "sharding defaults to a single device");
        assert_eq!(back.kv_quant, KvQuantMode::ColdQ8, "tiered compression ships on by default");
        assert_eq!(back.quantize_after_windows, 2);
    }

    #[test]
    fn serve_config_kv_quant_roundtrip_and_clamp() {
        let cfg = ServeConfig {
            kv_quant: KvQuantMode::Off,
            quantize_after_windows: 5,
            ..Default::default()
        };
        let back = ServeConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.kv_quant, KvQuantMode::Off, "exact mode must round-trip");
        assert_eq!(back.quantize_after_windows, 5);
        // 0 windows would demote the hot window itself: clamped to 1 from
        // both JSON and CLI
        let zero = ServeConfig { quantize_after_windows: 0, ..Default::default() };
        assert_eq!(ServeConfig::from_json(&zero.to_json()).unwrap().quantize_after_windows, 1);
        let args = Args::parse(vec!["--quantize-after-windows".into(), "0".into()]);
        assert_eq!(ServeConfig::from_args(&args).unwrap().quantize_after_windows, 1);
        // CLI mode override + bad values rejected with a parse error
        let args = Args::parse(vec!["--kv-quant".into(), "off".into()]);
        assert_eq!(ServeConfig::from_args(&args).unwrap().kv_quant, KvQuantMode::Off);
        let args = Args::parse(vec!["--kv-quant".into(), "q4".into()]);
        let err = ServeConfig::from_args(&args).unwrap_err();
        assert!(format!("{err}").contains("kv-quant"), "{err}");
    }

    #[test]
    fn serve_config_devices_roundtrip_and_clamp() {
        let cfg = ServeConfig { devices: 4, ..Default::default() };
        let back = ServeConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.devices, 4);
        // 0 devices is meaningless: clamped to 1 from both JSON and CLI
        let zero = ServeConfig { devices: 0, ..Default::default() };
        assert_eq!(ServeConfig::from_json(&zero.to_json()).unwrap().devices, 1);
        let args = Args::parse(vec!["--devices".into(), "0".into()]);
        assert_eq!(ServeConfig::from_args(&args).unwrap().devices, 1);
        let args = Args::parse(vec!["--devices".into(), "3".into()]);
        assert_eq!(ServeConfig::from_args(&args).unwrap().devices, 3);
    }

    #[test]
    fn serve_config_cli_overrides() {
        let args = Args::parse(
            [
                "--model",
                "mini",
                "--policy",
                "streaming:budget=64",
                "--capacity",
                "512",
                "--max-active",
                "9",
                "--kv-pool-bytes",
                "1048576",
                "--scratch-pool-entries",
                "5",
                "--device-pool-bytes",
                "2097152",
                "--prefix-pool-bytes",
                "4194304",
                "--max-inflight-calls",
                "3",
                "--call-retries",
                "7",
                "--retry-backoff-ms",
                "20",
                "--kv-quant",
                "off",
                "--quantize-after-windows",
                "3",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        );
        let cfg = ServeConfig::from_args(&args).unwrap();
        assert_eq!(cfg.model, "mini");
        assert_eq!(cfg.policy, "streaming:budget=64");
        assert_eq!(cfg.capacity, 512);
        assert_eq!(cfg.window, 128); // default preserved
        assert_eq!(cfg.max_active, 9);
        assert_eq!(cfg.kv_pool_bytes, 1 << 20);
        assert_eq!(cfg.scratch_pool_entries, 5);
        assert_eq!(cfg.device_pool_bytes, 2 << 20);
        assert_eq!(cfg.prefix_pool_bytes, 4 << 20);
        assert_eq!(cfg.max_inflight_calls, 3);
        assert_eq!(cfg.call_retries, 7);
        assert_eq!(cfg.retry_backoff_ms, 20);
        assert_eq!(cfg.kv_quant, KvQuantMode::Off);
        assert_eq!(cfg.quantize_after_windows, 3);
    }

    #[test]
    fn serve_config_scheduler_fields_roundtrip_json() {
        // regression: max_active used to be hardcoded in the executor loop,
        // scratch_pool_entries in the runtime
        let cfg = ServeConfig {
            max_active: 7,
            kv_pool_bytes: 4096,
            scratch_pool_entries: 3,
            device_pool_bytes: 0,
            prefix_pool_bytes: 0,
            max_inflight_calls: 4,
            call_retries: 0,
            retry_backoff_ms: 50,
            ..Default::default()
        };
        let back = ServeConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.max_active, 7);
        assert_eq!(back.kv_pool_bytes, 4096);
        assert_eq!(back.scratch_pool_entries, 3);
        assert_eq!(back.device_pool_bytes, 0, "0 (residency disabled) must round-trip");
        assert_eq!(back.prefix_pool_bytes, 0, "0 (prefix cache disabled) must round-trip");
        assert_eq!(back.max_inflight_calls, 4, "in-flight capacity must round-trip");
        assert_eq!(back.call_retries, 0, "0 (retries disabled) must round-trip");
        assert_eq!(back.retry_backoff_ms, 50);
    }

    #[test]
    fn serve_config_trace_fields_roundtrip() {
        let d = ServeConfig::default();
        assert_eq!(d.trace_sample_every, 1, "tracing defaults to record-everything");
        assert_eq!(d.trace_buffer_events, crate::obs::DEFAULT_CAPACITY);
        // 0 (tracing off) and a custom ring size must round-trip via JSON
        let cfg = ServeConfig {
            trace_sample_every: 0,
            trace_buffer_events: 1024,
            ..Default::default()
        };
        let back = ServeConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.trace_sample_every, 0, "0 (tracing disabled) must round-trip");
        assert_eq!(back.trace_buffer_events, 1024);
        // CLI overrides
        let args = Args::parse(
            ["--trace-sample-every", "8", "--trace-buffer-events", "2048"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        );
        let cfg = ServeConfig::from_args(&args).unwrap();
        assert_eq!(cfg.trace_sample_every, 8);
        assert_eq!(cfg.trace_buffer_events, 2048);
    }

    #[test]
    fn exp_config_lists() {
        let args = Args::parse(
            ["--budgets", "32,64", "--lengths", "128,256", "--seeds", "1,2,3"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        );
        let cfg = ExpConfig::from_args(&args);
        assert_eq!(cfg.budgets, vec![32, 64]);
        assert_eq!(cfg.lengths, vec![128, 256]);
        assert_eq!(cfg.seeds, vec![1, 2, 3]);
    }
}
