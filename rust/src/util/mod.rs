//! Support substrates hand-rolled for the offline dependency universe
//! (no serde/clap/rand/criterion/proptest — see DESIGN.md §3/§6).

pub mod args;
pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
