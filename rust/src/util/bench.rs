//! Mini benchmark harness (criterion substitute): warmup + timed iterations,
//! mean/p50/p95 reporting, ns..s auto-units. Used by `cargo bench` targets
//! (declared with `harness = false`).

use std::time::Instant;

use super::stats::Samples;

pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup_iters: 3, iters: 10 }
    }
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Self { warmup_iters, iters }
    }

    /// Time `f` and print a report line; returns mean seconds per iteration.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> f64 {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Samples::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.record(t0.elapsed().as_secs_f64());
        }
        println!(
            "bench {name:<44} mean {:>10} p50 {:>10} p95 {:>10} ({} iters)",
            fmt_time(samples.mean()),
            fmt_time(samples.p50()),
            fmt_time(samples.percentile(95.0)),
            self.iters
        );
        samples.mean()
    }

    /// Time `f` which processes `units` items per call; prints throughput.
    pub fn run_throughput<F: FnMut()>(&self, name: &str, units: u64, unit_name: &str, mut f: F) -> f64 {
        let mean_s = self.run(name, &mut f);
        let rate = units as f64 / mean_s;
        println!("      {name:<44} {rate:>12.1} {unit_name}/s");
        rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0usize;
        let b = Bench::new(1, 5);
        let mean = b.run("noop", || {
            count += 1;
        });
        assert_eq!(count, 6);
        assert!(mean >= 0.0);
    }

    #[test]
    fn throughput_positive() {
        let b = Bench::new(0, 3);
        let rate = b.run_throughput("sum", 1000, "elems", || {
            let s: u64 = (0..1000u64).sum();
            std::hint::black_box(s);
        });
        assert!(rate > 0.0);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.5e-9).ends_with("ns"));
        assert!(fmt_time(2.5e-6).ends_with("µs"));
        assert!(fmt_time(2.5e-3).ends_with("ms"));
        assert!(fmt_time(2.5).ends_with('s'));
    }
}
