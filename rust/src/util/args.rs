//! Tiny CLI argument parser (clap substitute): `--key value`, `--flag`,
//! positional args, with typed getters and auto-generated usage text.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    spec: Vec<(String, String, Option<String>)>, // (name, help, default)
}

impl Args {
    /// Parse from `std::env::args` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(argv: Vec<String>) -> Self {
        let mut a = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    a.opts.insert(name.to_string(), v);
                } else {
                    a.flags.push(name.to_string());
                }
            } else {
                a.positional.push(arg);
            }
        }
        a
    }

    /// Register an option for usage text (returns self for chaining).
    pub fn describe(mut self, name: &str, help: &str, default: Option<&str>) -> Self {
        self.spec.push((name.to_string(), help.to_string(), default.map(|s| s.to_string())));
        self
    }

    pub fn usage(&self, prog: &str) -> String {
        let mut s = format!("usage: {prog} [options]\n");
        for (name, help, default) in &self.spec {
            let d = default.as_ref().map(|d| format!(" (default: {d})")).unwrap_or_default();
            s.push_str(&format!("  --{name:<24} {help}{d}\n"));
        }
        s
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).map(|v| exit_on_bad_value(parse_value(name, v, "integer"))).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).map(|v| exit_on_bad_value(parse_value(name, v, "integer"))).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).map(|v| exit_on_bad_value(parse_value(name, v, "number"))).unwrap_or(default)
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .map(|s| exit_on_bad_value(parse_value(name, s.trim(), "comma-separated integer")))
                .collect(),
            None => default.to_vec(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Fallible core of the typed getters: parse `raw` as `T` for flag
/// `--name`, reporting the flag name and offending value on failure.
/// Kept separate from the exiting wrapper so it is unit-testable.
fn parse_value<T: std::str::FromStr>(
    name: &str,
    raw: &str,
    expected: &str,
) -> std::result::Result<T, String> {
    raw.parse()
        .map_err(|_| format!("error: invalid value '{raw}' for --{name} (expected {expected})"))
}

/// A malformed CLI value is a user error, not a bug: print the diagnostic
/// from [`parse_value`] and exit with status 2 instead of panicking with a
/// backtrace.
fn exit_on_bad_value<T>(r: std::result::Result<T, String>) -> T {
    r.unwrap_or_else(|msg| {
        eprintln!("{msg}");
        std::process::exit(2);
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn options_and_flags() {
        // note: a bare `--flag` must be followed by another `--option` or
        // end-of-args; `--flag value` is parsed as an option (documented).
        let a = mk(&["--model", "base", "--budget=128", "pos1", "--verbose"]);
        assert_eq!(a.get("model"), Some("base"));
        assert_eq!(a.usize_or("budget", 0), 128);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn defaults() {
        let a = mk(&[]);
        assert_eq!(a.str_or("model", "mini"), "mini");
        assert_eq!(a.f64_or("x", 1.5), 1.5);
        assert_eq!(a.usize_list_or("budgets", &[64, 128]), vec![64, 128]);
    }

    #[test]
    fn lists() {
        let a = mk(&["--budgets", "32,64,128"]);
        assert_eq!(a.usize_list_or("budgets", &[]), vec![32, 64, 128]);
        let b = mk(&["--models", "base, mini"]);
        assert_eq!(b.list_or("models", &[]), vec!["base", "mini"]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = mk(&["--fast", "--model", "base"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get("model"), Some("base"));
    }

    #[test]
    fn usage_text() {
        let a = mk(&[]).describe("model", "model name", Some("base"));
        assert!(a.usage("prog").contains("--model"));
    }

    #[test]
    fn usize_parser_names_flag_and_value() {
        assert_eq!(parse_value::<usize>("budget", "128", "integer").unwrap(), 128);
        let err = parse_value::<usize>("budget", "12x", "integer").unwrap_err();
        assert!(err.contains("--budget"), "missing flag name: {err}");
        assert!(err.contains("'12x'"), "missing offending value: {err}");
        assert!(err.contains("integer"), "missing expected type: {err}");
    }

    #[test]
    fn u64_parser_names_flag_and_value() {
        assert_eq!(parse_value::<u64>("seed", "7", "integer").unwrap(), 7);
        let err = parse_value::<u64>("seed", "-1", "integer").unwrap_err();
        assert!(err.contains("--seed") && err.contains("'-1'"), "bad diagnostic: {err}");
    }

    #[test]
    fn f64_parser_names_flag_and_value() {
        assert_eq!(parse_value::<f64>("rate", "0.25", "number").unwrap(), 0.25);
        let err = parse_value::<f64>("rate", "fast", "number").unwrap_err();
        assert!(err.contains("--rate") && err.contains("'fast'"), "bad diagnostic: {err}");
    }

    #[test]
    fn usize_list_parser_names_flag_and_element() {
        let a = mk(&["--budgets", "32,64"]);
        assert_eq!(a.usize_list_or("budgets", &[]), vec![32, 64]);
        let err =
            parse_value::<usize>("budgets", "sixty-four", "comma-separated integer").unwrap_err();
        assert!(err.contains("--budgets") && err.contains("'sixty-four'"), "bad diagnostic: {err}");
    }
}
