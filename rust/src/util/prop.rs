//! Mini property-testing harness (proptest substitute): deterministic
//! generator-driven checks with failure-case reporting and simple shrinking
//! for integer vectors.

use super::rng::Xoshiro256;

pub struct PropRunner {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropRunner {
    fn default() -> Self {
        Self { cases: 128, seed: 0x1acac4e }
    }
}

impl PropRunner {
    pub fn new(cases: usize) -> Self {
        Self { cases, ..Default::default() }
    }

    /// Run `prop` against `cases` generated inputs. On failure, tries to
    /// shrink (for `Vec<i64>`-like inputs the caller can shrink internally);
    /// panics with the failing seed + debug repr.
    pub fn run<T: std::fmt::Debug, G, P>(&self, mut gen: G, mut prop: P)
    where
        G: FnMut(&mut Xoshiro256) -> T,
        P: FnMut(&T) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let mut rng = Xoshiro256::new(case_seed);
            let input = gen(&mut rng);
            if let Err(msg) = prop(&input) {
                panic!(
                    "property failed (case {case}, seed {case_seed:#x}): {msg}\ninput: {input:?}"
                );
            }
        }
    }
}

/// Convenience macro: `prop_assert!(cond, "msg {}", x)` inside property fns.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        PropRunner::new(64).run(
            |rng| (rng.below(100) as i64, rng.below(100) as i64),
            |&(a, b)| {
                prop_assert!(a + b == b + a, "commutativity {a} {b}");
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        PropRunner::new(64).run(
            |rng| rng.below(1000) as i64,
            |&x| {
                prop_assert!(x < 990, "found large value {x}");
                Ok(())
            },
        );
    }

    #[test]
    fn deterministic_inputs() {
        let mut first: Vec<i64> = vec![];
        PropRunner::new(10).run(
            |rng| rng.below(1_000_000) as i64,
            |&x| {
                first.push(x);
                Ok(())
            },
        );
        let mut second: Vec<i64> = vec![];
        PropRunner::new(10).run(
            |rng| rng.below(1_000_000) as i64,
            |&x| {
                second.push(x);
                Ok(())
            },
        );
        assert_eq!(first, second);
    }
}
