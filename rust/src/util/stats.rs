//! Latency/throughput statistics helpers (criterion substitute foundation).

/// Online mean/min/max/percentile tracker over recorded samples.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    vals: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.vals.push(v);
    }

    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.vals.is_empty() {
            return 0.0;
        }
        self.vals.iter().sum::<f64>() / self.vals.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.vals.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.vals.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        if self.vals.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.vals.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (self.vals.len() - 1) as f64)
            .sqrt()
    }

    /// Percentile in [0, 100] by nearest-rank on a sorted copy.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.vals.is_empty() {
            return 0.0;
        }
        let mut sorted = self.vals.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn summary(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.3}{u} p50={:.3}{u} p95={:.3}{u} p99={:.3}{u} max={:.3}{u}",
            self.len(),
            self.mean(),
            self.p50(),
            self.p95(),
            self.p99(),
            self.max(),
            u = unit
        )
    }
}

/// Fixed-memory log-bucket histogram for latency tracking in the server
/// metrics registry: geometric bucket bounds, exact min/max/mean tracking,
/// and bucket-resolution quantiles clamped to the observed range. Memory is
/// `n_buckets + 1` counters regardless of how many samples are recorded —
/// the replacement for the unbounded [`Samples`] vectors on a long-running
/// server (quantile error is bounded by the bucket ratio, ~25% per step at
/// the default serving scheme of 64 buckets over [100 µs, 100 s]).
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    n: u64,
    min: f64,
    max: f64,
}

impl Histogram {
    /// Buckets: geometric from `lo` to `hi` (in whatever unit the caller uses).
    pub fn new(lo: f64, hi: f64, n_buckets: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n_buckets >= 2);
        let ratio = (hi / lo).powf(1.0 / (n_buckets as f64 - 1.0));
        let bounds: Vec<f64> = (0..n_buckets).map(|i| lo * ratio.powi(i as i32)).collect();
        let counts = vec![0; n_buckets + 1];
        Self { bounds, counts, sum: 0.0, n: 0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn record(&mut self, v: f64) {
        let idx = self.bounds.partition_point(|b| *b < v);
        self.counts[idx] += 1;
        self.sum += v;
        self.n += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample count as usize ([`Samples`]-compatible).
    pub fn len(&self) -> usize {
        self.n as usize
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact smallest recorded value (0 when empty — never NaN/±inf).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact largest recorded value (0 when empty — never NaN/±inf).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Approximate quantile: the bucket upper bound at rank `ceil(q·n)`,
    /// clamped to the exact observed `[min, max]` so a quantile never
    /// exceeds the largest (or undercuts the smallest) recorded value.
    /// Returns 0 when empty — never NaN.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil().max(1.0) as u64;
        let mut acc = 0;
        let mut bound = *self.bounds.last().unwrap();
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                if i < self.bounds.len() {
                    bound = self.bounds[i];
                }
                break;
            }
        }
        bound.clamp(self.min, self.max)
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Cumulative `(le, count)` pairs in Prometheus exposition order; the
    /// final entry is `(f64::INFINITY, n)` (the `+Inf` bucket).
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.counts.len());
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            let le = if i < self.bounds.len() { self.bounds[i] } else { f64::INFINITY };
            out.push((le, acc));
        }
        out
    }
}

/// Simple throughput meter.
#[derive(Clone, Debug, Default)]
pub struct Meter {
    pub count: u64,
    pub elapsed_s: f64,
}

impl Meter {
    pub fn add(&mut self, n: u64, dt_s: f64) {
        self.count += n;
        self.elapsed_s += dt_s;
    }

    pub fn rate(&self) -> f64 {
        if self.elapsed_s <= 0.0 {
            0.0
        } else {
            self.count as f64 / self.elapsed_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_basic() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.len(), 100);
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
        assert!((s.p50() - 50.0).abs() <= 1.0);
        assert!((s.p95() - 95.0).abs() <= 1.0);
    }

    #[test]
    fn percentile_edges() {
        let mut s = Samples::new();
        s.record(5.0);
        assert_eq!(s.percentile(0.0), 5.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(Samples::new().p99(), 0.0);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new(0.001, 10.0, 40);
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0);
        }
        assert_eq!(h.count(), 1000);
        let q50 = h.quantile(0.5);
        assert!(q50 > 0.3 && q50 < 0.8, "q50={q50}");
        assert!((h.mean() - 0.5005).abs() < 1e-6);
    }

    #[test]
    fn histogram_overflow_bucket() {
        let mut h = Histogram::new(1.0, 10.0, 5);
        h.record(100.0); // beyond hi
        h.record(0.1); // below lo
        assert_eq!(h.count(), 2);
        // min/max stay exact even outside the bucket range, and quantiles
        // clamp to the observed values instead of reporting a bucket bound
        assert_eq!(h.min(), 0.1);
        assert_eq!(h.max(), 100.0);
        assert_eq!(h.quantile(1.0), 100.0);
        assert!(h.quantile(0.25) >= 0.1);
    }

    #[test]
    fn histogram_empty_is_zero_not_nan() {
        let h = Histogram::new(1e-4, 100.0, 64);
        for v in [h.p50(), h.p95(), h.p99(), h.min(), h.max(), h.mean(), h.sum()] {
            assert_eq!(v, 0.0, "empty histogram must export 0, got {v}");
            assert!(!v.is_nan());
        }
        assert_eq!(h.len(), 0);
        assert!(h.is_empty());
    }

    #[test]
    fn histogram_single_sample_quantiles_are_exact() {
        let mut h = Histogram::new(1e-4, 100.0, 64);
        h.record(0.01);
        // the [min, max] clamp collapses every quantile onto the one sample
        assert_eq!(h.p50(), 0.01);
        assert_eq!(h.p95(), 0.01);
        assert_eq!(h.p99(), 0.01);
        assert_eq!(h.max(), 0.01);
    }

    #[test]
    fn histogram_cumulative_buckets_for_exposition() {
        let mut h = Histogram::new(1.0, 16.0, 5);
        for v in [0.5, 2.0, 3.0, 100.0] {
            h.record(v);
        }
        let buckets = h.cumulative_buckets();
        assert_eq!(buckets.len(), 6, "n_buckets + the +Inf overflow bucket");
        let (last_le, last_n) = *buckets.last().unwrap();
        assert!(last_le.is_infinite());
        assert_eq!(last_n, 4, "+Inf bucket counts everything");
        // cumulative counts are monotone non-decreasing
        assert!(buckets.windows(2).all(|w| w[0].1 <= w[1].1));
        // and the below-lo sample landed in the first bucket
        assert_eq!(buckets[0].1, 1);
    }

    #[test]
    fn meter() {
        let mut m = Meter::default();
        m.add(100, 2.0);
        m.add(100, 2.0);
        assert!((m.rate() - 50.0).abs() < 1e-9);
    }
}
