//! Deterministic PRNGs (no `rand` crate in the offline dependency universe).
//!
//! [`SplitMix64`] mirrors `python/compile/corpus.py::Rng` bit-for-bit — the
//! corpus generators on both sides must produce identical streams (asserted
//! against `artifacts/corpus_golden.json`). [`Xoshiro256`] is the
//! general-purpose engine for workloads, property tests and samplers.

/// SplitMix64 — the corpus-parity PRNG.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` via modulo — matches the python side exactly
    /// (slight bias is irrelevant here; parity is what matters).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }
}

/// Xoshiro256++ — general-purpose engine.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire-style rejection-free reduction (bias < 2^-64 * n).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with the given mean (inter-arrival sampling).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = self.f64().max(1e-12);
        -mean * u.ln()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_golden() {
        // Mirrors python/tests/test_corpus.py::test_rng_golden.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 16294208416658607535);
        assert_eq!(r.next_u64(), 7960286522194355700);
        assert_eq!(r.next_u64(), 487617019471545679);
    }

    #[test]
    fn splitmix_below_matches_modulo() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            let n = 1 + (b.next_u64() % 1000);
            let mut c = a.clone();
            assert_eq!(a.below(n), c.next_u64() % n);
        }
    }

    #[test]
    fn xoshiro_statistics() {
        let mut r = Xoshiro256::new(7);
        let n = 10_000;
        let mean = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
        let mut counts = [0usize; 10];
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!(c > n / 10 / 2 && c < n / 10 * 2);
        }
    }

    #[test]
    fn xoshiro_deterministic() {
        let mut a = Xoshiro256::new(9);
        let mut b = Xoshiro256::new(9);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
