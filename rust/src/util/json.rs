//! Minimal JSON parser/serializer (no `serde` in the offline dependency
//! universe). Used for the artifact manifest, config files, experiment
//! outputs and the server wire protocol.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ----- accessors ------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required fields (manifest is trusted input).
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required json key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    pub fn str_of(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|j| j.as_str())
    }

    pub fn f64_of(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|j| j.as_f64())
    }

    pub fn usize_of(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|j| j.as_usize())
    }

    pub fn bool_of(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(|j| j.as_bool())
    }

    // ----- parsing ----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
    }

    // ----- serialization (via `Display`; `to_string()` comes from the
    // blanket `ToString` impl) -----------------------------------------------
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("utf8"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u"))?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes at once
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.req("a").as_arr().unwrap()[2].str_of("b"), Some("x"));
        assert_eq!(j.req("c"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s\"q"],"num":-7,"obj":{"k":1}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn big_int_array() {
        let v: Vec<Json> = (0..2000).map(|i| Json::from(i as i64)).collect();
        let s = Json::Arr(v).to_string();
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 2000);
        assert_eq!(j.as_arr().unwrap()[1999].as_i64(), Some(1999));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
