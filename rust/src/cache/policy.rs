//! The cache-policy abstraction: given a layer's occupancy state, decide
//! which slots survive. Policies are *pure position/metadata functions* —
//! except the H2O family, which additionally consumes per-slot attention
//! mass and therefore forces the runtime onto the scored (slow) program
//! variant. That architectural split is exactly the paper's Fig. 7 axis.

use crate::runtime::KvCache;

/// How a policy consumes attention mass (drives program selection and
/// engine-side mass bookkeeping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MassUse {
    /// Attention-free (LaCache, StreamingLLM, full, random): fast path.
    None,
    /// Accumulated mass across the whole stream (H2O, PyramidInfer).
    Accumulated,
    /// Only the most recent window's mass (TOVA, SnapKV).
    LastWindow,
}

pub trait CachePolicy: Send {
    fn name(&self) -> String;

    /// Per-layer slot budget (compaction trigger threshold).
    fn budget(&self) -> usize;

    fn mass_use(&self) -> MassUse {
        MassUse::None
    }

    fn needs_scores(&self) -> bool {
        self.mass_use() != MassUse::None
    }

    /// Attention sinks this policy pins at the front (consumed by the
    /// eviction fallback so degenerate configs honor the policy's own sink
    /// count instead of a hardcoded default).
    fn n_sink(&self) -> usize {
        0
    }

    /// Slots (sorted, strictly increasing) to keep for `layer`. Called when
    /// `cache.lens[layer] > budget()`. Must return fewer slots than
    /// currently resident (progress guarantee).
    fn keep_slots(&self, layer: usize, cache: &KvCache) -> Vec<usize>;

    /// Apply the policy to every over-budget layer. A single ladder pass may
    /// keep more than the budget (its keep-ratio is S/L of the middle); the
    /// pass is re-applied to the already-compacted slots until occupancy is
    /// within budget — this IS the paper's iterative compaction (§3.3).
    fn evict(&self, cache: &mut KvCache) -> anyhow::Result<usize> {
        let mut evicted = 0;
        for layer in 0..cache.l {
            let mut guard = 0;
            while cache.lens[layer] > self.budget() {
                let mut keep = self.keep_slots(layer, cache);
                let n = cache.lens[layer];
                if keep.len() >= n || guard >= 8 {
                    // progress guarantee: degenerate configs fall back to
                    // a recency truncation at budget
                    keep = fallback_recency(n, self.budget(), self.n_sink());
                }
                evicted += n - keep.len();
                cache.retain_slots(layer, &keep)?;
                guard += 1;
            }
        }
        Ok(evicted)
    }
}

/// Sink + recency keep-set (shared fallback and StreamingLLM core).
pub fn fallback_recency(n: usize, budget: usize, n_sink: usize) -> Vec<usize> {
    let sink = n_sink.min(n).min(budget);
    let recent = budget.saturating_sub(sink).min(n - sink);
    let mut keep: Vec<usize> = (0..sink).collect();
    keep.extend(n - recent..n);
    keep
}

/// Helper: top-`k` slot indices by score, returned sorted ascending.
pub fn top_k_sorted(scores: &[f64], candidates: &[usize], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = candidates.to_vec();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal));
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::KvArena;

    /// Degenerate policy that never makes progress on its own — forces the
    /// `evict` fallback path.
    struct AllKeep {
        budget: usize,
        sinks: usize,
    }

    impl CachePolicy for AllKeep {
        fn name(&self) -> String {
            "allkeep".into()
        }

        fn budget(&self) -> usize {
            self.budget
        }

        fn n_sink(&self) -> usize {
            self.sinks
        }

        fn keep_slots(&self, layer: usize, cache: &KvCache) -> Vec<usize> {
            (0..cache.lens[layer]).collect()
        }
    }

    fn cache_with(n: usize) -> KvCache {
        let mut kv = KvCache::with_arena(KvArena::new(), 1, 1, 64, 2);
        let w = vec![0.0f32; n * 2];
        kv.append_layer(0, &w, &w, n, n, 0).unwrap();
        kv
    }

    #[test]
    fn evict_fallback_honors_policy_sink_count() {
        // regression: the fallback used a hardcoded 4 sinks, pinning slots
        // the policy never asked to keep (e.g. n_sink = 0 ladder configs)
        let mut kv = cache_with(20);
        let no_sinks = AllKeep { budget: 8, sinks: 0 };
        no_sinks.evict(&mut kv).unwrap();
        assert_eq!(kv.lens[0], 8);
        assert_eq!(kv.positions[0], (12..20).collect::<Vec<u64>>());

        let mut kv = cache_with(20);
        let two_sinks = AllKeep { budget: 8, sinks: 2 };
        two_sinks.evict(&mut kv).unwrap();
        assert_eq!(kv.lens[0], 8);
        assert_eq!(&kv.positions[0][..2], &[0, 1]);
        assert_eq!(&kv.positions[0][2..], &(14..20).collect::<Vec<u64>>()[..]);
    }

    #[test]
    fn fallback_recency_shapes() {
        assert_eq!(fallback_recency(10, 6, 4), vec![0, 1, 2, 3, 8, 9]);
        assert_eq!(fallback_recency(3, 6, 4), vec![0, 1, 2]);
        assert_eq!(fallback_recency(10, 2, 4), vec![0, 1]);
    }

    #[test]
    fn top_k_sorted_orders_by_score_then_position() {
        let scores = vec![0.1, 5.0, 0.2, 3.0, 9.9];
        let cands = vec![0, 1, 2, 3, 4];
        assert_eq!(top_k_sorted(&scores, &cands, 2), vec![1, 4]);
        assert_eq!(top_k_sorted(&scores, &cands, 10), vec![0, 1, 2, 3, 4]);
    }
}
