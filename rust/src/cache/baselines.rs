//! Baseline eviction policies the paper compares against (Tab. 1/3/4/5,
//! Fig. 7): StreamingLLM, full cache, H2O, TOVA, SnapKV, PyramidInfer.
//!
//! The H2O family consumes per-slot attention mass and therefore routes the
//! engine onto the scored (attention-map-emitting) programs — the slow path
//! that costs them throughput in Fig. 7. LaCache and StreamingLLM never need
//! it.

use super::policy::{fallback_recency, top_k_sorted, CachePolicy, MassUse};
use crate::runtime::KvCache;

/// StreamingLLM (Xiao et al., 2023): attention sinks + recency window,
/// identical in every layer.
#[derive(Clone, Debug)]
pub struct StreamingPolicy {
    pub budget: usize,
    pub n_sink: usize,
}

impl StreamingPolicy {
    pub fn new(budget: usize) -> Self {
        Self { budget, n_sink: 4 }
    }
}

impl CachePolicy for StreamingPolicy {
    fn name(&self) -> String {
        format!("streaming_llm(b={},sink={})", self.budget, self.n_sink)
    }

    fn budget(&self) -> usize {
        self.budget
    }

    fn n_sink(&self) -> usize {
        self.n_sink
    }

    fn keep_slots(&self, layer: usize, cache: &KvCache) -> Vec<usize> {
        fallback_recency(cache.lens[layer], self.budget, self.n_sink)
    }
}

/// Full KV cache: never evicts; the engine's memory accountant supplies the
/// OOM axis (Fig. 5) and positions grow past t_train (PPL explosion, Tab. 1).
#[derive(Clone, Debug, Default)]
pub struct FullPolicy;

impl CachePolicy for FullPolicy {
    fn name(&self) -> String {
        "full".into()
    }

    fn budget(&self) -> usize {
        usize::MAX
    }

    fn keep_slots(&self, layer: usize, cache: &KvCache) -> Vec<usize> {
        (0..cache.lens[layer]).collect()
    }
}

/// H2O (Zhang et al., 2024): heavy hitters by *accumulated* attention mass +
/// a recency half, per layer.
#[derive(Clone, Debug)]
pub struct H2oPolicy {
    pub budget: usize,
    pub n_sink: usize,
    /// Fraction of the budget reserved for the recency window (paper: 1/2).
    pub recent_frac: f64,
}

impl H2oPolicy {
    pub fn new(budget: usize) -> Self {
        Self { budget, n_sink: 4, recent_frac: 0.5 }
    }
}

impl CachePolicy for H2oPolicy {
    fn name(&self) -> String {
        format!("h2o(b={})", self.budget)
    }

    fn budget(&self) -> usize {
        self.budget
    }

    fn mass_use(&self) -> MassUse {
        MassUse::Accumulated
    }

    fn n_sink(&self) -> usize {
        self.n_sink
    }

    fn keep_slots(&self, layer: usize, cache: &KvCache) -> Vec<usize> {
        let n = cache.lens[layer];
        let sink = self.n_sink.min(n).min(self.budget);
        let recent = ((self.budget as f64 * self.recent_frac) as usize).min(n - sink);
        let heavy_budget = self.budget.saturating_sub(sink + recent);
        let middle: Vec<usize> = (sink..n - recent).collect();
        let mut keep: Vec<usize> = (0..sink).collect();
        keep.extend(top_k_sorted(&cache.mass[layer], &middle, heavy_budget));
        keep.extend(n - recent..n);
        keep
    }
}

/// TOVA (Oren et al., 2024): at each eviction point drop the tokens with the
/// lowest attention from the *most recent* queries (fresh window mass).
#[derive(Clone, Debug)]
pub struct TovaPolicy {
    pub budget: usize,
    pub n_sink: usize,
}

impl TovaPolicy {
    pub fn new(budget: usize) -> Self {
        Self { budget, n_sink: 4 }
    }
}

impl CachePolicy for TovaPolicy {
    fn name(&self) -> String {
        format!("tova(b={})", self.budget)
    }

    fn budget(&self) -> usize {
        self.budget
    }

    fn mass_use(&self) -> MassUse {
        MassUse::LastWindow
    }

    fn n_sink(&self) -> usize {
        self.n_sink
    }

    fn keep_slots(&self, layer: usize, cache: &KvCache) -> Vec<usize> {
        let n = cache.lens[layer];
        let sink = self.n_sink.min(n).min(self.budget);
        let k = self.budget - sink;
        let cands: Vec<usize> = (sink..n).collect();
        let mut keep: Vec<usize> = (0..sink).collect();
        keep.extend(top_k_sorted(&cache.mass[layer], &cands, k));
        keep
    }
}

/// SnapKV (Li et al., 2024): selection by observation-window attention with
/// local pooling (cluster-preserving smoothing) + recency.
#[derive(Clone, Debug)]
pub struct SnapKvPolicy {
    pub budget: usize,
    pub n_sink: usize,
    pub pool_radius: usize,
    pub recent_frac: f64,
}

impl SnapKvPolicy {
    pub fn new(budget: usize) -> Self {
        Self { budget, n_sink: 4, pool_radius: 2, recent_frac: 0.25 }
    }
}

impl CachePolicy for SnapKvPolicy {
    fn name(&self) -> String {
        format!("snapkv(b={})", self.budget)
    }

    fn budget(&self) -> usize {
        self.budget
    }

    fn mass_use(&self) -> MassUse {
        MassUse::LastWindow
    }

    fn n_sink(&self) -> usize {
        self.n_sink
    }

    fn keep_slots(&self, layer: usize, cache: &KvCache) -> Vec<usize> {
        let n = cache.lens[layer];
        let sink = self.n_sink.min(n).min(self.budget);
        let recent = ((self.budget as f64 * self.recent_frac) as usize).min(n - sink);
        let k = self.budget.saturating_sub(sink + recent);
        // pooled mass: average over a [-r, +r] neighborhood
        let mass = &cache.mass[layer];
        let r = self.pool_radius;
        let pooled: Vec<f64> = (0..n)
            .map(|i| {
                let lo = i.saturating_sub(r);
                let hi = (i + r + 1).min(n);
                mass[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
            })
            .collect();
        let cands: Vec<usize> = (sink..n - recent).collect();
        let mut keep: Vec<usize> = (0..sink).collect();
        keep.extend(top_k_sorted(&pooled, &cands, k));
        keep.extend(n - recent..n);
        keep
    }
}

/// PyramidInfer (Yang et al., 2024): per-layer *decreasing* budgets (deep
/// layers keep less), selection by accumulated mass + recency within each
/// layer's own budget. Mean budget across layers equals `budget`.
#[derive(Clone, Debug)]
pub struct PyramidPolicy {
    pub budget: usize,
    pub n_sink: usize,
    pub n_layers: usize,
    /// Budget ratio between the shallowest and deepest layer (e.g. 3.0).
    pub taper: f64,
}

impl PyramidPolicy {
    pub fn new(budget: usize, n_layers: usize) -> Self {
        Self { budget, n_sink: 4, n_layers, taper: 3.0 }
    }

    /// Per-layer budget, linearly tapered, mean == self.budget.
    pub fn layer_budget(&self, layer: usize) -> usize {
        let l = self.n_layers.max(2) as f64;
        let t = self.taper;
        // weights w_l linear from t down to 1, normalized to mean 1
        let w = t - (t - 1.0) * (layer as f64) / (l - 1.0);
        let mean_w = (t + 1.0) / 2.0;
        ((self.budget as f64) * w / mean_w).round().max(8.0) as usize
    }
}

impl CachePolicy for PyramidPolicy {
    fn name(&self) -> String {
        format!("pyramid_infer(b={},taper={})", self.budget, self.taper)
    }

    fn budget(&self) -> usize {
        // capacity planning must account for the *widest* layer
        self.layer_budget(0)
    }

    fn mass_use(&self) -> MassUse {
        MassUse::Accumulated
    }

    fn n_sink(&self) -> usize {
        self.n_sink
    }

    fn keep_slots(&self, layer: usize, cache: &KvCache) -> Vec<usize> {
        let b = self.layer_budget(layer);
        let n = cache.lens[layer];
        if n <= b {
            return (0..n).collect();
        }
        let sink = self.n_sink.min(n).min(b);
        let recent = (b / 2).min(n - sink);
        let k = b.saturating_sub(sink + recent);
        let cands: Vec<usize> = (sink..n - recent).collect();
        let mut keep: Vec<usize> = (0..sink).collect();
        keep.extend(top_k_sorted(&cache.mass[layer], &cands, k));
        keep.extend(n - recent..n);
        keep
    }

    fn evict(&self, cache: &mut KvCache) -> anyhow::Result<usize> {
        // trigger on the *per-layer* budgets, not the mean
        let mut evicted = 0;
        for layer in 0..cache.l {
            if cache.lens[layer] > self.layer_budget(layer) {
                let keep = self.keep_slots(layer, cache);
                evicted += cache.lens[layer] - keep.len();
                cache.retain_slots(layer, &keep)?;
            }
        }
        Ok(evicted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache_with_mass(l: usize, n: usize) -> KvCache {
        let mut kv = KvCache::new(l, 1, 256, 2);
        for layer in 0..l {
            let wk = vec![0.0f32; n * 2];
            kv.append_layer(layer, &wk, &wk, n, n, 0).unwrap();
            // mass: slot i has mass i%7 (so "heavy hitters" are i%7==6)
            let mass: Vec<f32> = (0..n).map(|i| (i % 7) as f32).collect();
            kv.add_mass(layer, &mass);
        }
        kv
    }

    #[test]
    fn streaming_is_layer_uniform() {
        let p = StreamingPolicy::new(32);
        let kv = cache_with_mass(4, 100);
        let k0 = p.keep_slots(0, &kv);
        for l in 1..4 {
            assert_eq!(k0, p.keep_slots(l, &kv));
        }
        assert_eq!(k0.len(), 32);
        assert_eq!(&k0[..4], &[0, 1, 2, 3]);
        assert_eq!(*k0.last().unwrap(), 99);
    }

    #[test]
    fn full_never_evicts() {
        let p = FullPolicy;
        let mut kv = cache_with_mass(2, 200);
        assert_eq!(p.evict(&mut kv).unwrap(), 0);
        assert_eq!(kv.lens, vec![200, 200]);
    }

    #[test]
    fn h2o_keeps_heavy_hitters() {
        let p = H2oPolicy::new(40);
        let kv = cache_with_mass(2, 120);
        let keep = p.keep_slots(0, &kv);
        assert_eq!(keep.len(), 40);
        // middle keepers must be heavy (mass 6 = i%7==6)
        let recent_lo = 120 - 20;
        let middle: Vec<usize> =
            keep.iter().copied().filter(|&s| s >= 4 && s < recent_lo).collect();
        assert!(!middle.is_empty());
        // top-k by accumulated mass: only the heaviest two tiers survive
        assert!(middle.iter().all(|&s| s % 7 >= 5), "non-heavy slot kept: {middle:?}");
        assert!(middle.iter().filter(|&&s| s % 7 == 6).count() >= 12);
        assert!(p.needs_scores());
    }

    #[test]
    fn tova_budget_respected() {
        let p = TovaPolicy::new(24);
        let mut kv = cache_with_mass(2, 90);
        p.evict(&mut kv).unwrap();
        assert!(kv.lens.iter().all(|&n| n == 24));
        kv.check_invariants().unwrap();
        assert_eq!(p.mass_use(), MassUse::LastWindow);
    }

    #[test]
    fn snapkv_pooling_prefers_clusters() {
        let p = SnapKvPolicy::new(24);
        let mut kv = KvCache::new(1, 1, 256, 2);
        let n = 100;
        let wk = vec![0.0f32; n * 2];
        kv.append_layer(0, &wk, &wk, n, n, 0).unwrap();
        // one tight cluster of mass at 40..45, one isolated spike at 70
        let mut mass = vec![0.0f32; n];
        for i in 40..45 {
            mass[i] = 5.0;
        }
        mass[70] = 6.0;
        kv.add_mass(0, &mass);
        let keep = p.keep_slots(0, &kv);
        let cluster_kept = (38..47).filter(|s| keep.contains(s)).count();
        assert!(cluster_kept >= 5, "cluster not preserved: {keep:?}");
    }

    #[test]
    fn pyramid_budgets_decrease_and_average() {
        let p = PyramidPolicy::new(64, 8);
        let budgets: Vec<usize> = (0..8).map(|l| p.layer_budget(l)).collect();
        assert!(budgets.windows(2).all(|w| w[0] >= w[1]), "{budgets:?}");
        let mean = budgets.iter().sum::<usize>() as f64 / 8.0;
        assert!((mean - 64.0).abs() < 4.0, "mean {mean} budgets {budgets:?}");
    }

    #[test]
    fn pyramid_evicts_per_layer_budget() {
        let p = PyramidPolicy::new(32, 4);
        let mut kv = cache_with_mass(4, 100);
        p.evict(&mut kv).unwrap();
        for l in 0..4 {
            assert!(kv.lens[l] <= p.layer_budget(l));
        }
        assert!(kv.lens[0] > kv.lens[3], "pyramid shape missing: {:?}", kv.lens);
    }
}
