//! KV-cache eviction policies: LaCache (the paper's contribution) and every
//! baseline in its evaluation, behind one [`CachePolicy`] trait consumed by
//! the engine and server.

pub mod baselines;
pub mod ladder;
pub mod policy;

pub use baselines::{FullPolicy, H2oPolicy, PyramidPolicy, SnapKvPolicy, StreamingPolicy, TovaPolicy};
pub use ladder::{LadderPolicy, RandomPatternPolicy};
pub use policy::{CachePolicy, MassUse};

use anyhow::{bail, Context, Result};

/// Build a policy from a CLI-style spec string:
/// `"lacache:budget=128,span=2,overlap=1,recent=16,sink=4"`,
/// `"streaming:budget=128"`, `"full"`, `"h2o:budget=64"`, ...
pub fn make_policy(spec: &str, n_layers: usize) -> Result<Box<dyn CachePolicy>> {
    let (name, rest) = spec.split_once(':').unwrap_or((spec, ""));
    let mut kv = std::collections::BTreeMap::new();
    for part in rest.split(',').filter(|p| !p.is_empty()) {
        let (k, v) = part.split_once('=').with_context(|| format!("bad policy param `{part}`"))?;
        kv.insert(k.trim().to_string(), v.trim().to_string());
    }
    let get = |k: &str| -> Option<usize> { kv.get(k).map(|v| v.parse().expect("bad number")) };
    let budget = get("budget").unwrap_or(128);
    Ok(match name {
        "lacache" | "ladder" => {
            let mut p = ladder::LadderPolicy::lm_default(budget, n_layers);
            if let Some(s) = get("span") {
                p.span = s;
            }
            if let Some(o) = get("overlap") {
                p.overlap = o;
            }
            if let Some(r) = get("recent") {
                p.n_recent = r;
            }
            if let Some(s) = get("sink") {
                p.n_sink = s;
            }
            Box::new(p)
        }
        "lacache_und" => {
            let ratio = kv
                .get("ratio")
                .map(|v| v.parse::<f64>().expect("bad ratio"))
                .unwrap_or(0.5);
            let mut p = ladder::LadderPolicy::understanding_default(budget, n_layers, ratio);
            if let Some(o) = get("overlap") {
                p.overlap = o;
            }
            if let Some(r) = get("recent") {
                p.n_recent = r;
            }
            Box::new(p)
        }
        "streaming" | "streaming_llm" => {
            let mut p = baselines::StreamingPolicy::new(budget);
            if let Some(s) = get("sink") {
                p.n_sink = s;
            }
            Box::new(p)
        }
        "full" => Box::new(baselines::FullPolicy),
        "h2o" => Box::new(baselines::H2oPolicy::new(budget)),
        "tova" => Box::new(baselines::TovaPolicy::new(budget)),
        "snapkv" => Box::new(baselines::SnapKvPolicy::new(budget)),
        "pyramid" | "pyramid_infer" => Box::new(baselines::PyramidPolicy::new(budget, n_layers)),
        "random" => {
            let frac = kv
                .get("frac")
                .map(|v| v.parse::<f64>().expect("bad frac"))
                .unwrap_or(0.25);
            let seed = get("seed").unwrap_or(1) as u64;
            let mut p = RandomPatternPolicy {
                budget,
                n_sink: 4,
                n_recent: (budget / 4).max(8),
                keep_frac: frac,
                seed,
            };
            if let Some(r) = get("recent") {
                p.n_recent = r;
            }
            Box::new(p)
        }
        other => bail!("unknown policy `{other}`"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_policies() {
        for spec in [
            "lacache:budget=64,span=2,overlap=4",
            "streaming:budget=64",
            "full",
            "h2o:budget=32",
            "tova:budget=32",
            "snapkv:budget=32",
            "pyramid:budget=32",
            "random:budget=64,frac=0.3,seed=9",
            "lacache_und:budget=64,ratio=0.25",
        ] {
            let p = make_policy(spec, 8).unwrap();
            assert!(!p.name().is_empty());
        }
        assert!(make_policy("bogus", 8).is_err());
    }

    #[test]
    fn parsed_params_take_effect() {
        let p = make_policy("lacache:budget=99,span=3,overlap=7,recent=11,sink=2", 8).unwrap();
        assert_eq!(p.budget(), 99);
        assert!(p.name().contains("S=3"));
        assert!(p.name().contains("O=7"));
        assert!(!p.needs_scores());
        let h = make_policy("h2o:budget=10", 8).unwrap();
        assert!(h.needs_scores());
    }
}
