//! **LaCache**: the ladder-shaped retention pattern + iterative compaction
//! (paper §3.2/§3.3).
//!
//! Geometry (integer formulation of Fig. 2): within a layer's resident slots,
//! `[sinks | middle | recent]`. The middle region is tiled with period
//! `P = ceil(L·O/S)` tokens; layer `ℓ` keeps the contiguous window of width
//! `O` (the paper's *overlap/coverage* hyper-parameter) starting at
//! `(ℓ·O)/S` within each period (the paper's *span* `S` = number of
//! consecutive layers that retain the same token, since the window start
//! advances by `O/S` per layer). The phase anchors the ladder's end at the
//! newest middle slot, so deeper layers hold newer tokens — the stepwise
//! ladder of Fig. 1(c)/Fig. 2.
//!
//! **Iterative compaction** (§3.3) falls out operationally: `keep_slots` is
//! invoked on the *already-compacted* slot sequence every time occupancy
//! exceeds the budget, so older content is geometrically re-thinned while
//! fresh tokens arrive at full resolution — exactly Fig. 4.

use super::policy::{fallback_recency, CachePolicy};
use crate::runtime::KvCache;

#[derive(Clone, Debug)]
pub struct LadderPolicy {
    /// Per-layer slot budget (compaction trigger).
    pub budget: usize,
    /// Attention sinks always kept (StreamingLLM heritage; default 4).
    pub n_sink: usize,
    /// Newest slots kept in all layers (0 = pure ladder).
    pub n_recent: usize,
    /// Span S: #consecutive layers retaining the same token.
    pub span: usize,
    /// Overlap O: per-layer kept window width (tokens per period).
    pub overlap: usize,
}

impl LadderPolicy {
    /// Paper defaults for language modeling (§4.4): S = L/4, O = S/2,
    /// a small recency tail, 4 sinks.
    pub fn lm_default(budget: usize, n_layers: usize) -> Self {
        let span = (n_layers / 4).max(1);
        Self {
            budget,
            n_sink: 4,
            n_recent: (budget / 4).max(8),
            span,
            overlap: (span / 2).max(1),
        }
    }

    /// Paper defaults for long-context understanding (§4.4):
    /// S ≈ L · budget_ratio, O task-dependent (default S/4).
    pub fn understanding_default(budget: usize, n_layers: usize, budget_ratio: f64) -> Self {
        let span = ((n_layers as f64 * budget_ratio).round() as usize).clamp(1, n_layers);
        Self {
            budget,
            n_sink: 4,
            n_recent: (budget / 4).max(8),
            span,
            overlap: (span / 4).max(1),
        }
    }

    /// Is middle-offset `m` (0 = oldest middle slot) covered by `layer`?
    #[inline]
    pub fn covered(&self, layer: usize, m: usize, middle_len: usize, n_layers: usize) -> bool {
        let o = self.overlap.max(1);
        let s = self.span.clamp(1, n_layers);
        let p = (n_layers * o).div_ceil(s).max(1);
        // anchor the ladder's end at the newest middle slot
        let phase = (p - (middle_len % p)) % p;
        let pos = (m + phase) % p;
        let start = (layer * o / s) % p;
        let end = start + o;
        if end <= p {
            pos >= start && pos < end
        } else {
            pos >= start || pos < end - p
        }
    }
}

impl CachePolicy for LadderPolicy {
    fn name(&self) -> String {
        format!(
            "lacache(b={},S={},O={},sink={},recent={})",
            self.budget, self.span, self.overlap, self.n_sink, self.n_recent
        )
    }

    fn budget(&self) -> usize {
        self.budget
    }

    fn n_sink(&self) -> usize {
        self.n_sink
    }

    fn keep_slots(&self, layer: usize, cache: &KvCache) -> Vec<usize> {
        let n = cache.lens[layer];
        let n_layers = cache.l;
        let sink = self.n_sink.min(n).min(self.budget);
        let recent = self.n_recent.min(n - sink);
        let middle_lo = sink;
        let middle_hi = n - recent;
        let middle_len = middle_hi - middle_lo;

        let mut keep: Vec<usize> = (0..sink).collect();
        for m in 0..middle_len {
            // bubble guard (paper footnote 1): rung boundaries at the very
            // ends of the ladder are always preserved
            let boundary = m == 0 || m + 1 == middle_len;
            if boundary || self.covered(layer, m, middle_len, n_layers) {
                keep.push(middle_lo + m);
            }
        }
        keep.extend(middle_hi..n);
        if keep.len() >= n && n > self.budget {
            return fallback_recency(n, self.budget, self.n_sink);
        }
        keep
    }
}

/// Random retention patterns with the *same* per-layer kept-count as a
/// reference ladder — the Fig. 3 pattern cloud. Each layer keeps sinks +
/// recent + a seeded random middle subset.
#[derive(Clone, Debug)]
pub struct RandomPatternPolicy {
    pub budget: usize,
    pub n_sink: usize,
    pub n_recent: usize,
    /// Fraction of the middle region each layer keeps.
    pub keep_frac: f64,
    pub seed: u64,
}

impl CachePolicy for RandomPatternPolicy {
    fn name(&self) -> String {
        format!("random(b={},frac={:.3},seed={})", self.budget, self.keep_frac, self.seed)
    }

    fn budget(&self) -> usize {
        self.budget
    }

    fn n_sink(&self) -> usize {
        self.n_sink
    }

    fn keep_slots(&self, layer: usize, cache: &KvCache) -> Vec<usize> {
        let n = cache.lens[layer];
        let sink = self.n_sink.min(n).min(self.budget);
        let recent = self.n_recent.min(n - sink);
        let middle_len = n - sink - recent;
        let target = ((middle_len as f64) * self.keep_frac).round() as usize;
        // seeded per (seed, layer) but *stable across compactions* only in
        // distribution — mirrors how the paper samples arbitrary patterns
        let mut rng = crate::util::rng::Xoshiro256::new(
            self.seed ^ (layer as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let mut middle: Vec<usize> = (0..middle_len).collect();
        rng.shuffle(&mut middle);
        middle.truncate(target);
        middle.sort_unstable();
        let mut keep: Vec<usize> = (0..sink).collect();
        keep.extend(middle.into_iter().map(|m| m + sink));
        keep.extend(n - recent..n);
        if keep.len() >= n && n > self.budget {
            return fallback_recency(n, self.budget, self.n_sink);
        }
        keep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::KvCache;
    use crate::util::prop::PropRunner;
    use crate::util::rng::Xoshiro256;

    fn cache_with(l: usize, n: usize) -> KvCache {
        let mut kv = KvCache::with_arena(crate::runtime::KvArena::new(), l, 1, 256, 2);
        for layer in 0..l {
            let wk = vec![0.0f32; n * 2];
            kv.append_layer(layer, &wk, &wk, n, n, 0).unwrap();
        }
        kv
    }

    #[test]
    fn keeps_sinks_and_recent() {
        let p = LadderPolicy { budget: 64, n_sink: 4, n_recent: 16, span: 2, overlap: 4 };
        let kv = cache_with(8, 128);
        for layer in 0..8 {
            let keep = p.keep_slots(layer, &kv);
            assert!(keep.windows(2).all(|w| w[0] < w[1]));
            for s in 0..4 {
                assert!(keep.contains(&s), "sink {s} evicted in layer {layer}");
            }
            for s in 112..128 {
                assert!(keep.contains(&s), "recent {s} evicted in layer {layer}");
            }
            assert!(keep.len() < 128);
        }
    }

    #[test]
    fn equal_coverage_across_layers() {
        // Rationale 1 (§3.2): per-layer coverage of the middle is balanced.
        let p = LadderPolicy { budget: 64, n_sink: 4, n_recent: 8, span: 2, overlap: 8 };
        let kv = cache_with(8, 200);
        let counts: Vec<usize> = (0..8).map(|l| p.keep_slots(l, &kv).len()).collect();
        let min = *counts.iter().min().unwrap();
        let max = *counts.iter().max().unwrap();
        // tolerance: one shift (O/S) per period boundary
        assert!(max - min <= 2 * (p.overlap / p.span).max(1), "unbalanced coverage: {counts:?}");
    }

    #[test]
    fn union_coverage_spans_middle() {
        // The union over layers covers every middle slot (no dead zones).
        let p = LadderPolicy { budget: 64, n_sink: 4, n_recent: 8, span: 2, overlap: 8 };
        let kv = cache_with(8, 200);
        let mut covered = vec![false; 200];
        for l in 0..8 {
            for s in p.keep_slots(l, &kv) {
                covered[s] = true;
            }
        }
        let holes = covered.iter().filter(|&&c| !c).count();
        assert_eq!(holes, 0, "ladder left {holes} uncovered slots");
    }

    #[test]
    fn span_property_tokens_kept_in_s_consecutive_layers() {
        // The defining ladder property: a middle token's retaining layers
        // form ~S consecutive layers (mod wraparound).
        let n_layers = 8;
        let p = LadderPolicy { budget: 64, n_sink: 0, n_recent: 0, span: 2, overlap: 8 };
        let middle_len = 64; // one full period = L*O/S = 32 -> two periods
        for m in 0..middle_len {
            let keepers: Vec<usize> = (0..n_layers)
                .filter(|&l| p.covered(l, m, middle_len, n_layers))
                .collect();
            assert!(
                (1..=p.span + 1).contains(&keepers.len()),
                "token {m} kept in {keepers:?} (span {})",
                p.span
            );
        }
    }

    #[test]
    fn deeper_layers_hold_newer_tokens() {
        // The ladder slope (Fig. 2): the mean middle-offset retained grows
        // with layer depth within one period.
        let p = LadderPolicy { budget: 64, n_sink: 0, n_recent: 0, span: 1, overlap: 4 };
        let n_layers = 8;
        let middle_len = 32; // exactly one period
        let mean_of = |l: usize| {
            let kept: Vec<f64> = (0..middle_len)
                .filter(|&m| p.covered(l, m, middle_len, n_layers))
                .map(|m| m as f64)
                .collect();
            kept.iter().sum::<f64>() / kept.len() as f64
        };
        assert!(mean_of(6) > mean_of(1), "ladder slope inverted");
    }

    #[test]
    fn iterative_compaction_thins_geometrically() {
        // §3.3: repeated evict() keeps compressing older content while
        // occupancy stays bounded.
        let p = LadderPolicy { budget: 48, n_sink: 4, n_recent: 8, span: 2, overlap: 4 };
        let mut kv = cache_with(8, 0);
        let mut next_pos = 0u64;
        for _round in 0..20 {
            for layer in 0..8 {
                let add = 16;
                let wk = vec![0.0f32; add * 2];
                let first = next_pos;
                kv.append_layer(layer, &wk, &wk, add, add, first).unwrap();
            }
            next_pos += 16;
            p.evict(&mut kv).unwrap();
            kv.check_invariants().unwrap();
            assert!(kv.max_len() <= 48, "over budget after evict");
            // paged-arena invariant under the compaction workload: resident
            // bytes track page-granular occupancy, never compiled capacity
            let expect: usize = kv
                .lens
                .iter()
                .map(|&n| n.div_ceil(crate::runtime::PAGE_SLOTS) * crate::runtime::Page::bytes(2))
                .sum();
            assert_eq!(kv.resident_bytes(), expect);
            assert!(kv.resident_bytes() < 8 * 256 * 2 * 2 * 4, "resident at capacity scale");
        }
        // oldest retained (non-sink) middle content is sparse, recent dense:
        let pos = &kv.positions[4];
        let old_density = pos.iter().filter(|&&p| p > 16 && p < 100).count();
        let new_density = pos.iter().filter(|&&p| p >= next_pos - 16).count();
        assert!(new_density >= 8, "recent tokens missing");
        assert!(old_density <= new_density, "old {old_density} new {new_density}");
    }

    #[test]
    fn progress_guarantee_property() {
        // For arbitrary (budget, span, overlap, occupancy), evict always
        // reduces an over-budget layer strictly below occupancy.
        PropRunner::new(200).run(
            |rng: &mut Xoshiro256| {
                let budget = 16 + rng.below(64) as usize;
                let span = 1 + rng.below(8) as usize;
                let overlap = 1 + rng.below(16) as usize;
                let n = budget + 1 + rng.below(100) as usize;
                let n_recent = rng.below(budget as u64 / 2) as usize;
                (budget, span, overlap, n, n_recent)
            },
            |&(budget, span, overlap, n, n_recent)| {
                let p = LadderPolicy { budget, n_sink: 4, n_recent, span, overlap };
                let kv = cache_with(8, n.min(250));
                let n = n.min(250);
                for layer in 0..8 {
                    let keep = p.keep_slots(layer, &kv);
                    crate::prop_assert!(keep.len() < n, "no progress: kept {} of {n}", keep.len());
                    crate::prop_assert!(
                        keep.windows(2).all(|w| w[0] < w[1]),
                        "not strictly increasing"
                    );
                    crate::prop_assert!(
                        keep.iter().all(|&s| s < n),
                        "out of range"
                    );
                }
                Ok(())
            },
        );
    }

    #[test]
    fn random_pattern_same_budget_discipline() {
        let p = RandomPatternPolicy {
            budget: 64,
            n_sink: 4,
            n_recent: 8,
            keep_frac: 0.25,
            seed: 7,
        };
        let mut kv = cache_with(8, 128);
        p.evict(&mut kv).unwrap();
        kv.check_invariants().unwrap();
        for l in 0..8 {
            assert!(kv.lens[l] < 128);
            assert!(kv.positions[l].iter().take(4).eq([0, 1, 2, 3].iter()));
        }
    }
}
