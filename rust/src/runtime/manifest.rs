//! Artifact manifest: the contract between `python/compile/aot.py` (producer)
//! and the rust runtime (consumer). See DESIGN.md §2 for the program table.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Mirror of `python/compile/model.py::ModelConfig`.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_model: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub rope_theta: f64,
    /// Pretraining context length — positions beyond this are OOD (the
    /// full-cache PPL-explosion axis in Tab. 1 / Fig. 5).
    pub t_train: usize,
}

impl ModelCfg {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.str_of("name").context("cfg.name")?.to_string(),
            vocab: j.usize_of("vocab").context("cfg.vocab")?,
            n_layers: j.usize_of("n_layers").context("cfg.n_layers")?,
            n_heads: j.usize_of("n_heads").context("cfg.n_heads")?,
            d_model: j.usize_of("d_model").context("cfg.d_model")?,
            head_dim: j.usize_of("head_dim").context("cfg.head_dim")?,
            d_ff: j.usize_of("d_ff").context("cfg.d_ff")?,
            rope_theta: j.f64_of("rope_theta").context("cfg.rope_theta")?,
            t_train: j.usize_of("t_train").context("cfg.t_train")?,
        })
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProgKind {
    Score,
    Generate,
}

/// One AOT-compiled HLO program.
#[derive(Clone, Debug)]
pub struct ProgMeta {
    pub name: String,
    pub kind: ProgKind,
    /// Window length (score) — 0 for generate programs.
    pub w: usize,
    /// Cache capacity baked into the program shapes.
    pub c: usize,
    /// Decode steps per call (generate) — 0 for score programs.
    pub k: usize,
    /// Emits per-slot attention mass (the slow path for H2O-family policies).
    pub scored: bool,
    pub path: PathBuf,
}

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub cfg: ModelCfg,
    pub weights_path: PathBuf,
    pub n_params: usize,
    pub programs: BTreeMap<String, ProgMeta>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub c_small: usize,
    pub c_full: usize,
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        if j.usize_of("version") != Some(1) {
            bail!("unsupported manifest version");
        }
        let mut models = BTreeMap::new();
        for m in j.req("models").as_arr().context("models")? {
            let cfg = ModelCfg::from_json(m.req("config"))?;
            let mut programs = BTreeMap::new();
            for (pname, pj) in m.req("programs").as_obj().context("programs")? {
                let kind = match pj.str_of("kind") {
                    Some("score") => ProgKind::Score,
                    Some("generate") => ProgKind::Generate,
                    other => bail!("unknown program kind {other:?}"),
                };
                programs.insert(
                    pname.clone(),
                    ProgMeta {
                        name: pname.clone(),
                        kind,
                        w: pj.usize_of("w").unwrap_or(0),
                        c: pj.usize_of("c").context("prog.c")?,
                        k: pj.usize_of("k").unwrap_or(0),
                        scored: pj.bool_of("scored").unwrap_or(false),
                        path: dir.join(pj.str_of("path").context("prog.path")?),
                    },
                );
            }
            let name = m.str_of("name").context("model.name")?.to_string();
            models.insert(
                name,
                ModelEntry {
                    cfg,
                    weights_path: dir.join(m.str_of("weights").context("weights")?),
                    n_params: m.usize_of("n_params").context("n_params")?,
                    programs,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            c_small: j.usize_of("c_small").context("c_small")?,
            c_full: j.usize_of("c_full").context("c_full")?,
            models,
        })
    }

    /// Pick the score program for (w, c, scored).
    pub fn score_prog(&self, model: &str, w: usize, c: usize, scored: bool) -> Result<&ProgMeta> {
        let name = if scored {
            format!("score_scored_w{w}_c{c}")
        } else {
            format!("score_w{w}_c{c}")
        };
        self.prog(model, &name)
    }

    pub fn generate_prog(&self, model: &str, k: usize, c: usize, scored: bool) -> Result<&ProgMeta> {
        let name = if scored {
            format!("generate_scored_k{k}_c{c}")
        } else {
            format!("generate_k{k}_c{c}")
        };
        self.prog(model, &name)
    }

    /// The interpret-mode Pallas-kernel decode variant (numerics-identical to
    /// the fast path; the artifact a TPU target would compile natively).
    pub fn generate_pallas_prog(&self, model: &str, k: usize, c: usize) -> Result<&ProgMeta> {
        self.prog(model, &format!("generate_pallas_k{k}_c{c}"))
    }

    pub fn prog(&self, model: &str, name: &str) -> Result<&ProgMeta> {
        let entry = self.models.get(model).with_context(|| format!("no model `{model}`"))?;
        entry.programs.get(name).with_context(|| format!("no program `{model}/{name}`"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).with_context(|| format!("no model `{name}`"))
    }
}

/// Program names the serving path pre-compiles at startup (one score window
/// plus both decode chunk sizes) — warmed once per device shard so no
/// shard pays first-call compile latency. Shared by the server and the
/// bench harness so the two never warm different program sets.
pub fn serving_prog_names(window: usize, capacity: usize) -> Vec<String> {
    vec![
        format!("score_w{window}_c{capacity}"),
        format!("generate_k16_c{capacity}"),
        format!("generate_k1_c{capacity}"),
    ]
}

/// Expected flat weight length for a config (mirrors model.py::weight_spec).
pub fn expected_n_params(cfg: &ModelCfg) -> usize {
    let d = cfg.d_model;
    let hd = cfg.n_heads * cfg.head_dim;
    let f = cfg.d_ff;
    let per_layer = d + 3 * d * hd + hd * d + d + 2 * d * f + f * d;
    cfg.vocab * d + cfg.n_layers * per_layer + d
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn load_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        assert!(man.models.contains_key("base"));
        assert!(man.models.contains_key("mini"));
        let base = man.model("base").unwrap();
        assert_eq!(base.cfg.n_layers, 8);
        assert_eq!(base.n_params, expected_n_params(&base.cfg));
        let p = man.score_prog("base", 32, 256, false).unwrap();
        assert_eq!(p.kind, ProgKind::Score);
        assert!(p.path.exists());
        let g = man.generate_prog("base", 16, 256, false).unwrap();
        assert_eq!(g.k, 16);
        assert!(man.generate_prog("base", 16, 256, true).is_ok());
        assert!(man.prog("base", "nonexistent").is_err());
    }

    #[test]
    fn serving_progs_cover_score_and_both_decode_chunks() {
        let names = serving_prog_names(128, 256);
        assert_eq!(
            names,
            vec!["score_w128_c256", "generate_k16_c256", "generate_k1_c256"],
            "serving warmup set must match the compiled program naming scheme"
        );
    }
}
