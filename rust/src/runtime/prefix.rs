//! Cross-request prefix cache: a radix tree over prompt-token chunks whose
//! nodes pin frozen, refcounted arena pages ([`SharedPage`]), so a new
//! sequence whose prompt starts with an already-served prefix adopts the
//! donor's ladder KV state instead of re-running prefill.
//!
//! Why this is sound: the ladder policy (and every other registered policy)
//! is a deterministic function of the token stream, the ingestion-window
//! cadence, and the compiled capacity — two sequences fed the same tokens
//! through the same `(model, policy, window, capacity)` signature hold
//! byte-identical KV pages at every window boundary. The serving backend
//! therefore freezes a sequence's pages after each FULL ingestion window
//! ([`PrefixSnapshot::freeze`]) and publishes them here; adoption
//! ([`PrefixSnapshot::apply`] via `KvCache::adopt_shared`) installs the
//! same pages into a fork, which then continues prefilling at the matched
//! offset with the identical chunk cadence. Snapshots are only accepted at
//! whole-window boundaries (`tokens.len() % window == 0`): a partial-window
//! boundary would shift the adopter's eviction cadence and diverge from its
//! cold state.
//!
//! Mutation safety is the arena's copy-on-write: the donor keeps appending
//! and compacting over its now-shared pages (each first write copies that
//! page privately), and so does every fork — the frozen pages themselves
//! never change, and the last reader returns them to the pool.
//!
//! The tree is capacity-bounded (`ServeConfig.prefix_pool_bytes`): each
//! snapshot charges its full pinned page span and the least-recently-used
//! LEAF snapshot is evicted first (inner snapshots share most of their
//! pages with their descendants, so leaf-first eviction frees real bytes
//! while keeping the shortest — most reusable — prefixes). Invariants and
//! the interaction with the residency tier's `(id, sync_gen)` stamps are
//! documented in PERF.md "Prefix sharing".

use anyhow::Result;

use super::arena::SharedPage;
use super::kv::KvCache;
use crate::obs::{self, EventKind};

/// A frozen cache state at one prefill-chunk boundary: shared page handles
/// plus the occupancy bookkeeping a fork needs to resume from it.
#[derive(Clone)]
pub struct PrefixSnapshot {
    /// Per-layer frozen pages (`lens[l].div_ceil(PAGE_SLOTS)` handles each).
    pages: Vec<Vec<SharedPage>>,
    lens: Vec<usize>,
    positions: Vec<Vec<u64>>,
    mass: Vec<Vec<f64>>,
    /// Page bytes pinned by this snapshot. Nested snapshots share page
    /// handles but each charges its full span — a simple over-count that
    /// keeps the eviction bound conservative.
    bytes: usize,
    /// The shard whose residency/scratch tiers served this snapshot's donor.
    /// The tree stays one LOGICAL index over all shards, but placement
    /// prefers this shard so adoption stays device-local; an unserviceable
    /// home shard means cold prefill elsewhere (a counted spillover), never
    /// an implicit cross-device migration.
    home_shard: usize,
}

impl PrefixSnapshot {
    /// Freeze `cache`'s current state (converting its pages to shared in
    /// place; the cache keeps running over them through CoW). Single-shard
    /// convenience for [`Self::freeze_on`] with home shard 0.
    pub fn freeze(cache: &mut KvCache) -> Self {
        Self::freeze_on(cache, 0)
    }

    /// Freeze `cache`, stamping the donor's `home_shard` for locality-aware
    /// placement.
    pub fn freeze_on(cache: &mut KvCache, home_shard: usize) -> Self {
        let pages = cache.freeze_pages();
        // per-page actual bytes: with `--kv-quant cold-q8` the donor froze
        // straight to Q8, so the same `prefix_pool_bytes` budget holds ~4x
        // more reusable prefixes
        let bytes = pages.iter().flat_map(|t| t.iter()).map(|sp| sp.bytes()).sum();
        Self {
            pages,
            lens: cache.lens.clone(),
            positions: cache.positions.clone(),
            mass: cache.mass.clone(),
            bytes,
            home_shard,
        }
    }

    /// The shard this snapshot's KV state is local to.
    pub fn home_shard(&self) -> usize {
        self.home_shard
    }

    /// Install into an EMPTY cache (the fork path). Validates shape first;
    /// a failed apply leaves the cache untouched.
    pub fn apply(&self, cache: &mut KvCache) -> Result<()> {
        cache.adopt_shared(&self.pages, &self.lens, &self.positions, &self.mass)
    }

    /// Page bytes pinned by this snapshot (the prefix-pool charge unit).
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

/// Cumulative prefix-cache counters (exported in `op:stats`).
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefixStats {
    /// Lookups that matched a snapshot (one adopted fork each).
    pub hits: u64,
    /// Lookups that matched nothing.
    pub misses: u64,
    /// Snapshots accepted into the tree.
    pub inserts: u64,
    /// Snapshots evicted by the capacity bound.
    pub evictions: u64,
    /// Prompt tokens whose prefill was skipped via adoption.
    pub tokens_reused: u64,
}

struct Node {
    /// Child edges, each labeled by one full ingestion-window token chunk.
    children: Vec<(Vec<i32>, Node)>,
    snap: Option<PrefixSnapshot>,
    last_used: u64,
}

impl Node {
    fn new() -> Self {
        Self { children: Vec::new(), snap: None, last_used: 0 }
    }
}

/// The capacity-bounded radix tree. One instance per serving signature —
/// reusing KV state across a different `(model, policy, window, capacity)`
/// would be unsound, so the owner validates [`PrefixCache::signature`]
/// before adopting.
pub struct PrefixCache {
    sig: String,
    /// Byte bound on pinned snapshots; 0 disables the cache entirely.
    capacity_bytes: usize,
    root: Node,
    clock: u64,
    resident_bytes: usize,
    stats: PrefixStats,
}

impl PrefixCache {
    pub fn new(sig: String, capacity_bytes: usize) -> Self {
        Self {
            sig,
            capacity_bytes,
            root: Node::new(),
            clock: 0,
            resident_bytes: 0,
            stats: PrefixStats::default(),
        }
    }

    /// The determinism domain this tree's snapshots are valid for.
    pub fn signature(&self) -> &str {
        &self.sig
    }

    pub fn enabled(&self) -> bool {
        self.capacity_bytes > 0
    }

    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    /// Bytes currently pinned by stored snapshots (the `op:stats` gauge and
    /// the admission gate's prefix term).
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// Longest stored prefix of `prompt` that ends at a snapshot, walking
    /// whole chunk edges only. Returns the matched token count and ONE
    /// clone of that snapshot (handles to the same shared pages — the walk
    /// itself clones nothing); refreshes LRU clocks along the matched path.
    pub fn lookup(&mut self, prompt: &[i32]) -> Option<(usize, PrefixSnapshot)> {
        if !self.enabled() {
            return None;
        }
        self.clock += 1;
        let clock = self.clock;
        // pass 1 (read-only): find the deepest snapshot-bearing boundary.
        // The root never carries a snapshot (paths are non-empty), so
        // best_pos == 0 means no match.
        let mut best_pos = 0usize;
        {
            let mut node = &self.root;
            let mut pos = 0usize;
            loop {
                if node.snap.is_some() {
                    best_pos = pos;
                }
                let found = node.children.iter().find(|(chunk, _)| {
                    prompt.len() - pos >= chunk.len() && prompt[pos..pos + chunk.len()] == chunk[..]
                });
                match found {
                    Some((chunk, child)) => {
                        pos += chunk.len();
                        node = child;
                    }
                    None => break,
                }
            }
        }
        if best_pos == 0 {
            self.stats.misses += 1;
            return None;
        }
        // pass 2 (mutable): stamp the adopted path's clocks and clone
        // exactly the snapshot being handed out
        let mut node = &mut self.root;
        node.last_used = clock;
        let mut pos = 0usize;
        while pos < best_pos {
            let i = node
                .children
                .iter()
                .position(|(chunk, _)| {
                    prompt.len() - pos >= chunk.len()
                        && prompt[pos..pos + chunk.len()] == chunk[..]
                })
                .expect("path verified by the read-only pass");
            let (chunk, child) = &mut node.children[i];
            pos += chunk.len();
            node = child;
            node.last_used = clock;
        }
        let snap = node.snap.clone().expect("snapshot verified by the read-only pass");
        self.stats.hits += 1;
        self.stats.tokens_reused += best_pos as u64;
        obs::record(
            EventKind::PrefixAdopt,
            clock,
            snap.home_shard(),
            best_pos as i64,
            snap.bytes() as i64,
        );
        Some((best_pos, snap))
    }

    /// Publish a snapshot for the boundary after `tokens` (the full
    /// ingested prefix), chunked by `window`. `make` is only called when
    /// the tree actually wants the snapshot — an existing equivalent node
    /// just gets its LRU clock refreshed, and partial-window boundaries
    /// (`tokens.len() % window != 0`) are rejected outright because the
    /// adopter's re-chunking would diverge from its cold eviction cadence.
    /// Returns whether a new snapshot was stored.
    pub fn insert_with(
        &mut self,
        tokens: &[i32],
        window: usize,
        make: impl FnOnce() -> PrefixSnapshot,
    ) -> bool {
        if !self.enabled() || window == 0 || tokens.is_empty() || tokens.len() % window != 0 {
            return false;
        }
        self.clock += 1;
        let clock = self.clock;
        // pass 1: walk the existing path; an existing snapshot is
        // equivalent state (determinism), so only the clocks move
        let mut node = &mut self.root;
        node.last_used = clock;
        let mut missing = false;
        for chunk in tokens.chunks(window) {
            let found = node.children.iter().position(|(c, _)| c[..] == chunk[..]);
            match found {
                Some(i) => {
                    node = &mut node.children[i].1;
                    node.last_used = clock;
                }
                None => {
                    missing = true;
                    break;
                }
            }
        }
        if !missing && node.snap.is_some() {
            return false;
        }
        let snap = make();
        if snap.bytes() > self.capacity_bytes {
            return false; // could never fit; create no empty path nodes
        }
        // pass 2: create the remaining path and install
        let mut node = &mut self.root;
        for chunk in tokens.chunks(window) {
            let i = match node.children.iter().position(|(c, _)| c[..] == chunk[..]) {
                Some(i) => i,
                None => {
                    node.children.push((chunk.to_vec(), Node::new()));
                    node.children.len() - 1
                }
            };
            node = &mut node.children[i].1;
            node.last_used = clock;
        }
        self.resident_bytes += snap.bytes();
        obs::record(
            EventKind::PrefixFreeze,
            clock,
            snap.home_shard(),
            tokens.len() as i64,
            snap.bytes() as i64,
        );
        node.snap = Some(snap);
        self.stats.inserts += 1;
        self.evict_to_capacity();
        true
    }

    /// Drop everything (tests and signature rotation).
    pub fn clear(&mut self) {
        self.root = Node::new();
        self.resident_bytes = 0;
    }

    fn evict_to_capacity(&mut self) {
        while self.resident_bytes > self.capacity_bytes {
            let Some(freed) = evict_lru_leaf(&mut self.root) else {
                break;
            };
            self.resident_bytes -= freed;
            self.stats.evictions += 1;
            obs::record(EventKind::PrefixEvict, self.clock, 0, freed as i64, 0);
        }
    }
}

/// Evict the least-recently-used LEAF snapshot, pruning emptied nodes on
/// the way out. Returns the bytes it charged, or None when the tree holds
/// no leaf snapshot.
fn evict_lru_leaf(root: &mut Node) -> Option<usize> {
    fn min_leaf_clock(node: &Node) -> Option<u64> {
        if node.children.is_empty() {
            return node.snap.as_ref().map(|_| node.last_used);
        }
        node.children.iter().filter_map(|(_, c)| min_leaf_clock(c)).min()
    }

    fn remove(node: &mut Node, target: u64) -> Option<usize> {
        if node.children.is_empty() {
            if node.snap.is_some() && node.last_used == target {
                return node.snap.take().map(|s| s.bytes());
            }
            return None;
        }
        let mut hit: Option<(usize, usize)> = None; // (child index, freed)
        for (i, (_, child)) in node.children.iter_mut().enumerate() {
            if let Some(freed) = remove(child, target) {
                hit = Some((i, freed));
                break;
            }
        }
        let (i, freed) = hit?;
        let child = &node.children[i].1;
        if child.children.is_empty() && child.snap.is_none() {
            node.children.remove(i);
        }
        Some(freed)
    }

    let target = min_leaf_clock(root)?;
    remove(root, target)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::runtime::arena::{KvArena, Page, PAGE_SLOTS};
    use crate::util::prop::PropRunner;
    use crate::util::rng::Xoshiro256;

    fn mk(arena: &KvArena, l: usize, h: usize, c: usize, dh: usize) -> KvCache {
        KvCache::with_arena(arena.clone(), l, h, c, dh)
    }

    /// Append one `n`-slot window of values derived ONLY from `seed`, so a
    /// replica replaying the same seeds builds byte-identical state.
    fn append_window(kv: &mut KvCache, n: usize, next_pos: &mut u64, seed: u64) {
        let (l, h, dh) = (kv.l, kv.h, kv.dh);
        let mut rng = Xoshiro256::new(seed);
        for layer in 0..l {
            let wk: Vec<f32> = (0..h * n * dh).map(|_| rng.below(1000) as f32 * 0.5).collect();
            let wv: Vec<f32> = (0..h * n * dh).map(|_| rng.below(1000) as f32 * -0.5).collect();
            kv.append_layer(layer, &wk, &wv, n, n, *next_pos).unwrap();
        }
        *next_pos += n as u64;
    }

    #[test]
    fn radix_insert_lookup_longest_chunk_match() {
        let arena = KvArena::new();
        let mut donor = mk(&arena, 1, 1, 64, 2);
        let mut pc = PrefixCache::new("sig".into(), 1 << 20);
        let w = 4;
        let prompt: Vec<i32> = (0..12).collect();
        let mut pos = 0;
        append_window(&mut donor, w, &mut pos, 1);
        assert!(pc.insert_with(&prompt[..4], w, || PrefixSnapshot::freeze(&mut donor)));
        append_window(&mut donor, w, &mut pos, 2);
        assert!(pc.insert_with(&prompt[..8], w, || PrefixSnapshot::freeze(&mut donor)));
        // partial-window boundaries are rejected (cadence divergence)
        assert!(!pc.insert_with(&prompt[..6], w, || unreachable!()));
        // an equivalent boundary refreshes LRU instead of re-freezing
        assert!(!pc.insert_with(&prompt[..8], w, || unreachable!()));
        assert_eq!(pc.stats().inserts, 2);

        // longest chunk-aligned match wins; the diverging tail stops it
        let (m, snap) = pc.lookup(&[0, 1, 2, 3, 4, 5, 6, 7, 99, 98]).unwrap();
        assert_eq!(m, 8);
        let mut fork = mk(&arena, 1, 1, 64, 2);
        snap.apply(&mut fork).unwrap();
        assert_eq!(fork.lens[0], 8);
        let (fk, _) = fork.gather_dense();
        let (dk, _) = donor.gather_dense();
        assert_eq!(fk, dk, "adopted state equals the donor's at the boundary");

        let (m4, _) = pc.lookup(&[0, 1, 2, 3, 9, 9, 9, 9]).unwrap();
        assert_eq!(m4, 4);
        assert!(pc.lookup(&[5, 5, 5, 5]).is_none());
        assert!(pc.lookup(&[0, 1]).is_none(), "sub-window prompts cannot match");
        let st = pc.stats();
        assert_eq!((st.hits, st.misses), (2, 2));
        assert_eq!(st.tokens_reused, 12);
    }

    #[test]
    fn snapshots_carry_their_home_shard() {
        let arena = KvArena::new();
        let mut donor = mk(&arena, 1, 1, 64, 2);
        let mut pc = PrefixCache::new("sig".into(), 1 << 20);
        let w = 4;
        let mut pos = 0;
        append_window(&mut donor, w, &mut pos, 3);
        assert_eq!(PrefixSnapshot::freeze(&mut donor).home_shard(), 0, "freeze defaults to 0");
        let prompt: Vec<i32> = (0..4).collect();
        assert!(pc.insert_with(&prompt, w, || PrefixSnapshot::freeze_on(&mut donor, 2)));
        let (m, snap) = pc.lookup(&prompt).unwrap();
        assert_eq!(m, 4);
        assert_eq!(snap.home_shard(), 2, "lookup hands back the donor's shard");
    }

    #[test]
    fn disabled_prefix_cache_stores_and_matches_nothing() {
        let mut pc = PrefixCache::new("sig".into(), 0);
        assert!(!pc.enabled());
        assert!(!pc.insert_with(&[1, 2], 2, || unreachable!()));
        assert!(pc.lookup(&[1, 2]).is_none());
        let st = pc.stats();
        assert_eq!((st.hits, st.misses, st.inserts), (0, 0, 0));
        assert_eq!(pc.resident_bytes(), 0);
    }

    #[test]
    fn lru_leaf_eviction_and_page_release() {
        let arena = KvArena::new();
        let w = PAGE_SLOTS; // one full page per window at rw 2
        let per = Page::bytes(2);
        let mut pc = PrefixCache::new("sig".into(), per + per / 2);
        let chunk_a: Vec<i32> = (0..w as i32).collect();
        let chunk_b: Vec<i32> = (100..100 + w as i32).collect();
        let mut donor_a = mk(&arena, 1, 1, 64, 2);
        let mut pa = 0;
        append_window(&mut donor_a, w, &mut pa, 7);
        assert!(pc.insert_with(&chunk_a, w, || PrefixSnapshot::freeze(&mut donor_a)));
        assert_eq!(pc.resident_bytes(), per);
        let mut donor_b = mk(&arena, 1, 1, 64, 2);
        let mut pb = 0;
        append_window(&mut donor_b, w, &mut pb, 8);
        assert!(pc.insert_with(&chunk_b, w, || PrefixSnapshot::freeze(&mut donor_b)));
        // over capacity: the least-recently-used leaf (A) was evicted
        assert_eq!(pc.stats().evictions, 1);
        assert_eq!(pc.resident_bytes(), per);
        assert!(pc.lookup(&chunk_a).is_none());
        assert!(pc.lookup(&chunk_b).is_some());
        // dropping the donors leaves only the pinned snapshot's page in use
        drop(donor_a);
        drop(donor_b);
        assert_eq!(arena.stats().bytes_in_use, per, "only the surviving leaf pins a page");
        pc.clear();
        assert_eq!(arena.stats().bytes_in_use, 0, "clearing the tree returns the pages");
    }

    #[test]
    fn lookup_refreshes_lru_order() {
        let arena = KvArena::new();
        let w = PAGE_SLOTS;
        let per = Page::bytes(2);
        let mut pc = PrefixCache::new("sig".into(), 2 * per + per / 2);
        let chunks: Vec<Vec<i32>> =
            (0..3).map(|k| (k * 100..k * 100 + w as i32).collect()).collect();
        let mut donors = Vec::new();
        for (k, chunk) in chunks.iter().enumerate().take(2) {
            let mut d = mk(&arena, 1, 1, 64, 2);
            let mut p = 0;
            append_window(&mut d, w, &mut p, k as u64);
            assert!(pc.insert_with(chunk, w, || PrefixSnapshot::freeze(&mut d)));
            donors.push(d);
        }
        // touching A makes B the LRU victim when C overflows the pool
        assert!(pc.lookup(&chunks[0]).is_some());
        let mut d = mk(&arena, 1, 1, 64, 2);
        let mut p = 0;
        append_window(&mut d, w, &mut p, 9);
        assert!(pc.insert_with(&chunks[2], w, || PrefixSnapshot::freeze(&mut d)));
        assert_eq!(pc.stats().evictions, 1);
        assert!(pc.lookup(&chunks[1]).is_none(), "LRU leaf B must be the victim");
        assert!(pc.lookup(&chunks[0]).is_some());
        assert!(pc.lookup(&chunks[2]).is_some());
    }

    #[derive(Debug, Clone, Copy)]
    enum Op {
        Append { n: usize, seed: u64 },
        Retain { seed: u64 },
        Truncate { seed: u64 },
    }

    #[test]
    fn forked_sequence_matches_from_scratch_property() {
        // donor + fork share one frozen prefix over one arena; replicas in
        // a SEPARATE arena replay the identical history from scratch. After
        // every random append/compact/evict/CoW interleaving step, each
        // sequence's dense image must equal its replica's (CoW isolation in
        // both directions), and after all drops the shared arena must
        // return to baseline (no leaked pages or refcounts).
        PropRunner::new(40).run(
            |rng: &mut Xoshiro256| {
                let h = 1 + rng.below(2) as usize;
                let dh = 1 + rng.below(3) as usize;
                let prefix_windows = 1 + rng.below(3) as usize;
                let prefix_seed = rng.below(u64::MAX);
                let ops: Vec<(usize, Op)> = (0..12)
                    .map(|_| {
                        let which = rng.below(2) as usize;
                        let op = match rng.below(4) {
                            0 | 1 => Op::Append {
                                n: 1 + rng.below(6) as usize,
                                seed: rng.below(u64::MAX),
                            },
                            2 => Op::Retain { seed: rng.below(u64::MAX) },
                            _ => Op::Truncate { seed: rng.below(u64::MAX) },
                        };
                        (which, op)
                    })
                    .collect();
                (h, dh, prefix_windows, prefix_seed, ops)
            },
            |(h, dh, prefix_windows, prefix_seed, ops)| {
                let (h, dh) = (*h, *dh);
                let (l, c, w) = (2usize, 64usize, 8usize);
                let arena = KvArena::new();
                let ref_arena = KvArena::new();
                let mut donor = mk(&arena, l, h, c, dh);
                let mut donor_ref = mk(&ref_arena, l, h, c, dh);
                let mut fork_ref = mk(&ref_arena, l, h, c, dh);
                let mut pos = 0u64;
                for i in 0..*prefix_windows {
                    let seed = prefix_seed.wrapping_add(i as u64);
                    let (mut p1, mut p2) = (pos, pos);
                    append_window(&mut donor, w, &mut pos, seed);
                    append_window(&mut donor_ref, w, &mut p1, seed);
                    append_window(&mut fork_ref, w, &mut p2, seed);
                }
                let snap = PrefixSnapshot::freeze(&mut donor);
                let mut fork = mk(&arena, l, h, c, dh);
                snap.apply(&mut fork).map_err(|e| format!("apply: {e}"))?;

                let mut subjects = [donor, fork];
                let mut replicas = [donor_ref, fork_ref];
                let mut next_pos = [pos, pos];
                for &(which, op) in ops {
                    match op {
                        Op::Append { n, seed } => {
                            if subjects[which].max_len() + n > c {
                                continue;
                            }
                            let mut p2 = next_pos[which];
                            append_window(&mut subjects[which], n, &mut next_pos[which], seed);
                            append_window(&mut replicas[which], n, &mut p2, seed);
                        }
                        Op::Retain { seed } => {
                            for layer in 0..l {
                                let n = subjects[which].lens[layer];
                                let mut krng = Xoshiro256::new(seed.wrapping_add(layer as u64));
                                let keep: Vec<usize> =
                                    (0..n).filter(|_| krng.below(3) > 0).collect();
                                subjects[which].retain_slots(layer, &keep).unwrap();
                                replicas[which].retain_slots(layer, &keep).unwrap();
                            }
                        }
                        Op::Truncate { seed } => {
                            let mut trng = Xoshiro256::new(seed);
                            for layer in 0..l {
                                let n = subjects[which].lens[layer];
                                let new_len = trng.below(n as u64 + 1) as usize;
                                subjects[which].truncate_layer(layer, new_len).unwrap();
                                replicas[which].truncate_layer(layer, new_len).unwrap();
                            }
                        }
                    }
                    for i in 0..2 {
                        prop_assert!(
                            subjects[i].check_invariants().is_ok(),
                            "invariants broken on sequence {i}"
                        );
                        let (sk, sv) = subjects[i].gather_dense();
                        let (rk, rv) = replicas[i].gather_dense();
                        prop_assert!(
                            sk == rk && sv == rv,
                            "sequence {i} diverged from its from-scratch replica"
                        );
                    }
                }
                drop(snap);
                drop(subjects);
                drop(replicas);
                let leaked = arena.stats().bytes_in_use;
                prop_assert!(leaked == 0, "leaked {leaked} arena bytes after all drops");
                Ok(())
            },
        );
    }
}

