//! Shared paged-KV arena: a process-wide pool of fixed-size pages backing
//! every [`super::KvCache`].
//!
//! A sequence's resident bytes track its *actual* occupancy (`lens` rounded
//! up to the page size) instead of the compiled capacity `C`; freed pages
//! return to the pool and are recycled across sequences, so concurrent
//! serving pays for what the ladder policy actually keeps — the block/paged
//! KV management idea from vLLM-style serving stacks, applied under the
//! paper's compaction policies.
//!
//! Pages come in two precisions (see [`PageData`]): full `f32` for hot,
//! still-mutating slots, and **Q8** — symmetric-absmax int8 with per-head,
//! per-page f32 scales — for cold read-mostly slots (~4x capacity per
//! byte). The head-major page layout keeps each head's slots contiguous, so
//! one scale covers one contiguous run and dequantize-on-gather streams
//! straight through it. The pool free-list is keyed by
//! `(row_width, precision)` so mixed-precision pooling never double-counts
//! reclaimed bytes.
//!
//! An optional byte budget turns the arena into the serving-path admission
//! signal: allocations that would exceed it fail with [`ARENA_OOM_MARKER`],
//! and the scheduler consults [`KvArena::stats`] before admitting new
//! sequences.
//!
//! Pages can also be **frozen** into refcounted [`SharedPage`]s (the
//! cross-request prefix cache pins them, and every cache that adopts a
//! prefix holds handles to the same pages): the bytes stay charged exactly
//! once and return to the pool only when the LAST reader drops. Mutation of
//! a shared page is copy-on-write, performed by [`super::KvCache`] and
//! counted in [`ArenaStats::cow_copies`].

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Result};

/// Slots per page. 16 rows amortizes page-table overhead while keeping
/// per-sequence over-allocation below one page per layer.
pub const PAGE_SLOTS: usize = 16;

/// Raised (string-matched, like the engine's simulated-OOM marker) when an
/// allocation would push the pool past its byte budget.
pub const ARENA_OOM_MARKER: &str = "kv-arena-OOM";

/// Storage precision of one arena page — the pool free-list key alongside
/// row width.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Precision {
    /// Full precision, 4 bytes/element. All writes happen at f32.
    #[default]
    F32,
    /// Symmetric-absmax int8 with per-head, per-page f32 scales
    /// (1 byte/element + `2 * H * 4` scale bytes). Read-only: the cache
    /// re-materializes f32 before any in-place write.
    Q8,
}

/// One page: `PAGE_SLOTS` KV rows for one layer, stored **head-major**
/// `[H, PAGE_SLOTS, Dh]` — one head's slots are contiguous, matching the
/// device-contiguous `[L, H, C, Dh]` image layout so gather/scatter move
/// whole `PAGE_SLOTS * Dh` runs per head (16x fewer copies than the
/// slot-major layout's `Dh` fragments). Compaction relocates a slot with
/// one `Dh`-sized move per head (see `KvCache::retain_slots`).
pub struct Page {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl Page {
    fn new(row_width: usize) -> Self {
        Page { k: vec![0.0; PAGE_SLOTS * row_width], v: vec![0.0; PAGE_SLOTS * row_width] }
    }

    /// Bytes held by one full-precision page of the given row width
    /// (K + V, f32). Quantized pages are smaller — see
    /// [`QuantPage::bytes_for`] and [`PageData::bytes`] for the
    /// precision-aware accounting.
    pub fn bytes(row_width: usize) -> usize {
        2 * PAGE_SLOTS * row_width * 4
    }
}

/// A quantized arena page: int8 K/V in the same head-major layout as
/// [`Page`], plus one symmetric-absmax f32 scale per head per tensor
/// (`deq(x) = q * scale`, `scale = absmax / 127` over the head's valid
/// slots). ~4x smaller than the f32 page it replaces.
pub struct QuantPage {
    pub k: Vec<i8>,
    pub v: Vec<i8>,
    /// Per-head K scales, length `H`.
    pub k_scales: Vec<f32>,
    /// Per-head V scales, length `H`.
    pub v_scales: Vec<f32>,
}

impl QuantPage {
    fn new(row_width: usize, heads: usize) -> Self {
        QuantPage {
            k: vec![0; PAGE_SLOTS * row_width],
            v: vec![0; PAGE_SLOTS * row_width],
            k_scales: vec![0.0; heads],
            v_scales: vec![0.0; heads],
        }
    }

    /// Heads covered by the per-head scales.
    pub fn heads(&self) -> usize {
        self.k_scales.len()
    }

    /// Bytes held by one Q8 page: int8 K + V plus the per-head f32 scales.
    pub fn bytes_for(row_width: usize, heads: usize) -> usize {
        2 * PAGE_SLOTS * row_width + 2 * heads * 4
    }

    /// Quantize `page` into this buffer. Only the first `valid_slots` slots
    /// of each head run participate in the absmax and are encoded — slots
    /// beyond the sequence length hold recycled junk that must not inflate
    /// the scale (they are zeroed here and never read back).
    pub fn encode(&mut self, page: &Page, valid_slots: usize) {
        let heads = self.heads();
        let dh = page.k.len() / (heads * PAGE_SLOTS);
        let valid = valid_slots.min(PAGE_SLOTS) * dh;
        encode_tensor(&page.k, &mut self.k, &mut self.k_scales, dh, valid);
        encode_tensor(&page.v, &mut self.v, &mut self.v_scales, dh, valid);
    }

    /// Dequantize the whole page into `page` (all `PAGE_SLOTS` slots; slots
    /// beyond the sequence length decode to zeros from [`Self::encode`]).
    pub fn decode_into(&self, page: &mut Page) {
        let heads = self.heads();
        let dh = page.k.len() / (heads * PAGE_SLOTS);
        for h in 0..heads {
            let lo = h * PAGE_SLOTS * dh;
            let hi = (h + 1) * PAGE_SLOTS * dh;
            let (ks, vs) = (self.k_scales[h], self.v_scales[h]);
            for (o, &q) in page.k[lo..hi].iter_mut().zip(&self.k[lo..hi]) {
                *o = q as f32 * ks;
            }
            for (o, &q) in page.v[lo..hi].iter_mut().zip(&self.v[lo..hi]) {
                *o = q as f32 * vs;
            }
        }
    }

    /// Dequantize `out.len()` K elements starting at flat offset `src`. The
    /// run must lie within head `head`'s region (the cache's copy loops are
    /// per-head, so this always holds).
    pub fn k_run_into(&self, head: usize, src: usize, out: &mut [f32]) {
        let s = self.k_scales[head];
        for (o, &q) in out.iter_mut().zip(&self.k[src..src + out.len()]) {
            *o = q as f32 * s;
        }
    }

    /// Dequantize `out.len()` V elements starting at flat offset `src`.
    pub fn v_run_into(&self, head: usize, src: usize, out: &mut [f32]) {
        let s = self.v_scales[head];
        for (o, &q) in out.iter_mut().zip(&self.v[src..src + out.len()]) {
            *o = q as f32 * s;
        }
    }
}

/// Quantize one head-major tensor: per head, symmetric-absmax scale over
/// the first `valid` elements of the head's run, int8 encode, zero-fill the
/// (never read) junk tail so recycled garbage can neither inflate the scale
/// nor survive a whole-page decode.
fn encode_tensor(src: &[f32], dst: &mut [i8], scales: &mut [f32], dh: usize, valid: usize) {
    for (h, scale) in scales.iter_mut().enumerate() {
        let run = &src[h * PAGE_SLOTS * dh..(h + 1) * PAGE_SLOTS * dh];
        let out = &mut dst[h * PAGE_SLOTS * dh..(h + 1) * PAGE_SLOTS * dh];
        let absmax = run[..valid].iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        *scale = absmax / 127.0;
        let inv = if *scale > 0.0 { 1.0 / *scale } else { 0.0 };
        for (o, &x) in out[..valid].iter_mut().zip(&run[..valid]) {
            *o = (x * inv).round().clamp(-127.0, 127.0) as i8;
        }
        out[valid..].fill(0);
    }
}

/// Precision-tagged page payload: what the pool actually stores and what
/// every [`super::KvCache`] page entry holds. Hot pages are `F32`; the
/// demotion policy rewrites cold pages as `Q8` (and the prefix tree freezes
/// snapshots directly to `Q8`). All mutation paths re-materialize `F32`
/// first — a quantized page is never written in place.
pub enum PageData {
    F32(Page),
    Q8(QuantPage),
}

impl PageData {
    pub fn precision(&self) -> Precision {
        match self {
            PageData::F32(_) => Precision::F32,
            PageData::Q8(_) => Precision::Q8,
        }
    }

    /// Actual bytes held by this page at the given row width.
    pub fn bytes(&self, row_width: usize) -> usize {
        match self {
            PageData::F32(_) => Page::bytes(row_width),
            PageData::Q8(q) => QuantPage::bytes_for(row_width, q.heads()),
        }
    }

    pub fn as_f32(&self) -> Option<&Page> {
        match self {
            PageData::F32(p) => Some(p),
            PageData::Q8(_) => None,
        }
    }

    /// The f32 payload; panics on a quantized page — callers must promote
    /// (dequantize into a fresh f32 page) before touching bytes in place.
    pub fn expect_f32(&self) -> &Page {
        match self {
            PageData::F32(p) => p,
            PageData::Q8(_) => panic!("expected f32 page, found Q8 (promote before writing)"),
        }
    }

    /// Mutable f32 payload; panics on a quantized page (see
    /// [`Self::expect_f32`] — no quantized page is ever written in place).
    pub fn expect_f32_mut(&mut self) -> &mut Page {
        match self {
            PageData::F32(p) => p,
            PageData::Q8(_) => panic!("expected f32 page, found Q8 (promote before writing)"),
        }
    }
}

impl From<Page> for PageData {
    fn from(p: Page) -> Self {
        PageData::F32(p)
    }
}

impl From<QuantPage> for PageData {
    fn from(q: QuantPage) -> Self {
        PageData::Q8(q)
    }
}

#[derive(Default)]
struct Pool {
    /// Free pages keyed by `(row_width, precision)`, recycled across
    /// sequences. Separate keys per precision keep the byte accounting of
    /// mixed pools exact (a pooled Q8 page is ~4x smaller than a pooled f32
    /// page of the same row width).
    free: BTreeMap<(usize, Precision), Vec<PageData>>,
    bytes_in_use: usize,
    bytes_pooled: usize,
    high_water: usize,
    budget: Option<usize>,
    pages_allocated: u64,
    pool_hits: u64,
    pages_freed: u64,
    cow_copies: u64,
    /// Live Q8 pages / their bytes / the f32 bytes they replace.
    quant_pages: usize,
    quant_bytes: usize,
    quant_fp32_equiv: usize,
}

/// Cheaply cloneable handle to a shared page pool.
#[derive(Clone, Default)]
pub struct KvArena {
    pool: Arc<Mutex<Pool>>,
}

/// Point-in-time arena occupancy (the admission-control signal) plus
/// cumulative pool-churn counters (exported in `op:stats` so bench records
/// can correlate prefix reuse with real page traffic).
#[derive(Clone, Copy, Debug, Default)]
pub struct ArenaStats {
    /// Bytes currently held by live caches (shared pages count once,
    /// however many readers pin them). Mixed-precision: Q8 pages contribute
    /// their actual (compressed) size.
    pub bytes_in_use: usize,
    /// Bytes parked on the free lists, ready for reuse.
    pub bytes_pooled: usize,
    /// Peak `bytes_in_use` observed over the process lifetime.
    pub high_water: usize,
    /// Configured pool budget (None = unlimited).
    pub budget: Option<usize>,
    /// Pages currently parked on the free lists (gauge form of
    /// `bytes_pooled`, across `(row_width, precision)` keys).
    pub pages_pooled: usize,
    /// Total page allocations served (pool recycles + fresh constructions).
    pub pages_allocated: u64,
    /// Allocations served by recycling a pooled page of the same
    /// `(row_width, precision)` instead of constructing a fresh one.
    pub pool_hits: u64,
    /// Pages returned to the free lists.
    pub pages_freed: u64,
    /// Copy-on-write materializations: a shared page was about to be
    /// mutated and a private copy was allocated instead.
    pub cow_copies: u64,
    /// Live quantized (Q8) pages across all caches and frozen snapshots.
    pub quant_pages: usize,
    /// Bytes held by live Q8 pages (subset of `bytes_in_use`).
    pub quant_bytes: usize,
    /// Bytes held by live f32 pages (`bytes_in_use - quant_bytes`).
    pub fp32_bytes: usize,
    /// f32 bytes the live Q8 pages replace divided by their actual bytes
    /// (~4 at steady state; 0.0 when nothing is quantized).
    pub quant_compaction_ratio: f64,
}

impl KvArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-wide arena every [`super::KvCache::new`] draws from.
    pub fn global() -> &'static KvArena {
        static GLOBAL: OnceLock<KvArena> = OnceLock::new();
        GLOBAL.get_or_init(KvArena::new)
    }

    /// Cap `bytes_in_use` (None = unlimited). Existing allocations persist;
    /// only future allocations are checked.
    pub fn set_budget(&self, budget: Option<usize>) {
        super::error::lock_recover(&self.pool, "kv arena pool").budget = budget;
    }

    pub fn stats(&self) -> ArenaStats {
        let p = super::error::lock_recover(&self.pool, "kv arena pool");
        ArenaStats {
            bytes_in_use: p.bytes_in_use,
            bytes_pooled: p.bytes_pooled,
            high_water: p.high_water,
            budget: p.budget,
            pages_pooled: p.free.values().map(|v| v.len()).sum(),
            pages_allocated: p.pages_allocated,
            pool_hits: p.pool_hits,
            pages_freed: p.pages_freed,
            cow_copies: p.cow_copies,
            quant_pages: p.quant_pages,
            quant_bytes: p.quant_bytes,
            fp32_bytes: p.bytes_in_use.saturating_sub(p.quant_bytes),
            quant_compaction_ratio: if p.quant_bytes > 0 {
                p.quant_fp32_equiv as f64 / p.quant_bytes as f64
            } else {
                0.0
            },
        }
    }

    /// Allocate one f32 page (recycled from the free list when possible).
    /// Fails with [`ARENA_OOM_MARKER`] when the pool budget would be
    /// exceeded.
    pub fn alloc(&self, row_width: usize) -> Result<Page> {
        let bytes = Page::bytes(row_width);
        let mut p = super::error::lock_recover(&self.pool, "kv arena pool");
        if let Some(limit) = p.budget {
            if p.bytes_in_use + bytes > limit {
                bail!(
                    "{ARENA_OOM_MARKER}: page alloc {bytes} B would exceed pool budget \
                     {limit} B ({} B in use)",
                    p.bytes_in_use
                );
            }
        }
        let page = match p.free.get_mut(&(row_width, Precision::F32)).and_then(|v| v.pop()) {
            Some(PageData::F32(page)) => {
                p.bytes_pooled -= bytes;
                p.pool_hits += 1;
                page
            }
            Some(PageData::Q8(_)) => unreachable!("f32 free list holds only f32 pages"),
            None => Page::new(row_width),
        };
        p.pages_allocated += 1;
        p.bytes_in_use += bytes;
        p.high_water = p.high_water.max(p.bytes_in_use);
        Ok(page)
    }

    /// Allocate one Q8 page (recycled when possible). `checked` gates the
    /// budget test: demotion passes `false` — replacing a live f32 page
    /// with its Q8 form shrinks net usage, so it must not fail at the very
    /// moment the pool is full — while clone/fork paths pass `true` and can
    /// OOM like any other growth.
    pub fn alloc_q8(&self, row_width: usize, heads: usize, checked: bool) -> Result<QuantPage> {
        let bytes = QuantPage::bytes_for(row_width, heads);
        let mut p = super::error::lock_recover(&self.pool, "kv arena pool");
        if checked {
            if let Some(limit) = p.budget {
                if p.bytes_in_use + bytes > limit {
                    bail!(
                        "{ARENA_OOM_MARKER}: q8 page alloc {bytes} B would exceed pool budget \
                         {limit} B ({} B in use)",
                        p.bytes_in_use
                    );
                }
            }
        }
        let page = match p.free.get_mut(&(row_width, Precision::Q8)).and_then(|v| v.pop()) {
            Some(PageData::Q8(mut q)) => {
                // Pooled Q8 pages of this row width may carry a different
                // head count (different scale-vector length => different
                // byte size): credit what was parked, reshape, charge the
                // requested shape.
                p.bytes_pooled -= QuantPage::bytes_for(row_width, q.heads());
                p.pool_hits += 1;
                q.k_scales.resize(heads, 0.0);
                q.v_scales.resize(heads, 0.0);
                q
            }
            Some(PageData::F32(_)) => unreachable!("q8 free list holds only q8 pages"),
            None => QuantPage::new(row_width, heads),
        };
        p.pages_allocated += 1;
        p.bytes_in_use += bytes;
        p.quant_pages += 1;
        p.quant_bytes += bytes;
        p.quant_fp32_equiv += Page::bytes(row_width);
        p.high_water = p.high_water.max(p.bytes_in_use);
        Ok(page)
    }

    /// Return a page (either precision) to its free list for reuse.
    pub fn free(&self, row_width: usize, page: PageData) {
        let bytes = page.bytes(row_width);
        let precision = page.precision();
        let mut p = super::error::lock_recover(&self.pool, "kv arena pool");
        p.bytes_in_use = p.bytes_in_use.saturating_sub(bytes);
        p.bytes_pooled += bytes;
        p.pages_freed += 1;
        if precision == Precision::Q8 {
            p.quant_pages = p.quant_pages.saturating_sub(1);
            p.quant_bytes = p.quant_bytes.saturating_sub(bytes);
            p.quant_fp32_equiv = p.quant_fp32_equiv.saturating_sub(Page::bytes(row_width));
        }
        p.free.entry((row_width, precision)).or_default().push(page);
    }

    /// Record one copy-on-write materialization (a shared page was about to
    /// be mutated; [`super::KvCache`] allocated a private copy instead).
    pub fn note_cow(&self) {
        super::error::lock_recover(&self.pool, "kv arena pool").cow_copies += 1;
    }
}

/// A frozen, immutable arena page shared by multiple readers: the
/// cross-request prefix tree pins one handle per leaf page, and every
/// [`super::KvCache`] that adopted the prefix holds handles to the same
/// pages. The bytes were charged once at allocation and are freed exactly
/// once — when the LAST handle drops, the page returns to the pool.
#[derive(Clone)]
pub struct SharedPage {
    inner: Arc<SharedInner>,
}

struct SharedInner {
    /// `None` only after [`SharedPage::try_unshare`] reclaimed the page.
    page: Option<PageData>,
    row_width: usize,
    arena: KvArena,
}

impl Drop for SharedInner {
    fn drop(&mut self) {
        if let Some(page) = self.page.take() {
            self.arena.free(self.row_width, page);
        }
    }
}

impl SharedPage {
    /// Freeze an owned page (either precision). No bytes move and no
    /// accounting changes: the page stays `bytes_in_use` until the last
    /// handle drops.
    pub fn freeze(arena: KvArena, row_width: usize, page: PageData) -> Self {
        Self { inner: Arc::new(SharedInner { page: Some(page), row_width, arena }) }
    }

    /// The frozen page contents (valid until the last handle drops).
    pub fn page(&self) -> &PageData {
        self.inner.page.as_ref().expect("shared page present until last drop")
    }

    /// Floats per slot row (`H * Dh`) — the arena pooling key.
    pub fn row_width(&self) -> usize {
        self.inner.row_width
    }

    /// Actual bytes this frozen page holds (precision-aware).
    pub fn bytes(&self) -> usize {
        self.page().bytes(self.inner.row_width)
    }

    /// Handles currently pinning this page (prefix-tree leaves + caches).
    pub fn readers(&self) -> usize {
        Arc::strong_count(&self.inner)
    }

    /// Reclaim sole ownership without copying: succeeds iff this handle is
    /// the last reader, in which case the page moves back out un-shared
    /// (accounting unchanged — it stays in use). Otherwise the handle is
    /// returned and the caller must copy (the CoW path).
    pub fn try_unshare(self) -> Result<PageData, SharedPage> {
        match Arc::try_unwrap(self.inner) {
            Ok(mut inner) => Ok(inner.page.take().expect("page present until last drop")),
            Err(inner) => Err(SharedPage { inner }),
        }
    }
}

/// Page-granular worst-case footprint of one sequence holding `slots` slots
/// in every one of `n_layers` layers at row width `H * Dh`, all at f32 (the
/// quantization-off projection).
pub fn seq_footprint_bytes(n_layers: usize, row_width: usize, slots: usize) -> usize {
    n_layers * slots.div_ceil(PAGE_SLOTS) * Page::bytes(row_width)
}

/// Mixed-precision footprint under cold-Q8 demotion: the first `fp32_slots`
/// slots' worth of pages (attention sinks + the hot tail + demotion lag)
/// stay f32; everything older is Q8. This is the admission projection when
/// `--kv-quant cold-q8` is active — actual bytes, not logical f32 bytes.
pub fn seq_footprint_bytes_mixed(
    n_layers: usize,
    row_width: usize,
    heads: usize,
    slots: usize,
    fp32_slots: usize,
) -> usize {
    let total_pages = slots.div_ceil(PAGE_SLOTS);
    let fp32_pages = fp32_slots.min(slots).div_ceil(PAGE_SLOTS).min(total_pages);
    let q8_pages = total_pages - fp32_pages;
    n_layers
        * (fp32_pages * Page::bytes(row_width)
            + q8_pages * QuantPage::bytes_for(row_width, heads))
}

/// Shared admission gate (server + benches): measured arena pressure plus
/// staging-tier bytes (device-resident K/V images + host scratch images,
/// which exist per hot sequence and back-pressure intake instead of OOMing
/// the device) plus one projected footprint must fit the budget, AND
/// reserving the peak footprint for every already-admitted sequence (which
/// may not have allocated its pages yet) must still fit alongside
/// `prefix_bytes` — the pages pinned by the cross-request prefix tree,
/// which belong to no active sequence (they are already inside
/// `bytes_in_use`, so only the reservation term adds them).
pub fn admission_ok(
    stats: &ArenaStats,
    active: usize,
    est_seq_bytes: usize,
    limit: usize,
    staging_bytes: usize,
    prefix_bytes: usize,
) -> bool {
    let reserved = (active + 1).saturating_mul(est_seq_bytes);
    stats.bytes_in_use + staging_bytes + est_seq_bytes <= limit
        && reserved.saturating_add(prefix_bytes) <= limit
}

/// Per-shard staging pressure folded into the single `staging_bytes` number
/// [`admission_ok`] counts. `staged[i]` is shard `i`'s measured staging
/// bytes (device tier + scratch pool) and `caps[i]` its physical ceiling
/// (residency slice + scratch worst case); `projected_total` is the
/// admission projection for the whole hot set ((active+1) dense images).
///
/// Each shard contributes `max(measured, its even share of the projection)`
/// clamped to its own cap — so an oversubscribed shard cannot borrow
/// headroom from an idle one, and no shard is ever charged beyond what its
/// tiers can physically hold (LRU evicts the rest). With one shard this
/// reduces exactly to the pre-sharding formula
/// `max(measured, min(projected, cap))` (both clamp at the same cap).
pub fn sharded_staging_bytes(staged: &[usize], caps: &[usize], projected_total: usize) -> usize {
    if staged.is_empty() {
        return projected_total;
    }
    let share = projected_total.div_ceil(staged.len());
    staged.iter().zip(caps).map(|(&s, &cap)| s.max(share).min(cap)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_accounting_and_reuse() {
        let arena = KvArena::new();
        let rw = 8;
        let a = arena.alloc(rw).unwrap();
        let b = arena.alloc(rw).unwrap();
        assert_eq!(arena.stats().bytes_in_use, 2 * Page::bytes(rw));
        arena.free(rw, a.into());
        let st = arena.stats();
        assert_eq!(st.bytes_in_use, Page::bytes(rw));
        assert_eq!(st.bytes_pooled, Page::bytes(rw));
        assert_eq!(st.high_water, 2 * Page::bytes(rw));
        // reuse drains the free list instead of growing the pool
        let c = arena.alloc(rw).unwrap();
        let st = arena.stats();
        assert_eq!(st.bytes_pooled, 0);
        assert_eq!(st.bytes_in_use, 2 * Page::bytes(rw));
        arena.free(rw, b.into());
        arena.free(rw, c.into());
        assert_eq!(arena.stats().bytes_in_use, 0);
    }

    #[test]
    fn budget_rejects_with_marker() {
        let arena = KvArena::new();
        let rw = 4;
        arena.set_budget(Some(Page::bytes(rw)));
        let a = arena.alloc(rw).unwrap();
        let err = arena.alloc(rw).unwrap_err();
        assert!(format!("{err}").contains(ARENA_OOM_MARKER), "{err}");
        // freeing makes room again
        arena.free(rw, a.into());
        arena.alloc(rw).unwrap();
    }

    #[test]
    fn admission_gate_and_footprint() {
        let est = seq_footprint_bytes(2, 8, 17); // 17 slots -> 2 pages, x2 layers
        assert_eq!(est, 2 * 2 * Page::bytes(8));
        let empty = ArenaStats::default();
        assert!(admission_ok(&empty, 0, est, est, 0, 0));
        // one active sequence reserves its footprint even before allocating
        assert!(!admission_ok(&empty, 1, est, est, 0, 0));
        assert!(admission_ok(&empty, 1, est, 2 * est, 0, 0));
        let loaded = ArenaStats { bytes_in_use: est, ..Default::default() };
        assert!(!admission_ok(&loaded, 0, est, est, 0, 0));
        // staging bytes (device-resident images + scratch pool) count like
        // arena pressure: a full device tier back-pressures intake
        assert!(admission_ok(&empty, 0, est, 2 * est, est, 0));
        assert!(!admission_ok(&empty, 0, est, 2 * est, est + 1, 0));
        // prefix-pinned pages join the reservation term: worst-case
        // per-sequence footprints must coexist with the pinned tree
        assert!(admission_ok(&empty, 1, est, 2 * est, 0, 0));
        assert!(!admission_ok(&empty, 1, est, 2 * est, 0, 1));
        assert!(admission_ok(&empty, 1, est, 3 * est, 0, est));
    }

    #[test]
    fn mixed_footprint_interpolates_between_precisions() {
        let (l, rw, h) = (2, 8, 2);
        // all slots hot => identical to the f32 projection
        assert_eq!(seq_footprint_bytes_mixed(l, rw, h, 40, 40), seq_footprint_bytes(l, rw, 40));
        assert_eq!(seq_footprint_bytes_mixed(l, rw, h, 40, 999), seq_footprint_bytes(l, rw, 40));
        // no slots hot => every page at the Q8 rate
        assert_eq!(
            seq_footprint_bytes_mixed(l, rw, h, 40, 0),
            l * 3 * QuantPage::bytes_for(rw, h)
        );
        // mixed: 1 hot page + 2 cold pages per layer
        assert_eq!(
            seq_footprint_bytes_mixed(l, rw, h, 40, PAGE_SLOTS),
            l * (Page::bytes(rw) + 2 * QuantPage::bytes_for(rw, h))
        );
        // Q8 pages are ~4x smaller: 4 Q8 pages cost one f32 page plus
        // exactly their scale vectors (2 tensors x h heads x 4 bytes each)
        assert_eq!(4 * QuantPage::bytes_for(rw, h), Page::bytes(rw) + 4 * (2 * h * 4));
    }

    #[test]
    fn sharded_staging_reduces_to_single_tier_formula() {
        // one shard: identical to max(measured, min(projected, cap))
        for (measured, cap, proj) in
            [(0usize, 100usize, 40usize), (70, 100, 40), (10, 100, 250), (90, 100, 250)]
        {
            assert_eq!(
                sharded_staging_bytes(&[measured], &[cap], proj),
                measured.max(proj.min(cap)),
                "single-shard equivalence for measured={measured} cap={cap} proj={proj}"
            );
        }
    }

    #[test]
    fn sharded_staging_isolates_per_shard_budgets() {
        // an oversubscribed shard cannot borrow the idle shard's headroom:
        // each shard is charged at least its projection share
        let staged = [100usize, 0];
        let caps = [100usize, 100];
        assert_eq!(sharded_staging_bytes(&staged, &caps, 80), 140, "100 (full) + 40 (share)");
        // ...and never beyond its own physical cap
        assert_eq!(sharded_staging_bytes(&staged, &caps, 400), 200, "both clamp at their cap");
        // empty topology degrades to the raw projection (no caps known)
        assert_eq!(sharded_staging_bytes(&[], &[], 64), 64);
    }

    #[test]
    fn shared_page_frees_once_on_last_drop() {
        let arena = KvArena::new();
        let rw = 8;
        let page = arena.alloc(rw).unwrap();
        let sp = SharedPage::freeze(arena.clone(), rw, page.into());
        assert_eq!(sp.row_width(), rw);
        assert_eq!(sp.bytes(), Page::bytes(rw));
        assert_eq!(arena.stats().bytes_in_use, Page::bytes(rw), "freeze keeps bytes charged");
        let sp2 = sp.clone();
        assert_eq!(sp2.readers(), 2);
        drop(sp);
        let st = arena.stats();
        assert_eq!(st.bytes_in_use, Page::bytes(rw), "live reader keeps the page");
        assert_eq!(st.pages_freed, 0);
        drop(sp2);
        let st = arena.stats();
        assert_eq!(st.bytes_in_use, 0, "last drop returns the page");
        assert_eq!(st.bytes_pooled, Page::bytes(rw));
        assert_eq!(st.pages_freed, 1);
    }

    #[test]
    fn shared_page_sole_reader_unshares_without_copy() {
        let arena = KvArena::new();
        let rw = 4;
        let mut page = arena.alloc(rw).unwrap();
        page.k[0] = 7.0;
        let sp = SharedPage::freeze(arena.clone(), rw, page.into());
        let sp2 = sp.clone();
        // two readers: un-sharing must fail and hand the handle back
        let sp2 = match sp2.try_unshare() {
            Err(handle) => handle,
            Ok(_) => panic!("two readers cannot unshare"),
        };
        drop(sp2);
        // sole reader: the page moves back out, no alloc/free churn
        let before = arena.stats();
        let page = match sp.try_unshare() {
            Ok(page) => page,
            Err(_) => panic!("sole reader reclaims"),
        };
        assert_eq!(page.expect_f32().k[0], 7.0);
        let st = arena.stats();
        assert_eq!(st.bytes_in_use, before.bytes_in_use);
        assert_eq!(st.pages_allocated, before.pages_allocated);
        assert_eq!(st.pages_freed, before.pages_freed);
        arena.free(rw, page);
        assert_eq!(arena.stats().bytes_in_use, 0);
    }

    #[test]
    fn pool_counters_track_alloc_free_churn() {
        let arena = KvArena::new();
        let rw = 4;
        let a = arena.alloc(rw).unwrap();
        let st = arena.stats();
        assert_eq!((st.pages_allocated, st.pool_hits, st.pages_freed), (1, 0, 0));
        assert_eq!(st.pages_pooled, 0);
        arena.free(rw, a.into());
        let st = arena.stats();
        assert_eq!(st.pages_freed, 1);
        assert_eq!(st.pages_pooled, 1);
        // the next alloc recycles the pooled page
        let b = arena.alloc(rw).unwrap();
        let st = arena.stats();
        assert_eq!((st.pages_allocated, st.pool_hits), (2, 1));
        assert_eq!(st.pages_pooled, 0);
        arena.note_cow();
        assert_eq!(arena.stats().cow_copies, 1);
        arena.free(rw, b.into());
    }

    #[test]
    fn row_widths_pool_independently() {
        let arena = KvArena::new();
        let a = arena.alloc(4).unwrap();
        arena.free(4, a.into());
        // a different row width must not receive the pooled page
        let b = arena.alloc(8).unwrap();
        assert_eq!(b.k.len(), PAGE_SLOTS * 8);
        assert_eq!(arena.stats().bytes_pooled, Page::bytes(4));
    }

    #[test]
    fn precisions_pool_independently() {
        let arena = KvArena::new();
        let (rw, h) = (8, 2);
        let a = arena.alloc(rw).unwrap();
        arena.free(rw, a.into());
        // a Q8 request at the same row width must not receive the f32 page
        let q = arena.alloc_q8(rw, h, true).unwrap();
        let st = arena.stats();
        assert_eq!(st.pool_hits, 0, "pooled f32 page is not a q8 hit");
        assert_eq!(st.bytes_pooled, Page::bytes(rw));
        assert_eq!(st.bytes_in_use, QuantPage::bytes_for(rw, h));
        // ...and vice versa: a freed q8 page only serves q8 requests
        arena.free(rw, q.into());
        let b = arena.alloc(rw).unwrap();
        let st = arena.stats();
        assert_eq!(st.pool_hits, 1, "the f32 page parked above is recycled");
        assert_eq!(st.bytes_pooled, QuantPage::bytes_for(rw, h));
        let q2 = arena.alloc_q8(rw, h, true).unwrap();
        assert_eq!(arena.stats().pool_hits, 2, "the q8 page is recycled for q8");
        arena.free(rw, b.into());
        arena.free(rw, q2.into());
    }

    #[test]
    fn quant_gauges_and_compaction_ratio() {
        let arena = KvArena::new();
        let (rw, h) = (8, 2);
        let f = arena.alloc(rw).unwrap();
        let q = arena.alloc_q8(rw, h, true).unwrap();
        let st = arena.stats();
        assert_eq!(st.quant_pages, 1);
        assert_eq!(st.quant_bytes, QuantPage::bytes_for(rw, h));
        assert_eq!(st.fp32_bytes, Page::bytes(rw));
        assert_eq!(st.bytes_in_use, st.quant_bytes + st.fp32_bytes);
        let ratio = Page::bytes(rw) as f64 / QuantPage::bytes_for(rw, h) as f64;
        assert!((st.quant_compaction_ratio - ratio).abs() < 1e-9);
        arena.free(rw, q.into());
        let st = arena.stats();
        assert_eq!((st.quant_pages, st.quant_bytes), (0, 0));
        assert_eq!(st.quant_compaction_ratio, 0.0);
        arena.free(rw, f.into());
    }

    #[test]
    fn q8_budget_check_only_when_asked() {
        let arena = KvArena::new();
        let (rw, h) = (8, 2);
        arena.set_budget(Some(Page::bytes(rw)));
        let a = arena.alloc(rw).unwrap();
        // checked q8 alloc fails like any other growth...
        let err = arena.alloc_q8(rw, h, true).unwrap_err();
        assert!(format!("{err}").contains(ARENA_OOM_MARKER), "{err}");
        // ...but the demotion path (unchecked) succeeds even at the limit:
        // the f32 page it replaces frees right after, shrinking net usage
        let q = arena.alloc_q8(rw, h, false).unwrap();
        arena.free(rw, a.into());
        assert!(arena.stats().bytes_in_use <= Page::bytes(rw));
        arena.free(rw, q.into());
    }

    #[test]
    fn quantize_roundtrip_exact_for_representable_values() {
        let arena = KvArena::new();
        let (rw, h) = (8, 2); // dh = 4
        let mut page = arena.alloc(rw).unwrap();
        // values that are exact multiples of absmax/127 survive the
        // round-trip bit-exactly (q = round(x/s) lands on an integer):
        // every head run here spans the full integer range [-127, 127], so
        // absmax = 127 => scale 1.0 and q = x for every element
        for (i, x) in page.k.iter_mut().enumerate() {
            *x = ((i * 3) % 255) as f32 - 127.0;
        }
        for (i, x) in page.v.iter_mut().enumerate() {
            *x = -((i % 64) as f32) * 2.0; // absmax 126 => scale 126/127
        }
        let mut q = arena.alloc_q8(rw, h, true).unwrap();
        q.encode(&page, PAGE_SLOTS);
        let mut back = arena.alloc(rw).unwrap();
        q.decode_into(&mut back);
        assert_eq!(page.k, back.k);
        for (a, b) in page.v.iter().zip(&back.v) {
            assert!((a - b).abs() <= q.v_scales[0].max(q.v_scales[1]) / 2.0 + 1e-6, "{a} {b}");
        }
        arena.free(rw, page.into());
        arena.free(rw, back.into());
        arena.free(rw, q.into());
    }

    #[test]
    fn quantize_excludes_junk_slots_from_scale() {
        let arena = KvArena::new();
        let (rw, h) = (4, 1); // dh = 4, one head
        let mut page = arena.alloc(rw).unwrap();
        page.k.fill(1.0);
        // slots >= 2 hold recycled junk with a huge magnitude; with
        // valid_slots = 2 it must not inflate the scale
        for x in page.k[2 * 4..].iter_mut() {
            *x = 1.0e6;
        }
        let mut q = arena.alloc_q8(rw, h, true).unwrap();
        q.encode(&page, 2);
        assert!((q.k_scales[0] - 1.0 / 127.0).abs() < 1e-9, "scale from valid slots only");
        let mut out = [0.0f32; 4];
        q.k_run_into(0, 0, &mut out);
        for x in out {
            assert!((x - 1.0).abs() < 1e-5);
        }
        // junk region decodes to zeros, not garbage
        q.k_run_into(0, 3 * 4, &mut out);
        assert_eq!(out, [0.0; 4]);
        arena.free(rw, page.into());
        arena.free(rw, q.into());
    }
}
