//! Shared paged-KV arena: a process-wide pool of fixed-size pages backing
//! every [`super::KvCache`].
//!
//! A sequence's resident bytes track its *actual* occupancy (`lens` rounded
//! up to the page size) instead of the compiled capacity `C`; freed pages
//! return to the pool and are recycled across sequences, so concurrent
//! serving pays for what the ladder policy actually keeps — the block/paged
//! KV management idea from vLLM-style serving stacks, applied under the
//! paper's compaction policies.
//!
//! The pool is keyed by row width (`H * Dh`) so models of different shapes
//! can share one process-wide arena. An optional byte budget turns the
//! arena into the serving-path admission signal: allocations that would
//! exceed it fail with [`ARENA_OOM_MARKER`], and the scheduler consults
//! [`KvArena::stats`] before admitting new sequences.
//!
//! Pages can also be **frozen** into refcounted [`SharedPage`]s (the
//! cross-request prefix cache pins them, and every cache that adopts a
//! prefix holds handles to the same pages): the bytes stay charged exactly
//! once and return to the pool only when the LAST reader drops. Mutation of
//! a shared page is copy-on-write, performed by [`super::KvCache`] and
//! counted in [`ArenaStats::cow_copies`].

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Result};

/// Slots per page. 16 rows amortizes page-table overhead while keeping
/// per-sequence over-allocation below one page per layer.
pub const PAGE_SLOTS: usize = 16;

/// Raised (string-matched, like the engine's simulated-OOM marker) when an
/// allocation would push the pool past its byte budget.
pub const ARENA_OOM_MARKER: &str = "kv-arena-OOM";

/// One page: `PAGE_SLOTS` KV rows for one layer, stored **head-major**
/// `[H, PAGE_SLOTS, Dh]` — one head's slots are contiguous, matching the
/// device-contiguous `[L, H, C, Dh]` image layout so gather/scatter move
/// whole `PAGE_SLOTS * Dh` runs per head (16x fewer copies than the
/// slot-major layout's `Dh` fragments). Compaction relocates a slot with
/// one `Dh`-sized move per head (see `KvCache::retain_slots`).
pub struct Page {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl Page {
    fn new(row_width: usize) -> Self {
        Page { k: vec![0.0; PAGE_SLOTS * row_width], v: vec![0.0; PAGE_SLOTS * row_width] }
    }

    /// Bytes held by one page of the given row width (K + V, f32).
    pub fn bytes(row_width: usize) -> usize {
        2 * PAGE_SLOTS * row_width * 4
    }
}

#[derive(Default)]
struct Pool {
    /// Free pages keyed by row width (`H * Dh`), recycled across sequences.
    free: BTreeMap<usize, Vec<Page>>,
    bytes_in_use: usize,
    bytes_pooled: usize,
    high_water: usize,
    budget: Option<usize>,
    pages_allocated: u64,
    pool_hits: u64,
    pages_freed: u64,
    cow_copies: u64,
}

/// Cheaply cloneable handle to a shared page pool.
#[derive(Clone, Default)]
pub struct KvArena {
    pool: Arc<Mutex<Pool>>,
}

/// Point-in-time arena occupancy (the admission-control signal) plus
/// cumulative pool-churn counters (exported in `op:stats` so bench records
/// can correlate prefix reuse with real page traffic).
#[derive(Clone, Copy, Debug, Default)]
pub struct ArenaStats {
    /// Bytes currently held by live caches (shared pages count once,
    /// however many readers pin them).
    pub bytes_in_use: usize,
    /// Bytes parked on the free lists, ready for reuse.
    pub bytes_pooled: usize,
    /// Peak `bytes_in_use` observed over the process lifetime.
    pub high_water: usize,
    /// Configured pool budget (None = unlimited).
    pub budget: Option<usize>,
    /// Pages currently parked on the free lists (gauge form of
    /// `bytes_pooled`, across row widths).
    pub pages_pooled: usize,
    /// Total page allocations served (pool recycles + fresh constructions).
    pub pages_allocated: u64,
    /// Allocations served by recycling a pooled page instead of
    /// constructing a fresh one.
    pub pool_hits: u64,
    /// Pages returned to the free lists.
    pub pages_freed: u64,
    /// Copy-on-write materializations: a shared page was about to be
    /// mutated and a private copy was allocated instead.
    pub cow_copies: u64,
}

impl KvArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-wide arena every [`super::KvCache::new`] draws from.
    pub fn global() -> &'static KvArena {
        static GLOBAL: OnceLock<KvArena> = OnceLock::new();
        GLOBAL.get_or_init(KvArena::new)
    }

    /// Cap `bytes_in_use` (None = unlimited). Existing allocations persist;
    /// only future allocations are checked.
    pub fn set_budget(&self, budget: Option<usize>) {
        super::error::lock_recover(&self.pool, "kv arena pool").budget = budget;
    }

    pub fn stats(&self) -> ArenaStats {
        let p = super::error::lock_recover(&self.pool, "kv arena pool");
        ArenaStats {
            bytes_in_use: p.bytes_in_use,
            bytes_pooled: p.bytes_pooled,
            high_water: p.high_water,
            budget: p.budget,
            pages_pooled: p.free.values().map(|v| v.len()).sum(),
            pages_allocated: p.pages_allocated,
            pool_hits: p.pool_hits,
            pages_freed: p.pages_freed,
            cow_copies: p.cow_copies,
        }
    }

    /// Allocate one page (recycled from the free list when possible). Fails
    /// with [`ARENA_OOM_MARKER`] when the pool budget would be exceeded.
    pub fn alloc(&self, row_width: usize) -> Result<Page> {
        let bytes = Page::bytes(row_width);
        let mut p = super::error::lock_recover(&self.pool, "kv arena pool");
        if let Some(limit) = p.budget {
            if p.bytes_in_use + bytes > limit {
                bail!(
                    "{ARENA_OOM_MARKER}: page alloc {bytes} B would exceed pool budget \
                     {limit} B ({} B in use)",
                    p.bytes_in_use
                );
            }
        }
        let page = match p.free.get_mut(&row_width).and_then(|v| v.pop()) {
            Some(page) => {
                p.bytes_pooled -= bytes;
                p.pool_hits += 1;
                page
            }
            None => Page::new(row_width),
        };
        p.pages_allocated += 1;
        p.bytes_in_use += bytes;
        p.high_water = p.high_water.max(p.bytes_in_use);
        Ok(page)
    }

    /// Return a page to the free list for reuse.
    pub fn free(&self, row_width: usize, page: Page) {
        let bytes = Page::bytes(row_width);
        let mut p = super::error::lock_recover(&self.pool, "kv arena pool");
        p.bytes_in_use = p.bytes_in_use.saturating_sub(bytes);
        p.bytes_pooled += bytes;
        p.pages_freed += 1;
        p.free.entry(row_width).or_default().push(page);
    }

    /// Record one copy-on-write materialization (a shared page was about to
    /// be mutated; [`super::KvCache`] allocated a private copy instead).
    pub fn note_cow(&self) {
        super::error::lock_recover(&self.pool, "kv arena pool").cow_copies += 1;
    }
}

/// A frozen, immutable arena page shared by multiple readers: the
/// cross-request prefix tree pins one handle per leaf page, and every
/// [`super::KvCache`] that adopted the prefix holds handles to the same
/// pages. The bytes were charged once at allocation and are freed exactly
/// once — when the LAST handle drops, the page returns to the pool.
#[derive(Clone)]
pub struct SharedPage {
    inner: Arc<SharedInner>,
}

struct SharedInner {
    /// `None` only after [`SharedPage::try_unshare`] reclaimed the page.
    page: Option<Page>,
    row_width: usize,
    arena: KvArena,
}

impl Drop for SharedInner {
    fn drop(&mut self) {
        if let Some(page) = self.page.take() {
            self.arena.free(self.row_width, page);
        }
    }
}

impl SharedPage {
    /// Freeze an owned page. No bytes move and no accounting changes: the
    /// page stays `bytes_in_use` until the last handle drops.
    pub fn freeze(arena: KvArena, row_width: usize, page: Page) -> Self {
        Self { inner: Arc::new(SharedInner { page: Some(page), row_width, arena }) }
    }

    /// The frozen page contents (valid until the last handle drops).
    pub fn page(&self) -> &Page {
        self.inner.page.as_ref().expect("shared page present until last drop")
    }

    /// Floats per slot row (`H * Dh`) — the arena pooling key.
    pub fn row_width(&self) -> usize {
        self.inner.row_width
    }

    /// Handles currently pinning this page (prefix-tree leaves + caches).
    pub fn readers(&self) -> usize {
        Arc::strong_count(&self.inner)
    }

    /// Reclaim sole ownership without copying: succeeds iff this handle is
    /// the last reader, in which case the page moves back out un-shared
    /// (accounting unchanged — it stays in use). Otherwise the handle is
    /// returned and the caller must copy (the CoW path).
    pub fn try_unshare(self) -> Result<Page, SharedPage> {
        match Arc::try_unwrap(self.inner) {
            Ok(mut inner) => Ok(inner.page.take().expect("page present until last drop")),
            Err(inner) => Err(SharedPage { inner }),
        }
    }
}

/// Page-granular worst-case footprint of one sequence holding `slots` slots
/// in every one of `n_layers` layers at row width `H * Dh`.
pub fn seq_footprint_bytes(n_layers: usize, row_width: usize, slots: usize) -> usize {
    n_layers * slots.div_ceil(PAGE_SLOTS) * Page::bytes(row_width)
}

/// Shared admission gate (server + benches): measured arena pressure plus
/// staging-tier bytes (device-resident K/V images + host scratch images,
/// which exist per hot sequence and back-pressure intake instead of OOMing
/// the device) plus one projected footprint must fit the budget, AND
/// reserving the peak footprint for every already-admitted sequence (which
/// may not have allocated its pages yet) must still fit alongside
/// `prefix_bytes` — the pages pinned by the cross-request prefix tree,
/// which belong to no active sequence (they are already inside
/// `bytes_in_use`, so only the reservation term adds them).
pub fn admission_ok(
    stats: &ArenaStats,
    active: usize,
    est_seq_bytes: usize,
    limit: usize,
    staging_bytes: usize,
    prefix_bytes: usize,
) -> bool {
    let reserved = (active + 1).saturating_mul(est_seq_bytes);
    stats.bytes_in_use + staging_bytes + est_seq_bytes <= limit
        && reserved.saturating_add(prefix_bytes) <= limit
}

/// Per-shard staging pressure folded into the single `staging_bytes` number
/// [`admission_ok`] counts. `staged[i]` is shard `i`'s measured staging
/// bytes (device tier + scratch pool) and `caps[i]` its physical ceiling
/// (residency slice + scratch worst case); `projected_total` is the
/// admission projection for the whole hot set ((active+1) dense images).
///
/// Each shard contributes `max(measured, its even share of the projection)`
/// clamped to its own cap — so an oversubscribed shard cannot borrow
/// headroom from an idle one, and no shard is ever charged beyond what its
/// tiers can physically hold (LRU evicts the rest). With one shard this
/// reduces exactly to the pre-sharding formula
/// `max(measured, min(projected, cap))` (both clamp at the same cap).
pub fn sharded_staging_bytes(staged: &[usize], caps: &[usize], projected_total: usize) -> usize {
    if staged.is_empty() {
        return projected_total;
    }
    let share = projected_total.div_ceil(staged.len());
    staged.iter().zip(caps).map(|(&s, &cap)| s.max(share).min(cap)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_accounting_and_reuse() {
        let arena = KvArena::new();
        let rw = 8;
        let a = arena.alloc(rw).unwrap();
        let b = arena.alloc(rw).unwrap();
        assert_eq!(arena.stats().bytes_in_use, 2 * Page::bytes(rw));
        arena.free(rw, a);
        let st = arena.stats();
        assert_eq!(st.bytes_in_use, Page::bytes(rw));
        assert_eq!(st.bytes_pooled, Page::bytes(rw));
        assert_eq!(st.high_water, 2 * Page::bytes(rw));
        // reuse drains the free list instead of growing the pool
        let c = arena.alloc(rw).unwrap();
        let st = arena.stats();
        assert_eq!(st.bytes_pooled, 0);
        assert_eq!(st.bytes_in_use, 2 * Page::bytes(rw));
        arena.free(rw, b);
        arena.free(rw, c);
        assert_eq!(arena.stats().bytes_in_use, 0);
    }

    #[test]
    fn budget_rejects_with_marker() {
        let arena = KvArena::new();
        let rw = 4;
        arena.set_budget(Some(Page::bytes(rw)));
        let a = arena.alloc(rw).unwrap();
        let err = arena.alloc(rw).unwrap_err();
        assert!(format!("{err}").contains(ARENA_OOM_MARKER), "{err}");
        // freeing makes room again
        arena.free(rw, a);
        arena.alloc(rw).unwrap();
    }

    #[test]
    fn admission_gate_and_footprint() {
        let est = seq_footprint_bytes(2, 8, 17); // 17 slots -> 2 pages, x2 layers
        assert_eq!(est, 2 * 2 * Page::bytes(8));
        let empty = ArenaStats::default();
        assert!(admission_ok(&empty, 0, est, est, 0, 0));
        // one active sequence reserves its footprint even before allocating
        assert!(!admission_ok(&empty, 1, est, est, 0, 0));
        assert!(admission_ok(&empty, 1, est, 2 * est, 0, 0));
        let loaded = ArenaStats { bytes_in_use: est, ..Default::default() };
        assert!(!admission_ok(&loaded, 0, est, est, 0, 0));
        // staging bytes (device-resident images + scratch pool) count like
        // arena pressure: a full device tier back-pressures intake
        assert!(admission_ok(&empty, 0, est, 2 * est, est, 0));
        assert!(!admission_ok(&empty, 0, est, 2 * est, est + 1, 0));
        // prefix-pinned pages join the reservation term: worst-case
        // per-sequence footprints must coexist with the pinned tree
        assert!(admission_ok(&empty, 1, est, 2 * est, 0, 0));
        assert!(!admission_ok(&empty, 1, est, 2 * est, 0, 1));
        assert!(admission_ok(&empty, 1, est, 3 * est, 0, est));
    }

    #[test]
    fn sharded_staging_reduces_to_single_tier_formula() {
        // one shard: identical to max(measured, min(projected, cap))
        for (measured, cap, proj) in
            [(0usize, 100usize, 40usize), (70, 100, 40), (10, 100, 250), (90, 100, 250)]
        {
            assert_eq!(
                sharded_staging_bytes(&[measured], &[cap], proj),
                measured.max(proj.min(cap)),
                "single-shard equivalence for measured={measured} cap={cap} proj={proj}"
            );
        }
    }

    #[test]
    fn sharded_staging_isolates_per_shard_budgets() {
        // an oversubscribed shard cannot borrow the idle shard's headroom:
        // each shard is charged at least its projection share
        let staged = [100usize, 0];
        let caps = [100usize, 100];
        assert_eq!(sharded_staging_bytes(&staged, &caps, 80), 140, "100 (full) + 40 (share)");
        // ...and never beyond its own physical cap
        assert_eq!(sharded_staging_bytes(&staged, &caps, 400), 200, "both clamp at their cap");
        // empty topology degrades to the raw projection (no caps known)
        assert_eq!(sharded_staging_bytes(&[], &[], 64), 64);
    }

    #[test]
    fn shared_page_frees_once_on_last_drop() {
        let arena = KvArena::new();
        let rw = 8;
        let page = arena.alloc(rw).unwrap();
        let sp = SharedPage::freeze(arena.clone(), rw, page);
        assert_eq!(sp.row_width(), rw);
        assert_eq!(arena.stats().bytes_in_use, Page::bytes(rw), "freeze keeps bytes charged");
        let sp2 = sp.clone();
        assert_eq!(sp2.readers(), 2);
        drop(sp);
        let st = arena.stats();
        assert_eq!(st.bytes_in_use, Page::bytes(rw), "live reader keeps the page");
        assert_eq!(st.pages_freed, 0);
        drop(sp2);
        let st = arena.stats();
        assert_eq!(st.bytes_in_use, 0, "last drop returns the page");
        assert_eq!(st.bytes_pooled, Page::bytes(rw));
        assert_eq!(st.pages_freed, 1);
    }

    #[test]
    fn shared_page_sole_reader_unshares_without_copy() {
        let arena = KvArena::new();
        let rw = 4;
        let mut page = arena.alloc(rw).unwrap();
        page.k[0] = 7.0;
        let sp = SharedPage::freeze(arena.clone(), rw, page);
        let sp2 = sp.clone();
        // two readers: un-sharing must fail and hand the handle back
        let sp2 = match sp2.try_unshare() {
            Err(handle) => handle,
            Ok(_) => panic!("two readers cannot unshare"),
        };
        drop(sp2);
        // sole reader: the page moves back out, no alloc/free churn
        let before = arena.stats();
        let page = match sp.try_unshare() {
            Ok(page) => page,
            Err(_) => panic!("sole reader reclaims"),
        };
        assert_eq!(page.k[0], 7.0);
        let st = arena.stats();
        assert_eq!(st.bytes_in_use, before.bytes_in_use);
        assert_eq!(st.pages_allocated, before.pages_allocated);
        assert_eq!(st.pages_freed, before.pages_freed);
        arena.free(rw, page);
        assert_eq!(arena.stats().bytes_in_use, 0);
    }

    #[test]
    fn pool_counters_track_alloc_free_churn() {
        let arena = KvArena::new();
        let rw = 4;
        let a = arena.alloc(rw).unwrap();
        let st = arena.stats();
        assert_eq!((st.pages_allocated, st.pool_hits, st.pages_freed), (1, 0, 0));
        assert_eq!(st.pages_pooled, 0);
        arena.free(rw, a);
        let st = arena.stats();
        assert_eq!(st.pages_freed, 1);
        assert_eq!(st.pages_pooled, 1);
        // the next alloc recycles the pooled page
        let b = arena.alloc(rw).unwrap();
        let st = arena.stats();
        assert_eq!((st.pages_allocated, st.pool_hits), (2, 1));
        assert_eq!(st.pages_pooled, 0);
        arena.note_cow();
        assert_eq!(arena.stats().cow_copies, 1);
        arena.free(rw, b);
    }

    #[test]
    fn row_widths_pool_independently() {
        let arena = KvArena::new();
        let a = arena.alloc(4).unwrap();
        arena.free(4, a);
        // a different row width must not receive the pooled page
        let b = arena.alloc(8).unwrap();
        assert_eq!(b.k.len(), PAGE_SLOTS * 8);
        assert_eq!(arena.stats().bytes_pooled, Page::bytes(4));
    }
}
