//! Shard placement policy: which [`DeviceShard`](crate::runtime::Runtime)
//! a sequence is admitted onto.
//!
//! Placement is decided once, at admission, from a point-in-time load
//! snapshot of every shard ([`ShardLoad`]). The policy is two-level:
//!
//! 1. **Prefix-local first.** If the radix prefix tree holds a snapshot for
//!    the sequence's deepest prompt-prefix match, prefer that snapshot's
//!    *home shard* — the shard whose residency tier and scratch pool already
//!    serve that KV state — so a hot shared system prompt is served from one
//!    shard instead of being duplicated N times. When the home shard is
//!    unserviceable (degraded, or a zero-byte residency slice) the sequence
//!    spills to another shard by load and the caller must **cold prefill**
//!    there: snapshots are never migrated across devices implicitly, only
//!    counted ([`PlacementKind::Spillover`]).
//! 2. **Least-loaded-bytes otherwise.** No prefix preference → the
//!    serviceable shard with the fewest device-resident bytes wins (ties
//!    broken by in-flight calls, then by the lowest shard index, so
//!    placement is deterministic for a given snapshot).
//!
//! If *every* shard is degraded or capacity-less the sequence is still
//! assigned a shard — calls must route through some executor lane — but the
//! decision is reported as [`PlacementKind::HostOnly`]: each tier is in its
//! degraded bypass, so K/V state stays host-side and no residency is
//! expected.

/// Point-in-time load snapshot of one shard, fed to [`place`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardLoad {
    /// Device ordinal backing this shard.
    pub device: usize,
    /// Device-resident K/V bytes currently held by the shard's tier.
    pub resident_bytes: usize,
    /// Calls in flight on the shard's executor lane.
    pub inflight: usize,
    /// Sticky per-shard degraded flag (tier bypasses residency).
    pub degraded: bool,
    /// The shard's `device_pool_bytes` slice; 0 means the shard can hold no
    /// resident image and is skipped by placement.
    pub capacity_bytes: usize,
}

impl ShardLoad {
    fn serviceable(&self) -> bool {
        !self.degraded && self.capacity_bytes > 0
    }
}

/// Why a sequence landed on its shard (drives the `placement_*` counters).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementKind {
    /// The preferred (prefix-home) shard was healthy: the snapshot is
    /// adopted where it lives.
    LocalPrefix,
    /// No prefix preference — the least-loaded-bytes shard won.
    LeastLoaded,
    /// A prefix-home shard existed but was unserviceable: placed elsewhere
    /// by load, and the caller must cold-prefill instead of migrating the
    /// snapshot cross-device.
    Spillover,
    /// Every shard is degraded or capacity-less: a shard is still named
    /// (calls route somewhere) but residency is host-only.
    HostOnly,
}

impl PlacementKind {
    /// Dense code carried as the flight recorder's `placed` event payload
    /// (`b` field): 0 local-prefix, 1 least-loaded, 2 spillover, 3 host-only.
    pub fn code(self) -> i64 {
        match self {
            PlacementKind::LocalPrefix => 0,
            PlacementKind::LeastLoaded => 1,
            PlacementKind::Spillover => 2,
            PlacementKind::HostOnly => 3,
        }
    }
}

/// A placement decision: shard index plus the rule that produced it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    /// Index into the `loads` slice passed to [`place`].
    pub shard: usize,
    pub kind: PlacementKind,
}

/// Decide the shard for one sequence. `preferred` is the home shard of the
/// deepest prefix-tree match, if any. Never fails: with no serviceable
/// shard the least-loaded shard overall is named with
/// [`PlacementKind::HostOnly`] (an empty `loads` slice yields shard 0,
/// which callers with at least one shard never observe).
pub fn place(loads: &[ShardLoad], preferred: Option<usize>) -> Placement {
    if let Some(p) = preferred {
        if loads.get(p).map(ShardLoad::serviceable).unwrap_or(false) {
            return Placement { shard: p, kind: PlacementKind::LocalPrefix };
        }
    }
    if let Some(shard) = least_loaded(loads, true) {
        let kind =
            if preferred.is_some() { PlacementKind::Spillover } else { PlacementKind::LeastLoaded };
        return Placement { shard, kind };
    }
    let shard = least_loaded(loads, false).unwrap_or(0);
    Placement { shard, kind: PlacementKind::HostOnly }
}

/// Lowest `(resident_bytes, inflight, index)` shard, optionally restricted
/// to serviceable shards.
fn least_loaded(loads: &[ShardLoad], serviceable_only: bool) -> Option<usize> {
    loads
        .iter()
        .enumerate()
        .filter(|(_, l)| !serviceable_only || l.serviceable())
        .min_by_key(|&(i, l)| (l.resident_bytes, l.inflight, i))
        .map(|(i, _)| i)
}

/// Running totals of placement decisions, exported as `op:stats` counters
/// (`placement_local_prefix`, `placement_spillover`, ...).
#[derive(Clone, Copy, Debug, Default)]
pub struct PlacementStats {
    /// Sequences placed on their prefix snapshot's home shard.
    pub local_prefix: u64,
    /// Sequences placed purely by least-loaded-bytes.
    pub least_loaded: u64,
    /// Cross-shard snapshot migrations *avoided*: the home shard was
    /// unserviceable, so the sequence cold-prefilled elsewhere.
    pub spillover: u64,
    /// Placements made with every shard degraded or capacity-less.
    pub host_only: u64,
}

impl PlacementStats {
    pub fn note(&mut self, kind: PlacementKind) {
        match kind {
            PlacementKind::LocalPrefix => self.local_prefix += 1,
            PlacementKind::LeastLoaded => self.least_loaded += 1,
            PlacementKind::Spillover => self.spillover += 1,
            PlacementKind::HostOnly => self.host_only += 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(device: usize, resident: usize, cap: usize, degraded: bool) -> ShardLoad {
        ShardLoad {
            device,
            resident_bytes: resident,
            inflight: 0,
            degraded,
            capacity_bytes: cap,
        }
    }

    #[test]
    fn least_loaded_bytes_wins_without_preference() {
        let loads = [shard(0, 900, 1024, false), shard(1, 100, 1024, false)];
        assert_eq!(
            place(&loads, None),
            Placement { shard: 1, kind: PlacementKind::LeastLoaded }
        );
    }

    #[test]
    fn ties_break_by_inflight_then_index() {
        let mut loads = [shard(0, 64, 1024, false), shard(1, 64, 1024, false)];
        assert_eq!(place(&loads, None).shard, 0, "equal load resolves to the lowest index");
        loads[0].inflight = 3;
        assert_eq!(place(&loads, None).shard, 1, "in-flight calls break byte ties");
    }

    #[test]
    fn healthy_home_shard_is_preferred_over_load() {
        // shard 1 holds the prefix snapshot; it is busier but still wins
        let loads = [shard(0, 0, 1024, false), shard(1, 1000, 1024, false)];
        assert_eq!(
            place(&loads, Some(1)),
            Placement { shard: 1, kind: PlacementKind::LocalPrefix }
        );
    }

    #[test]
    fn zero_capacity_shard_is_skipped() {
        // shard 0 has no residency slice: never placed on, even when idle
        let loads = [shard(0, 0, 0, false), shard(1, 500, 1024, false)];
        assert_eq!(
            place(&loads, None),
            Placement { shard: 1, kind: PlacementKind::LeastLoaded }
        );
        // ... including as a prefix home: spill, don't migrate
        let p = place(&loads, Some(0));
        assert_eq!(p, Placement { shard: 1, kind: PlacementKind::Spillover });
    }

    #[test]
    fn degraded_home_shard_spills_without_migration() {
        let loads = [shard(0, 0, 1024, true), shard(1, 500, 1024, false)];
        let p = place(&loads, Some(0));
        assert_eq!(p, Placement { shard: 1, kind: PlacementKind::Spillover });
    }

    #[test]
    fn all_shards_degraded_falls_back_to_host_only() {
        let loads = [shard(0, 700, 1024, true), shard(1, 300, 1024, true)];
        let p = place(&loads, None);
        assert_eq!(p.kind, PlacementKind::HostOnly);
        assert_eq!(p.shard, 1, "host-only still routes by least resident bytes");
        // a prefix preference cannot resurrect a degraded home shard
        assert_eq!(place(&loads, Some(0)).kind, PlacementKind::HostOnly);
    }

    #[test]
    fn single_shard_degenerates_to_shard_zero() {
        let loads = [shard(0, 0, 1024, false)];
        for preferred in [None, Some(0), Some(9)] {
            assert_eq!(place(&loads, preferred).shard, 0);
        }
        assert_eq!(place(&loads, Some(0)).kind, PlacementKind::LocalPrefix);
        assert_eq!(place(&loads, None).kind, PlacementKind::LeastLoaded);
    }

    #[test]
    fn out_of_range_preference_is_ignored() {
        let loads = [shard(0, 10, 1024, false), shard(1, 0, 1024, false)];
        let p = place(&loads, Some(7));
        assert_eq!(p.shard, 1, "stale home shard index falls back to load placement");
        assert_eq!(p.kind, PlacementKind::Spillover);
    }

    #[test]
    fn stats_note_buckets_by_kind() {
        let mut s = PlacementStats::default();
        s.note(PlacementKind::LocalPrefix);
        s.note(PlacementKind::LocalPrefix);
        s.note(PlacementKind::Spillover);
        s.note(PlacementKind::LeastLoaded);
        s.note(PlacementKind::HostOnly);
        assert_eq!(
            (s.local_prefix, s.least_loaded, s.spillover, s.host_only),
            (2, 1, 1, 1)
        );
    }
}
