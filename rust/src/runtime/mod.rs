//! PJRT runtime: loads AOT artifacts (HLO text) once, compiles them on the
//! CPU PJRT client, and exposes typed `score` / `generate` calls over
//! on-device buffers. Python never runs here — the rust binary is
//! self-contained after `make artifacts`.
//!
//! Wiring follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute_b`.
//! Weights are uploaded to the device once per model. Per call, the paged KV
//! store reaches the device through a three-tier residency path (see
//! [`device::DeviceTier`] and PERF.md "Device residency"):
//!
//! - **device-hit** — the sequence's K/V image is already resident
//!   ([`DeviceKvState`], stamped `(id, sync_gen)`): only dirty slot ranges
//!   are uploaded over it, and generate calls donate the buffers to the
//!   program (`execute_with_donation`), downloading just the appended rows —
//!   steady-state decode moves tokens and lens, not the cache;
//! - **host-hit** — no resident buffers, but the [`transfer::ScratchPool`]
//!   (now the spill tier) holds a stamped host image: incremental gather,
//!   one full upload, promotion;
//! - **cold** — full gather, full upload, promotion.
//!
//! Residency is capacity-bounded with cost-aware spill-to-scratch, and
//! everything is accounted in [`RuntimeStats`] (`bytes_h2d` / `bytes_d2h` /
//! `device_resident_bytes` / `residency_hits` / `spills` / `donations`),
//! which the serving admission gate and `op:stats` consume.

pub mod arena;
pub mod device;
pub mod error;
pub mod executor;
pub mod kv;
pub mod manifest;
pub mod prefix;
pub mod transfer;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

pub use arena::{
    admission_ok, seq_footprint_bytes, ArenaStats, KvArena, Page, SharedPage, ARENA_OOM_MARKER,
    PAGE_SLOTS,
};
pub use device::{Acquired, DeviceKvState, DeviceStats, DeviceTier};
pub use error::{classify, lock_poisoned_total, lock_recover, CallError, CallErrorKind};
pub use executor::{CallExecutor, Completion};
pub use kv::{GatherBytes, KvCache};
pub use manifest::{Manifest, ModelCfg, ProgKind, ProgMeta};
pub use prefix::{PrefixCache, PrefixSnapshot, PrefixStats};
pub use transfer::{DenseImage, ScratchPool, TransferStats};

/// Wrap a device-call stage failure with its classified [`CallErrorKind`]
/// (downcast if already typed, marker strings otherwise), so every error
/// leaving `score`/`generate`/upload/download paths carries the taxonomy.
fn classify_call(stage: &str, e: anyhow::Error) -> anyhow::Error {
    let kind = classify(&e);
    CallError::new(kind, format!("{stage}: {e:#}"))
}

/// Knobs for the runtime's staging tiers (serving exposes them through
/// `ServeConfig`; the defaults here serve the CLI/eval paths).
#[derive(Clone, Debug)]
pub struct RuntimeOpts {
    /// Dense scratch images the transfer layer keeps warm (LRU) — one per
    /// sequence in the serving hot set; clamped to >= 1 (the gather path
    /// always needs one staging image). A sequence beyond this pays one
    /// full re-gather when it rotates back in.
    pub scratch_pool_entries: usize,
    /// Byte capacity of the device-residency tier (K + V across resident
    /// sequences). 0 disables residency: every call re-uploads its image,
    /// the pre-residency behavior.
    pub device_pool_bytes: usize,
}

impl Default for RuntimeOpts {
    fn default() -> Self {
        Self { scratch_pool_entries: 16, device_pool_bytes: 256 << 20 }
    }
}

/// Cumulative runtime counters (per process) for the perf log. The transfer
/// and residency fields are folded in from the staging tiers by
/// [`Runtime::stats`].
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub calls: u64,
    pub compile_s: f64,
    /// Host->device upload time (includes the host-side gather; `gather_s`
    /// isolates that part).
    pub upload_s: f64,
    pub execute_s: f64,
    pub download_s: f64,
    /// Bytes uploaded host->device across all calls (call inputs + full
    /// image uploads + dirty-range reconciles).
    pub bytes_h2d: u64,
    /// Bytes downloaded device->host across all calls (call outputs +
    /// residency spills).
    pub bytes_d2h: u64,
    /// Host-side gather wall-clock (pages -> dense scratch image).
    pub gather_s: f64,
    /// Bytes written into scratch images (dirty copies + zero-fill) — the
    /// number the incremental path drives toward zero per decode step.
    pub gathered_bytes: u64,
    pub gathers_full: u64,
    pub gathers_incremental: u64,
    pub gathers_noop: u64,
    /// Dense-buffer allocations by the transfer layer (zero after warmup).
    pub dense_scratch_allocs: u64,
    /// Host bytes currently pooled as scratch images (staging memory that
    /// the admission gate counts; bounded by the pool's entry cap).
    pub scratch_resident_bytes: u64,
    /// Bytes currently resident in the device tier (K + V across entries) —
    /// counted by the admission gate alongside arena pages.
    pub device_resident_bytes: u64,
    /// Calls served by a resident device image (no full upload).
    pub residency_hits: u64,
    /// Calls that uploaded a full image (cold, post-spill, or stale stamp).
    pub residency_misses: u64,
    /// Spills from the device tier (image read back to scratch).
    pub spills: u64,
    /// Generate calls that donated resident buffers to the program and kept
    /// the output state on-device.
    pub donations: u64,
    /// Bytes uploaded by dirty-range reconciliation over resident images
    /// (the device-hit path's only KV upload traffic).
    pub reconciled_bytes: u64,
    /// Whether the device tier is in sticky degraded mode (repeated
    /// retryable call failures): residency is bypassed and every call
    /// serves via the host/scratch path until restart.
    pub device_degraded: bool,
    /// Consecutive retryable device-call failures (resets on success;
    /// flipping the tier degraded at the threshold).
    pub device_failures: u64,
    /// Poisoned-mutex recoveries by [`lock_recover`] (process-wide).
    pub lock_poisoned: u64,
}

/// Reusable per-call buffers (padded token/target windows, i32 lens, f32
/// staging for appended-row downloads): steady-state calls allocate nothing
/// here.
#[derive(Default)]
struct CallBuf {
    tok: Vec<i32>,
    tgt: Vec<i32>,
    lens: Vec<i32>,
    stage_k: Vec<f32>,
    stage_v: Vec<f32>,
}

pub struct LoadedModel {
    pub name: String,
    pub cfg: ModelCfg,
    pub n_params: usize,
    weights: xla::PjRtBuffer,
    #[allow(dead_code)]
    entry: manifest::ModelEntry,
    exes: Mutex<BTreeMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

/// The runtime is `Sync`: interior state lives behind `Mutex`es so in-flight
/// calls on [`executor::CallExecutor`] workers can share one `&Runtime`.
/// Lock-ordering rule for the staging tiers: **device before scratch** —
/// every path that holds both takes `device` first (or takes them in
/// disjoint scopes), so concurrent calls cannot deadlock.
pub struct Runtime {
    client: xla::PjRtClient,
    pub man: Manifest,
    models: BTreeMap<String, LoadedModel>,
    stats: Mutex<RuntimeStats>,
    /// Reusable dense K/V transfer images (dirty-range incremental gather);
    /// the spill tier under `device`.
    scratch: Mutex<ScratchPool>,
    /// Device-resident K/V images (the hot tier).
    device: Mutex<DeviceTier>,
    /// Reusable small i32 call buffers.
    call_buf: Mutex<CallBuf>,
    /// Simulated device-memory budget in bytes (None = unlimited). The
    /// engine consults this to reproduce the paper's OOM axis.
    pub memory_budget_bytes: Mutex<Option<usize>>,
}

/// Output of a score (teacher-forced window) call.
pub struct ScoreOut {
    /// Per-token logprob of the target, `[W]` (padding entries are garbage —
    /// the caller slices to `n_valid`).
    pub logprobs: Vec<f32>,
    /// Window keys `[L, H, W, Dh]`, pre-RoPE.
    pub win_k: Vec<f32>,
    /// Window values `[L, H, W, Dh]`.
    pub win_v: Vec<f32>,
    /// Per-slot attention mass `[L, C+W]` (scored programs only).
    pub mass: Option<Vec<f32>>,
}

/// Donated output buffers of a device-resident generate call: the K/V state
/// never left the device. Consumed by [`Runtime::absorb_generated`], which
/// downloads only the appended rows and re-installs the buffers as the
/// cache's resident image.
pub(crate) struct DeviceGenOut {
    pub k: xla::PjRtBuffer,
    pub v: xla::PjRtBuffer,
}

/// Output of a generate (greedy decode) call. On the host/transient path,
/// `k`/`v` hold the full downloaded state image `[L, H, C, Dh]`; on the
/// device-resident path they are EMPTY (the state stayed on the device,
/// `device` carries the donated output buffers). Either way,
/// [`Runtime::absorb_generated`] merges the appended rows into the host
/// cache and seeds the next call's image.
pub struct GenOut {
    pub tokens: Vec<i32>,
    pub last_logits: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub lens: Vec<i32>,
    /// Per-slot attention mass `[L, C]` (scored programs only).
    pub mass: Option<Vec<f32>>,
    pub(crate) device: Option<DeviceGenOut>,
}

impl Runtime {
    /// Load the manifest and the listed models with default staging-tier
    /// knobs (weights uploaded eagerly; program compilation is lazy, cached
    /// per program).
    pub fn load(dir: &Path, model_names: &[&str]) -> Result<Runtime> {
        Self::load_with(dir, model_names, RuntimeOpts::default())
    }

    /// [`Self::load`] with explicit staging-tier sizing (the serving path
    /// passes `ServeConfig.scratch_pool_entries` / `device_pool_bytes`).
    pub fn load_with(dir: &Path, model_names: &[&str], opts: RuntimeOpts) -> Result<Runtime> {
        let man = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut models = BTreeMap::new();
        for &name in model_names {
            let entry = man.model(name)?.clone();
            let bytes = std::fs::read(&entry.weights_path).with_context(|| {
                format!(
                    "reading weights {} (run `make artifacts` to train + lower)",
                    entry.weights_path.display()
                )
            })?;
            if bytes.len() != entry.n_params * 4 {
                bail!(
                    "weights size mismatch for {name}: {} bytes != {} params * 4",
                    bytes.len(),
                    entry.n_params
                );
            }
            let floats: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            let weights = client.buffer_from_host_buffer(&floats, &[entry.n_params], None)?;
            models.insert(
                name.to_string(),
                LoadedModel {
                    name: name.to_string(),
                    cfg: entry.cfg.clone(),
                    n_params: entry.n_params,
                    weights,
                    entry,
                    exes: Mutex::new(BTreeMap::new()),
                },
            );
        }
        Ok(Runtime {
            client,
            man,
            models,
            stats: Mutex::new(RuntimeStats::default()),
            scratch: Mutex::new(ScratchPool::new(opts.scratch_pool_entries)),
            device: Mutex::new(DeviceTier::new(opts.device_pool_bytes)),
            call_buf: Mutex::new(CallBuf::default()),
            memory_budget_bytes: Mutex::new(None),
        })
    }

    pub fn model(&self, name: &str) -> Result<&LoadedModel> {
        self.models.get(name).with_context(|| format!("model `{name}` not loaded"))
    }

    /// Runtime counters with the staging-tier stats folded in. Sweeps dead
    /// entries first, so the gauges never count dropped sequences.
    pub fn stats(&self) -> RuntimeStats {
        self.sweep_staging();
        let mut st = lock_recover(&self.stats, "runtime stats").clone();
        // scratch and device guards are taken in disjoint scopes (never
        // nested scratch->device, which would invert the lock order)
        {
            let pool = lock_recover(&self.scratch, "scratch pool");
            let ts = pool.stats();
            st.gather_s = ts.gather_s;
            st.gathered_bytes = ts.gathered_bytes + ts.zeroed_bytes;
            st.gathers_full = ts.gathers_full;
            st.gathers_incremental = ts.gathers_incremental;
            st.gathers_noop = ts.gathers_noop;
            st.dense_scratch_allocs = ts.dense_allocs;
            st.scratch_resident_bytes = pool.resident_bytes() as u64;
        }
        {
            let dev = lock_recover(&self.device, "device tier");
            let ds = dev.stats();
            st.bytes_h2d += ds.uploaded_bytes;
            st.bytes_d2h += ds.spill_bytes_d2h;
            st.device_resident_bytes = dev.resident_bytes() as u64;
            st.residency_hits = ds.hits;
            st.residency_misses = ds.misses;
            st.spills = ds.spills;
            st.donations = ds.donations;
            st.reconciled_bytes = ds.reconciled_bytes;
            st.device_degraded = dev.degraded();
            st.device_failures = ds.call_failures;
        }
        st.lock_poisoned = lock_poisoned_total();
        st
    }

    /// Raw transfer-layer counters (bench/diagnostic use).
    pub fn transfer_stats(&self) -> TransferStats {
        lock_recover(&self.scratch, "scratch pool").stats()
    }

    /// Raw residency-tier counters (bench/diagnostic use).
    pub fn device_stats(&self) -> DeviceStats {
        lock_recover(&self.device, "device tier").stats()
    }

    /// Whether the device tier has flipped into sticky degraded mode
    /// (served to load balancers via `op:ping`).
    pub fn device_degraded(&self) -> bool {
        lock_recover(&self.device, "device tier").degraded()
    }

    /// Drop staging entries (device tier + scratch pool) whose cache was
    /// dropped. Called before every stats read and admission decision, so a
    /// cancelled sequence's `device_resident_bytes` are gone before the next
    /// reactor round admits anyone.
    pub fn sweep_staging(&self) {
        lock_recover(&self.device, "device tier").sweep();
        lock_recover(&self.scratch, "scratch pool").sweep();
    }

    /// Host + device staging bytes currently held for live sequences — the
    /// footprint the serving admission gate counts alongside arena pages.
    pub fn staging_bytes(&self) -> usize {
        lock_recover(&self.device, "device tier").resident_bytes()
            + lock_recover(&self.scratch, "scratch pool").resident_bytes()
    }

    /// Deterministically release one cache's staging state (device buffers +
    /// scratch image) — the engine-reset / teardown path; dropped caches are
    /// also caught lazily by [`Self::sweep_staging`].
    pub fn release_cache_state(&self, cache_id: u64) {
        lock_recover(&self.device, "device tier").release(cache_id);
        lock_recover(&self.scratch, "scratch pool").release(cache_id);
    }

    /// Pre-compile a set of programs (avoids first-call latency in serving).
    pub fn warmup(&self, model: &str, prog_names: &[&str]) -> Result<()> {
        for p in prog_names {
            let meta = self.man.prog(model, p)?.clone();
            self.exe(model, &meta)?;
        }
        Ok(())
    }

    fn exe(&self, model: &str, prog: &ProgMeta) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let lm = self.model(model)?;
        if let Some(e) = lock_recover(&lm.exes, "model executables").get(&prog.name) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&prog.path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", prog.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {model}/{}: {e}", prog.name))?,
        );
        lock_recover(&self.stats, "runtime stats").compile_s += t0.elapsed().as_secs_f64();
        lock_recover(&lm.exes, "model executables").insert(prog.name.clone(), exe.clone());
        Ok(exe)
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| classify_call("upload", e.into()))
    }

    /// Teacher-forced scoring of `tokens` (with next-token `targets`) over
    /// the resident cache. `tokens.len()` may be shorter than the program
    /// window; inputs are padded and only valid logprobs are meaningful.
    /// Takes the cache mutably to advance its dirty-range sync point: on a
    /// device hit the call uploads only dirty slot ranges (tokens, targets
    /// and lens aside), otherwise it uploads one full image and promotes it
    /// into the residency tier.
    #[allow(clippy::too_many_arguments)]
    pub fn score(
        &self,
        model: &str,
        w: usize,
        c: usize,
        scored: bool,
        tokens: &[i32],
        targets: &[i32],
        cache: &mut KvCache,
    ) -> Result<ScoreOut> {
        let prog = self.man.score_prog(model, w, c, scored)?.clone();
        let exe = self.exe(model, &prog)?;
        let lm = self.model(model)?;
        let cfg = &lm.cfg;
        if tokens.len() > w || tokens.len() != targets.len() {
            bail!("score: bad window ({} tokens, prog w={w})", tokens.len());
        }
        if cache.c != c || cache.l != cfg.n_layers {
            bail!("score: cache shape mismatch (cache c={} prog c={c})", cache.c);
        }
        let l = cache.l;
        let t0 = Instant::now();
        let (tok_b, tgt_b, lens_b) = {
            // pad the token windows into the reusable call buffers
            let mut bufs = lock_recover(&self.call_buf, "call buffers");
            bufs.tok.clear();
            bufs.tok.extend_from_slice(tokens);
            bufs.tok.resize(w, 0);
            bufs.tgt.clear();
            bufs.tgt.extend_from_slice(targets);
            bufs.tgt.resize(w, 0);
            bufs.lens.clear();
            bufs.lens.extend(cache.lens.iter().map(|&x| x as i32));
            let tok_b = self.upload_i32(&bufs.tok, &[w])?;
            let tgt_b = self.upload_i32(&bufs.tgt, &[w])?;
            let lens_b = self.upload_i32(&bufs.lens, &[l])?;
            (tok_b, tgt_b, lens_b)
        };
        // three-tier K/V path: resident reconcile, or gather + upload +
        // promote (the tier accounts its own upload bytes; lock order is
        // device -> scratch, matching every other dual-guard path)
        let mut device = lock_recover(&self.device, "device tier");
        let acq = {
            let mut pool = lock_recover(&self.scratch, "scratch pool");
            device.sweep();
            pool.sweep();
            device
                .acquire(&self.client, cache, &mut pool)
                .map_err(|e| classify_call("upload", e))?
        };
        let (kc_b, vc_b): (&xla::PjRtBuffer, &xla::PjRtBuffer) = match &acq {
            Acquired::Resident => {
                let e = device.resident(cache.id()).expect("acquired entry present");
                (&e.k, &e.v)
            }
            Acquired::Transient(k, v) => (k, v),
        };
        let arg_refs: Vec<&xla::PjRtBuffer> =
            vec![&lm.weights, &tok_b, &tgt_b, kc_b, vc_b, &lens_b];
        let t1 = Instant::now();
        let exec_res = exe.execute_b(&arg_refs);
        let t2 = Instant::now();
        let out = match exec_res {
            Ok(o) => {
                device.note_call_success();
                o
            }
            Err(e) => {
                let err = classify_call("execute", e.into());
                if classify(&err).retryable() {
                    device.note_call_failure();
                }
                return Err(err.context(format!("score {model}/{}", prog.name)));
            }
        };
        let lit = out[0][0].to_literal_sync().map_err(|e| classify_call("download", e.into()))?;
        let mut parts = lit.to_tuple().map_err(|e| classify_call("download", e.into()))?;
        let t3 = Instant::now();
        let mass = if scored {
            Some(parts.pop().context("missing mass output")?.to_vec::<f32>()?)
        } else {
            None
        };
        let win_v = parts.pop().context("win_v")?.to_vec::<f32>()?;
        let win_k = parts.pop().context("win_k")?.to_vec::<f32>()?;
        let logprobs = parts.pop().context("logprobs")?.to_vec::<f32>()?;
        {
            let mut st = lock_recover(&self.stats, "runtime stats");
            st.calls += 1;
            st.upload_s += (t1 - t0).as_secs_f64();
            st.execute_s += (t2 - t1).as_secs_f64();
            st.download_s += (t3 - t2).as_secs_f64();
            // KV image bytes are accounted by the residency tier; only the
            // small call inputs are counted here
            st.bytes_h2d += 4 * (2 * w + l) as u64;
            let d2h = logprobs.len()
                + win_k.len()
                + win_v.len()
                + mass.as_ref().map_or(0, |m| m.len());
            st.bytes_d2h += 4 * d2h as u64;
        }
        Ok(ScoreOut { logprobs, win_k, win_v, mass })
    }

    /// Greedy decode of `k_steps` tokens; the device appends K/V in-graph,
    /// and the state merges back into the host cache via
    /// [`Runtime::absorb_generated`]. On a device hit the resident buffers
    /// are DONATED to the program and the output state stays on the device.
    pub fn generate(
        &self,
        model: &str,
        k_steps: usize,
        scored: bool,
        cache: &mut KvCache,
        last_token: i32,
    ) -> Result<GenOut> {
        self.generate_variant(model, k_steps, scored, false, cache, last_token)
    }

    /// Decode with explicit program-variant selection (`pallas = true` runs
    /// the interpret-mode Pallas-kernel artifact — numerics-identical to the
    /// fast path, used for kernel validation and the PERF.md comparison).
    pub fn generate_variant(
        &self,
        model: &str,
        k_steps: usize,
        scored: bool,
        pallas: bool,
        cache: &mut KvCache,
        last_token: i32,
    ) -> Result<GenOut> {
        let c = cache.c;
        let prog = if pallas {
            self.man.generate_pallas_prog(model, k_steps, c)?.clone()
        } else {
            self.man.generate_prog(model, k_steps, c, scored)?.clone()
        };
        let exe = self.exe(model, &prog)?;
        let lm = self.model(model)?;
        if cache.max_len() + k_steps > c {
            bail!(
                "generate: cache would overflow (len {} + k {} > C {})",
                cache.max_len(),
                k_steps,
                c
            );
        }
        let l = cache.l;
        let t0 = Instant::now();
        let (lens_b, tok_b) = {
            let mut bufs = lock_recover(&self.call_buf, "call buffers");
            bufs.lens.clear();
            bufs.lens.extend(cache.lens.iter().map(|&x| x as i32));
            let lens_b = self.upload_i32(&bufs.lens, &[l])?;
            let tok_b = self.upload_i32(&[last_token], &[])?;
            (lens_b, tok_b)
        };
        let mut device = lock_recover(&self.device, "device tier");
        let acq = {
            let mut pool = lock_recover(&self.scratch, "scratch pool");
            device.sweep();
            pool.sweep();
            device
                .acquire(&self.client, cache, &mut pool)
                .map_err(|e| classify_call("upload", e))?
        };
        match acq {
            Acquired::Resident => {
                // donation path: the program consumes the resident buffers
                // and appends in place; the output state never leaves the
                // device — only tokens/logits/lens (+ mass) come back
                let (kc_dev, vc_dev) = device.take(cache.id()).expect("acquired entry present");
                drop(device);
                let t1 = Instant::now();
                let exec_res = {
                    let arg_refs: Vec<&xla::PjRtBuffer> =
                        vec![&lm.weights, &kc_dev, &vc_dev, &lens_b, &tok_b];
                    // on error the donated state is lost either way: the
                    // entry is already out of the tier, host pages stay
                    // authoritative, and the next call re-promotes — this
                    // is the invariant the scheduler's rebuild-from-arena
                    // retry leans on
                    exe.execute_with_donation(&arg_refs, &[1, 2])
                };
                let out = match exec_res {
                    Ok(o) => {
                        lock_recover(&self.device, "device tier").note_call_success();
                        o
                    }
                    Err(e) => {
                        let err = classify_call("execute", e.into());
                        if classify(&err).retryable() {
                            lock_recover(&self.device, "device tier").note_call_failure();
                        }
                        return Err(
                            err.context(format!("execute(donated) {model}/{}", prog.name))
                        );
                    }
                };
                let t2 = Instant::now();
                let mut leaves = out.into_iter().next().context("empty execution result")?;
                // leaf order mirrors the tupled path: tokens, last_logits,
                // kcache, vcache, lens [, mass]
                let mass = if scored {
                    let b = leaves.pop().context("mass")?;
                    Some(b.to_literal_sync()?.to_vec::<f32>()?)
                } else {
                    None
                };
                let lens_out = leaves.pop().context("lens")?;
                let vc_out = leaves.pop().context("vcache")?;
                let kc_out = leaves.pop().context("kcache")?;
                let logits_out = leaves.pop().context("last_logits")?;
                let tokens_out = leaves.pop().context("tokens")?;
                let tokens = tokens_out.to_literal_sync()?.to_vec::<i32>()?;
                let last_logits = logits_out.to_literal_sync()?.to_vec::<f32>()?;
                let lens = lens_out.to_literal_sync()?.to_vec::<i32>()?;
                let t3 = Instant::now();
                {
                    let mut st = lock_recover(&self.stats, "runtime stats");
                    st.calls += 1;
                    st.upload_s += (t1 - t0).as_secs_f64();
                    st.execute_s += (t2 - t1).as_secs_f64();
                    st.download_s += (t3 - t2).as_secs_f64();
                    st.bytes_h2d += 4 * (l + 1) as u64;
                    let d2h = tokens.len()
                        + last_logits.len()
                        + lens.len()
                        + mass.as_ref().map_or(0, |m| m.len());
                    st.bytes_d2h += 4 * d2h as u64;
                }
                Ok(GenOut {
                    tokens,
                    last_logits,
                    k: Vec::new(),
                    v: Vec::new(),
                    lens,
                    mass,
                    device: Some(DeviceGenOut { k: kc_out, v: vc_out }),
                })
            }
            Acquired::Transient(kc_b, vc_b) => {
                drop(device);
                let arg_refs: Vec<&xla::PjRtBuffer> =
                    vec![&lm.weights, &kc_b, &vc_b, &lens_b, &tok_b];
                let t1 = Instant::now();
                let exec_res = exe.execute_b(&arg_refs);
                let t2 = Instant::now();
                let out = match exec_res {
                    Ok(o) => {
                        lock_recover(&self.device, "device tier").note_call_success();
                        o
                    }
                    Err(e) => {
                        let err = classify_call("execute", e.into());
                        if classify(&err).retryable() {
                            lock_recover(&self.device, "device tier").note_call_failure();
                        }
                        return Err(err.context(format!("execute {model}/{}", prog.name)));
                    }
                };
                let lit =
                    out[0][0].to_literal_sync().map_err(|e| classify_call("download", e.into()))?;
                let mut parts = lit.to_tuple().map_err(|e| classify_call("download", e.into()))?;
                let t3 = Instant::now();
                let mass = if scored {
                    Some(parts.pop().context("mass")?.to_vec::<f32>()?)
                } else {
                    None
                };
                let lens = parts.pop().context("lens")?.to_vec::<i32>()?;
                let v = parts.pop().context("vcache")?.to_vec::<f32>()?;
                let k = parts.pop().context("kcache")?.to_vec::<f32>()?;
                let last_logits = parts.pop().context("last_logits")?.to_vec::<f32>()?;
                let tokens = parts.pop().context("tokens")?.to_vec::<i32>()?;
                {
                    let mut st = lock_recover(&self.stats, "runtime stats");
                    st.calls += 1;
                    st.upload_s += (t1 - t0).as_secs_f64();
                    st.execute_s += (t2 - t1).as_secs_f64();
                    st.download_s += (t3 - t2).as_secs_f64();
                    st.bytes_h2d += 4 * (l + 1) as u64;
                    let d2h = last_logits.len()
                        + k.len()
                        + v.len()
                        + mass.as_ref().map_or(0, |m| m.len());
                    st.bytes_d2h += 4 * (d2h + tokens.len() + lens.len()) as u64;
                }
                Ok(GenOut { tokens, last_logits, k, v, lens, mass, device: None })
            }
        }
    }

    /// Merge a generate call's output state into `cache` and seed the next
    /// call's image.
    ///
    /// **Device-resident path** (`go.device` set): only the `appended` rows
    /// are downloaded from the donated output buffers (one contiguous run
    /// per (layer, head)) and appended to the host pages; the buffers are
    /// then re-installed as the cache's resident image
    /// ([`DeviceTier::install_absorbed`]) — resident rows passed through the
    /// program unchanged, the appended rows were just merged, padding stays
    /// zero, so the buffers *are* a dense gather of the post-merge cache and
    /// the next device-hit call reconciles nothing.
    ///
    /// **Host path**: the downloaded buffers are merged via
    /// [`KvCache::replace_from_device`] and adopted as the synced scratch
    /// image (taking `go.k` / `go.v`, leaving them empty).
    pub fn absorb_generated(
        &self,
        cache: &mut KvCache,
        go: &mut GenOut,
        appended: usize,
        first_pos: u64,
    ) -> Result<()> {
        if let Some(dev) = go.device.take() {
            let (l, h, c, dh) = (cache.l, cache.h, cache.c, cache.dh);
            for layer in 0..l {
                let new_len = go.lens[layer] as usize;
                if new_len != cache.lens[layer] + appended {
                    bail!(
                        "absorb(device): layer {layer} len {new_len} != {} + {appended}",
                        cache.lens[layer]
                    );
                }
                if let Some(&last) = cache.positions[layer].last() {
                    if first_pos <= last {
                        bail!("absorb(device): first_pos {first_pos} <= resident tail {last}");
                    }
                }
            }
            let t0 = Instant::now();
            // download the appended rows, staged [H, appended, Dh] per layer
            // (exactly append_layer's window layout) into the reusable call
            // buffers — the donated decode path allocates nothing
            let n = appended * dh;
            let mut bufs = lock_recover(&self.call_buf, "call buffers");
            bufs.stage_k.clear();
            bufs.stage_k.resize(h * n, 0.0);
            bufs.stage_v.clear();
            bufs.stage_v.resize(h * n, 0.0);
            for layer in 0..l {
                let old_len = cache.lens[layer];
                for hh in 0..h {
                    let off = ((layer * h + hh) * c + old_len) * dh;
                    dev.k
                        .copy_to_host_partial(&mut bufs.stage_k[hh * n..(hh + 1) * n], off)
                        .map_err(|e| classify_call("download", e.into()))?;
                    dev.v
                        .copy_to_host_partial(&mut bufs.stage_v[hh * n..(hh + 1) * n], off)
                        .map_err(|e| classify_call("download", e.into()))?;
                }
                cache.append_layer(
                    layer,
                    &bufs.stage_k,
                    &bufs.stage_v,
                    appended,
                    appended,
                    first_pos,
                )?;
            }
            drop(bufs);
            {
                let mut st = lock_recover(&self.stats, "runtime stats");
                st.bytes_d2h += (2 * 4 * l * h * appended * dh) as u64;
                st.download_s += t0.elapsed().as_secs_f64();
            }
            // lock order: device -> scratch
            let mut device = lock_recover(&self.device, "device tier");
            let mut pool = lock_recover(&self.scratch, "scratch pool");
            device.install_absorbed(cache, dev.k, dev.v, &mut pool)?;
            return Ok(());
        }
        cache.replace_from_device(&go.k, &go.v, &go.lens, appended, first_pos)?;
        let k = std::mem::take(&mut go.k);
        let v = std::mem::take(&mut go.v);
        lock_recover(&self.scratch, "scratch pool").absorb(cache, k, v);
        Ok(())
    }
}
