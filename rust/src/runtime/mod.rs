//! PJRT runtime: loads AOT artifacts (HLO text) once, compiles them on the
//! CPU PJRT client, and exposes typed `score` / `generate` calls over
//! on-device buffers. Python never runs here — the rust binary is
//! self-contained after `make artifacts`.
//!
//! Wiring follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute_b`.
//! Weights are uploaded to the device once per model. Per call, the paged KV
//! store is materialized through the [`transfer::ScratchPool`]: a reusable
//! dense image per cache that is re-copied only over dirty slot ranges (a
//! pure-append decode step gathers just the appended rows; an unchanged
//! cache gathers nothing), and on the generate path the downloaded device
//! state is absorbed wholesale as the next image
//! ([`Runtime::absorb_generated`]). Transfer volume is tracked per call in
//! [`RuntimeStats`] (`bytes_h2d` / `bytes_d2h` / `gather_s`); see PERF.md
//! for the transfer-layer design, invariants, and bench methodology.

pub mod arena;
pub mod kv;
pub mod manifest;
pub mod transfer;

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

pub use arena::{
    admission_ok, seq_footprint_bytes, ArenaStats, KvArena, Page, ARENA_OOM_MARKER, PAGE_SLOTS,
};
pub use kv::{GatherBytes, KvCache};
pub use manifest::{Manifest, ModelCfg, ProgKind, ProgMeta};
pub use transfer::{DenseImage, ScratchPool, TransferStats};

/// Dense scratch images the runtime keeps warm (LRU) — one per sequence in
/// the serving hot set. A sequence beyond this pays one full re-gather when
/// it rotates back in.
const SCRATCH_POOL_ENTRIES: usize = 16;

/// Cumulative runtime counters (per process) for the perf log. The transfer
/// fields are folded in from the scratch pool by [`Runtime::stats`].
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub calls: u64,
    pub compile_s: f64,
    /// Host->device upload time (includes the host-side gather; `gather_s`
    /// isolates that part).
    pub upload_s: f64,
    pub execute_s: f64,
    pub download_s: f64,
    /// Bytes uploaded host->device across all calls.
    pub bytes_h2d: u64,
    /// Bytes downloaded device->host across all calls.
    pub bytes_d2h: u64,
    /// Host-side gather wall-clock (pages -> dense scratch image).
    pub gather_s: f64,
    /// Bytes written into scratch images (dirty copies + zero-fill) — the
    /// number the incremental path drives toward zero per decode step.
    pub gathered_bytes: u64,
    pub gathers_full: u64,
    pub gathers_incremental: u64,
    pub gathers_noop: u64,
    /// Dense-buffer allocations by the transfer layer (zero after warmup).
    pub dense_scratch_allocs: u64,
    /// Host bytes currently pooled as scratch images (staging memory outside
    /// the arena's device budget; bounded by the pool's entry cap).
    pub scratch_resident_bytes: u64,
}

/// Reusable small per-call buffers (padded token/target windows, i32 lens):
/// steady-state calls allocate nothing here.
#[derive(Default)]
struct CallBuf {
    tok: Vec<i32>,
    tgt: Vec<i32>,
    lens: Vec<i32>,
}

pub struct LoadedModel {
    pub name: String,
    pub cfg: ModelCfg,
    pub n_params: usize,
    weights: xla::PjRtBuffer,
    #[allow(dead_code)]
    entry: manifest::ModelEntry,
    exes: RefCell<BTreeMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

pub struct Runtime {
    client: xla::PjRtClient,
    pub man: Manifest,
    models: BTreeMap<String, LoadedModel>,
    stats: RefCell<RuntimeStats>,
    /// Reusable dense K/V transfer images (dirty-range incremental gather).
    scratch: RefCell<ScratchPool>,
    /// Reusable small i32 call buffers.
    call_buf: RefCell<CallBuf>,
    /// Simulated device-memory budget in bytes (None = unlimited). The
    /// engine consults this to reproduce the paper's OOM axis.
    pub memory_budget_bytes: Cell<Option<usize>>,
}

/// Output of a score (teacher-forced window) call.
pub struct ScoreOut {
    /// Per-token logprob of the target, `[W]` (padding entries are garbage —
    /// the caller slices to `n_valid`).
    pub logprobs: Vec<f32>,
    /// Window keys `[L, H, W, Dh]`, pre-RoPE.
    pub win_k: Vec<f32>,
    /// Window values `[L, H, W, Dh]`.
    pub win_v: Vec<f32>,
    /// Per-slot attention mass `[L, C+W]` (scored programs only).
    pub mass: Option<Vec<f32>>,
}

/// Output of a generate (greedy decode) call. `k`/`v` hold the full device
/// state image `[L, H, C, Dh]`; [`Runtime::absorb_generated`] takes them to
/// seed the next call's upload, leaving empty vectors behind.
pub struct GenOut {
    pub tokens: Vec<i32>,
    pub last_logits: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub lens: Vec<i32>,
    /// Per-slot attention mass `[L, C]` (scored programs only).
    pub mass: Option<Vec<f32>>,
}

impl Runtime {
    /// Load the manifest and the listed models (weights uploaded eagerly;
    /// program compilation is lazy, cached per program).
    pub fn load(dir: &Path, model_names: &[&str]) -> Result<Runtime> {
        let man = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut models = BTreeMap::new();
        for &name in model_names {
            let entry = man.model(name)?.clone();
            let bytes = std::fs::read(&entry.weights_path).with_context(|| {
                format!(
                    "reading weights {} (run `make artifacts` to train + lower)",
                    entry.weights_path.display()
                )
            })?;
            if bytes.len() != entry.n_params * 4 {
                bail!(
                    "weights size mismatch for {name}: {} bytes != {} params * 4",
                    bytes.len(),
                    entry.n_params
                );
            }
            let floats: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            let weights = client.buffer_from_host_buffer(&floats, &[entry.n_params], None)?;
            models.insert(
                name.to_string(),
                LoadedModel {
                    name: name.to_string(),
                    cfg: entry.cfg.clone(),
                    n_params: entry.n_params,
                    weights,
                    entry,
                    exes: RefCell::new(BTreeMap::new()),
                },
            );
        }
        Ok(Runtime {
            client,
            man,
            models,
            stats: RefCell::new(RuntimeStats::default()),
            scratch: RefCell::new(ScratchPool::new(SCRATCH_POOL_ENTRIES)),
            call_buf: RefCell::new(CallBuf::default()),
            memory_budget_bytes: Cell::new(None),
        })
    }

    pub fn model(&self, name: &str) -> Result<&LoadedModel> {
        self.models.get(name).with_context(|| format!("model `{name}` not loaded"))
    }

    /// Runtime counters with the transfer-layer stats folded in.
    pub fn stats(&self) -> RuntimeStats {
        let mut st = self.stats.borrow().clone();
        let pool = self.scratch.borrow();
        let ts = pool.stats();
        st.gather_s = ts.gather_s;
        st.gathered_bytes = ts.gathered_bytes + ts.zeroed_bytes;
        st.gathers_full = ts.gathers_full;
        st.gathers_incremental = ts.gathers_incremental;
        st.gathers_noop = ts.gathers_noop;
        st.dense_scratch_allocs = ts.dense_allocs;
        st.scratch_resident_bytes = pool.resident_bytes() as u64;
        st
    }

    /// Raw transfer-layer counters (bench/diagnostic use).
    pub fn transfer_stats(&self) -> TransferStats {
        self.scratch.borrow().stats()
    }

    /// Pre-compile a set of programs (avoids first-call latency in serving).
    pub fn warmup(&self, model: &str, prog_names: &[&str]) -> Result<()> {
        for p in prog_names {
            let meta = self.man.prog(model, p)?.clone();
            self.exe(model, &meta)?;
        }
        Ok(())
    }

    fn exe(&self, model: &str, prog: &ProgMeta) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let lm = self.model(model)?;
        if let Some(e) = lm.exes.borrow().get(&prog.name) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&prog.path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", prog.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {model}/{}: {e}", prog.name))?,
        );
        self.stats.borrow_mut().compile_s += t0.elapsed().as_secs_f64();
        lm.exes.borrow_mut().insert(prog.name.clone(), exe.clone());
        Ok(exe)
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Teacher-forced scoring of `tokens` (with next-token `targets`) over
    /// the resident cache. `tokens.len()` may be shorter than the program
    /// window; inputs are padded and only valid logprobs are meaningful.
    /// Takes the cache mutably to advance its dirty-range sync point.
    #[allow(clippy::too_many_arguments)]
    pub fn score(
        &self,
        model: &str,
        w: usize,
        c: usize,
        scored: bool,
        tokens: &[i32],
        targets: &[i32],
        cache: &mut KvCache,
    ) -> Result<ScoreOut> {
        let prog = self.man.score_prog(model, w, c, scored)?.clone();
        let exe = self.exe(model, &prog)?;
        let lm = self.model(model)?;
        let cfg = &lm.cfg;
        if tokens.len() > w || tokens.len() != targets.len() {
            bail!("score: bad window ({} tokens, prog w={w})", tokens.len());
        }
        if cache.c != c || cache.l != cfg.n_layers {
            bail!("score: cache shape mismatch (cache c={} prog c={c})", cache.c);
        }
        let (l, h, dh) = (cache.l, cache.h, cache.dh);
        let t0 = Instant::now();
        let (tok_b, tgt_b, lens_b, kc_b, vc_b) = {
            // pad the token windows into the reusable call buffers
            let mut bufs = self.call_buf.borrow_mut();
            bufs.tok.clear();
            bufs.tok.extend_from_slice(tokens);
            bufs.tok.resize(w, 0);
            bufs.tgt.clear();
            bufs.tgt.extend_from_slice(targets);
            bufs.tgt.resize(w, 0);
            bufs.lens.clear();
            bufs.lens.extend(cache.lens.iter().map(|&x| x as i32));
            let tok_b = self.upload_i32(&bufs.tok, &[w])?;
            let tgt_b = self.upload_i32(&bufs.tgt, &[w])?;
            let lens_b = self.upload_i32(&bufs.lens, &[l])?;
            // incremental gather of the paged store into the reusable image
            let mut pool = self.scratch.borrow_mut();
            let image = pool.gather(cache);
            let kc_b = self.upload_f32(&image.k, &[l, h, c, dh])?;
            let vc_b = self.upload_f32(&image.v, &[l, h, c, dh])?;
            (tok_b, tgt_b, lens_b, kc_b, vc_b)
        };
        let arg_refs: Vec<&xla::PjRtBuffer> =
            vec![&lm.weights, &tok_b, &tgt_b, &kc_b, &vc_b, &lens_b];
        let t1 = Instant::now();
        let out = exe.execute_b(&arg_refs)?;
        let t2 = Instant::now();
        let lit = out[0][0].to_literal_sync()?;
        let mut parts = lit.to_tuple()?;
        let t3 = Instant::now();
        let mass = if scored {
            Some(parts.pop().context("missing mass output")?.to_vec::<f32>()?)
        } else {
            None
        };
        let win_v = parts.pop().context("win_v")?.to_vec::<f32>()?;
        let win_k = parts.pop().context("win_k")?.to_vec::<f32>()?;
        let logprobs = parts.pop().context("logprobs")?.to_vec::<f32>()?;
        {
            let mut st = self.stats.borrow_mut();
            st.calls += 1;
            st.upload_s += (t1 - t0).as_secs_f64();
            st.execute_s += (t2 - t1).as_secs_f64();
            st.download_s += (t3 - t2).as_secs_f64();
            st.bytes_h2d += 4 * (2 * cache.dense_elems() + 2 * w + l) as u64;
            let d2h = logprobs.len()
                + win_k.len()
                + win_v.len()
                + mass.as_ref().map_or(0, |m| m.len());
            st.bytes_d2h += 4 * d2h as u64;
        }
        Ok(ScoreOut { logprobs, win_k, win_v, mass })
    }

    /// Greedy decode of `k_steps` tokens; the device appends K/V in-graph,
    /// and the returned state merges back into the host cache via
    /// [`Runtime::absorb_generated`] (which also adopts it as the next
    /// upload's scratch image).
    pub fn generate(
        &self,
        model: &str,
        k_steps: usize,
        scored: bool,
        cache: &mut KvCache,
        last_token: i32,
    ) -> Result<GenOut> {
        self.generate_variant(model, k_steps, scored, false, cache, last_token)
    }

    /// Decode with explicit program-variant selection (`pallas = true` runs
    /// the interpret-mode Pallas-kernel artifact — numerics-identical to the
    /// fast path, used for kernel validation and the PERF.md comparison).
    pub fn generate_variant(
        &self,
        model: &str,
        k_steps: usize,
        scored: bool,
        pallas: bool,
        cache: &mut KvCache,
        last_token: i32,
    ) -> Result<GenOut> {
        let c = cache.c;
        let prog = if pallas {
            self.man.generate_pallas_prog(model, k_steps, c)?.clone()
        } else {
            self.man.generate_prog(model, k_steps, c, scored)?.clone()
        };
        let exe = self.exe(model, &prog)?;
        let lm = self.model(model)?;
        if cache.max_len() + k_steps > c {
            bail!(
                "generate: cache would overflow (len {} + k {} > C {})",
                cache.max_len(),
                k_steps,
                c
            );
        }
        let (l, h, dh) = (cache.l, cache.h, cache.dh);
        let t0 = Instant::now();
        let (lens_b, tok_b, kc_b, vc_b) = {
            let mut bufs = self.call_buf.borrow_mut();
            bufs.lens.clear();
            bufs.lens.extend(cache.lens.iter().map(|&x| x as i32));
            let lens_b = self.upload_i32(&bufs.lens, &[l])?;
            let tok_b = self.upload_i32(&[last_token], &[])?;
            // incremental gather of the paged store into the reusable image
            let mut pool = self.scratch.borrow_mut();
            let image = pool.gather(cache);
            let kc_b = self.upload_f32(&image.k, &[l, h, c, dh])?;
            let vc_b = self.upload_f32(&image.v, &[l, h, c, dh])?;
            (lens_b, tok_b, kc_b, vc_b)
        };
        let arg_refs: Vec<&xla::PjRtBuffer> = vec![&lm.weights, &kc_b, &vc_b, &lens_b, &tok_b];
        let t1 = Instant::now();
        let out = exe.execute_b(&arg_refs)?;
        let t2 = Instant::now();
        let lit = out[0][0].to_literal_sync()?;
        let mut parts = lit.to_tuple()?;
        let t3 = Instant::now();
        let mass = if scored {
            Some(parts.pop().context("mass")?.to_vec::<f32>()?)
        } else {
            None
        };
        let lens = parts.pop().context("lens")?.to_vec::<i32>()?;
        let v = parts.pop().context("vcache")?.to_vec::<f32>()?;
        let k = parts.pop().context("kcache")?.to_vec::<f32>()?;
        let last_logits = parts.pop().context("last_logits")?.to_vec::<f32>()?;
        let tokens = parts.pop().context("tokens")?.to_vec::<i32>()?;
        {
            let mut st = self.stats.borrow_mut();
            st.calls += 1;
            st.upload_s += (t1 - t0).as_secs_f64();
            st.execute_s += (t2 - t1).as_secs_f64();
            st.download_s += (t3 - t2).as_secs_f64();
            st.bytes_h2d += 4 * (2 * cache.dense_elems() + l + 1) as u64;
            let d2h = last_logits.len()
                + k.len()
                + v.len()
                + mass.as_ref().map_or(0, |m| m.len());
            st.bytes_d2h += 4 * (d2h + tokens.len() + lens.len()) as u64;
        }
        Ok(GenOut { tokens, last_logits, k, v, lens, mass })
    }

    /// Merge a generate call's device state into `cache` and adopt the
    /// downloaded buffers as the cache's synced dense image: resident rows
    /// were uploaded from this cache and pass through the program unchanged,
    /// the appended rows are merged here, and padding beyond `lens` stays
    /// zero — so the buffers *are* a full dense gather of the post-merge
    /// cache, and the next upload for it re-gathers nothing. Takes `go.k` /
    /// `go.v` (leaving them empty); the rest of `go` is untouched.
    pub fn absorb_generated(
        &self,
        cache: &mut KvCache,
        go: &mut GenOut,
        appended: usize,
        first_pos: u64,
    ) -> Result<()> {
        cache.replace_from_device(&go.k, &go.v, &go.lens, appended, first_pos)?;
        let k = std::mem::take(&mut go.k);
        let v = std::mem::take(&mut go.v);
        self.scratch.borrow_mut().absorb(cache, k, v);
        Ok(())
    }
}
