//! PJRT runtime: loads AOT artifacts (HLO text) once, compiles them on the
//! CPU PJRT client, and exposes typed `score` / `generate` calls over
//! on-device buffers. Python never runs here — the rust binary is
//! self-contained after `make artifacts`.
//!
//! Wiring follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute_b`.
//! Weights are uploaded to the device once per model; per call we upload the
//! cache + token buffers and download the output tuple (PJRT returns the
//! root tuple as a single buffer, so state round-trips host<->device per
//! call — measured and attacked in EXPERIMENTS.md §Perf).

pub mod arena;
pub mod kv;
pub mod manifest;

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

pub use arena::{
    admission_ok, seq_footprint_bytes, ArenaStats, KvArena, Page, ARENA_OOM_MARKER, PAGE_SLOTS,
};
pub use kv::KvCache;
pub use manifest::{Manifest, ModelCfg, ProgKind, ProgMeta};

/// Cumulative runtime counters (per process) for the perf log.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub calls: u64,
    pub compile_s: f64,
    pub upload_s: f64,
    pub execute_s: f64,
    pub download_s: f64,
}

pub struct LoadedModel {
    pub name: String,
    pub cfg: ModelCfg,
    pub n_params: usize,
    weights: xla::PjRtBuffer,
    #[allow(dead_code)]
    entry: manifest::ModelEntry,
    exes: RefCell<BTreeMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

pub struct Runtime {
    client: xla::PjRtClient,
    pub man: Manifest,
    models: BTreeMap<String, LoadedModel>,
    stats: RefCell<RuntimeStats>,
    /// Simulated device-memory budget in bytes (None = unlimited). The
    /// engine consults this to reproduce the paper's OOM axis.
    pub memory_budget_bytes: Cell<Option<usize>>,
}

/// Output of a score (teacher-forced window) call.
pub struct ScoreOut {
    /// Per-token logprob of the target, `[W]` (padding entries are garbage —
    /// the caller slices to `n_valid`).
    pub logprobs: Vec<f32>,
    /// Window keys `[L, H, W, Dh]`, pre-RoPE.
    pub win_k: Vec<f32>,
    /// Window values `[L, H, W, Dh]`.
    pub win_v: Vec<f32>,
    /// Per-slot attention mass `[L, C+W]` (scored programs only).
    pub mass: Option<Vec<f32>>,
}

/// Output of a generate (greedy decode) call.
pub struct GenOut {
    pub tokens: Vec<i32>,
    pub last_logits: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub lens: Vec<i32>,
    /// Per-slot attention mass `[L, C]` (scored programs only).
    pub mass: Option<Vec<f32>>,
}

impl Runtime {
    /// Load the manifest and the listed models (weights uploaded eagerly;
    /// program compilation is lazy, cached per program).
    pub fn load(dir: &Path, model_names: &[&str]) -> Result<Runtime> {
        let man = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut models = BTreeMap::new();
        for &name in model_names {
            let entry = man.model(name)?.clone();
            let bytes = std::fs::read(&entry.weights_path).with_context(|| {
                format!(
                    "reading weights {} (run `make artifacts` to train + lower)",
                    entry.weights_path.display()
                )
            })?;
            if bytes.len() != entry.n_params * 4 {
                bail!(
                    "weights size mismatch for {name}: {} bytes != {} params * 4",
                    bytes.len(),
                    entry.n_params
                );
            }
            let floats: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            let weights = client.buffer_from_host_buffer(&floats, &[entry.n_params], None)?;
            models.insert(
                name.to_string(),
                LoadedModel {
                    name: name.to_string(),
                    cfg: entry.cfg.clone(),
                    n_params: entry.n_params,
                    weights,
                    entry,
                    exes: RefCell::new(BTreeMap::new()),
                },
            );
        }
        Ok(Runtime {
            client,
            man,
            models,
            stats: RefCell::new(RuntimeStats::default()),
            memory_budget_bytes: Cell::new(None),
        })
    }

    pub fn model(&self, name: &str) -> Result<&LoadedModel> {
        self.models.get(name).with_context(|| format!("model `{name}` not loaded"))
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    /// Pre-compile a set of programs (avoids first-call latency in serving).
    pub fn warmup(&self, model: &str, prog_names: &[&str]) -> Result<()> {
        for p in prog_names {
            let meta = self.man.prog(model, p)?.clone();
            self.exe(model, &meta)?;
        }
        Ok(())
    }

    fn exe(&self, model: &str, prog: &ProgMeta) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let lm = self.model(model)?;
        if let Some(e) = lm.exes.borrow().get(&prog.name) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&prog.path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", prog.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {model}/{}: {e}", prog.name))?,
        );
        self.stats.borrow_mut().compile_s += t0.elapsed().as_secs_f64();
        lm.exes.borrow_mut().insert(prog.name.clone(), exe.clone());
        Ok(exe)
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Teacher-forced scoring of `tokens` (with next-token `targets`) over
    /// the resident cache. `tokens.len()` may be shorter than the program
    /// window; inputs are padded and only valid logprobs are meaningful.
    pub fn score(
        &self,
        model: &str,
        w: usize,
        c: usize,
        scored: bool,
        tokens: &[i32],
        targets: &[i32],
        cache: &KvCache,
    ) -> Result<ScoreOut> {
        let prog = self.man.score_prog(model, w, c, scored)?.clone();
        let exe = self.exe(model, &prog)?;
        let lm = self.model(model)?;
        let cfg = &lm.cfg;
        if tokens.len() > w || tokens.len() != targets.len() {
            bail!("score: bad window ({} tokens, prog w={w})", tokens.len());
        }
        if cache.c != c || cache.l != cfg.n_layers {
            bail!("score: cache shape mismatch (cache c={} prog c={c})", cache.c);
        }
        let mut tok = tokens.to_vec();
        let mut tgt = targets.to_vec();
        tok.resize(w, 0);
        tgt.resize(w, 0);

        let t0 = Instant::now();
        let (l, h, dh) = (cache.l, cache.h, cache.dh);
        let tok_b = self.upload_i32(&tok, &[w])?;
        let tgt_b = self.upload_i32(&tgt, &[w])?;
        // gather the paged store into the device-contiguous layout
        let (kd, vd) = cache.gather_dense();
        let kc_b = self.upload_f32(&kd, &[l, h, c, dh])?;
        let vc_b = self.upload_f32(&vd, &[l, h, c, dh])?;
        let lens_b = self.upload_i32(&cache.lens_i32(), &[l])?;
        let arg_refs: Vec<&xla::PjRtBuffer> =
            vec![&lm.weights, &tok_b, &tgt_b, &kc_b, &vc_b, &lens_b];
        let t1 = Instant::now();
        let out = exe.execute_b(&arg_refs)?;
        let t2 = Instant::now();
        let lit = out[0][0].to_literal_sync()?;
        let mut parts = lit.to_tuple()?;
        let t3 = Instant::now();
        {
            let mut st = self.stats.borrow_mut();
            st.calls += 1;
            st.upload_s += (t1 - t0).as_secs_f64();
            st.execute_s += (t2 - t1).as_secs_f64();
            st.download_s += (t3 - t2).as_secs_f64();
        }
        let mass = if scored {
            Some(parts.pop().context("missing mass output")?.to_vec::<f32>()?)
        } else {
            None
        };
        let win_v = parts.pop().context("win_v")?.to_vec::<f32>()?;
        let win_k = parts.pop().context("win_k")?.to_vec::<f32>()?;
        let logprobs = parts.pop().context("logprobs")?.to_vec::<f32>()?;
        Ok(ScoreOut { logprobs, win_k, win_v, mass })
    }

    /// Greedy decode of `k_steps` tokens; the device appends K/V in-graph,
    /// and the returned state replaces the host cache via
    /// [`KvCache::replace_from_device`].
    pub fn generate(
        &self,
        model: &str,
        k_steps: usize,
        scored: bool,
        cache: &KvCache,
        last_token: i32,
    ) -> Result<GenOut> {
        self.generate_variant(model, k_steps, scored, false, cache, last_token)
    }

    /// Decode with explicit program-variant selection (`pallas = true` runs
    /// the interpret-mode Pallas-kernel artifact — numerics-identical to the
    /// fast path, used for kernel validation and the §Perf comparison).
    pub fn generate_variant(
        &self,
        model: &str,
        k_steps: usize,
        scored: bool,
        pallas: bool,
        cache: &KvCache,
        last_token: i32,
    ) -> Result<GenOut> {
        let c = cache.c;
        let prog = if pallas {
            self.man.generate_pallas_prog(model, k_steps, c)?.clone()
        } else {
            self.man.generate_prog(model, k_steps, c, scored)?.clone()
        };
        let exe = self.exe(model, &prog)?;
        let lm = self.model(model)?;
        if cache.max_len() + k_steps > c {
            bail!(
                "generate: cache would overflow (len {} + k {} > C {})",
                cache.max_len(),
                k_steps,
                c
            );
        }
        let t0 = Instant::now();
        let (l, h, dh) = (cache.l, cache.h, cache.dh);
        // gather the paged store into the device-contiguous layout
        let (kd, vd) = cache.gather_dense();
        let kc_b = self.upload_f32(&kd, &[l, h, c, dh])?;
        let vc_b = self.upload_f32(&vd, &[l, h, c, dh])?;
        let lens_b = self.upload_i32(&cache.lens_i32(), &[l])?;
        let tok_b = self.upload_i32(&[last_token], &[])?;
        let arg_refs: Vec<&xla::PjRtBuffer> = vec![&lm.weights, &kc_b, &vc_b, &lens_b, &tok_b];
        let t1 = Instant::now();
        let out = exe.execute_b(&arg_refs)?;
        let t2 = Instant::now();
        let lit = out[0][0].to_literal_sync()?;
        let mut parts = lit.to_tuple()?;
        let t3 = Instant::now();
        {
            let mut st = self.stats.borrow_mut();
            st.calls += 1;
            st.upload_s += (t1 - t0).as_secs_f64();
            st.execute_s += (t2 - t1).as_secs_f64();
            st.download_s += (t3 - t2).as_secs_f64();
        }
        let mass = if scored {
            Some(parts.pop().context("mass")?.to_vec::<f32>()?)
        } else {
            None
        };
        let lens = parts.pop().context("lens")?.to_vec::<i32>()?;
        let v = parts.pop().context("vcache")?.to_vec::<f32>()?;
        let k = parts.pop().context("kcache")?.to_vec::<f32>()?;
        let last_logits = parts.pop().context("last_logits")?.to_vec::<f32>()?;
        let tokens = parts.pop().context("tokens")?.to_vec::<i32>()?;
        Ok(GenOut { tokens, last_logits, k, v, lens, mass })
    }
}
