//! PJRT runtime: loads AOT artifacts (HLO text) once, compiles them on the
//! CPU PJRT client, and exposes typed `score` / `generate` calls over
//! on-device buffers. Python never runs here — the rust binary is
//! self-contained after `make artifacts`.
//!
//! Wiring follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute_b`.
//! Weights are uploaded to the device once per model. Per call, the paged KV
//! store reaches the device through a three-tier residency path (see
//! [`device::DeviceTier`] and PERF.md "Device residency"):
//!
//! - **device-hit** — the sequence's K/V image is already resident
//!   ([`DeviceKvState`], stamped `(id, sync_gen)`): only dirty slot ranges
//!   are uploaded over it, and generate calls donate the buffers to the
//!   program (`execute_with_donation`), downloading just the appended rows —
//!   steady-state decode moves tokens and lens, not the cache;
//! - **host-hit** — no resident buffers, but the [`transfer::ScratchPool`]
//!   (now the spill tier) holds a stamped host image: incremental gather,
//!   one full upload, promotion;
//! - **cold** — full gather, full upload, promotion.
//!
//! # Device shards
//!
//! The runtime is partitioned into N **shards**, one per PJRT device
//! ordinal: each shard bundles its own residency tier (an equal slice of
//! `device_pool_bytes`), scratch pool, and call buffers, so calls routed to
//! different shards contend on nothing but the shared stats counter. Calls
//! name their shard explicitly (`score_on` / `generate_on` /
//! `absorb_generated_on`); the unsuffixed entry points are shard-0 wrappers
//! serving the single-device CLI/eval paths. Which shard a sequence lands
//! on is the admission-time [`placement`] policy's call (prefix-locality
//! first, least-loaded-bytes otherwise); one shard's sticky degraded flag
//! leaves the other shards serving ([`Runtime::device_degraded`] only
//! reports fleet-wide degradation).
//!
//! Residency is capacity-bounded with cost-aware spill-to-scratch, and
//! everything is accounted in [`RuntimeStats`] (`bytes_h2d` / `bytes_d2h` /
//! `device_resident_bytes` / `residency_hits` / `spills` / `donations`),
//! which the serving admission gate and `op:stats` consume; per-shard
//! gauges come from [`Runtime::shard_stats`].
//!
//! # Observability
//!
//! Beyond the cumulative counters, the storage tiers record structured
//! events into the process-global flight recorder ([`crate::obs`]), keyed
//! by **KV cache id** (`KvCache::id`, unlike the scheduler's request-keyed
//! lifecycle events): `residency-hit` / `residency-miss` / `spill` /
//! `donation` from [`device::DeviceTier`], `prefix-adopt` /
//! `prefix-freeze` / `prefix-evict` from [`prefix::PrefixCache`],
//! `quant-demote` / `quant-promote` from [`kv::KvCache`]'s tiered
//! compression, and a shard-level `quarantine` when a tier trips its
//! sticky degraded mode. Recording is non-blocking and byte-invisible to
//! generation — `op:trace` exposes the ring.

pub mod arena;
pub mod device;
pub mod error;
pub mod executor;
pub mod kv;
pub mod manifest;
pub mod placement;
pub mod prefix;
pub mod transfer;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

pub use arena::{
    admission_ok, seq_footprint_bytes, seq_footprint_bytes_mixed, sharded_staging_bytes,
    ArenaStats, KvArena, Page, PageData, Precision, QuantPage, SharedPage, ARENA_OOM_MARKER,
    PAGE_SLOTS,
};
pub use device::{Acquired, DeviceKvState, DeviceStats, DeviceTier};
pub use error::{classify, lock_poisoned_total, lock_recover, CallError, CallErrorKind};
pub use executor::{CallExecutor, Completion};
pub use kv::{GatherBytes, KvCache};
pub use manifest::{Manifest, ModelCfg, ProgKind, ProgMeta};
pub use placement::{place, Placement, PlacementKind, PlacementStats, ShardLoad};
pub use prefix::{PrefixCache, PrefixSnapshot, PrefixStats};
pub use transfer::{DenseImage, ScratchPool, TransferStats};

/// Wrap a device-call stage failure with its classified [`CallErrorKind`]
/// (downcast if already typed, marker strings otherwise), so every error
/// leaving `score`/`generate`/upload/download paths carries the taxonomy.
fn classify_call(stage: &str, e: anyhow::Error) -> anyhow::Error {
    let kind = classify(&e);
    CallError::new(kind, format!("{stage}: {e:#}"))
}

/// Knobs for the runtime's staging tiers (serving exposes them through
/// `ServeConfig`; the defaults here serve the CLI/eval paths).
#[derive(Clone, Debug)]
pub struct RuntimeOpts {
    /// Dense scratch images the transfer layer keeps warm (LRU) — one per
    /// sequence in the serving hot set; divided across shards and clamped
    /// to >= 1 per shard (the gather path always needs one staging image).
    /// A sequence beyond this pays one full re-gather when it rotates back
    /// in.
    pub scratch_pool_entries: usize,
    /// Byte capacity of the device-residency tier (K + V across resident
    /// sequences), split evenly across shards. 0 disables residency: every
    /// call re-uploads its image, the pre-residency behavior.
    pub device_pool_bytes: usize,
    /// Device shards to partition the runtime across. Each shard binds one
    /// PJRT device ordinal and owns a `device_pool_bytes / devices`
    /// residency slice, a scratch pool, and call buffers. The stub client
    /// materializes this many devices; under `real-pjrt` the client's own
    /// enumeration is authoritative and this is clamped to it.
    pub devices: usize,
}

impl Default for RuntimeOpts {
    fn default() -> Self {
        Self { scratch_pool_entries: 16, device_pool_bytes: 256 << 20, devices: 1 }
    }
}

/// Cumulative runtime counters (per process) for the perf log. The transfer
/// and residency fields are folded in — summed across every shard — by
/// [`Runtime::stats`].
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub calls: u64,
    pub compile_s: f64,
    /// Host->device upload time (includes the host-side gather; `gather_s`
    /// isolates that part).
    pub upload_s: f64,
    pub execute_s: f64,
    pub download_s: f64,
    /// Bytes uploaded host->device across all calls (call inputs + full
    /// image uploads + dirty-range reconciles).
    pub bytes_h2d: u64,
    /// Bytes downloaded device->host across all calls (call outputs +
    /// residency spills).
    pub bytes_d2h: u64,
    /// Host-side gather wall-clock (pages -> dense scratch image).
    pub gather_s: f64,
    /// Wall-clock spent dequantizing Q8 pages inside gathers (subset of
    /// `gather_s`; zero with `--kv-quant off`).
    pub dequant_s: f64,
    /// Bytes written into scratch images (dirty copies + zero-fill) — the
    /// number the incremental path drives toward zero per decode step.
    pub gathered_bytes: u64,
    pub gathers_full: u64,
    pub gathers_incremental: u64,
    pub gathers_noop: u64,
    /// Dense-buffer allocations by the transfer layer (zero after warmup).
    pub dense_scratch_allocs: u64,
    /// Host bytes currently pooled as scratch images (staging memory that
    /// the admission gate counts; bounded by the pools' entry caps).
    pub scratch_resident_bytes: u64,
    /// Bytes currently resident across every shard's device tier (K + V) —
    /// counted by the admission gate alongside arena pages.
    pub device_resident_bytes: u64,
    /// Calls served by a resident device image (no full upload).
    pub residency_hits: u64,
    /// Calls that uploaded a full image (cold, post-spill, or stale stamp).
    pub residency_misses: u64,
    /// Spills from the device tiers (image read back to scratch).
    pub spills: u64,
    /// Generate calls that donated resident buffers to the program and kept
    /// the output state on-device.
    pub donations: u64,
    /// Bytes uploaded by dirty-range reconciliation over resident images
    /// (the device-hit path's only KV upload traffic).
    pub reconciled_bytes: u64,
    /// Whether EVERY shard's device tier is in sticky degraded mode
    /// (repeated retryable call failures): residency is bypassed fleet-wide
    /// and every call serves via the host/scratch path until restart. A
    /// single lost device degrades only its shard — see
    /// [`Runtime::shard_stats`] for the per-shard flags.
    pub device_degraded: bool,
    /// Consecutive retryable device-call failures summed across shards
    /// (each shard resets its own count on success).
    pub device_failures: u64,
    /// Poisoned-mutex recoveries by [`lock_recover`] (process-wide).
    pub lock_poisoned: u64,
}

/// Point-in-time per-shard gauges for `op:stats` / `op:ping` (the
/// fleet-level aggregation lives in [`RuntimeStats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardStat {
    /// PJRT device ordinal backing the shard.
    pub device: usize,
    /// The shard's residency-tier byte slice.
    pub capacity_bytes: usize,
    /// Bytes currently resident in the shard's device tier.
    pub resident_bytes: u64,
    /// Host bytes held by the shard's scratch pool.
    pub scratch_resident_bytes: u64,
    /// Calls this shard served from a resident image.
    pub residency_hits: u64,
    /// Calls this shard served with a full image upload.
    pub residency_misses: u64,
    /// Spills from this shard's device tier.
    pub spills: u64,
    /// Sticky per-shard degraded flag: this shard bypasses residency, the
    /// rest of the fleet keeps serving normally.
    pub degraded: bool,
}

/// Reusable per-call buffers (padded token/target windows, i32 lens, f32
/// staging for appended-row downloads): steady-state calls allocate nothing
/// here.
#[derive(Default)]
struct CallBuf {
    tok: Vec<i32>,
    tgt: Vec<i32>,
    lens: Vec<i32>,
    stage_k: Vec<f32>,
    stage_v: Vec<f32>,
}

/// One device's slice of the runtime: residency tier, scratch pool, and
/// call buffers bound to a single PJRT device ordinal. Shards share the
/// client and the compiled-model table but no mutable call state, so calls
/// on different shards proceed in parallel.
struct DeviceShard {
    /// PJRT device ordinal this shard's buffers live on.
    device: usize,
    /// This shard's `device_pool_bytes` slice (capacity of `tier`).
    capacity_bytes: usize,
    /// Reusable dense K/V transfer images (dirty-range incremental gather);
    /// the spill tier under `tier`.
    scratch: Mutex<ScratchPool>,
    /// Device-resident K/V images (the hot tier), bound to `device`.
    tier: Mutex<DeviceTier>,
    /// Reusable small i32/f32 call buffers.
    call_buf: Mutex<CallBuf>,
}

pub struct LoadedModel {
    pub name: String,
    pub cfg: ModelCfg,
    pub n_params: usize,
    /// One uploaded weights buffer per shard, indexed by shard.
    weights: Vec<xla::PjRtBuffer>,
    #[allow(dead_code)]
    entry: manifest::ModelEntry,
    /// Compiled executables keyed by `(shard, program name)` — real PJRT
    /// executables are device-bound, so each shard compiles (and caches)
    /// its own handle.
    exes: Mutex<BTreeMap<(usize, String), Arc<xla::PjRtLoadedExecutable>>>,
}

/// Byte slice of the global `device_pool_bytes` owned by shard `i` of `n`:
/// an even split with the remainder spread over the lowest-indexed shards,
/// so the slices sum exactly to the configured pool.
pub(crate) fn shard_slice_bytes(total: usize, n: usize, i: usize) -> usize {
    let n = n.max(1);
    total / n + usize::from(i < total % n)
}

/// Stub client: materialize exactly the requested device count.
#[cfg(not(feature = "real-pjrt"))]
fn new_client(devices: usize) -> xla::Result<xla::PjRtClient> {
    xla::PjRtClient::cpu_with_devices(devices)
}

/// Real PJRT enumerates its own topology; `devices` is clamped to what the
/// client reports after construction.
#[cfg(feature = "real-pjrt")]
fn new_client(_devices: usize) -> xla::Result<xla::PjRtClient> {
    xla::PjRtClient::cpu()
}

/// The runtime is `Sync`: interior state lives behind `Mutex`es so in-flight
/// calls on [`executor::CallExecutor`] workers can share one `&Runtime`.
/// Lock-ordering rule for the staging tiers: **device before scratch**,
/// within one shard — every path that holds both takes the shard's `tier`
/// first (or takes them in disjoint scopes), and no path ever holds two
/// shards' guards at once, so concurrent calls cannot deadlock.
pub struct Runtime {
    client: xla::PjRtClient,
    pub man: Manifest,
    models: BTreeMap<String, LoadedModel>,
    stats: Mutex<RuntimeStats>,
    /// One shard per PJRT device; never empty.
    shards: Vec<DeviceShard>,
    /// Simulated device-memory budget in bytes (None = unlimited). The
    /// engine consults this to reproduce the paper's OOM axis.
    pub memory_budget_bytes: Mutex<Option<usize>>,
}

/// Output of a score (teacher-forced window) call.
pub struct ScoreOut {
    /// Per-token logprob of the target, `[W]` (padding entries are garbage —
    /// the caller slices to `n_valid`).
    pub logprobs: Vec<f32>,
    /// Window keys `[L, H, W, Dh]`, pre-RoPE.
    pub win_k: Vec<f32>,
    /// Window values `[L, H, W, Dh]`.
    pub win_v: Vec<f32>,
    /// Per-slot attention mass `[L, C+W]` (scored programs only).
    pub mass: Option<Vec<f32>>,
}

/// Donated output buffers of a device-resident generate call: the K/V state
/// never left the device. Consumed by [`Runtime::absorb_generated`], which
/// downloads only the appended rows and re-installs the buffers as the
/// cache's resident image.
pub(crate) struct DeviceGenOut {
    pub k: xla::PjRtBuffer,
    pub v: xla::PjRtBuffer,
}

/// Output of a generate (greedy decode) call. On the host/transient path,
/// `k`/`v` hold the full downloaded state image `[L, H, C, Dh]`; on the
/// device-resident path they are EMPTY (the state stayed on the device,
/// `device` carries the donated output buffers). Either way,
/// [`Runtime::absorb_generated`] merges the appended rows into the host
/// cache and seeds the next call's image.
pub struct GenOut {
    pub tokens: Vec<i32>,
    pub last_logits: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub lens: Vec<i32>,
    /// Per-slot attention mass `[L, C]` (scored programs only).
    pub mass: Option<Vec<f32>>,
    pub(crate) device: Option<DeviceGenOut>,
}

impl Runtime {
    /// Load the manifest and the listed models with default staging-tier
    /// knobs (weights uploaded eagerly; program compilation is lazy, cached
    /// per (shard, program)).
    pub fn load(dir: &Path, model_names: &[&str]) -> Result<Runtime> {
        Self::load_with(dir, model_names, RuntimeOpts::default())
    }

    /// [`Self::load`] with explicit staging-tier sizing (the serving path
    /// passes `ServeConfig.{scratch_pool_entries, device_pool_bytes,
    /// devices}`). Weights are uploaded once per shard so every device can
    /// execute without cross-device transfers.
    pub fn load_with(dir: &Path, model_names: &[&str], opts: RuntimeOpts) -> Result<Runtime> {
        let man = Manifest::load(dir)?;
        let client = new_client(opts.devices.max(1))?;
        let devices = opts.devices.max(1).min(client.device_count().max(1));
        let scratch_entries = (opts.scratch_pool_entries / devices).max(1);
        let shards: Vec<DeviceShard> = (0..devices)
            .map(|i| {
                let capacity = shard_slice_bytes(opts.device_pool_bytes, devices, i);
                DeviceShard {
                    device: i,
                    capacity_bytes: capacity,
                    scratch: Mutex::new(ScratchPool::new(scratch_entries)),
                    tier: Mutex::new(DeviceTier::with_device(capacity, i)),
                    call_buf: Mutex::new(CallBuf::default()),
                }
            })
            .collect();
        let mut models = BTreeMap::new();
        for &name in model_names {
            let entry = man.model(name)?.clone();
            let bytes = std::fs::read(&entry.weights_path).with_context(|| {
                format!(
                    "reading weights {} (run `make artifacts` to train + lower)",
                    entry.weights_path.display()
                )
            })?;
            if bytes.len() != entry.n_params * 4 {
                bail!(
                    "weights size mismatch for {name}: {} bytes != {} params * 4",
                    bytes.len(),
                    entry.n_params
                );
            }
            let floats: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            let weights = shards
                .iter()
                .map(|s| {
                    client
                        .buffer_from_host_buffer(&floats, &[entry.n_params], Some(s.device))
                        .map_err(|e| {
                            anyhow::anyhow!(
                                "uploading {name} weights to device {}: {e}",
                                s.device
                            )
                        })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.to_string(),
                LoadedModel {
                    name: name.to_string(),
                    cfg: entry.cfg.clone(),
                    n_params: entry.n_params,
                    weights,
                    entry,
                    exes: Mutex::new(BTreeMap::new()),
                },
            );
        }
        Ok(Runtime {
            client,
            man,
            models,
            stats: Mutex::new(RuntimeStats::default()),
            shards,
            memory_budget_bytes: Mutex::new(None),
        })
    }

    pub fn model(&self, name: &str) -> Result<&LoadedModel> {
        self.models.get(name).with_context(|| format!("model `{name}` not loaded"))
    }

    /// Number of device shards (>= 1).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn shard(&self, idx: usize) -> Result<&DeviceShard> {
        self.shards
            .get(idx)
            .with_context(|| format!("shard {idx} out of range ({} shards)", self.shards.len()))
    }

    /// Runtime counters with every shard's staging-tier stats folded in
    /// (summed). Sweeps dead entries first, so the gauges never count
    /// dropped sequences. `device_degraded` is fleet-level: true only when
    /// ALL shards are degraded.
    pub fn stats(&self) -> RuntimeStats {
        self.sweep_staging();
        let mut st = lock_recover(&self.stats, "runtime stats").clone();
        let mut all_degraded = true;
        for sh in &self.shards {
            // scratch and tier guards are taken in disjoint scopes (never
            // nested scratch->tier, which would invert the lock order)
            {
                let pool = lock_recover(&sh.scratch, "scratch pool");
                let ts = pool.stats();
                st.gather_s += ts.gather_s;
                st.dequant_s += ts.dequant_s;
                st.gathered_bytes += ts.gathered_bytes + ts.zeroed_bytes;
                st.gathers_full += ts.gathers_full;
                st.gathers_incremental += ts.gathers_incremental;
                st.gathers_noop += ts.gathers_noop;
                st.dense_scratch_allocs += ts.dense_allocs;
                st.scratch_resident_bytes += pool.resident_bytes() as u64;
            }
            {
                let dev = lock_recover(&sh.tier, "device tier");
                let ds = dev.stats();
                st.bytes_h2d += ds.uploaded_bytes;
                st.bytes_d2h += ds.spill_bytes_d2h;
                st.device_resident_bytes += dev.resident_bytes() as u64;
                st.residency_hits += ds.hits;
                st.residency_misses += ds.misses;
                st.spills += ds.spills;
                st.donations += ds.donations;
                st.reconciled_bytes += ds.reconciled_bytes;
                st.device_failures += ds.call_failures;
                all_degraded &= dev.degraded();
            }
        }
        st.device_degraded = all_degraded;
        st.lock_poisoned = lock_poisoned_total();
        st
    }

    /// Point-in-time per-shard gauges (`op:stats` `shards[i]`, `op:ping`
    /// shard health). Sweeps dead entries first, like [`Self::stats`].
    pub fn shard_stats(&self) -> Vec<ShardStat> {
        self.sweep_staging();
        self.shards
            .iter()
            .map(|sh| {
                let (resident_bytes, ds, degraded) = {
                    let dev = lock_recover(&sh.tier, "device tier");
                    (dev.resident_bytes() as u64, dev.stats(), dev.degraded())
                };
                let scratch_resident_bytes =
                    lock_recover(&sh.scratch, "scratch pool").resident_bytes() as u64;
                ShardStat {
                    device: sh.device,
                    capacity_bytes: sh.capacity_bytes,
                    resident_bytes,
                    scratch_resident_bytes,
                    residency_hits: ds.hits,
                    residency_misses: ds.misses,
                    spills: ds.spills,
                    degraded,
                }
            })
            .collect()
    }

    /// Load snapshot for the [`placement`] policy. `inflight` is zero here —
    /// the runtime does not track executor lanes; serving overlays each
    /// lane's in-flight count before calling [`place`].
    pub fn shard_loads(&self) -> Vec<ShardLoad> {
        self.shards
            .iter()
            .map(|sh| {
                let dev = lock_recover(&sh.tier, "device tier");
                ShardLoad {
                    device: sh.device,
                    resident_bytes: dev.resident_bytes(),
                    inflight: 0,
                    degraded: dev.degraded(),
                    capacity_bytes: sh.capacity_bytes,
                }
            })
            .collect()
    }

    /// Raw transfer-layer counters for one shard (bench/diagnostic use).
    pub fn transfer_stats_on(&self, shard: usize) -> TransferStats {
        lock_recover(&self.shards[shard].scratch, "scratch pool").stats()
    }

    /// Shard-0 transfer counters (single-device bench/diagnostic paths).
    pub fn transfer_stats(&self) -> TransferStats {
        self.transfer_stats_on(0)
    }

    /// Raw residency-tier counters for one shard (bench/diagnostic use).
    pub fn device_stats_on(&self, shard: usize) -> DeviceStats {
        lock_recover(&self.shards[shard].tier, "device tier").stats()
    }

    /// Shard-0 residency counters (single-device bench/diagnostic paths).
    pub fn device_stats(&self) -> DeviceStats {
        self.device_stats_on(0)
    }

    /// Whether EVERY shard's device tier has flipped into sticky degraded
    /// mode (served to load balancers via `op:ping`). A single degraded
    /// shard does not trip this — the fleet keeps serving; per-shard flags
    /// are in [`Self::shard_stats`].
    pub fn device_degraded(&self) -> bool {
        self.shards.iter().all(|sh| lock_recover(&sh.tier, "device tier").degraded())
    }

    /// Sticky degraded flag of one shard's device tier (out-of-range shards
    /// read as degraded).
    pub fn shard_degraded(&self, shard: usize) -> bool {
        self.shards
            .get(shard)
            .map(|sh| lock_recover(&sh.tier, "device tier").degraded())
            .unwrap_or(true)
    }

    /// Drop staging entries (device tiers + scratch pools, every shard)
    /// whose cache was dropped. Called before every stats read and
    /// admission decision, so a cancelled sequence's
    /// `device_resident_bytes` are gone before the next reactor round
    /// admits anyone.
    pub fn sweep_staging(&self) {
        for sh in &self.shards {
            lock_recover(&sh.tier, "device tier").sweep();
            lock_recover(&sh.scratch, "scratch pool").sweep();
        }
    }

    /// Host + device staging bytes currently held for live sequences across
    /// all shards — the footprint the serving admission gate counts
    /// alongside arena pages.
    pub fn staging_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|sh| {
                lock_recover(&sh.tier, "device tier").resident_bytes()
                    + lock_recover(&sh.scratch, "scratch pool").resident_bytes()
            })
            .sum()
    }

    /// Staging bytes held by one shard (its per-shard admission slice).
    pub fn staging_bytes_on(&self, shard: usize) -> usize {
        self.shards
            .get(shard)
            .map(|sh| {
                lock_recover(&sh.tier, "device tier").resident_bytes()
                    + lock_recover(&sh.scratch, "scratch pool").resident_bytes()
            })
            .unwrap_or(0)
    }

    /// Deterministically release one cache's staging state (device buffers +
    /// scratch image, on whichever shard holds them) — the engine-reset /
    /// teardown path; dropped caches are also caught lazily by
    /// [`Self::sweep_staging`].
    pub fn release_cache_state(&self, cache_id: u64) {
        for sh in &self.shards {
            lock_recover(&sh.tier, "device tier").release(cache_id);
            lock_recover(&sh.scratch, "scratch pool").release(cache_id);
        }
    }

    /// Pre-compile a set of programs on every shard (avoids first-call
    /// latency in serving).
    pub fn warmup(&self, model: &str, prog_names: &[&str]) -> Result<()> {
        for p in prog_names {
            let meta = self.man.prog(model, p)?.clone();
            for shard in 0..self.shards.len() {
                self.exe(shard, model, &meta)?;
            }
        }
        Ok(())
    }

    fn exe(
        &self,
        shard: usize,
        model: &str,
        prog: &ProgMeta,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let lm = self.model(model)?;
        let key = (shard, prog.name.clone());
        if let Some(e) = lock_recover(&lm.exes, "model executables").get(&key) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&prog.path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", prog.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {model}/{}: {e}", prog.name))?,
        );
        lock_recover(&self.stats, "runtime stats").compile_s += t0.elapsed().as_secs_f64();
        lock_recover(&lm.exes, "model executables").insert(key, exe.clone());
        Ok(exe)
    }

    fn upload_i32(&self, device: usize, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, Some(device))
            .map_err(|e| classify_call("upload", e.into()))
    }

    /// Shard-0 [`Self::score_on`] — the single-device CLI/eval entry point.
    #[allow(clippy::too_many_arguments)]
    pub fn score(
        &self,
        model: &str,
        w: usize,
        c: usize,
        scored: bool,
        tokens: &[i32],
        targets: &[i32],
        cache: &mut KvCache,
    ) -> Result<ScoreOut> {
        self.score_on(0, model, w, c, scored, tokens, targets, cache)
    }

    /// Teacher-forced scoring of `tokens` (with next-token `targets`) over
    /// the resident cache, on shard `shard`'s device. `tokens.len()` may be
    /// shorter than the program window; inputs are padded and only valid
    /// logprobs are meaningful. Takes the cache mutably to advance its
    /// dirty-range sync point: on a device hit the call uploads only dirty
    /// slot ranges (tokens, targets and lens aside), otherwise it uploads
    /// one full image and promotes it into the shard's residency tier.
    #[allow(clippy::too_many_arguments)]
    pub fn score_on(
        &self,
        shard: usize,
        model: &str,
        w: usize,
        c: usize,
        scored: bool,
        tokens: &[i32],
        targets: &[i32],
        cache: &mut KvCache,
    ) -> Result<ScoreOut> {
        let sh = self.shard(shard)?;
        let prog = self.man.score_prog(model, w, c, scored)?.clone();
        let exe = self.exe(shard, model, &prog)?;
        let lm = self.model(model)?;
        let cfg = &lm.cfg;
        if tokens.len() > w || tokens.len() != targets.len() {
            bail!("score: bad window ({} tokens, prog w={w})", tokens.len());
        }
        if cache.c != c || cache.l != cfg.n_layers {
            bail!("score: cache shape mismatch (cache c={} prog c={c})", cache.c);
        }
        let l = cache.l;
        let t0 = Instant::now();
        let (tok_b, tgt_b, lens_b) = {
            // pad the token windows into the shard's reusable call buffers
            let mut bufs = lock_recover(&sh.call_buf, "call buffers");
            bufs.tok.clear();
            bufs.tok.extend_from_slice(tokens);
            bufs.tok.resize(w, 0);
            bufs.tgt.clear();
            bufs.tgt.extend_from_slice(targets);
            bufs.tgt.resize(w, 0);
            bufs.lens.clear();
            bufs.lens.extend(cache.lens.iter().map(|&x| x as i32));
            let tok_b = self.upload_i32(sh.device, &bufs.tok, &[w])?;
            let tgt_b = self.upload_i32(sh.device, &bufs.tgt, &[w])?;
            let lens_b = self.upload_i32(sh.device, &bufs.lens, &[l])?;
            (tok_b, tgt_b, lens_b)
        };
        // three-tier K/V path: resident reconcile, or gather + upload +
        // promote (the tier accounts its own upload bytes; lock order is
        // tier -> scratch, matching every other dual-guard path)
        let mut device = lock_recover(&sh.tier, "device tier");
        let acq = {
            let mut pool = lock_recover(&sh.scratch, "scratch pool");
            device.sweep();
            pool.sweep();
            device
                .acquire(&self.client, cache, &mut pool)
                .map_err(|e| classify_call("upload", e))?
        };
        let (kc_b, vc_b): (&xla::PjRtBuffer, &xla::PjRtBuffer) = match &acq {
            Acquired::Resident => {
                let e = device.resident(cache.id()).expect("acquired entry present");
                (&e.k, &e.v)
            }
            Acquired::Transient(k, v) => (k, v),
        };
        let arg_refs: Vec<&xla::PjRtBuffer> =
            vec![&lm.weights[shard], &tok_b, &tgt_b, kc_b, vc_b, &lens_b];
        let t1 = Instant::now();
        let exec_res = exe.execute_b(&arg_refs);
        let t2 = Instant::now();
        let out = match exec_res {
            Ok(o) => {
                device.note_call_success();
                o
            }
            Err(e) => {
                let err = classify_call("execute", e.into());
                if classify(&err).retryable() {
                    device.note_call_failure();
                }
                return Err(err.context(format!("score {model}/{}", prog.name)));
            }
        };
        let lit = out[0][0].to_literal_sync().map_err(|e| classify_call("download", e.into()))?;
        let mut parts = lit.to_tuple().map_err(|e| classify_call("download", e.into()))?;
        let t3 = Instant::now();
        let mass = if scored {
            Some(parts.pop().context("missing mass output")?.to_vec::<f32>()?)
        } else {
            None
        };
        let win_v = parts.pop().context("win_v")?.to_vec::<f32>()?;
        let win_k = parts.pop().context("win_k")?.to_vec::<f32>()?;
        let logprobs = parts.pop().context("logprobs")?.to_vec::<f32>()?;
        {
            let mut st = lock_recover(&self.stats, "runtime stats");
            st.calls += 1;
            st.upload_s += (t1 - t0).as_secs_f64();
            st.execute_s += (t2 - t1).as_secs_f64();
            st.download_s += (t3 - t2).as_secs_f64();
            // KV image bytes are accounted by the residency tier; only the
            // small call inputs are counted here
            st.bytes_h2d += 4 * (2 * w + l) as u64;
            let d2h = logprobs.len()
                + win_k.len()
                + win_v.len()
                + mass.as_ref().map_or(0, |m| m.len());
            st.bytes_d2h += 4 * d2h as u64;
        }
        Ok(ScoreOut { logprobs, win_k, win_v, mass })
    }

    /// Shard-0 greedy decode — the single-device CLI/eval entry point.
    pub fn generate(
        &self,
        model: &str,
        k_steps: usize,
        scored: bool,
        cache: &mut KvCache,
        last_token: i32,
    ) -> Result<GenOut> {
        self.generate_variant_on(0, model, k_steps, scored, false, cache, last_token)
    }

    /// Greedy decode of `k_steps` tokens on shard `shard`; the device
    /// appends K/V in-graph, and the state merges back into the host cache
    /// via [`Runtime::absorb_generated_on`]. On a device hit the resident
    /// buffers are DONATED to the program and the output state stays on the
    /// device.
    pub fn generate_on(
        &self,
        shard: usize,
        model: &str,
        k_steps: usize,
        scored: bool,
        cache: &mut KvCache,
        last_token: i32,
    ) -> Result<GenOut> {
        self.generate_variant_on(shard, model, k_steps, scored, false, cache, last_token)
    }

    /// Shard-0 [`Self::generate_variant_on`] (`pallas = true` runs the
    /// interpret-mode Pallas-kernel artifact — numerics-identical to the
    /// fast path, used for kernel validation and the PERF.md comparison).
    pub fn generate_variant(
        &self,
        model: &str,
        k_steps: usize,
        scored: bool,
        pallas: bool,
        cache: &mut KvCache,
        last_token: i32,
    ) -> Result<GenOut> {
        self.generate_variant_on(0, model, k_steps, scored, pallas, cache, last_token)
    }

    /// Decode with explicit program-variant selection, on shard `shard`.
    #[allow(clippy::too_many_arguments)]
    pub fn generate_variant_on(
        &self,
        shard: usize,
        model: &str,
        k_steps: usize,
        scored: bool,
        pallas: bool,
        cache: &mut KvCache,
        last_token: i32,
    ) -> Result<GenOut> {
        let sh = self.shard(shard)?;
        let c = cache.c;
        let prog = if pallas {
            self.man.generate_pallas_prog(model, k_steps, c)?.clone()
        } else {
            self.man.generate_prog(model, k_steps, c, scored)?.clone()
        };
        let exe = self.exe(shard, model, &prog)?;
        let lm = self.model(model)?;
        if cache.max_len() + k_steps > c {
            bail!(
                "generate: cache would overflow (len {} + k {} > C {})",
                cache.max_len(),
                k_steps,
                c
            );
        }
        let l = cache.l;
        let t0 = Instant::now();
        let (lens_b, tok_b) = {
            let mut bufs = lock_recover(&sh.call_buf, "call buffers");
            bufs.lens.clear();
            bufs.lens.extend(cache.lens.iter().map(|&x| x as i32));
            let lens_b = self.upload_i32(sh.device, &bufs.lens, &[l])?;
            let tok_b = self.upload_i32(sh.device, &[last_token], &[])?;
            (lens_b, tok_b)
        };
        let mut device = lock_recover(&sh.tier, "device tier");
        let acq = {
            let mut pool = lock_recover(&sh.scratch, "scratch pool");
            device.sweep();
            pool.sweep();
            device
                .acquire(&self.client, cache, &mut pool)
                .map_err(|e| classify_call("upload", e))?
        };
        match acq {
            Acquired::Resident => {
                // donation path: the program consumes the resident buffers
                // and appends in place; the output state never leaves the
                // device — only tokens/logits/lens (+ mass) come back
                let (kc_dev, vc_dev) = device.take(cache.id()).expect("acquired entry present");
                drop(device);
                let t1 = Instant::now();
                let exec_res = {
                    let arg_refs: Vec<&xla::PjRtBuffer> =
                        vec![&lm.weights[shard], &kc_dev, &vc_dev, &lens_b, &tok_b];
                    // on error the donated state is lost either way: the
                    // entry is already out of the tier, host pages stay
                    // authoritative, and the next call re-promotes — this
                    // is the invariant the scheduler's rebuild-from-arena
                    // retry leans on
                    exe.execute_with_donation(&arg_refs, &[1, 2])
                };
                let out = match exec_res {
                    Ok(o) => {
                        lock_recover(&sh.tier, "device tier").note_call_success();
                        o
                    }
                    Err(e) => {
                        let err = classify_call("execute", e.into());
                        if classify(&err).retryable() {
                            lock_recover(&sh.tier, "device tier").note_call_failure();
                        }
                        return Err(
                            err.context(format!("execute(donated) {model}/{}", prog.name))
                        );
                    }
                };
                let t2 = Instant::now();
                let mut leaves = out.into_iter().next().context("empty execution result")?;
                // leaf order mirrors the tupled path: tokens, last_logits,
                // kcache, vcache, lens [, mass]
                let mass = if scored {
                    let b = leaves.pop().context("mass")?;
                    Some(b.to_literal_sync()?.to_vec::<f32>()?)
                } else {
                    None
                };
                let lens_out = leaves.pop().context("lens")?;
                let vc_out = leaves.pop().context("vcache")?;
                let kc_out = leaves.pop().context("kcache")?;
                let logits_out = leaves.pop().context("last_logits")?;
                let tokens_out = leaves.pop().context("tokens")?;
                let tokens = tokens_out.to_literal_sync()?.to_vec::<i32>()?;
                let last_logits = logits_out.to_literal_sync()?.to_vec::<f32>()?;
                let lens = lens_out.to_literal_sync()?.to_vec::<i32>()?;
                let t3 = Instant::now();
                {
                    let mut st = lock_recover(&self.stats, "runtime stats");
                    st.calls += 1;
                    st.upload_s += (t1 - t0).as_secs_f64();
                    st.execute_s += (t2 - t1).as_secs_f64();
                    st.download_s += (t3 - t2).as_secs_f64();
                    st.bytes_h2d += 4 * (l + 1) as u64;
                    let d2h = tokens.len()
                        + last_logits.len()
                        + lens.len()
                        + mass.as_ref().map_or(0, |m| m.len());
                    st.bytes_d2h += 4 * d2h as u64;
                }
                Ok(GenOut {
                    tokens,
                    last_logits,
                    k: Vec::new(),
                    v: Vec::new(),
                    lens,
                    mass,
                    device: Some(DeviceGenOut { k: kc_out, v: vc_out }),
                })
            }
            Acquired::Transient(kc_b, vc_b) => {
                drop(device);
                let arg_refs: Vec<&xla::PjRtBuffer> =
                    vec![&lm.weights[shard], &kc_b, &vc_b, &lens_b, &tok_b];
                let t1 = Instant::now();
                let exec_res = exe.execute_b(&arg_refs);
                let t2 = Instant::now();
                let out = match exec_res {
                    Ok(o) => {
                        lock_recover(&sh.tier, "device tier").note_call_success();
                        o
                    }
                    Err(e) => {
                        let err = classify_call("execute", e.into());
                        if classify(&err).retryable() {
                            lock_recover(&sh.tier, "device tier").note_call_failure();
                        }
                        return Err(err.context(format!("execute {model}/{}", prog.name)));
                    }
                };
                let lit =
                    out[0][0].to_literal_sync().map_err(|e| classify_call("download", e.into()))?;
                let mut parts = lit.to_tuple().map_err(|e| classify_call("download", e.into()))?;
                let t3 = Instant::now();
                let mass = if scored {
                    Some(parts.pop().context("mass")?.to_vec::<f32>()?)
                } else {
                    None
                };
                let lens = parts.pop().context("lens")?.to_vec::<i32>()?;
                let v = parts.pop().context("vcache")?.to_vec::<f32>()?;
                let k = parts.pop().context("kcache")?.to_vec::<f32>()?;
                let last_logits = parts.pop().context("last_logits")?.to_vec::<f32>()?;
                let tokens = parts.pop().context("tokens")?.to_vec::<i32>()?;
                {
                    let mut st = lock_recover(&self.stats, "runtime stats");
                    st.calls += 1;
                    st.upload_s += (t1 - t0).as_secs_f64();
                    st.execute_s += (t2 - t1).as_secs_f64();
                    st.download_s += (t3 - t2).as_secs_f64();
                    st.bytes_h2d += 4 * (l + 1) as u64;
                    let d2h = last_logits.len()
                        + k.len()
                        + v.len()
                        + mass.as_ref().map_or(0, |m| m.len());
                    st.bytes_d2h += 4 * (d2h + tokens.len() + lens.len()) as u64;
                }
                Ok(GenOut { tokens, last_logits, k, v, lens, mass, device: None })
            }
        }
    }

    /// Shard-0 [`Self::absorb_generated_on`].
    pub fn absorb_generated(
        &self,
        cache: &mut KvCache,
        go: &mut GenOut,
        appended: usize,
        first_pos: u64,
    ) -> Result<()> {
        self.absorb_generated_on(0, cache, go, appended, first_pos)
    }

    /// Merge a generate call's output state into `cache` and seed the next
    /// call's image on shard `shard` (the shard that ran the generate).
    ///
    /// **Device-resident path** (`go.device` set): only the `appended` rows
    /// are downloaded from the donated output buffers (one contiguous run
    /// per (layer, head)) and appended to the host pages; the buffers are
    /// then re-installed as the cache's resident image
    /// ([`DeviceTier::install_absorbed`]) — resident rows passed through the
    /// program unchanged, the appended rows were just merged, padding stays
    /// zero, so the buffers *are* a dense gather of the post-merge cache and
    /// the next device-hit call reconciles nothing.
    ///
    /// **Host path**: the downloaded buffers are merged via
    /// [`KvCache::replace_from_device`] and adopted as the synced scratch
    /// image (taking `go.k` / `go.v`, leaving them empty).
    pub fn absorb_generated_on(
        &self,
        shard: usize,
        cache: &mut KvCache,
        go: &mut GenOut,
        appended: usize,
        first_pos: u64,
    ) -> Result<()> {
        let sh = self.shard(shard)?;
        if let Some(dev) = go.device.take() {
            let (l, h, c, dh) = (cache.l, cache.h, cache.c, cache.dh);
            for layer in 0..l {
                let new_len = go.lens[layer] as usize;
                if new_len != cache.lens[layer] + appended {
                    bail!(
                        "absorb(device): layer {layer} len {new_len} != {} + {appended}",
                        cache.lens[layer]
                    );
                }
                if let Some(&last) = cache.positions[layer].last() {
                    if first_pos <= last {
                        bail!("absorb(device): first_pos {first_pos} <= resident tail {last}");
                    }
                }
            }
            let t0 = Instant::now();
            // download the appended rows, staged [H, appended, Dh] per layer
            // (exactly append_layer's window layout) into the shard's
            // reusable call buffers — the donated decode path allocates
            // nothing
            let n = appended * dh;
            let mut bufs = lock_recover(&sh.call_buf, "call buffers");
            bufs.stage_k.clear();
            bufs.stage_k.resize(h * n, 0.0);
            bufs.stage_v.clear();
            bufs.stage_v.resize(h * n, 0.0);
            for layer in 0..l {
                let old_len = cache.lens[layer];
                for hh in 0..h {
                    let off = ((layer * h + hh) * c + old_len) * dh;
                    dev.k
                        .copy_to_host_partial(&mut bufs.stage_k[hh * n..(hh + 1) * n], off)
                        .map_err(|e| classify_call("download", e.into()))?;
                    dev.v
                        .copy_to_host_partial(&mut bufs.stage_v[hh * n..(hh + 1) * n], off)
                        .map_err(|e| classify_call("download", e.into()))?;
                }
                cache.append_layer(
                    layer,
                    &bufs.stage_k,
                    &bufs.stage_v,
                    appended,
                    appended,
                    first_pos,
                )?;
            }
            drop(bufs);
            {
                let mut st = lock_recover(&self.stats, "runtime stats");
                st.bytes_d2h += (2 * 4 * l * h * appended * dh) as u64;
                st.download_s += t0.elapsed().as_secs_f64();
            }
            // lock order: tier -> scratch
            let mut device = lock_recover(&sh.tier, "device tier");
            let mut pool = lock_recover(&sh.scratch, "scratch pool");
            device.install_absorbed(cache, dev.k, dev.v, &mut pool)?;
            return Ok(());
        }
        cache.replace_from_device(&go.k, &go.v, &go.lens, appended, first_pos)?;
        let k = std::mem::take(&mut go.k);
        let v = std::mem::take(&mut go.v);
        lock_recover(&sh.scratch, "scratch pool").absorb(cache, k, v);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::shard_slice_bytes;

    #[test]
    fn shard_slices_partition_the_pool_exactly() {
        for (total, n) in [(0usize, 3usize), (10, 3), (256 << 20, 4), (7, 8), (5, 1)] {
            let sum: usize = (0..n).map(|i| shard_slice_bytes(total, n, i)).sum();
            assert_eq!(sum, total, "slices must sum to the pool ({total} over {n} shards)");
        }
        // remainder bytes land on the lowest-indexed shards
        assert_eq!(shard_slice_bytes(10, 3, 0), 4);
        assert_eq!(shard_slice_bytes(10, 3, 1), 3);
        assert_eq!(shard_slice_bytes(10, 3, 2), 3);
    }
}
