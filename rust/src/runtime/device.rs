//! Device-residency tier: keeps each hot sequence's dense K/V image alive
//! ON THE DEVICE across program calls, so steady-state serving uploads
//! tokens and lens — not the `O(L·H·C·Dh)` cache image — per call.
//!
//! The storage stack now has three tiers, consulted in order by
//! [`super::Runtime::score`] / [`super::Runtime::generate`]:
//!
//! 1. **Device-hit** (this module): the cache's `(id, sync_gen)`-stamped
//!    [`DeviceKvState`] is resident. Host-side mutations since the stamp
//!    (ladder compaction, eviction, truncation, window appends) are
//!    reconciled by uploading ONLY the dirty slot ranges over the resident
//!    buffers ([`KvCache::stage_rows`] → partial overwrite, one contiguous
//!    run per (layer, head)); an unchanged cache uploads nothing. Generate
//!    calls donate the resident buffers to the program
//!    (`execute_with_donation`), which appends KV in place — the output
//!    buffers become the new resident state and only the appended rows are
//!    downloaded.
//! 2. **Host-hit** (the [`ScratchPool`] spill tier): no resident buffers,
//!    but a stamped host image exists — incremental gather, full upload,
//!    then promotion into this tier.
//! 3. **Cold**: full gather, full upload, promotion.
//!
//! Residency is capacity-bounded ([`DeviceTier::new`]) with cost-aware
//! **spill-to-scratch**: the victim is the entry with the cheapest
//! re-promotion (smallest `last_sync_bytes / resident bytes` — see
//! [`DeviceTier::spill_one`]; LRU breaks ties), its image is read back
//! (`copy_to_host_partial`) and handed to the scratch pool with its stamp
//! ([`ScratchPool::adopt`]), so a spilled sequence re-promotes through an
//! incremental gather instead of a full one. Entries hold a liveness token
//! ([`KvCache::residency_token`]); [`DeviceTier::sweep`] releases buffers
//! whose cache was dropped — the Drop → arena-page-return lifecycle extended
//! to device state, which is what frees a cancelled sequence's
//! `device_resident_bytes` before the next reactor round admits anyone.
//!
//! Invariants, the tier diagram, and the bench methodology live in PERF.md
//! ("Device residency").

use std::sync::Weak;

use anyhow::Result;

use super::kv::KvCache;
use super::transfer::ScratchPool;
use crate::obs::{self, EventKind};

/// One sequence's resident device K/V image (`[L, H, C, Dh]` f32 each side),
/// stamped with the cache state it equals.
pub struct DeviceKvState {
    pub k: xla::PjRtBuffer,
    pub v: xla::PjRtBuffer,
    cache_id: u64,
    /// The image equals the cache's dense gather at this sync generation;
    /// pending dirty ranges are the exact divergence (invariant I2 of
    /// PERF.md, shared with the scratch pool).
    sync_gen: u64,
    /// f32 elements per buffer side.
    elems: usize,
    /// On-device bytes (K + V) — the tier's capacity accounting unit.
    bytes: usize,
    /// Bytes the most recent acquire/install had to move to bring this
    /// entry current (0 for clean hits and donations, dirty-range size for
    /// reconciles, the full image for promotions/stale refreshes) — the
    /// re-promotion-cost proxy the spill policy minimizes.
    last_sync_bytes: u64,
    /// Source-cache liveness ([`KvCache::residency_token`]).
    alive: Weak<()>,
}

/// Cumulative residency-tier counters (folded into
/// [`super::RuntimeStats`] by the runtime).
#[derive(Clone, Copy, Debug, Default)]
pub struct DeviceStats {
    /// Calls served by a resident image (at most a dirty-range reconcile).
    pub hits: u64,
    /// Calls that had to upload a full image (cold, post-spill, or stale).
    pub misses: u64,
    /// Full images installed into the tier.
    pub promotions: u64,
    /// Spills (image read back and handed to the scratch pool).
    pub spills: u64,
    /// Generate calls whose resident buffers were donated to the program
    /// and whose outputs were re-installed as the new resident state.
    pub donations: u64,
    /// Entries released because their cache was dropped or reset.
    pub released: u64,
    /// Bytes uploaded by dirty-range reconciliation (subset of
    /// `uploaded_bytes`) — the number the device-hit path drives toward
    /// zero per decode step.
    pub reconciled_bytes: u64,
    /// Total host→device bytes moved by this tier (full uploads +
    /// reconciles).
    pub uploaded_bytes: u64,
    /// Device→host bytes moved by spills.
    pub spill_bytes_d2h: u64,
    /// Cumulative retryable device-call failures reported by the runtime
    /// ([`DeviceTier::note_call_failure`]); enough of them in a row flips
    /// the tier into sticky degraded mode.
    pub call_failures: u64,
}

/// Consecutive retryable call failures that flip the tier into sticky
/// degraded mode (the host/scratch path keeps serving; residency is out of
/// the fault loop until restart).
pub const DEGRADED_FAILURE_THRESHOLD: u32 = 3;

/// Outcome of [`DeviceTier::acquire`]: where the call's K/V image lives.
pub enum Acquired {
    /// The image is resident in the tier (entry stamped current); look it up
    /// with [`DeviceTier::resident`] or consume it with
    /// [`DeviceTier::take`] for donation.
    Resident,
    /// The image was uploaded for this call only (tier disabled, or one
    /// image exceeds the tier capacity); the buffers die with the call.
    Transient(xla::PjRtBuffer, xla::PjRtBuffer),
}

/// Capacity-bounded LRU pool of resident device images.
pub struct DeviceTier {
    /// LRU order: most recently used last.
    entries: Vec<DeviceKvState>,
    /// Byte capacity (K + V, all entries); 0 disables residency entirely —
    /// every call uploads transiently, the pre-residency behavior.
    capacity_bytes: usize,
    /// PJRT device ordinal this tier's uploads target. One tier per
    /// [`super::Runtime`] shard; device 0 for the single-device layout.
    device: usize,
    stats: DeviceStats,
    /// Reusable reconcile staging (one (layer, head) run at a time); no
    /// allocations in steady state.
    stage_k: Vec<f32>,
    stage_v: Vec<f32>,
    /// Sticky degraded mode: residency is bypassed (every acquire is
    /// transient, donations are not re-installed) after repeated retryable
    /// call failures — the device is suspect, the host path is the durable
    /// fallback. Never clears at runtime; a restart gets a fresh tier.
    degraded: bool,
    /// Consecutive retryable call failures (reset by
    /// [`Self::note_call_success`]).
    consec_failures: u32,
}

impl DeviceTier {
    pub fn new(capacity_bytes: usize) -> Self {
        Self::with_device(capacity_bytes, 0)
    }

    /// A tier whose uploads target a specific PJRT device ordinal (one tier
    /// per runtime shard).
    pub fn with_device(capacity_bytes: usize, device: usize) -> Self {
        Self {
            entries: Vec::new(),
            capacity_bytes,
            device,
            stats: DeviceStats::default(),
            stage_k: Vec::new(),
            stage_v: Vec::new(),
            degraded: false,
            consec_failures: 0,
        }
    }

    /// The PJRT device ordinal this tier's uploads target.
    pub fn device(&self) -> usize {
        self.device
    }

    pub fn stats(&self) -> DeviceStats {
        self.stats
    }

    /// Whether the tier is in sticky degraded mode.
    pub fn degraded(&self) -> bool {
        self.degraded
    }

    /// Force degraded mode on (ops/test hook; the organic path is
    /// [`Self::note_call_failure`] crossing [`DEGRADED_FAILURE_THRESHOLD`]).
    pub fn set_degraded(&mut self) {
        if !self.degraded {
            self.degraded = true;
            self.drop_entries();
        }
    }

    /// Record one RETRYABLE device-call failure (transient / device-lost —
    /// the runtime classifies before calling). Crossing the consecutive
    /// threshold flips sticky degraded mode and frees every resident image:
    /// they would never be used again, and their bytes count against the
    /// serving admission budget.
    pub fn note_call_failure(&mut self) {
        self.stats.call_failures += 1;
        self.consec_failures += 1;
        if !self.degraded && self.consec_failures >= DEGRADED_FAILURE_THRESHOLD {
            eprintln!(
                "lacache: device tier degraded after {} consecutive retryable call \
                 failures; serving via the host/scratch path",
                self.consec_failures
            );
            // shard-level quarantine (seq 0 = no single sequence at fault):
            // the trace shows WHEN the shard left the residency fast path
            obs::record(EventKind::Quarantine, 0, self.device, self.consec_failures as i64, 1);
            self.degraded = true;
            self.drop_entries();
        }
    }

    /// Record a successful device call (resets the consecutive-failure
    /// count; degraded mode, once entered, is sticky).
    pub fn note_call_success(&mut self) {
        self.consec_failures = 0;
    }

    fn drop_entries(&mut self) {
        let n = self.entries.len() as u64;
        self.entries.clear();
        self.stats.released += n;
    }

    /// Bytes currently resident (K + V across all entries) — the gauge the
    /// admission gate counts alongside arena pages and scratch staging.
    pub fn resident_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.bytes).sum()
    }

    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Resident entry for a cache, if any (no LRU side effects).
    pub fn resident(&self, cache_id: u64) -> Option<&DeviceKvState> {
        self.entries.iter().find(|e| e.cache_id == cache_id)
    }

    /// Release buffers whose source cache was dropped. Mirrors the
    /// `KvCache` Drop → arena page return path for device state: a
    /// cancelled sequence's entry is gone the next time anything consults
    /// the tier (the admission gate sweeps before counting).
    pub fn sweep(&mut self) {
        let before = self.entries.len();
        self.entries.retain(|e| e.alive.strong_count() > 0);
        self.stats.released += (before - self.entries.len()) as u64;
    }

    /// Deterministically release one cache's entry (engine reset path).
    pub fn release(&mut self, cache_id: u64) {
        let before = self.entries.len();
        self.entries.retain(|e| e.cache_id != cache_id);
        self.stats.released += (before - self.entries.len()) as u64;
    }

    /// Remove and return a cache's resident buffers — the donation path:
    /// the caller passes them to `execute_with_donation` (which consumes
    /// them) and re-installs the outputs via [`Self::install_absorbed`].
    pub fn take(&mut self, cache_id: u64) -> Option<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        let i = self.entries.iter().position(|e| e.cache_id == cache_id)?;
        let e = self.entries.remove(i);
        Some((e.k, e.v))
    }

    /// Make the call's K/V image available on the device, moving as few
    /// bytes as possible:
    ///
    /// - resident + stamp current → reconcile dirty slot ranges only
    ///   (possibly nothing);
    /// - resident + stamp stale → overwrite the resident buffers with a
    ///   fresh gather (buffers are reused, no allocation);
    /// - not resident → gather through the scratch pool (incremental when
    ///   its stamp matches), upload, and promote — spilling LRU entries to
    ///   the scratch pool until the image fits.
    ///
    /// On return the cache is synced: either the entry is stamped with the
    /// cache's current generation ([`Acquired::Resident`]) or the uploaded
    /// buffers equal its dense image ([`Acquired::Transient`]).
    pub fn acquire(
        &mut self,
        client: &xla::PjRtClient,
        cache: &mut KvCache,
        pool: &mut ScratchPool,
    ) -> Result<Acquired> {
        let elems = cache.dense_elems();
        let image_bytes = 2 * 4 * elems;
        let dims = [cache.l, cache.h, cache.c, cache.dh];
        if self.degraded {
            // degraded mode: never promote, never consult residency — a full
            // gather + transient upload per call, exactly the pre-residency
            // behavior. The arena pages stay the source of truth, so this is
            // always correct, just slower.
            self.stats.misses += 1;
            obs::record(
                EventKind::ResidencyMiss,
                cache.id(),
                self.device,
                image_bytes as i64,
                1,
            );
            let (k_b, v_b) = {
                let img = pool.gather(cache);
                (
                    client.buffer_from_host_buffer(&img.k, &dims, Some(self.device))?,
                    client.buffer_from_host_buffer(&img.v, &dims, Some(self.device))?,
                )
            };
            self.stats.uploaded_bytes += image_bytes as u64;
            return Ok(Acquired::Transient(k_b, v_b));
        }
        if let Some(i) = self.entries.iter().position(|e| e.cache_id == cache.id()) {
            if self.entries[i].elems != elems {
                // shape drift (cannot happen for a live cache; be safe)
                self.entries.remove(i);
            } else if self.entries[i].sync_gen == cache.sync_gen() {
                // device-hit: reconcile the dirty ranges in place (a clean
                // cache moves nothing and — like a no-op gather — keeps its
                // sync generation, so any scratch image stays valid too)
                let uploaded = if cache.is_clean() {
                    0
                } else {
                    let e = &self.entries[i];
                    let up = reconcile_dirty(e, cache, &mut self.stage_k, &mut self.stage_v)?;
                    cache.mark_synced();
                    self.entries[i].sync_gen = cache.sync_gen();
                    up
                };
                self.entries[i].last_sync_bytes = uploaded;
                self.stats.hits += 1;
                obs::record(
                    EventKind::ResidencyHit,
                    cache.id(),
                    self.device,
                    uploaded as i64,
                    0,
                );
                self.stats.reconciled_bytes += uploaded;
                self.stats.uploaded_bytes += uploaded;
                self.touch(i);
                return Ok(Acquired::Resident);
            } else {
                // stale stamp (another tier synced this cache since the
                // entry was made): refresh the resident buffers wholesale
                {
                    let img = pool.gather(cache);
                    let e = &self.entries[i];
                    e.k.overwrite_from_host_partial(&img.k, 0)?;
                    e.v.overwrite_from_host_partial(&img.v, 0)?;
                }
                self.entries[i].sync_gen = cache.sync_gen();
                self.entries[i].last_sync_bytes = image_bytes as u64;
                self.stats.misses += 1;
                obs::record(
                    EventKind::ResidencyMiss,
                    cache.id(),
                    self.device,
                    image_bytes as i64,
                    0,
                );
                self.stats.uploaded_bytes += image_bytes as u64;
                self.touch(i);
                // resident again: the scratch copy is redundant staging
                pool.release(cache.id());
                return Ok(Acquired::Resident);
            }
        }
        // host-hit or cold: gather (incremental when the scratch stamp
        // matches — e.g. right after a spill), upload, promote
        self.stats.misses += 1;
        obs::record(
            EventKind::ResidencyMiss,
            cache.id(),
            self.device,
            image_bytes as i64,
            0,
        );
        let retain = self.capacity_bytes > 0 && image_bytes <= self.capacity_bytes;
        if retain {
            // free room BEFORE the upload, so peak device occupancy stays
            // within capacity (plus any backend padding slack) instead of
            // capacity + one full image at upload time
            self.make_room(image_bytes, pool)?;
        }
        let (k_b, v_b) = {
            let img = pool.gather(cache);
            (
                client.buffer_from_host_buffer(&img.k, &dims, Some(self.device))?,
                client.buffer_from_host_buffer(&img.v, &dims, Some(self.device))?,
            )
        };
        self.stats.uploaded_bytes += image_bytes as u64;
        // capacity accounting uses the ACTUAL on-device size (real backends
        // may pad); the stub reports the logical size
        let device_bytes = k_b.on_device_size_bytes() + v_b.on_device_size_bytes();
        if !retain || device_bytes > self.capacity_bytes {
            return Ok(Acquired::Transient(k_b, v_b));
        }
        if device_bytes > image_bytes {
            // backend padding exceeded the pre-upload estimate
            self.make_room(device_bytes, pool)?;
        }
        self.entries.push(DeviceKvState {
            k: k_b,
            v: v_b,
            cache_id: cache.id(),
            sync_gen: cache.sync_gen(),
            elems,
            bytes: device_bytes,
            last_sync_bytes: image_bytes as u64,
            alive: cache.residency_token(),
        });
        self.stats.promotions += 1;
        // the scratch image this promotion gathered from is now redundant:
        // device-resident sequences bypass the pool, and the copy's stamp
        // goes stale on the first reconcile — keep staging at ONE image per
        // hot sequence (a later spill re-adopts into the pool)
        pool.release(cache.id());
        Ok(Acquired::Resident)
    }

    /// Install a donated generate call's output buffers as the cache's new
    /// resident state. The caller guarantees the image-equality invariant
    /// (I4 in PERF.md, extended to the device): the inputs were this
    /// cache's synced image, the program appended in place, and the
    /// appended rows were just merged into the host pages — so the buffers
    /// equal a dense gather of the cache right now. On a shape mismatch the
    /// buffers are dropped and the cache stays dirty (degraded to a future
    /// full upload, never corrupt).
    pub fn install_absorbed(
        &mut self,
        cache: &mut KvCache,
        k: xla::PjRtBuffer,
        v: xla::PjRtBuffer,
        pool: &mut ScratchPool,
    ) -> Result<()> {
        if self.degraded {
            // drop the buffers WITHOUT mark_synced: the cache stays dirty,
            // its next acquire gathers from the host pages, and the suspect
            // device holds no durable state
            return Ok(());
        }
        let elems = cache.dense_elems();
        // shape check by ELEMENT count: real backends may report a padded
        // on-device size, which only affects capacity accounting below
        if k.element_count() != elems || v.element_count() != elems {
            return Ok(());
        }
        cache.mark_synced();
        self.stats.donations += 1;
        self.release_quietly(cache.id());
        let bytes = k.on_device_size_bytes() + v.on_device_size_bytes();
        obs::record(
            EventKind::Donation,
            cache.id(),
            self.device,
            bytes as i64,
            0,
        );
        if self.capacity_bytes == 0 || bytes > self.capacity_bytes {
            return Ok(());
        }
        self.make_room(bytes, pool)?;
        self.entries.push(DeviceKvState {
            k,
            v,
            cache_id: cache.id(),
            sync_gen: cache.sync_gen(),
            elems,
            bytes,
            // the donated output IS the cache's current image: spilling it
            // costs nothing to repair, making pure decoders cheap victims
            last_sync_bytes: 0,
            alive: cache.residency_token(),
        });
        // once resident, the sequence's scratch image is dead weight (its
        // stamp goes stale on the first reconcile/donation) — drop it so
        // staging bytes track one image per hot sequence, not two
        pool.release(cache.id());
        Ok(())
    }

    /// Victim choice for the next spill: cost-aware, not pure LRU. The
    /// score is the re-promotion cost proxy `last_sync_bytes / bytes` — the
    /// entry whose spilled image would need the least repair on the way
    /// back (a clean hit or donated decoder scores 0, a heavy compactor
    /// scores high, a fresh promotion scores a full image and is protected
    /// from spill-thrash). Dead entries win outright (spilling them is
    /// free). The most-recently-used entry is exempt unless it is alone: a
    /// hot donating decoder always scores 0 and would otherwise be the
    /// perpetual victim while idle entries pin the tier. Ties fall back to
    /// LRU (entries are kept in recency order, oldest first).
    fn victim_index(&self) -> Option<usize> {
        if let Some(i) = self.entries.iter().position(|e| e.alive.strong_count() == 0) {
            return Some(i);
        }
        let n = self.entries.len();
        let candidates = if n > 1 { n - 1 } else { n };
        let mut best: Option<(f64, usize)> = None;
        for (i, e) in self.entries.iter().take(candidates).enumerate() {
            let score = e.last_sync_bytes as f64 / e.bytes.max(1) as f64;
            let better = match best {
                None => true,
                Some((s, _)) => score < s,
            };
            if better {
                best = Some((score, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// Spill one entry — the cheapest-to-re-promote victim per the cost
    /// scoring above: read its image back and hand it to the scratch pool
    /// stamped, so the spilled sequence's next call gathers incrementally
    /// (or not at all) instead of fully. Dead entries are simply dropped.
    /// Returns the spilled cache id, or None when the tier is empty.
    pub fn spill_one(&mut self, pool: &mut ScratchPool) -> Result<Option<u64>> {
        let Some(i) = self.victim_index() else {
            return Ok(None);
        };
        let e = self.entries.remove(i);
        if e.alive.strong_count() == 0 {
            self.stats.released += 1;
            return Ok(Some(e.cache_id));
        }
        self.stats.spills += 1;
        self.stats.spill_bytes_d2h += e.bytes as u64;
        obs::record(
            EventKind::Spill,
            e.cache_id,
            self.device,
            e.bytes as i64,
            0,
        );
        let mut k = vec![0.0f32; e.elems];
        let mut v = vec![0.0f32; e.elems];
        e.k.copy_to_host_partial(&mut k, 0)?;
        e.v.copy_to_host_partial(&mut v, 0)?;
        pool.adopt(e.cache_id, e.sync_gen, e.alive, k, v);
        Ok(Some(e.cache_id))
    }

    /// Read a resident entry's full image back to host vectors (tests,
    /// benches, diagnostics).
    pub fn read_back(&self, cache_id: u64) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        let Some(e) = self.resident(cache_id) else {
            return Ok(None);
        };
        let mut k = vec![0.0f32; e.elems];
        let mut v = vec![0.0f32; e.elems];
        e.k.copy_to_host_partial(&mut k, 0)?;
        e.v.copy_to_host_partial(&mut v, 0)?;
        Ok(Some((k, v)))
    }

    fn make_room(&mut self, need: usize, pool: &mut ScratchPool) -> Result<()> {
        while !self.entries.is_empty() && self.resident_bytes() + need > self.capacity_bytes {
            self.spill_one(pool)?;
        }
        Ok(())
    }

    fn release_quietly(&mut self, cache_id: u64) {
        self.entries.retain(|e| e.cache_id != cache_id);
    }

    fn touch(&mut self, i: usize) {
        if i != self.entries.len() - 1 {
            let e = self.entries.remove(i);
            self.entries.push(e);
        }
    }
}

/// Upload a cache's dirty slot ranges over a resident image: one partial
/// overwrite per (layer, head) — the dense layout makes each range one
/// contiguous `(hi-lo)·Dh` run per head. Slots beyond the current length
/// upload as zeros (the padding invariant). Returns bytes uploaded (K + V).
fn reconcile_dirty(
    e: &DeviceKvState,
    cache: &KvCache,
    stage_k: &mut Vec<f32>,
    stage_v: &mut Vec<f32>,
) -> Result<u64> {
    let (h, c, dh) = (cache.h, cache.c, cache.dh);
    let mut uploaded = 0u64;
    for layer in 0..cache.l {
        let Some((lo, hi)) = cache.dirty_range(layer) else {
            continue;
        };
        let n = (hi - lo) * dh;
        if stage_k.len() < n {
            stage_k.resize(n, 0.0);
            stage_v.resize(n, 0.0);
        }
        for head in 0..h {
            cache.stage_rows(layer, head, lo, hi, &mut stage_k[..n], &mut stage_v[..n]);
            let off = ((layer * h + head) * c + lo) * dh;
            e.k.overwrite_from_host_partial(&stage_k[..n], off)?;
            e.v.overwrite_from_host_partial(&stage_v[..n], off)?;
            uploaded += 2 * 4 * n as u64;
        }
    }
    Ok(uploaded)
}

/// Test/bench support: emulate ONE donated generate step without a compiled
/// program, exercising the exact tier contract of the runtime's donation
/// path — acquire (reconcile), take the resident buffers, "device" appends
/// one slot per layer in place via partial writes (emulated execution, not
/// transfer traffic), the host merges the same rows, and the buffers are
/// re-installed ([`DeviceTier::install_absorbed`]). Row element values come
/// from `value` (K gets `v`, V gets `-v`). Kept here — next to the contract
/// it emulates — so the device property tests and the bench scenario cannot
/// drift apart.
#[doc(hidden)]
pub fn emulate_donated_step(
    client: &xla::PjRtClient,
    tier: &mut DeviceTier,
    pool: &mut ScratchPool,
    kv: &mut KvCache,
    next_pos: &mut u64,
    mut value: impl FnMut() -> f32,
) -> Result<()> {
    let (l, h, c, dh) = (kv.l, kv.h, kv.c, kv.dh);
    let (kb, vb) = match tier.acquire(client, kv, pool)? {
        Acquired::Resident => tier.take(kv.id()).expect("resident entry"),
        Acquired::Transient(k, v) => (k, v),
    };
    for layer in 0..l {
        let slot = kv.lens[layer];
        let mut wk = vec![0.0f32; h * dh];
        let mut wv = vec![0.0f32; h * dh];
        for hh in 0..h {
            for d in 0..dh {
                let x = value();
                wk[hh * dh + d] = x;
                wv[hh * dh + d] = -x;
            }
            let off = ((layer * h + hh) * c + slot) * dh;
            kb.overwrite_from_host_partial(&wk[hh * dh..(hh + 1) * dh], off)?;
            vb.overwrite_from_host_partial(&wv[hh * dh..(hh + 1) * dh], off)?;
        }
        kv.append_layer(layer, &wk, &wv, 1, 1, *next_pos)?;
    }
    *next_pos += 1;
    tier.install_absorbed(kv, kb, vb, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::runtime::arena::KvArena;
    use crate::util::prop::PropRunner;
    use crate::util::rng::Xoshiro256;

    fn mk_cache(l: usize, h: usize, c: usize, dh: usize) -> KvCache {
        KvCache::with_arena(KvArena::new(), l, h, c, dh)
    }

    fn append_random(kv: &mut KvCache, n: usize, next_pos: &mut u64, rng: &mut Xoshiro256) {
        let (l, h, dh) = (kv.l, kv.h, kv.dh);
        for layer in 0..l {
            let wk: Vec<f32> = (0..h * n * dh).map(|_| rng.below(1000) as f32 * 0.5).collect();
            let wv: Vec<f32> = (0..h * n * dh).map(|_| rng.below(1000) as f32 * -0.5).collect();
            kv.append_layer(layer, &wk, &wv, n, n, *next_pos).unwrap();
        }
        *next_pos += n as u64;
    }

    fn image_bytes(l: usize, h: usize, c: usize, dh: usize) -> usize {
        2 * 4 * l * h * c * dh
    }

    /// The resident device image must equal a from-scratch host gather.
    fn assert_device_current(tier: &DeviceTier, kv: &KvCache) -> Result<(), String> {
        let (dk, dv) = tier
            .read_back(kv.id())
            .map_err(|e| format!("read_back: {e}"))?
            .ok_or_else(|| "expected a resident entry".to_string())?;
        let (fk, fv) = kv.gather_dense();
        prop_assert!(dk == fk, "device K image diverged from host gather");
        prop_assert!(dv == fv, "device V image diverged from host gather");
        Ok(())
    }

    /// One emulated donated step with rng-driven row values.
    fn donated_step(
        client: &xla::PjRtClient,
        tier: &mut DeviceTier,
        pool: &mut ScratchPool,
        kv: &mut KvCache,
        next_pos: &mut u64,
        rng: &mut Xoshiro256,
    ) -> anyhow::Result<()> {
        emulate_donated_step(client, tier, pool, kv, next_pos, || {
            rng.below(1000) as f32 * 0.25
        })
    }

    #[test]
    fn promote_then_hit_reconciles_only_dirty_rows() {
        let client = xla::PjRtClient::cpu().unwrap();
        let (l, h, c, dh) = (2usize, 2usize, 64usize, 4usize);
        let mut kv = mk_cache(l, h, c, dh);
        let mut pool = ScratchPool::new(2);
        let mut tier = DeviceTier::new(4 * image_bytes(l, h, c, dh));
        let mut pos = 0;
        let mut rng = Xoshiro256::new(31);
        append_random(&mut kv, 20, &mut pos, &mut rng);

        // cold call: full upload + promotion
        assert!(matches!(tier.acquire(&client, &mut kv, &mut pool).unwrap(), Acquired::Resident));
        let st = tier.stats();
        assert_eq!((st.misses, st.promotions, st.hits), (1, 1, 0));
        assert_eq!(st.uploaded_bytes, image_bytes(l, h, c, dh) as u64);
        assert_eq!(tier.resident_bytes(), image_bytes(l, h, c, dh));

        // clean hit: zero bytes move
        tier.acquire(&client, &mut kv, &mut pool).unwrap();
        let st = tier.stats();
        assert_eq!(st.hits, 1);
        assert_eq!(st.reconciled_bytes, 0);

        // one appended row per layer: reconcile uploads exactly those rows
        append_random(&mut kv, 1, &mut pos, &mut rng);
        tier.acquire(&client, &mut kv, &mut pool).unwrap();
        let st = tier.stats();
        assert_eq!(st.hits, 2);
        assert_eq!(st.reconciled_bytes, (2 * 4 * l * h * dh) as u64);
        assert_device_current(&tier, &kv).unwrap();

        // compaction: reconcile covers the moved rows + vacated tail only
        let keep: Vec<usize> = (0..kv.lens[0]).step_by(2).collect();
        for layer in 0..l {
            kv.retain_slots(layer, &keep).unwrap();
        }
        let expect: u64 = (0..l)
            .map(|layer| {
                let (lo, hi) = kv.dirty_range(layer).unwrap();
                (2 * 4 * h * (hi - lo) * dh) as u64
            })
            .sum();
        let before = tier.stats().reconciled_bytes;
        tier.acquire(&client, &mut kv, &mut pool).unwrap();
        assert_eq!(tier.stats().reconciled_bytes - before, expect);
        assert_device_current(&tier, &kv).unwrap();
        // the hot path never touched the host gather after the cold call
        assert_eq!(pool.stats().gathers_full, 1);
    }

    #[test]
    fn spill_to_scratch_then_repromotion_is_incremental_and_exact() {
        let client = xla::PjRtClient::cpu().unwrap();
        let (l, h, c, dh) = (2usize, 1usize, 32usize, 3usize);
        let mut a = mk_cache(l, h, c, dh);
        let mut b = mk_cache(l, h, c, dh);
        let mut pool = ScratchPool::new(2);
        // capacity for exactly ONE image: acquiring the other cache spills
        let mut tier = DeviceTier::new(image_bytes(l, h, c, dh));
        let mut rng = Xoshiro256::new(37);
        let (mut pa, mut pb) = (0, 0);
        append_random(&mut a, 7, &mut pa, &mut rng);
        append_random(&mut b, 12, &mut pb, &mut rng);

        tier.acquire(&client, &mut a, &mut pool).unwrap();
        tier.acquire(&client, &mut b, &mut pool).unwrap(); // spills a
        let st = tier.stats();
        assert_eq!(st.spills, 1);
        assert_eq!(st.spill_bytes_d2h, image_bytes(l, h, c, dh) as u64);
        assert!(tier.resident(a.id()).is_none());
        assert_device_current(&tier, &b).unwrap();

        // re-promotion of the spilled cache goes through the adopted scratch
        // image: NO full host gather, and the device image is byte-exact
        let full_before = pool.stats().gathers_full;
        tier.acquire(&client, &mut a, &mut pool).unwrap(); // spills b
        assert_eq!(
            pool.stats().gathers_full,
            full_before,
            "spill-to-scratch must make re-promotion incremental"
        );
        assert_device_current(&tier, &a).unwrap();

        // mutate the twice-spilled cache, re-promote, still byte-exact
        append_random(&mut b, 2, &mut pb, &mut rng);
        b.truncate_layer(1, 5).unwrap();
        tier.acquire(&client, &mut b, &mut pool).unwrap();
        assert_device_current(&tier, &b).unwrap();
        b.check_invariants().unwrap();
    }

    #[test]
    fn zero_capacity_disables_residency() {
        let client = xla::PjRtClient::cpu().unwrap();
        let mut kv = mk_cache(1, 1, 16, 2);
        let mut pool = ScratchPool::new(2);
        let mut tier = DeviceTier::new(0);
        let mut pos = 0;
        let mut rng = Xoshiro256::new(41);
        append_random(&mut kv, 4, &mut pos, &mut rng);
        for _ in 0..2 {
            match tier.acquire(&client, &mut kv, &mut pool).unwrap() {
                Acquired::Transient(k, _) => {
                    assert_eq!(k.on_device_size_bytes(), 4 * kv.dense_elems())
                }
                Acquired::Resident => panic!("disabled tier must not retain"),
            }
        }
        assert_eq!(tier.resident_bytes(), 0);
        assert_eq!(tier.stats().promotions, 0);
    }

    #[test]
    fn oversized_image_stays_transient() {
        let client = xla::PjRtClient::cpu().unwrap();
        let (l, h, c, dh) = (1usize, 1usize, 16usize, 2usize);
        let mut kv = mk_cache(l, h, c, dh);
        let mut pool = ScratchPool::new(2);
        let mut tier = DeviceTier::new(image_bytes(l, h, c, dh) / 2);
        let mut pos = 0;
        let mut rng = Xoshiro256::new(43);
        append_random(&mut kv, 4, &mut pos, &mut rng);
        assert!(matches!(
            tier.acquire(&client, &mut kv, &mut pool).unwrap(),
            Acquired::Transient(..)
        ));
        assert!(tier.is_empty(), "an image larger than the tier must not evict everyone else");
    }

    #[test]
    fn cost_aware_spill_picks_cheapest_repromotion_victim() {
        let client = xla::PjRtClient::cpu().unwrap();
        let (l, h, c, dh) = (1usize, 1usize, 32usize, 2usize);
        let mut pool = ScratchPool::new(4);
        let mut tier = DeviceTier::new(8 * image_bytes(l, h, c, dh));
        let mut rng = Xoshiro256::new(59);
        let mut a = mk_cache(l, h, c, dh);
        let mut b = mk_cache(l, h, c, dh);
        let mut third = mk_cache(l, h, c, dh);
        let (mut pa, mut pb, mut pt) = (0, 0, 0);
        append_random(&mut a, 4, &mut pa, &mut rng);
        append_random(&mut b, 4, &mut pb, &mut rng);
        append_random(&mut third, 4, &mut pt, &mut rng);
        for kv in [&mut a, &mut b, &mut third] {
            tier.acquire(&client, kv, &mut pool).unwrap();
        }
        // a: clean hit -> zero repair backlog; b: one appended row -> small
        // reconcile AND most-recently-used (exempt until alone); third:
        // untouched since promotion -> full-image cost
        tier.acquire(&client, &mut a, &mut pool).unwrap();
        append_random(&mut b, 1, &mut pb, &mut rng);
        tier.acquire(&client, &mut b, &mut pool).unwrap();
        let order: Vec<u64> = (0..3)
            .map(|_| tier.spill_one(&mut pool).unwrap().expect("an entry to spill"))
            .collect();
        assert_eq!(
            order,
            vec![a.id(), third.id(), b.id()],
            "victims must order by re-promotion cost (cheapest first), with the \
             most-recently-used entry protected until it is the only one left"
        );
        assert!(tier.is_empty());
    }

    #[test]
    fn spill_ties_fall_back_to_lru_order() {
        let client = xla::PjRtClient::cpu().unwrap();
        let (l, h, c, dh) = (1usize, 1usize, 16usize, 2usize);
        let mut pool = ScratchPool::new(4);
        let mut tier = DeviceTier::new(4 * image_bytes(l, h, c, dh));
        let mut rng = Xoshiro256::new(61);
        let mut a = mk_cache(l, h, c, dh);
        let mut b = mk_cache(l, h, c, dh);
        let mut third = mk_cache(l, h, c, dh);
        let (mut pa, mut pb, mut pt) = (0, 0, 0);
        append_random(&mut a, 3, &mut pa, &mut rng);
        append_random(&mut b, 3, &mut pb, &mut rng);
        append_random(&mut third, 3, &mut pt, &mut rng);
        for kv in [&mut a, &mut b, &mut third] {
            tier.acquire(&client, kv, &mut pool).unwrap();
        }
        // all three carry the same (full-image) score and `third` is MRU
        // (exempt): the tie between a and b must break toward a, the older
        let spilled = tier.spill_one(&mut pool).unwrap();
        assert_eq!(spilled, Some(a.id()), "equal scores must break ties by LRU");
    }

    #[test]
    fn sweep_and_release_free_dead_entries() {
        let client = xla::PjRtClient::cpu().unwrap();
        let mut pool = ScratchPool::new(2);
        let mut tier = DeviceTier::new(1 << 20);
        let mut rng = Xoshiro256::new(47);
        let mut kv = mk_cache(1, 1, 16, 2);
        let mut pos = 0;
        append_random(&mut kv, 3, &mut pos, &mut rng);
        tier.acquire(&client, &mut kv, &mut pool).unwrap();
        assert!(tier.resident_bytes() > 0);
        drop(kv);
        tier.sweep();
        assert_eq!(tier.resident_bytes(), 0, "dropped cache's buffers must be released");
        assert_eq!(tier.stats().released, 1);

        // explicit release (engine reset path)
        let mut kv2 = mk_cache(1, 1, 16, 2);
        append_random(&mut kv2, 2, &mut pos, &mut rng);
        tier.acquire(&client, &mut kv2, &mut pool).unwrap();
        tier.release(kv2.id());
        assert!(tier.is_empty());
    }

    #[test]
    fn donated_decode_steps_keep_device_exact_with_zero_reconcile() {
        let client = xla::PjRtClient::cpu().unwrap();
        let (l, h, c, dh) = (2usize, 2usize, 48usize, 3usize);
        let mut kv = mk_cache(l, h, c, dh);
        let mut pool = ScratchPool::new(2);
        let mut tier = DeviceTier::new(2 * image_bytes(l, h, c, dh));
        let mut pos = 0;
        let mut rng = Xoshiro256::new(53);
        append_random(&mut kv, 10, &mut pos, &mut rng);
        tier.acquire(&client, &mut kv, &mut pool).unwrap();
        let warm = tier.stats();
        for _ in 0..8 {
            donated_step(&client, &mut tier, &mut pool, &mut kv, &mut pos, &mut rng).unwrap();
            assert_device_current(&tier, &kv).unwrap();
        }
        let st = tier.stats();
        assert_eq!(st.donations, 8);
        assert_eq!(
            st.reconciled_bytes, warm.reconciled_bytes,
            "pure donated decode must upload zero KV bytes"
        );
        assert_eq!(
            st.uploaded_bytes, warm.uploaded_bytes,
            "pure donated decode must upload zero KV bytes"
        );
        // ... and after a host-side eviction, only the dirty rows move
        let keep: Vec<usize> = (0..kv.lens[0]).filter(|s| s % 3 != 1).collect();
        for layer in 0..l {
            kv.retain_slots(layer, &keep).unwrap();
        }
        tier.acquire(&client, &mut kv, &mut pool).unwrap();
        let st2 = tier.stats();
        assert!(st2.reconciled_bytes > st.reconciled_bytes);
        assert!(st2.reconciled_bytes - st.reconciled_bytes < image_bytes(l, h, c, dh) as u64);
        assert_device_current(&tier, &kv).unwrap();
    }

    #[test]
    fn degraded_mode_bypasses_residency() {
        let client = xla::PjRtClient::cpu().unwrap();
        let (l, h, c, dh) = (2usize, 1usize, 32usize, 2usize);
        let mut kv = mk_cache(l, h, c, dh);
        let mut pool = ScratchPool::new(2);
        let mut tier = DeviceTier::new(4 * image_bytes(l, h, c, dh));
        let mut pos = 0;
        let mut rng = Xoshiro256::new(67);
        append_random(&mut kv, 6, &mut pos, &mut rng);

        // healthy: promote to residency
        assert!(matches!(tier.acquire(&client, &mut kv, &mut pool).unwrap(), Acquired::Resident));
        assert!(tier.resident_bytes() > 0);

        // failures below the threshold don't flip the tier, and a success
        // in between resets the consecutive count
        tier.note_call_failure();
        tier.note_call_failure();
        tier.note_call_success();
        tier.note_call_failure();
        tier.note_call_failure();
        assert!(!tier.degraded());
        assert_eq!(tier.stats().call_failures, 4);

        // one more consecutive failure crosses DEGRADED_FAILURE_THRESHOLD:
        // sticky degraded, resident images freed
        tier.note_call_failure();
        assert!(tier.degraded());
        assert!(tier.is_empty());
        assert_eq!(tier.resident_bytes(), 0);

        // degraded acquire: always transient, byte-exact vs the host gather
        let (fk, fv) = kv.gather_dense();
        match tier.acquire(&client, &mut kv, &mut pool).unwrap() {
            Acquired::Transient(k, v) => {
                let mut dk = vec![0.0f32; kv.dense_elems()];
                let mut dv = vec![0.0f32; kv.dense_elems()];
                k.copy_to_host_partial(&mut dk, 0).unwrap();
                v.copy_to_host_partial(&mut dv, 0).unwrap();
                assert_eq!(dk, fk);
                assert_eq!(dv, fv);
            }
            Acquired::Resident => panic!("degraded tier must not promote"),
        }

        // donated-step contract still holds end to end: the host pages stay
        // the source of truth even though install_absorbed drops the buffers
        donated_step(&client, &mut tier, &mut pool, &mut kv, &mut pos, &mut rng).unwrap();
        assert!(tier.is_empty(), "degraded tier must not re-install donations");
        kv.check_invariants().unwrap();
        let (gk, _) = kv.gather_dense();
        assert_eq!(gk.len(), kv.dense_elems());

        // success does NOT un-degrade (sticky until restart)
        tier.note_call_success();
        assert!(tier.degraded());
    }

    #[test]
    fn tier_bound_to_killed_device_fails_and_degrades_alone() {
        let client = xla::PjRtClient::cpu_with_devices(2).unwrap();
        let (l, h, c, dh) = (1usize, 1usize, 16usize, 2usize);
        let mut kv0 = mk_cache(l, h, c, dh);
        let mut kv1 = mk_cache(l, h, c, dh);
        let mut pool0 = ScratchPool::new(2);
        let mut pool1 = ScratchPool::new(2);
        let mut tier0 = DeviceTier::with_device(4 * image_bytes(l, h, c, dh), 0);
        let mut tier1 = DeviceTier::with_device(4 * image_bytes(l, h, c, dh), 1);
        assert_eq!((tier0.device(), tier1.device()), (0, 1));
        let mut rng = Xoshiro256::new(71);
        let (mut p0, mut p1) = (0, 0);
        append_random(&mut kv0, 3, &mut p0, &mut rng);
        append_random(&mut kv1, 3, &mut p1, &mut rng);
        tier0.acquire(&client, &mut kv0, &mut pool0).unwrap();

        client.kill_device(1);
        let err = tier1.acquire(&client, &mut kv1, &mut pool1).unwrap_err();
        assert!(format!("{err}").contains("DEVICE_LOST"), "unexpected error: {err}");
        for _ in 0..DEGRADED_FAILURE_THRESHOLD {
            tier1.note_call_failure();
        }
        assert!(tier1.degraded(), "lost device's tier must degrade");
        assert!(!tier0.degraded(), "sibling shard must stay healthy");

        // the surviving shard still serves residency
        tier0.acquire(&client, &mut kv0, &mut pool0).unwrap();
        assert!(tier0.resident_bytes() > 0);
        assert_device_current(&tier0, &kv0).unwrap();
    }

    #[derive(Debug, Clone, Copy)]
    enum Op {
        Append { n: usize },
        Retain { seed: u64 },
        Truncate { seed: u64 },
        DeviceStep,
        Spill,
    }

    #[test]
    fn device_image_matches_host_gather_property() {
        // random append/compact/evict/spill/absorb sequences over TWO caches
        // sharing one tier + one scratch pool: after every op, acquiring a
        // cache must leave a resident device image byte-identical to a
        // from-scratch host gather — including after spill and
        // re-promotion, and with the scratch pool small enough to thrash
        PropRunner::new(25).run(
            |rng: &mut Xoshiro256| {
                let h = 1 + rng.below(2) as usize;
                let dh = 1 + rng.below(3) as usize;
                let cap_images = 1 + rng.below(2) as usize; // 1 forces spills
                let ops: Vec<(usize, Op)> = (0..12)
                    .map(|_| {
                        let which = rng.below(2) as usize;
                        let op = match rng.below(6) {
                            0 | 1 => Op::Append { n: 1 + rng.below(5) as usize },
                            2 => Op::Retain { seed: rng.below(u64::MAX) },
                            3 => Op::Truncate { seed: rng.below(u64::MAX) },
                            4 => Op::DeviceStep,
                            _ => Op::Spill,
                        };
                        (which, op)
                    })
                    .collect();
                (h, dh, cap_images, ops)
            },
            |(h, dh, cap_images, ops)| {
                let (h, dh) = (*h, *dh);
                let (l, c) = (2usize, 48usize);
                let client = xla::PjRtClient::cpu().unwrap();
                let mut caches = [mk_cache(l, h, c, dh), mk_cache(l, h, c, dh)];
                let mut next_pos = [0u64, 0u64];
                let mut pool = ScratchPool::new(1); // worst case: thrashing
                let mut tier = DeviceTier::new(cap_images * image_bytes(l, h, c, dh));
                let mut rng = Xoshiro256::new(0xca11);
                for &(which, op) in ops {
                    let kv = &mut caches[which];
                    match op {
                        Op::Append { n } => {
                            if kv.max_len() + n > c {
                                continue;
                            }
                            append_random(kv, n, &mut next_pos[which], &mut rng);
                        }
                        Op::Retain { seed } => {
                            let mut krng = Xoshiro256::new(seed);
                            for layer in 0..l {
                                let n = kv.lens[layer];
                                let keep: Vec<usize> =
                                    (0..n).filter(|_| krng.below(3) > 0).collect();
                                kv.retain_slots(layer, &keep).unwrap();
                            }
                        }
                        Op::Truncate { seed } => {
                            let mut trng = Xoshiro256::new(seed);
                            for layer in 0..l {
                                let n = kv.lens[layer];
                                let new_len = trng.below(n as u64 + 1) as usize;
                                kv.truncate_layer(layer, new_len).unwrap();
                            }
                        }
                        Op::DeviceStep => {
                            if kv.max_len() + 1 > c {
                                continue;
                            }
                            donated_step(
                                &client,
                                &mut tier,
                                &mut pool,
                                kv,
                                &mut next_pos[which],
                                &mut rng,
                            )
                            .map_err(|e| format!("donated_step: {e}"))?;
                        }
                        Op::Spill => {
                            tier.spill_one(&mut pool).map_err(|e| format!("spill: {e}"))?;
                        }
                    }
                    prop_assert!(caches[which].check_invariants().is_ok(), "invariants broken");
                    // acquiring either cache must yield an exact device image
                    // (capacity always fits at least one image)
                    for idx in [which, 1 - which] {
                        let kv = &mut caches[idx];
                        match tier
                            .acquire(&client, kv, &mut pool)
                            .map_err(|e| format!("acquire: {e}"))?
                        {
                            Acquired::Resident => assert_device_current(&tier, kv)?,
                            Acquired::Transient(..) => {
                                return Err("image unexpectedly exceeded capacity".into())
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
