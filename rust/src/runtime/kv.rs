//! Host-side KV cache state over the shared paged arena: per-layer page
//! tables + occupancy + original-token-position bookkeeping.
//!
//! Rows live in fixed-size arena pages ([`PAGE_SLOTS`] slots, each slot a
//! contiguous `[H, Dh]` row). Slot order within a layer is time order;
//! eviction is an order-preserving in-place remap (`retain_slots`) that only
//! touches rows whose slot index changes, after which slot index ==
//! cache-relative RoPE position on the device side. The device-contiguous
//! `[L, H, C, Dh]` layout is materialized on demand ([`KvCache::gather_dense`])
//! at program-call time, so a sequence's host memory tracks its actual
//! occupancy (`lens`) instead of the compiled capacity `C`.

use anyhow::{bail, Result};

use super::arena::{KvArena, Page, PAGE_SLOTS};

pub struct KvCache {
    pub l: usize,
    pub h: usize,
    pub c: usize,
    pub dh: usize,
    arena: KvArena,
    /// Per-layer page table: page `i` backs slots
    /// `[i * PAGE_SLOTS, (i + 1) * PAGE_SLOTS)`.
    pages: Vec<Vec<Page>>,
    /// Valid slot count per layer.
    pub lens: Vec<usize>,
    /// Original token index of each valid slot, per layer (time-ordered).
    pub positions: Vec<Vec<u64>>,
    /// Accumulated attention mass per valid slot, per layer (H2O-family
    /// bookkeeping; stays zero on the fast path).
    pub mass: Vec<Vec<f64>>,
}

impl KvCache {
    /// Allocate against the process-wide arena (the serving default).
    pub fn new(l: usize, h: usize, c: usize, dh: usize) -> Self {
        Self::with_arena(KvArena::global().clone(), l, h, c, dh)
    }

    /// Allocate against a specific arena (isolated pools for tests/benches).
    pub fn with_arena(arena: KvArena, l: usize, h: usize, c: usize, dh: usize) -> Self {
        Self {
            l,
            h,
            c,
            dh,
            arena,
            pages: (0..l).map(|_| Vec::new()).collect(),
            lens: vec![0; l],
            positions: vec![Vec::new(); l],
            mass: vec![Vec::new(); l],
        }
    }

    /// Floats per slot row (`H * Dh`) — the arena pooling key.
    #[inline]
    pub fn row_width(&self) -> usize {
        self.h * self.dh
    }

    pub fn lens_i32(&self) -> Vec<i32> {
        self.lens.iter().map(|&x| x as i32).collect()
    }

    /// Logical bytes for valid slots (the paper's OOM-accounting metric).
    pub fn kv_bytes(&self) -> usize {
        self.lens.iter().map(|&n| 2 * self.h * n * self.dh * 4).sum()
    }

    /// Actual bytes held in the arena (page-granular occupancy — what the
    /// serving admission control sees).
    pub fn resident_bytes(&self) -> usize {
        let per = Page::bytes(self.row_width());
        self.pages.iter().map(|t| t.len() * per).sum()
    }

    /// Pages currently mapped for one layer.
    pub fn n_pages(&self, layer: usize) -> usize {
        self.pages[layer].len()
    }

    /// Max occupancy across layers.
    pub fn max_len(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }

    /// One slot's K row for one head (`Dh` floats).
    pub fn row_k(&self, layer: usize, head: usize, slot: usize) -> &[f32] {
        let off = ((slot % PAGE_SLOTS) * self.h + head) * self.dh;
        &self.pages[layer][slot / PAGE_SLOTS].k[off..off + self.dh]
    }

    /// One slot's V row for one head (`Dh` floats).
    pub fn row_v(&self, layer: usize, head: usize, slot: usize) -> &[f32] {
        let off = ((slot % PAGE_SLOTS) * self.h + head) * self.dh;
        &self.pages[layer][slot / PAGE_SLOTS].v[off..off + self.dh]
    }

    fn ensure_pages(&mut self, layer: usize, new_len: usize) -> Result<()> {
        let needed = new_len.div_ceil(PAGE_SLOTS);
        while self.pages[layer].len() < needed {
            let page = self.arena.alloc(self.row_width())?;
            self.pages[layer].push(page);
        }
        Ok(())
    }

    fn release_excess(&mut self, layer: usize) {
        let needed = self.lens[layer].div_ceil(PAGE_SLOTS);
        let rw = self.row_width();
        while self.pages[layer].len() > needed {
            let page = self.pages[layer].pop().unwrap();
            self.arena.free(rw, page);
        }
    }

    /// Append one layer's window K/V rows (from a score program's output,
    /// shaped `[H, W, Dh]` with `n_valid <= W` rows valid) at the tail.
    pub fn append_layer(
        &mut self,
        layer: usize,
        win_k: &[f32],
        win_v: &[f32],
        w: usize,
        n_valid: usize,
        first_pos: u64,
    ) -> Result<()> {
        let len = self.lens[layer];
        if len + n_valid > self.c {
            bail!("cache overflow: layer {layer} len {len} + {n_valid} > C {}", self.c);
        }
        debug_assert_eq!(win_k.len(), self.h * w * self.dh);
        self.ensure_pages(layer, len + n_valid)?;
        let (h, dh) = (self.h, self.dh);
        for i in 0..n_valid {
            let slot = len + i;
            let page = &mut self.pages[layer][slot / PAGE_SLOTS];
            for hh in 0..h {
                let src = (hh * w + i) * dh;
                let dst = ((slot % PAGE_SLOTS) * h + hh) * dh;
                page.k[dst..dst + dh].copy_from_slice(&win_k[src..src + dh]);
                page.v[dst..dst + dh].copy_from_slice(&win_v[src..src + dh]);
            }
        }
        self.lens[layer] = len + n_valid;
        for i in 0..n_valid {
            self.positions[layer].push(first_pos + i as u64);
            self.mass[layer].push(0.0);
        }
        Ok(())
    }

    /// Order-preserving compaction: keep exactly the slots in `keep`
    /// (sorted, unique, all < lens[layer]) for one layer. Rows whose slot
    /// index is unchanged are untouched; the rest move once (in-page
    /// `copy_within`, or one bounce through a scratch row across pages), and
    /// emptied tail pages return to the arena.
    pub fn retain_slots(&mut self, layer: usize, keep: &[usize]) -> Result<()> {
        let len = self.lens[layer];
        let mut prev: Option<usize> = None;
        for &s in keep {
            if s >= len {
                bail!("retain_slots: slot {s} >= len {len}");
            }
            if let Some(p) = prev {
                if s <= p {
                    bail!("retain_slots: indices must be strictly increasing");
                }
            }
            prev = Some(s);
        }
        let rw = self.row_width();
        let mut scratch_k = vec![0.0f32; rw];
        let mut scratch_v = vec![0.0f32; rw];
        for (dst_i, &src_i) in keep.iter().enumerate() {
            if dst_i == src_i {
                continue; // prefix already in place
            }
            let (sp, so) = (src_i / PAGE_SLOTS, (src_i % PAGE_SLOTS) * rw);
            let (dp, dof) = (dst_i / PAGE_SLOTS, (dst_i % PAGE_SLOTS) * rw);
            if sp == dp {
                let page = &mut self.pages[layer][sp];
                page.k.copy_within(so..so + rw, dof);
                page.v.copy_within(so..so + rw, dof);
            } else {
                scratch_k.copy_from_slice(&self.pages[layer][sp].k[so..so + rw]);
                scratch_v.copy_from_slice(&self.pages[layer][sp].v[so..so + rw]);
                let dpage = &mut self.pages[layer][dp];
                dpage.k[dof..dof + rw].copy_from_slice(&scratch_k);
                dpage.v[dof..dof + rw].copy_from_slice(&scratch_v);
            }
        }
        self.positions[layer] = keep.iter().map(|&s| self.positions[layer][s]).collect();
        self.mass[layer] = keep.iter().map(|&s| self.mass[layer][s]).collect();
        self.lens[layer] = keep.len();
        self.release_excess(layer);
        Ok(())
    }

    /// Drop the tail so exactly `new_len` slots remain (the engine's rollback
    /// of over-generated decode steps). Emptied pages return to the arena.
    pub fn truncate_layer(&mut self, layer: usize, new_len: usize) -> Result<()> {
        if new_len > self.lens[layer] {
            bail!("truncate_layer: {new_len} > len {}", self.lens[layer]);
        }
        self.lens[layer] = new_len;
        self.positions[layer].truncate(new_len);
        self.mass[layer].truncate(new_len);
        self.release_excess(layer);
        Ok(())
    }

    /// Merge a generate program's output state (device-shaped `[L, H, C, Dh]`
    /// buffers with `appended` new slots per layer) back into the paged
    /// store. Only the appended rows are copied — resident rows were uploaded
    /// from this cache and are unchanged on the device. `first_pos` is the
    /// engine's authoritative stream position of the first appended token:
    /// it cannot be inferred from `positions.last() + 1`, which drifts
    /// whenever the recency tail was evicted (any `n_recent = 0` config).
    pub fn replace_from_device(
        &mut self,
        k: &[f32],
        v: &[f32],
        lens: &[i32],
        appended: usize,
        first_pos: u64,
    ) -> Result<()> {
        debug_assert_eq!(k.len(), self.l * self.h * self.c * self.dh);
        let (h, c, dh) = (self.h, self.c, self.dh);
        for l in 0..self.l {
            let new_len = lens[l] as usize;
            let old_len = self.lens[l];
            if new_len != old_len + appended {
                bail!("replace_from_device: layer {l} len {new_len} != {old_len} + {appended}");
            }
            if let Some(&last) = self.positions[l].last() {
                if first_pos <= last {
                    bail!("replace_from_device: first_pos {first_pos} <= resident tail {last}");
                }
            }
            self.ensure_pages(l, new_len)?;
            for slot in old_len..new_len {
                let page = &mut self.pages[l][slot / PAGE_SLOTS];
                for hh in 0..h {
                    let src = ((l * h + hh) * c + slot) * dh;
                    let dst = ((slot % PAGE_SLOTS) * h + hh) * dh;
                    page.k[dst..dst + dh].copy_from_slice(&k[src..src + dh]);
                    page.v[dst..dst + dh].copy_from_slice(&v[src..src + dh]);
                }
            }
            for i in 0..appended {
                self.positions[l].push(first_pos + i as u64);
                self.mass[l].push(0.0);
            }
            self.lens[l] = new_len;
        }
        Ok(())
    }

    /// Materialize the device-contiguous `[L, H, C, Dh]` K/V buffers
    /// (invalid slots zero-padded) for a program call.
    pub fn gather_dense(&self) -> (Vec<f32>, Vec<f32>) {
        let (h, c, dh) = (self.h, self.c, self.dh);
        let mut k = vec![0.0f32; self.l * h * c * dh];
        let mut v = vec![0.0f32; self.l * h * c * dh];
        for l in 0..self.l {
            for slot in 0..self.lens[l] {
                let page = &self.pages[l][slot / PAGE_SLOTS];
                for hh in 0..h {
                    let src = ((slot % PAGE_SLOTS) * h + hh) * dh;
                    let dst = ((l * h + hh) * c + slot) * dh;
                    k[dst..dst + dh].copy_from_slice(&page.k[src..src + dh]);
                    v[dst..dst + dh].copy_from_slice(&page.v[src..src + dh]);
                }
            }
        }
        (k, v)
    }

    /// Add per-slot attention mass from a scored program (`mass_row` is the
    /// device `[C+W]` or `[C]` row for `layer`; only the first lens entries
    /// apply to resident slots).
    pub fn add_mass(&mut self, layer: usize, mass_row: &[f32]) {
        let n = self.lens[layer].min(mass_row.len());
        for i in 0..n {
            self.mass[layer][i] += mass_row[i] as f64;
        }
    }

    /// Consistency invariants (used by tests and debug assertions).
    pub fn check_invariants(&self) -> Result<()> {
        for l in 0..self.l {
            if self.lens[l] > self.c {
                bail!("len > capacity");
            }
            if self.positions[l].len() != self.lens[l] || self.mass[l].len() != self.lens[l] {
                bail!("bookkeeping length mismatch");
            }
            if self.pages[l].len() != self.lens[l].div_ceil(PAGE_SLOTS) {
                bail!(
                    "page table mismatch in layer {l}: {} pages for {} slots",
                    self.pages[l].len(),
                    self.lens[l]
                );
            }
            for w in self.positions[l].windows(2) {
                if w[0] >= w[1] {
                    bail!("positions not strictly increasing in layer {l}");
                }
            }
        }
        Ok(())
    }
}

impl Clone for KvCache {
    /// Deep copy: fresh pages from the same arena. Panics if the arena
    /// budget cannot accommodate the copy (clones are a bench/test affair;
    /// the serving path never clones caches).
    fn clone(&self) -> Self {
        let mut out = KvCache::with_arena(self.arena.clone(), self.l, self.h, self.c, self.dh);
        let rw = self.row_width();
        for l in 0..self.l {
            for page in &self.pages[l] {
                let mut p = out
                    .arena
                    .alloc(rw)
                    .expect("kv-arena budget exceeded while cloning KvCache");
                p.k.copy_from_slice(&page.k);
                p.v.copy_from_slice(&page.v);
                out.pages[l].push(p);
            }
        }
        out.lens = self.lens.clone();
        out.positions = self.positions.clone();
        out.mass = self.mass.clone();
        out
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        let rw = self.row_width();
        for table in &mut self.pages {
            for page in table.drain(..) {
                self.arena.free(rw, page);
            }
        }
    }
}

impl std::fmt::Debug for KvCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvCache")
            .field("l", &self.l)
            .field("h", &self.h)
            .field("c", &self.c)
            .field("dh", &self.dh)
            .field("lens", &self.lens)
            .field("resident_bytes", &self.resident_bytes())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::PropRunner;
    use crate::util::rng::Xoshiro256;

    fn filled(l: usize, h: usize, c: usize, dh: usize, n: usize) -> KvCache {
        let mut kv = KvCache::with_arena(KvArena::new(), l, h, c, dh);
        for layer in 0..l {
            let w = n;
            let mut wk = vec![0.0f32; h * w * dh];
            let mut wv = vec![0.0f32; h * w * dh];
            for hh in 0..h {
                for i in 0..w {
                    for d in 0..dh {
                        wk[(hh * w + i) * dh + d] = (layer * 1000 + hh * 100 + i) as f32;
                        wv[(hh * w + i) * dh + d] = -((layer * 1000 + hh * 100 + i) as f32);
                    }
                }
            }
            kv.append_layer(layer, &wk, &wv, w, n, 0).unwrap();
        }
        kv
    }

    #[test]
    fn append_and_invariants() {
        let kv = filled(2, 2, 16, 4, 5);
        assert_eq!(kv.lens, vec![5, 5]);
        kv.check_invariants().unwrap();
        assert_eq!(kv.kv_bytes(), 2 * 2 * 2 * 5 * 4 * 4);
        // 5 slots -> one page per layer; resident bytes are page-granular
        assert_eq!(kv.resident_bytes(), 2 * Page::bytes(2 * 4));
    }

    #[test]
    fn append_overflow_fails() {
        let mut kv = KvCache::with_arena(KvArena::new(), 1, 1, 4, 2);
        let w = vec![0.0; 6 * 2];
        assert!(kv.append_layer(0, &w, &w, 6, 6, 0).is_err());
    }

    #[test]
    fn retain_gathers_rows() {
        let mut kv = filled(2, 2, 16, 4, 6);
        kv.retain_slots(0, &[0, 2, 5]).unwrap();
        assert_eq!(kv.lens[0], 3);
        assert_eq!(kv.positions[0], vec![0, 2, 5]);
        // head 1 row 1 should now hold original slot 2's value (=102)
        assert_eq!(kv.row_k(0, 1, 1)[0], 102.0);
        assert_eq!(kv.row_v(0, 1, 1)[0], -102.0);
        // layer 1 untouched
        assert_eq!(kv.lens[1], 6);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn retain_rejects_bad_indices() {
        let mut kv = filled(1, 1, 8, 2, 4);
        assert!(kv.retain_slots(0, &[2, 1]).is_err());
        assert!(kv.retain_slots(0, &[0, 9]).is_err());
        assert!(kv.retain_slots(0, &[1, 1]).is_err());
    }

    #[test]
    fn mass_tracking() {
        let mut kv = filled(1, 1, 8, 2, 4);
        kv.add_mass(0, &[1.0, 2.0, 3.0, 4.0, 99.0]);
        assert_eq!(kv.mass[0], vec![1.0, 2.0, 3.0, 4.0]);
        kv.retain_slots(0, &[1, 3]).unwrap();
        assert_eq!(kv.mass[0], vec![2.0, 4.0]);
    }

    #[test]
    fn retain_across_page_boundaries_frees_tail_pages() {
        // 40 slots = 3 pages; keep a sparse 10 -> 1 page
        let mut kv = filled(1, 2, 64, 4, 40);
        let arena_before = kv.resident_bytes();
        assert_eq!(kv.n_pages(0), 3);
        assert_eq!(arena_before, 3 * Page::bytes(2 * 4));
        let keep: Vec<usize> = (0..40).step_by(4).collect();
        kv.retain_slots(0, &keep).unwrap();
        assert_eq!(kv.lens[0], 10);
        assert_eq!(kv.n_pages(0), 1);
        kv.check_invariants().unwrap();
        // moved rows carry their content (slot 5 now holds original slot 20)
        assert_eq!(kv.row_k(0, 1, 5)[0], 120.0);
        assert_eq!(kv.positions[0], (0..40).step_by(4).collect::<Vec<u64>>());
    }

    #[test]
    fn truncate_layer_drops_tail_and_pages() {
        let mut kv = filled(1, 1, 64, 2, 33); // 3 pages
        kv.add_mass(0, &[1.0; 33]);
        kv.truncate_layer(0, 16).unwrap(); // exactly one page
        assert_eq!(kv.lens[0], 16);
        assert_eq!(kv.n_pages(0), 1);
        assert_eq!(kv.positions[0].len(), 16);
        assert_eq!(kv.mass[0].len(), 16);
        kv.check_invariants().unwrap();
        assert!(kv.truncate_layer(0, 17).is_err());
    }

    #[test]
    fn replace_from_device_uses_stream_counter_not_tail_inference() {
        // regression: after evicting the recency tail, the next position must
        // come from the engine's stream counter, not `positions.last() + 1`
        let mut kv = filled(1, 1, 8, 2, 6); // positions 0..=5
        kv.retain_slots(0, &[0, 1]).unwrap(); // tail evicted
        let mut k = vec![0.0f32; 8 * 2];
        let mut v = vec![0.0f32; 8 * 2];
        k[2 * 2] = 7.5; // slot 2, head 0, d 0
        v[2 * 2] = -7.5;
        kv.replace_from_device(&k, &v, &[3], 1, 6).unwrap();
        // the appended slot is stream token 6; the old inference gave 2
        assert_eq!(kv.positions[0], vec![0, 1, 6]);
        assert_eq!(kv.row_k(0, 0, 2)[0], 7.5);
        assert_eq!(kv.row_v(0, 0, 2)[0], -7.5);
        kv.check_invariants().unwrap();
        // non-monotone first_pos is rejected
        let err = kv.replace_from_device(&k, &v, &[4], 1, 3).unwrap_err();
        assert!(format!("{err}").contains("first_pos"));
    }

    #[test]
    fn drop_returns_pages_to_arena() {
        let arena = KvArena::new();
        {
            let kv = {
                let mut kv = KvCache::with_arena(arena.clone(), 2, 1, 64, 2);
                let w = vec![0.0f32; 20 * 2];
                kv.append_layer(0, &w, &w, 20, 20, 0).unwrap();
                kv.append_layer(1, &w, &w, 20, 20, 0).unwrap();
                kv
            };
            assert_eq!(arena.stats().bytes_in_use, 4 * Page::bytes(2));
            drop(kv);
        }
        let st = arena.stats();
        assert_eq!(st.bytes_in_use, 0);
        assert_eq!(st.bytes_pooled, 4 * Page::bytes(2));
    }

    #[test]
    fn clone_is_deep() {
        let kv = filled(1, 1, 16, 2, 5);
        let mut c = kv.clone();
        c.retain_slots(0, &[0, 4]).unwrap();
        assert_eq!(kv.lens[0], 5);
        assert_eq!(c.lens[0], 2);
        assert_eq!(kv.row_k(0, 0, 1)[0], 1.0);
        assert_eq!(c.row_k(0, 0, 1)[0], 4.0);
    }

    /// Reference model: plain dense per-layer rows, the old storage layout.
    struct DenseRef {
        h: usize,
        dh: usize,
        rows_k: Vec<Vec<f32>>, // per slot: [H * Dh]
        rows_v: Vec<Vec<f32>>,
        positions: Vec<u64>,
    }

    impl DenseRef {
        fn append(&mut self, win_k: &[f32], win_v: &[f32], w: usize, n_valid: usize, p0: u64) {
            for i in 0..n_valid {
                let mut rk = vec![0.0f32; self.h * self.dh];
                let mut rv = vec![0.0f32; self.h * self.dh];
                for hh in 0..self.h {
                    for d in 0..self.dh {
                        rk[hh * self.dh + d] = win_k[(hh * w + i) * self.dh + d];
                        rv[hh * self.dh + d] = win_v[(hh * w + i) * self.dh + d];
                    }
                }
                self.rows_k.push(rk);
                self.rows_v.push(rv);
                self.positions.push(p0 + i as u64);
            }
        }

        fn retain(&mut self, keep: &[usize]) {
            self.rows_k = keep.iter().map(|&s| self.rows_k[s].clone()).collect();
            self.rows_v = keep.iter().map(|&s| self.rows_v[s].clone()).collect();
            self.positions = keep.iter().map(|&s| self.positions[s]).collect();
        }
    }

    #[derive(Debug)]
    enum Op {
        Append { w: usize, n_valid: usize, seed: u32 },
        Retain { keep_mask_seed: u64 },
    }

    #[test]
    fn paged_store_matches_dense_reference_property() {
        // arena-backed page layout must be observationally identical to the
        // old dense layout: same gather_dense output, rows, and positions
        // under arbitrary append/retain interleavings
        PropRunner::new(60).run(
            |rng: &mut Xoshiro256| {
                let h = 1 + rng.below(3) as usize;
                let dh = 1 + rng.below(4) as usize;
                let ops: Vec<Op> = (0..10)
                    .map(|_| {
                        if rng.below(3) < 2 {
                            Op::Append {
                                w: 1 + rng.below(9) as usize,
                                n_valid: 0, // filled below
                                seed: rng.below(u32::MAX as u64) as u32,
                            }
                        } else {
                            Op::Retain { keep_mask_seed: rng.below(u64::MAX) }
                        }
                    })
                    .map(|op| match op {
                        Op::Append { w, seed, .. } => {
                            Op::Append { w, n_valid: 1 + (seed as usize) % w, seed }
                        }
                        other => other,
                    })
                    .collect();
                (h, dh, ops)
            },
            |(h, dh, ops)| {
                let (h, dh) = (*h, *dh);
                let c = 96;
                let mut kv = KvCache::with_arena(KvArena::new(), 1, h, c, dh);
                let mut dref = DenseRef {
                    h,
                    dh,
                    rows_k: Vec::new(),
                    rows_v: Vec::new(),
                    positions: Vec::new(),
                };
                let mut next_pos = 0u64;
                for op in ops {
                    match *op {
                        Op::Append { w, n_valid, seed } => {
                            if kv.lens[0] + n_valid > c {
                                continue;
                            }
                            let mut vrng = Xoshiro256::new(seed as u64 + 1);
                            let wk: Vec<f32> =
                                (0..h * w * dh).map(|_| vrng.below(1000) as f32).collect();
                            let wv: Vec<f32> =
                                (0..h * w * dh).map(|_| vrng.below(1000) as f32).collect();
                            kv.append_layer(0, &wk, &wv, w, n_valid, next_pos).unwrap();
                            dref.append(&wk, &wv, w, n_valid, next_pos);
                            next_pos += n_valid as u64;
                        }
                        Op::Retain { keep_mask_seed } => {
                            let n = kv.lens[0];
                            if n == 0 {
                                continue;
                            }
                            let mut krng = Xoshiro256::new(keep_mask_seed);
                            let keep: Vec<usize> =
                                (0..n).filter(|_| krng.below(2) == 0).collect();
                            kv.retain_slots(0, &keep).unwrap();
                            dref.retain(&keep);
                        }
                    }
                    // full observational equivalence after every op
                    prop_assert!(
                        kv.lens[0] == dref.rows_k.len(),
                        "len {} != ref {}",
                        kv.lens[0],
                        dref.rows_k.len()
                    );
                    prop_assert!(kv.positions[0] == dref.positions, "positions diverged");
                    prop_assert!(kv.check_invariants().is_ok(), "invariants broken");
                    let (dk, dv) = kv.gather_dense();
                    for slot in 0..kv.lens[0] {
                        for hh in 0..h {
                            for d in 0..dh {
                                let got_k = dk[(hh * c + slot) * dh + d];
                                let got_v = dv[(hh * c + slot) * dh + d];
                                let want_k = dref.rows_k[slot][hh * dh + d];
                                let want_v = dref.rows_v[slot][hh * dh + d];
                                prop_assert!(
                                    got_k == want_k && got_v == want_v,
                                    "row mismatch at slot {slot} head {hh} d {d}"
                                );
                            }
                        }
                    }
                    // padding beyond lens stays zero
                    for slot in kv.lens[0]..c {
                        for hh in 0..h {
                            for d in 0..dh {
                                prop_assert!(
                                    dk[(hh * c + slot) * dh + d] == 0.0,
                                    "padding not zero at slot {slot}"
                                );
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
